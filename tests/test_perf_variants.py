"""Correctness of the §Perf variants: grouped MoE dispatch, context-parallel
attention (constraint-only), int8 KV cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers import (
    MoEConfig,
    kv_dequantize,
    kv_quantize,
    moe_apply_flat,
    moe_apply_grouped,
    moe_init,
)
from repro.models.lm import decode_step, forward, init_params, prefill, reduced


def test_grouped_moe_matches_flat(rng):
    cfg = MoEConfig(d_model=64, d_ff_expert=32, num_experts=8, top_k=2,
                    num_shared=1, capacity_factor=16.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(0, 1, (4, 16, 64)), jnp.float32)
    o1, a1 = moe_apply_flat(params, cfg, x)
    o2, a2 = moe_apply_grouped(params, cfg._replace(groups=4), x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    assert float(a1) == pytest.approx(float(a2), abs=1e-6)


def test_grouped_moe_grad_finite(rng):
    cfg = MoEConfig(d_model=32, d_ff_expert=16, num_experts=4, top_k=2,
                    groups=2, capacity_factor=8.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, 32)), jnp.float32)
    g = jax.grad(lambda p: moe_apply_grouped(p, cfg, x)[0].sum())(params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


def test_seq_shard_is_identity_on_one_device(rng):
    cfg = reduced(get_config("qwen2_7b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)}
    l1, _ = forward(params, cfg, batch)
    l2, _ = forward(params, dataclasses.replace(cfg, attn_seq_shard=True), batch)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_kv_quantize_roundtrip(rng):
    k = jnp.asarray(rng.normal(0, 2, (3, 5, 4, 32)), jnp.float32)
    q, s = kv_quantize(k)
    assert q.dtype == jnp.int8
    back = kv_dequantize(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - k)) / jnp.max(jnp.abs(k)))
    assert rel < 0.02


@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_moe_16b", "qwen2_vl_2b"])
def test_int8_cache_decode_close(arch, rng):
    cfg = reduced(get_config(arch))
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.vision_tokens, cfg.d_model)), jnp.float32
        )
        batch["positions_3d"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    ll, cache = prefill(params, cfg, batch, capacity=S + 4)
    llq, cacheq = prefill(params, cfgq, batch, capacity=S + 4)
    np.testing.assert_array_equal(np.asarray(ll), np.asarray(llq))
    # quantised cache is smaller
    assert sum(v.nbytes for v in jax.tree.leaves(cacheq)) < sum(
        v.nbytes for v in jax.tree.leaves(cache)
    )
    nxt = jnp.argmax(ll, -1).astype(jnp.int32)
    p3d = jnp.full((3, B, 1), S) if cfg.arch_type == "vlm" else None
    d1, _ = decode_step(params, cfg, cache, nxt, jnp.asarray(S, jnp.int32), p3d)
    d2, _ = decode_step(params, cfgq, cacheq, nxt, jnp.asarray(S, jnp.int32), p3d)
    rel = float(jnp.max(jnp.abs(d1 - d2)) / (jnp.max(jnp.abs(d1)) + 1e-9))
    assert rel < 0.05
