"""End-to-end behaviour: the paper's full offloading pipeline on a tiny
scale (trained detectors -> ORIC -> estimator -> policy), and the LM
early-exit cascade transfer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CdfTransform,
    EstimatorConfig,
    RewardEstimator,
    RewardOracle,
    ThresholdPolicy,
    cascade_map,
    extract_features_batch,
    match_pairs,
    topk_offload_mask,
)
from repro.detection.map_engine import dataset_map, match_detections


@pytest.fixture(scope="module")
def tiny_pipeline():
    """Train tiny weak/strong detectors and produce matched val results."""
    from repro.data.shapes import ShapesDataset
    from repro.models.detector import STRONG, WEAK, decode_detections
    from repro.train.trainer import train_detector

    train = ShapesDataset.generate(256, seed=0)
    val = ShapesDataset.generate(96, seed=1)
    pool = ShapesDataset.generate(96, seed=2)
    pw, _ = train_detector(WEAK, train, steps=80, batch_size=32, log_every=0)
    ps, _ = train_detector(STRONG, train, steps=160, batch_size=32, log_every=0)
    weak_val = decode_detections(pw, WEAK, val.images)
    strong_val = decode_detections(ps, STRONG, val.images)
    weak_pool = decode_detections(pw, WEAK, pool.images)
    pairs = match_pairs(weak_val, strong_val, val.gts)
    pool_evals = [match_detections(d, g, (0.5,)) for d, g in zip(weak_pool, pool.gts)]
    return {
        "pairs": pairs,
        "pool": pool_evals,
        "weak_val": weak_val,
        "weak_map": dataset_map(weak_val, val.gts),
        "strong_map": dataset_map(strong_val, val.gts),
    }


def test_strong_detector_better(tiny_pipeline):
    assert tiny_pipeline["strong_map"] > tiny_pipeline["weak_map"]


def test_oric_oracle_cascade_improves_on_weak(tiny_pipeline):
    rng = np.random.default_rng(0)
    oracle = RewardOracle.from_pool(tiny_pipeline["pool"], 64, rng)
    rewards = oracle.oric_batch(tiny_pipeline["pairs"])
    cas = cascade_map(tiny_pipeline["pairs"], topk_offload_mask(rewards, 0.3))
    assert cas > tiny_pipeline["weak_map"]


def test_estimated_pipeline_end_to_end(tiny_pipeline):
    """Features -> MORIC targets -> estimator -> threshold policy, full loop."""
    rng = np.random.default_rng(0)
    pairs = tiny_pipeline["pairs"]
    oracle = RewardOracle.from_pool(tiny_pipeline["pool"], 64, rng)
    rewards = oracle.oric_batch(pairs)
    x = extract_features_batch(tiny_pipeline["weak_val"], 8, image_size=64.0)
    cdf = CdfTransform(rewards)
    est = RewardEstimator(x.shape[1], EstimatorConfig(epochs=15))
    est.fit(x, cdf(rewards))
    preds = est.predict(x)
    policy = ThresholdPolicy(preds, ratio=0.3)
    mask = policy.decide_batch(preds)
    assert 0.15 <= mask.mean() <= 0.45  # policy lands near the target ratio
    cas = cascade_map(pairs, mask)
    # estimated cascade should sit between weak-only and strong-only
    assert cas >= tiny_pipeline["weak_map"] - 1e-9


def test_lm_cascade_transfer():
    """Early-exit ORIC cascade on a tiny LM: decisions route the batch and
    the blended NLL never exceeds the weak-only NLL (strong >= weak here
    since the strong path includes the full depth)."""
    from repro.configs import get_config
    from repro.data.lm_synth import synth_lm_batch
    from repro.models.lm import init_params, reduced
    from repro.serving.cascade_serving import LMCascade

    cfg = reduced(get_config("yi_6b"), num_layers=4)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def mk(seed):
        toks, labels = synth_lm_batch(np.random.default_rng(seed), 16, 32, cfg.vocab_size)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    cascade = LMCascade.fit(
        params, cfg, exit_layer=2, calib_batches=[mk(1), mk(2)], ratio=0.25, epochs=10
    )
    out = cascade.serve_batch(params, mk(3))
    assert 0.0 <= out["offload_ratio"] <= 0.7
    assert np.all(np.isfinite(out["nll_final"]))
    # routed quality is a mix of the two models' NLLs
    lo = np.minimum(out["nll_weak"], out["nll_strong"]).mean()
    hi = np.maximum(out["nll_weak"], out["nll_strong"]).mean()
    assert lo - 1e-6 <= out["nll_final"].mean() <= hi + 1e-6
