"""The unified OffloadEngine API: fit/score/decide, the fused Pallas scoring
path, feature-extractor adapters, the policy registry, and save/load."""
import numpy as np
import pytest

from repro.api import (
    DetectionBoxFeatures,
    LMLogitsFeatures,
    MLPRewardModel,
    OffloadEngine,
    make_feature_extractor,
    make_policy,
)
from repro.core import Cascade, EstimatorConfig, extract_features_batch


def synth(n=256, d=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 2.0 * x[:, 0] + 0.3 * rng.normal(size=n)
    return x, rewards


@pytest.fixture(scope="module")
def fitted():
    x, rewards = synth()
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(32,), epochs=60, batch_size=64)
        ),
        ratio=0.3,
    )
    eng.fit(features=x, rewards=rewards)
    return eng, x, rewards


def test_fit_score_decide(fitted):
    eng, x, rewards = fitted
    scores = eng.score(features=x)
    assert scores.shape == (len(x),)
    assert 0.0 <= scores.min() and scores.max() <= 1.0  # MORIC ranks
    # the estimator learned the reward ordering
    assert np.corrcoef(scores, rewards)[0, 1] > 0.5
    d = eng.decide(features=x)
    assert d.offload.dtype == bool and abs(d.ratio - 0.3) < 0.05


def test_empty_and_single_item_batches(fitted):
    eng, x, _ = fitted
    d1 = eng.decide(features=x[:1])
    assert d1.offload.shape == (1,)
    d0 = eng.decide(features=np.zeros((0, x.shape[1]), np.float32))
    assert d0.offload.shape == (0,) and d0.ratio == 0.0


def test_set_ratio_runtime(fitted):
    eng, x, _ = fitted
    try:
        eng.set_ratio(0.6)
        assert abs(eng.decide(features=x).ratio - 0.6) < 0.05
    finally:
        eng.set_ratio(0.3)


def test_score_uses_fused_pallas_path(fitted):
    """Batched score must run the fused estimator_mlp kernel (interpret-mode
    fallback) and agree with the pure-jnp estimator path."""
    import jax.numpy as jnp

    from repro.kernels.estimator_mlp import estimator_mlp_ref

    eng, x, _ = fitted
    assert eng.reward_model.fused
    est = eng.reward_model.estimator
    xs = (x - est._mu) / est._sigma
    p = est.params
    want = np.asarray(
        estimator_mlp_ref(
            jnp.asarray(xs), p["layer0"]["w"], p["layer0"]["b"],
            p["layer1"]["w"][:, 0], p["layer1"]["b"][0],
        )
    )
    np.testing.assert_allclose(eng.score(features=x), want, atol=1e-5)
    np.testing.assert_allclose(eng.score(features=x), est.predict(x), atol=1e-5)


def test_multilayer_model_falls_back_to_jnp():
    x, rewards = synth(n=64)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(16, 8), epochs=3)),
    )
    eng.fit(features=x, rewards=rewards)
    assert not eng.reward_model.fused
    np.testing.assert_array_equal(
        eng.score(features=x), eng.reward_model.estimator.predict(x)
    )


def test_cnn_reward_model_behind_engine(rng):
    """The §V-A feature-map CNN fits behind the same engine contract."""
    from repro.api import CNNRewardModel

    fmaps = rng.normal(0, 1, (64, 8, 8, 4)).astype(np.float32)
    rewards = fmaps.mean(axis=(1, 2, 3))
    eng = OffloadEngine(
        reward_model=CNNRewardModel(epochs=2, batch_size=32), ratio=0.25
    )
    eng.fit(features=fmaps, rewards=rewards)
    assert not eng.reward_model.fused
    scores = eng.score(features=fmaps)
    assert scores.shape == (64,) and np.isfinite(scores).all()
    assert 0.0 <= eng.decide(features=fmaps).ratio <= 1.0


def test_save_load_roundtrip(fitted, tmp_path):
    eng, x, _ = fitted
    path = str(tmp_path / "engine")
    eng.save(path, extra_meta={"note": "unit-test"})
    loaded = OffloadEngine.load(path)
    np.testing.assert_array_equal(eng.score(features=x), loaded.score(features=x))
    np.testing.assert_array_equal(
        eng.decide(features=x).offload, loaded.decide(features=x).offload
    )
    assert loaded.ratio == eng.ratio
    assert loaded.transform is not None
    np.testing.assert_allclose(loaded.transform._sorted, eng.transform._sorted)
    # runtime re-budget still works on the loaded engine
    loaded.set_ratio(0.7)
    assert abs(loaded.decide(features=x).ratio - 0.7) < 0.05


def test_save_load_with_feature_extractor(noisy_pair, tmp_path):
    """An engine with a registered extractor decides from RAW weak outputs
    after reload — the full deployable-artifact contract."""
    from repro.core import RewardOracle, match_pairs
    from repro.detection.map_engine import match_detections

    gts, weak, strong = noisy_pair
    pairs = match_pairs(weak, strong, gts)
    pool = [match_detections(d, g, (0.5,)) for d, g in zip(weak[:30], gts[:30])]
    rewards = RewardOracle.from_pool(
        pool, 25, np.random.default_rng(0)
    ).oric_batch(pairs)
    eng = OffloadEngine(
        feature_extractor=DetectionBoxFeatures(num_classes=8, image_size=64.0),
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(32,), epochs=5)),
        ratio=0.25,
    )
    eng.fit(weak, rewards)
    path = str(tmp_path / "det_engine")
    eng.save(path)
    loaded = OffloadEngine.load(path)
    assert loaded.feature_extractor.spec() == eng.feature_extractor.spec()
    np.testing.assert_array_equal(eng.decide(weak).offload, loaded.decide(weak).offload)


def test_detection_feature_adapter(noisy_pair):
    _, weak, _ = noisy_pair
    fx = DetectionBoxFeatures(num_classes=8, image_size=64.0)
    np.testing.assert_array_equal(
        fx(weak), extract_features_batch(weak, 8, image_size=64.0)
    )
    assert fx.feature_dim == fx(weak).shape[1]


def test_lm_logits_feature_adapter(rng):
    import jax.numpy as jnp

    logits = jnp.asarray(rng.normal(0, 1, (4, 6, 32)), jnp.float32)
    labels = jnp.asarray(
        np.where(rng.uniform(size=(4, 6)) < 0.8, rng.integers(0, 32, (4, 6)), -1)
    )
    fx = LMLogitsFeatures(top_k=8)
    feats = fx((logits, labels))
    assert feats.shape == (4, fx.feature_dim) and np.isfinite(feats).all()
    # decode-time path: no labels -> every position valid
    feats_nolab = fx((logits, None))
    assert feats_nolab.shape == (4, fx.feature_dim) and np.isfinite(feats_nolab).all()


def test_feature_extractor_registry():
    fx = make_feature_extractor("detection_boxes", num_classes=3, top_k=5)
    assert fx.spec()["top_k"] == 5
    with pytest.raises(KeyError):
        make_feature_extractor("no_such_adapter")


def test_token_bucket_rebudget_keeps_level():
    """set_ratio must not refill the bucket — no free burst on re-budget."""
    tb = make_policy("token_bucket", np.linspace(0, 1, 100), ratio=0.5, depth=4.0)
    for e in (0.99, 0.98, 0.97):
        tb.decide(e)
    drained = tb.bucket.level
    assert drained < 4.0
    tb.set_ratio(0.5)
    assert tb.bucket.level <= drained + tb.ratio


def test_save_records_live_policy_ratio(fitted, tmp_path):
    """Back-compat callers re-budget the policy directly; save/load must
    still round-trip the decisions."""
    eng, x, _ = fitted
    try:
        eng.policy.set_ratio(0.55)  # bypasses engine.set_ratio
        path = str(tmp_path / "live_ratio")
        eng.save(path)
        loaded = OffloadEngine.load(path)
        assert loaded.ratio == pytest.approx(0.55)
        np.testing.assert_array_equal(
            eng.decide(features=x).offload, loaded.decide(features=x).offload
        )
    finally:
        eng.set_ratio(0.3)


def test_policy_registry_contract():
    rng = np.random.default_rng(0)
    cal = rng.uniform(size=1000)
    thr = make_policy("threshold", cal, ratio=0.2)
    assert abs(thr.decide_batch(cal).mean() - 0.2) < 0.03
    topk = make_policy("topk", cal, ratio=0.2)
    assert topk.decide_batch(cal).sum() == round(0.2 * len(cal))
    tb = make_policy("token_bucket", cal, ratio=0.1, depth=4.0)
    mask = tb.decide_batch(cal)
    assert mask.mean() <= 0.1 + 4.0 / len(cal) + 1e-9
    with pytest.raises(KeyError):
        make_policy("no_such_policy", cal, ratio=0.2)


def test_cascade_from_engine(fitted):
    eng, x, _ = fitted
    strong_calls = []

    def weak_fn(item):
        return item  # items ARE feature rows (engine has no extractor)

    def strong_fn(item):
        strong_calls.append(1)
        return item * 2

    cas = Cascade.from_engine(weak_fn, strong_fn, eng)
    records = cas.run(list(x[:40]))
    ratio = cas.offload_ratio(records)
    assert len(strong_calls) == sum(r.offloaded for r in records)
    assert 0.0 <= ratio <= 1.0
    # per-item estimates agree with the engine's batched scoring
    np.testing.assert_allclose(
        [r.estimate for r in records], eng.score(features=x[:40]), atol=1e-6
    )


def test_lm_cascade_save_load(tmp_path):
    """LMCascade persists its decision stack through the engine artifact."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.lm_synth import synth_lm_batch
    from repro.models.lm import init_params, reduced
    from repro.serving.cascade_serving import LMCascade

    cfg = reduced(get_config("yi_6b"), num_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def mk(seed):
        toks, labels = synth_lm_batch(np.random.default_rng(seed), 8, 16, cfg.vocab_size)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    cascade = LMCascade.fit(
        params, cfg, exit_layer=1, calib_batches=[mk(1)], ratio=0.25, epochs=3
    )
    assert cascade.engine.reward_model.fused  # single hidden layer -> Pallas
    path = str(tmp_path / "lm_engine")
    cascade.save(path)
    loaded = LMCascade.load(path, cfg)
    assert loaded.exit_layer == cascade.exit_layer
    a = cascade.serve_batch(params, mk(5))
    b = loaded.serve_batch(params, mk(5))
    np.testing.assert_array_equal(a["offload"], b["offload"])
    np.testing.assert_allclose(a["estimates"], b["estimates"], atol=1e-6)


def test_cdf_transform_state_roundtrip(rng):
    from repro.core import CdfTransform

    rewards = rng.normal(0, 1, 200)
    cdf = CdfTransform(rewards)
    clone = CdfTransform.from_state(cdf.state())
    probe = rng.normal(0, 2, 64)
    np.testing.assert_array_equal(cdf(probe), clone(probe))
    # mutating the exported state must not alias the fitted transform
    state = cdf.state()
    state["sorted_rewards"][:] = 0.0
    np.testing.assert_array_equal(cdf(probe), clone(probe))


def test_registry_listings_exported():
    """Satellite: repro.api enumerates its registries for configs/errors."""
    from repro.api import list_feature_extractors, list_policies

    assert set(list_policies()) >= {"threshold", "topk", "token_bucket"}
    assert set(list_feature_extractors()) >= {"detection_boxes", "lm_logits"}
    with pytest.raises(KeyError) as ei:
        make_policy("bogus", np.zeros(4), 0.2)
    for name in list_policies():
        assert name in str(ei.value)


def test_lm_cascade_serve_stream(tmp_path):
    """Streaming serve over an OffloadSession: same decisions as the batch
    path for the stateless threshold policy, plus telemetry across batches."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.lm_synth import synth_lm_batch
    from repro.models.lm import init_params, reduced
    from repro.serving.cascade_serving import LMCascade

    cfg = reduced(get_config("yi_6b"), num_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def mk(seed):
        toks, labels = synth_lm_batch(np.random.default_rng(seed), 8, 16, cfg.vocab_size)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    cascade = LMCascade.fit(
        params, cfg, exit_layer=1, calib_batches=[mk(1)], ratio=0.4, epochs=3
    )
    batches = [mk(s) for s in (5, 6, 7)]
    out = cascade.serve_stream(params, batches, micro_batch=8)
    assert out["offload"].shape == (24,)
    want = np.concatenate(
        [cascade.serve_batch(params, b)["offload"] for b in batches]
    )
    np.testing.assert_array_equal(out["offload"], want)
    t = out["telemetry"]
    assert t["processed"] == 24
    # realized rewards are recorded only for requests the strong model served
    assert t["rewards_recorded"] == int(out["offload"].sum())
    assert t["realized_ratio"] == pytest.approx(out["offload"].mean())
    np.testing.assert_allclose(
        out["nll_final"], np.where(out["offload"], out["nll_strong"], out["nll_weak"])
    )
    # mid-stream re-budget to zero: later batches stop offloading
    out2 = cascade.serve_stream(
        params, batches, micro_batch=8, set_ratio_at={8: 0.0}
    )
    assert not out2["offload"][8:].any()


def test_cascade_generate_routes_by_engine():
    """Engine-gated decode: offloaded rows get full-depth tokens."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.lm_synth import synth_lm_batch
    from repro.models.lm import init_params, reduced
    from repro.serving.cascade_serving import LMCascade
    from repro.serving.decode_loop import cascade_generate, generate

    cfg = reduced(get_config("yi_6b"), num_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def mk(seed):
        toks, labels = synth_lm_batch(np.random.default_rng(seed), 8, 16, cfg.vocab_size)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    cascade = LMCascade.fit(
        params, cfg, exit_layer=1, calib_batches=[mk(1)], ratio=0.5, epochs=3
    )
    batch = mk(7)
    out = cascade_generate(
        params, cfg, batch, steps=4, engine=cascade.engine,
        exit_layer=cascade.exit_layer,
    )
    assert out["tokens"].shape == (8, 4)
    assert out["offload"].shape == (8,)
    if out["offload"].any():
        idx = np.where(out["offload"])[0]
        strong = np.asarray(
            generate(params, cfg, {k: v[idx] for k, v in batch.items()}, steps=4)
        )
        np.testing.assert_array_equal(out["tokens"][idx], strong)
