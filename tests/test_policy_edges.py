"""Policy edge cases: threshold at ratio 0/1, token-bucket drain/refill
invariants, top-k tie stability."""
import numpy as np

from repro.core import ThresholdPolicy, TokenBucket, topk_offload_mask


def test_threshold_ratio_zero_never_offloads():
    cal = np.random.default_rng(0).uniform(size=500)
    pol = ThresholdPolicy(cal, ratio=0.0)
    assert pol.threshold == np.inf
    assert not pol.decide(1e9)
    assert not pol.decide_batch(np.array([0.0, 0.5, 1.0, 1e9])).any()


def test_threshold_ratio_one_always_offloads():
    cal = np.random.default_rng(0).uniform(size=500)
    pol = ThresholdPolicy(cal, ratio=1.0)
    assert pol.threshold == -np.inf
    assert pol.decide(-1e9)
    assert pol.decide_batch(np.array([-1e9, 0.0, 1.0])).all()


def test_threshold_ratio_clipped():
    cal = np.random.default_rng(0).uniform(size=100)
    assert ThresholdPolicy(cal, ratio=-0.5).ratio == 0.0
    assert ThresholdPolicy(cal, ratio=7.0).ratio == 1.0


def test_token_bucket_default_level_is_depth():
    tb = TokenBucket(rate=0.1, depth=4.0, base_threshold=0.5)
    assert tb.level == 4.0
    tb2 = TokenBucket(rate=0.1, depth=4.0, base_threshold=0.5, level=1.5)
    assert tb2.level == 1.5


def test_token_bucket_level_invariants():
    """Level stays in [0, depth] through arbitrary drain/refill traffic."""
    rng = np.random.default_rng(0)
    tb = TokenBucket(rate=0.3, depth=3.0, base_threshold=0.2)
    for e in rng.uniform(size=3000):
        tb.decide(float(e))
        assert 0.0 <= tb.level <= tb.depth + 1e-12


def test_token_bucket_drains_then_refills():
    tb = TokenBucket(rate=0.0, depth=2.0, base_threshold=0.0)
    # full bucket: scarcity 0, threshold = base -> spend a token
    assert tb.decide(0.99)
    # drained to the last token: scarcity-adjusted threshold hits 1.0
    assert not tb.decide(0.999)
    # refill restores capacity and the base threshold
    tb.rate = 1.0
    assert tb.decide(0.99)


def test_token_bucket_never_spends_below_one_token():
    tb = TokenBucket(rate=0.01, depth=1.0, base_threshold=0.0)
    assert tb.decide(1.5)  # clears the scarcity threshold, spends the token
    for _ in range(5):
        assert not tb.decide(2.0)  # level < 1: hard no regardless of estimate
    assert tb.level >= 0.0


def test_topk_tie_stability():
    """Equal scores are broken by position (stable argsort): the earliest
    indices win, and the mask is deterministic."""
    scores = np.array([0.5, 0.5, 0.5, 0.5, 0.1])
    mask = topk_offload_mask(scores, ratio=0.4)  # k = 2
    np.testing.assert_array_equal(mask, [True, True, False, False, False])
    np.testing.assert_array_equal(mask, topk_offload_mask(scores, 0.4))


def test_topk_edge_ratios():
    scores = np.random.default_rng(0).uniform(size=10)
    assert topk_offload_mask(scores, 0.0).sum() == 0
    assert topk_offload_mask(scores, 1.0).all()
