"""Detection substrate: box ops, mAP engine, incremental evaluation, NMS,
TIDE decomposition."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.detection import (
    Detections,
    GroundTruth,
    box_iou,
    box_iou_np,
    dataset_map,
    nms,
    tide_errors,
)
from repro.detection.map_engine import APAccumulator, average_precision, match_detections


def test_iou_known_values():
    a = jnp.array([[0.0, 0, 10, 10]])
    b = jnp.array([[0.0, 0, 10, 10], [5, 5, 15, 15], [20, 20, 30, 30]])
    iou = np.asarray(box_iou(a, b))[0]
    assert iou[0] == pytest.approx(1.0)
    assert iou[1] == pytest.approx(25 / 175)
    assert iou[2] == pytest.approx(0.0)


def test_iou_np_matches_jnp(rng):
    a = rng.uniform(0, 50, (40, 2))
    a = np.concatenate([a, a + rng.uniform(1, 20, (40, 2))], 1)
    b = rng.uniform(0, 50, (30, 2))
    b = np.concatenate([b, b + rng.uniform(1, 20, (30, 2))], 1)
    np.testing.assert_allclose(
        box_iou_np(a, b), np.asarray(box_iou(jnp.asarray(a), jnp.asarray(b))),
        rtol=1e-6, atol=1e-6,
    )


def test_perfect_detector_map_is_one(noisy_pair):
    gts, _, _ = noisy_pair
    perfect = [Detections(g.boxes, np.ones(len(g)), g.classes) for g in gts]
    assert dataset_map(perfect, gts) == pytest.approx(1.0)


def test_empty_detector_map_is_zero(noisy_pair):
    gts, _, _ = noisy_pair
    empty = [Detections(np.zeros((0, 4)), np.zeros(0), np.zeros(0, int)) for _ in gts]
    assert dataset_map(empty, gts) == 0.0


def test_strong_beats_weak(noisy_pair):
    gts, weak, strong = noisy_pair
    assert dataset_map(strong, gts) > dataset_map(weak, gts)


def test_incremental_equals_batch(noisy_pair):
    """APAccumulator.map_with_image must be EXACT vs full recomputation."""
    gts, weak, _ = noisy_pair
    acc = APAccumulator((0.5,))
    for d, g in zip(weak[:-1], gts[:-1]):
        acc.add(match_detections(d, g, (0.5,)))
    ev = match_detections(weak[-1], gts[-1], (0.5,))
    assert acc.map_with_image(ev) == pytest.approx(dataset_map(weak, gts), abs=1e-12)


def test_map_with_image_does_not_mutate(noisy_pair):
    gts, weak, strong = noisy_pair
    acc = APAccumulator((0.5,))
    for d, g in zip(weak[:20], gts[:20]):
        acc.add(match_detections(d, g, (0.5,)))
    base = acc.map()
    acc.map_with_image(match_detections(strong[25], gts[25], (0.5,)))
    assert acc.map() == base


def test_hallucination_only_visible_with_context(noisy_pair):
    """The paper's motivating case: a background error on a class absent
    from the image is invisible to per-image mAP but hurts context mAP."""
    gts, weak, _ = noisy_pair
    acc = APAccumulator((0.5,))
    for d, g in zip(weak[:30], gts[:30]):
        acc.add(match_detections(d, g, (0.5,)))
    gt = GroundTruth(np.array([[0.0, 0, 10, 10]]), np.array([0]))
    clean = Detections(np.array([[0.0, 0, 10, 10]]), np.array([0.9]), np.array([0]))
    halluc = Detections(
        np.array([[0.0, 0, 10, 10], [30, 30, 40, 40]]),
        np.array([0.9, 0.95]),
        np.array([0, 7]),
    )
    ev_c = match_detections(clean, gt, (0.5,))
    ev_h = match_detections(halluc, gt, (0.5,))
    # per-image (empty context): identical
    empty = APAccumulator((0.5,))
    assert empty.map_with_image(ev_h) == empty.map_with_image(ev_c)
    # with context: hallucination strictly worse
    assert acc.map_with_image(ev_h) < acc.map_with_image(ev_c)


def test_average_precision_edges():
    assert np.isnan(average_precision(np.array([0.9]), np.array([True]), 0))
    assert average_precision(np.zeros(0), np.zeros(0, bool), 3) == 0.0
    ap_all_tp = average_precision(np.array([0.9, 0.8]), np.array([True, True]), 2)
    assert ap_all_tp == pytest.approx(1.0)
    ap_with_fp = average_precision(
        np.array([0.95, 0.9, 0.8]), np.array([False, True, True]), 2
    )
    assert ap_with_fp < 1.0


def test_nms_suppresses_same_class_only():
    boxes = jnp.array([[0.0, 0, 10, 10], [1, 1, 11, 11], [0, 0, 10, 10]])
    scores = jnp.array([0.9, 0.8, 0.7])
    classes = jnp.array([0, 0, 1])
    keep = np.asarray(nms(boxes, scores, classes, iou_threshold=0.5))
    assert keep.tolist() == [True, False, True]


def test_tide_on_perfect_is_zero(noisy_pair):
    gts, _, _ = noisy_pair
    perfect = [Detections(g.boxes, np.ones(len(g)), g.classes) for g in gts]
    errs = tide_errors(perfect, gts)
    for cat in ("cls", "loc", "cls_loc", "dupe", "bkg", "miss"):
        assert errs[cat] == 0.0
        assert errs[f"{cat}_count"] == 0


def test_tide_counts_specific_errors():
    gt = GroundTruth(np.array([[0.0, 0, 10, 10], [30.0, 30, 40, 40]]), np.array([0, 1]))
    det = Detections(
        np.array([
            [0.0, 0, 10, 10],   # cls error (wrong label, IoU 1)
            [50.0, 50, 60, 60],  # background
        ]),
        np.array([0.9, 0.8]),
        np.array([3, 2]),
    )
    errs = tide_errors([det], [gt])
    assert errs["cls_count"] == 1
    assert errs["bkg_count"] == 1
    assert errs["miss_count"] >= 1  # the un-covered GT at (30,30)
