"""ORIC/ORI reward metrics, MORIC transform, policies, baselines."""
import numpy as np
import pytest

from repro.core import (
    AdaptiveFeedingSVM,
    CdfTransform,
    RewardOracle,
    ThresholdPolicy,
    TokenBucket,
    cascade_map,
    dcsb_signals,
    fit_dcsb,
    match_pairs,
    ori_batch,
    random_offload_mask,
    topk_offload_mask,
)
from repro.detection.map_engine import match_detections


@pytest.fixture(scope="module")
def matched(noisy_pair):
    gts, weak, strong = noisy_pair
    return match_pairs(weak, strong, gts)


@pytest.fixture(scope="module")
def oracle(noisy_pair, matched):
    gts, weak, _ = noisy_pair
    pool = [match_detections(d, g, (0.5,)) for d, g in zip(weak[:30], gts[:30])]
    return RewardOracle.from_pool(pool, 25, np.random.default_rng(0))


def test_oric_with_empty_context_equals_ori(matched):
    """Eq. 5 degenerates to ORI when E = ∅ (scale (|E|+1) = 1)."""
    empty_oracle = RewardOracle([], (0.5,))
    oric0 = empty_oracle.oric_batch(matched[:20])
    ori = ori_batch(matched[:20])
    np.testing.assert_allclose(oric0, ori, atol=1e-12)


def test_oracle_cascade_between_weak_and_strong(matched, oracle):
    n = len(matched)
    rewards = oracle.oric_batch(matched)
    weak_map = cascade_map(matched, np.zeros(n, bool))
    strong_map = cascade_map(matched, np.ones(n, bool))
    cas = cascade_map(matched, topk_offload_mask(rewards, 0.3))
    assert weak_map < cas  # offloading the top-reward images helps


def test_oracle_beats_random(matched, oracle):
    rng = np.random.default_rng(1)
    rewards = oracle.oric_batch(matched)
    r = 0.3
    cas = cascade_map(matched, topk_offload_mask(rewards, r))
    rand = np.mean([
        cascade_map(matched, random_offload_mask(len(matched), r, rng))
        for _ in range(5)
    ])
    assert cas > rand


def test_full_offload_equals_strong(matched):
    strong_map = cascade_map(matched, np.ones(len(matched), bool))
    mask = topk_offload_mask(np.zeros(len(matched)), 1.0)
    assert cascade_map(matched, mask) == pytest.approx(strong_map)


def test_cdf_transform_uniformises():
    r = np.random.default_rng(0).normal(0, 3, 500)
    cdf = CdfTransform(r)
    y = cdf(r)
    assert y.min() >= 0 and y.max() <= 1.0
    # ranks of a continuous sample are ~uniform
    hist, _ = np.histogram(y, bins=5, range=(0, 1))
    assert hist.std() / hist.mean() < 0.2
    # monotone
    order = np.argsort(r)
    assert np.all(np.diff(y[order]) >= 0)


def test_topk_mask_count():
    scores = np.random.default_rng(0).uniform(size=100)
    for r in (0.0, 0.1, 0.35, 1.0):
        assert topk_offload_mask(scores, r).sum() == round(100 * r)


def test_threshold_policy_runtime_ratio():
    cal = np.random.default_rng(0).uniform(size=1000)
    pol = ThresholdPolicy(cal, ratio=0.2)
    assert abs(pol.decide_batch(cal).mean() - 0.2) < 0.02
    pol.set_ratio(0.5)  # runtime adjustment (paper Table I)
    assert abs(pol.decide_batch(cal).mean() - 0.5) < 0.02


def test_token_bucket_enforces_rate():
    rng = np.random.default_rng(0)
    tb = TokenBucket(rate=0.1, depth=3, base_threshold=0.0)
    est = rng.uniform(size=2000)
    decisions = [tb.decide(float(e)) for e in est]
    ratio = np.mean(decisions)
    assert ratio <= 0.1 + 3 / 2000 + 1e-9  # rate + initial burst


def test_adaptive_feeding_ratio_grows_with_cplus(matched, noisy_pair):
    gts, weak, _ = noisy_pair
    from repro.core import extract_features_batch

    x = extract_features_batch(weak, 8, image_size=64.0)
    difficult = ori_batch(matched) > 0
    ratios = []
    for cp in (0.25, 4.0):
        svm = AdaptiveFeedingSVM(c_plus=cp, epochs=40).fit(x, difficult)
        ratios.append(svm.predict(x).mean())
    assert ratios[0] <= ratios[1]


def test_dcsb_signals_and_rule(noisy_pair):
    gts, weak, strong = noisy_pair
    counts, areas = dcsb_signals(weak)
    assert counts.shape == (len(weak),) and areas.shape == (len(weak),)
    rule = fit_dcsb(weak, strong)
    pred = rule.predict_signals(counts, areas)
    assert pred.dtype == bool and pred.shape == (len(weak),)
