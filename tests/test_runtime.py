"""The streaming runtime: sessions over a frozen engine, edge workers,
multi-edge dispatch, and the seeded end-to-end simulation."""
import numpy as np
import pytest

from repro.api import MLPRewardModel, OffloadEngine, list_policies
from repro.core import EstimatorConfig
from repro.core.policy import TokenBucket
from repro.runtime import (
    OUTCOME_DEGRADED,
    OUTCOME_DROPPED,
    OUTCOME_LOCAL,
    OUTCOME_OFFLOADED,
    EdgeLatencyModel,
    EdgeWorker,
    ManualClock,
    MultiEdgeDispatcher,
    OffloadRuntime,
    OffloadSession,
    default_edge_fleet,
    list_strategies,
    simulate,
)


def synth(n=256, d=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 2.0 * x[:, 0] + 0.3 * rng.normal(size=n)
    return x, rewards


def fit_engine(policy="threshold", ratio=0.3, **policy_kwargs):
    x, rewards = synth()
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=15, batch_size=64)
        ),
        policy=policy,
        ratio=ratio,
        policy_kwargs=policy_kwargs,
    )
    eng.fit(features=x, rewards=rewards)
    return eng, x


@pytest.fixture(scope="module")
def threshold_engine():
    return fit_engine()


# --------------------------------------------------------------- sessions


def test_session_matches_batch_decide(threshold_engine):
    """A threshold session is arrival-order invariant: per-item streaming
    decisions equal the engine's one-shot batch mask under any order."""
    eng, x = threshold_engine
    batch_mask = eng.decide(features=x[:64]).offload
    for perm_seed in (0, 1):
        order = np.random.default_rng(perm_seed).permutation(64)
        session = OffloadSession(eng, micro_batch=8)
        decisions = session.submit_batch(features=x[:64][order])
        stream_mask = np.array([d.offload for d in decisions])
        np.testing.assert_array_equal(stream_mask, batch_mask[order])


def test_session_micro_batch_size_invariance(threshold_engine):
    eng, x = threshold_engine
    masks = []
    for mb in (1, 7, 64):
        session = OffloadSession(eng, micro_batch=mb)
        decisions = session.submit_batch(features=x[:60])
        assert [d.step for d in decisions] == list(range(60))
        masks.append([d.offload for d in decisions])
    assert masks[0] == masks[1] == masks[2]


def test_token_bucket_session_order_dependent_but_rate_bound():
    """token_bucket decisions depend on arrival order (the bucket is
    stateful) yet the hard rate constraint holds under every order."""
    eng, x = fit_engine(policy="token_bucket", ratio=0.2, depth=4.0)
    masks = []
    for perm_seed in (0, 1, 2):
        order = np.random.default_rng(perm_seed).permutation(len(x))
        session = OffloadSession(eng, micro_batch=16)
        decisions = session.submit_batch(features=x[order])
        mask = np.array([d.offload for d in decisions])
        assert mask.mean() <= 0.2 + 4.0 / len(x) + 1e-9
        masks.append(mask)
    assert any(not np.array_equal(masks[0], m) for m in masks[1:])


def test_sessions_isolate_policy_state():
    """Two sessions over one engine must not share bucket state."""
    eng, x = fit_engine(policy="token_bucket", ratio=0.1, depth=2.0)
    a = OffloadSession(eng, micro_batch=4)
    b = OffloadSession(eng, micro_batch=4)
    ma = [d.offload for d in a.submit_batch(features=x[:32])]
    mb = [d.offload for d in b.submit_batch(features=x[:32])]
    assert ma == mb  # identical streams -> identical decisions
    assert eng.policy.bucket.level == eng.policy.depth  # engine untouched


def test_midstream_set_ratio(threshold_engine):
    eng, x = threshold_engine
    session = OffloadSession(eng, ratio=0.0, micro_batch=8)
    first = session.submit_batch(features=x[:80])
    assert not any(d.offload for d in first)
    session.set_ratio(1.0)
    assert session.telemetry.target_ratio == 1.0
    second = session.submit_batch(features=x[80:160])
    assert all(d.offload for d in second)
    # engine's own budget is untouched by session-local re-budgets
    assert eng.ratio == 0.3 and eng.policy.ratio == 0.3
    t = session.telemetry
    assert t.processed == 160 and t.offloaded == 80
    assert t.realized_ratio == pytest.approx(0.5)
    assert t.rolling_ratio == 1.0  # the 64-frame window saw only offloads


def test_session_telemetry_rewards(threshold_engine):
    eng, x = threshold_engine
    session = OffloadSession(eng, micro_batch=4)
    session.submit_batch(features=x[:8])
    for r in (0.5, -0.25):
        session.record_reward(r)
    t = session.telemetry
    assert t.rewards_recorded == 2 and t.reward_sum == pytest.approx(0.25)


def test_session_requires_fitted_engine():
    with pytest.raises(RuntimeError):
        OffloadSession(OffloadEngine())


def test_session_telemetry_as_dict_byte_stable(threshold_engine):
    """The default ``as_dict`` payload must stay byte-stable for existing
    consumers: the video counters appear only behind ``include_video``,
    the measured-network / online-update counters only behind
    ``include_online``, the fleet budget counters only behind
    ``include_fleet``."""
    eng, x = threshold_engine
    session = OffloadSession(eng, micro_batch=4)
    session.submit_batch(features=x[:12])
    legacy_keys = [
        "processed", "offloaded", "realized_ratio", "rolling_ratio",
        "mean_estimate", "target_ratio", "pending", "reward_sum",
        "rewards_recorded",
    ]
    assert list(session.telemetry.as_dict().keys()) == legacy_keys
    before = session.telemetry.as_dict()
    # recording temporal state must not leak into the default payload
    session.record_staleness(2.0)
    session.record_staleness(4.0)
    session.record_effective_accuracy(0.5)
    # nor the online-loop counters
    session.record_rtt(3.5)
    session.record_rtt(4.5)
    session.record_bandwidth(0.5)
    session.record_update()
    # nor the fleet budget counters
    session.record_budget_share(0.4)
    session.record_redistribution()
    assert session.telemetry.as_dict() == before
    fleet = session.telemetry.as_dict(include_fleet=True)
    assert list(fleet.keys()) == legacy_keys + [
        "budget_share", "budget_redistributions",
    ]
    assert fleet["budget_share"] == pytest.approx(0.4)
    assert fleet["budget_redistributions"] == 1
    full = session.telemetry.as_dict(include_video=True)
    assert list(full.keys()) == legacy_keys + [
        "covered_frames", "mean_staleness", "effective_frames",
        "mean_effective_accuracy",
    ]
    assert full["covered_frames"] == 2
    online = session.telemetry.as_dict(include_online=True)
    assert list(online.keys()) == legacy_keys + [
        "rtt_samples", "mean_rtt", "bandwidth_samples", "mean_bandwidth",
        "online_updates",
    ]
    assert online["rtt_samples"] == 2
    assert online["mean_rtt"] == pytest.approx(4.0)
    assert online["bandwidth_samples"] == 1
    assert online["online_updates"] == 1
    assert full["mean_staleness"] == pytest.approx(3.0)
    assert full["effective_frames"] == 1
    assert full["mean_effective_accuracy"] == pytest.approx(0.5)


def test_session_carries_tracker_and_temporal_probes(threshold_engine):
    """``tracker=`` rides the session and temporal probes reach only the
    policies that declare them (threshold accepts none — no crash)."""
    eng, x = threshold_engine
    marker = object()
    session = OffloadSession(
        eng, micro_batch=1, tracker=marker,
        staleness=lambda: 1.0, scene_change=lambda: 0.0,
    )
    assert session.tracker is marker
    out = session.submit(features=x[0])
    assert len(out) == 1  # probes ignored by a contextless policy


def test_engine_save_load_resume_session(threshold_engine, tmp_path):
    """save -> load -> a session over the loaded engine continues the stream
    with decisions identical to the original artifact's."""
    eng, x = threshold_engine
    path = str(tmp_path / "engine")
    eng.save(path)
    loaded = OffloadEngine.load(path)
    s1 = OffloadSession(eng, micro_batch=8)
    s2 = OffloadSession(loaded, micro_batch=8)
    for lo, hi in ((0, 40), (40, 100), (100, 180)):
        d1 = s1.submit_batch(features=x[lo:hi])
        d2 = s2.submit_batch(features=x[lo:hi])
        assert [d.offload for d in d1] == [d.offload for d in d2]
        np.testing.assert_allclose(
            [d.estimate for d in d1], [d.estimate for d in d2], atol=1e-6
        )
    assert s1.telemetry.as_dict() == s2.telemetry.as_dict()


# ------------------------------------------------------- token-bucket clock


def test_token_bucket_injectable_clock_refill():
    clock = ManualClock()
    tb = TokenBucket(rate=1.0, depth=4.0, base_threshold=0.0, clock=clock)
    # drain the bucket; frozen time -> no refill between takes
    for _ in range(4):
        assert tb.try_take()
    assert not tb.try_take()
    assert not tb.decide(0.9)  # still frozen, still empty
    clock.advance(2.0)  # 2 time units -> 2 tokens
    assert tb.level < 1.0
    assert tb.decide(0.99)  # thresholded spend still works under the clock
    assert tb.level == pytest.approx(1.0)


def test_token_bucket_clock_determinism():
    def run():
        clock = ManualClock()
        tb = TokenBucket(rate=0.5, depth=3.0, base_threshold=0.2, clock=clock)
        out = []
        for i in range(40):
            out.append(tb.decide(0.3 + 0.6 * ((i * 7) % 10) / 10))
            clock.advance(1.0)
        return out

    assert run() == run()


def test_manual_clock_monotone():
    clock = ManualClock(5.0)
    assert clock() == 5.0
    clock.advance(1.5)
    assert clock() == 6.5
    with pytest.raises(ValueError):
        clock.advance(-1.0)


def test_manual_clock_rejects_nan():
    """Satellite: NaN would poison every downstream schedule silently."""
    clock = ManualClock()
    with pytest.raises(ValueError):
        clock.advance(float("nan"))
    assert clock() == 0.0  # the failed advance left time untouched


def test_edge_latency_model_validates():
    """Satellite: negative components are configuration bugs, not models."""
    for kw in (
        {"base": -1.0},
        {"per_inflight": -0.1},
        {"jitter": -0.5},
        {"base": float("nan")},
    ):
        with pytest.raises(ValueError):
            EdgeLatencyModel(**kw)
    assert EdgeLatencyModel(base=0.0).sample(0, np.random.default_rng(0)) == 0.0


# ------------------------------------------------------------ edge workers


def test_edge_worker_capacity_and_completion():
    e = EdgeWorker("e0", capacity=2, latency=EdgeLatencyModel(base=1.0))
    assert e.try_admit(0.0, 0, 0.9) == pytest.approx(1.0)
    assert e.try_admit(0.0, 1, 0.9) == pytest.approx(1.0)
    assert e.try_admit(0.0, 2, 0.9) is None  # capacity full
    assert e.stats()["rejected"] == 1
    done = e.poll(1.0)
    assert sorted(j.step for j in done) == [0, 1]
    assert e.try_admit(1.0, 3, 0.9) is not None  # slots freed


def test_edge_worker_rate_limit_uses_sim_time():
    e = EdgeWorker(
        "e0", capacity=16, rate=1.0, burst=2.0, latency=EdgeLatencyModel(base=0.1)
    )
    # burst of 2 admits at t=0, then the bucket is dry until time advances
    assert e.try_admit(0.0, 0, 0.9) is not None
    assert e.try_admit(0.0, 1, 0.9) is not None
    assert e.try_admit(0.0, 2, 0.9) is None
    assert e.try_admit(2.0, 3, 0.9) is not None  # refilled by dt=2


def test_edge_worker_load_dependent_latency():
    e = EdgeWorker(
        "e0", capacity=4, latency=EdgeLatencyModel(base=1.0, per_inflight=0.5)
    )
    lat0 = e.try_admit(0.0, 0, 0.9)
    lat1 = e.try_admit(0.0, 1, 0.9)
    assert lat1 == pytest.approx(lat0 + 0.5)


# -------------------------------------------------------------- dispatcher


def tiny_fleet(capacity=1, **kw):
    return [
        EdgeWorker(f"e{i}", capacity=capacity, latency=EdgeLatencyModel(base=100.0), **kw)
        for i in range(2)
    ]


def test_dispatcher_validates_config():
    with pytest.raises(KeyError) as ei:
        MultiEdgeDispatcher(tiny_fleet(), "no_such_strategy")
    assert "round_robin" in str(ei.value)  # error enumerates the registry
    with pytest.raises(KeyError):
        MultiEdgeDispatcher(tiny_fleet(), on_saturation="explode")
    with pytest.raises(ValueError):
        MultiEdgeDispatcher([])
    with pytest.raises(ValueError):
        MultiEdgeDispatcher([EdgeWorker("same"), EdgeWorker("same")])
    assert list_strategies() == ["round_robin", "least_loaded", "score_weighted"]


@pytest.mark.parametrize("on_saturation,outcome", [
    ("degrade", OUTCOME_DEGRADED), ("drop", OUTCOME_DROPPED),
])
def test_dispatcher_saturation_accounting(on_saturation, outcome):
    """Slow 1-slot edges: 2 admits, everything after is degraded/dropped,
    and the books balance exactly."""
    disp = MultiEdgeDispatcher(
        tiny_fleet(), "least_loaded", on_saturation=on_saturation
    )
    results = [disp.dispatch(0.0, step, 0.9) for step in range(10)]
    offloaded = [r for r in results if r.outcome == OUTCOME_OFFLOADED]
    saturated = [r for r in results if r.outcome == outcome]
    assert len(offloaded) == 2 and len(saturated) == 8
    stats = disp.stats()
    assert stats["dropped" if on_saturation == "drop" else "degraded"] == 8
    assert sum(e["accepted"] for e in stats["edges"].values()) == 2
    # every saturated frame probed (and was rejected by) both edges
    assert sum(e["rejected"] for e in stats["edges"].values()) == 16


def test_dispatcher_round_robin_spreads_evenly():
    edges = [
        EdgeWorker(f"e{i}", capacity=100, latency=EdgeLatencyModel(base=0.1))
        for i in range(3)
    ]
    disp = MultiEdgeDispatcher(edges, "round_robin")
    t = 0.0
    for step in range(30):
        disp.dispatch(t, step, 0.9)
        t += 1.0
    assert [e.accepted for e in edges] == [10, 10, 10]


def test_dispatcher_least_loaded_prefers_idle():
    slow = EdgeWorker("slow", capacity=4, latency=EdgeLatencyModel(base=1000.0))
    idle = EdgeWorker("idle", capacity=4, latency=EdgeLatencyModel(base=1000.0))
    disp = MultiEdgeDispatcher([slow, idle], "least_loaded")
    disp.dispatch(0.0, 0, 0.9)  # tie -> first edge
    r = disp.dispatch(0.0, 1, 0.9)
    assert r.edge == "idle"  # now slow has load, idle wins


def test_dispatcher_score_weighted_handles_saturated_edges():
    """Regression: zero-weight (full) edges must not break the seeded
    sampling — they are probed last instead."""
    disp = MultiEdgeDispatcher(tiny_fleet(), "score_weighted", seed=0)
    results = [disp.dispatch(0.0, step, 0.9) for step in range(6)]
    assert sum(r.outcome == OUTCOME_OFFLOADED for r in results) == 2
    assert sum(r.outcome == OUTCOME_DEGRADED for r in results) == 4


def test_dispatcher_score_weighted_uses_estimate():
    """Satellite: the estimate sharpens the probe-order weights — a
    high-value frame concentrates on the fast free edge more often than a
    low-value frame does (same seed, independent dispatchers)."""

    def first_pick_rate(estimate):
        edges = [
            EdgeWorker("fast", capacity=3, latency=EdgeLatencyModel(base=1.0)),
            EdgeWorker("slow", capacity=1, latency=EdgeLatencyModel(base=3.0)),
        ]
        disp = MultiEdgeDispatcher(edges, "score_weighted", seed=123)
        hits = sum(disp._probe_order(estimate)[0] == 0 for _ in range(400))
        return hits / 400

    lo, hi = first_pick_rate(0.0), first_pick_rate(1.0)
    assert hi > lo  # sharper exponent -> best edge leads more often
    assert hi > 0.85 and 0.6 < lo < 0.95


def test_dispatcher_score_weighted_saturation_paths():
    """Satellite: both saturation paths — all edges full (uniform index
    order) and a mixed fleet (positive-weight edges sampled first, full
    edges appended in index order)."""
    edges = [
        EdgeWorker(f"e{i}", capacity=1, latency=EdgeLatencyModel(base=100.0))
        for i in range(3)
    ]
    disp = MultiEdgeDispatcher(edges, "score_weighted", seed=0)
    # mixed path: fill only edge 1; it must come last, others sampled first
    assert edges[1].try_admit(0.0, 0, 0.9) is not None
    order = disp._probe_order(0.9)
    assert order[-1] == 1 and sorted(order[:2]) == [0, 2]
    # saturated path: fill the rest -> uniform index order fallback
    assert edges[0].try_admit(0.0, 1, 0.9) is not None
    assert edges[2].try_admit(0.0, 2, 0.9) is not None
    assert disp._probe_order(0.9) == [0, 1, 2]
    # estimates outside [0, 1] (raw-reward engines) must not break sampling
    assert sorted(disp._probe_order(-3.7)) == [0, 1, 2]


def test_dispatcher_score_weighted_deterministic():
    def run():
        edges = [
            EdgeWorker(f"e{i}", capacity=3, latency=EdgeLatencyModel(base=1.0 + i))
            for i in range(3)
        ]
        disp = MultiEdgeDispatcher(edges, "score_weighted", seed=11)
        out = []
        t = 0.0
        for step in range(24):
            out.append(disp.dispatch(t, step, 0.9).edge)
            t += 0.5
        return out

    assert run() == run()


# -------------------------------------------------------------- simulation


def test_simulate_trace_exactly_reproducible(threshold_engine):
    eng, x = threshold_engine

    def run():
        return simulate(
            eng, features=x, n_edges=3, ratio=0.4, micro_batch=8,
            set_ratio_at={128: 0.1}, seed=7,
        )

    t1, t2 = run(), run()
    assert t1.records == t2.records
    assert t1.summary() == t2.summary()


def test_simulate_end_to_end_multi_edge(threshold_engine):
    """The acceptance scenario: 1 weak device -> 3 heterogeneous edges."""
    eng, x = threshold_engine
    trace = simulate(eng, features=x, n_edges=3, ratio=0.3, micro_batch=8, seed=0)
    assert len(trace.records) == len(x)
    assert [r.step for r in trace.records] == list(range(len(x)))
    counts = trace.outcome_counts()
    assert sum(counts.values()) == len(x)
    valid = {OUTCOME_LOCAL, OUTCOME_OFFLOADED, OUTCOME_DEGRADED, OUTCOME_DROPPED}
    assert set(counts) <= valid
    assert counts.get(OUTCOME_OFFLOADED, 0) > 0
    # decision ratio tracks the budget; the served mask can only be smaller
    assert abs(trace.telemetry.realized_ratio - 0.3) < 0.07
    assert trace.offload_mask().mean() <= trace.telemetry.realized_ratio
    # per-edge accounting matches the trace
    served = {n: 0 for n in trace.dispatcher["edges"]}
    for r in trace.records:
        if r.outcome == OUTCOME_OFFLOADED:
            served[r.edge] += 1
    for name, st in trace.dispatcher["edges"].items():
        assert st["accepted"] == served[name]
        assert st["completed"] == st["accepted"]  # drained at end of stream
        assert st["inflight"] == 0
    # arrival clock: one frame per period
    assert [r.t_arrival for r in trace.records] == [float(i) for i in range(len(x))]


def test_simulate_mid_stream_rebudget(threshold_engine):
    eng, x = threshold_engine
    trace = simulate(
        eng, features=x, ratio=0.0, micro_batch=4, set_ratio_at={128: 1.0}, seed=0
    )
    first, second = trace.records[:128], trace.records[128:]
    assert not any(r.offload for r in first)
    assert all(r.offload for r in second)


def test_simulate_rebudget_not_retroactive(threshold_engine):
    """A rebudget at a non-boundary step flushes the pending micro-batch
    first: arrivals before the step keep the old budget."""
    eng, x = threshold_engine
    trace = simulate(
        eng, features=x[:32], ratio=1.0, micro_batch=8,
        set_ratio_at={13: 0.0}, seed=0,
    )
    assert all(r.offload for r in trace.records[:13])
    assert not any(r.offload for r in trace.records[13:])


def test_edge_worker_tolerates_duplicate_step_ids():
    """Concurrent sessions reuse step indices; one edge must keep each
    admission's own admit time and complete both jobs."""
    e = EdgeWorker("e0", capacity=4, latency=EdgeLatencyModel(base=1.0))
    assert e.try_admit(0.0, 0, 0.9) is not None
    assert e.try_admit(0.5, 0, 0.8) is not None  # same step, other session
    done = e.poll(2.0)
    assert [j.t_admit for j in sorted(done, key=lambda j: j.t_done)] == [0.0, 0.5]
    assert e.stats()["completed"] == 2 and e.stats()["inflight"] == 0


def test_engine_save_strips_policy_clock(tmp_path):
    """An injected clock is runtime wiring, not artifact state: saving a
    clocked token_bucket engine must work and reload clock-free."""
    eng, x = fit_engine(
        policy="token_bucket", ratio=0.2, depth=4.0, clock=ManualClock()
    )
    path = str(tmp_path / "clocked")
    eng.save(path)
    loaded = OffloadEngine.load(path)
    assert "clock" not in loaded.policy_kwargs
    assert loaded.policy.clock is None


def test_simulate_drop_mode_accounts_everything(threshold_engine):
    eng, x = threshold_engine
    fleet = [EdgeWorker("only", capacity=1, latency=EdgeLatencyModel(base=1e6))]
    trace = simulate(
        eng, features=x[:64], edges=fleet, ratio=1.0, on_saturation="drop",
        micro_batch=8, seed=0,
    )
    counts = trace.outcome_counts()
    assert counts[OUTCOME_OFFLOADED] == 1  # the single slot, never freed
    assert counts[OUTCOME_DROPPED] == 63
    assert trace.dispatcher["dropped"] == 63


def test_runtime_sessions_share_frozen_engine(threshold_engine):
    """Sessions opened from one runtime decide identically on identical
    streams — the engine is frozen, per-stream state is session-local."""
    eng, x = threshold_engine
    runtime = OffloadRuntime(eng, default_edge_fleet(3, seed=0))
    s1 = runtime.open_session(micro_batch=8)
    s2 = runtime.open_session(micro_batch=8)
    m1 = [d.offload for d in s1.submit_batch(features=x[:48])]
    m2 = [d.offload for d in s2.submit_batch(features=x[:48])]
    assert m1 == m2


def test_streaming_study_registry_helpers():
    """Satellite: registries are enumerable for configs/error messages."""
    from repro.api import list_feature_extractors

    assert "threshold" in list_policies() and "token_bucket" in list_policies()
    assert "detection_boxes" in list_feature_extractors()
    assert "lm_logits" in list_feature_extractors()
