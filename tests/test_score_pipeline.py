"""The fused serve-time score pipeline: bit-identity against the composed
features→standardize→MLP route, Pallas-kernel agreement with the lax path,
param-bundle guards, and the session device fast path."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import DetectionBoxFeatures, MLPRewardModel, OffloadEngine
from repro.core import EstimatorConfig
from repro.core.features import extract_features_batch
from repro.detection.batch import DetectionsBatch
from repro.detection.map_engine import Detections
from repro.kernels.score_pipeline import (
    PIPELINE_PATHS,
    pipeline_params,
    resolve_pipeline_path,
    score_pipeline,
)

NUM_CLASSES = 7
TOP_K = 25


def make_batch(rng, n_images, kmax, frac_empty=0.2):
    """Ragged synthetic detections; ``kmax`` below TOP_K exercises the
    in-dispatch box-axis padding, above it the top-k selection."""
    dets = []
    for _ in range(n_images):
        n = 0 if rng.uniform() < frac_empty else int(rng.integers(1, kmax + 1))
        xy = rng.uniform(0, 0.8, (n, 2))
        wh = rng.uniform(0.01, 0.2, (n, 2))
        dets.append(
            Detections(
                np.concatenate([xy, xy + wh], 1).astype(np.float32),
                rng.uniform(0, 1, n).astype(np.float32),
                rng.integers(0, NUM_CLASSES, n).astype(np.int32),
            )
        )
    return DetectionsBatch.from_list(dets)


@pytest.fixture(scope="module")
def fitted_engine():
    rng = np.random.default_rng(0)
    cal = make_batch(rng, 200, 40)
    eng = OffloadEngine(
        feature_extractor=DetectionBoxFeatures(num_classes=NUM_CLASSES, top_k=TOP_K),
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(32,), epochs=2, batch_size=64)
        ),
        ratio=0.3,
    )
    eng.fit(
        features=extract_features_batch(cal, NUM_CLASSES, TOP_K),
        rewards=rng.uniform(0, 1, 200),
    )
    assert eng.reward_model.fused
    return eng


@pytest.mark.parametrize("B,kmax", [(1, 12), (7, 40), (64, 12), (512, 40), (5, 3)])
def test_fused_bit_identical_to_composed(fitted_engine, B, kmax):
    """The PR's core contract: one-dispatch ``score_device`` on a padded
    block equals the composed extract_features_batch → predict route
    bit for bit (both box-axis regimes, rows below/above top_k)."""
    eng = fitted_engine
    db = make_batch(np.random.default_rng(B * 131 + kmax), B, kmax)
    x = extract_features_batch(db, NUM_CLASSES, TOP_K)
    composed = eng.score(features=x)
    fused = np.asarray(eng.score_device(db))
    assert fused.dtype == np.float32
    np.testing.assert_array_equal(composed, fused)
    # decide() consumes the same estimates
    dec = eng.decide(db)
    np.testing.assert_array_equal(dec.estimates, fused)


def test_fused_all_padded_rows(fitted_engine):
    """Rows with zero live detections must score like the composed path
    scores them (the features are the all-empty stats, not garbage)."""
    eng = fitted_engine
    empty = Detections(
        np.zeros((0, 4), np.float32), np.zeros(0, np.float32), np.zeros(0, np.int32)
    )
    db = DetectionsBatch.from_list([empty] * 5)
    x = extract_features_batch(db, NUM_CLASSES, TOP_K)
    np.testing.assert_array_equal(
        eng.score(features=x), np.asarray(eng.score_device(db))
    )


def test_fused_empty_batch(fitted_engine):
    out = np.asarray(fitted_engine.score_device(DetectionsBatch.from_list([])))
    assert out.shape == (0,)
    assert out.dtype == np.float32


def test_predict_device_matches_predict(fitted_engine):
    rng = np.random.default_rng(3)
    x = rng.normal(0, 1, (33, fitted_engine.reward_model.in_dim)).astype(np.float32)
    np.testing.assert_array_equal(
        fitted_engine.reward_model.predict(x),
        np.asarray(fitted_engine.reward_model.predict_device(x)),
    )


@pytest.mark.parametrize("B,kmax,tile_b", [(1, 12, 128), (64, 40, 128), (300, 12, 128), (512, 40, 128), (40, 18, 16)])
def test_pallas_kernel_matches_lax_path(fitted_engine, B, kmax, tile_b):
    """The fused Pallas kernel (interpreter here — compiled lowering needs
    TPU/GPU) agrees with the jitted lax composition in both batch-grid
    regimes (one tile and several)."""
    eng = fitted_engine
    db = make_batch(np.random.default_rng(B + kmax), B, kmax)
    params = eng.reward_model.pipeline_params()
    kw = dict(num_classes=NUM_CLASSES, top_k=TOP_K, image_size=1.0)
    lax_out = np.asarray(score_pipeline(db, params, path="lax", **kw))
    pal_out = np.asarray(
        score_pipeline(db, params, path="pallas_interpret", tile_b=tile_b, **kw)
    )
    np.testing.assert_allclose(pal_out, lax_out, atol=2e-6)


def test_score_pipeline_accepts_array_tuple(fitted_engine):
    eng = fitted_engine
    db = make_batch(np.random.default_rng(9), 16, 30)
    params = eng.reward_model.pipeline_params()
    kw = dict(num_classes=NUM_CLASSES, top_k=TOP_K, image_size=1.0)
    via_batch = np.asarray(score_pipeline(db, params, **kw))
    via_tuple = np.asarray(
        score_pipeline(
            (jnp.asarray(db.boxes), jnp.asarray(db.scores),
             jnp.asarray(db.classes), jnp.asarray(db.mask)),
            params, **kw,
        )
    )
    np.testing.assert_array_equal(via_batch, via_tuple)


def test_pipeline_params_requires_fused_model():
    model = MLPRewardModel(config=EstimatorConfig(hidden=(16, 16), epochs=1))
    rng = np.random.default_rng(0)
    model.fit(rng.normal(0, 1, (32, 8)).astype(np.float32), rng.uniform(0, 1, 32))
    assert not model.fused
    with pytest.raises(ValueError, match="fused"):
        pipeline_params(model)


def test_feature_dim_mismatch_raises(fitted_engine):
    db = make_batch(np.random.default_rng(1), 4, 10)
    params = fitted_engine.reward_model.pipeline_params()
    with pytest.raises(ValueError, match="features"):
        score_pipeline(db, params, num_classes=NUM_CLASSES + 1, top_k=TOP_K)


def test_resolve_pipeline_path():
    import jax

    assert resolve_pipeline_path("lax") == "lax"
    auto = resolve_pipeline_path(None)
    assert auto == ("lax" if jax.default_backend() == "cpu" else "pallas")
    assert auto in PIPELINE_PATHS
    with pytest.raises(ValueError):
        resolve_pipeline_path("jnp")


def test_pipeline_params_track_online_updates(fitted_engine):
    """The bundle must be rebuilt per call: online adaptation swaps
    estimator layers in place and a stale cache would serve old weights."""
    eng = fitted_engine
    db = make_batch(np.random.default_rng(5), 8, 20)
    before = np.asarray(eng.score_device(db))
    est = eng.reward_model.estimator
    p = est.params
    try:
        est.params = {
            "layer0": p["layer0"],
            "layer1": {"w": p["layer1"]["w"] + 0.25, "b": p["layer1"]["b"]},
        }
        after = np.asarray(eng.score_device(db))
        assert not np.array_equal(before, after)
        np.testing.assert_array_equal(
            after, eng.score(features=extract_features_batch(db, NUM_CLASSES, TOP_K))
        )
    finally:
        est.params = p


# ---------------------------------------------------------------- session


def test_session_fast_path_matches_buffered(fitted_engine):
    from repro.runtime.session import OffloadSession

    rng = np.random.default_rng(11)
    db = make_batch(rng, 96, 30)
    fast = OffloadSession(fitted_engine, micro_batch=32)
    direct = fast.submit_batch(db)  # nothing pending: device fast path
    buffered = OffloadSession(fitted_engine, micro_batch=32)
    via_queue = buffered.submit_batch(db, flush=False)  # 96 = 3 micro-batches
    via_queue += buffered.flush()
    assert len(direct) == len(via_queue) == 96
    np.testing.assert_array_equal(
        [d.estimate for d in direct], [d.estimate for d in via_queue]
    )
    assert [d.offload for d in direct] == [d.offload for d in via_queue]
    assert [d.step for d in direct] == [d.step for d in via_queue]


def test_session_buffer_interleaving(fitted_engine):
    """Mixed single-frame submits and batch submits drain in arrival order
    through the preallocated buffer, matching one flat scoring pass."""
    from repro.runtime.session import OffloadSession

    rng = np.random.default_rng(13)
    blocks = [make_batch(rng, n, 20) for n in (10, 3, 50, 1, 7)]
    sess = OffloadSession(fitted_engine, micro_batch=16)
    out = []
    for db in blocks:
        out += sess.submit_batch(db, flush=False)
    out += sess.flush()
    assert len(out) == 71
    assert [d.step for d in out] == list(range(71))
    feats = np.concatenate(
        [extract_features_batch(db, NUM_CLASSES, TOP_K) for db in blocks]
    )
    # every frame's estimate equals a micro-batched pass over the same rows
    ref = []
    for s in range(0, 71, 16):
        ref.extend(fitted_engine.score(features=feats[s : s + 16]).tolist())
    np.testing.assert_allclose([d.estimate for d in out], ref, rtol=0, atol=0)


@pytest.mark.parametrize("n_blocks", [40])
def test_session_buffer_growth_property(fitted_engine, n_blocks):
    """Property sweep (hypothesis when available): random block sizes and
    flush points never lose, duplicate, or reorder frames."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.runtime.session import OffloadSession

    @hyp.given(
        sizes=st.lists(st.integers(min_value=0, max_value=40), min_size=1, max_size=12),
        micro=st.integers(min_value=1, max_value=33),
    )
    @hyp.settings(max_examples=20, deadline=None)
    def check(sizes, micro):
        rng = np.random.default_rng(sum(sizes) + micro)
        sess = OffloadSession(fitted_engine, micro_batch=micro)
        total, out = 0, []
        for n in sizes:
            x = extract_features_batch(
                make_batch(rng, n, 15), NUM_CLASSES, TOP_K
            ) if n else np.zeros((0, fitted_engine.reward_model.in_dim), np.float32)
            out += sess.submit_batch(features=x, flush=False)
            total += n
        out += sess.flush()
        assert len(out) == total
        assert [d.step for d in out] == list(range(total))
        assert sess._pending_rows == 0

    check()
