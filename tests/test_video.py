"""repro.video: seeded scene generation, the jitted tracker vs its Python
reference associator, stale-result propagation, the temporal policies, and
the 8-stream congested-fleet acceptance scenario."""
import numpy as np
import pytest

from repro.api import OffloadEngine, list_policies, make_policy
from repro.api.policies import policy_context_params
from repro.detection.map_engine import Detections
from repro.runtime import OffloadSession
from repro.video import (
    STRONG_PROFILE,
    WEAK_PROFILE,
    DetectionClip,
    SceneConfig,
    TrackerConfig,
    VideoTracker,
    default_video_scenario,
    detection_overlap,
    frame_accuracies,
    generate_clip,
    run_video_scenario,
    synthesize_detections,
    track_clip,
    track_clip_ref,
)
from repro.video.runtime import fuse_detections

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - hypothesis is optional (see CI)
    given = None


EXACT_FIELDS = (
    "ids", "active", "classes", "age", "det_track",
    "n_active", "n_matched", "n_new", "n_dead",
)
CLOSE_FIELDS = ("boxes", "vel", "conf")


def assert_tracks_equal(got, ref):
    for f in EXACT_FIELDS:
        assert np.array_equal(getattr(got, f), getattr(ref, f)), f
    for f in CLOSE_FIELDS:
        np.testing.assert_allclose(
            getattr(got, f), getattr(ref, f), atol=1e-5, rtol=1e-5, err_msg=f
        )


# ------------------------------------------------------------------ scene


def test_generate_clip_seeded_bit_identical():
    a = generate_clip(3, 24, seed=7)
    b = generate_clip(3, 24, seed=7)
    for f in ("boxes", "classes", "ids", "mask", "cuts"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    c = generate_clip(3, 24, seed=8)
    assert not np.array_equal(a.boxes, c.boxes)


def test_clip_containers_round_trip():
    clip = generate_clip(2, 10, seed=0)
    assert clip.n_frames == 10 and clip.n_streams == 2
    gb = clip.gt_frame(3)
    assert len(gb) == 2
    g = clip.gt(3, 1)
    np.testing.assert_allclose(g.boxes, gb[1].boxes)
    assert len(clip.gt_stream(0)) == 10
    # object identities persist between consecutive frames (tracking exists)
    common = set(clip.ids[4, 0][clip.mask[4, 0]]) & set(
        clip.ids[5, 0][clip.mask[5, 0]]
    )
    assert common


def test_detection_clip_layout_and_flatten():
    clip = generate_clip(2, 8, seed=1)
    weak = synthesize_detections(clip, WEAK_PROFILE, seed=2)
    assert (weak.n_frames, weak.n_streams) == (8, 2)
    fb = weak.frame(5)
    np.testing.assert_array_equal(fb.boxes, weak.boxes[5])
    d = weak.det(5, 1)
    assert len(d) == int(weak.mask[5, 1].sum())
    flat = weak.flatten()
    assert len(flat) == 16
    np.testing.assert_array_equal(flat.boxes[5 * 2 + 1], weak.boxes[5, 1])


def test_synthesized_tiers_order_by_accuracy():
    """The weak profile must actually be weaker: per-frame AP of strong
    detections dominates weak on the same clip."""
    clip = generate_clip(3, 16, seed=3)
    gts = [clip.gt(t, b) for t in range(16) for b in range(3)]
    weak = synthesize_detections(clip, WEAK_PROFILE, seed=4)
    strong = synthesize_detections(clip, STRONG_PROFILE, seed=5)
    accs_w = frame_accuracies(
        [weak.det(t, b) for t in range(16) for b in range(3)], gts
    )
    accs_s = frame_accuracies(
        [strong.det(t, b) for t in range(16) for b in range(3)], gts
    )
    assert accs_s.mean() > accs_w.mean() + 0.2


# ---------------------------------------------------------------- tracker


def test_track_clip_matches_reference_on_real_clip():
    clip = generate_clip(3, 20, seed=11, config=SceneConfig(p_cut=0.1))
    weak = synthesize_detections(clip, WEAK_PROFILE, seed=12)
    cfg = TrackerConfig()
    assert_tracks_equal(track_clip(weak, cfg), track_clip_ref(weak, cfg))


def test_streaming_update_equals_scan():
    clip = generate_clip(2, 12, seed=13)
    weak = synthesize_detections(clip, WEAK_PROFILE, seed=14)
    hist = track_clip(weak)
    vt = VideoTracker(2)
    for t in range(12):
        tf = vt.update(weak.frame(t))
    assert np.array_equal(tf.ids, hist.ids[-1])
    assert np.array_equal(tf.active, hist.active[-1])
    np.testing.assert_array_equal(tf.boxes, hist.boxes[-1])


if given is not None:

    _T, _B, _K = 4, 2, 4

    @settings(max_examples=30, deadline=None)
    @given(
        present=st.lists(st.booleans(), min_size=_T * _B * _K, max_size=_T * _B * _K),
        geom=st.lists(
            st.tuples(
                st.integers(0, 10),   # x1 / 4
                st.integers(0, 10),   # y1 / 4
                st.integers(2, 6),    # w / 4
                st.integers(2, 6),    # h / 4
            ),
            min_size=_T * _B * _K,
            max_size=_T * _B * _K,
        ),
        scores=st.lists(
            st.sampled_from([0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]),
            min_size=_T * _B * _K,
            max_size=_T * _B * _K,
        ),
        classes=st.lists(
            st.integers(0, 2), min_size=_T * _B * _K, max_size=_T * _B * _K
        ),
    )
    def test_tracker_scan_matches_reference_property(present, geom, scores, classes):
        """Hypothesis oracle: the jitted scan association over a clip is
        identical to the per-frame Python reference — including empty
        frames and arbitrary (non-prefix) padded rows.  Geometry lives on a
        4-px grid so float32 IoU rounds identically on both paths."""
        shape = (_T, _B, _K)
        boxes = np.zeros(shape + (4,), np.float32)
        g = np.asarray(geom, np.float32).reshape(shape + (4,))
        boxes[..., 0] = g[..., 0] * 4
        boxes[..., 1] = g[..., 1] * 4
        boxes[..., 2] = (g[..., 0] + g[..., 2]) * 4
        boxes[..., 3] = (g[..., 1] + g[..., 3]) * 4
        mask = np.asarray(present, bool).reshape(shape)
        dets = DetectionClip(
            boxes=np.where(mask[..., None], boxes, 0.0),
            scores=np.where(mask, np.asarray(scores, np.float32).reshape(shape), 0.0),
            classes=np.where(mask, np.asarray(classes, np.int32).reshape(shape), -1),
            mask=mask,
        )
        cfg = TrackerConfig(max_tracks=8, max_dets=_K)
        assert_tracks_equal(track_clip(dets, cfg), track_clip_ref(dets, cfg))

else:

    @pytest.mark.parametrize("seed", range(5))
    def test_tracker_scan_matches_reference_property(seed):
        """Deterministic stand-in for the hypothesis oracle above
        (hypothesis is not installed in this environment): seeded random
        clips with arbitrary non-prefix padded rows, geometry on the 4-px
        grid so float32 IoU rounds identically on both paths."""
        T, B, K = 4, 2, 4
        shape = (T, B, K)
        rng = np.random.default_rng(seed)
        x = rng.integers(0, 11, shape).astype(np.float32)
        y = rng.integers(0, 11, shape).astype(np.float32)
        w = rng.integers(2, 7, shape).astype(np.float32)
        h = rng.integers(2, 7, shape).astype(np.float32)
        boxes = np.stack([x * 4, y * 4, (x + w) * 4, (y + h) * 4], axis=-1)
        mask = rng.random(shape) < 0.6
        scores = rng.choice(
            [0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9], shape
        ).astype(np.float32)
        classes = rng.integers(0, 3, shape).astype(np.int32)
        dets = DetectionClip(
            boxes=np.where(mask[..., None], boxes, 0.0),
            scores=np.where(mask, scores, 0.0),
            classes=np.where(mask, classes, -1),
            mask=mask,
        )
        cfg = TrackerConfig(max_tracks=8, max_dets=K)
        assert_tracks_equal(track_clip(dets, cfg), track_clip_ref(dets, cfg))


def test_tracker_rejects_mismatched_stream_count():
    clip = generate_clip(2, 4, seed=0)
    weak = synthesize_detections(clip, seed=0)
    vt = VideoTracker(3)
    with pytest.raises(ValueError):
        vt.update(weak.frame(0))


def test_propagate_snaps_to_tracks_and_decays():
    """A static object: the stale edge det must land exactly on the track's
    current box (pure-IoU association, class kept from the edge result)."""
    box = np.array([[8.0, 8.0, 24.0, 24.0]])
    frames = [[Detections(box, [0.9], [2])] for _ in range(4)]
    dets = DetectionClip.from_frames(frames)
    vt = VideoTracker(1, TrackerConfig(stale_decay=0.5))
    for t in range(4):
        vt.update(dets.frame(t))
    # the edge saw the object at t0=1 with a different (corrected) class
    edge = Detections(box + 1.0, [0.8], [5])
    out = vt.propagate(edge, 1, 3, stream=0)
    np.testing.assert_allclose(out.boxes, box)           # snapped to the track
    assert out.classes.tolist() == [5]                    # edge label kept
    assert out.scores[0] == pytest.approx(0.8 * 0.5 ** 2)
    with pytest.raises(ValueError):
        vt.propagate(edge, 3, 1)


def test_propagate_unmatched_keeps_stale_geometry():
    vt = VideoTracker(1)
    vt.update(
        DetectionClip.from_frames(
            [[Detections(np.array([[40.0, 40.0, 56.0, 56.0]]), [0.9], [1])]]
        ).frame(0)
    )
    edge = Detections(np.array([[0.0, 0.0, 8.0, 8.0]]), [0.7], [1])
    out = vt.propagate(edge, 0, 2, stream=0)
    np.testing.assert_allclose(out.boxes, edge.boxes)  # nothing to snap to


def test_tracker_max_dets_overflow_raises():
    vt = VideoTracker(1, TrackerConfig(max_dets=4))
    big = Detections(np.zeros((6, 4)), np.zeros(6), np.zeros(6, int))
    frame = DetectionClip.from_frames([[big]]).frame(0)
    with pytest.raises(ValueError):
        vt.update(frame)


# --------------------------------------------------------------- features


def test_detection_overlap_bounds():
    a = Detections(np.array([[0.0, 0, 10, 10]]), [0.9], [1])
    b = Detections(np.array([[0.0, 0, 10, 10], [30.0, 30, 40, 40]]), [0.8, 0.7], [1, 2])
    assert detection_overlap(a, a) == 1.0
    assert detection_overlap(a, b) == 0.5  # second det unexplained
    empty = Detections(np.zeros((0, 4)), np.zeros(0), np.zeros(0, int))
    assert detection_overlap(a, empty) == 1.0
    assert detection_overlap(empty, a) == 0.0


def test_fuse_detections_fills_uncovered_regions():
    edge = Detections(np.array([[0.0, 0, 10, 10]]), [0.9], [3])
    weak = Detections(
        np.array([[1.0, 1, 11, 11], [30.0, 30, 40, 40]]), [0.6, 0.5], [1, 2]
    )
    fused = fuse_detections(edge, weak)
    assert len(fused) == 2  # overlapping weak det suppressed, far one kept
    assert fused.classes.tolist() == [3, 2]
    empty = Detections(np.zeros((0, 4)), np.zeros(0), np.zeros(0, int))
    assert fuse_detections(empty, weak) is weak
    assert fuse_detections(edge, empty) is edge


# ---------------------------------------------------------------- policies


def test_video_policies_registered():
    names = list_policies()
    assert "temporal_hysteresis" in names and "keyframe" in names
    assert policy_context_params("temporal_hysteresis") == ("staleness",)
    assert policy_context_params("keyframe") == ("scene_change",)


def test_temporal_hysteresis_tracks_ratio_without_probe():
    rng = np.random.default_rng(1)
    cal = rng.uniform(0, 1, 500)
    p = make_policy("temporal_hysteresis", cal, 0.3)
    mask = p.decide_batch(rng.uniform(0, 1, 2000))
    assert abs(mask.mean() - 0.3) < 0.05


def test_temporal_hysteresis_credit_defers_covered_frames():
    """While a fresh edge result covers the stream the policy suppresses
    offloads; the integral controller pays the budget back elsewhere."""
    rng = np.random.default_rng(2)
    cal = rng.uniform(0, 1, 500)
    t = {"i": 0}

    def staleness():
        return 0.0 if 400 <= t["i"] < 600 else float("inf")

    p = make_policy("temporal_hysteresis", cal, 0.4, staleness=staleness)
    decisions = []
    for e in rng.uniform(0, 1, 1000):
        decisions.append(p.decide(float(e)))
        t["i"] += 1
    d = np.array(decisions)
    covered, calm = d[400:600].mean(), np.concatenate([d[:400], d[600:]]).mean()
    assert covered < calm
    assert abs(d.mean() - 0.4) < 0.06


def test_temporal_hysteresis_degenerate_budgets_stay_hard():
    cal = np.random.default_rng(3).uniform(0, 1, 200)
    xs = np.linspace(0, 1, 64)
    assert not make_policy("temporal_hysteresis", cal, 0.0).decide_batch(xs).any()
    assert make_policy("temporal_hysteresis", cal, 1.0).decide_batch(xs).all()
    assert not make_policy("keyframe", cal, 0.0).decide_batch(xs).any()
    assert make_policy("keyframe", cal, 1.0).decide_batch(xs).all()


def test_temporal_hysteresis_validates_params():
    cal = np.zeros(8)
    with pytest.raises(ValueError):
        make_policy("temporal_hysteresis", cal, 0.3, stale_horizon=0.0)
    with pytest.raises(ValueError):
        make_policy("temporal_hysteresis", cal, 0.3, ewma=0.0)
    with pytest.raises(ValueError):
        make_policy("keyframe", cal, 0.3, refractory=0)


def test_keyframe_refractory_spaces_offloads():
    rng = np.random.default_rng(4)
    cal = rng.uniform(0, 1, 500)
    p = make_policy("keyframe", cal, 0.3, refractory=3)
    d = np.array([p.decide(float(e)) for e in rng.uniform(0, 1, 600)])
    gaps = np.diff(np.flatnonzero(d))
    assert gaps.size and gaps.min() >= 3
    assert abs(d.mean() - 0.3) < 0.06


def test_keyframe_refractory_is_a_hard_cap():
    """A target above the refractory ceiling must clamp at 1/refractory —
    a saturated deficit controller may NOT break the spacing (only the
    degenerate ratio-1.0 target is absolute)."""
    rng = np.random.default_rng(7)
    cal = rng.uniform(0, 1, 500)
    p = make_policy("keyframe", cal, 0.6, refractory=3)
    d = np.array([p.decide(float(e)) for e in rng.uniform(0, 1, 2000)])
    gaps = np.diff(np.flatnonzero(d))
    assert gaps.min() >= 3
    assert d.mean() <= 1.0 / 3.0 + 1e-9


def test_keyframe_boosts_scene_changes():
    rng = np.random.default_rng(5)
    cal = rng.uniform(0, 1, 500)
    cuts = set(range(50, 1000, 100))
    t = {"i": 0}
    # refractory=1 leaves every frame eligible, isolating the boost:
    # with the hard cap active a cut right after an offload is (by
    # design) not offloadable, which is not what this test measures
    p = make_policy(
        "keyframe", cal, 0.2, refractory=1,
        scene_change=lambda: 1.0 if t["i"] in cuts else 0.0,
    )
    hits = 0
    estimates = rng.uniform(0, 0.6, 1000)  # below-threshold stream
    for e in estimates:
        if p.decide(float(e)) and t["i"] in cuts:
            hits += 1
        t["i"] += 1
    assert hits >= len([c for c in cuts if c < 1000]) * 0.8  # cuts get offloaded


def test_video_policy_save_strips_probes(tmp_path):
    rng = np.random.default_rng(6)
    x = rng.normal(0, 1, (128, 8)).astype(np.float32)
    from repro.api import MLPRewardModel
    from repro.core import EstimatorConfig

    eng = OffloadEngine(
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(8,), epochs=2)),
        policy="temporal_hysteresis",
        policy_kwargs=dict(staleness=lambda: 1.0, stale_credit=0.7),
        ratio=0.3,
    )
    eng.fit(features=x, rewards=rng.normal(0, 1, 128))
    path = str(tmp_path / "video_engine")
    eng.save(path)
    loaded = OffloadEngine.load(path)
    assert loaded.policy_name == "temporal_hysteresis"
    assert "staleness" not in loaded.policy_kwargs
    assert loaded.policy_kwargs["stale_credit"] == 0.7
    assert loaded.policy.staleness is None


# ------------------------------------------------------------- end to end


@pytest.fixture(scope="module")
def scenario():
    return default_video_scenario(8, 96, seed=0)


def test_serve_clip_staleness_and_accuracy_semantics(scenario):
    trace = run_video_scenario(scenario, "temporal_hysteresis", ratio=0.3)
    assert trace.n_streams == 8 and trace.n_frames == 96
    covered = 0
    for s in trace.streams:
        for r in s.records:
            assert r.effective_accuracy is not None and 0.0 <= r.effective_accuracy <= 1.0
            assert (r.staleness is not None) == (r.source == "edge")
            if r.source == "edge":
                covered += 1
                assert 0.0 <= r.staleness <= scenario.max_stale
            else:
                assert r.source == "weak"
        tel = s.telemetry
        assert tel.effective_frames == len(s.records)
        assert tel.covered_frames == sum(r.source == "edge" for r in s.records)
        d = tel.as_dict(include_video=True)
        assert d["mean_effective_accuracy"] == pytest.approx(s.effective_accuracy())
    assert covered  # stale results actually got reused
    # a frame can only be covered after some offload completed its round trip
    first_edge = min(
        r.step for s in trace.streams for r in s.records if r.source == "edge"
    )
    assert first_edge > 0
    summary = trace.summary()
    assert summary["staleness"]["covered_fraction"] > 0.2


def test_video_trace_bit_identical_across_runs(scenario):
    t1 = run_video_scenario(scenario, "keyframe", ratio=0.3)
    t2 = run_video_scenario(scenario, "keyframe", ratio=0.3)
    for s1, s2 in zip(t1.streams, t2.streams):
        assert s1.records == s2.records
    assert t1.summary() == t2.summary()


def test_temporal_hysteresis_beats_threshold_at_equal_ratio(scenario):
    """The headline acceptance criterion: on the seeded 8-stream congested
    video scenario, ``temporal_hysteresis`` achieves strictly higher mean
    effective accuracy than the per-image ``threshold`` policy at equal
    realized offload ratio."""
    qa = run_video_scenario(scenario, "temporal_hysteresis", ratio=0.3)
    r_qa = qa.realized_ratio()
    acc_qa = qa.mean_effective_accuracy()

    # match the threshold policy's realized ratio empirically over a target
    # grid (estimate-distribution shift moves realized off target)
    runs = [
        run_video_scenario(scenario, "threshold", ratio=t)
        for t in (0.21, 0.24, 0.27, 0.30, 0.33)
    ]
    th = min(runs, key=lambda tr: abs(tr.realized_ratio() - r_qa))
    assert abs(th.realized_ratio() - r_qa) < 0.03, (th.realized_ratio(), r_qa)
    assert acc_qa > th.mean_effective_accuracy(), (
        acc_qa, th.mean_effective_accuracy(),
    )
    # and the win is not bought with extra budget: every threshold run at
    # the same-or-lower realized ratio also scores lower
    for tr in runs:
        if tr.realized_ratio() <= r_qa + 0.02:
            assert acc_qa > tr.mean_effective_accuracy()
    # more of the stream is served by (fresh enough) edge results
    assert (
        qa.staleness_profile()["covered_fraction"]
        >= th.staleness_profile()["covered_fraction"]
    )
