"""repro.obs — the unified observability plane.

Covers the instrument/registry core, the manual-clock tracer, jit retrace
accounting, the dispatch profiler, and the two contracts the serve stack
must hold when an ``Obs`` handle rides along:

- **byte-stability**: telemetry ``as_dict()`` payloads are identical with
  observability on, off, and noop — the counters ARE the instruments, so
  there is exactly one accounting path;
- **determinism**: under the manual clock the same seed produces
  byte-identical metrics and trace exports across runs.
"""
import json

import numpy as np
import pytest

from repro.api import MLPRewardModel, OffloadEngine
from repro.core import EstimatorConfig
from repro.fleet import simulate_fleet
from repro.obs import (
    DEFAULT_TIME_BUCKETS,
    Counter,
    DispatchProfiler,
    Gauge,
    Histogram,
    MetricsRegistry,
    Obs,
    Tracer,
    jit_stats,
)
from repro.runtime import (
    ManualClock,
    default_congested_fleet,
    default_edge_fleet,
    simulate,
)


def fit_engine(policy="threshold", ratio=0.3, n=256, d=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 2.0 * x[:, 0] + 0.3 * rng.normal(size=n)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=15, batch_size=64)
        ),
        policy=policy,
        ratio=ratio,
    )
    eng.fit(features=x, rewards=rewards)
    return eng, x


@pytest.fixture(scope="module")
def engine_and_features():
    return fit_engine()


# ------------------------------------------------------------- instruments


def test_counter_stays_int_under_int_increments():
    c = Counter("c")
    c.inc()
    c.inc(3)
    assert c.value == 4 and isinstance(c.value, int)
    c.inc(0.5)
    assert isinstance(c.value, float)


def test_gauge_set_and_callback():
    g = Gauge("g")
    g.set(2.5)
    assert g.value == 2.5
    state = {"x": 7}
    live = Gauge("live", fn=lambda: state["x"])
    assert live.value == 7
    state["x"] = 9
    assert live.value == 9


def test_histogram_buckets_and_stats():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert list(h.counts) == [1, 1, 1, 1]  # one per bucket + overflow
    assert h.n == 4
    assert h.sum == pytest.approx(105.0)
    assert h.mean == pytest.approx(105.0 / 4)


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        Histogram("h", buckets=(2.0, 1.0))
    Histogram("h", buckets=(1.0, 2.0, 4.0))  # strictly increasing is fine


def test_registry_get_or_create_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("hits", {"edge": "e0"})
    b = reg.counter("hits", {"edge": "e0"})
    c = reg.counter("hits", {"edge": "e1"})
    assert a is b and a is not c
    a.inc(2)
    snap = reg.snapshot()
    assert snap['hits{edge="e0"}'] == 2
    assert snap['hits{edge="e1"}'] == 0


def test_registry_callback_gauge_rebinds_fn():
    # a fresh fleet re-registering the same metric must win the callback
    reg = MetricsRegistry()
    reg.gauge("depth", fn=lambda: 1)
    g = reg.gauge("depth", fn=lambda: 2)
    assert g.value == 2
    assert reg.snapshot()["depth"] == 2


def test_registry_delta():
    reg = MetricsRegistry()
    c = reg.counter("n")
    prev = reg.snapshot()
    c.inc(5)
    d = MetricsRegistry.delta(prev, reg.snapshot())
    assert d["n"] == 5


def test_prometheus_exposition_cumulative_histogram():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0))
    for v in (0.5, 1.5, 9.0):
        h.observe(v)
    text = reg.to_prometheus()
    assert '# TYPE lat histogram' in text
    assert 'lat_bucket{le="1.0"} 1' in text
    assert 'lat_bucket{le="2.0"} 2' in text
    assert 'lat_bucket{le="+Inf"} 3' in text
    assert "lat_count 3" in text


def test_registry_json_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a").inc(3)
    p = tmp_path / "m.json"
    reg.export_json(str(p))
    payload = json.loads(p.read_text())
    assert any(s["name"] == "a" and s["value"] == 3 for s in payload["series"])


# ------------------------------------------------------------------ tracer


def test_tracer_manual_clock_spans():
    clock = ManualClock()
    tr = Tracer()
    tr.bind_clock(clock)
    t0 = tr.clock()
    clock.advance(2.0)
    tr.add_span("work", t0, tr.clock(), tid=1, args={"k": 1})
    doc = tr.to_chrome()
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(evs) == 1
    assert evs[0]["name"] == "work" and evs[0]["dur"] == pytest.approx(2000.0)


def test_tracer_async_pairs_share_id():
    tr = Tracer()
    tr.bind_clock(ManualClock())
    jid = tr.next_id()
    tr.add_async_span("offload", 0.0, 3.0, id=jid, tid=5)
    evs = tr.to_chrome()["traceEvents"]
    b = [e for e in evs if e["ph"] == "b"]
    e = [e for e in evs if e["ph"] == "e"]
    assert len(b) == 1 and len(e) == 1
    assert b[0]["id"] == e[0]["id"]


def test_tracer_overflow_drops_not_grows():
    tr = Tracer(max_events=4)
    tr.bind_clock(ManualClock())
    for i in range(10):
        tr.add_span("s", 0.0, 1.0, tid=0)
    doc = tr.to_chrome()
    assert len([e for e in doc["traceEvents"] if e["ph"] == "X"]) <= 4
    meta = [e for e in doc["traceEvents"] if e.get("name") == "trace_overflow"]
    assert meta and meta[0]["args"]["dropped"] == 6


# --------------------------------------------------------------- jit stats


def test_jit_stats_sites_registered_by_kernel_imports():
    import repro.kernels.score_pipeline  # noqa: F401  (registers sites)

    sites = jit_stats.sites()
    assert "iou_matrix.batch_pallas" in sites
    assert "features.box_feature_stack" in sites


def test_jit_stats_counts_retraces(engine_and_features):
    eng, x = engine_and_features
    before = jit_stats.snapshot()
    eng.score(features=x)
    eng.score(features=x[: len(x) // 2])  # new shape → retrace
    delta = jit_stats.delta(before, jit_stats.snapshot())
    assert sum(traces for traces, _ in delta.values()) >= 1


# ---------------------------------------------------------------- profiler


def test_profiler_report_shares_sum_to_one():
    prof = DispatchProfiler()
    for phase, n in (("a", 3), ("b", 2)):
        for _ in range(n):
            t0 = prof.begin()
            prof.add(phase, t0)
    rep = prof.report()
    assert set(rep) == {"a", "b"}
    assert sum(row["share"] for row in rep.values()) == pytest.approx(1.0)
    assert {phase: row["count"] for phase, row in rep.items()} == {"a": 3, "b": 2}
    assert "phase" in prof.format_report()


# ------------------------------------------------------------- obs handle


def test_noop_handle_disables_every_plane():
    obs = Obs.noop()
    assert obs.metrics is None and obs.tracer is None and obs.profiler is None
    assert not obs.enabled
    assert Obs().enabled


# --------------------------------------------- byte-stability of telemetry


def test_session_telemetry_byte_stable_under_obs(engine_and_features):
    eng, x = engine_and_features
    feats = x[:128]

    def run(obs):
        return simulate(
            eng, features=feats, edges=default_congested_fleet(3, seed=0),
            ratio=0.3, micro_batch=16, seed=0, obs=obs,
        )

    base = run(None).telemetry
    for handle in (Obs(), Obs.noop(), Obs(metrics=False), Obs(tracing=False)):
        t = run(handle).telemetry
        for kwargs in (
            {},
            {"include_video": True},
            {"include_online": True},
            {"include_fleet": True},
        ):
            assert t.as_dict(**kwargs) == base.as_dict(**kwargs), kwargs


def test_fleet_telemetry_byte_stable_under_obs(engine_and_features):
    eng, x = engine_and_features
    rng = np.random.default_rng(3)
    feats = rng.normal(size=(10, 64, x.shape[1])).astype(np.float32)
    base = simulate_fleet(eng, feats, n_shards=4).telemetry
    observed = simulate_fleet(eng, feats, n_shards=4, obs=Obs()).telemetry
    assert observed.as_dict(include_per_shard=True) == base.as_dict(
        include_per_shard=True
    )


# --------------------------------------------- deterministic export bytes


def test_exports_byte_identical_across_seeded_runs(
    engine_and_features, tmp_path
):
    eng, x = engine_and_features
    feats = x[:96]
    payloads = []
    for i in range(2):
        obs = Obs()
        simulate(
            eng, features=feats, edges=default_edge_fleet(3, seed=0),
            ratio=0.3, micro_batch=16, seed=0, obs=obs,
        )
        mp, tp = tmp_path / f"m{i}.json", tmp_path / f"t{i}.json"
        obs.metrics.export_json(str(mp))
        obs.tracer.export(str(tp))
        payloads.append((mp.read_bytes(), tp.read_bytes()))
    assert payloads[0][0] == payloads[1][0], "metrics export not deterministic"
    assert payloads[0][1] == payloads[1][1], "trace export not deterministic"


# --------------------------------------- fleet trace validity and nesting


def test_simulate_fleet_trace_valid_and_nested(engine_and_features):
    eng, x = engine_and_features
    rng = np.random.default_rng(7)
    feats = rng.normal(size=(8, 64, x.shape[1])).astype(np.float32)
    obs = Obs()
    simulate_fleet(eng, feats, n_shards=4, obs=obs)

    doc = json.loads(json.dumps(obs.tracer.to_chrome()))  # valid JSON
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"fleet.tick", "session.flush", "offload"} <= names

    # track layout: driver on 0, sessions on 1+, edges on 100+
    tracks = {
        e["args"]["name"]: e["tid"] for e in evs if e["ph"] == "M"
    }
    assert tracks["fleet"] == 0
    assert all(tracks[f"shard:{s}"] == 1 + s for s in range(4))
    assert all(v >= 100 for k, v in tracks.items() if k.startswith("edge:"))

    # nesting: every session flush sits inside a fleet tick; every edge
    # offload group opens at a session-track decision time or later
    ticks = [
        (e["ts"], e["ts"] + e["dur"]) for e in evs
        if e["name"] == "fleet.tick"
    ]
    flushes = [e for e in evs if e["name"] == "session.flush"]
    assert flushes
    for f in flushes:
        assert 1 <= f["tid"] < 100
        end = f["ts"] + f["dur"]
        assert any(t0 <= f["ts"] and end <= t1 for t0, t1 in ticks)
    offloads = [e for e in evs if e["name"] == "offload" and e["ph"] == "b"]
    assert offloads
    first_flush = min(f["ts"] for f in flushes)
    for o in offloads:
        assert o["tid"] >= 100
        assert o["ts"] >= first_flush
    # children stay inside their offload slice, matched by async id
    ends = {
        e["id"]: e["ts"] for e in evs if e["name"] == "offload" and e["ph"] == "e"
    }
    for child in ("queue", "transmit", "service"):
        for e in evs:
            if e["name"] == child and e["ph"] == "b":
                parent_b = next(
                    o for o in offloads if o["id"] == e["id"]
                )
                assert parent_b["ts"] <= e["ts"] <= ends[e["id"]]


def test_fleet_prometheus_exposes_required_series(engine_and_features):
    eng, x = engine_and_features
    rng = np.random.default_rng(5)
    feats = rng.normal(size=(6, 64, x.shape[1])).astype(np.float32)
    obs = Obs()
    simulate_fleet(eng, feats, n_shards=2, obs=obs)
    text = obs.metrics.to_prometheus()
    for series in (
        "repro_realized_ratio",
        "repro_dispatch_total",
        "repro_edge_queue_depth",
        "repro_offload_rtt",
        "repro_jit_retraces_total",
    ):
        assert series in text, series


# --------------------------------------------------- runtime obs plumbing


def test_simulate_profiler_attributes_phases(engine_and_features):
    eng, x = engine_and_features
    obs = Obs(metrics=False, tracing=False)
    simulate(
        eng, features=x[:64], edges=default_edge_fleet(3, seed=0),
        ratio=0.3, micro_batch=16, seed=0, obs=obs,
    )
    phases = obs.profiler.totals()
    assert {"serve.submit", "serve.settle", "session.score"} <= set(phases)


def test_adaptive_engine_obs_counters(engine_and_features):
    from repro.online import AdaptiveEngine, OnlineConfig

    eng, x = engine_and_features
    obs = Obs()
    ada = AdaptiveEngine(
        eng,
        OnlineConfig(min_observations=1, update_every=32, refit_every=10**9),
        obs=obs,
    )
    rng = np.random.default_rng(0)
    for _ in range(2):
        f = rng.normal(size=(32, x.shape[1])).astype(np.float32)
        est = np.asarray(eng.score(features=f))
        ada.observe(f, est, rng.uniform(size=32))
        ada.maybe_update()
    snap = obs.metrics.snapshot()
    assert snap.get('repro_adaptive_updates_total{kind="incremental"}', 0) >= 1
