"""repro.netsim: link models, the uplink queue, queue-aware policies, and
their integration with the serving runtime (EdgeWorker link front-ends,
per-step latency decomposition, the seeded congestion scenario)."""
import numpy as np
import pytest

from repro.api import MLPRewardModel, OffloadEngine, list_policies, make_policy
from repro.core import EstimatorConfig
from repro.netsim import (
    CHANNEL_BAD,
    CHANNEL_GOOD,
    ConstantRateLink,
    GilbertElliottLink,
    NetworkLink,
    TraceBandwidthLink,
    UplinkQueue,
    quantile_threshold,
    solve_value_iteration,
    value_iteration_ref,
    value_iteration_sweep,
)
from repro.runtime import (
    OUTCOME_OFFLOADED,
    EdgeLatencyModel,
    EdgeWorker,
    default_congested_fleet,
    simulate,
)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - hypothesis is optional (see CI)
    given = None


def synth(n=256, d=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 2.0 * x[:, 0] + 0.3 * rng.normal(size=n)
    return x, rewards


@pytest.fixture(scope="module")
def fitted_engine():
    x, rewards = synth()
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=15, batch_size=64)
        ),
        ratio=0.35,
    )
    eng.fit(features=x, rewards=rewards)
    return eng, x


# ------------------------------------------------------------------- links


def test_constant_rate_link_sizes_delay():
    link = ConstantRateLink(2.0, propagation=0.5)
    assert link.transmit_delay(4.0, now=0.0) == pytest.approx(2.5)
    assert link.transmit_delay(0.0, now=10.0) == pytest.approx(0.5)
    with pytest.raises(ValueError):
        ConstantRateLink(0.0)
    with pytest.raises(ValueError):
        ConstantRateLink(1.0, propagation=-1.0)
    with pytest.raises(ValueError):
        link.transmit_delay(-1.0, 0.0)


def test_trace_bandwidth_link_segments():
    link = TraceBandwidthLink([0.0, 10.0, 20.0], [1.0, 0.5, 2.0])
    assert link.bandwidth_at(-5.0) == 1.0  # before the trace: first segment
    assert link.bandwidth_at(5.0) == 1.0
    assert link.bandwidth_at(10.0) == 0.5  # boundary belongs to the new segment
    assert link.bandwidth_at(19.9) == 0.5
    assert link.bandwidth_at(1e9) == 2.0  # last segment holds forever
    assert link.transmit_delay(1.0, 15.0) == pytest.approx(2.0)
    with pytest.raises(ValueError):
        TraceBandwidthLink([0.0, 5.0], [1.0])
    with pytest.raises(ValueError):
        TraceBandwidthLink([5.0, 0.0], [1.0, 1.0])
    with pytest.raises(ValueError):
        TraceBandwidthLink([0.0], [0.0])


def test_gilbert_elliott_seeded_and_probe_invariant():
    """The channel trajectory is a pure function of (seed, slot): probing
    the future — as queue-delay predictors do — must not perturb it."""
    a = GilbertElliottLink(1.0, p_gb=0.3, p_bg=0.4, seed=7)
    b = GilbertElliottLink(1.0, p_gb=0.3, p_bg=0.4, seed=7)
    assert a.state_at(200.0) in (CHANNEL_GOOD, CHANNEL_BAD)  # far-future probe
    traj_a = [a.state_at(t) for t in np.arange(0.0, 100.0, 0.5)]
    traj_b = [b.state_at(t) for t in np.arange(0.0, 100.0, 0.5)]
    assert traj_a == traj_b
    assert CHANNEL_BAD in traj_a  # p_gb=0.3 over 100 slots: fades happen
    bad_bw = a.bad_bandwidth
    assert bad_bw < a.bandwidth
    t_bad = next(t for t, s in zip(np.arange(0.0, 100.0, 0.5), traj_a) if s)
    assert a.bandwidth_at(t_bad) == bad_bw
    assert a.stationary_bad_fraction() == pytest.approx(0.3 / 0.7)


def test_gilbert_elliott_bounded_materialization():
    """A runaway far-future probe (drain sentinels like t=1e12) raises a
    clear error instead of materializing unbounded slot history."""
    link = GilbertElliottLink(1.0, slot=1.0, max_slots=100)
    assert link.state_at(99.0) in (CHANNEL_GOOD, CHANNEL_BAD)
    with pytest.raises(ValueError, match="max_slots"):
        link.state_at(101.0)
    with pytest.raises(ValueError, match="max_slots"):
        GilbertElliottLink(1.0, seed=0).bandwidth_at(1e12)


def test_gilbert_elliott_validates_params():
    with pytest.raises(ValueError):
        GilbertElliottLink(1.0, p_gb=1.5)
    with pytest.raises(ValueError):
        GilbertElliottLink(1.0, p_bg=-0.1)
    with pytest.raises(ValueError):
        GilbertElliottLink(1.0, slot=0.0)
    with pytest.raises(ValueError):
        GilbertElliottLink(1.0, bad_bandwidth=0.0)


# ------------------------------------------------------------ uplink queue


def test_uplink_queue_fifo_schedule_exact():
    """Back-to-back frames serialize on the link; the schedule is computed
    at enqueue and every sojourn decomposes exactly."""
    q = UplinkQueue(ConstantRateLink(1.0), depth=8, frame_bits=2.0)
    f0 = q.enqueue(0.0, 0)
    f1 = q.enqueue(0.5, 1)
    f2 = q.enqueue(5.0, 2)
    assert (f0.t_start, f0.t_delivered) == (0.0, 2.0)
    assert (f1.t_start, f1.t_delivered) == (2.0, 4.0)  # waited for f0
    assert f1.queue_delay == pytest.approx(1.5)
    assert (f2.t_start, f2.t_delivered) == (5.0, 7.0)  # link went idle
    for f in (f0, f1, f2):
        assert f.sojourn == pytest.approx(f.queue_delay + f.transmit_delay)
    # enqueue(5.0, ...) advanced the queue's clock past f0/f1 delivery
    assert q.delivered == [f0, f1]
    assert q.occupancy == 1
    assert q.poll(100.0) == [f2]


def test_uplink_queue_bounded_depth_drops():
    q = UplinkQueue(ConstantRateLink(0.1), depth=2, frame_bits=1.0)
    assert q.enqueue(0.0, 0) is not None
    assert q.enqueue(0.0, 1) is not None
    assert q.enqueue(0.0, 2) is None  # full -> dropped, counted
    assert q.stats()["dropped"] == 1
    q.poll(1e9)
    assert q.enqueue(1e9, 3) is not None  # drained -> admits again


def test_uplink_queue_predictions_pure():
    q = UplinkQueue(ConstantRateLink(1.0), depth=8, frame_bits=3.0)
    assert q.predicted_wait(0.0) == 0.0
    assert q.predicted_sojourn(0.0) == pytest.approx(3.0)
    q.enqueue(0.0, 0)
    before = q.stats()
    assert q.predicted_wait(1.0) == pytest.approx(2.0)
    assert q.predicted_sojourn(1.0) == pytest.approx(5.0)
    assert q.stats() == before  # prediction does not mutate accounting


def _random_queue_run(seed: int):
    """Drive a random config + arrival pattern; return (queue, offered)."""
    rng = np.random.default_rng(seed)
    link_kind = rng.integers(0, 3)
    if link_kind == 0:
        link = ConstantRateLink(float(rng.uniform(0.2, 3.0)))
    elif link_kind == 1:
        times = np.cumsum(rng.uniform(0.5, 3.0, 4)) - 0.5
        link = TraceBandwidthLink(times, rng.uniform(0.2, 3.0, 4))
    else:
        link = GilbertElliottLink(
            float(rng.uniform(0.5, 3.0)), p_gb=0.2, p_bg=0.3, seed=int(seed)
        )
    q = UplinkQueue(link, depth=int(rng.integers(1, 6)), frame_bits=1.0)
    offered = int(rng.integers(1, 40))
    t = 0.0
    for i in range(offered):
        t += float(rng.uniform(0.0, 1.5))
        q.enqueue(t, i, float(rng.uniform(0.1, 4.0)))
    return q, offered


@pytest.mark.parametrize("seed", range(12))
def test_uplink_queue_conservation_seeded(seed):
    """Every offered frame is exactly one of delivered / dropped once the
    clock passes the last schedule (no frame lost, none double-counted)."""
    q, offered = _random_queue_run(seed)
    q.poll(1e12)
    st = q.stats()
    assert st["delivered"] + st["dropped"] == offered
    assert st["occupancy"] == 0
    # delivered frames left in FIFO order with non-overlapping transmissions
    delivered = q.delivered
    for a, b in zip(delivered, delivered[1:]):
        assert b.t_start >= a.t_delivered - 1e-12
        assert b.t_enqueue >= a.t_enqueue - 1e-12


if given is not None:

    @settings(max_examples=60, deadline=None)
    @given(
        depth=st.integers(1, 6),
        bandwidth=st.floats(0.2, 3.0),
        arrivals=st.lists(
            st.tuples(st.floats(0.0, 2.0), st.floats(0.0, 4.0)),
            min_size=1,
            max_size=40,
        ),
    )
    def test_uplink_queue_conservation_property(depth, bandwidth, arrivals):
        """Hypothesis form of the conservation law: enqueued + dropped
        partition the offered frames under arbitrary arrival patterns."""
        q = UplinkQueue(ConstantRateLink(bandwidth), depth=depth)
        t = 0.0
        for i, (gap, size) in enumerate(arrivals):
            t += gap
            q.enqueue(t, i, size)
        q.poll(1e12)
        st = q.stats()
        assert st["delivered"] + st["dropped"] == len(arrivals)
        assert st["delivered"] == st["enqueued"]
        assert st["occupancy"] == 0

else:

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_uplink_queue_conservation_property(seed):
        """Deterministic stand-in for the hypothesis form above (hypothesis
        is not installed in this environment): the same conservation law —
        delivered + dropped partition the offered frames — over seeded
        random depths, bandwidths, and arrival patterns."""
        rng = np.random.default_rng(seed)
        depth = int(rng.integers(1, 7))
        bandwidth = float(rng.uniform(0.2, 3.0))
        n = int(rng.integers(1, 41))
        q = UplinkQueue(ConstantRateLink(bandwidth), depth=depth)
        t = 0.0
        for i in range(n):
            t += float(rng.uniform(0.0, 2.0))
            q.enqueue(t, i, float(rng.uniform(0.0, 4.0)))
        q.poll(1e12)
        stats = q.stats()
        assert stats["delivered"] + stats["dropped"] == n
        assert stats["delivered"] == stats["enqueued"]
        assert stats["occupancy"] == 0


# -------------------------------------------------------- queue_aware policy


def test_queue_aware_registered_and_constructible():
    assert "queue_aware" in list_policies()
    assert "value_iteration" in list_policies()
    cal = np.random.default_rng(0).uniform(0, 1, 200)
    p = make_policy("queue_aware", cal, 0.3)
    assert p.spec() == {"delay_weight": 0.5, "delay_scale": 2.0, "gain": 0.05}


def test_queue_aware_tracks_ratio_without_congestion():
    rng = np.random.default_rng(1)
    cal = rng.uniform(0, 1, 500)
    p = make_policy("queue_aware", cal, 0.3)
    mask = p.decide_batch(rng.uniform(0, 1, 1000))
    assert abs(mask.mean() - 0.3) < 0.05


def test_queue_aware_controller_compensates_constant_congestion():
    """Under *any* constant congestion level the integral controller pulls
    the realized ratio back to the target — deferral is paid back."""
    rng = np.random.default_rng(2)
    cal = rng.uniform(0, 1, 500)
    for delay in (0.0, 1.0, 5.0, 50.0):
        p = make_policy("queue_aware", cal, 0.3, congestion=lambda d=delay: d)
        mask = p.decide_batch(rng.uniform(0, 1, 2000))
        assert abs(mask.mean() - 0.3) < 0.05, f"delay={delay}"


def test_queue_aware_defers_under_congestion_spikes():
    """A congestion spike suppresses offloads during the spike relative to
    the calm stretches of the same stream."""
    rng = np.random.default_rng(3)
    cal = rng.uniform(0, 1, 500)
    t = {"i": 0}

    def congestion():
        return 40.0 if 400 <= t["i"] < 600 else 0.0

    p = make_policy("queue_aware", cal, 0.4, congestion=congestion)
    decisions = []
    for e in rng.uniform(0, 1, 1000):
        decisions.append(p.decide(float(e)))
        t["i"] += 1
    d = np.array(decisions)
    spike, calm = d[400:600].mean(), np.concatenate([d[:400], d[600:]]).mean()
    assert spike < calm
    assert abs(d.mean() - 0.4) < 0.06  # overall budget still tracked


def test_queue_aware_degenerate_budgets_stay_hard():
    cal = np.random.default_rng(4).uniform(0, 1, 100)
    never = make_policy("queue_aware", cal, 0.0)
    always = make_policy("queue_aware", cal, 1.0)
    xs = np.linspace(0, 1, 64)
    assert not never.decide_batch(xs).any()
    assert always.decide_batch(xs).all()


def test_queue_aware_validates_delay_scale():
    with pytest.raises(ValueError):
        make_policy("queue_aware", np.zeros(4), 0.3, delay_scale=0.0)


# --------------------------------------------------------- value iteration


def test_value_iteration_scan_matches_python_ref():
    e = np.linspace(0.0, 1.0, 16)
    V1, th1 = solve_value_iteration(e, 0.4, max_queue=8, n_sweeps=40)
    V2, th2 = value_iteration_ref(e, 0.4, max_queue=8, n_sweeps=40)
    np.testing.assert_allclose(V1, V2, atol=1e-4)
    np.testing.assert_allclose(th1, th2, atol=1e-4)


def test_value_iteration_thresholds_monotone_in_state():
    """Deeper queues and the bad channel both demand higher estimates —
    the qualitative shape the MDP is for."""
    e = np.linspace(0.0, 1.0, 32)
    _, theta = solve_value_iteration(e, 0.3, max_queue=10, n_sweeps=60)
    assert theta.shape == (11, 2)
    # interior states only: at the truncated top state q=Q the offload
    # transition min(q+1, Q) self-loops, so its marginal congestion cost
    # (and hence the threshold) can dip — a boundary artifact, not a bug
    assert np.all(np.diff(theta[:-1, CHANNEL_GOOD]) > 0)
    assert np.all(np.diff(theta[:-1, CHANNEL_BAD]) > 0)
    assert np.all(theta[:, CHANNEL_BAD] >= theta[:, CHANNEL_GOOD])


def test_value_iteration_sweep_batches_ratio_grid():
    cal = np.random.default_rng(5).uniform(0, 1, 400)
    ratios = [0.1, 0.3, 0.6]
    thetas = value_iteration_sweep(cal, ratios, max_queue=8, n_sweeps=40)
    assert thetas.shape == (3, 9, 2)
    for i, r in enumerate(ratios):
        single = solve_value_iteration(
            np.quantile(cal, (np.arange(32) + 0.5) / 32),
            quantile_threshold(cal, r),
            max_queue=8,
            n_sweeps=40,
        )[1]
        np.testing.assert_allclose(thetas[i], single, atol=1e-4)
    # more budget -> lower thresholds, uniformly over states
    assert np.all(thetas[2] < thetas[0])


def test_value_iteration_sweep_rejects_unknown_kwargs():
    """Misspelled MDP parameters must fail loudly, not silently default."""
    with pytest.raises(TypeError):
        value_iteration_sweep(np.linspace(0, 1, 16), [0.3], delay_costs=0.2)


def test_value_iteration_policy_conditions_on_state():
    cal = np.random.default_rng(6).uniform(0, 1, 400)
    state = {"q": 0, "c": CHANNEL_GOOD}
    p = make_policy(
        "value_iteration", cal, 0.4, max_queue=8, n_sweeps=40,
        state_probe=lambda: (state["q"], state["c"]),
    )
    # a borderline estimate offloads from an idle queue, not a deep one
    border = float((p.theta[0, CHANNEL_GOOD] + p.theta[8, CHANNEL_GOOD]) / 2.0)
    assert p.decide(border)
    state["q"] = 8
    assert not p.decide(border)
    state["q"] = 0
    state["c"] = CHANNEL_BAD
    assert p.theta[0, CHANNEL_BAD] > p.theta[0, CHANNEL_GOOD]
    p.set_ratio(1.0)
    assert p.decide(0.0)  # always-offload budget wins in any state


# ----------------------------------------------- EdgeWorker link front-end


def test_edge_worker_link_breakdown_decomposes():
    e = EdgeWorker(
        "e0", capacity=8,
        latency=EdgeLatencyModel(base=0.5),
        link=ConstantRateLink(0.5), queue_depth=4, frame_bits=1.0,
    )
    lat0 = e.try_admit(0.0, 0, 0.9)
    bd0 = e.last_breakdown
    assert lat0 == pytest.approx(2.5)  # transmit 2.0 + service 0.5
    assert (bd0.queue, bd0.transmit, bd0.service) == (0.0, 2.0, 0.5)
    lat1 = e.try_admit(0.0, 1, 0.9)
    bd1 = e.last_breakdown
    assert bd1.queue == pytest.approx(2.0)  # behind frame 0 on the link
    assert lat1 == pytest.approx(bd1.total)
    assert e.stats()["uplink"]["enqueued"] == 2


def test_edge_worker_link_queue_full_rejects():
    e = EdgeWorker(
        "e0", capacity=100,
        latency=EdgeLatencyModel(base=0.1),
        link=ConstantRateLink(0.01), queue_depth=2,
    )
    assert e.try_admit(0.0, 0, 0.9) is not None
    assert e.try_admit(0.0, 1, 0.9) is not None
    assert e.try_admit(0.0, 2, 0.9) is None  # uplink full, capacity free
    assert e.stats()["rejected"] == 1
    # the worker pre-checks fullness, so the queue never saw the frame
    assert e.stats()["uplink"]["dropped"] == 0
    assert e.stats()["uplink"]["occupancy"] == 2


def test_edge_worker_full_uplink_does_not_burn_rate_token():
    """A full uplink queue must reject BEFORE the rate limiter spends a
    token, or the edge's budget drains on frames that were never sent."""
    e = EdgeWorker(
        "e0", capacity=100, rate=0.0, burst=2.0,
        latency=EdgeLatencyModel(base=0.1),
        link=ConstantRateLink(0.5), queue_depth=1,  # transmit = 2.0
    )
    assert e.try_admit(0.0, 0, 0.9) is not None  # spends 1 of 2 burst tokens
    assert e.try_admit(0.0, 1, 0.9) is None      # queue full: NO token spent
    # rate=0 never refills: the admit after the queue drains needs the
    # token the full-queue rejection must have preserved
    assert e.try_admit(3.0, 2, 0.9) is not None
    assert e.try_admit(6.0, 3, 0.9) is None  # burst truly exhausted now


def test_edge_worker_link_free_breakdown_is_pure_service():
    e = EdgeWorker("e0", capacity=2, latency=EdgeLatencyModel(base=1.0))
    lat = e.try_admit(0.0, 0, 0.9)
    bd = e.last_breakdown
    assert (bd.queue, bd.transmit) == (0.0, 0.0)
    assert bd.service == pytest.approx(lat)
    assert e.predicted_uplink_delay(0.0) == 0.0
    assert e.uplink_state(0.0) == (0, CHANNEL_GOOD)


def test_edge_worker_congestion_probes():
    e = EdgeWorker(
        "e0", capacity=8, latency=EdgeLatencyModel(base=0.1),
        link=ConstantRateLink(0.5), queue_depth=8,
    )
    assert e.predicted_uplink_delay(0.0) == 0.0
    e.try_admit(0.0, 0, 0.9)
    assert e.predicted_uplink_delay(0.0) == pytest.approx(2.0)
    assert e.uplink_state(0.0) == (1, CHANNEL_GOOD)
    assert e.expected_latency() > e.latency.base  # sojourn folded into weight
    e.poll(10.0)
    assert e.uplink_state(10.0) == (0, CHANNEL_GOOD)


# ----------------------------------------------------- end-to-end scenario


def test_simulate_linked_fleet_latency_decomposes(fitted_engine):
    """Acceptance: every offloaded StepRecord's latency splits exactly into
    queue + transmit + service."""
    eng, x = fitted_engine
    trace = simulate(
        eng, features=x[:200], edges=default_congested_fleet(3, seed=3),
        ratio=0.35, micro_batch=1, seed=3,
    )
    offloaded = [r for r in trace.records if r.outcome == OUTCOME_OFFLOADED]
    assert offloaded
    for r in offloaded:
        assert r.latency == pytest.approx(
            r.queue_delay + r.transmit_delay + r.service_delay
        )
    locals_ = [r for r in trace.records if r.outcome != OUTCOME_OFFLOADED]
    assert all(r.queue_delay is None for r in locals_)
    d = trace.latency_decomposition()
    assert d is not None and d["total"] == pytest.approx(
        d["queue"] + d["transmit"] + d["service"]
    )
    assert "uplink" in trace.dispatcher["edges"]["edge0"]


def test_simulate_linked_fleet_bit_identical(fitted_engine):
    """Seeded determinism holds through the netsim layer: two runs of the
    same congested simulation produce identical traces record-for-record."""
    eng, x = fitted_engine

    def run():
        return simulate(
            eng, features=x[:160], edges=default_congested_fleet(3, seed=9),
            ratio=0.35, micro_batch=4, seed=9,
        )

    t1, t2 = run(), run()
    assert t1.records == t2.records
    assert t1.summary() == t2.summary()


def test_queue_aware_beats_threshold_at_equal_ratio(fitted_engine):
    """The headline acceptance criterion: on the seeded congestion scenario
    the queue_aware policy strictly reduces mean end-to-end offload latency
    vs the plain threshold at (approximately) equal realized offload ratio."""
    eng, x = fitted_engine
    rng = np.random.default_rng(42)
    stream = rng.normal(0, 1, (400, x.shape[1])).astype(np.float32)

    qa = simulate(
        eng.with_policy("queue_aware"), features=stream,
        edges=default_congested_fleet(3, seed=5), ratio=0.35,
        micro_batch=1, seed=5,
    )
    r_qa = qa.telemetry.realized_ratio

    # threshold run whose realized ratio lands closest to queue_aware's
    # (the stream's estimate distribution shifts realized off target, so
    # match empirically over a target grid instead of assuming realized ==
    # target)
    def threshold_run(target):
        return simulate(
            eng.with_policy("threshold", ratio=target), features=stream,
            edges=default_congested_fleet(3, seed=5), ratio=target,
            micro_batch=1, seed=5,
        )

    runs = [threshold_run(t) for t in (0.30, 0.33, 0.35, 0.38, 0.40)]
    th = min(runs, key=lambda tr: abs(tr.telemetry.realized_ratio - r_qa))
    r_th = th.telemetry.realized_ratio
    assert abs(r_qa - r_th) < 0.02, (r_qa, r_th)
    lat_qa = qa.summary()["mean_offload_latency"]
    lat_th = th.summary()["mean_offload_latency"]
    assert lat_qa < lat_th, (lat_qa, lat_th)
    # the win comes from the queue component, as it should
    assert qa.latency_decomposition()["queue"] < th.latency_decomposition()["queue"]
    # and it is not bought by offloading less: every threshold run at the
    # same-or-lower realized ratio also pays more latency
    for tr in runs:
        if tr.telemetry.realized_ratio <= r_qa + 0.02:
            assert lat_qa < tr.summary()["mean_offload_latency"]


def test_value_iteration_end_to_end_runs(fitted_engine):
    eng, x = fitted_engine
    trace = simulate(
        eng.with_policy(
            "value_iteration",
            policy_kwargs=dict(max_queue=12, n_sweeps=40, delay_cost=0.03),
        ),
        features=x[:160], edges=default_congested_fleet(3, seed=5),
        ratio=0.35, micro_batch=1, seed=5,
    )
    counts = trace.outcome_counts()
    assert counts.get(OUTCOME_OFFLOADED, 0) > 0
    assert sum(counts.values()) == 160


# --------------------------------------------------------- engine plumbing


def test_engine_with_policy_shares_fit(fitted_engine):
    eng, x = fitted_engine
    clone = eng.with_policy("queue_aware", ratio=0.2)
    assert clone.calibration_scores is eng.calibration_scores
    assert clone.reward_model is eng.reward_model
    assert clone.policy_name == "queue_aware" and clone.ratio == 0.2
    assert eng.policy_name == "threshold" and eng.ratio == 0.35  # untouched
    with pytest.raises(RuntimeError):
        OffloadEngine().with_policy("threshold")


def test_engine_with_policy_uses_live_policy_ratio(fitted_engine):
    """Back-compat callers re-budget the policy directly (like save()
    handles): the clone must inherit the LIVE budget, not the stale one."""
    eng, _ = fitted_engine
    base = eng.with_policy("threshold")  # fresh clone to mutate safely
    base.policy.set_ratio(0.6)  # direct policy re-budget, engine.ratio stale
    clone = base.with_policy("queue_aware")
    assert clone.ratio == pytest.approx(0.6)


def test_engine_save_strips_context_callables(fitted_engine, tmp_path):
    """Injected congestion probes are runtime wiring like the token-bucket
    clock: saving must drop them, loading must rebuild cleanly."""
    eng, _ = fitted_engine
    qa = eng.with_policy(
        "queue_aware", policy_kwargs=dict(congestion=lambda: 0.0, gain=2.0)
    )
    path = str(tmp_path / "qa_engine")
    qa.save(path)
    loaded = OffloadEngine.load(path)
    assert loaded.policy_name == "queue_aware"
    assert "congestion" not in loaded.policy_kwargs
    assert loaded.policy_kwargs["gain"] == 2.0
    assert loaded.policy.congestion is None
