"""repro.fleet — fleet budget coordination, the fleet_fair policy, the
city-scale runtime, and the coordinated-vs-static headline.

The sharded data plane's bit-identity to single-device lives in
``tests/test_sharding.py`` (it needs a forced multi-device subprocess);
here everything runs on the plain 1-device view, where the plane degrades
to the single-device paths and the *logical* shard machinery is exercised
in full.
"""
import numpy as np
import pytest

from repro.api import MLPRewardModel, OffloadEngine, list_policies, make_policy
from repro.core import EstimatorConfig
from repro.fleet import (
    FleetBudget,
    FleetRuntime,
    default_city_scenario,
    run_city_scenario,
    simulate_fleet,
)
from repro.runtime import ManualClock, OffloadSession


def fit_engine(policy="threshold", ratio=0.3, n=256, d=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 2.0 * x[:, 0] + 0.3 * rng.normal(size=n)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=15, batch_size=64)
        ),
        policy=policy,
        ratio=ratio,
    )
    eng.fit(features=x, rewards=rewards)
    return eng, x


@pytest.fixture(scope="module")
def threshold_engine():
    return fit_engine()


# ------------------------------------------------------------ fleet budget


def test_fleet_budget_conserves_total_rate():
    clock = ManualClock()
    budget = FleetBudget(
        16.0, 4, depth=8.0, clock=clock, redistribute_every=1.0, smooth=1.0
    )
    assert np.isclose(sum(b.rate for b in budget.buckets), 16.0)
    # skewed realized rewards: shard 3 spends on high scores, shard 0 low
    for shard, score in enumerate((0.05, 0.2, 0.5, 0.9)):
        for _ in range(8):
            budget.record_reward(shard, score)
    budget.maybe_redistribute(clock())  # first call only stamps the clock
    clock.advance(1.0)
    assert budget.maybe_redistribute(clock())
    assert budget.redistributions == 1
    assert np.isclose(sum(b.rate for b in budget.buckets), 16.0)
    assert np.isclose(budget.shares.sum(), 1.0)
    # shares ordered like the reward signal
    assert list(budget.shares) == sorted(budget.shares)
    assert budget.shares[3] > 0.25 > budget.shares[0]


def test_fleet_budget_min_share_floor():
    clock = ManualClock()
    budget = FleetBudget(
        8.0, 4, clock=clock, redistribute_every=1.0, smooth=1.0, min_share=0.4
    )
    # extreme skew: only shard 0 ever realizes reward
    for _ in range(16):
        budget.record_reward(0, 1.0)
    budget.maybe_redistribute(clock())
    clock.advance(1.0)
    assert budget.maybe_redistribute(clock())
    floor = 0.4 / 4
    assert all(s >= floor - 1e-12 for s in budget.shares)
    assert budget.shares[0] == max(budget.shares)


def test_fleet_budget_congestion_only_shock_shifts_shares():
    """Equal realized rewards everywhere; shard 0's uplink alone drowns.
    The congestion discount must move budget *away* from shard 0 even
    though the reward signal is flat — tokens sent into a saturated uplink
    buy queueing delay, not accuracy."""
    clock = ManualClock()
    budget = FleetBudget(
        16.0, 4, clock=clock, redistribute_every=1.0, smooth=1.0,
        congestion_weight=0.5, staleness_weight=0.5,
    )
    for shard in range(4):
        for _ in range(8):
            budget.record_reward(shard, 0.5)  # identical reward signal
            budget.record_congestion(shard, 8.0 if shard == 0 else 0.5)
    budget.maybe_redistribute(clock())
    clock.advance(1.0)
    assert budget.maybe_redistribute(clock())
    assert np.isclose(budget.shares.sum(), 1.0)
    assert budget.shares[0] < 0.25  # below the equal split
    assert all(budget.shares[s] > budget.shares[0] for s in range(1, 4))
    # uncongested shards stay symmetric with each other
    assert np.allclose(budget.shares[1:], budget.shares[1])
    assert np.isclose(sum(b.rate for b in budget.buckets), 16.0)
    # with the signal disabled the same shock changes nothing
    flat = FleetBudget(
        16.0, 4, clock=ManualClock(), redistribute_every=1.0, smooth=1.0,
        congestion_weight=0.0, staleness_weight=0.0,
    )
    for shard in range(4):
        for _ in range(8):
            flat.record_reward(shard, 0.5)
            flat.record_congestion(shard, 8.0 if shard == 0 else 0.5)
    flat.maybe_redistribute(0.0)
    assert flat.maybe_redistribute(1.0)
    assert np.allclose(flat.shares, 0.25)


def test_fleet_budget_staleness_boosts_starved_shard():
    clock = ManualClock()
    budget = FleetBudget(
        16.0, 4, clock=clock, redistribute_every=1.0, smooth=1.0,
        congestion_weight=0.0, staleness_weight=0.5,
    )
    for shard in range(4):
        for _ in range(8):
            budget.record_reward(shard, 0.5)
            budget.record_staleness(shard, 9.0 if shard == 2 else 1.0)
    budget.maybe_redistribute(clock())
    clock.advance(1.0)
    assert budget.maybe_redistribute(clock())
    assert budget.shares[2] == max(budget.shares) and budget.shares[2] > 0.25
    assert np.isclose(budget.shares.sum(), 1.0)


def test_fleet_budget_static_never_redistributes():
    clock = ManualClock()
    budget = FleetBudget(8.0, 4, clock=clock, redistribute_every=None)
    for _ in range(16):
        budget.record_reward(3, 1.0)
    for _ in range(10):
        clock.advance(5.0)
        assert not budget.maybe_redistribute(clock())
    assert list(budget.shares) == [0.25] * 4
    assert budget.redistributions == 0


def test_fleet_budget_validates():
    with pytest.raises(ValueError):
        FleetBudget(8.0, 0)
    with pytest.raises(ValueError):
        FleetBudget(-1.0, 2)
    with pytest.raises(ValueError):
        FleetBudget(8.0, 2, min_share=1.5)


# ------------------------------------------------------- fleet_fair policy


def test_fleet_fair_registered():
    assert "fleet_fair" in list_policies()


def test_fleet_fair_artifact_strips_runtime_wiring(threshold_engine):
    eng, _ = threshold_engine
    budget = FleetBudget(8.0, 4, clock=ManualClock())
    clone = eng.with_policy(
        "fleet_fair",
        ratio=0.3,
        policy_kwargs={"gain": 0.1, "budget": budget, "shard": 2},
    )
    _, meta = clone.artifact_state()
    kwargs = meta["policy"]["kwargs"]
    assert kwargs == {"gain": 0.1}
    assert meta["policy"]["name"] == "fleet_fair"


def test_fleet_fair_without_budget_tracks_ratio():
    """Uncoordinated degrade: the integral-tracked local quantile converges
    the realized ratio to the target on a stationary stream."""
    rng = np.random.default_rng(0)
    policy = make_policy("fleet_fair", rng.uniform(0, 1, 512), 0.3)
    mask = policy.decide_batch(rng.uniform(0, 1, 2000))
    assert abs(mask.mean() - 0.3) < 0.03


def test_fleet_fair_local_window_recalibrates_skewed_shard():
    """A shard whose scores live in the bottom fifth of the fleet-wide
    calibration range must still realize its allocated ratio: the rolling
    local window replaces the (unreachable) global quantile."""
    rng = np.random.default_rng(1)
    cal = rng.uniform(0, 1, 512)  # fleet-wide calibration
    policy = make_policy("fleet_fair", cal, 0.25, window=256, warmup=64)
    local = rng.uniform(0, 0.2, 4000)  # this shard's actual traffic
    mask = policy.decide_batch(local)
    steady = mask[1000:]
    assert abs(steady.mean() - 0.25) < 0.03
    # and it selects within the local distribution: offloads score higher
    assert local[1000:][steady].mean() > local[1000:][~steady].mean() + 0.05


def test_fleet_fair_token_refusal_keeps_selectivity():
    """Token refusals must not wind the threshold down: with a bucket rate
    at half the allocated want-rate, the policy keeps offloading only
    top-quantile frames instead of chasing the shortfall."""
    rng = np.random.default_rng(2)
    clock = ManualClock()
    # rate 0.125/frame vs want-rate 0.25/frame -> ~half the wants refused
    budget = FleetBudget(0.125, 1, depth=4.0, clock=clock)
    policy = make_policy(
        "fleet_fair", rng.uniform(0, 1, 512), 0.25, budget=budget, shard=0
    )
    xs = rng.uniform(0, 1, 3000)
    taken = []
    for i, x in enumerate(xs):
        if policy.decide(float(x)):
            taken.append((i, x))
        clock.advance(1.0)
    assert policy.denied > 100  # the bucket genuinely refused wants
    # realized rate pinned by the bucket, not the controller
    assert len(taken) / len(xs) < 0.25 * 0.75
    # selectivity preserved past warmup: the taken frames are still the
    # top-quantile ones (windup would hand tokens to ~anything above a
    # collapsed threshold, dragging the mean toward 0.6)
    steady = [x for i, x in taken if i >= 500]
    assert np.mean(steady) > 0.82


def test_fleet_fair_rejects_bad_shard():
    budget = FleetBudget(8.0, 2, clock=ManualClock())
    with pytest.raises(ValueError):
        make_policy("fleet_fair", np.ones(8), 0.3, budget=budget, shard=5)


# -------------------------------------------------- submit_scored session


def test_submit_scored_matches_submit_batch(threshold_engine):
    """Centrally-scored fan-out (the fleet seam) decides exactly like the
    session scoring the same frames itself."""
    eng, x = threshold_engine
    a = OffloadSession(eng, micro_batch=8)
    ref = a.submit_batch(features=x[:64])
    b = OffloadSession(eng, micro_batch=8)
    scores = np.asarray(eng.score(features=x[:64]), np.float64).ravel()
    got = []
    for lo in range(0, 64, 16):
        got.extend(b.submit_scored(scores[lo : lo + 16]))
    assert [d.offload for d in got] == [d.offload for d in ref]
    assert [d.step for d in got] == [d.step for d in ref]
    np.testing.assert_allclose(
        [d.estimate for d in got], [d.estimate for d in ref]
    )
    assert a.telemetry.as_dict() == b.telemetry.as_dict()


def test_submit_scored_refuses_pending_unscored(threshold_engine):
    eng, x = threshold_engine
    session = OffloadSession(eng, micro_batch=8)
    session.submit(features=x[0])  # buffered, unscored
    with pytest.raises(RuntimeError, match="flush"):
        session.submit_scored(np.array([0.5]))
    session.flush()
    assert session.submit_scored(np.array([0.5]))  # drained -> fine


# ----------------------------------------------------------- FleetRuntime


def test_fleet_runtime_smoke(threshold_engine):
    eng, _ = threshold_engine
    rng = np.random.default_rng(3)
    feats = rng.normal(0, 1, (8, 32, 12)).astype(np.float32)
    trace = simulate_fleet(eng, feats, n_shards=4, ratio=0.3, seed=0)
    t = trace.telemetry
    assert t.n_streams == 32 and t.n_shards == 4
    assert t.processed == 8 * 32
    assert t.offloaded == int(trace.decision_mask().sum())
    assert t.realized_ratio == pytest.approx(t.offloaded / t.processed)
    assert len(t.per_shard) == 4
    assert all("budget_share" in d for d in t.per_shard)
    # an edge-served frame is always a frame the policy offloaded
    assert not np.any(trace.offload_mask() & ~trace.decision_mask())
    assert set(trace.dispatcher) == {f"shard{i}" for i in range(4)}
    summary = trace.summary()
    assert summary["ticks"] == 8
    assert sum(summary["outcomes"].values()) >= t.offloaded


def test_fleet_runtime_deterministic(threshold_engine):
    eng, _ = threshold_engine
    rng = np.random.default_rng(4)
    feats = rng.normal(0, 1, (6, 16, 12)).astype(np.float32)
    kw = dict(n_shards=4, ratio=0.3, redistribute_every=2.0, seed=7)
    t1 = simulate_fleet(eng, feats, **kw)
    t2 = simulate_fleet(eng, feats, **kw)
    for s1, s2 in zip(t1.steps, t2.steps):
        np.testing.assert_array_equal(s1.offload, s2.offload)
        np.testing.assert_array_equal(s1.outcome, s2.outcome)
        np.testing.assert_array_equal(
            s1.latency[s1.served_strong()], s2.latency[s2.served_strong()]
        )
    assert t1.telemetry.as_dict() == t2.telemetry.as_dict()


def test_fleet_runtime_validates(threshold_engine):
    eng, _ = threshold_engine
    with pytest.raises(ValueError):
        FleetRuntime(eng, 2, n_shards=4)
    rt = FleetRuntime(eng, 8, n_shards=2)
    with pytest.raises(ValueError):
        rt.step(np.zeros((4, 12), np.float32))
    with pytest.raises(ValueError):
        simulate_fleet(eng, np.zeros((8, 12), np.float32))  # not 3-D


# ------------------------------------------------------- the city headline


def test_city_coordinated_beats_static_equal_budget():
    """The PR's headline: reward-driven budget redistribution beats the
    static equal split on mean effective accuracy, at (approximately) equal
    total realized offload spend — same scenario, same engine, same fleets,
    same clock; only ``redistribute_every`` differs."""
    scenario = default_city_scenario(
        n_streams=256, n_ticks=32, calibration_frames=2048
    )
    static = run_city_scenario(scenario, coordinated=False)
    coord = run_city_scenario(scenario, coordinated=True)
    # equal-budget comparison: realized spend within 2 points
    assert abs(coord.realized_ratio() - static.realized_ratio()) <= 0.02
    assert coord.mean_effective() > static.mean_effective()
    # the mechanism, not luck: budget actually moved toward hard districts
    assert coord.trace.telemetry.budget_redistributions >= 2
    shares = coord.trace.telemetry.shard_shares
    assert shares[-1] > 0.25 > shares[0]  # hardest up, easiest down
    ratios = coord.trace.telemetry.shard_ratios
    assert ratios[-1] > ratios[0]
    # static arm's split never moved
    assert static.trace.telemetry.shard_shares == (0.25,) * 4
