"""repro.mobility: seeded motion traces (scan ≡ reference, bit-identical
reruns), coverage/path-loss mapping, downlink conservation, handover
hysteresis + in-flight semantics, the mobility_aware policy, and the
headline: handover-aware dispatch beats static pinning in mean effective
accuracy at equal realized offload ratio."""
import numpy as np
import pytest

from repro.api import MLPRewardModel, OffloadEngine, list_policies, make_policy
from repro.core import EstimatorConfig
from repro.mobility import (
    BaseStation,
    CoverageMap,
    HandoverController,
    MobileRuntime,
    MotionConfig,
    PendingResult,
    apply_in_flight,
    default_mobile_scenario,
    default_stations,
    rollout,
    rollout_ref,
    run_mobile_scenario,
    station_fleet,
)
from repro.netsim import ConstantRateLink, DownlinkQueue
from repro.runtime import (
    OUTCOME_DEGRADED,
    OUTCOME_OFFLOADED,
    EdgeLatencyModel,
    EdgeWorker,
    MultiEdgeDispatcher,
)
from repro.runtime.session import OffloadSession


def fitted_engine(ratio=0.5, policy=None, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (256, 8)).astype(np.float32)
    rewards = 2.0 * x[:, 0] + 0.3 * rng.normal(size=256)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=10, batch_size=64)
        ),
        ratio=ratio,
    )
    eng.fit(features=x, rewards=rewards)
    if policy is not None:
        eng = eng.with_policy(policy, ratio=ratio)
    return eng


# ------------------------------------------------------------------ motion


@pytest.mark.parametrize("model", ["waypoint", "random_walk"])
def test_motion_scan_matches_reference(model):
    cfg = MotionConfig(model=model, area=(800.0, 400.0), speed=9.0)
    scan = rollout(cfg, 6, 50, seed=11)
    ref = rollout_ref(cfg, 6, 50, seed=11)
    assert scan.shape == ref.shape == (50, 6, 2)
    np.testing.assert_allclose(scan, ref, atol=1e-3)
    # positions stay inside the area
    assert scan[..., 0].min() >= 0 and scan[..., 0].max() <= 800.0
    assert scan[..., 1].min() >= 0 and scan[..., 1].max() <= 400.0


@pytest.mark.parametrize("model", ["waypoint", "random_walk"])
def test_motion_trace_bit_identical_under_seed(model):
    cfg = MotionConfig(model=model)
    a = rollout(cfg, 4, 64, seed=7)
    b = rollout(cfg, 4, 64, seed=7)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, rollout(cfg, 4, 64, seed=8))


def test_motion_validation():
    with pytest.raises(KeyError):
        MotionConfig(model="teleport")
    with pytest.raises(ValueError):
        MotionConfig(dt=0.0)
    with pytest.raises(ValueError):
        rollout(MotionConfig(), 0, 10)


# ---------------------------------------------------------------- coverage


def test_path_loss_monotone_and_rate_factor():
    st = BaseStation("bs", x=0.0, y=0.0)
    d = np.array([[1.0, 0.0], [10.0, 0.0], [100.0, 0.0], [1000.0, 0.0]])
    rss = st.rss_dbm(d)
    assert np.all(np.diff(rss) < 0)  # farther is strictly weaker
    cov = CoverageMap([st], floor_dbm=-80.0, full_dbm=-50.0)
    assert cov.rate_factor(-40.0) == 1.0       # above full: clamp
    assert cov.rate_factor(-95.0) == cov.min_rate_factor
    mid = cov.rate_factor(-65.0)
    assert cov.min_rate_factor < mid < 1.0
    assert cov.rate_factor(-60.0) > mid        # stronger signal, more rate


def test_time_to_coverage_loss():
    cov = CoverageMap(
        [BaseStation("bs", x=0.0, y=0.0)], floor_dbm=-70.0, full_dbm=-50.0
    )
    # walk straight away from the station; signal drops below floor en route
    trace = np.stack(
        [np.linspace(1.0, 2000.0, 40), np.zeros(40)], axis=-1
    )
    ttl0 = cov.time_to_loss(trace, 0, dt=1.0)
    assert np.isfinite(ttl0) and ttl0 > 0
    # from a later step the loss is closer
    assert cov.time_to_loss(trace, 10, dt=1.0) < ttl0
    assert cov.time_to_loss(trace, len(trace) - 1, dt=1.0) == 0.0
    # near the station, coverage holds through the horizon
    home = np.ones((40, 2))
    assert cov.time_to_loss(home, 0, dt=1.0) == float("inf")


# ---------------------------------------------------------------- downlink


def test_downlink_queue_conservation():
    q = DownlinkQueue(ConstantRateLink(2.0), depth=4, frame_bits=1.0)
    rng = np.random.default_rng(0)
    t = 0.0
    for i in range(200):
        q.enqueue(t, i, size_bits=float(rng.uniform(0.2, 3.0)))
        t += float(rng.uniform(0.0, 0.6))
    q.poll(t + 1e9)
    s = q.stats()
    # every offered frame is exactly one of accepted (-> delivered once
    # fully drained) or dropped at the bounded queue
    assert s["dropped"] > 0
    assert s["enqueued"] + s["dropped"] == 200
    assert s["delivered"] == s["enqueued"]
    assert s["occupancy"] == 0


def test_edge_downlink_prices_return_transit():
    up = ConstantRateLink(10.0)
    down = ConstantRateLink(2.0)
    e = EdgeWorker(
        "e", capacity=4,
        latency=EdgeLatencyModel(base=1.0, per_inflight=0.0, jitter=0.0),
        link=up, queue_depth=8, frame_bits=1.0,
        downlink=down, result_bits=1.0, seed=0,
    )
    lat = e.try_admit(0.0, 0, 0.5)
    bd = e.last_breakdown
    assert lat is not None and bd is not None
    assert bd.downlink == pytest.approx(0.5)   # 1 bit over rate-2 downlink
    assert lat == pytest.approx(bd.total)
    assert bd.total == pytest.approx(
        bd.queue + bd.transmit + bd.service + bd.downlink
    )
    assert "downlink" in e.stats()


def test_edge_cancel_steps_frees_inflight():
    e = EdgeWorker(
        "e", capacity=4,
        latency=EdgeLatencyModel(base=5.0, per_inflight=0.0, jitter=0.0),
        seed=0,
    )
    for s in range(3):
        assert e.try_admit(0.0, s, 0.5) is not None
    assert e.inflight == 3
    assert e.cancel_steps({0, 2}) == 2
    assert e.inflight == 1 and e.cancelled == 2
    e.poll(100.0)
    assert len(e.completed) == 1  # cancelled jobs never complete


# -------------------------------------------------------- dispatch prefer/pin


def test_dispatch_prefer_and_pin():
    edges = [
        EdgeWorker(f"e{i}", capacity=1,
                   latency=EdgeLatencyModel(base=2.0, jitter=0.0), seed=i)
        for i in range(3)
    ]
    disp = MultiEdgeDispatcher(edges, "round_robin", on_saturation="degrade")
    res = disp.dispatch(0.0, 0, 0.5, prefer=2)
    assert res.edge == "e2"                      # preferred probes first
    res = disp.dispatch(0.0, 1, 0.5, prefer=2)   # e2 saturated -> falls back
    assert res.outcome == OUTCOME_OFFLOADED and res.edge != "e2"
    res = disp.dispatch(0.0, 2, 0.5, prefer=2, pin=True)  # e2 saturated
    assert res.outcome == OUTCOME_DEGRADED       # pinned: no fallback
    with pytest.raises(ValueError):
        disp.dispatch(0.0, 3, 0.5, pin=True)
    with pytest.raises(IndexError):
        disp.dispatch(0.0, 3, 0.5, prefer=9)


# ---------------------------------------------------------------- handover


def test_handover_hysteresis_and_dwell():
    cov = CoverageMap(default_stations(2, area=(1000.0, 600.0)))
    ctrl = HandoverController(cov, hysteresis_db=3.0, min_dwell=5.0)
    assert ctrl.update(0.0, np.array([260.0, 300.0])) is None  # attach, no event
    assert ctrl.serving == 0
    # just across the midline: inside the hysteresis band, no handover
    assert ctrl.update(1.0, np.array([510.0, 300.0])) is None
    # clearly in cell 1 but dwell not yet satisfied
    assert ctrl.update(2.0, np.array([700.0, 300.0])) is None
    ev = ctrl.update(6.0, np.array([700.0, 300.0]))
    assert ev is not None and (ev.source, ev.target) == (0, 1)
    assert ev.rss_target - ev.rss_source > 3.0
    assert ctrl.serving == 1 and len(ctrl.events) == 1


def test_apply_in_flight_semantics():
    def ledger():
        return [
            PendingResult(t_done=5.0, capture_step=3, step=30, edge=0),
            PendingResult(t_done=6.0, capture_step=4, step=41, edge=1),
        ]

    from repro.mobility import HandoverEvent

    ev = HandoverEvent(t=4.0, source=0, target=1, rss_source=-80, rss_target=-60)
    surv, n = apply_in_flight(ledger(), ev, "survive")
    assert n == 0 and len(surv) == 2

    edge = EdgeWorker("e", capacity=4,
                      latency=EdgeLatencyModel(base=9.0, jitter=0.0), seed=0)
    edge.try_admit(0.0, 30, 0.5)
    died, n = apply_in_flight(ledger(), ev, "die", edges=[edge, None])
    assert n == 1 and [p.edge for p in died] == [1]
    assert edge.cancelled == 1 and edge.inflight == 0

    stale, n = apply_in_flight(ledger(), ev, "stale", stale_penalty=4)
    assert n == 1 and len(stale) == 2
    assert stale[0].capture_step == 3 - 4 and stale[1].capture_step == 4

    with pytest.raises(KeyError):
        apply_in_flight(ledger(), ev, "teleport")


def _crossing_runtime(in_flight: str, engine):
    """One client walking straight through a 2-cell corridor with slow edge
    service, so results are in flight when the handover fires."""
    cov = CoverageMap(default_stations(2, area=(1000.0, 600.0)))
    fleet = station_fleet(
        cov, capacity=16,
        service=EdgeLatencyModel(base=6.0, per_inflight=0.0, jitter=0.0),
        transmit_time=0.05, downlink_time=0.02, seed=0,
    )
    rt = MobileRuntime(
        engine, cov, fleet,
        motion=MotionConfig(area=(1000.0, 600.0), speed=12.0),
        mode="handover", in_flight=in_flight,
        hysteresis_db=2.0, min_dwell=4.0, stale_penalty=5,
        stale_horizon=24, seed=0,
    )
    T = 70
    x = np.linspace(60.0, 940.0, T, dtype=np.float32)
    pos = np.stack([x, np.full(T, 300.0, np.float32)], axis=-1)[:, None, :]
    rng = np.random.default_rng(3)
    feats = rng.normal(0, 1, (T, 1, 8)).astype(np.float32)
    weak = np.full((T, 1), 0.3)
    strong = np.full((T, 1), 0.9)
    return rt.serve(feats, weak, strong, ratio=0.9, positions=pos)


@pytest.fixture(scope="module")
def crossing_engine():
    return fitted_engine(ratio=0.9)


def test_in_flight_semantics_on_seeded_trace(crossing_engine):
    traces = {
        m: _crossing_runtime(m, crossing_engine)
        for m in ("survive", "die", "stale")
    }
    for mode, tr in traces.items():
        assert tr.n_handovers() >= 1, mode
        # seeded trace is bit-identical on a re-run
        again = _crossing_runtime(mode, crossing_engine)
        assert [r.as_dict() for r in again.records] == [
            r.as_dict() for r in tr.records
        ]
        assert np.array_equal(again.positions, tr.positions)

    surv, die, stale = traces["survive"], traces["die"], traces["stale"]
    # die: the old edge's in-flight results were cancelled
    cancelled = sum(
        e.get("cancelled", 0) for e in die.dispatcher["edges"].values()
    )
    assert cancelled >= 1
    assert sum(
        e.get("cancelled", 0) for e in surv.dispatcher["edges"].values()
    ) == 0
    # die lost coverage survive kept
    assert die.telemetry[0].covered_frames < surv.telemetry[0].covered_frames
    # stale: same results arrive, but older
    assert stale.telemetry[0].mean_staleness > surv.telemetry[0].mean_staleness
    assert surv.mean_effective_accuracy() >= die.mean_effective_accuracy()


# ------------------------------------------------------------------ policy


def test_mobility_aware_policy_registered_and_discounts():
    assert "mobility_aware" in list_policies()
    cal = np.linspace(0.0, 1.0, 200)
    # no probe: plain quantile behaviour
    p = make_policy("mobility_aware", cal, 0.5)
    assert p.decide(0.9) and not p.decide(0.05)
    # ttl=0 kills the estimate entirely
    p0 = make_policy("mobility_aware", cal, 0.5, coverage_ttl=lambda: 0.0)
    assert not p0.decide(0.99)
    # ample ttl leaves it untouched
    pinf = make_policy(
        "mobility_aware", cal, 0.5, coverage_ttl=lambda: float("inf")
    )
    assert pinf.decide(0.99)
    with pytest.raises(ValueError):
        make_policy("mobility_aware", cal, 0.5, rtt_horizon=0.0)


def test_mobility_aware_budget_converges():
    rng = np.random.default_rng(0)
    cal = rng.uniform(0, 1, 500)
    ttls = iter(np.r_[np.full(200, 0.5), np.full(800, np.inf)])
    p = make_policy(
        "mobility_aware", cal, 0.3, coverage_ttl=lambda: next(ttls)
    )
    dec = [p.decide(float(e)) for e in rng.uniform(0, 1, 1000)]
    # suppressed early frames are paid back: realized ratio near target
    assert abs(np.mean(dec) - 0.3) < 0.05


def test_session_mobility_telemetry_gated():
    eng = fitted_engine(ratio=0.4)
    s = OffloadSession(eng, micro_batch=1)
    base_keys = set(s.telemetry.as_dict())
    s.record_handover()
    s.record_coverage(-70.0)
    s.record_coverage(-80.0)
    tel = s.telemetry
    assert set(tel.as_dict()) == base_keys  # byte-stable without the gate
    d = tel.as_dict(include_mobility=True)
    assert d["handovers"] == 1
    assert d["coverage_samples"] == 2
    assert d["mean_coverage_dbm"] == pytest.approx(-75.0)


# ---------------------------------------------------------------- headline


@pytest.fixture(scope="module")
def scenario():
    return default_mobile_scenario(n_clients=4, n_steps=120, seed=0)


def test_headline_handover_beats_static_pinning(scenario):
    handover = run_mobile_scenario(scenario, "handover")
    static = run_mobile_scenario(scenario, "static")
    # equal budget: identical policy decisions, so identical realized ratio
    assert handover.realized_ratio() == pytest.approx(
        static.realized_ratio(), abs=1e-12
    )
    # the headline: strictly better mean effective accuracy
    assert handover.mean_effective_accuracy() > static.mean_effective_accuracy()
    assert handover.n_handovers() >= 1 and static.n_handovers() == 0
    # same seeded world: both modes saw bit-identical client motion
    assert np.array_equal(handover.positions, static.positions)


def test_headline_trace_deterministic(scenario):
    a = run_mobile_scenario(scenario, "handover")
    b = run_mobile_scenario(scenario, "handover")
    assert np.array_equal(a.positions, b.positions)
    assert [r.as_dict() for r in a.records] == [r.as_dict() for r in b.records]
    assert [
        [e.as_dict() for e in evs] for evs in a.handovers
    ] == [[e.as_dict() for e in evs] for evs in b.handovers]


def test_mobility_obs_spans_and_series(scenario):
    import json

    from repro.obs import Obs

    obs = Obs()
    tr = run_mobile_scenario(scenario, "handover", obs=obs)
    assert tr.n_handovers() >= 1

    text = obs.metrics.to_prometheus()
    for series in (
        "repro_handovers_total",
        "repro_coverage_dbm",
        "repro_coverage_samples_total",
    ):
        assert series in text, series
    assert 'stream="client0"' in text

    evs = json.loads(json.dumps(obs.tracer.to_chrome()))["traceEvents"]
    offloads = {
        e["id"]: (e["ts"], None) for e in evs
        if e["name"] == "offload" and e["ph"] == "b"
    }
    assert offloads
    ends = {
        e["id"]: e["ts"] for e in evs
        if e["name"] == "offload" and e["ph"] == "e"
    }
    # the return leg traces as its own async child inside the offload group
    downlinks = [e for e in evs if e["name"] == "downlink" and e["ph"] == "b"]
    assert downlinks
    for d in downlinks:
        assert offloads[d["id"]][0] <= d["ts"] <= ends[d["id"]]

    # observability never perturbs the simulation
    bare = run_mobile_scenario(scenario, "handover")
    assert [r.as_dict() for r in bare.records] == [
        r.as_dict() for r in tr.records
    ]


def test_mobile_trace_summary_shape(scenario):
    tr = run_mobile_scenario(scenario, "handover")
    s = tr.summary()
    assert s["mode"] == "handover" and s["clients"] == 4
    assert 0.0 < s["mean_effective_accuracy"] < 1.0
    assert len(s["telemetry"]) == 4
    for tel in s["telemetry"]:
        assert "handovers" in tel and "mean_coverage_dbm" in tel
    # static pinning still observes (and reports) the decaying signal
    st = run_mobile_scenario(scenario, "static")
    assert all(t["coverage_samples"] > 0 for t in st.summary()["telemetry"])
