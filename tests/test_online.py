"""repro.online: closed-loop adaptation.

Covers the subsystem bottom-up — streaming quantiles, replay buffer,
last-layer solver, drift detector, measured network estimation, the
adaptive policy — then the two acceptance claims:

- **headline**: on the seeded mid-stream distribution shift, the adaptive
  arm's post-shift effective accuracy strictly exceeds the frozen arm's at
  (approximately) equal realized offload ratio;
- **measured RTT**: queue_aware driven by the measured NetworkEstimator is
  within 5% of the oracle-probe latency on the congested-fleet scenario.
"""
import numpy as np
import pytest

from repro.api import MLPRewardModel, OffloadEngine
from repro.api.policies import list_policies, make_policy
from repro.core import EstimatorConfig
from repro.core.reward import CdfTransform
from repro.online import (
    AdaptiveEngine,
    DriftConfig,
    DriftDetector,
    LastLayerSolver,
    NetworkEstimator,
    OnlineConfig,
    ReplayBuffer,
    StreamingQuantiles,
    apply_last_layer,
    clone_engine,
    default_shift_scenario,
    hidden_features,
    reward_to_logit,
    run_shift_scenario,
)
from repro.runtime import default_congested_fleet, simulate
from repro.runtime.edge import LatencyBreakdown


# ---------------------------------------------------------------------------
# StreamingQuantiles
# ---------------------------------------------------------------------------


def test_streaming_quantiles_track_normal_stream():
    rng = np.random.default_rng(0)
    xs = rng.normal(0.0, 1.0, 4000)
    t = StreamingQuantiles(65)
    t.update_batch(xs)
    for q in (0.1, 0.25, 0.5, 0.75, 0.9):
        assert abs(t.quantile(q) - np.quantile(xs, q)) < 0.1
    cal = t.calibration_scores()
    assert np.all(np.diff(cal) >= 0)  # marker heights stay sorted


def test_streaming_quantiles_adapt_after_warm_start():
    rng = np.random.default_rng(1)
    t = StreamingQuantiles(33).warm_start(rng.normal(0.0, 1.0, 200))
    before = t.quantile(0.5)
    assert abs(before) < 0.2
    t.update_batch(rng.normal(3.0, 0.5, 3000))
    assert abs(t.quantile(0.5) - 3.0) < 0.25  # markers followed the shift


def test_streaming_quantiles_state_roundtrip_continues_identically():
    rng = np.random.default_rng(2)
    t = StreamingQuantiles(17)
    t.update_batch(rng.normal(size=120))
    clone = StreamingQuantiles.from_state(t.state())
    more = rng.normal(size=150)
    t.update_batch(more)
    clone.update_batch(more)
    np.testing.assert_array_equal(t.heights, clone.heights)
    np.testing.assert_array_equal(t.positions, clone.positions)
    assert t.count == clone.count


def test_streaming_quantiles_cdf_transform_roundtrip():
    rng = np.random.default_rng(3)
    sample = rng.normal(0.0, 1.0, 800)
    base = CdfTransform(sample)
    t = StreamingQuantiles.from_transform(base, n_markers=65)
    back = t.to_transform()
    grid = np.linspace(-2.0, 2.0, 41)
    got, ref = back(grid), base(grid)
    assert np.all(got >= 0.0) and np.all(got <= 1.0)
    assert np.all(np.diff(got) >= -1e-12)
    np.testing.assert_allclose(got, ref, atol=0.05)


def test_streaming_quantiles_seed_buffer_until_enough():
    t = StreamingQuantiles(9)
    for v in range(5):
        t.update(float(v))
    assert not t.initialized  # still buffering exact samples
    for v in range(5, 12):
        t.update(float(v))
    assert t.initialized
    assert abs(t.quantile(0.0) - 0.0) < 1e-9
    assert abs(t.quantile(1.0) - 11.0) < 1e-9


# ---------------------------------------------------------------------------
# ReplayBuffer
# ---------------------------------------------------------------------------


def test_replay_buffer_wraps_oldest_first():
    buf = ReplayBuffer(capacity=4, feature_dim=2)
    for i in range(6):
        buf.append(np.full(2, float(i)), float(i))
    assert len(buf) == 4 and buf.count == 6
    x, y = buf.data()
    np.testing.assert_array_equal(y, [2.0, 3.0, 4.0, 5.0])
    np.testing.assert_array_equal(x[:, 0], [2.0, 3.0, 4.0, 5.0])


def test_replay_buffer_rejects_mismatched_blocks():
    buf = ReplayBuffer(capacity=4, feature_dim=3)
    with pytest.raises(ValueError):
        buf.append(np.zeros((2, 3)), np.zeros(3))
    with pytest.raises(ValueError):
        buf.append(np.zeros((2, 5)), np.zeros(2))


def test_replay_buffer_state_roundtrip_keeps_cursor():
    buf = ReplayBuffer(capacity=3, feature_dim=1)
    for i in range(5):
        buf.append(np.asarray([float(i)]), float(i))
    clone = ReplayBuffer.from_state(buf.state())
    buf.append(np.asarray([9.0]), 9.0)
    clone.append(np.asarray([9.0]), 9.0)
    np.testing.assert_array_equal(buf.data()[1], clone.data()[1])
    assert buf.cursor == clone.cursor and buf.count == clone.count


# ---------------------------------------------------------------------------
# last-layer solver
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted_engine():
    """A small fitted engine with the deployable fused-MLP shape."""
    rng = np.random.default_rng(0)
    x = rng.normal(0.0, 1.0, (256, 12)).astype(np.float32)
    r = 1.5 * x[:, 0] - 0.5 * x[:, 1] + 0.2 * rng.normal(size=256)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=8, batch_size=64, seed=0)
        ),
        ratio=0.3,
    )
    eng.fit(features=x, rewards=r)
    return eng, x


def test_last_layer_solver_recovers_known_head(fitted_engine):
    eng, x = fitted_engine
    model = clone_engine(eng).reward_model
    h = hidden_features(model, x)
    rng = np.random.default_rng(4)
    w_true = rng.normal(0.0, 0.5, h.shape[1])
    b_true = 0.3
    y = 1.0 / (1.0 + np.exp(-(h @ w_true + b_true)))
    solver = LastLayerSolver(hidden_dim=h.shape[1], l2=1e-6)
    solver.ingest(h, reward_to_logit(y))
    w, b = solver.solve()
    np.testing.assert_allclose(w, w_true, atol=1e-3)
    assert abs(b - b_true) < 1e-3
    apply_last_layer(model, w, b)
    np.testing.assert_allclose(model.predict(x), y, atol=1e-3)


def test_last_layer_solver_forgetting_prefers_recent_blocks(fitted_engine):
    eng, x = fitted_engine
    model = eng.reward_model
    h = hidden_features(model, x)
    rng = np.random.default_rng(5)
    w_old = rng.normal(0.0, 0.5, h.shape[1])
    w_new = rng.normal(0.0, 0.5, h.shape[1])
    y_old = reward_to_logit(1.0 / (1.0 + np.exp(-(h @ w_old))))
    y_new = reward_to_logit(1.0 / (1.0 + np.exp(-(h @ w_new))))
    solver = LastLayerSolver(hidden_dim=h.shape[1], l2=1e-6, forget=0.5)
    solver.ingest(h, y_old)
    for _ in range(8):  # old evidence decays by 0.5 per block
        solver.ingest(h, y_new)
    w, _ = solver.solve()
    assert np.linalg.norm(w - w_new) < np.linalg.norm(w - w_old)


def test_last_layer_solver_guards():
    solver = LastLayerSolver(hidden_dim=4)
    with pytest.raises(RuntimeError):
        solver.solve()
    with pytest.raises(ValueError):
        LastLayerSolver(hidden_dim=4, forget=0.0)


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


def _residual_stream(n, level, seed, noise=0.05):
    rng = np.random.default_rng(seed)
    return level + noise * rng.normal(size=n)


def test_drift_steady_selection_bias_does_not_fire():
    det = DriftDetector(DriftConfig())
    # offloaded-subset residuals: constant negative offset, small noise
    for r in _residual_stream(400, level=-0.12, seed=0):
        det.update(predicted=0.0, realized=r)
    assert not det.drifted
    assert det.statistic < 0.5 * det.config.h
    assert det.ratio_multiplier() == 1.0  # no widening in the noise band


def test_drift_detects_level_shift_quickly():
    det = DriftDetector(DriftConfig())
    for r in _residual_stream(200, level=-0.12, seed=1):
        det.update(predicted=0.0, realized=r)
    fired_after = None
    for i, r in enumerate(_residual_stream(50, level=0.30, seed=2)):
        det.update(predicted=0.0, realized=r)
        if det.drifted:
            fired_after = i + 1
            break
    assert fired_after is not None and fired_after <= 10
    assert det.ratio_multiplier() > 1.0  # evidence past the gate widens


def test_drift_reset_rebaselines_on_new_level():
    det = DriftDetector(DriftConfig())
    for r in _residual_stream(200, level=-0.12, seed=3):
        det.update(predicted=0.0, realized=r)
    for r in _residual_stream(30, level=0.30, seed=4):
        det.update(predicted=0.0, realized=r)
    assert det.drifted
    det.reset()
    assert det.events == 1 and det.statistic == 0.0
    # sustained NEW level is the new normal after the handled refit
    for r in _residual_stream(150, level=0.30, seed=5):
        det.update(predicted=0.0, realized=r)
    assert not det.drifted


def test_drift_rebaseline_keeps_cusum_evidence():
    det = DriftDetector(DriftConfig())
    for r in _residual_stream(100, level=0.0, seed=6):
        det.update(predicted=0.0, realized=r)
    det.cusum_pos = 3.0  # surviving evidence from persistent mispredictions
    det.rebaseline()
    assert det.statistic == 3.0  # kept — only the baseline mean re-anchors
    det.update(predicted=0.0, realized=0.5)
    assert det.mean == 0.5  # reseeded at the post-update residual level


def test_drift_periodic_reset_not_counted_as_event():
    det = DriftDetector(DriftConfig())
    for r in _residual_stream(50, level=0.0, seed=7):
        det.update(predicted=0.0, realized=r)
    det.reset(count_event=False)
    assert det.events == 0
    assert det.settle_until == det.n + det.config.min_obs


def test_drift_state_roundtrip_continues_identically():
    det = DriftDetector(DriftConfig())
    for r in _residual_stream(90, level=-0.1, seed=8):
        det.update(predicted=0.0, realized=r)
    det.reset()
    clone = DriftDetector.from_state(det.state(), det.config)
    for r in _residual_stream(120, level=0.2, seed=9):
        det.update(predicted=0.0, realized=r)
        clone.update(predicted=0.0, realized=r)
    assert det.statistic == clone.statistic
    assert det.mean == clone.mean and det.var == clone.var
    assert det.settle_until == clone.settle_until and det.n == clone.n


def test_drift_ratio_multiplier_gated_and_capped():
    det = DriftDetector(DriftConfig(h=8.0, widen=1.25))
    det.cusum_pos = 3.9  # below h/2
    assert det.ratio_multiplier() == 1.0
    det.cusum_pos = 6.0  # halfway through the ramp
    assert 1.0 < det.ratio_multiplier() < 1.25
    det.cusum_pos = 50.0
    assert det.ratio_multiplier() == 1.25


# ---------------------------------------------------------------------------
# NetworkEstimator
# ---------------------------------------------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_netstate_send_time_causality():
    clk = _Clock()
    net = NetworkEstimator(clock=clk)
    net.record(t_sent=0.0, rtt=10.0)
    clk.t = 5.0
    assert net.rtt() == 0.0  # result not back yet
    assert net.outstanding == 1
    clk.t = 10.0
    assert net.rtt() == 10.0
    assert net.outstanding == 0


def test_netstate_rfc6298_smoothing():
    clk = _Clock()
    net = NetworkEstimator(clock=clk)
    net.record(0.0, 10.0)
    clk.t = 10.0
    net.poll()
    assert net.srtt == 10.0 and net.rttvar == 5.0
    net.record(10.0, 20.0)
    clk.t = 30.0
    net.poll()
    # RFC 6298: rttvar then srtt, beta=0.25 / alpha=0.125
    assert net.rttvar == pytest.approx(0.75 * 5.0 + 0.25 * 10.0)
    assert net.srtt == pytest.approx(0.875 * 10.0 + 0.125 * 20.0)


def test_netstate_congestion_is_gated_inflight_census():
    clk = _Clock()
    net = NetworkEstimator(clock=clk, parallelism=2, pressure=1.0)
    net.record(0.0, 4.0, LatencyBreakdown(queue=0.0, transmit=2.0, service=2.0))
    clk.t = 4.0
    net.poll()
    assert net.transmit_ewma == 2.0
    assert net.congestion() == 0.0  # nothing in flight -> no backlog
    net.record(4.0, 50.0)
    assert net.congestion() == 0.0  # one of two uplinks still free
    net.record(4.0, 50.0)
    assert net.congestion() == pytest.approx(1.0 * (2 / 2) * 2.0)
    net.record(4.0, 50.0)
    assert net.congestion() == pytest.approx(1.0 * (3 / 2) * 2.0)


def test_netstate_bandwidth_and_state_probe():
    clk = _Clock()
    net = NetworkEstimator(clock=clk, parallelism=1)
    net.record(0.0, 4.0, LatencyBreakdown(0.0, 2.0, 2.0), bits=8.0)
    clk.t = 4.0
    assert net.bandwidth() == pytest.approx(8.0 / 2.0)
    assert net.state_probe() == (0, 0)  # empty queue, channel at its best
    # a fade: transmit times triple the best observed
    for i in range(12):
        net.record(4.0 + i, 8.0, LatencyBreakdown(0.0, 6.0, 2.0))
    clk.t = 30.0
    net.poll()
    assert net.state_probe()[1] == 1  # channel flagged bad


def test_netstate_state_roundtrip_with_pending_samples():
    clk = _Clock()
    net = NetworkEstimator(clock=clk, parallelism=2)
    for i in range(6):
        net.record(float(i), 3.0 + i, LatencyBreakdown(0.5, 1.0, 1.5 + i))
    clk.t = 5.0
    net.poll()
    assert net.outstanding > 0  # round-trip while samples are still in flight
    clone = NetworkEstimator.from_state(net.state(), parallelism=2, clock=clk)
    clk.t = 30.0
    assert net.telemetry() == clone.telemetry()


def test_netstate_ignores_bad_rtt_samples():
    net = NetworkEstimator()
    net.record(0.0, float("nan"))
    net.record(0.0, -1.0)
    assert net.delivered == 0 and net.rtt() == 0.0


# ---------------------------------------------------------------------------
# adaptive_threshold policy
# ---------------------------------------------------------------------------


def test_adaptive_threshold_registered():
    assert "adaptive_threshold" in list_policies()


def test_adaptive_threshold_tracks_ratio_through_shift():
    rng = np.random.default_rng(6)
    cal = rng.normal(0.0, 1.0, 128)  # fitted on a distribution that moves
    policy = make_policy("adaptive_threshold", cal, 0.3)
    decisions = [policy.decide(float(e)) for e in rng.normal(2.0, 1.0, 600)]
    assert abs(np.mean(decisions) - 0.3) < 0.05


# ---------------------------------------------------------------------------
# AdaptiveEngine: cadence, save/load, bit-identical replay
# ---------------------------------------------------------------------------

_FAST = OnlineConfig(
    buffer_capacity=64,
    min_observations=8,
    update_every=4,
    refit_every=24,
    refit_epochs=2,
    seed=0,
)


def _observation_stream(eng, x, seed, n):
    """Deterministic (features, estimate, reward) triples for feedback."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, x.shape[0], n)
    est = np.asarray(eng.score(features=x[idx]), np.float64)
    rewards = rng.uniform(-0.5, 1.5, n)
    return x[idx], est, rewards


def test_adaptive_engine_warmup_then_updates(fitted_engine):
    eng, x = fitted_engine
    ada = AdaptiveEngine(clone_engine(eng), _FAST)
    xs, est, rw = _observation_stream(eng, x, seed=7, n=6)
    ada.observe(xs, est, rw)
    report = ada.maybe_update()
    assert not report.changed  # still below min_observations
    xs, est, rw = _observation_stream(eng, x, seed=8, n=6)
    ada.observe(xs, est, rw)
    report = ada.maybe_update()
    assert report.incremental and report.recalibrated
    assert ada.incremental_updates == 1 and ada.observations == 12


def test_adaptive_engine_periodic_refit_fires(fitted_engine):
    eng, x = fitted_engine
    ada = AdaptiveEngine(clone_engine(eng), _FAST)
    for i in range(5):
        xs, est, rw = _observation_stream(eng, x, seed=20 + i, n=6)
        ada.observe(xs, est, rw)
        ada.maybe_update()
    assert ada.refits >= 1
    assert ada.drift_events == 0  # schedule-driven, not drift-forced


def test_adaptive_engine_save_load_roundtrip(fitted_engine, tmp_path):
    eng, x = fitted_engine
    ada = AdaptiveEngine(clone_engine(eng), _FAST)
    for i in range(3):
        xs, est, rw = _observation_stream(eng, x, seed=30 + i, n=6)
        ada.observe(xs, est, rw)
        ada.maybe_update()
    path = str(tmp_path / "adaptive.npz")
    ada.save(path)
    back = AdaptiveEngine.load(path)
    assert back.config == ada.config
    assert back.observations == ada.observations
    assert back.incremental_updates == ada.incremental_updates
    assert back.refits == ada.refits
    np.testing.assert_array_equal(back.buffer.data()[0], ada.buffer.data()[0])
    np.testing.assert_array_equal(
        back.score_tracker.heights, ada.score_tracker.heights
    )
    assert back.drift.statistic == ada.drift.statistic
    np.testing.assert_array_equal(
        np.asarray(back.engine.score(features=x)),
        np.asarray(ada.engine.score(features=x)),
    )


def test_adaptive_engine_load_rejects_plain_engine_artifact(
    fitted_engine, tmp_path
):
    eng, _ = fitted_engine
    path = str(tmp_path / "plain.npz")
    eng.save(path)
    with pytest.raises(ValueError):
        AdaptiveEngine.load(path)


def test_adaptive_engine_replay_from_checkpoint_is_bit_identical(
    fitted_engine, tmp_path
):
    eng, x = fitted_engine
    ada = AdaptiveEngine(clone_engine(eng), _FAST)
    for i in range(4):
        xs, est, rw = _observation_stream(eng, x, seed=40 + i, n=5)
        ada.observe(xs, est, rw)
        ada.maybe_update()
    path = str(tmp_path / "mid.npz")
    ada.save(path)
    back = AdaptiveEngine.load(path)
    # identical continuation (crosses the refit_every boundary in both arms)
    tail = [_observation_stream(eng, x, seed=50 + i, n=5) for i in range(6)]
    for xs, est, rw in tail:
        ada.observe(xs, est, rw)
        ada.maybe_update()
    for xs, est, rw in tail:
        back.observe(xs, est, rw)
        back.maybe_update()
    assert back.refits == ada.refits and back.refits >= 1
    assert back.incremental_updates == ada.incremental_updates
    a_params = ada.engine.reward_model.estimator.params
    b_params = back.engine.reward_model.estimator.params
    for layer in a_params:
        for leaf in a_params[layer]:
            np.testing.assert_array_equal(
                np.asarray(a_params[layer][leaf]),
                np.asarray(b_params[layer][leaf]),
            )
    np.testing.assert_array_equal(
        np.asarray(ada.engine.score(features=x)),
        np.asarray(back.engine.score(features=x)),
    )
    assert ada.drift.statistic == back.drift.statistic


# ---------------------------------------------------------------------------
# acceptance: measured RTT within 5% of the oracle probes
# ---------------------------------------------------------------------------


def test_measured_netstate_matches_oracle_latency():
    rng = np.random.default_rng(0)
    x = rng.normal(0.0, 1.0, (512, 32)).astype(np.float32)
    r = 2.0 * x[:, 0] + 0.3 * rng.normal(size=512)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=10, batch_size=64)
        ),
        ratio=0.3,
    )
    eng.fit(features=x, rewards=r)
    qa = eng.with_policy("queue_aware")
    results = {}
    for label, net in (("oracle", None), ("measured", NetworkEstimator())):
        trace = simulate(
            qa,
            features=x,
            edges=default_congested_fleet(3, seed=0),
            ratio=0.3,
            micro_batch=1,
            seed=0,
            net_state=net,
        )
        off = [rec.latency for rec in trace.records if rec.outcome == "offloaded"]
        results[label] = (
            float(np.mean(off)),
            float(np.mean([(rec.latency or 0.0) for rec in trace.records])),
            len(off),
        )
    oracle, measured = results["oracle"], results["measured"]
    # the measured probes must not cost more than 5% latency vs the oracle
    assert measured[0] <= oracle[0] * 1.05
    assert measured[1] <= oracle[1] * 1.05
    # and the budget controller holds the same offload volume
    assert abs(measured[2] - oracle[2]) <= 0.1 * oracle[2]


# ---------------------------------------------------------------------------
# acceptance: the distribution-shift headline
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shift_runs():
    scenario = default_shift_scenario()
    frozen = run_shift_scenario(scenario)
    adaptive = run_shift_scenario(scenario, adaptive=True)
    return frozen, adaptive


def test_headline_adaptive_recovers_post_shift_accuracy(shift_runs):
    frozen, adaptive = shift_runs
    assert adaptive.mean_effective(post_shift=True) > frozen.mean_effective(
        post_shift=True
    )
    # equal-budget comparison: realized ratios must agree closely
    assert abs(adaptive.realized_ratio() - frozen.realized_ratio()) <= 0.05


def test_headline_adaptive_arm_actually_adapted(shift_runs):
    _, adaptive = shift_runs
    up = adaptive.updates
    assert up["observations"] > 0
    assert up["incremental_updates"] > 0
    assert up["refits"] > 0
    handle = adaptive.adaptive
    assert handle is not None and handle.observations == up["observations"]


def test_headline_frames_served_strong_only_when_offloaded(shift_runs):
    frozen, adaptive = shift_runs
    for run in (frozen, adaptive):
        assert not np.any(run.served_strong & ~run.offload)
    assert frozen.updates == {}  # the frozen arm never adapts


def test_headline_sessions_report_online_telemetry(shift_runs):
    _, adaptive = shift_runs
    for tele in adaptive.telemetry:
        assert tele["rtt_samples"] > 0
        assert tele["mean_rtt"] > 0.0
        assert tele["online_updates"] > 0
