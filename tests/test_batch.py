"""Batched detection data plane: padded containers, the device matcher vs
per-image ``match_detections``, batched features, and consumers."""
import numpy as np
import pytest

from repro.core.features import extract_features, extract_features_batch
from repro.core.reward import (
    RewardOracle,
    match_pairs,
    match_pairs_batched,
    ori,
    ori_batch,
)
from repro.detection.batch import (
    DetectionsBatch,
    GroundTruthBatch,
    match_batch,
    to_image_evals,
)
from repro.detection.map_engine import (
    Detections,
    GroundTruth,
    match_detections,
)

THRESHOLDS = (0.5, 0.75)


def empty_dets() -> Detections:
    return Detections(np.zeros((0, 4)), np.zeros(0), np.zeros(0, int))


def empty_gt() -> GroundTruth:
    return GroundTruth(np.zeros((0, 4)), np.zeros(0, int))


# ------------------------------------------------------------- containers

def test_batch_round_trip(noisy_pair):
    gts, weak, _ = noisy_pair
    db = DetectionsBatch.from_list(weak)
    gb = GroundTruthBatch.from_list(gts)
    assert len(db) == len(weak) and len(gb) == len(gts)
    assert db.boxes.dtype == np.float32 and db.classes.dtype == np.int32
    assert np.array_equal(db.counts, [len(d) for d in weak])
    for i in (0, len(weak) - 1):
        d = db[i]
        np.testing.assert_allclose(d.boxes, weak[i].boxes.astype(np.float32))
        np.testing.assert_allclose(d.scores, weak[i].scores.astype(np.float32))
        assert np.array_equal(d.classes, weak[i].classes)
    rt = gb.to_list()
    for g0, g1 in zip(gts, rt):
        np.testing.assert_allclose(g1.boxes, g0.boxes.astype(np.float32))
        assert np.array_equal(g1.classes, g0.classes)


def test_batch_round_trip_with_empty_images():
    dets = [empty_dets(), Detections([[0.0, 0, 5, 5]], [0.7], [2])]
    db = DetectionsBatch.from_list(dets)
    assert db.counts.tolist() == [0, 1]
    assert len(db[0]) == 0 and len(db[1]) == 1
    assert db.max_boxes >= 8  # padded to the bucket floor


def test_from_list_empty_is_explicit_zero_length_batch():
    """``from_list([])`` is a well-defined zero-length batch for BOTH
    containers — not an incidental numpy stack error."""
    db = DetectionsBatch.from_list([])
    gb = GroundTruthBatch.from_list([])
    for batch in (db, gb):
        assert len(batch) == 0
        assert batch.boxes.shape == (0, batch.max_boxes, 4)
        assert batch.counts.shape == (0,)
        assert batch.to_list() == []
    assert db.scores.shape == (0, db.max_boxes)
    # and the zero-length batch flows through the matcher + eval conversion
    res = match_batch(db, gb, THRESHOLDS)
    assert res.tp.shape == (0, len(THRESHOLDS), db.max_boxes)
    assert to_image_evals(db, gb, res) == []


def test_from_list_empty_respects_explicit_max_boxes():
    db = DetectionsBatch.from_list([], max_boxes=32)
    gb = GroundTruthBatch.from_list([], max_boxes=32)
    assert db.max_boxes == 32 and gb.max_boxes == 32


def test_from_list_overflow_raises():
    d = Detections(np.zeros((5, 4)), np.zeros(5), np.zeros(5, int))
    with pytest.raises(ValueError):
        DetectionsBatch.from_list([d], max_boxes=4)
    g = GroundTruth(np.zeros((5, 4)), np.zeros(5, int))
    with pytest.raises(ValueError):
        GroundTruthBatch.from_list([g], max_boxes=4)


def test_match_batch_size_mismatch_raises():
    db = DetectionsBatch.from_list([empty_dets()])
    gb = GroundTruthBatch.from_list([empty_gt(), empty_gt()])
    with pytest.raises(ValueError):
        match_batch(db, gb)


# ---------------------------------------------------------------- matcher

def assert_matches_reference(dets, gts, thresholds=THRESHOLDS):
    db = DetectionsBatch.from_list(dets)
    gb = GroundTruthBatch.from_list(gts)
    res = match_batch(db, gb, thresholds)
    assert res.tp.shape == (len(dets), len(thresholds), db.max_boxes)
    # padded slots are never tp
    assert not res.tp[~np.broadcast_to(db.mask[:, None, :], res.tp.shape)].any()
    evs = to_image_evals(db, gb, res)
    for ev, d, g in zip(evs, dets, gts):
        ref = match_detections(d, g, thresholds)
        assert ev.gt_counts == ref.gt_counts
        assert set(ev.per_class) == set(ref.per_class)
        for c in ref.per_class:
            s_ref, tp_ref = ref.per_class[c]
            s_got, tp_got = ev.per_class[c]
            assert np.array_equal(tp_got, tp_ref)  # bit-for-bit tp flags
            assert np.array_equal(ev.matched_gt[c], ref.matched_gt[c])
            np.testing.assert_allclose(s_got, s_ref, rtol=1e-6)


def test_match_batch_equals_match_detections(noisy_pair):
    gts, weak, strong = noisy_pair
    assert_matches_reference(weak, gts)
    assert_matches_reference(strong, gts)


def test_match_batch_empty_rows():
    gts = [empty_gt(), GroundTruth([[0.0, 0, 10, 10]], [1]), empty_gt()]
    dets = [
        Detections([[0.0, 0, 10, 10]], [0.9], [1]),  # dets, no GT
        empty_dets(),                                # GT, no dets
        empty_dets(),                                # nothing at all
    ]
    assert_matches_reference(dets, gts)


def test_match_batch_greedy_one_gt_per_detection():
    """Two detections over one GT: only the higher-scored one matches."""
    gt = GroundTruth([[0.0, 0, 10, 10]], [0])
    det = Detections(
        [[0.0, 0, 10, 10], [0.5, 0.5, 10.5, 10.5]], [0.6, 0.9], [0, 0]
    )
    assert_matches_reference([det], [gt], thresholds=(0.5,))
    db = DetectionsBatch.from_list([det])
    gb = GroundTruthBatch.from_list([gt])
    res = match_batch(db, gb, (0.5,))
    assert res.tp[0, 0, :2].tolist() == [False, True]  # score order wins
    assert res.match_gt[0, 0, 1] == 0 and res.match_gt[0, 0, 0] == -1


def test_match_batch_respects_classes():
    gt = GroundTruth([[0.0, 0, 10, 10]], [3])
    det = Detections([[0.0, 0, 10, 10]], [0.9], [2])  # perfect box, wrong class
    res = match_batch(
        DetectionsBatch.from_list([det]), GroundTruthBatch.from_list([gt]), (0.5,)
    )
    assert not res.tp.any()


# ----------------------------------------------------------- reward layer

def test_match_pairs_batched_equals_match_pairs(noisy_pair):
    gts, weak, strong = noisy_pair
    ref = match_pairs(weak, strong, gts)
    got = match_pairs_batched(weak, strong, gts)
    # identical tp flags -> identical ORI / ORIC rewards
    np.testing.assert_allclose(ori_batch(got), ori_batch(ref), atol=1e-12)
    rng = np.random.default_rng(0)
    pool = [im.weak for im in ref[:30]]
    oracle = RewardOracle.from_pool(pool, 20, rng)
    np.testing.assert_allclose(
        oracle.oric_batch(got), oracle.oric_batch(ref), atol=1e-12
    )


def test_match_pairs_batched_accepts_batches(noisy_pair):
    gts, weak, strong = noisy_pair
    wb = DetectionsBatch.from_list(weak)
    sb = DetectionsBatch.from_list(strong)
    gb = GroundTruthBatch.from_list(gts)
    a = match_pairs_batched(wb, sb, gb)
    b = match_pairs_batched(weak, strong, gts)
    np.testing.assert_allclose(ori_batch(a), ori_batch(b), atol=1e-12)


def test_ori_batch_equals_scalar_ori(noisy_pair):
    gts, weak, strong = noisy_pair
    imgs = match_pairs(weak[:25], strong[:25], gts[:25])
    np.testing.assert_allclose(
        ori_batch(imgs), np.array([ori(im) for im in imgs]), atol=1e-12
    )


# --------------------------------------------------------------- features

def test_features_batched_equals_per_image(noisy_pair):
    _, weak, _ = noisy_pair
    num_classes = 8
    ref = np.stack([extract_features(d, num_classes, 25, 64.0) for d in weak])
    got = extract_features_batch(weak, num_classes, 25, 64.0)
    assert got.shape == ref.shape and got.dtype == np.float32
    np.testing.assert_allclose(got, ref, atol=2e-5, rtol=1e-5)
    # DetectionsBatch input is the same path
    got2 = extract_features_batch(
        DetectionsBatch.from_list(weak), num_classes, 25, 64.0
    )
    np.testing.assert_array_equal(got, got2)


def test_features_batched_empty_and_overflow():
    num_classes = 4
    many = Detections(
        np.concatenate([np.zeros((30, 2)), np.ones((30, 2))], 1) * 10.0
        + np.arange(30)[:, None],
        np.linspace(0.9, 0.1, 30),
        np.arange(30) % num_classes,
    )
    dets = [empty_dets(), many]
    ref = np.stack([extract_features(d, num_classes, 25, 64.0) for d in dets])
    got = extract_features_batch(dets, num_classes, 25, 64.0)
    np.testing.assert_allclose(got, ref, atol=2e-5, rtol=1e-5)
    assert np.all(got[0] == 0.0)  # empty image -> all-zero feature row


# -------------------------------------------------------------- consumers

def test_detection_box_features_accepts_batch(noisy_pair):
    from repro.api import DetectionBoxFeatures

    _, weak, _ = noisy_pair
    fx = DetectionBoxFeatures(num_classes=8, image_size=64.0)
    np.testing.assert_array_equal(
        fx(DetectionsBatch.from_list(weak)), fx(weak)
    )


def test_session_scores_prebatched_features_without_item_conversion():
    from repro.api import MLPRewardModel, OffloadEngine
    from repro.core import EstimatorConfig
    from repro.runtime import OffloadSession

    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (64, 16)).astype(np.float32)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(8,), epochs=1)),
        ratio=0.3,
    )
    eng.fit(features=x, rewards=rng.normal(0, 1, 64))
    session = OffloadSession(eng, micro_batch=8)
    # partial flushes keep a trailing sub-micro-batch pending as one block
    out = session.submit_batch(features=x[:21], flush=False)
    assert [d.step for d in out] == list(range(16))
    assert session.telemetry.pending == 5
    out2 = session.flush()
    assert [d.step for d in out2] == [16, 17, 18, 19, 20]
    # decisions equal the engine's one-shot mask regardless of batching
    mask = eng.decide(features=x[:21]).offload
    np.testing.assert_array_equal(
        np.array([d.offload for d in out + out2]), mask
    )


def test_topk_session_invariant_to_micro_batch():
    """Streaming decisions under the topk policy must not depend on
    buffering: per-batch top-k would offload nothing at micro_batch=1, so
    sessions keep decide()'s quantile-threshold semantics."""
    from repro.api import MLPRewardModel, OffloadEngine
    from repro.core import EstimatorConfig
    from repro.runtime import OffloadSession

    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (64, 16)).astype(np.float32)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(8,), epochs=2)),
        policy="topk",
        ratio=0.25,
    )
    eng.fit(features=x, rewards=rng.normal(0, 1, 64))
    masks = []
    for mb in (1, 7, 64):
        session = OffloadSession(eng, micro_batch=mb)
        masks.append([d.offload for d in session.submit_batch(features=x)])
    assert masks[0] == masks[1] == masks[2]
    assert any(masks[0])  # micro_batch=1 must still offload


def test_iou_matrix_batch_matches_per_image(rng):
    import jax.numpy as jnp

    from repro.detection.boxes import box_iou_np
    from repro.kernels.iou_matrix import iou_matrix_batch

    B, K, M = 5, 9, 6
    a = rng.uniform(0, 50, (B, K, 2))
    a = np.concatenate([a, a + rng.uniform(1, 20, (B, K, 2))], -1).astype(np.float32)
    b = rng.uniform(0, 50, (B, M, 2))
    b = np.concatenate([b, b + rng.uniform(1, 20, (B, M, 2))], -1).astype(np.float32)
    got = np.asarray(iou_matrix_batch(jnp.asarray(a), jnp.asarray(b)))
    assert got.shape == (B, K, M)
    for i in range(B):
        np.testing.assert_allclose(got[i], box_iou_np(a[i], b[i]), atol=1e-6)
