"""Sharding rules + mesh context (1-device CPU view; the 512-device mesh
is exercised by the dryrun CLI, not here).  The fleet-plane bit-identity
property runs in a subprocess with a forced multi-device host view — the
in-process suite deliberately keeps the real 1-device view."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch.meshctx import bind_mesh, constrain
from repro.launch.mesh import FLEET_AXIS, make_fleet_mesh, make_production_mesh
from repro.launch.sharding import (
    CACHE_RULES,
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.models.lm import abstract_params, init_cache, reduced


@pytest.fixture
def mesh1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


MAPPING = {"batch": "data", "model": "model", "expert": "model"}


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    assert y is x


def test_constrain_applies_under_mesh(mesh1):
    x = jnp.ones((4, 4))
    with bind_mesh(mesh1, MAPPING):
        y = jax.jit(lambda a: constrain(a, "batch", None))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_moe_16b", "rwkv6_1b6", "zamba2_2b7", "whisper_base"])
def test_param_rules_cover_big_leaves(arch, mesh1):
    """Every >1M-element parameter must hit a sharding rule (not end up
    replicated by fallthrough) — guards against rule-table rot."""
    cfg = get_config(arch)
    params = abstract_params(cfg)
    sh = param_shardings(params, mesh1, MAPPING)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(sh)
    uncovered = []
    for (path, leaf), s in zip(flat_p, flat_s):
        n = int(np.prod(leaf.shape))
        if n >= 1_000_000 and s.spec == P():
            uncovered.append("/".join(str(p) for p in path))
    assert not uncovered, uncovered


def test_divisibility_fallback(mesh1):
    """A dim not divisible by the mesh axis must replicate, not fail."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    # pretend a model axis of size 16 via resolver arithmetic on mesh1 (size
    # 1 divides everything), so instead test _resolve directly:
    from repro.launch.sharding import _resolve

    class FakeMesh:
        shape = {"data": 1, "model": 16}

    spec = _resolve((None, "model"), MAPPING, (10, 24), FakeMesh())
    assert spec == P(None, None)  # 24 % 16 != 0 -> replicated
    spec2 = _resolve((None, "model"), MAPPING, (10, 32), FakeMesh())
    assert spec2 == P(None, "model")


@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_v2_lite_16b", "rwkv6_1b6", "zamba2_2b7", "whisper_base"])
def test_cache_rules_cover_all_fields(arch, mesh1):
    cfg = reduced(get_config(arch))
    cache = init_cache(cfg, batch=2, capacity=16, abstract=True)
    for k in cache:
        assert k in CACHE_RULES, k
    sh = cache_shardings(cache, mesh1, MAPPING)
    assert set(sh) == set(cache)


def test_batch_shardings_positions_3d(mesh1):
    import jax as _jax

    specs = {
        "tokens": _jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "positions_3d": _jax.ShapeDtypeStruct((3, 8, 16), jnp.int32),
    }
    sh = batch_shardings(specs, mesh1, MAPPING)
    assert sh["tokens"].spec[0] == "data"
    assert sh["positions_3d"].spec == P(None, "data", None)


# ------------------------------------------------------------ fleet meshes


def test_make_fleet_mesh_defaults_and_clamps():
    """On this 1-device view: None takes the device, oversubscription
    clamps instead of failing (the CI degrade path)."""
    mesh = make_fleet_mesh()
    assert mesh.axis_names == (FLEET_AXIS,)
    assert mesh.shape[FLEET_AXIS] == len(jax.devices())
    clamped = make_fleet_mesh(64)
    assert clamped.shape[FLEET_AXIS] == min(64, len(jax.devices()))
    with pytest.raises(ValueError):
        make_fleet_mesh(0)


def test_make_production_mesh_degrades_to_available_devices():
    """Asking for the 256/512-chip mesh on a small host must fold the
    available devices into the data axis, keeping every axis name."""
    mesh = make_production_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == len(jax.devices())
    assert mesh.shape["model"] == 1
    multi = make_production_mesh(multi_pod=True)
    assert multi.axis_names == ("pod", "data", "model")
    assert multi.shape["pod"] == 1 and multi.shape["model"] == 1


def test_fleet_plane_single_device_falls_through():
    """On a 1-shard mesh the plane must return the exact single-device
    results (it routes straight to the unsharded implementations)."""
    from repro.core.features import extract_features_batch
    from repro.detection.batch import (
        DetectionsBatch,
        GroundTruthBatch,
        match_batch,
    )
    from repro.detection.map_engine import Detections, GroundTruth
    from repro.fleet import FleetPlane

    plane = FleetPlane(make_fleet_mesh(1))
    assert plane.n_devices == 1
    r = np.random.default_rng(0)
    dets = [
        Detections(
            np.abs(r.normal(10, 3, (4, 4))), r.uniform(0.1, 1, 4),
            r.integers(0, 5, 4),
        )
        for _ in range(9)
    ]
    gts = [
        GroundTruth(np.abs(r.normal(10, 3, (3, 4))), r.integers(0, 5, 3))
        for _ in range(9)
    ]
    db, gb = DetectionsBatch.from_list(dets), GroundTruthBatch.from_list(gts)
    ref = match_batch(db, gb)
    out = plane.match(db, gb)
    np.testing.assert_array_equal(ref.tp, out.tp)
    np.testing.assert_array_equal(ref.match_gt, out.match_gt)
    np.testing.assert_array_equal(
        extract_features_batch(db, 5, 10, 64.0),
        plane.extract_features(db, 5, 10, 64.0),
    )


def test_fleet_plane_rejects_multi_axis_mesh(mesh1):
    from repro.fleet import FleetPlane

    with pytest.raises(ValueError):
        FleetPlane(mesh1)  # ("data", "model") is not a 1-D serving mesh


_FLEET_PLANE_PROPERTY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax

    assert len(jax.devices()) == 4, jax.devices()

    from repro.api import MLPRewardModel, OffloadEngine
    from repro.core import EstimatorConfig
    from repro.core.features import extract_features_batch
    from repro.detection.batch import (
        DetectionsBatch, GroundTruthBatch, match_batch,
    )
    from repro.detection.map_engine import Detections, GroundTruth
    from repro.fleet import FleetPlane
    from repro.launch.mesh import make_fleet_mesh

    def synth(n_images, seed, num_classes=8, size=64.0):
        r = np.random.default_rng(seed)
        dets, gts = [], []
        for _ in range(n_images):
            m = int(r.integers(1, 6))
            b = r.uniform(0, size - 25, (m, 2))
            wh = r.uniform(5, 20, (m, 2))
            gts.append(GroundTruth(
                np.concatenate([b, b + wh], 1), r.integers(0, num_classes, m)
            ))
            k = int(r.integers(0, 12))
            b = r.uniform(0, size - 25, (k, 2))
            wh = r.uniform(5, 20, (k, 2))
            dets.append(Detections(
                np.concatenate([b, b + wh], 1), r.uniform(0.1, 1.0, k),
                r.integers(0, num_classes, k),
            ))
        return dets, gts

    mesh = make_fleet_mesh()
    assert mesh.shape["shard"] == 4
    plane = FleetPlane(mesh)

    # B=13: global grid_b == 1; B=150: grid_b >= 2 — the two XLA:CPU
    # compilation regimes of the batched IoU kernel.  Both are ragged
    # against the 4-way shard split (13 = 4+4+4+1, 150 = 38*3+36).
    for B in (13, 150):
        dets, gts = synth(B, seed=B)
        db = DetectionsBatch.from_list(dets)
        gb = GroundTruthBatch.from_list(gts)
        ref = match_batch(db, gb, (0.5, 0.75))
        out = plane.match(db, gb, (0.5, 0.75))
        assert np.array_equal(ref.tp, out.tp), B
        assert np.array_equal(ref.match_gt, out.match_gt), B
        fref = extract_features_batch(db, 8, 25, 64.0)
        fout = plane.extract_features(db, 8, 25, 64.0)
        assert np.array_equal(fref, fout), B

    # fused estimator scoring, ragged (B=250 over 4 shards) and aligned
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (256, 32)).astype(np.float32)
    eng = OffloadEngine(reward_model=MLPRewardModel(
        config=EstimatorConfig(hidden=(16,), epochs=2, batch_size=64)
    ))
    eng.fit(features=x, rewards=rng.normal(0, 1, 256))
    assert eng.reward_model.fused
    for B in (7, 250, 256):
        ref = np.asarray(eng.score(features=x[:B]))
        out = plane.score(eng, x[:B])
        assert np.array_equal(ref, out), B

    # fused boxes→estimates pipeline, sharded: score_detections must be
    # bit-identical to the single-device fused dispatch AND the composed
    # features→score route, over ragged shard splits
    from repro.api import DetectionBoxFeatures

    dets_cal, _ = synth(120, seed=3)
    dcal = DetectionsBatch.from_list(dets_cal)
    fx = DetectionBoxFeatures(num_classes=8, top_k=25, image_size=64.0)
    eng2 = OffloadEngine(
        feature_extractor=fx,
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=2, batch_size=64)
        ),
    )
    eng2.fit(
        features=extract_features_batch(dcal, 8, 25, 64.0),
        rewards=np.random.default_rng(1).uniform(0, 1, 120),
    )
    assert eng2.reward_model.fused
    # 7/13 land in small shard blocks, 150/250 in the large-block gemm
    # regime (250 is also ragged: 63*3+61) — the gather-before-head split
    # must hold bit-identity in all of them
    for B in (7, 13, 150, 250):
        dets, _ = synth(B, seed=100 + B)
        db = DetectionsBatch.from_list(dets)
        ref = np.asarray(eng2.score_device(db))
        assert np.array_equal(ref, eng2.score(db)), B
        out = plane.score_detections(eng2, db)
        assert np.array_equal(ref, out), B

    print("FLEET-PLANE-BITIDENT-OK")
    """
)


def test_fleet_plane_sharded_is_bit_identical_multi_device():
    """The PR's core property: scoring, matching, and feature extraction
    through the 4-device sharded plane are bit-for-bit equal to the
    single-device results, including ragged last-shard padding, in both
    IoU-grid compilation regimes.  Runs in a subprocess because the forced
    multi-device host view must be set before jax initializes."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the script sets its own
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", _FLEET_PLANE_PROPERTY],
        capture_output=True, text=True, timeout=540, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "FLEET-PLANE-BITIDENT-OK" in proc.stdout
