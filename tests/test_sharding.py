"""Sharding rules + mesh context (1-device CPU view; the 512-device mesh
is exercised by the dryrun CLI, not here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch.meshctx import bind_mesh, constrain
from repro.launch.sharding import (
    CACHE_RULES,
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.models.lm import abstract_params, init_cache, reduced


@pytest.fixture
def mesh1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


MAPPING = {"batch": "data", "model": "model", "expert": "model"}


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    assert y is x


def test_constrain_applies_under_mesh(mesh1):
    x = jnp.ones((4, 4))
    with bind_mesh(mesh1, MAPPING):
        y = jax.jit(lambda a: constrain(a, "batch", None))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_moe_16b", "rwkv6_1b6", "zamba2_2b7", "whisper_base"])
def test_param_rules_cover_big_leaves(arch, mesh1):
    """Every >1M-element parameter must hit a sharding rule (not end up
    replicated by fallthrough) — guards against rule-table rot."""
    cfg = get_config(arch)
    params = abstract_params(cfg)
    sh = param_shardings(params, mesh1, MAPPING)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(sh)
    uncovered = []
    for (path, leaf), s in zip(flat_p, flat_s):
        n = int(np.prod(leaf.shape))
        if n >= 1_000_000 and s.spec == P():
            uncovered.append("/".join(str(p) for p in path))
    assert not uncovered, uncovered


def test_divisibility_fallback(mesh1):
    """A dim not divisible by the mesh axis must replicate, not fail."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    # pretend a model axis of size 16 via resolver arithmetic on mesh1 (size
    # 1 divides everything), so instead test _resolve directly:
    from repro.launch.sharding import _resolve

    class FakeMesh:
        shape = {"data": 1, "model": 16}

    spec = _resolve((None, "model"), MAPPING, (10, 24), FakeMesh())
    assert spec == P(None, None)  # 24 % 16 != 0 -> replicated
    spec2 = _resolve((None, "model"), MAPPING, (10, 32), FakeMesh())
    assert spec2 == P(None, "model")


@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_v2_lite_16b", "rwkv6_1b6", "zamba2_2b7", "whisper_base"])
def test_cache_rules_cover_all_fields(arch, mesh1):
    cfg = reduced(get_config(arch))
    cache = init_cache(cfg, batch=2, capacity=16, abstract=True)
    for k in cache:
        assert k in CACHE_RULES, k
    sh = cache_shardings(cache, mesh1, MAPPING)
    assert set(sh) == set(cache)


def test_batch_shardings_positions_3d(mesh1):
    import jax as _jax

    specs = {
        "tokens": _jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "positions_3d": _jax.ShapeDtypeStruct((3, 8, 16), jnp.int32),
    }
    sh = batch_shardings(specs, mesh1, MAPPING)
    assert sh["tokens"].spec[0] == "data"
    assert sh["positions_3d"].spec == P(None, "data", None)
