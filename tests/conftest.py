"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real
1-device CPU view; only dryrun.py forces 512 host devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_noisy_pair(rng, n_images=60, num_classes=8, size=64):
    """Synthetic GT + weak/strong detection lists with realistic error mix."""
    from repro.detection.map_engine import Detections, GroundTruth

    def rand_dets(k):
        b = rng.uniform(0, size - 20, (k, 2))
        wh = rng.uniform(5, 20, (k, 2))
        return Detections(
            np.concatenate([b, b + wh], 1),
            rng.uniform(0.1, 1, k),
            rng.integers(0, num_classes, k),
        )

    def noisy(gt, drop, jitter, hall_p):
        keep = rng.uniform(size=len(gt)) > drop
        boxes = gt.boxes[keep] + rng.normal(0, jitter, (int(keep.sum()), 4))
        cls = gt.classes[keep].copy()
        flip = rng.uniform(size=len(cls)) < 0.1
        cls[flip] = rng.integers(0, num_classes, int(flip.sum()))
        scores = rng.uniform(0.4, 1.0, len(cls))
        extra = rand_dets(int(rng.integers(0, 3))) if rng.uniform() < hall_p else rand_dets(0)
        return Detections(
            np.concatenate([boxes, extra.boxes]),
            np.concatenate([scores, extra.scores * 0.5]),
            np.concatenate([cls, extra.classes]),
        )

    gts, weak, strong = [], [], []
    for _ in range(n_images):
        m = int(rng.integers(1, 5))
        b = rng.uniform(0, size - 25, (m, 2))
        wh = rng.uniform(8, 20, (m, 2))
        gt = GroundTruth(np.concatenate([b, b + wh], 1), rng.integers(0, num_classes, m))
        gts.append(gt)
        weak.append(noisy(gt, 0.4, 4.0, 0.5))
        strong.append(noisy(gt, 0.1, 1.0, 0.1))
    return gts, weak, strong


@pytest.fixture(scope="session")
def noisy_pair():
    return make_noisy_pair(np.random.default_rng(7))
