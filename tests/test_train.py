"""Training substrate: AdamW, schedules, checkpointing, detector training."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.adamw import adamw_init, adamw_update
from repro.train.checkpoint import load_pytree, save_pytree
from repro.train.schedule import warmup_cosine


def test_adamw_minimises_quadratic():
    params = {"x": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["x"] - 1.0))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, 0.05, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["x"]), [1.0, 1.0], atol=1e-2)


def test_adamw_grad_clip():
    params = {"x": jnp.array([0.0])}
    opt = adamw_init(params)
    g = {"x": jnp.array([1e6])}
    p2, _ = adamw_update(g, opt, params, lr=1.0, grad_clip=1.0, weight_decay=0.0)
    assert abs(float(p2["x"][0])) < 10.0  # clipped step, not 1e6-scaled


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == pytest.approx(0.0)
    assert float(s(10)) == pytest.approx(1.0, abs=0.02)
    assert float(s(100)) == pytest.approx(0.1, abs=0.02)
    # monotone warmup
    assert float(s(3)) < float(s(7))


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.int32)},
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    loaded = load_pytree(path, like)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_detector_training_reduces_loss():
    from repro.data.shapes import ShapesDataset
    from repro.models.detector import WEAK
    from repro.train.trainer import train_detector

    ds = ShapesDataset.generate(128, seed=3)
    _, losses = train_detector(WEAK, ds, steps=40, batch_size=32, log_every=0)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
