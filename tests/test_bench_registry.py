"""The benchmark harness selection registry: every ``bench_*`` function in
benchmarks/run.py must be registered (and hence runnable by ``--smoke`` or
the full run) — the tier-1 twin of the ``run.py --check`` CI guard, so a
new bench can't silently drop out of the allowlists."""
import importlib.util
import os

import pytest


@pytest.fixture(scope="module")
def bench_run():
    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "benchmarks", "run.py"
    )
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_every_bench_function_is_registered(bench_run):
    assert bench_run.check_registry() == []


def test_registry_names_are_unique_and_disjoint(bench_run):
    full, smoke = bench_run.registered_benches()
    names = [n for n, _ in full + smoke]
    assert len(names) == len(set(names))


def test_video_bench_in_smoke_allowlist(bench_run):
    _, smoke = bench_run.registered_benches()
    assert "video_pipeline" in {n for n, _ in smoke}
