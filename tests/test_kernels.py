"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.estimator_mlp import estimator_mlp, estimator_mlp_ref
from repro.kernels.iou_matrix import iou_matrix, iou_matrix_ref
from repro.kernels.wkv6 import wkv6, wkv6_ref


def boxes(rng, n, dtype):
    b = rng.uniform(0, 50, (n, 2))
    return jnp.asarray(np.concatenate([b, b + rng.uniform(1, 20, (n, 2))], 1), dtype)


@pytest.mark.parametrize("n,m", [(1, 1), (7, 300), (256, 256), (511, 130), (1024, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("interpret", [True, None])
def test_iou_matrix_sweep(n, m, dtype, interpret, rng):
    # interpret=True pins the Pallas kernel; None exercises the backend
    # auto-dispatch (the jitted jnp reference on CPU hosts)
    a, b = boxes(rng, n, dtype), boxes(rng, m, dtype)
    got = iou_matrix(a, b, interpret=interpret)
    want = iou_matrix_ref(a, b)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("tile", [128, 256])
def test_iou_matrix_tiles(tile, rng):
    a, b = boxes(rng, 300, jnp.float32), boxes(rng, 200, jnp.float32)
    got = iou_matrix(a, b, tile_n=tile, tile_m=tile, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(iou_matrix_ref(a, b)), atol=1e-6
    )


@pytest.mark.parametrize("B,F,H", [(1, 10, 8), (37, 395, 96), (128, 512, 128), (300, 100, 64)])
@pytest.mark.parametrize("interpret", [True, None])
def test_estimator_mlp_sweep(B, F, H, interpret, rng):
    x = jnp.asarray(rng.normal(0, 1, (B, F)), jnp.float32)
    w1 = jnp.asarray(rng.normal(0, 0.1, (F, H)), jnp.float32)
    b1 = jnp.asarray(rng.normal(0, 0.1, H), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 0.1, H), jnp.float32)
    b2 = jnp.asarray(0.05, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(estimator_mlp(x, w1, b1, w2, b2, interpret=interpret)),
        np.asarray(estimator_mlp_ref(x, w1, b1, w2, b2)),
        atol=1e-5,
    )


def test_estimator_mlp_matches_trained_estimator(rng):
    """The kernel must agree with a RewardEstimator restricted to one
    hidden layer (the deployable on-device path)."""
    from repro.core.estimator import EstimatorConfig, RewardEstimator

    x = rng.normal(0, 1, (64, 50)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.float32)
    est = RewardEstimator(50, EstimatorConfig(hidden=(32,), epochs=5, standardize=False))
    est.fit(x, y)
    p = est.params
    got = estimator_mlp(
        jnp.asarray(x), p["layer0"]["w"], p["layer0"]["b"],
        p["layer1"]["w"][:, 0], p["layer1"]["b"][0],
    )
    np.testing.assert_allclose(np.asarray(got), est.predict(x), atol=1e-5)


@pytest.mark.parametrize("B,T,H,K,V", [(1, 8, 1, 8, 8), (2, 64, 3, 16, 16), (2, 33, 2, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(B, T, H, K, V, dtype, rng):
    r = jnp.asarray(rng.normal(0, 1, (B, T, H, K)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, T, H, K)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, T, H, V)), dtype)
    w = jnp.asarray(rng.uniform(0.5, 0.99, (B, T, H, K)), dtype)
    u = jnp.asarray(rng.normal(0, 0.2, (H, K)), dtype)
    s0 = jnp.asarray(rng.normal(0, 0.1, (B, H, K, V)), dtype)
    out, sT = wkv6(r, k, v, w, u, s0)

    def fold(x):
        return jnp.moveaxis(jnp.asarray(x, jnp.float32), 1, 2).reshape(B * H, T, x.shape[-1])

    u_b = jnp.broadcast_to(jnp.asarray(u, jnp.float32)[None], (B, H, K)).reshape(B * H, K, 1)
    oref, sref = wkv6_ref(fold(r), fold(k), fold(v), fold(w), u_b,
                          jnp.asarray(s0, jnp.float32).reshape(B * H, K, V))
    oref = jnp.moveaxis(oref.reshape(B, H, T, V), 1, 2)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref), atol=tol, rtol=tol)
    np.testing.assert_allclose(
        np.asarray(sT).reshape(-1), np.asarray(sref).reshape(-1), atol=tol, rtol=tol
    )


@pytest.mark.parametrize(
    "B,S,T,H,K,D,window,off",
    [
        (1, 128, 128, 2, 1, 32, 0, 0),
        (2, 256, 256, 4, 2, 64, 0, 0),
        (1, 100, 300, 4, 4, 32, 0, 200),  # unpadded sizes + query offset
        (2, 256, 256, 4, 2, 64, 64, 0),  # sliding window
        (1, 64, 512, 8, 2, 128, 128, 448),  # windowed decode-tail
    ],
)
def test_flash_sdpa_sweep(B, S, T, H, K, D, window, off, rng):
    from repro.kernels.flash_sdpa import flash_sdpa, flash_sdpa_ref

    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, K, D)), jnp.float32)
    got = flash_sdpa(q, k, v, tq=64, tk=64, window=window, q_offset=off)
    G = H // K
    qf = jnp.moveaxis(q, 1, 2).reshape(B * H, S, D)
    kf = jnp.repeat(jnp.moveaxis(k, 1, 2), G, axis=1).reshape(B * H, T, D)
    vf = jnp.repeat(jnp.moveaxis(v, 1, 2), G, axis=1).reshape(B * H, T, D)
    want = flash_sdpa_ref(qf, kf, vf, window=window, q_offset=off)
    want = jnp.moveaxis(want.reshape(B, H, S, D), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_flash_sdpa_matches_model_sdpa(rng):
    from repro.kernels.flash_sdpa import flash_sdpa
    from repro.models.layers import _sdpa, causal_mask

    q = jnp.asarray(rng.normal(0, 1, (2, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 128, 2, 64)), jnp.float32)
    want = _sdpa(q, k, v, causal_mask(128, 128, 0), 2, 4).reshape(2, 128, 4, 64)
    got = flash_sdpa(q, k, v, tq=64, tk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_wkv6_matches_model_layer(rng):
    """Kernel semantics == the RWKV6 time-mix inner scan in the model."""
    from repro.models.layers import RWKV6Config, rwkv6_init, rwkv6_time_mix

    cfg = RWKV6Config(d_model=64, head_size=16)
    params = rwkv6_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = jnp.asarray(rng.normal(0, 1, (B, S, 64)), jnp.float32)
    out_model, state_model, _ = rwkv6_time_mix(params, cfg, x)
    # recompute r/k/v/w/u exactly as the layer does, then run the kernel
    from repro.models.layers import _rwkv6_mix

    x_prev = jnp.concatenate([jnp.zeros((B, 1, 64)), x[:, :-1]], axis=1)
    mixed = _rwkv6_mix(params, x, x_prev)
    xr, xk, xv, xg, xw = [mixed[:, :, i] for i in range(5)]
    H, Hd = cfg.num_heads, cfg.head_size
    r = (xr @ params["wr"]).reshape(B, S, H, Hd)
    k = (xk @ params["wk"]).reshape(B, S, H, Hd)
    v = (xv @ params["wv"]).reshape(B, S, H, Hd)
    dl = jnp.tanh(xw @ params["decay_lora_a"]) @ params["decay_lora_b"]
    w = jnp.exp(-jnp.exp(params["decay_base"] + dl)).reshape(B, S, H, Hd)
    s0 = jnp.zeros((B, H, Hd, Hd), jnp.float32)
    out_k, _ = wkv6(r, k, v, w, params["bonus"], s0)
    # compare pre-norm wkv outputs by applying the same output transform
    from repro.models.layers import layernorm

    g = jax.nn.silu(xg @ params["wg"])
    out_ref = layernorm(params["ln_x"], out_k.reshape(B, S, 64).astype(x.dtype)) * g
    out_ref = out_ref @ params["wo"]
    np.testing.assert_allclose(
        np.asarray(out_ref), np.asarray(out_model), atol=1e-4
    )
