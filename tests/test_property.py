"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed here (CI installs it; the dev image "
    "does not) — deterministic stand-ins for the runtime-facing invariants "
    "live in test_netsim.py / test_video.py",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.features import extract_features, extract_features_batch
from repro.core.reward import CdfTransform, topk_offload_mask
from repro.detection.batch import (
    DetectionsBatch,
    GroundTruthBatch,
    match_batch,
    to_image_evals,
)
from repro.detection.boxes import box_iou_np
from repro.detection.map_engine import (
    Detections,
    GroundTruth,
    average_precision,
    dataset_map,
    match_detections,
)

finite = st.floats(min_value=0.0, max_value=100.0, allow_nan=False)


@st.composite
def box_arrays(draw, max_n=12):
    n = draw(st.integers(1, max_n))
    xy = draw(
        st.lists(st.tuples(finite, finite, finite, finite), min_size=n, max_size=n)
    )
    arr = np.array(xy)
    x1 = np.minimum(arr[:, 0], arr[:, 2])
    x2 = np.maximum(arr[:, 0], arr[:, 2]) + 0.1
    y1 = np.minimum(arr[:, 1], arr[:, 3])
    y2 = np.maximum(arr[:, 1], arr[:, 3]) + 0.1
    return np.stack([x1, y1, x2, y2], axis=1)


@given(box_arrays(), box_arrays())
@settings(max_examples=50, deadline=None)
def test_iou_bounds_and_symmetry(a, b):
    iou = box_iou_np(a, b)
    assert np.all(iou >= 0) and np.all(iou <= 1 + 1e-9)
    np.testing.assert_allclose(iou, box_iou_np(b, a).T, atol=1e-12)


@given(box_arrays())
@settings(max_examples=30, deadline=None)
def test_iou_self_diagonal_is_one(a):
    iou = box_iou_np(a, a)
    np.testing.assert_allclose(np.diag(iou), 1.0, atol=1e-9)


@given(
    st.lists(st.floats(0.01, 1.0, allow_nan=False), min_size=1, max_size=30),
    st.data(),
)
@settings(max_examples=50, deadline=None)
def test_ap_false_positive_never_helps(scores, data):
    """Appending a false positive must not increase AP."""
    n = len(scores)
    tp = np.array(data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
    n_gt = max(int(tp.sum()), 1)
    s = np.array(scores)
    base = average_precision(s, tp, n_gt)
    fp_score = data.draw(st.floats(0.01, 1.0, allow_nan=False))
    worse = average_precision(
        np.append(s, fp_score), np.append(tp, False), n_gt
    )
    assert worse <= base + 1e-12


@given(st.lists(st.floats(-5, 5, allow_nan=False), min_size=2, max_size=200))
@settings(max_examples=50, deadline=None)
def test_cdf_transform_properties(rewards):
    r = np.array(rewards)
    cdf = CdfTransform(r)
    y = cdf(r)
    assert np.all(y >= 0) and np.all(y <= 1)
    order = np.argsort(r, kind="stable")
    assert np.all(np.diff(y[order]) >= -1e-12)  # monotone in reward


@given(
    st.integers(1, 300),
    st.floats(0.0, 1.0, allow_nan=False),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=50, deadline=None)
def test_topk_mask_exact_budget(n, ratio, seed):
    scores = np.random.default_rng(seed).uniform(size=n)
    mask = topk_offload_mask(scores, ratio)
    assert mask.sum() == round(ratio * n)
    if 0 < mask.sum() < n:
        # every offloaded score >= every kept score
        assert scores[mask].min() >= scores[~mask].max() - 1e-12


# ----------------------------------------------------- batched data plane
#
# Boxes live on an integer grid and scores on a 1/256 grid so float32
# (batched) and float64 (per-image reference) agree bit-for-bit on every
# IoU-threshold comparison, argmax tie-break, and score sort — the batched
# matcher is then required to be EXACTLY the per-image one.

NUM_CLASSES = 4


@st.composite
def ragged_images(draw, n_images=4, max_det=6, max_gt=5):
    """Per-example ragged (Detections, GroundTruth) lists, including empty
    detections and empty-GT (all-padded) rows."""
    dets, gts = [], []
    for _ in range(n_images):
        m = draw(st.integers(0, max_gt))
        g_boxes, g_cls = [], []
        for _ in range(m):
            x = draw(st.integers(0, 24)); y = draw(st.integers(0, 24))
            w = draw(st.integers(1, 12)); h = draw(st.integers(1, 12))
            g_boxes.append([x, y, x + w, y + h])
            g_cls.append(draw(st.integers(0, NUM_CLASSES - 1)))
        gts.append(
            GroundTruth(np.array(g_boxes, float).reshape(-1, 4), np.array(g_cls, int))
        )
        k = draw(st.integers(0, max_det))
        d_boxes, d_cls, d_scores = [], [], []
        for _ in range(k):
            x = draw(st.integers(0, 24)); y = draw(st.integers(0, 24))
            w = draw(st.integers(1, 12)); h = draw(st.integers(1, 12))
            d_boxes.append([x, y, x + w, y + h])
            d_cls.append(draw(st.integers(0, NUM_CLASSES - 1)))
            d_scores.append(draw(st.integers(1, 256)) / 256.0)
        dets.append(
            Detections(
                np.array(d_boxes, float).reshape(-1, 4),
                np.array(d_scores, float),
                np.array(d_cls, int),
            )
        )
    return dets, gts


@given(ragged_images())
@settings(max_examples=40, deadline=None)
def test_match_batch_equals_per_image_matching(batch):
    dets, gts = batch
    thresholds = (0.5, 0.75)
    db = DetectionsBatch.from_list(dets)
    gb = GroundTruthBatch.from_list(gts)
    evs = to_image_evals(db, gb, match_batch(db, gb, thresholds))
    for ev, d, g in zip(evs, dets, gts):
        ref = match_detections(d, g, thresholds)
        assert ev.gt_counts == ref.gt_counts
        assert set(ev.per_class) == set(ref.per_class)
        for c in ref.per_class:
            assert np.array_equal(ev.per_class[c][1], ref.per_class[c][1])
            assert np.array_equal(ev.matched_gt[c], ref.matched_gt[c])


@given(ragged_images())
@settings(max_examples=30, deadline=None)
def test_features_batched_equals_per_image(batch):
    dets, _ = batch
    ref = np.stack([extract_features(d, NUM_CLASSES, 25, 32.0) for d in dets])
    got = extract_features_batch(dets, NUM_CLASSES, 25, 32.0)
    np.testing.assert_allclose(got, ref, atol=2e-5, rtol=1e-5)


@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_map_permutation_invariance(seed, n_img):
    """Dataset mAP must not depend on image order."""
    rng = np.random.default_rng(seed)
    gts, dets = [], []
    for _ in range(n_img):
        m = int(rng.integers(1, 4))
        b = rng.uniform(0, 40, (m, 2))
        boxes = np.concatenate([b, b + rng.uniform(2, 10, (m, 2))], 1)
        cls = rng.integers(0, 4, m)
        gts.append(GroundTruth(boxes, cls))
        keep = rng.uniform(size=m) > 0.3
        dets.append(
            Detections(
                boxes[keep] + rng.normal(0, 1, (int(keep.sum()), 4)),
                rng.uniform(0.2, 1.0, int(keep.sum())),
                cls[keep],
            )
        )
    base = dataset_map(dets, gts)
    perm = rng.permutation(n_img)
    shuffled = dataset_map([dets[i] for i in perm], [gts[i] for i in perm])
    np.testing.assert_allclose(base, shuffled, atol=1e-12)
