"""Per-architecture smoke tests: REDUCED variants (2 layers, d_model<=256,
<=4 experts) run one forward/train step on CPU asserting output shapes and
no NaNs; decode against a prefilled cache must match the parallel forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, long_context_variant
from repro.launch.steps import make_train_step
from repro.models.lm import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    reduced,
)
from repro.train.adamw import adamw_init


def make_batch(cfg, B=2, S=32, seed=0, with_labels=True):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.vision_tokens, cfg.d_model)), jnp.float32
        )
        batch["positions_3d"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    if cfg.arch_type == "encdec":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.encoder_frames, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def smoke(request):
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = make_batch(cfg)
    step = make_train_step(cfg, lr=1e-3)
    l0 = float(loss_fn(params, cfg, batch))
    params, opt, loss = step(params, opt, batch)
    assert np.isfinite(float(loss))
    l1 = float(loss_fn(params, cfg, batch))
    assert l1 < l0  # one step on the same batch reduces loss


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S, with_labels=False)
    last_logits, cache = prefill(params, cfg, batch, capacity=S + 4)
    logits_full, _ = forward(params, cfg, batch)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(logits_full[:, -1, :]), atol=2e-4
    )
    nxt = jnp.argmax(last_logits, -1).astype(jnp.int32)
    p3d = jnp.full((3, B, 1), S) if cfg.arch_type == "vlm" else None
    dl, _ = decode_step(params, cfg, cache, nxt, jnp.asarray(S, jnp.int32), p3d)
    toks2 = jnp.concatenate([batch["tokens"], nxt[:, None]], 1)
    b2 = dict(batch, tokens=toks2)
    if cfg.arch_type == "vlm":
        b2["positions_3d"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None], (3, B, S + 1)
        )
    ref, _ = forward(params, cfg, b2)
    np.testing.assert_allclose(
        np.asarray(dl), np.asarray(ref[:, -1, :]), atol=5e-4
    )


@pytest.mark.parametrize("arch", ["yi_6b", "qwen2_vl_2b", "deepseek_moe_16b"])
def test_sliding_window_decode_ring_buffer(arch):
    """long_500k variant: window ring cache must match a full-attention
    forward restricted to the window."""
    cfg = dataclasses.replace(reduced(get_config(arch)), window=8)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 12
    batch = make_batch(cfg, B=B, S=S, with_labels=False)
    # prefill with capacity = window
    last_logits, cache = prefill(params, cfg, batch, capacity=cfg.window)
    ref, _ = forward(params, cfg, batch)  # forward applies the same window
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(ref[:, -1, :]), atol=2e-4
    )
    # one decode step past the window boundary
    nxt = jnp.argmax(last_logits, -1).astype(jnp.int32)
    p3d = jnp.full((3, B, 1), S) if cfg.arch_type == "vlm" else None
    dl, _ = decode_step(params, cfg, cache, nxt, jnp.asarray(S, jnp.int32), p3d)
    toks2 = jnp.concatenate([batch["tokens"], nxt[:, None]], 1)
    b2 = dict(batch, tokens=toks2)
    if cfg.arch_type == "vlm":
        b2["positions_3d"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None], (3, B, S + 1)
        )
    ref2, _ = forward(params, cfg, b2)
    np.testing.assert_allclose(
        np.asarray(dl), np.asarray(ref2[:, -1, :]), atol=5e-4
    )


def test_rwkv_long_decode_is_stateful():
    """RWKV decode needs no KV cache: state size is independent of context."""
    cfg = reduced(get_config("rwkv6_1b6"))
    cache = init_cache(cfg, batch=1, capacity=10**6)
    total = sum(np.prod(v.shape) for v in jax.tree.leaves(cache))
    cache_small = init_cache(cfg, batch=1, capacity=8)
    total_small = sum(np.prod(v.shape) for v in jax.tree.leaves(cache_small))
    assert total == total_small


def test_mla_cache_is_compressed():
    """MLA decode cache stores kv_lora_rank + rope dims, far below the
    expanded per-head K/V footprint."""
    cfg = reduced(get_config("deepseek_v2_lite_16b"))
    cache = init_cache(cfg, batch=1, capacity=64)
    per_tok = sum(
        np.prod(v.shape) for v in jax.tree.leaves(cache)
    ) / (cfg.num_layers * 64)
    expanded = 2 * cfg.num_heads * cfg.head_dim
    assert per_tok < expanded
