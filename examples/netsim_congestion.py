"""Network simulation walkthrough: congestion-aware offloading end to end.

The paper's deployment setting puts the strong detector behind a
rate-constrained wireless uplink.  ``repro.netsim`` makes that link
explicit — size-dependent transmission delay, a bounded FIFO uplink queue,
and a seeded Gilbert–Elliott fading channel — and adds two queue-aware
decision policies on top of the ``OffloadEngine`` registry:

- ``queue_aware``   threshold on the congestion-discounted estimate with an
                    integral budget tracker (defer in fades, pay back after),
- ``value_iteration`` the (queue depth x channel state) MDP, solved as one
                    jitted ``jax.lax.scan``.

This example (1) shows the raw netsim pieces, (2) runs the seeded
congestion scenario under ``threshold`` vs ``queue_aware`` vs
``value_iteration`` at the same budget, and (3) sweeps the value-iteration
thresholds over a whole ratio grid in one batched device call.

Run:  python examples/netsim_congestion.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import numpy as np

from repro.api import MLPRewardModel, OffloadEngine
from repro.core import EstimatorConfig
from repro.netsim import GilbertElliottLink, UplinkQueue, value_iteration_sweep
from repro.runtime import default_congested_fleet, simulate


def fitted_engine(n=2000, d=24, seed=0) -> OffloadEngine:
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 1.5 * x[:, 0] - 0.8 * x[:, 1] + 0.3 * rng.normal(size=n)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(32,), epochs=20, seed=seed)
        ),
        ratio=0.35,
    )
    eng.fit(features=x, rewards=rewards)
    return eng


def main() -> None:
    print("== the raw pieces: a fading link behind a bounded FIFO ==")
    link = GilbertElliottLink(
        bandwidth=0.5, bad_bandwidth=0.125, p_gb=0.1, p_bg=0.3, seed=4
    )
    queue = UplinkQueue(link, depth=6, frame_bits=1.0)
    for step in range(8):
        f = queue.enqueue(0.6 * step, step)
        if f is None:
            print(f"  frame {step}: DROPPED (queue full)")
        else:
            print(
                f"  frame {step}: wait {f.queue_delay:5.2f}"
                f"  transmit {f.transmit_delay:5.2f}"
                f"  delivered t={f.t_delivered:5.2f}"
            )
    queue.poll(1e9)
    print(f"  conservation: {queue.stats()}")

    print("\n== seeded congestion scenario, three policies, one budget ==")
    engine = fitted_engine()
    stream = np.random.default_rng(42).normal(0, 1, (400, 24)).astype(np.float32)
    policies = {
        "threshold": engine,
        "queue_aware": engine.with_policy("queue_aware"),
        "value_iteration": engine.with_policy(
            "value_iteration", policy_kwargs=dict(max_queue=12, delay_cost=0.03)
        ),
    }
    for name, eng in policies.items():
        trace = simulate(
            eng, features=stream, edges=default_congested_fleet(3, seed=5),
            ratio=0.35, micro_batch=1, seed=5,
        )
        s = trace.summary()
        d = s["latency_decomposition"] or {}
        print(
            f"  {name:16s} realized_ratio={s['telemetry']['realized_ratio']:.3f}"
            f"  mean_latency={s['mean_offload_latency']:6.2f}"
            f"  (queue {d.get('queue', 0):5.2f} + transmit {d.get('transmit', 0):5.2f}"
            f" + service {d.get('service', 0):4.2f})"
        )
    print("  -> queue-aware policies trade the queue component away at the")
    print("     same offload budget; the trace proves where the time went.")

    print("\n== value-iteration threshold tables, one batched solve ==")
    ratios = [0.1, 0.3, 0.5]
    thetas = value_iteration_sweep(
        engine.calibration_scores, ratios, max_queue=8, n_sweeps=60
    )
    print(f"  theta grid shape {thetas.shape}  (ratio x queue-depth x channel)")
    for r, th in zip(ratios, thetas):
        print(
            f"  ratio {r:.1f}: offload threshold rises"
            f" q=0 {th[0, 0]:.2f} -> q=8 {th[8, 0]:.2f} (good)"
            f" | bad channel q=0 {th[0, 1]:.2f}"
        )


if __name__ == "__main__":
    main()
