"""Observability walkthrough: metrics + tracing + profiling on one run.

``repro.obs`` puts one handle over the whole serve stack.  Pass ``obs=``
to any runtime entry point and three planes light up:

- a ``MetricsRegistry`` the session/dispatcher/edge counters live in
  (Prometheus text + JSON exporters, snapshot/delta),
- a ``Tracer`` stamping nested spans from the simulation's manual clock
  (byte-identical traces under a fixed seed) exported as Chrome-trace
  JSON — open it in Perfetto or chrome://tracing,
- a ``DispatchProfiler`` attributing host-loop wall time to serve phases,
  plus per-callsite jit retrace counts.

This example runs the seeded congested-fleet scenario with everything
on, prints the Prometheus exposition and the profiler table, and writes
``obs_trace.json`` / ``obs_metrics.json``.

Run:  python examples/observability.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import numpy as np

from repro.api import MLPRewardModel, OffloadEngine
from repro.core import EstimatorConfig
from repro.obs import Obs
from repro.runtime import default_congested_fleet, simulate


def fitted_engine(n=2000, d=24, seed=0) -> OffloadEngine:
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 1.5 * x[:, 0] - 0.8 * x[:, 1] + 0.3 * rng.normal(size=n)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(32,), epochs=20, seed=seed)
        ),
        ratio=0.3,
    )
    eng.fit(features=x, rewards=rewards)
    return eng


def main() -> None:
    engine = fitted_engine()
    stream = np.random.default_rng(7).normal(0, 1, (512, 24)).astype(np.float32)

    obs = Obs()  # metrics + tracing + profiling; obs=None stays the free default
    trace = simulate(
        engine,
        features=stream,
        edges=default_congested_fleet(3, seed=5),
        ratio=0.3,
        micro_batch=32,
        seed=5,
        obs=obs,
    )
    t = trace.telemetry
    print("== run ==")
    print(
        f"  processed {t.processed}  offloaded {t.offloaded}"
        f"  realized_ratio {t.realized_ratio:.3f}"
    )

    print("\n== Prometheus exposition (what a scraper would see) ==")
    text = obs.metrics.to_prometheus()
    shown, total = 0, len(text.splitlines())
    for line in text.splitlines():
        if line.startswith(("repro_realized_ratio", "repro_dispatch_total",
                            "repro_edge_queue_depth", "repro_offload_rtt_sum",
                            "repro_offload_rtt_count", "repro_jit_retraces")):
            print(f"  {line}")
            shown += 1
    print(f"  ... ({shown} of {total} lines shown)")

    print("\n== host-phase profile (where the serve loop's time went) ==")
    print("  " + obs.profiler.format_report().replace("\n", "\n  "))

    print("\n== jit retraces since the handle was built ==")
    for site, (retraces, calls) in sorted(obs.jit_delta().items()):
        if retraces or calls:
            print(f"  {site:32s} retraces={retraces:3d}  calls={calls}")

    obs.tracer.export("obs_trace.json")
    obs.metrics.export_json("obs_metrics.json")
    n_events = len(obs.tracer.events)
    print(f"\nwrote obs_trace.json ({n_events} events — load it in Perfetto)")
    print("wrote obs_metrics.json (structured series dump)")
    print("rerun with the same seed: both files are byte-identical.")


if __name__ == "__main__":
    main()
