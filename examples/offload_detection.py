"""The paper's full evaluation (Figs. 5/9/10, Tables II) from the cached
pipeline — runs the complete experiment suite, prints a summary, then fits
the deployable ``OffloadEngine`` artifact and round-trips it through
save/load.

Run:  python examples/offload_detection.py [--quick] [--force]
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import argparse
import os

from repro.api import OffloadEngine
from repro.experiments.detection_repro import build_engine, build_pipeline, run_all

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../artifacts")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    results = run_all(force=args.force, quick=args.quick)

    print("\n===== summary =====")
    print(f"weak mAP {results['weak_map']:.4f}   strong mAP {results['strong_map']:.4f}")
    print("\nFig. 5 (oracle mAP vs |E|, r=0.2):")
    f5 = results["figure5"]
    for e, m in zip(f5["context_sizes"], f5["curves"]["r=0.2"]["mean"]):
        print(f"  |E|={e:4d}: {m:.4f}")
    print("\nFig. 10 (normalized mAP, % of weak->strong gap closed):")
    for name, cur in results["figure9_10"]["curves"].items():
        pts = ", ".join(f"{v:.0f}" for v in cur["norm"][:6])
        print(f"  {name:18s} [{pts}]  @ratios {results['figure9_10']['ratios'][:6]}")

    # ---- deployable artifact: fit, save, reload, verify ------------------
    print("\n===== OffloadEngine artifact =====")
    state = build_pipeline()  # cached by run_all above
    ctx = 400 if args.quick else 800
    engine = build_engine(
        state, context_size=ctx, ratio=0.2, epochs=10 if args.quick else 40
    )
    path = os.path.join(ARTIFACTS, "offload_engine")
    engine.save(path)
    reloaded = OffloadEngine.load(path)
    probe = state.weak_dets_val[:64]
    d1, d2 = engine.decide(probe), reloaded.decide(probe)
    assert (d1.offload == d2.offload).all(), "save/load round trip diverged"
    print(f"saved {path}.npz  (fused Pallas scoring: {engine.reward_model.fused})")
    print(f"decisions on 64 probe images: ratio={d1.ratio:.2f}, round trip exact")
    reloaded.set_ratio(0.5)
    print(f"runtime re-budget to 0.5: ratio={reloaded.decide(probe).ratio:.2f}")


if __name__ == "__main__":
    main()
