"""The paper's full evaluation (Figs. 5/9/10, Tables II) from the cached
pipeline — runs the complete experiment suite and prints a summary.

Run:  PYTHONPATH=src python examples/offload_detection.py [--quick] [--force]
"""
import argparse
import json

from repro.experiments.detection_repro import run_all


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    results = run_all(force=args.force, quick=args.quick)

    print("\n===== summary =====")
    print(f"weak mAP {results['weak_map']:.4f}   strong mAP {results['strong_map']:.4f}")
    print("\nFig. 5 (oracle mAP vs |E|, r=0.2):")
    f5 = results["figure5"]
    for e, m in zip(f5["context_sizes"], f5["curves"]["r=0.2"]["mean"]):
        print(f"  |E|={e:4d}: {m:.4f}")
    print("\nFig. 10 (normalized mAP, % of weak->strong gap closed):")
    for name, cur in results["figure9_10"]["curves"].items():
        pts = ", ".join(f"{v:.0f}" for v in cur["norm"][:6])
        print(f"  {name:18s} [{pts}]  @ratios {results['figure9_10']['ratios'][:6]}")


if __name__ == "__main__":
    main()
