"""Online adaptation walkthrough: closing the loop on the frozen engine.

The paper fits its reward estimator once.  But every offloaded frame
returns the strong detection — free supervision for exactly the quantity
the estimator predicts.  ``repro.online`` feeds it back:

1. the drift detector in isolation — why steady selection bias must NOT
   fire, and why a genuine level shift must;
2. the measured network estimator vs the oracle probes on the congested
   fleet (same ``queue_aware`` policy, no simulator internals consulted);
3. the headline: a mid-stream distribution shift served by a frozen vs an
   adaptive engine at the same offload budget.

Run:  python examples/online_adaptation.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import numpy as np

from repro.api import MLPRewardModel, OffloadEngine
from repro.core import EstimatorConfig
from repro.online import (
    DriftConfig,
    DriftDetector,
    NetworkEstimator,
    default_shift_scenario,
    run_shift_scenario,
)
from repro.runtime import default_congested_fleet, simulate


def drift_demo() -> None:
    print("== drift detection on realized-vs-predicted residuals ==")
    det = DriftDetector(DriftConfig())
    rng = np.random.default_rng(0)
    # offloaded-subset residuals: constant negative offset (selection bias)
    for r in -0.12 + 0.05 * rng.normal(size=300):
        det.update(predicted=0.0, realized=r)
    print(
        f"  300 obs of steady bias:  statistic {det.statistic:5.2f}"
        f"  (threshold {det.config.h})  drifted={det.drifted}"
    )
    fired_at = None
    for i, r in enumerate(0.30 + 0.05 * rng.normal(size=50)):
        det.update(predicted=0.0, realized=r)
        if det.drifted and fired_at is None:
            fired_at = i + 1
    print(
        f"  level shift of ~8 sigma:  fired after {fired_at} obs,"
        f"  ratio widened x{det.ratio_multiplier():.2f}"
    )
    det.reset()  # the forced refit handles it; baseline re-settles
    print(f"  after reset: statistic {det.statistic:.2f}, events {det.events}")


def netstate_demo() -> None:
    print("\n== measured probes vs the oracle (congested fleet) ==")
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (512, 32)).astype(np.float32)
    rewards = 2.0 * x[:, 0] + 0.3 * rng.normal(size=512)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(16,), epochs=10, batch_size=64)
        ),
        ratio=0.3,
    )
    eng.fit(features=x, rewards=rewards)
    qa = eng.with_policy("queue_aware")
    for label, net in (("oracle probes", None), ("measured RTT", NetworkEstimator())):
        trace = simulate(
            qa, features=x, edges=default_congested_fleet(3, seed=0),
            ratio=0.3, micro_batch=1, seed=0, net_state=net,
        )
        off = [rec.latency for rec in trace.records if rec.outcome == "offloaded"]
        print(
            f"  {label:14s} offloads={len(off):3d}"
            f"  mean_offload_latency={np.mean(off):5.2f}"
        )
        if net is not None:
            t = net.telemetry()
            print(
                f"                 estimator view: srtt={t['rtt']:.2f}"
                f"  bandwidth={t['bandwidth']:.3f}  delivered={t['delivered']:.0f}"
            )
    print("  -> the in-flight census (known at send time) matches the oracle's")
    print("     sharp congestion signal; no simulator internals were read.")


def headline_demo() -> None:
    print("\n== the headline: mid-stream shift, frozen vs adaptive ==")
    scenario = default_shift_scenario()
    frozen = run_shift_scenario(scenario)
    adaptive = run_shift_scenario(scenario, adaptive=True)
    for label, run in (("frozen", frozen), ("adaptive", adaptive)):
        s = run.summary()
        print(
            f"  {label:9s} realized_ratio={s['realized_ratio']:.3f}"
            f"  pre_shift={s['pre_shift_effective']:.3f}"
            f"  post_shift={s['post_shift_effective']:.3f}"
        )
    up = adaptive.updates
    print(
        f"  adaptive arm: {up['observations']} observations ->"
        f" {up['incremental_updates']} incremental updates,"
        f" {up['refits']} refits, {up['drift_events']} drift event(s)"
    )
    gain = adaptive.mean_effective(post_shift=True) - frozen.mean_effective(
        post_shift=True
    )
    print(f"  -> post-shift effective accuracy recovered: +{gain:.3f} AP")


def main() -> None:
    drift_demo()
    netstate_demo()
    headline_demo()


if __name__ == "__main__":
    main()
