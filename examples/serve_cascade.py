"""Cascade serving example: the paper's offloading pipeline applied to LM
early-exit serving (paper §V-A: the approach "is readily applicable to
edge frameworks with embedded early exits").

A small decoder serves batches of requests; the early-exit head (weak) runs
"locally", the unified ``OffloadEngine`` (logits features → MORIC estimator
→ runtime-adjustable threshold policy) decides per request whether to
escalate to full depth ("edge"), and the calibrated engine is saved and
reloaded as a deployable artifact.

Run:  python examples/serve_cascade.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import OffloadEngine
from repro.configs import get_config
from repro.data.lm_synth import synth_lm_batch
from repro.models.lm import init_params, reduced
from repro.serving.cascade_serving import LMCascade

CKPT = os.path.join(os.path.dirname(__file__), "../artifacts/lm_100m.npz")
ENGINE_PATH = os.path.join(os.path.dirname(__file__), "../artifacts/lm_cascade_engine")


def main() -> None:
    if os.path.exists(CKPT):
        # use the ~100M model trained by examples/train_lm.py — gives a
        # real weak(early-exit)/strong(full-depth) quality gap
        import sys

        sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        from examples.train_lm import scaled_100m
        from repro.train.checkpoint import load_pytree

        cfg = scaled_100m("yi_6b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        params = load_pytree(CKPT, params)
        print(f"loaded trained checkpoint {CKPT} ({cfg.name})")
    else:
        cfg = dataclasses.replace(
            reduced(get_config("qwen2_7b"), num_layers=6), name="qwen2-cascade-demo"
        )
        params = init_params(cfg, jax.random.PRNGKey(0))

    def mk(seed, B=32, S=48):
        toks, labels = synth_lm_batch(np.random.default_rng(seed), B, S, cfg.vocab_size)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    exit_layer = max(cfg.num_layers // 2, 1)
    print(f"== fit cascade (exit layer {exit_layer} of {cfg.num_layers}) ==")
    cascade = LMCascade.fit(
        params, cfg, exit_layer=exit_layer,
        calib_batches=[mk(s) for s in range(1, 5)],
        ratio=0.25, epochs=25,
    )

    for ratio in (0.1, 0.25, 0.5):
        cascade.set_ratio(ratio)  # runtime budget adjustment (via the engine)
        out = cascade.serve_batch(params, mk(99))
        print(
            f"budget={ratio:.2f}  actual={out['offload_ratio']:.2f}  "
            f"NLL weak={out['nll_weak'].mean():.4f}  "
            f"strong={out['nll_strong'].mean():.4f}  "
            f"cascade={out['nll_final'].mean():.4f}"
        )

    # the calibrated decision stack is a deployable artifact
    cascade.save(ENGINE_PATH)
    reloaded = LMCascade.load(ENGINE_PATH, cfg)
    out_a = cascade.serve_batch(params, mk(123))
    out_b = reloaded.serve_batch(params, mk(123))
    assert (out_a["offload"] == out_b["offload"]).all()
    print(
        f"saved+reloaded engine {ENGINE_PATH}.npz: decisions identical "
        f"(fused Pallas scoring: {cascade.engine.reward_model.fused})"
    )


if __name__ == "__main__":
    main()
