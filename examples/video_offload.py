"""Temporal offloading walkthrough: video streams, tracked reward
propagation, and stale-edge-result reuse.

The paper decides offloading per image; ``repro.video`` turns the decision
stack stream-level, which is what its deployment setting (a camera feeding
an edge over a constrained uplink) actually is:

- a seeded synthetic *video* scene (moving shapes, entries/exits/occlusions
  and scene cuts) with temporally-correlated weak/strong detections,
- a device-resident tracker — greedy IoU association through the
  ``iou_matrix`` Pallas kernel inside one jitted ``lax.scan`` — whose
  ``propagate`` snaps a stale edge result onto the current frame,
- two temporal policies in the engine registry: ``temporal_hysteresis``
  (stale-result credit: frames already covered by a fresh edge result are
  discounted) and ``keyframe`` (offload on scene changes, refractory-
  spaced),
- ``VideoRuntime.serve_clip``: netsim links age edge results in flight;
  every frame's *effective accuracy* (what was actually served, scored by
  the AP engine) lands on the trace.

Run:  python examples/video_offload.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import numpy as np

from repro.video import (
    STRONG_PROFILE,
    WEAK_PROFILE,
    TrackerConfig,
    VideoTracker,
    default_video_scenario,
    generate_clip,
    run_video_scenario,
    synthesize_detections,
    track_clip,
)


def main() -> None:
    print("== the raw pieces: a clip and its device-resident tracker ==")
    clip = generate_clip(2, 24, seed=4)
    weak = synthesize_detections(clip, WEAK_PROFILE, seed=5)
    hist = track_clip(weak)  # ONE jitted lax.scan over all 24 frames
    print(f"  clip: {clip.n_frames} frames x {clip.n_streams} streams,"
          f" cuts at {np.flatnonzero(clip.cuts[:, 0]).tolist()} (stream 0)")
    print(f"  tracks alive per frame (stream 0): "
          f"{hist.n_active[:, 0].tolist()}")

    print("\n== stale-result reuse: propagate an old edge answer forward ==")
    vt = VideoTracker(2, TrackerConfig())
    for t in range(24):
        vt.update(weak.frame(t))
    strong = synthesize_detections(clip, STRONG_PROFILE, seed=6)
    edge = strong.det(20, 0)
    prop = vt.propagate(edge, 20, 23, stream=0)
    print(f"  edge result from t=20 propagated to t=23: {len(edge)} dets,"
          f" scores decayed x{vt.config.stale_decay ** 3:.2f}")

    print("\n== the seeded 8-stream congested scenario, three policies ==")
    scenario = default_video_scenario(8, 96, seed=0)
    for policy in ("threshold", "temporal_hysteresis", "keyframe"):
        trace = run_video_scenario(scenario, policy, ratio=0.3)
        s = trace.staleness_profile()
        print(
            f"  {policy:20s} realized_ratio={trace.realized_ratio():.3f}"
            f"  effective_acc={trace.mean_effective_accuracy():.4f}"
            f"  covered={s['covered_fraction']:.2f}"
            f" (mean staleness {s['mean_staleness']:.1f} frames)"
        )
    print("  -> temporal_hysteresis covers more of the stream with fresh edge")
    print("     results at a LOWER realized budget: the stale-result credit")
    print("     spaces offloads, so the uplink queues stay short and results")
    print("     arrive while still useful.  keyframe concentrates its budget")
    print("     on scene changes but congests the links at this ratio — the")
    print("     per-frame trace (r.source / r.staleness / r.effective_accuracy)")
    print("     shows exactly where the accuracy went.")


if __name__ == "__main__":
    main()
