"""City-scale sharded serving walkthrough.

1. the sharded data plane: scoring a big stream batch over forced CPU
   host devices, bit-identical to the single-device engine path;
2. the headline: 1024 streams in 4 districts of increasing offload
   hardness, served coordinated (reward-driven budget redistribution)
   vs static equal split at the same global token budget.

Run:  python examples/fleet_scale.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)

The XLA_FLAGS line below must execute before jax initializes — that is
the whole CPU host-device recipe.  Remove it (or run on a real
multi-device backend) and everything still works: the plane degrades to
the single-device path and the logical shards keep functioning.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np  # noqa: E402

from repro.api import MLPRewardModel, OffloadEngine  # noqa: E402
from repro.core import EstimatorConfig  # noqa: E402
from repro.fleet import (  # noqa: E402
    FleetPlane,
    default_city_scenario,
    run_city_scenario,
)


def sharded_plane_demo() -> None:
    print("== sharded data plane: bit-identity over forced host devices ==")
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (1024, 64)).astype(np.float32)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(32,), epochs=3)
        )
    )
    eng.fit(features=x, rewards=rng.normal(0, 1, 1024))
    plane = FleetPlane()  # all visible devices along the "shard" axis
    # 1000 streams is ragged over 4 shards (250 each) — padding included
    ref = np.asarray(eng.score(features=x[:1000]))
    out = np.asarray(plane.score(eng, x[:1000]))
    print(f"  devices: {plane.n_devices}")
    print(f"  sharded == single-device, bit-for-bit: {np.array_equal(ref, out)}")


def city_demo() -> None:
    print("== city headline: coordinated vs static budget, 1024 streams ==")
    scenario = default_city_scenario(n_streams=1024, n_ticks=48)
    print(f"  districts (hardness): {scenario.hardness}")
    static = run_city_scenario(scenario, coordinated=False)
    coord = run_city_scenario(scenario, coordinated=True)
    for name, res in (("static", static), ("coordinated", coord)):
        s = res.summary()
        shares = ", ".join(f"{v:.2f}" for v in s["shard_shares"])
        ratios = ", ".join(f"{v:.2f}" for v in s["shard_ratios"])
        print(
            f"  {name:>11}: effective={s['mean_effective']:.4f}"
            f"  realized={s['realized_ratio']:.3f}"
            f"  shares=[{shares}]  shard_ratios=[{ratios}]"
            f"  redistributions={s['redistributions']}"
        )
    gain = coord.mean_effective() - static.mean_effective()
    print(
        f"  coordination gain: {gain:+.4f} effective AP at "
        f"{coord.realized_ratio() - static.realized_ratio():+.3f} realized-ratio delta"
    )


if __name__ == "__main__":
    sharded_plane_demo()
    print()
    city_demo()
