"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic token streams using the framework's train_step (the same
code path the dry-run lowers for the production mesh).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 150] [--arch yi_6b]

The model is the assigned architecture's family scaled to ~100M params so
the driver completes on CPU; on a real mesh the full config lowers
identically (see launch/dryrun.py).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.lm_synth import synth_lm_batch
from repro.launch.steps import make_train_step
from repro.models.lm import init_params
from repro.train.adamw import adamw_init
from repro.train.checkpoint import save_pytree


def scaled_100m(arch: str):
    """~100M-param variant of the assigned arch family."""
    cfg = get_config(arch)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-100m",
        num_layers=10,
        d_model=640,
        num_heads=10,
        num_kv_heads=2,  # must divide num_heads (GQA)
        head_dim=64,
        d_ff=2560,
        vocab_size=32768,
        dtype="float32",
        vision_tokens=0,
        mrope_sections=None,
        attn_chunk=0,
    )


def n_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = scaled_100m(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model {cfg.name}: {n_params(params)/1e6:.1f}M params")
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=args.lr), donate_argnums=(0, 1))

    rng = np.random.default_rng(0)
    t0 = time.time()
    losses = []
    for it in range(args.steps):
        toks, labels = synth_lm_batch(rng, args.batch, args.seq, cfg.vocab_size)
        params, opt, loss = step(
            params, opt, {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        )
        losses.append(float(loss))
        if it % 10 == 0 or it == args.steps - 1:
            rate = (it + 1) / (time.time() - t0)
            print(f"step {it:4d}  loss {float(loss):.4f}  ({rate:.2f} it/s)")
    print(f"loss: first10={np.mean(losses[:10]):.4f}  last10={np.mean(losses[-10:]):.4f}")
    save_pytree("artifacts/lm_100m.npz", params)
    print("checkpoint written to artifacts/lm_100m.npz")


if __name__ == "__main__":
    main()
