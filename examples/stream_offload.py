"""Streaming serve example: one weak device, three heterogeneous edges.

The paper's deployment setting run end to end through the runtime layer:
a fitted ``OffloadEngine`` (the deployable artifact) is wrapped in an
``OffloadRuntime``; frames arrive as a stream, an ``OffloadSession`` scores
micro-batches through the fused Pallas path and decides in arrival order,
and the ``MultiEdgeDispatcher`` routes accepted offloads across a
capacity- and rate-constrained fleet — degrading to the weak result when
every edge is saturated.  Everything is seeded: re-running prints the
identical per-step trace.

Run:  python examples/stream_offload.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import numpy as np

from repro.api import MLPRewardModel, OffloadEngine, list_policies
from repro.core import EstimatorConfig
from repro.runtime import (
    OffloadRuntime,
    default_edge_fleet,
    list_strategies,
    simulate,
)


def fitted_engine(n=4000, d=48, seed=0) -> OffloadEngine:
    """A synthetic calibration: reward depends on a few feature directions,
    so the MLP has real structure to learn."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    rewards = 1.5 * x[:, 0] - 0.8 * x[:, 1] + 0.3 * rng.normal(size=n)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=(64,), epochs=25, seed=seed)
        ),
        ratio=0.25,
    )
    eng.fit(features=x, rewards=rewards)
    return eng


def main() -> None:
    print(f"policies: {list_policies()}   strategies: {list_strategies()}")
    engine = fitted_engine()
    rng = np.random.default_rng(42)
    stream = rng.normal(0, 1, (512, 48)).astype(np.float32)

    print("\n== one stream, three heterogeneous edges, mid-stream re-budget ==")
    trace = simulate(
        engine,
        features=stream,
        edges=default_edge_fleet(3, seed=1),
        strategy="least_loaded",
        ratio=0.25,
        micro_batch=16,
        set_ratio_at={256: 0.5},  # budget doubles halfway through
        seed=1,
    )
    s = trace.summary()
    print(f"outcomes: {s['outcomes']}")
    t = s["telemetry"]
    print(
        f"decided ratio={t['realized_ratio']:.3f} (target ended at "
        f"{t['target_ratio']:.2f}), rolling={t['rolling_ratio']:.3f}, "
        f"mean offload latency={s['mean_offload_latency']:.2f}"
    )
    for name, st in s["dispatcher"]["edges"].items():
        print(f"  {name}: accepted={st['accepted']:4d} rejected={st['rejected']:4d}")
    print("first 5 steps of the trace:")
    for rec in trace.records[:5]:
        print(f"  {rec.as_dict()}")

    # exact reproducibility: same seed -> identical per-step records
    again = simulate(
        engine, features=stream, edges=default_edge_fleet(3, seed=1),
        strategy="least_loaded", ratio=0.25, micro_batch=16,
        set_ratio_at={256: 0.5}, seed=1,
    )
    assert again.records == trace.records
    print("re-run with the same seed: per-step trace identical")

    print("\n== strategies under a saturating burst ==")
    for strategy in list_strategies():
        runtime = OffloadRuntime(
            engine, default_edge_fleet(3, seed=2), strategy=strategy, seed=2
        )
        tr = runtime.serve(features=stream, ratio=0.6, micro_batch=64)
        out = tr.outcome_counts()
        print(
            f"  {strategy:15s} offloaded={out.get('offloaded', 0):4d} "
            f"degraded={out.get('degraded', 0):4d} local={out.get('local', 0):4d}"
        )


if __name__ == "__main__":
    main()
