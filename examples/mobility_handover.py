"""Moving clients, coverage-dependent links, and mid-stream handover.

1. seeded mobility traces: the jitted ``lax.scan`` rollout matches the
   pure-Python reference oracle and reruns bit-identically;
2. the coverage map: per-position signal strength, rate factors, and the
   time-to-coverage-loss probe the ``mobility_aware`` policy discounts by;
3. the headline: handover-aware dispatch vs static edge pinning at the
   same realized offload budget — handover keeps frames landing on a
   nearby station, so offloads stay cheap and results stay fresh;
4. in-flight semantics: what happens to results still in transit when
   their source station is abandoned (survive / die / stale).

Run:  python examples/mobility_handover.py
      (after `pip install -e .`, or prefix with PYTHONPATH=src)
"""
import numpy as np

from repro.mobility import (
    CoverageMap,
    MotionConfig,
    default_mobile_scenario,
    default_stations,
    rollout,
    rollout_ref,
    run_mobile_scenario,
)


def motion_demo() -> None:
    print("== seeded motion: scan rollout vs Python reference ==")
    for model in ("waypoint", "random_walk"):
        cfg = MotionConfig(model=model, area=(1000.0, 600.0), speed=12.0)
        scan = rollout(cfg, 4, 80, seed=0)
        ref = rollout_ref(cfg, 4, 80, seed=0)
        again = rollout(cfg, 4, 80, seed=0)
        print(
            f"  {model:12s} max|scan-ref| = {np.abs(scan - ref).max():.2e}"
            f"   rerun bit-identical: {np.array_equal(scan, again)}"
        )


def coverage_demo() -> None:
    print("== coverage: signal, rate factor, time-to-loss ==")
    cov = CoverageMap(default_stations(3, area=(1200.0, 600.0)))
    # a client walking the corridor left to right
    T = 60
    trace = np.stack(
        [np.linspace(50.0, 1150.0, T), np.full(T, 300.0)], axis=-1
    )
    for t in (0, 15, 30):
        i, rss = cov.best(trace[t])
        ttl = cov.time_to_loss(trace, t, dt=1.0)
        print(
            f"  t={t:2d}  best=bs{i}  rss={rss:6.1f} dBm"
            f"  rate_factor={cov.rate_factor(rss):.2f}"
            f"  time_to_loss={'inf' if np.isinf(ttl) else f'{ttl:.0f}'}"
        )


def headline_demo() -> None:
    print("== headline: handover-aware dispatch vs static pinning ==")
    sc = default_mobile_scenario(n_clients=4, n_steps=160, seed=0)
    handover = run_mobile_scenario(sc, "handover")
    static = run_mobile_scenario(sc, "static")
    for name, tr in (("static pin", static), ("handover", handover)):
        print(
            f"  {name:11s} eff.acc={tr.mean_effective_accuracy():.4f}"
            f"  realized_ratio={tr.realized_ratio():.3f}"
            f"  handovers={tr.n_handovers()}"
        )
    gain = handover.mean_effective_accuracy() - static.mean_effective_accuracy()
    print(f"  gain: +{gain:.4f} effective accuracy at equal offload budget")


def in_flight_demo() -> None:
    print("== in-flight semantics at the moment of handover ==")
    sc = default_mobile_scenario(n_clients=4, n_steps=160, seed=0)
    for mode in ("survive", "die", "stale"):
        tr = run_mobile_scenario(sc, "handover", in_flight=mode)
        cancelled = sum(
            e.get("cancelled", 0) for e in tr.dispatcher["edges"].values()
        )
        stale = np.mean([t.mean_staleness for t in tr.telemetry])
        print(
            f"  {mode:8s} eff.acc={tr.mean_effective_accuracy():.4f}"
            f"  cancelled={cancelled:3d}  mean_staleness={stale:.2f}"
        )


if __name__ == "__main__":
    motion_demo()
    coverage_demo()
    headline_demo()
    in_flight_demo()
