"""Quickstart: the paper's offloading pipeline in ~60 lines.

Trains tiny weak/strong detectors on the procedural dataset, computes exact
ORIC rewards, trains the MORIC estimator, and prints the mAP achieved by
each offloading policy at a 20% budget.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    CdfTransform,
    EstimatorConfig,
    RewardEstimator,
    RewardOracle,
    cascade_map,
    extract_features_batch,
    match_pairs,
    random_offload_mask,
    topk_offload_mask,
)
from repro.data.shapes import ShapesDataset
from repro.detection.map_engine import dataset_map, match_detections
from repro.models.detector import STRONG, WEAK, decode_detections
from repro.train.trainer import train_detector


def main() -> None:
    print("== data ==")
    train = ShapesDataset.generate(600, seed=0)
    val = ShapesDataset.generate(200, seed=1)
    pool = ShapesDataset.generate(200, seed=2)

    print("== detectors ==")
    pw, _ = train_detector(WEAK, train, steps=150, log_every=50)
    ps, _ = train_detector(STRONG, train, steps=300, log_every=100)

    weak_val = decode_detections(pw, WEAK, val.images)
    strong_val = decode_detections(ps, STRONG, val.images)
    weak_pool = decode_detections(pw, WEAK, pool.images)
    weak_map = dataset_map(weak_val, val.gts)
    strong_map = dataset_map(strong_val, val.gts)
    print(f"weak mAP={weak_map:.4f}  strong mAP={strong_map:.4f}")

    print("== ORIC rewards (oracle) ==")
    rng = np.random.default_rng(0)
    pairs = match_pairs(weak_val, strong_val, val.gts)
    pool_evals = [match_detections(d, g, (0.5,)) for d, g in zip(weak_pool, pool.gts)]
    oracle = RewardOracle.from_pool(pool_evals, 150, rng)
    rewards = oracle.oric_batch(pairs)

    print("== MORIC estimator ==")
    x = extract_features_batch(weak_val, 8, image_size=64.0)
    cdf = CdfTransform(rewards)
    est = RewardEstimator(x.shape[1], EstimatorConfig(epochs=30))
    est.fit(x, cdf(rewards))
    preds = est.predict(x)

    r = 0.2
    rows = {
        "weak only": cascade_map(pairs, np.zeros(len(pairs), bool)),
        "strong only": cascade_map(pairs, np.ones(len(pairs), bool)),
        "random @20%": cascade_map(pairs, random_offload_mask(len(pairs), r, rng)),
        "ORIC oracle @20%": cascade_map(pairs, topk_offload_mask(rewards, r)),
        "MORIC estimator @20%": cascade_map(pairs, topk_offload_mask(preds, r)),
    }
    print("\npolicy                     mAP")
    for k, v in rows.items():
        print(f"{k:25s} {v:.4f}")


if __name__ == "__main__":
    main()
