# Tier-1 verification + dev conveniences.
#
#   make install      editable install of src/repro (replaces the PYTHONPATH=src hack)
#   make test         tier-1 test suite
#   make bench        benchmark harness (writes artifacts/bench_results.csv)
#   make bench-smoke  artifact-free benches only, incl. the video pipeline
#                     (CI; writes bench_results_smoke.csv); fails first if a
#                     bench_* function is missing from the selection registry
#   make bench-check  just the registry completeness guard
#   make bench-compare  perf gate: diff the two most recent
#                     artifacts/BENCH_<rev>.json, fail on >15% median
#                     regressions (exit 0 when fewer than two artifacts)

PY ?= python

.PHONY: install test bench bench-smoke bench-check bench-compare

install:
	$(PY) -m pip install -e .

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) benchmarks/run.py

bench-check:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) benchmarks/run.py --check

bench-smoke: bench-check
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) benchmarks/run.py --smoke

bench-compare:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) benchmarks/compare.py
