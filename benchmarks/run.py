"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Timing benchmarks measure
the CPU host (the TPU numbers come from the dry-run roofline, see
benchmarks/roofline.py); `derived` carries the table's headline quantity
(speedup, mAP, ms/image, ...).

  bench_fig5_context_cost    ORIC computation cost vs |E| (footnote 2)
  bench_fig5_context_gain    oracle mAP vs |E| (Fig. 5, from artifacts)
  bench_table2_conservatism  reward-sign subsets (Table II, from artifacts)
  bench_fig6_errors          TIDE decomposition (Fig. 6, from artifacts)
  bench_fig9_10_policies     mAP per policy @ r=0.2 (Figs. 9/10)
  bench_table3_pipeline      per-image pipeline latency breakdown (Table III)
  bench_fig13_ratio_latency  detection time & mAP vs offloading ratio (Fig 13)
  bench_incremental_map      APAccumulator incremental vs full recompute
  bench_oric_batch           vectorized oric_batch vs per-image loop
  bench_match_batch          batched device matcher vs per-image Python
  bench_features_batch       batched feature kernel vs per-image Python
  bench_score_pipeline       fused boxes→estimates dispatch vs the composed
                             features→score route (+ per-stage breakdown)
  bench_engine_score         OffloadEngine fused-Pallas batched scoring
  bench_dispatcher_throughput  streaming OffloadRuntime end-to-end frames/s
  bench_netsim_throughput    congested GE-linked fleet frames/s + the
                             value-iteration ref loop vs jitted scan sweep
  bench_video_pipeline       video tracker-scan fps + stale-result propagate
                             vs per-frame rematch
  bench_online_update        closed-loop updates/s (incremental last-layer
                             solve vs jitted mini-refit) + NetworkEstimator
                             per-offload overhead
  bench_fleet_scale          sharded data-plane scoring streams/s at 1 vs N
                             forced host-device shards (subprocess per view)
  bench_mobility_handover    motion-scan rollout throughput + handover-aware
                             vs static-pin effective accuracy at equal budget
  bench_iou                  iou_matrix ref vs Pallas side by side (+ratio)
  bench_kernels              Pallas oracles (jnp path) per-call time

``--smoke`` runs only the artifact-free benches (batched data plane, engine
scoring, dispatcher/netsim/video throughput, kernels) — the CI job.
``--only a,b,...`` filters either set by bench name (comma-separated
substrings, any match; a dev iteration aid: such runs skip the artifact
writes below).  ``--list`` prints the registered benches per set and
exits; ``--check`` verifies every module-level ``bench_*`` function is
registered in the full/smoke selection — CI runs it so a new bench can't
silently drop out of the smoke allowlist.  Every full run also writes
``artifacts/BENCH_<rev>.json`` (per-bench median ms + shapes) so the perf
trajectory is tracked across commits; CI uploads it as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import time
from typing import Callable, Dict, List, Optional

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "../artifacts")
ROWS: List[str] = []
BENCHES: List[Dict] = []


def emit(
    name: str,
    us: float,
    derived: str,
    shape: Optional[Dict] = None,
    stages: Optional[Dict[str, float]] = None,
) -> None:
    """Record one bench row; ``stages`` is an optional per-stage breakdown
    (median ms per stage) carried into ``BENCH_<rev>.json`` so
    ``benchmarks/compare.py`` can name the stage that regressed."""
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    entry = {
        "name": name, "median_ms": round(us / 1e3, 6), "derived": derived,
        "shape": shape or {},
    }
    if stages:
        entry["stages"] = {k: round(v, 6) for k, v in stages.items()}
    BENCHES.append(entry)
    print(row)


def _timeit(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Median per-call time in μs over ``n`` samples."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples)) * 1e6


def _load_results():
    path = os.path.join(ART, "repro_results.json")
    if not os.path.exists(path):
        from repro.experiments.detection_repro import run_all

        return run_all(quick=True)
    with open(path) as f:
        return json.load(f)


def _pipeline_state():
    from repro.experiments.detection_repro import build_pipeline

    return build_pipeline()


def bench_fig5_context_gain() -> None:
    r = _load_results()
    f5 = r["figure5"]
    for ratio_key, cur in f5["curves"].items():
        gain = cur["mean"][-1] - cur["mean"][0]
        emit(
            f"fig5_gain_{ratio_key}", 0.0,
            f"mAP(|E|=0)={cur['mean'][0]:.4f};mAP(|E|max)={cur['mean'][-1]:.4f};delta={gain:+.4f}",
        )


def bench_fig5_context_cost() -> None:
    """ORIC evaluation cost grows with |E| — the footnote-2 trade-off."""
    import numpy as _np

    from repro.core.reward import RewardOracle

    state = _pipeline_state()
    pairs = state.val_pairs[:100]
    rng = _np.random.default_rng(0)
    for E in (0, 100, 400, 800):
        oracle = RewardOracle.from_pool(state.pool_weak_evals, E, rng)
        us = _timeit(lambda: oracle.oric_batch(pairs), n=2)
        emit(f"fig5_cost_E{E}", us / len(pairs), f"us_per_image_at_context_{E}")


def bench_table2_conservatism() -> None:
    r = _load_results()
    for k, v in r["table2"].items():
        emit(
            f"table2_{k}", 0.0,
            f"pct={v['pct']:.1f};weak_map={v['weak_map']:.4f};strong_map={v['strong_map']:.4f}",
        )


def bench_fig6_errors() -> None:
    r = _load_results()
    for policy in ("weak", "strong", "ORI", "ORIC"):
        e = r["figure6"][policy]
        derived = ";".join(
            f"{c}={e[c]:.4f}" for c in ("cls", "loc", "cls_loc", "dupe", "bkg", "miss")
        )
        emit(f"fig6_{policy}", 0.0, derived)


def bench_fig9_10_policies() -> None:
    r = _load_results()
    ratios = r["figure9_10"]["ratios"]
    i = ratios.index(0.2)
    for name, cur in r["figure9_10"]["curves"].items():
        emit(f"fig10_{name}_r0.2", 0.0, f"norm_map={cur['norm'][i]:.1f}%")
    emit("fig10_dcsb", 0.0,
         f"ratio={r['figure9_10']['dcsb']['ratio']:.2f};norm_map={r['figure9_10']['dcsb']['norm']:.1f}%")


def bench_table3_pipeline() -> None:
    """Per-image latency breakdown on this host (Table III analogue); the
    decision stage is the unified OffloadEngine's batched score."""
    import jax

    from repro.api import DetectionBoxFeatures, MLPRewardModel, OffloadEngine
    from repro.core import EstimatorConfig
    from repro.data.shapes import ShapesDataset
    from repro.models.detector import STRONG, WEAK, decode_detections, detector_init

    val = ShapesDataset.generate(64, seed=5)
    pw = detector_init(jax.random.PRNGKey(0), WEAK)
    ps = detector_init(jax.random.PRNGKey(1), STRONG)
    eng = OffloadEngine(
        feature_extractor=DetectionBoxFeatures(num_classes=8, image_size=64.0),
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(128,), epochs=1)),
    )
    eng.fit(features=np.zeros((8, 387), np.float32), rewards=np.zeros(8))

    us_weak = _timeit(lambda: decode_detections(pw, WEAK, val.images), n=2) / len(val)
    dets = decode_detections(pw, WEAK, val.images)
    feats = eng.feature_extractor(dets)
    us_est = _timeit(lambda: eng.score(features=feats), n=5) / len(val)
    us_strong = _timeit(lambda: decode_detections(ps, STRONG, val.images), n=2) / len(val)
    total_off = us_weak + us_est + us_strong
    emit("table3_weak_detector", us_weak, f"share_not_offloaded={us_weak/(us_weak+us_est)*100:.1f}%")
    emit("table3_reward_estimation", us_est, f"share_not_offloaded={us_est/(us_weak+us_est)*100:.1f}%")
    emit("table3_strong_detector", us_strong, f"share_offloaded={us_strong/total_off*100:.1f}%")


def bench_fig13_ratio_latency() -> None:
    """mAP and mean per-image time vs offloading ratio (concavity check)."""
    r = _load_results()
    state = _pipeline_state()
    # host timings for weak/strong passes
    import jax

    from repro.models.detector import STRONG, WEAK, decode_detections
    from repro.train.checkpoint import load_pytree
    from repro.models.detector import detector_init

    curves = r["figure9_10"]["curves"]["est_MORIC"]
    ratios = r["figure9_10"]["ratios"]
    pw = detector_init(jax.random.PRNGKey(0), WEAK)
    ps = detector_init(jax.random.PRNGKey(1), STRONG)
    from repro.data.shapes import ShapesDataset

    val = ShapesDataset.generate(32, seed=6)
    us_w = _timeit(lambda: decode_detections(pw, WEAK, val.images), n=2) / 32
    us_s = _timeit(lambda: decode_detections(ps, STRONG, val.images), n=2) / 32
    for ratio, m in zip(ratios, curves["map"]):
        us = us_w + ratio * us_s
        emit(f"fig13_r{ratio}", us, f"map={m:.4f}")


def bench_incremental_map() -> None:
    """Beyond-paper: incremental context evaluation vs full recompute."""
    from repro.detection.map_engine import APAccumulator, dataset_map, match_detections

    state = _pipeline_state()
    evals = state.pool_weak_evals[:800]
    acc = APAccumulator((0.5,))
    for ev in evals:
        acc.add(ev)
    acc.map()  # warm caches
    probe = state.val_pairs[0].weak
    us_inc = _timeit(lambda: acc.map_with_image(probe), n=20)

    def full():
        a2 = APAccumulator((0.5,))
        for ev in evals:
            a2.add(ev)
        a2.add(probe)
        return a2.map()

    us_full = _timeit(full, n=2)
    emit("incremental_map", us_inc, f"full_recompute_us={us_full:.0f};speedup={us_full/us_inc:.0f}x")


def bench_oric_batch() -> None:
    """Vectorized RewardOracle.oric_batch vs the per-image oric() loop."""
    from repro.core.reward import RewardOracle

    state = _pipeline_state()
    pairs = state.val_pairs[:200]
    rng = np.random.default_rng(0)
    oracle = RewardOracle.from_pool(state.pool_weak_evals, 400, rng)

    def loop():
        return np.array([oracle.oric(im) for im in pairs])

    us_loop = _timeit(loop, n=2)
    us_vec = _timeit(lambda: oracle.oric_batch(pairs), n=2)
    emit(
        "oric_batch_vectorized", us_vec,
        f"loop_us={us_loop:.0f};speedup={us_loop / max(us_vec, 1e-9):.2f}x",
    )


def _synthetic_detections(n_images: int, seed: int, num_classes: int = 8,
                          size: float = 64.0):
    """Artifact-free ragged detection/GT lists with a realistic size mix."""
    from repro.detection.map_engine import Detections, GroundTruth

    rng = np.random.default_rng(seed)
    dets, gts = [], []
    for _ in range(n_images):
        m = int(rng.integers(1, 6))
        b = rng.uniform(0, size - 25, (m, 2))
        wh = rng.uniform(5, 20, (m, 2))
        gts.append(GroundTruth(np.concatenate([b, b + wh], 1),
                               rng.integers(0, num_classes, m)))
        k = int(rng.integers(1, 12))
        b = rng.uniform(0, size - 25, (k, 2))
        wh = rng.uniform(5, 20, (k, 2))
        dets.append(Detections(np.concatenate([b, b + wh], 1),
                               rng.uniform(0.1, 1.0, k),
                               rng.integers(0, num_classes, k)))
    return dets, gts


def bench_match_batch(n_images: int = 512) -> None:
    """Batched device matcher (Pallas IoU + lax greedy scan) vs the
    per-image Python ``match_detections`` loop at pool scale."""
    from repro.detection.batch import DetectionsBatch, GroundTruthBatch, match_batch
    from repro.detection.map_engine import match_detections

    dets, gts = _synthetic_detections(n_images, seed=0)
    db = DetectionsBatch.from_list(dets)
    gb = GroundTruthBatch.from_list(gts)

    def loop():
        return [match_detections(d, g, (0.5,)) for d, g in zip(dets, gts)]

    us_batch = _timeit(lambda: match_batch(db, gb, (0.5,)), n=5)
    us_loop = _timeit(loop, n=2)
    emit(
        f"match_batch_b{n_images}", us_batch / n_images,
        f"loop_us_per_image={us_loop / n_images:.1f}"
        f";speedup={us_loop / max(us_batch, 1e-9):.1f}x",
        shape={"images": n_images, "max_det": int(db.max_boxes),
               "max_gt": int(gb.max_boxes), "thresholds": 1},
    )


def bench_features_batch(n_images: int = 512, num_classes: int = 8) -> None:
    """One jitted feature kernel over a DetectionsBatch vs the per-image
    numpy ``extract_features`` loop."""
    from repro.core.features import extract_features, extract_features_batch
    from repro.detection.batch import DetectionsBatch

    dets, _ = _synthetic_detections(n_images, seed=1)
    db = DetectionsBatch.from_list(dets)

    def loop():
        return np.stack(
            [extract_features(d, num_classes, 25, 64.0) for d in dets]
        )

    us_batch = _timeit(lambda: extract_features_batch(db, num_classes, 25, 64.0), n=5)
    us_loop = _timeit(loop, n=2)
    emit(
        f"features_batch_b{n_images}", us_batch / n_images,
        f"loop_us_per_image={us_loop / n_images:.1f}"
        f";speedup={us_loop / max(us_batch, 1e-9):.1f}x",
        shape={"images": n_images, "max_det": int(db.max_boxes),
               "top_k": 25, "num_classes": num_classes},
    )


def bench_score_pipeline(n_images: int = 512, num_classes: int = 8) -> None:
    """The fused device-resident boxes→estimates dispatch
    (``engine.score_device``) vs the composed ``extract_features_batch →
    engine.score`` route at serve-block scale, with the per-stage
    breakdown (iou / features / mlp / fused, median ms) recorded in the
    bench JSON so ``benchmarks/compare.py`` can name a regressing stage."""
    import jax.numpy as jnp

    from repro.api import DetectionBoxFeatures, OffloadEngine
    from repro.core.features import extract_features_batch
    from repro.detection.batch import DetectionsBatch
    from repro.kernels.iou_matrix import iou_matrix_batch

    dets, _ = _synthetic_detections(n_images, seed=2, num_classes=num_classes)
    db = DetectionsBatch.from_list(dets)
    fx = DetectionBoxFeatures(num_classes=num_classes, top_k=25, image_size=64.0)
    rng = np.random.default_rng(0)
    xcal = extract_features_batch(db, num_classes, 25, 64.0)
    eng = OffloadEngine(feature_extractor=fx, ratio=0.3)
    eng.fit(features=xcal, rewards=rng.uniform(0, 1, n_images))

    def composed():
        return eng.score(features=extract_features_batch(db, num_classes, 25, 64.0))

    def fused():
        return np.asarray(eng.score_device(db))

    # warm both paths and pin the bit-identity contract while we're here
    assert np.array_equal(composed(), fused()), "fused path diverged from composed"
    us_comp = _timeit(composed, n=10)
    us_fused = _timeit(fused, n=10)
    us_feat = _timeit(
        lambda: extract_features_batch(db, num_classes, 25, 64.0), n=10
    )
    us_mlp = _timeit(lambda: eng.score(features=xcal), n=10)
    boxes = jnp.asarray(db.boxes)
    iou_matrix_batch(boxes, boxes).block_until_ready()
    us_iou = _timeit(
        lambda: iou_matrix_batch(boxes, boxes).block_until_ready(), n=10
    )
    speedup = us_comp / max(us_fused, 1e-9)
    emit(
        f"score_pipeline_b{n_images}", us_fused,
        f"composed_us={us_comp:.0f};speedup={speedup:.2f}x"
        f";frames_per_s={n_images / (us_fused / 1e6):.0f}",
        shape={"images": n_images, "max_det": int(db.max_boxes),
               "top_k": 25, "num_classes": num_classes},
        stages={"iou": us_iou / 1e3, "features": us_feat / 1e3,
                "mlp": us_mlp / 1e3, "fused": us_fused / 1e3},
    )


def bench_engine_score() -> None:
    """OffloadEngine batched scoring through the fused Pallas MLP path."""
    from repro.api import MLPRewardModel, OffloadEngine
    from repro.core import EstimatorConfig

    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (1024, 387)).astype(np.float32)
    r = rng.normal(0, 1, 1024)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(128,), epochs=2))
    )
    eng.fit(features=x, rewards=r)
    eng.score(features=x)  # compile
    us = _timeit(lambda: eng.score(features=x), n=5)
    emit("engine_score_b1024", us / 1024, f"us_per_image;fused={eng.reward_model.fused}")


def _smoke_engine(hidden=(128,), n=1024, d=387, seed=0):
    from repro.api import MLPRewardModel, OffloadEngine
    from repro.core import EstimatorConfig

    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    r = rng.normal(0, 1, n)
    eng = OffloadEngine(
        reward_model=MLPRewardModel(config=EstimatorConfig(hidden=hidden, epochs=2))
    )
    eng.fit(features=x, rewards=r)
    return eng, x


def bench_dispatcher_throughput() -> None:
    """Streaming serve loop end to end: session micro-batched scoring through
    the fused Pallas path + multi-edge dispatch, per strategy."""
    from repro.runtime import default_edge_fleet, simulate

    eng, x = _smoke_engine()
    n = len(x)
    for strategy in ("round_robin", "least_loaded", "score_weighted"):
        def run():
            return simulate(
                eng, features=x, edges=default_edge_fleet(3, seed=0),
                strategy=strategy, ratio=0.3, micro_batch=64, seed=0,
            )

        us = _timeit(run, n=2, warmup=1)
        trace = run()
        out = trace.outcome_counts()
        fps = n / (us / 1e6)
        emit(
            f"dispatcher_{strategy}_b{n}", us / n,
            f"frames_per_s={fps:.0f};offloaded={out.get('offloaded', 0)}"
            f";degraded={out.get('degraded', 0)};fused={eng.reward_model.fused}",
        )


def bench_netsim_throughput() -> None:
    """The netsim data plane end to end: queue-aware streaming through a
    congested Gilbert–Elliott 3-edge fleet (frames/s), plus the
    value-iteration solver — per-state Python reference loop vs the jitted
    ``lax.scan`` vmapped over a whole ratio grid."""
    from repro.netsim import (
        quantile_threshold,
        value_iteration_ref,
        value_iteration_sweep,
    )
    from repro.netsim.policy import _estimate_bins
    from repro.runtime import default_congested_fleet, simulate

    eng, x = _smoke_engine(n=512)
    qa = eng.with_policy("queue_aware")
    n = len(x)

    def run():
        return simulate(
            qa, features=x, edges=default_congested_fleet(3, seed=0),
            ratio=0.3, micro_batch=1, seed=0,
        )

    us = _timeit(run, n=2, warmup=1)
    trace = run()
    d = trace.latency_decomposition() or {}
    emit(
        f"netsim_congested_fps_b{n}", us / n,
        f"frames_per_s={n / (us / 1e6):.0f}"
        f";mean_queue_delay={d.get('queue', 0.0):.2f}"
        f";offloaded={trace.outcome_counts().get('offloaded', 0)}",
        shape={"frames": n, "edges": 3},
    )

    cal = np.asarray(eng.calibration_scores)
    ratios = np.linspace(0.05, 0.95, 16)
    e_bins = _estimate_bins(cal, 32)

    def ref_loop():
        return [
            value_iteration_ref(
                e_bins, quantile_threshold(cal, r), max_queue=16, n_sweeps=64
            )
            for r in ratios
        ]

    us_ref = _timeit(ref_loop, n=2)
    kw = dict(max_queue=16, n_sweeps=64, n_bins=32)
    value_iteration_sweep(cal, ratios, **kw)  # compile
    us_jit = _timeit(lambda: value_iteration_sweep(cal, ratios, **kw), n=5)
    emit(
        "netsim_value_iteration_sweep", us_jit,
        f"ref_loop_us={us_ref:.0f};speedup={us_ref / max(us_jit, 1e-9):.1f}x",
        shape={"ratios": len(ratios), "max_queue": 16, "sweeps": 64, "bins": 32},
    )


def bench_iou(n: int = 512, m: int = 512, interpret=None) -> None:
    """iou_matrix jnp reference vs the dispatched kernel, side by side, with
    the dispatch/ref ratio — ``interpret`` threads through to the kernel
    wrapper (None = backend auto: compiled Pallas on TPU/GPU, the jitted
    jnp reference on CPU where the interpreter cannot win; see
    ``repro.kernels.dispatch``).  On the auto reference path the ratio is
    asserted ~1x — the fallback must never reintroduce the old
    pallas-slower-than-ref regression."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.iou_matrix import iou_matrix, iou_matrix_ref, resolve_path

    rng = np.random.default_rng(0)
    a = jnp.asarray(np.concatenate([rng.uniform(0, 50, (n, 2))] * 2, 1), jnp.float32)
    b = jnp.asarray(np.concatenate([rng.uniform(0, 50, (m, 2))] * 2, 1), jnp.float32)
    shape = {"n": n, "m": m}
    f = jax.jit(iou_matrix_ref)
    f(a, b).block_until_ready()
    us_ref = _timeit(lambda: f(a, b).block_until_ready(), n=20)
    emit(f"kernel_iou_ref_{n}x{m}", us_ref, "jnp_oracle", shape=shape)
    mode = resolve_path(interpret)
    iou_matrix(a, b, interpret=interpret).block_until_ready()
    us_pal = _timeit(
        lambda: iou_matrix(a, b, interpret=interpret).block_until_ready(), n=20
    )
    ratio = us_pal / max(us_ref, 1e-9)
    if mode == "reference":
        assert ratio < 1.25, (
            f"auto iou dispatch ({ratio:.2f}x) slower than the jnp reference "
            f"it resolves to — dispatch overhead regression"
        )
    emit(
        f"kernel_iou_pallas_{n}x{m}", us_pal,
        f"mode={mode};pallas_over_ref={ratio:.2f}x",
        shape=shape,
    )


def bench_video_pipeline(n_streams: int = 8, n_frames: int = 64) -> None:
    """The repro.video data plane: jitted tracker-scan throughput over a
    seeded multi-stream clip, and stale-edge-result ``propagate`` (snap to
    live tracks) vs the naive per-frame rematch baseline."""
    from repro.video import (
        STRONG_PROFILE,
        WEAK_PROFILE,
        VideoTracker,
        generate_clip,
        propagate_rematch_ref,
        synthesize_detections,
        track_clip,
    )

    clip = generate_clip(n_streams, n_frames, seed=0)
    weak = synthesize_detections(clip, WEAK_PROFILE, seed=1)
    strong = synthesize_detections(clip, STRONG_PROFILE, seed=2)
    track_clip(weak)  # compile the scan
    frames = n_streams * n_frames
    us = _timeit(lambda: track_clip(weak), n=5)
    emit(
        f"video_tracker_scan_t{n_frames}_b{n_streams}", us / frames,
        f"frames_per_s={frames / (us / 1e6):.0f}",
        shape={"frames": n_frames, "streams": n_streams,
               "max_dets": int(weak.max_boxes)},
    )

    vt = VideoTracker(n_streams)
    for t in range(n_frames):
        vt.update(weak.frame(t))
    t0, t1 = n_frames - 5, n_frames - 1
    edge = strong.det(t0, 0)
    weak_seq = [weak.det(t, 0) for t in range(t0 + 1, t1 + 1)]
    us_prop = _timeit(lambda: vt.propagate(edge, t0, t1, stream=0), n=20)
    us_ref = _timeit(lambda: propagate_rematch_ref(edge, weak_seq), n=20)
    emit(
        "video_propagate_vs_rematch", us_prop,
        f"rematch_us={us_ref:.0f};speedup={us_ref / max(us_prop, 1e-9):.1f}x",
        shape={"staleness": t1 - t0, "edge_dets": len(edge)},
    )


def bench_online_update(n: int = 512, block: int = 8) -> None:
    """The closed-loop update path: incremental last-layer solve vs the
    jitted mini-refit (full updates/s, recalibration included), plus the
    NetworkEstimator record+poll per-offload overhead."""
    from repro.online import AdaptiveEngine, NetworkEstimator, OnlineConfig

    rng = np.random.default_rng(0)
    rewards = rng.uniform(0, 1, n)

    def make(update_every: int, refit_every: int):
        eng, x = _smoke_engine(n=n)
        cfg = OnlineConfig(
            min_observations=1, update_every=update_every,
            refit_every=refit_every, refit_epochs=2,
        )
        ada = AdaptiveEngine(eng, cfg)
        est = np.asarray(eng.score(features=x))
        state = {"i": 0}

        def step():
            i = state["i"] % (n // block)
            sl = slice(i * block, (i + 1) * block)
            ada.observe(x[sl], est[sl], rewards[sl])
            ada.maybe_update()
            state["i"] += 1

        return step

    us_incr = _timeit(make(1, 10**9), n=20, warmup=4)
    emit(
        f"online_incremental_update_b{block}", us_incr,
        f"updates_per_s={1e6 / us_incr:.0f};last_layer_solve",
        shape={"block": block, "features": 387},
    )
    us_refit = _timeit(make(10**9, 1), n=3, warmup=2)
    emit(
        f"online_mini_refit_b{block}", us_refit,
        f"updates_per_s={1e6 / us_refit:.0f}"
        f";incremental_speedup={us_refit / max(us_incr, 1e-9):.1f}x",
        shape={"block": block, "buffer": n},
    )

    net = NetworkEstimator()
    tick = {"t": 0.0}

    def net_step():
        t = tick["t"]
        net.record(t, 2.5, bits=8.0)
        net.poll(t + 5.0)
        tick["t"] = t + 1.0

    us_net = _timeit(net_step, n=200, warmup=10)
    emit(
        "online_netstate_step", us_net,
        f"rtt={net.rtt():.2f};per_offload_overhead",
    )


_FLEET_SCALE_CHILD = """
import sys, time
import numpy as np

n_streams = int(sys.argv[1])
import jax
from repro.api import MLPRewardModel, OffloadEngine
from repro.core import EstimatorConfig
from repro.fleet import FleetPlane

rng = np.random.default_rng(0)
x = rng.normal(0, 1, (1024, 387)).astype(np.float32)
eng = OffloadEngine(
    reward_model=MLPRewardModel(config=EstimatorConfig(hidden=(128,), epochs=2))
)
eng.fit(features=x, rewards=rng.normal(0, 1, 1024))
plane = FleetPlane()
feats = rng.normal(0, 1, (n_streams, 387)).astype(np.float32)
ref = np.asarray(eng.score(features=feats))
out = np.asarray(plane.score(eng, feats))  # also warms the sharded path
assert np.array_equal(ref, out), "sharded scoring diverged"
samples = []
for _ in range(5):
    t0 = time.perf_counter()
    np.asarray(plane.score(eng, feats))
    samples.append(time.perf_counter() - t0)
print("RESULT", len(jax.devices()), float(np.median(samples)) * 1e6)
"""


def bench_fleet_scale(n_streams: int = 2048) -> None:
    """Sharded fleet-plane scoring throughput at 1 vs 4 forced host-device
    shards.  ``XLA_FLAGS`` must be set before jax initializes, so each
    device view runs in its own subprocess (compile excluded — the child
    reports a warmed median); the child also re-checks bit-identity against
    the single-device engine path.  On a single-core host the scaling is
    honestly flat; on a multi-core host the fan-out shows."""
    import sys

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "../src"))
    for shards in (1, 4):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={shards}"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-c", _FLEET_SCALE_CHILD, str(n_streams)],
            capture_output=True, text=True, timeout=540, env=env,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"fleet_scale child (shards={shards}) failed:\n{proc.stderr}"
            )
        line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
        _, n_dev, us = line.split()
        us = float(us)
        emit(
            f"fleet_scale_shards{shards}", us / n_streams,
            f"streams_per_s={n_streams / (us / 1e6):.0f};devices={n_dev}",
            shape={"streams": n_streams, "features": 387, "shards": shards},
        )


def bench_kernels() -> None:
    import jax.numpy as jnp

    from repro.kernels.estimator_mlp.ref import estimator_mlp_ref
    import jax

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (256, 384)), jnp.float32)
    w1 = jnp.asarray(rng.normal(0, 0.1, (384, 128)), jnp.float32)
    b1 = jnp.zeros(128)
    w2 = jnp.asarray(rng.normal(0, 0.1, 128), jnp.float32)
    g = jax.jit(estimator_mlp_ref)
    g(x, w1, b1, w2, 0.0).block_until_ready()
    emit("kernel_estimator_mlp_b256", _timeit(lambda: g(x, w1, b1, w2, 0.0).block_until_ready(), n=50),
         "jnp_oracle;pallas_validated_in_tests")


def bench_obs_overhead() -> None:
    """Observability must cost nothing when it is off.  The streaming
    serve workload runs three ways — ``obs=None`` (the default), a noop
    handle (every plane constructed but disabled), and a fully enabled
    ``Obs`` — interleaved best-of-N so host-speed drift cancels.  The
    disabled handle is asserted within 3% of ``obs=None``; the enabled
    cost is reported, not gated (it buys metrics + spans + profiling)."""
    from repro.obs import Obs
    from repro.runtime import default_edge_fleet, simulate

    eng, x = _smoke_engine(n=512)

    def run(obs):
        return simulate(
            eng, features=x, edges=default_edge_fleet(3, seed=0),
            ratio=0.3, micro_batch=64, seed=0, obs=obs,
        )

    arms = {
        "none": lambda: run(None),
        "noop": lambda: run(Obs.noop()),
        "enabled": lambda: run(Obs()),
    }
    for f in arms.values():
        f()  # warm every path (jit caches, allocator)
    best = {k: float("inf") for k in arms}
    for _ in range(7):
        for k, f in arms.items():
            t0 = time.perf_counter()
            f()
            best[k] = min(best[k], time.perf_counter() - t0)
    over_noop = best["noop"] / best["none"] - 1.0
    over_on = best["enabled"] / best["none"] - 1.0
    assert over_noop < 0.03, (
        f"disabled observability costs {over_noop:+.1%} over obs=None "
        f"(>3%) — a hot path lost its `is None` guard"
    )
    emit(
        f"obs_overhead_b{len(x)}", best["none"] * 1e6 / len(x),
        f"noop={over_noop:+.1%};enabled={over_on:+.1%}"
        f";frames_per_s={len(x) / best['none']:.0f}",
        shape={"frames": len(x), "edges": 3, "reps": 7},
    )


def bench_mobility_handover(n_clients: int = 4, n_steps: int = 120) -> None:
    """The repro.mobility plane: jitted motion-scan rollout throughput
    (client-steps/s vs the pure-Python reference loop), and the headline
    quantity — handover-aware dispatch vs static edge pinning, effective
    accuracy at equal realized offload budget."""
    from repro.mobility import (
        MotionConfig,
        default_mobile_scenario,
        rollout,
        rollout_ref,
        run_mobile_scenario,
    )

    cfg = MotionConfig(area=(1200.0, 600.0), speed=14.0)
    T, n = 256, 64
    rollout(cfg, n, T, seed=0)  # compile the scan
    us_scan = _timeit(lambda: rollout(cfg, n, T, seed=0), n=5)
    us_ref = _timeit(lambda: rollout_ref(cfg, n, T, seed=0), n=2)
    steps = T * n
    emit(
        f"mobility_motion_scan_t{T}_b{n}", us_scan / steps,
        f"client_steps_per_s={steps / (us_scan / 1e6):.0f}"
        f";ref_loop_us={us_ref:.0f}"
        f";speedup={us_ref / max(us_scan, 1e-9):.1f}x",
        shape={"steps": T, "clients": n, "model": cfg.model},
    )

    sc = default_mobile_scenario(n_clients=n_clients, n_steps=n_steps, seed=0)

    def serve(mode):
        return run_mobile_scenario(sc, mode)

    us_serve = _timeit(lambda: serve("handover"), n=2, warmup=1)
    handover = serve("handover")
    static = serve("static")
    frames = n_clients * n_steps
    gain = (
        handover.mean_effective_accuracy() - static.mean_effective_accuracy()
    )
    emit(
        f"mobility_handover_b{frames}", us_serve / frames,
        f"frames_per_s={frames / (us_serve / 1e6):.0f}"
        f";eff_acc_gain={gain:+.4f}"
        f";handover={handover.mean_effective_accuracy():.4f}"
        f";static={static.mean_effective_accuracy():.4f}"
        f";handovers={handover.n_handovers()}",
        shape={"clients": n_clients, "steps": n_steps,
               "stations": len(sc.coverage.stations)},
    )


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:
        return "dev"


def _write_bench_json(smoke: bool) -> str:
    import jax

    rev = _git_rev()
    path = os.path.join(ART, f"BENCH_{rev}.json")
    payload = {
        "rev": rev,
        "smoke": smoke,
        "backend": jax.default_backend(),
        "benches": BENCHES,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def _write_obs_artifacts() -> List[str]:
    """One observed run of the congested-fleet scenario per bench sweep:
    exports ``artifacts/metrics_<rev>.json`` (the full registry, retrace
    counters included) and ``artifacts/trace_<rev>.json`` (Chrome trace —
    open in Perfetto) so every CI bench run leaves an inspectable picture
    of the serve stack, not just medians."""
    from repro.obs import Obs
    from repro.runtime import default_congested_fleet, simulate

    eng, x = _smoke_engine(n=256)
    obs = Obs()
    simulate(
        eng, features=x, edges=default_congested_fleet(3, seed=0),
        ratio=0.3, micro_batch=16, seed=0, obs=obs,
    )
    rev = _git_rev()
    metrics_path = os.path.join(ART, f"metrics_{rev}.json")
    trace_path = os.path.join(ART, f"trace_{rev}.json")
    obs.metrics.export_json(metrics_path)
    obs.tracer.export(trace_path)
    return [metrics_path, trace_path]


def registered_benches(interpret=None):
    """The selection registry: (full-run-only, smoke/artifact-free) bench
    lists.  Every module-level ``bench_*`` function MUST appear in exactly
    one of them (``--check`` / tests enforce it) — a new bench left out
    would silently never run in CI."""
    full = [
        ("fig5_context_gain", bench_fig5_context_gain),
        ("fig5_context_cost", bench_fig5_context_cost),
        ("table2_conservatism", bench_table2_conservatism),
        ("fig6_errors", bench_fig6_errors),
        ("fig9_10_policies", bench_fig9_10_policies),
        ("table3_pipeline", bench_table3_pipeline),
        ("fig13_ratio_latency", bench_fig13_ratio_latency),
        ("incremental_map", bench_incremental_map),
        ("oric_batch", bench_oric_batch),
    ]
    smoke = [
        ("match_batch", bench_match_batch),
        ("features_batch", bench_features_batch),
        ("score_pipeline", bench_score_pipeline),
        ("engine_score", bench_engine_score),
        ("dispatcher_throughput", bench_dispatcher_throughput),
        ("netsim_throughput", bench_netsim_throughput),
        ("video_pipeline", bench_video_pipeline),
        ("online_update", bench_online_update),
        ("fleet_scale", bench_fleet_scale),
        ("mobility_handover", bench_mobility_handover),
        ("iou", lambda: bench_iou(interpret=interpret)),
        ("kernels", bench_kernels),
        ("obs_overhead", bench_obs_overhead),
    ]
    return full, smoke


def check_registry() -> List[str]:
    """Module-level ``bench_*`` functions missing from the selection
    registry (a registry name is its function name minus the prefix)."""
    full, smoke = registered_benches()
    registered = {name for name, _ in full + smoke}
    return sorted(
        name
        for name, fn in globals().items()
        if name.startswith("bench_")
        and callable(fn)
        and name[len("bench_"):] not in registered
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="artifact-free benches only (batched data plane, engine score, "
             "dispatcher, netsim, video, kernels)",
    )
    ap.add_argument(
        "--interpret", choices=("auto", "true", "false"), default="auto",
        help="Pallas execution mode for bench_iou (auto = backend default)",
    )
    ap.add_argument(
        "--only", default=None, metavar="NAMES",
        help="run only benches whose name contains any of the "
             "comma-separated substrings (applied after --smoke selection)",
    )
    ap.add_argument(
        "--list", action="store_true",
        help="print the registered benches per selection set and exit",
    )
    ap.add_argument(
        "--check", action="store_true",
        help="fail if any bench_* function is missing from the selection "
             "registry (the CI guard)",
    )
    args = ap.parse_args(argv)
    interpret = {"auto": None, "true": True, "false": False}[args.interpret]
    full, smoke = registered_benches(interpret)
    if args.check:
        missing = check_registry()
        if missing:
            raise SystemExit(
                f"benches missing from the registry (add them to "
                f"registered_benches): {missing}"
            )
        print(f"# registry complete: {len(full)} full + {len(smoke)} smoke benches")
        return
    if args.list:
        for label, benches in (("full-only", full), ("smoke", smoke)):
            for name, _ in benches:
                print(f"{label},{name}")
        return
    selected = ([] if args.smoke else full) + smoke
    if args.only is not None:
        needles = [s for s in args.only.split(",") if s]
        selected = [
            (name, fn)
            for name, fn in selected
            if any(s in name for s in needles)
        ]
        if not selected:
            ap.error(f"--only {args.only!r} matches no bench")
    print("name,us_per_call,derived")
    os.makedirs(ART, exist_ok=True)
    for _, fn in selected:
        fn()
    if args.only is not None:
        # a filtered run is a dev iteration: never overwrite the canonical
        # full-run artifacts with a subset
        print("# --only run: artifacts not written")
        return
    out = os.path.join(ART, "bench_results_smoke.csv" if args.smoke else "bench_results.csv")
    with open(out, "w") as f:
        f.write("name,us_per_call,derived\n" + "\n".join(ROWS) + "\n")
    print(f"# wrote {out}")
    print(f"# wrote {_write_bench_json(args.smoke)}")
    for p in _write_obs_artifacts():
        print(f"# wrote {p}")


if __name__ == "__main__":
    main()
