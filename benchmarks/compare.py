"""Perf-regression gate over the benchmark artifact trail.

``benchmarks/run.py`` writes ``artifacts/BENCH_<rev>.json`` on every full
(or ``--smoke``) run.  This script diffs the two most recent artifacts (by
mtime) bench-by-bench and exits nonzero when any shared bench's median
regressed by more than the threshold (default 15%) — the CI perf gate.

Rules of the comparison:

- only benches present in BOTH artifacts are compared (smoke vs full runs
  intersect cleanly; renamed/new/retired benches never trip the gate);
- benches whose median is below ``--min-ms`` in either run are skipped —
  sub-noise timings (and the derived-only rows that report ``0.0``)
  whipsaw on shared CI hosts and would make the gate cry wolf;
- fewer than two artifacts is a clean exit 0: the first run of a fresh
  checkout (or a wiped artifacts dir) has nothing to compare against;
- an unreadable/malformed artifact, or two artifacts sharing no bench
  names at all, is also a clean exit 0 with a one-line explanation — the
  gate reports *perf* regressions, never masquerades a broken artifact
  trail as one.

Usage::

    python benchmarks/compare.py [--threshold 0.15] [--min-ms 0.05]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

ART = os.path.join(os.path.dirname(__file__), "../artifacts")


class ArtifactError(Exception):
    """A BENCH_*.json that cannot be compared (unreadable / malformed)."""


def latest_artifacts(art_dir: str, n: int = 2) -> List[str]:
    """The ``n`` most recent BENCH_*.json paths, oldest first."""
    paths = glob.glob(os.path.join(art_dir, "BENCH_*.json"))
    paths.sort(key=os.path.getmtime)
    return paths[-n:]


def load_medians(
    path: str,
) -> Tuple[str, Dict[str, float], Dict[str, Dict[str, float]]]:
    """(rev, name→median_ms, name→stage→median_ms) for one artifact; the
    stage map only has entries for benches that emitted a breakdown.
    Raises :class:`ArtifactError` with a readable message when the file
    is unreadable, not JSON, or missing the bench fields."""
    try:
        with open(path) as f:
            payload = json.load(f)
        benches = payload.get("benches", [])
        medians = {b["name"]: float(b["median_ms"]) for b in benches}
        stages = {
            b["name"]: {k: float(v) for k, v in b["stages"].items()}
            for b in benches
            if b.get("stages")
        }
    except OSError as e:
        raise ArtifactError(f"cannot read {path!r}: {e}") from e
    except json.JSONDecodeError as e:
        raise ArtifactError(f"{path!r} is not valid JSON: {e}") from e
    except (KeyError, TypeError, ValueError, AttributeError) as e:
        raise ArtifactError(
            f"{path!r} is not a BENCH artifact (expected "
            f'{{"benches": [{{"name", "median_ms", ...}}]}}): {e!r}'
        ) from e
    return payload.get("rev", os.path.basename(path)), medians, stages


def worst_stage(
    prev: Dict[str, float], cur: Dict[str, float]
) -> Optional[str]:
    """``"stage (+delta%)"`` for the most-regressed stage shared by two
    per-stage breakdowns, or ``None`` when they share nothing usable."""
    worst: Optional[Tuple[str, float]] = None
    for name in sorted(set(prev) & set(cur)):
        p, c = prev[name], cur[name]
        if p <= 0.0:
            continue
        delta = (c - p) / p
        if worst is None or delta > worst[1]:
            worst = (name, delta)
    return None if worst is None else f"{worst[0]} ({worst[1]:+.1%})"


def compare(
    prev: Dict[str, float],
    cur: Dict[str, float],
    threshold: float,
    min_ms: float,
    prev_stages: Optional[Dict[str, Dict[str, float]]] = None,
    cur_stages: Optional[Dict[str, Dict[str, float]]] = None,
) -> Tuple[List[str], List[str], int]:
    """(regressions, improvements, n_compared) between two median maps.
    When both runs carry a per-stage breakdown for a regressed bench, the
    regression line names the stage that slowed down most."""
    regressions: List[str] = []
    improvements: List[str] = []
    compared = 0
    for name in sorted(set(prev) & set(cur)):
        p, c = prev[name], cur[name]
        if p < min_ms or c < min_ms:
            continue
        compared += 1
        delta = (c - p) / p
        line = f"{name}: {p:.3f}ms -> {c:.3f}ms ({delta:+.1%})"
        if delta > threshold:
            if prev_stages and cur_stages:
                culprit = worst_stage(
                    prev_stages.get(name, {}), cur_stages.get(name, {})
                )
                if culprit is not None:
                    line += f" — worst stage: {culprit}"
            regressions.append(line)
        elif delta < -threshold:
            improvements.append(line)
    return regressions, improvements, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--threshold", type=float, default=0.15,
        help="relative median slowdown that counts as a regression",
    )
    ap.add_argument(
        "--min-ms", type=float, default=0.05,
        help="skip benches with medians below this in either run",
    )
    ap.add_argument("--art-dir", default=ART)
    args = ap.parse_args(argv)

    paths = latest_artifacts(args.art_dir)
    if len(paths) < 2:
        print(
            f"# {len(paths)} benchmark artifact(s) in {args.art_dir!r} — "
            "need two to compare, nothing to gate"
        )
        return 0
    try:
        (prev_rev, prev, prev_stages), (cur_rev, cur, cur_stages) = (
            load_medians(p) for p in paths
        )
    except ArtifactError as e:
        print(f"# skipping perf gate — {e}")
        return 0
    if not set(prev) & set(cur):
        print(
            f"# {prev_rev} and {cur_rev} share no bench names — "
            "nothing to gate (smoke/full sets diverged or a run was empty)"
        )
        return 0
    regressions, improvements, compared = compare(
        prev, cur, args.threshold, args.min_ms, prev_stages, cur_stages
    )
    print(
        f"# comparing {prev_rev} -> {cur_rev}: {compared} benches above "
        f"{args.min_ms}ms floor, threshold {args.threshold:.0%}"
    )
    for line in improvements:
        print(f"improved  {line}")
    for line in regressions:
        print(f"REGRESSED {line}")
    if regressions:
        print(f"# {len(regressions)} regression(s) beyond {args.threshold:.0%}")
        return 1
    print("# no regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
