"""Aggregate dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits a
markdown table per mesh with the three roofline terms, the dominant
bottleneck, and the MODEL_FLOPS/HLO_FLOPS usefulness ratio.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

ARTIFACTS = os.environ.get(
    "ROOFLINE_DIR", os.path.join(os.path.dirname(__file__), "../artifacts/dryrun")
)

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_all() -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(mesh: str = "16x16") -> str:
    rows = [r for r in load_all() if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    lines = [
        "| arch | shape | compute | memory | collective | dominant | model/HLO flops |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        ratio = r.get("model_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s','')} | "
            f"{ratio:.2f} |" if ratio else
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s','')} | n/a |"
        )
    return "\n".join(lines)


def summary() -> Dict:
    """Worst roofline fraction + most collective-bound pairs (hillclimb
    candidate selection)."""
    rows = [r for r in load_all() if r["mesh"] == "16x16"]
    def frac(r):
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        return rf["compute_s"] / total if total else 0.0
    by_frac = sorted(rows, key=frac)
    by_coll = sorted(
        rows,
        key=lambda r: -(r["roofline"]["collective_s"] /
                        max(r["roofline"]["compute_s"] + r["roofline"]["memory_s"] +
                            r["roofline"]["collective_s"], 1e-12)),
    )
    return {
        "worst_compute_fraction": [(r["arch"], r["shape"], round(frac(r), 3)) for r in by_frac[:5]],
        "most_collective_bound": [
            (r["arch"], r["shape"],
             round(r["roofline"]["collective_s"] /
                   max(r["roofline"]["compute_s"] + r["roofline"]["memory_s"] +
                       r["roofline"]["collective_s"], 1e-12), 3))
            for r in by_coll[:5]
        ],
    }


if __name__ == "__main__":
    print("## single-pod (16x16)\n")
    print(table("16x16"))
    print("\n## multi-pod (2x16x16)\n")
    print(table("2x16x16"))
    print("\n## hillclimb candidates\n")
    print(json.dumps(summary(), indent=2))
