"""End-to-end experiment drivers reproducing the paper's tables/figures."""
