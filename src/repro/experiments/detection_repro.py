"""The faithful end-to-end reproduction pipeline (paper §IV–§V).

Stages (all cached under ``artifacts/``):
  1. generate procedural train/val/pool splits,
  2. train weak + strong detectors,
  3. run both detectors over val + pool,
  4. compute ORI / ORIC oracles, the MORIC transform, train estimators,
  5. evaluate every policy (oracle + estimated + baselines) across ratios.

Each paper figure/table has a ``figure_*``/``table_*`` function reading from
the cached pipeline state; ``benchmarks/`` and ``examples/`` call into these.
"""
from __future__ import annotations

import json
import os
import pickle
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import DetectionBoxFeatures, MLPRewardModel, OffloadEngine, make_policy
from repro.core import (
    AdaptiveFeedingSVM,
    CdfTransform,
    EstimatorConfig,
    MatchedImage,
    RewardOracle,
    cascade_map,
    dcsb_signals,
    extract_features_batch,
    fit_dcsb,
    match_pairs_batched,
    ori_batch,
    random_offload_mask,
    topk_offload_mask,
)
from repro.data.shapes import NUM_CLASSES, ShapesDataset
from repro.detection.batch import (
    DetectionsBatch,
    GroundTruthBatch,
    match_batch,
    to_image_evals,
)
from repro.detection.map_engine import Detections, dataset_map
from repro.detection.tide import tide_errors
from repro.models.detector import STRONG, WEAK, decode_detections
from repro.train.checkpoint import load_pytree, save_pytree
from repro.train.trainer import train_detector

ARTIFACTS = os.environ.get("REPRO_ARTIFACTS", os.path.join(os.path.dirname(__file__), "../../../artifacts"))


@dataclass
class PipelineState:
    """Everything downstream experiments need, detector-free."""

    val_pairs: List[MatchedImage]
    pool_weak_evals: list
    weak_dets_val: List[Detections]
    strong_dets_val: List[Detections]
    val_gts: list
    weak_map: float
    strong_map: float
    features_val: np.ndarray
    image_size: float


def _cache_path(name: str) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    return os.path.join(ARTIFACTS, name)


def build_pipeline(
    n_train: int = 3000,
    n_val: int = 2000,
    n_pool: int = 1200,
    steps_weak: int = 500,
    steps_strong: int = 900,
    seed: int = 0,
    force: bool = False,
    verbose: bool = True,
) -> PipelineState:
    cache = _cache_path("pipeline_state.pkl")
    if os.path.exists(cache) and not force:
        with open(cache, "rb") as f:
            return pickle.load(f)

    if verbose:
        print("[pipeline] generating data ...")
    train = ShapesDataset.generate(n_train, seed=seed)
    val = ShapesDataset.generate(n_val, seed=seed + 1)
    pool = ShapesDataset.generate(n_pool, seed=seed + 2)

    params: Dict[str, dict] = {}
    for cfg, steps in ((WEAK, steps_weak), (STRONG, steps_strong)):
        ckpt = _cache_path(f"detector_{cfg.name}.npz")
        if verbose:
            print(f"[pipeline] training {cfg.name} detector ({steps} steps) ...")
        p, _ = train_detector(cfg, train, steps=steps, seed=seed + 10)
        save_pytree(ckpt, p)
        params[cfg.name] = p

    if verbose:
        print("[pipeline] running inference on val + pool ...")
    weak_val = decode_detections(params["weak"], WEAK, val.images)
    strong_val = decode_detections(params["strong"], STRONG, val.images)
    weak_pool = decode_detections(params["weak"], WEAK, pool.images)

    # everything downstream runs through the batched data plane: pad once,
    # match/featurize on device, convert to ImageEvals for the AP machinery
    weak_val_batch = DetectionsBatch.from_list(weak_val)
    val_pairs = match_pairs_batched(weak_val_batch, strong_val, val.gts)
    pool_batch = DetectionsBatch.from_list(weak_pool)
    pool_gt_batch = GroundTruthBatch.from_list(pool.gts)
    pool_weak_evals = to_image_evals(
        pool_batch, pool_gt_batch, match_batch(pool_batch, pool_gt_batch, (0.5,))
    )
    weak_map = dataset_map(weak_val, val.gts)
    strong_map = dataset_map(strong_val, val.gts)
    if verbose:
        print(f"[pipeline] weak mAP={weak_map:.4f} strong mAP={strong_map:.4f}")
    feats = extract_features_batch(
        weak_val_batch, NUM_CLASSES, image_size=float(WEAK.image_size)
    )
    state = PipelineState(
        val_pairs=val_pairs,
        pool_weak_evals=pool_weak_evals,
        weak_dets_val=weak_val,
        strong_dets_val=strong_val,
        val_gts=val.gts,
        weak_map=weak_map,
        strong_map=strong_map,
        features_val=feats,
        image_size=float(WEAK.image_size),
    )
    with open(cache, "wb") as f:
        pickle.dump(state, f)
    return state


def build_engine(
    state: PipelineState,
    context_size: int = 800,
    ratio: float = 0.2,
    seed: int = 0,
    epochs: int = 40,
    hidden: Tuple[int, ...] = (128,),
) -> "OffloadEngine":
    """The deployable artifact: ORIC rewards on the calibration split → one
    fitted ``OffloadEngine`` over weak-detector box features.  The default
    single hidden layer makes batched scoring take the fused Pallas
    ``estimator_mlp`` path; ``engine.save(path)`` ships the whole stack."""
    rng = np.random.default_rng(seed)
    oracle = RewardOracle.from_pool(state.pool_weak_evals, context_size, rng)
    rewards = oracle.oric_batch(state.val_pairs)
    engine = OffloadEngine(
        feature_extractor=DetectionBoxFeatures(
            num_classes=NUM_CLASSES, image_size=state.image_size
        ),
        reward_model=MLPRewardModel(
            config=EstimatorConfig(hidden=tuple(hidden), epochs=epochs, seed=seed)
        ),
        ratio=ratio,
    )
    engine.fit(state.weak_dets_val, rewards)
    return engine


# ---------------------------------------------------------------------------
# Paper figure/table analogues
# ---------------------------------------------------------------------------

def figure5_context_size(
    state: PipelineState,
    context_sizes: Sequence[int] = (0, 25, 50, 100, 200, 400, 800),
    ratios: Sequence[float] = (0.1, 0.2, 0.5),
    n_draws: int = 5,
    seed: int = 0,
) -> Dict:
    """Oracle mAP vs |E| for ORIC (|E|=0 == ORI), per offloading ratio."""
    rng = np.random.default_rng(seed)
    out: Dict = {"context_sizes": list(context_sizes), "ratios": list(ratios),
                 "weak_map": state.weak_map, "strong_map": state.strong_map,
                 "curves": {}}
    # rewards once per (E, draw); reuse across ratios
    rewards_by_size: Dict[int, List[np.ndarray]] = {}
    for E in context_sizes:
        draws = 1 if E == 0 else n_draws
        rewards_by_size[E] = [
            RewardOracle.from_pool(state.pool_weak_evals, E, rng).oric_batch(
                state.val_pairs
            )
            for _ in range(draws)
        ]
    for r in ratios:
        means, cis = [], []
        for E in context_sizes:
            vals = np.array(
                [
                    cascade_map(state.val_pairs, topk_offload_mask(rw, r))
                    for rw in rewards_by_size[E]
                ]
            )
            means.append(float(vals.mean()))
            cis.append(float(1.96 * vals.std() / np.sqrt(max(len(vals), 1))))
        out["curves"][f"r={r}"] = {"mean": means, "ci95": cis}
    return out


def table2_conservatism(
    state: PipelineState, context_size: int = 800, seed: int = 0
) -> Dict:
    """Weak/strong mAP on reward<=0 vs reward>0 subsets, ORIC vs ORI."""
    rng = np.random.default_rng(seed)
    oracle = RewardOracle.from_pool(state.pool_weak_evals, context_size, rng)
    oric = oracle.oric_batch(state.val_pairs)
    ori_r = ori_batch(state.val_pairs)
    out: Dict = {}
    n = len(state.val_pairs)
    for name, rewards in (("ORIC", oric), ("ORI", ori_r)):
        for label, mask in (
            ("nonpos", rewards <= 0),
            ("pos", rewards > 0),
        ):
            idx = np.where(mask)[0]
            sub = [state.val_pairs[i] for i in idx]
            out[f"{name}_{label}"] = {
                "pct": float(mask.mean() * 100),
                "weak_map": cascade_map(sub, np.zeros(len(sub), bool)) if len(sub) else float("nan"),
                "strong_map": cascade_map(sub, np.ones(len(sub), bool)) if len(sub) else float("nan"),
            }
    return out


def figure6_error_types(
    state: PipelineState, ratio: float = 0.2, context_size: int = 800, seed: int = 0
) -> Dict:
    """TIDE 6-category error decomposition of weak/strong/ORI/ORIC cascades."""
    rng = np.random.default_rng(seed)
    oracle = RewardOracle.from_pool(state.pool_weak_evals, context_size, rng)
    oric = oracle.oric_batch(state.val_pairs)
    ori_r = ori_batch(state.val_pairs)
    configs = {
        "weak": np.zeros(len(state.val_pairs), bool),
        "strong": np.ones(len(state.val_pairs), bool),
        "ORI": topk_offload_mask(ori_r, ratio),
        "ORIC": topk_offload_mask(oric, ratio),
    }
    out: Dict = {}
    for name, mask in configs.items():
        dets = [
            state.strong_dets_val[i] if mask[i] else state.weak_dets_val[i]
            for i in range(len(mask))
        ]
        out[name] = tide_errors(dets, state.val_gts)
    return out


def figure8_reward_cdf(state: PipelineState, context_size: int = 800, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    oracle = RewardOracle.from_pool(state.pool_weak_evals, context_size, rng)
    oric = oracle.oric_batch(state.val_pairs)
    ori_r = ori_batch(state.val_pairs)
    qs = np.linspace(0, 1, 21)
    return {
        "oric_quantiles": np.quantile(oric, qs).tolist(),
        "ori_quantiles": np.quantile(ori_r, qs).tolist(),
        "oric_frac_zero": float(np.mean(np.abs(oric) < 1e-9)),
        "ori_frac_zero": float(np.mean(np.abs(ori_r) < 1e-9)),
    }


@dataclass
class EstimatorBundle:
    """Estimators trained with 5-fold CV; predictions are out-of-fold."""

    preds: Dict[str, np.ndarray]
    rewards: Dict[str, np.ndarray]


def train_estimators(
    state: PipelineState,
    context_size: int = 800,
    seed: int = 0,
    folds: int = 5,
    epochs: int = 40,
) -> EstimatorBundle:
    """Out-of-fold predictions for MORIC / vanilla-ORIC / ORI / MORI."""
    rng = np.random.default_rng(seed)
    oracle = RewardOracle.from_pool(state.pool_weak_evals, context_size, rng)
    oric = oracle.oric_batch(state.val_pairs)
    ori_r = ori_batch(state.val_pairs)
    x = state.features_val
    n = x.shape[0]
    fold_ix = np.arange(n) % folds
    rng.shuffle(fold_ix)

    def oof(targets: np.ndarray, weighted: bool, sigmoid: bool, rank: bool) -> np.ndarray:
        preds = np.zeros(n)
        for f in range(folds):
            tr = fold_ix != f
            te = ~tr
            engine = OffloadEngine(
                reward_model=MLPRewardModel(
                    config=EstimatorConfig(weighted=weighted, sigmoid_out=sigmoid,
                                           epochs=epochs, seed=seed + f)
                ),
                transform="cdf" if rank else None,
            )
            engine.fit(features=x[tr], rewards=targets[tr])
            preds[te] = engine.score(features=x[te])
        return preds

    preds = {
        "MORIC": oof(oric, weighted=True, sigmoid=True, rank=True),
        "ORIC_vanilla": oof(oric, weighted=False, sigmoid=False, rank=False),
        "ORI": oof(ori_r, weighted=False, sigmoid=False, rank=False),
        "MORI": oof(ori_r, weighted=True, sigmoid=True, rank=True),
    }
    return EstimatorBundle(preds=preds, rewards={"ORIC": oric, "ORI": ori_r})


def evaluate_policies(
    state: PipelineState,
    bundle: EstimatorBundle,
    ratios: Sequence[float] = (0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.7, 1.0),
    seed: int = 0,
) -> Dict:
    """mAP-vs-ratio for every policy (Fig. 9/10 analogue).  mAPs are also
    reported normalized: 0% = weak alone, 100% = strong alone."""
    rng = np.random.default_rng(seed)
    n = len(state.val_pairs)
    out: Dict = {
        "ratios": list(ratios),
        "weak_map": state.weak_map,
        "strong_map": state.strong_map,
        "curves": {},
    }

    def norm(m: float) -> float:
        return 100.0 * (m - state.weak_map) / max(state.strong_map - state.weak_map, 1e-9)

    policies: Dict[str, np.ndarray] = {
        "oracle_ORIC": bundle.rewards["ORIC"],
        "oracle_ORI": bundle.rewards["ORI"],
        **{f"est_{k}": v for k, v in bundle.preds.items()},
    }
    for name, scores in policies.items():
        maps = [cascade_map(state.val_pairs, topk_offload_mask(scores, r)) for r in ratios]
        out["curves"][name] = {"map": maps, "norm": [norm(m) for m in maps]}
    # random baseline (mean over 5 draws)
    maps = []
    for r in ratios:
        vals = [
            cascade_map(state.val_pairs, random_offload_mask(n, r, rng))
            for _ in range(5)
        ]
        maps.append(float(np.mean(vals)))
    out["curves"]["random"] = {"map": maps, "norm": [norm(m) for m in maps]}

    # Adaptive Feeding: one SVM per c_plus; ratio is whatever the SVM yields
    af_pts = []
    difficult = bundle.rewards["ORI"] > 0
    for c_plus in (2.0 ** e for e in range(-3, 3)):
        svm = AdaptiveFeedingSVM(c_plus=float(c_plus), epochs=60).fit(
            state.features_val, difficult
        )
        mask = svm.predict(state.features_val)
        af_pts.append(
            {"c_plus": float(c_plus), "ratio": float(mask.mean()),
             "map": cascade_map(state.val_pairs, mask)}
        )
    for p in af_pts:
        p["norm"] = norm(p["map"])
    out["adaptive_feeding"] = af_pts

    # DCSB: rule search fixes its own ratio
    rule = fit_dcsb(state.weak_dets_val, state.strong_dets_val)
    counts, areas = dcsb_signals(state.weak_dets_val)
    mask = rule.predict_signals(counts, areas)
    out["dcsb"] = {
        "ratio": float(mask.mean()),
        "map": cascade_map(state.val_pairs, mask),
        "norm": norm(cascade_map(state.val_pairs, mask)),
        "thr_count": rule.thr_count,
        "thr_area": rule.thr_area,
    }
    return out


def figure7_input_study(
    state: PipelineState,
    context_size: int = 800,
    ratios: Sequence[float] = (0.1, 0.2, 0.3, 0.5),
    seed: int = 0,
    epochs: int = 30,
    n_val: int = 2000,
) -> Dict:
    """§V-A input study: estimate MORIC from the weak detector's OUTPUT
    (MLP on box features) vs from its backbone FEATURE MAPS (CNN) — the
    early-exit integration point.  Paper finding: limited impact.  Both
    estimators run behind the OffloadEngine reward-model interface."""
    import jax
    import jax.numpy as jnp

    from repro.api import CNNRewardModel
    from repro.data.shapes import ShapesDataset
    from repro.models.detector import WEAK, detector_forward
    from repro.train.checkpoint import load_pytree
    from repro.models.detector import detector_init

    rng = np.random.default_rng(seed)
    oracle = RewardOracle.from_pool(state.pool_weak_evals, context_size, rng)
    oric = oracle.oric_batch(state.val_pairs)
    cdf = CdfTransform(oric)
    y = cdf(oric)

    # recompute backbone feature maps for the val split (deterministic seed)
    val = ShapesDataset.generate(n_val, seed=1)
    wparams = detector_init(jax.random.PRNGKey(0), WEAK)
    wparams = load_pytree(_cache_path("detector_weak.npz"), wparams)
    feats = []
    for s in range(0, n_val, 256):
        _, _, _, fm = detector_forward(wparams, WEAK, jnp.asarray(val.images[s : s + 256]))
        feats.append(np.asarray(fm))
    fmaps = np.concatenate(feats)  # (N, G, G, C)

    # 2-fold CV: both input variants behind the engine's RewardModel
    # interface (targets are already rank-transformed, so transform=None)
    n = len(y)
    fold = np.arange(n) % 2
    rng.shuffle(fold)
    preds_cnn = np.zeros(n)
    preds_mlp = np.zeros(n)
    for f in range(2):
        tr, te = fold != f, fold == f
        cnn_engine = OffloadEngine(
            reward_model=CNNRewardModel(epochs=epochs, seed=seed + f),
            transform=None,
        )
        cnn_engine.fit(features=fmaps[tr], rewards=y[tr])
        preds_cnn[te] = cnn_engine.score(features=fmaps[te])

        mlp_engine = OffloadEngine(
            reward_model=MLPRewardModel(config=EstimatorConfig(epochs=epochs)),
            transform=None,
        )
        mlp_engine.fit(features=state.features_val[tr], rewards=y[tr])
        preds_mlp[te] = mlp_engine.score(features=state.features_val[te])

    out: Dict = {"ratios": list(ratios), "curves": {}}
    for name, preds in (("output_mlp", preds_mlp), ("featmap_cnn", preds_cnn)):
        out["curves"][name] = [
            cascade_map(state.val_pairs, topk_offload_mask(preds, r)) for r in ratios
        ]
    return out


def token_bucket_study(
    state: PipelineState,
    bundle: "EstimatorBundle",
    rate: float = 0.2,
    depth: float = 8.0,
    seed: int = 0,
) -> Dict:
    """Dynamic-budget serving ([23]-style): a token bucket enforcing a hard
    offload rate on a streaming trace vs the static threshold policy.  Both
    policies come from the OffloadEngine registry, calibrated on the same
    estimate distribution."""
    rng = np.random.default_rng(seed)
    est = bundle.preds["MORIC"]
    order = rng.permutation(len(est))  # arrival order
    # static threshold at the same target ratio
    pol = make_policy("threshold", est, ratio=rate)
    static_mask = np.zeros(len(est), bool)
    static_mask[order] = pol.decide_batch(est[order])
    tb = make_policy("token_bucket", est, ratio=rate, depth=depth)
    tb_mask = np.zeros(len(est), bool)
    tb_mask[order] = tb.decide_batch(est[order])
    return {
        "target_rate": rate,
        "static": {"ratio": float(static_mask.mean()),
                   "map": cascade_map(state.val_pairs, static_mask)},
        "token_bucket": {"ratio": float(tb_mask.mean()),
                         "map": cascade_map(state.val_pairs, tb_mask),
                         "max_burst": depth},
    }


def streaming_multi_edge_study(
    state: PipelineState,
    engine: Optional["OffloadEngine"] = None,
    *,
    context_size: int = 800,
    ratio: float = 0.2,
    n_edges: int = 3,
    strategy: str = "least_loaded",
    micro_batch: int = 16,
    epochs: int = 40,
    seed: int = 0,
) -> Dict:
    """Beyond-batch serving: the paper's deployment picture as a stream.

    Val images arrive one at a time (seeded arrival order) at one weak
    device; an :class:`repro.runtime.OffloadSession` scores micro-batches
    through the engine and decides in arrival order; accepted offloads are
    dispatched across ``n_edges`` heterogeneous rate-limited edges.  Frames
    the saturated fleet degrades fall back to the weak result, so the
    realized cascade mAP prices in serve-time constraints that the one-shot
    ``engine.decide`` evaluation cannot see."""
    from repro.runtime import default_edge_fleet, simulate

    if engine is None:
        engine = build_engine(
            state, context_size=context_size, ratio=ratio, seed=seed, epochs=epochs
        )
    rng = np.random.default_rng(seed)
    n = len(state.val_pairs)
    order = rng.permutation(n)  # arrival order of the stream
    trace = simulate(
        engine,
        features=state.features_val[order],
        edges=default_edge_fleet(n_edges, seed=seed),
        strategy=strategy,
        ratio=ratio,
        micro_batch=micro_batch,
        seed=seed,
    )
    # trace records are in arrival order; map the *served* offloads (admitted
    # by an edge) back to dataset order for the mAP accounting
    served_mask = np.zeros(n, bool)
    wanted_mask = np.zeros(n, bool)
    for rec in trace.records:
        served_mask[order[rec.step]] = rec.outcome == "offloaded"
        wanted_mask[order[rec.step]] = rec.offload
    return {
        "target_ratio": ratio,
        "strategy": strategy,
        "n_edges": n_edges,
        "decided_ratio": float(wanted_mask.mean()),
        "served_ratio": float(served_mask.mean()),
        "map_served": cascade_map(state.val_pairs, served_mask),
        "map_unconstrained": cascade_map(state.val_pairs, wanted_mask),
        "weak_map": state.weak_map,
        "strong_map": state.strong_map,
        "summary": trace.summary(),
    }


def run_all(force: bool = False, quick: bool = False) -> Dict:
    """Full repro; writes artifacts/repro_results.json."""
    kw = dict(n_train=1200, n_val=400, n_pool=500, steps_weak=250, steps_strong=400) if quick else {}
    state = build_pipeline(force=force, **kw)
    results: Dict = {
        "weak_map": state.weak_map,
        "strong_map": state.strong_map,
    }
    ctx = 400 if quick else 800
    results["figure5"] = figure5_context_size(
        state,
        context_sizes=(0, 25, 100, ctx // 2, ctx) if quick else (0, 25, 50, 100, 200, 400, 800),
        n_draws=3 if quick else 5,
    )
    results["table2"] = table2_conservatism(state, context_size=ctx)
    results["figure6"] = figure6_error_types(state, context_size=ctx)
    results["figure8"] = figure8_reward_cdf(state, context_size=ctx)
    bundle = train_estimators(state, context_size=ctx, epochs=20 if quick else 40)
    results["figure9_10"] = evaluate_policies(state, bundle)
    results["streaming_multi_edge"] = streaming_multi_edge_study(
        state, context_size=ctx, epochs=10 if quick else 40
    )
    if not quick:
        results["figure7"] = figure7_input_study(state, context_size=ctx)
        results["token_bucket"] = token_bucket_study(state, bundle)
    path = _cache_path("repro_results.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[pipeline] wrote {path}")
    return results


if __name__ == "__main__":
    import sys

    # run through the canonical module so pickled classes resolve on import
    from repro.experiments import detection_repro as _mod

    _mod.run_all(force="--force" in sys.argv, quick="--quick" in sys.argv)
