"""Reward-model interface for the OffloadEngine.

``MLPRewardModel`` wraps :class:`repro.core.estimator.RewardEstimator`; when
the trained MLP has a single hidden layer and a sigmoid head (the deployable
on-device shape), batched prediction takes the fused ``estimator_mlp``
kernel — compiled Pallas on TPU/GPU, the jitted jnp reference on CPU
(``interpret=None`` auto, see ``repro.kernels.dispatch``).  ``predict_device``
is the no-host-copy variant the serve runtime and the fused score pipeline
build on.  The CNN variant from the §V-A input study sits behind the same
interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Protocol, Tuple, Union, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import (
    EstimatorConfig,
    RewardEstimator,
    cnn_apply,
    cnn_init,
)
from repro.kernels.estimator_mlp import estimator_mlp
from repro.kernels.score_pipeline.ops import pipeline_params
from repro.train.adamw import adamw_init, adamw_update


@runtime_checkable
class RewardModel(Protocol):
    """fit/predict over (B, F) features (or feature maps for the CNN)."""

    kind: str

    def fit(self, x: np.ndarray, y: np.ndarray): ...

    def predict(self, x: np.ndarray) -> np.ndarray: ...

    def state(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(arrays, meta) for checkpointing."""
        ...


class MLPRewardModel:
    """MLP reward estimator with the fused Pallas batched-predict path."""

    kind = "mlp"

    def __init__(
        self,
        in_dim: Optional[int] = None,
        config: Optional[EstimatorConfig] = None,
        use_fused: bool = True,
        interpret: Union[None, bool, str] = None,
    ):
        self.config = config if config is not None else EstimatorConfig(hidden=(128,))
        self.in_dim = in_dim
        self.use_fused = use_fused
        self.interpret = interpret
        self.estimator: Optional[RewardEstimator] = (
            RewardEstimator(in_dim, self.config) if in_dim is not None else None
        )
        # (source leaves, bundle) — see pipeline_params()
        self._pipeline_cache: Optional[Tuple[Tuple, Dict[str, jnp.ndarray]]] = None

    def _ensure(self, in_dim: int) -> RewardEstimator:
        if self.estimator is None:
            self.in_dim = in_dim
            self.estimator = RewardEstimator(in_dim, self.config)
        return self.estimator

    @property
    def fused(self) -> bool:
        """True when batched predict runs the fused Pallas kernel: exactly
        one hidden layer (params = layer0 + layer1) and a sigmoid head."""
        return (
            self.use_fused
            and self.estimator is not None
            and len(self.estimator.params) == 2
            and self.config.sigmoid_out
        )

    def fit(self, x: np.ndarray, y: np.ndarray):
        return self._ensure(int(np.shape(x)[1])).fit(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        )

    def predict(self, x: np.ndarray) -> np.ndarray:
        if self.estimator is None:
            raise RuntimeError("predict() before fit()")
        est = self.estimator
        x = np.asarray(x, np.float32)
        if not self.fused:
            return est.predict(x)
        if self.config.standardize:
            x = (x - est._mu) / est._sigma
        p = est.params
        return np.asarray(
            estimator_mlp(
                jnp.asarray(x, jnp.float32),
                p["layer0"]["w"],
                p["layer0"]["b"],
                p["layer1"]["w"][:, 0],
                p["layer1"]["b"][0],
                interpret=self.interpret,
            )
        )

    def predict_device(self, x) -> jnp.ndarray:
        """``predict`` without the host exit: takes host or device features,
        returns a device array — bit-identical to ``predict`` (elementwise
        standardize + the same ``estimator_mlp`` dispatch).  Callers convert
        once at the policy boundary."""
        if self.estimator is None:
            raise RuntimeError("predict_device() before fit()")
        est = self.estimator
        if not self.fused:
            return jnp.asarray(est.predict(np.asarray(x, np.float32)))
        x = jnp.asarray(x, jnp.float32)
        if self.config.standardize:
            x = (x - jnp.asarray(est._mu)) / jnp.asarray(est._sigma)
        p = est.params
        return estimator_mlp(
            x,
            p["layer0"]["w"],
            p["layer0"]["b"],
            p["layer1"]["w"][:, 0],
            p["layer1"]["b"][0],
            interpret=self.interpret,
        )

    def pipeline_params(self) -> Dict[str, jnp.ndarray]:
        """Param bundle for ``repro.kernels.score_pipeline`` (requires the
        fused shape), cached by the *identity* of the source leaves: the
        online update path installs fresh layer dicts/arrays (jnp arrays
        are immutable), so any weight change misses the cache and rebuilds.
        This keeps the per-block serve path free of the eager slicing /
        device transfers a rebuild costs."""
        est = self.estimator
        if not self.fused:
            return pipeline_params(self)  # raises with the explanatory message
        p = est.params
        srcs = (
            est,
            p["layer0"]["w"], p["layer0"]["b"],
            p["layer1"]["w"], p["layer1"]["b"],
            est._mu, est._sigma,
        )
        cached = self._pipeline_cache
        if cached is not None and all(a is b for a, b in zip(cached[0], srcs)):
            return cached[1]
        bundle = pipeline_params(self)
        self._pipeline_cache = (srcs, bundle)
        return bundle

    def state(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        if self.estimator is None:
            raise RuntimeError("state() before fit()")
        est = self.estimator
        arrays = {"params": est.params, "mu": est._mu, "sigma": est._sigma}
        meta = {
            "kind": self.kind,
            "in_dim": self.in_dim,
            "use_fused": self.use_fused,
            "config": dataclasses.asdict(self.config),
        }
        return arrays, meta

    @classmethod
    def from_state(cls, arrays: Dict[str, Any], meta: Dict[str, Any]) -> "MLPRewardModel":
        ckw = dict(meta["config"])
        ckw["hidden"] = tuple(ckw["hidden"])
        model = cls(
            in_dim=int(meta["in_dim"]),
            config=EstimatorConfig(**ckw),
            use_fused=bool(meta.get("use_fused", True)),
        )
        est = model.estimator
        est.params = jax.tree.map(
            lambda a: jnp.asarray(a, jnp.float32), dict(arrays["params"])
        )
        est._mu = np.asarray(arrays["mu"], np.float32)
        est._sigma = np.asarray(arrays["sigma"], np.float32)
        return model


class CNNRewardModel:
    """CNN over weak-backbone feature maps (§V-A early-exit input study),
    behind the same fit/predict contract.  ``x`` is (B, H, W, C)."""

    kind = "cnn"

    def __init__(
        self,
        in_channels: Optional[int] = None,
        width: int = 16,
        lr: float = 2e-3,
        epochs: int = 30,
        batch_size: int = 256,
        weighted: bool = True,
        seed: int = 0,
    ):
        self.in_channels = in_channels
        self.width = width
        self.lr = lr
        self.epochs = epochs
        self.batch_size = batch_size
        self.weighted = weighted
        self.seed = seed
        self.params = (
            cnn_init(jax.random.PRNGKey(seed), in_channels, width)
            if in_channels is not None
            else None
        )

    @property
    def fused(self) -> bool:
        return False

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        if self.params is None:
            self.in_channels = x.shape[-1]
            self.params = cnn_init(jax.random.PRNGKey(self.seed), self.in_channels, self.width)
        weighted = self.weighted

        def loss_fn(p, xb, yb):
            pred = cnn_apply(p, xb)
            err = jnp.square(pred - yb)
            if weighted:
                err = jnp.maximum(yb, 0.0) * err
            return jnp.mean(err)

        @jax.jit
        def step(p, o, xb, yb):
            loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
            p, o = adamw_update(grads, o, p, self.lr)
            return p, o, loss

        params, opt = self.params, adamw_init(self.params)
        rng = np.random.default_rng(self.seed)
        xj = jnp.asarray(x)
        yj = jnp.asarray(y)
        losses = []
        for _ in range(self.epochs):
            perm = rng.permutation(x.shape[0])
            for s in range(0, len(perm), self.batch_size):
                sel = perm[s : s + self.batch_size]
                params, opt, loss = step(params, opt, xj[sel], yj[sel])
                losses.append(float(loss))
        self.params = params
        return losses

    def predict(self, x: np.ndarray) -> np.ndarray:
        if self.params is None:
            raise RuntimeError("predict() before fit()")
        return np.asarray(cnn_apply(self.params, jnp.asarray(x, jnp.float32)))

    def state(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        if self.params is None:
            raise RuntimeError("state() before fit()")
        meta = {
            "kind": self.kind,
            "in_channels": self.in_channels,
            "width": self.width,
            "lr": self.lr,
            "epochs": self.epochs,
            "batch_size": self.batch_size,
            "weighted": self.weighted,
            "seed": self.seed,
        }
        return {"params": self.params}, meta

    @classmethod
    def from_state(cls, arrays: Dict[str, Any], meta: Dict[str, Any]) -> "CNNRewardModel":
        kw = {k: v for k, v in meta.items() if k != "kind"}
        model = cls(**kw)
        model.params = jax.tree.map(
            lambda a: jnp.asarray(a, jnp.float32), dict(arrays["params"])
        )
        return model


_MODELS = {"mlp": MLPRewardModel, "cnn": CNNRewardModel}


def reward_model_from_state(arrays: Dict[str, Any], meta: Dict[str, Any]) -> RewardModel:
    kind = meta["kind"]
    if kind not in _MODELS:
        raise KeyError(f"unknown reward model kind {kind!r}")
    return _MODELS[kind].from_state(arrays, meta)
