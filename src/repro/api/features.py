"""Feature-extractor protocol + registered adapters (OffloadEngine inputs).

A ``FeatureExtractor`` turns a batch of *weak* model outputs into the fixed
(B, F) float matrix the reward estimator consumes — the paper's constraint
that the estimator reads only the weak detector's result.  Adapters register
under a string name so a saved engine can reconstruct its extractor.
"""
from __future__ import annotations

from typing import (
    Any,
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Union,
    runtime_checkable,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import extract_features_batch, feature_dim
from repro.detection.batch import DetectionsBatch
from repro.detection.map_engine import Detections


@runtime_checkable
class FeatureExtractor(Protocol):
    """Batch of weak outputs -> (B, F) feature matrix."""

    name: str

    def __call__(self, weak_outputs: Any) -> np.ndarray: ...

    def spec(self) -> Dict[str, Any]:
        """Constructor kwargs sufficient to rebuild this extractor."""
        ...


_EXTRACTORS: Dict[str, Callable[..., FeatureExtractor]] = {}


def register_feature_extractor(name: str):
    """Class decorator: register under ``name`` for save/load resolution."""

    def deco(cls):
        cls.name = name
        _EXTRACTORS[name] = cls
        return cls

    return deco


def list_feature_extractors() -> List[str]:
    """Registered extractor names (for runtime configs and error messages)."""
    return sorted(_EXTRACTORS)


def make_feature_extractor(name: str, **kwargs) -> FeatureExtractor:
    if name not in _EXTRACTORS:
        raise KeyError(
            f"unknown feature extractor {name!r}; have {list_feature_extractors()}"
        )
    return _EXTRACTORS[name](**kwargs)


@register_feature_extractor("detection_boxes")
class DetectionBoxFeatures:
    """Top-K box features + global summary stats of a weak detector ([13]-style).

    Accepts either a padded :class:`repro.detection.batch.DetectionsBatch`
    (the batched data plane — no per-image Python) or a ragged list of
    ``Detections`` (padded on entry); both run the one jitted feature
    kernel in ``repro.core.features``.
    """

    def __init__(self, num_classes: int, top_k: int = 25, image_size: float = 1.0):
        self.num_classes = int(num_classes)
        self.top_k = int(top_k)
        self.image_size = float(image_size)

    @property
    def feature_dim(self) -> int:
        return feature_dim(self.num_classes, self.top_k)

    def __call__(
        self, weak_outputs: Union[Sequence[Detections], DetectionsBatch]
    ) -> np.ndarray:
        return extract_features_batch(
            weak_outputs, self.num_classes, self.top_k, self.image_size
        )

    def spec(self) -> Dict[str, Any]:
        return {
            "num_classes": self.num_classes,
            "top_k": self.top_k,
            "image_size": self.image_size,
        }


def logits_features(
    logits: jnp.ndarray, labels: Optional[jnp.ndarray] = None, top_k: int = 8
) -> np.ndarray:
    """Per-request features from WEAK-head logits only (deployable inputs):
    mean/max entropy, mean margin, mean top-k probs, mean max-prob.

    ``labels`` marks valid positions (>= 0); ``None`` treats every position
    as valid (the decode-time case where no gold labels exist).
    """
    lf = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(lf)
    if labels is None:
        labels = jnp.zeros(logits.shape[:-1], jnp.int32)
    entropy = -(p * lf).sum(-1)  # (B,S)
    topv, _ = jax.lax.top_k(p, top_k)  # (B,S,k)
    margin = topv[..., 0] - topv[..., 1]
    vmask = labels >= 0
    denom = jnp.maximum(vmask.sum(-1), 1)

    def mavg(x):
        return (x * vmask).sum(-1) / denom

    feats = jnp.concatenate(
        [
            mavg(entropy)[:, None],
            jnp.max(entropy * vmask, axis=-1)[:, None],
            mavg(margin)[:, None],
            mavg(topv[..., 0])[:, None],
            (topv * vmask[..., None]).sum(1) / denom[:, None],  # mean top-k probs
        ],
        axis=-1,
    )
    return np.asarray(feats)


@register_feature_extractor("lm_logits")
class LMLogitsFeatures:
    """Entropy/margin/top-k summary of weak-head logits (the LM analogue of
    top-25 box confidences).  Accepts ``(logits, labels)`` tuples or dicts
    with ``logits``/``labels`` keys; ``labels`` may be None at decode time."""

    def __init__(self, top_k: int = 8):
        self.top_k = int(top_k)

    @property
    def feature_dim(self) -> int:
        return 4 + self.top_k

    def __call__(self, weak_outputs: Any) -> np.ndarray:
        if isinstance(weak_outputs, dict):
            logits, labels = weak_outputs["logits"], weak_outputs.get("labels")
        else:
            logits, labels = weak_outputs
        return logits_features(logits, labels, self.top_k)

    def spec(self) -> Dict[str, Any]:
        return {"top_k": self.top_k}
