"""`OffloadEngine` — the repo's single decision-stack object.

Owns the paper's full deployable pipeline (Fig. 4 right-hand side):

    weak output --FeatureExtractor--> features
                --RewardModel-------> reward estimate        (§V MLP/CNN)
                --RankTransform-----> MORIC rank target      (Eq. 6, fit time)
                --Policy------------> offload decision       (§III threshold /
                                                              topk / token_bucket)

One engine is fit once (``fit``), scores/decides batches at serve time
(``score``/``decide``), re-budgets at runtime (``set_ratio``), and
round-trips through ``save``/``load`` as a deployable artifact.  Detection,
LM early-exit serving, the experiments pipeline, and the benchmarks all
construct this object instead of hand-wiring estimator/cdf/policy tuples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.api.features import DetectionBoxFeatures, FeatureExtractor, make_feature_extractor
from repro.api.policies import Policy, make_policy, policy_context_params
from repro.api.reward_model import (
    MLPRewardModel,
    RewardModel,
    reward_model_from_state,
)
from repro.core.reward import CdfTransform
from repro.detection.batch import DetectionsBatch
from repro.kernels.score_pipeline import score_pipeline
from repro.train.checkpoint import load_flat, save_flat


@dataclass
class DecisionBatch:
    """One served batch: per-item reward estimates + offload mask."""

    estimates: np.ndarray
    offload: np.ndarray

    @property
    def ratio(self) -> float:
        return float(np.mean(self.offload)) if self.offload.size else 0.0


class OffloadEngine:
    """The unified decision stack; see module docstring.

    Parameters
    ----------
    feature_extractor : FeatureExtractor or None
        Registered adapter mapping weak outputs to features.  ``None`` means
        callers pass ready-made feature matrices (``features=`` keyword or
        positionally as ``weak_outputs``).
    reward_model : RewardModel
        Defaults to a single-hidden-layer MLP so batched scoring runs the
        fused Pallas ``estimator_mlp`` kernel.
    transform : "cdf" | None
        Rank transform applied to rewards before fitting (MORIC, Eq. 6);
        ``None`` regresses the raw reward (the Fig. 9 "vanilla" ablation).
    policy : str
        Registered policy name: "threshold" (default), "topk", "token_bucket".
    ratio : float
        Target offloading ratio; adjustable later via ``set_ratio``.
    """

    def __init__(
        self,
        feature_extractor: Optional[FeatureExtractor] = None,
        reward_model: Optional[RewardModel] = None,
        transform: Optional[str] = "cdf",
        policy: str = "threshold",
        ratio: float = 0.2,
        policy_kwargs: Optional[Dict[str, Any]] = None,
    ):
        if transform not in ("cdf", None):
            raise ValueError(f"unknown transform {transform!r} (use 'cdf' or None)")
        self.feature_extractor = feature_extractor
        self.reward_model: RewardModel = (
            reward_model if reward_model is not None else MLPRewardModel()
        )
        self.transform_kind = transform
        self.transform: Optional[CdfTransform] = None
        self.policy_name = policy
        self.policy_kwargs = dict(policy_kwargs or {})
        self.ratio = float(ratio)
        self.policy: Optional[Policy] = None
        self.calibration_scores: Optional[np.ndarray] = None
        self.extra_meta: Dict[str, Any] = {}

    # ------------------------------------------------------------------ fit

    def _features(self, weak_outputs: Any, features: Optional[np.ndarray]) -> np.ndarray:
        if features is not None:
            return np.asarray(features, np.float32)
        if weak_outputs is None:
            raise ValueError("pass weak_outputs or features=")
        if self.feature_extractor is None:
            # no adapter: weak outputs ARE the features
            return np.asarray(weak_outputs, np.float32)
        return np.asarray(self.feature_extractor(weak_outputs), np.float32)

    def features(
        self, weak_outputs: Any = None, *, features: Optional[np.ndarray] = None
    ) -> np.ndarray:
        """Resolve weak outputs to the (B, F) feature matrix the reward model
        consumes — the public entry point for the runtime session layer,
        which extracts once per stream batch and then scores micro-batches."""
        return self._features(weak_outputs, features)

    def fit(
        self,
        weak_outputs: Any = None,
        rewards: Optional[np.ndarray] = None,
        *,
        features: Optional[np.ndarray] = None,
    ) -> "OffloadEngine":
        """Fit transform + reward model on calibration data, then derive the
        policy from the calibration score distribution."""
        if rewards is None:
            raise ValueError("fit() needs rewards")
        x = self._features(weak_outputs, features)
        r = np.asarray(rewards, np.float64)
        if self.transform_kind == "cdf":
            self.transform = CdfTransform(r)
            y = self.transform(r)
        else:
            self.transform = None
            y = r
        self.reward_model.fit(x, y)
        self.calibration_scores = np.asarray(self.reward_model.predict(x), np.float64)
        self.policy = make_policy(
            self.policy_name, self.calibration_scores, self.ratio, **self.policy_kwargs
        )
        return self

    # ---------------------------------------------------------------- serve

    def score(
        self, weak_outputs: Any = None, *, features: Optional[np.ndarray] = None
    ) -> np.ndarray:
        """Batched reward estimates for weak outputs (Pallas-fused when the
        reward model is the deployable single-hidden-layer MLP)."""
        return np.asarray(self.reward_model.predict(self._features(weak_outputs, features)))

    def _fused_pipeline_ready(self, weak_outputs: Any, features) -> bool:
        """True when scoring can take the one-dispatch fused pipeline: a
        padded detection block, the box feature extractor, and the fused
        single-hidden-layer MLP."""
        return (
            features is None
            and isinstance(weak_outputs, DetectionsBatch)
            and isinstance(self.feature_extractor, DetectionBoxFeatures)
            and getattr(self.reward_model, "fused", False)
        )

    def score_device(
        self, weak_outputs: Any = None, *, features: Optional[np.ndarray] = None
    ) -> jnp.ndarray:
        """``score`` without the host exits: returns device-resident
        estimates, bit-identical to ``score``.  A padded
        :class:`DetectionsBatch` under the box extractor + fused MLP runs
        the whole boxes→estimates pipeline as ONE jitted dispatch
        (``repro.kernels.score_pipeline``) — no numpy materialization
        between the feature, standardize, and MLP stages.  Anything else
        falls through to feature extraction + the model's device predict.
        """
        if self._fused_pipeline_ready(weak_outputs, features):
            fx = self.feature_extractor
            return score_pipeline(
                weak_outputs,
                self.reward_model.pipeline_params(),
                num_classes=fx.num_classes,
                top_k=fx.top_k,
                image_size=fx.image_size,
            )
        x = self._features(weak_outputs, features)
        model = self.reward_model
        if hasattr(model, "predict_device"):
            return model.predict_device(x)
        return jnp.asarray(model.predict(x))

    def decide(
        self, weak_outputs: Any = None, *, features: Optional[np.ndarray] = None
    ) -> DecisionBatch:
        if self.policy is None:
            raise RuntimeError("decide() before fit()/load()")
        est = np.asarray(self.score_device(weak_outputs, features=features))
        mask = np.asarray(self.policy.decide_batch(est), bool)
        return DecisionBatch(estimates=est, offload=mask)

    def set_ratio(self, ratio: float) -> None:
        """Runtime budget adjustment (paper Table I row 3)."""
        self.ratio = float(ratio)
        if self.policy is not None:
            self.policy.set_ratio(ratio)

    def with_policy(
        self,
        policy: str,
        *,
        ratio: Optional[float] = None,
        policy_kwargs: Optional[Dict[str, Any]] = None,
    ) -> "OffloadEngine":
        """A clone sharing every fitted component (extractor, reward model,
        transform, calibration scores) under a different decision policy —
        fit once, compare policies at equal budgets (the congestion studies
        and benchmarks do)."""
        if self.calibration_scores is None:
            raise RuntimeError("with_policy() before fit()/load()")
        # like save(): the policy may have been re-budgeted directly by
        # back-compat callers, so its ratio is the live one
        live_ratio = float(getattr(self.policy, "ratio", self.ratio))
        clone = OffloadEngine(
            feature_extractor=self.feature_extractor,
            reward_model=self.reward_model,
            transform=self.transform_kind,
            policy=policy,
            ratio=live_ratio if ratio is None else float(ratio),
            policy_kwargs=policy_kwargs,
        )
        clone.transform = self.transform
        clone.calibration_scores = self.calibration_scores
        clone.extra_meta = dict(self.extra_meta)
        clone.policy = make_policy(
            clone.policy_name, clone.calibration_scores, clone.ratio,
            **clone.policy_kwargs,
        )
        return clone

    # ------------------------------------------------------------ save/load

    def artifact_state(
        self, extra_meta: Optional[Dict[str, Any]] = None
    ) -> "tuple[Dict[str, Any], Dict[str, Any]]":
        """The calibrated stack as checkpoint ``(arrays, meta)`` — what
        ``save`` writes.  Wrappers (``repro.online.AdaptiveEngine``) extend
        these with their own state before writing one combined artifact."""
        if self.calibration_scores is None:
            raise RuntimeError("save() before fit()")
        model_arrays, model_meta = self.reward_model.state()
        arrays: Dict[str, Any] = {
            "model": model_arrays,
            "calibration": self.calibration_scores,
        }
        if self.transform is not None:
            arrays["transform_sorted"] = self.transform.state()["sorted_rewards"]
        fx = self.feature_extractor
        # the policy may have been re-budgeted directly (back-compat callers
        # hold it via LMCascade.policy): its ratio is the live one
        live_ratio = float(getattr(self.policy, "ratio", self.ratio))
        # injected callables (clocks, congestion probes) are runtime wiring,
        # never part of the artifact — a loaded engine gets fresh ones
        context = set(policy_context_params(self.policy_name))
        policy_kwargs = {
            k: v for k, v in self.policy_kwargs.items() if k not in context
        }
        meta = {
            "kind": "offload_engine",
            "version": 1,
            "ratio": live_ratio,
            "transform": self.transform_kind,
            "policy": {"name": self.policy_name, "kwargs": policy_kwargs},
            "feature_extractor": (
                {"name": fx.name, "spec": fx.spec()} if fx is not None else None
            ),
            "reward_model": model_meta,
            "extra": extra_meta if extra_meta is not None else self.extra_meta,
        }
        return arrays, meta

    def save(self, path: str, extra_meta: Optional[Dict[str, Any]] = None) -> None:
        """Persist the calibrated stack as one ``.npz`` artifact."""
        arrays, meta = self.artifact_state(extra_meta)
        save_flat(path, arrays, meta)

    @classmethod
    def from_artifact_state(
        cls, arrays: Dict[str, Any], meta: Dict[str, Any]
    ) -> "OffloadEngine":
        """Rebuild a fitted engine from checkpoint ``(arrays, meta)`` (the
        inverse of ``artifact_state``; also used in-memory to clone engines
        without touching disk)."""
        fx_meta = meta.get("feature_extractor")
        fx = (
            make_feature_extractor(fx_meta["name"], **fx_meta["spec"])
            if fx_meta
            else None
        )
        engine = cls(
            feature_extractor=fx,
            reward_model=reward_model_from_state(arrays["model"], meta["reward_model"]),
            transform=meta["transform"],
            policy=meta["policy"]["name"],
            ratio=meta["ratio"],
            policy_kwargs=meta["policy"]["kwargs"],
        )
        if "transform_sorted" in arrays:
            engine.transform = CdfTransform.from_state(
                {"sorted_rewards": arrays["transform_sorted"]}
            )
        engine.extra_meta = meta.get("extra", {})
        engine.calibration_scores = np.asarray(arrays["calibration"], np.float64)
        engine.policy = make_policy(
            engine.policy_name,
            engine.calibration_scores,
            engine.ratio,
            **engine.policy_kwargs,
        )
        return engine

    @classmethod
    def load(cls, path: str) -> "OffloadEngine":
        arrays, meta = load_flat(path)
        if meta is None or meta.get("kind") != "offload_engine":
            raise ValueError(f"{path} is not an OffloadEngine checkpoint")
        return cls.from_artifact_state(arrays, meta)
