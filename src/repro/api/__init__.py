"""Unified OffloadEngine API: one decision-stack object across detection,
LM serving, experiments, and benchmarks.  See docs/API.md."""
from repro.api.engine import DecisionBatch, OffloadEngine
from repro.api.features import (
    DetectionBoxFeatures,
    FeatureExtractor,
    LMLogitsFeatures,
    list_feature_extractors,
    logits_features,
    make_feature_extractor,
    register_feature_extractor,
)
from repro.api.policies import (
    Policy,
    QuantileThresholdPolicy,
    TokenBucketPolicy,
    TopKPolicy,
    list_policies,
    make_policy,
    register_policy,
)
from repro.api.reward_model import (
    CNNRewardModel,
    MLPRewardModel,
    RewardModel,
    reward_model_from_state,
)

__all__ = [
    "OffloadEngine",
    "DecisionBatch",
    "FeatureExtractor",
    "DetectionBoxFeatures",
    "LMLogitsFeatures",
    "list_feature_extractors",
    "list_policies",
    "logits_features",
    "make_feature_extractor",
    "register_feature_extractor",
    "Policy",
    "QuantileThresholdPolicy",
    "TopKPolicy",
    "TokenBucketPolicy",
    "make_policy",
    "register_policy",
    "RewardModel",
    "MLPRewardModel",
    "CNNRewardModel",
    "reward_model_from_state",
]
