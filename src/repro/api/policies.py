"""Decision-policy registry for the OffloadEngine.

Every policy is constructed from ``(calibration_scores, ratio)`` — the
calibration distribution of reward estimates the engine records at fit time —
and exposes the common contract:

    decide(estimate) -> bool          streaming, one item
    decide_batch(estimates) -> mask   a batch (token_bucket: in arrival order)
    set_ratio(ratio)                  runtime budget adjustment (Table I)

Registered: ``threshold`` (the paper's deployable quantile threshold),
``topk`` (exact per-batch top-k, the oracle-style evaluation policy), and
``token_bucket`` (hard rate constraint with burst tolerance, [23]-style).
The netsim policies (``queue_aware``, ``value_iteration`` — see
:mod:`repro.netsim.policy`) register themselves on first registry access,
so engine-built runtimes get them without importing ``repro.netsim``.

Policies that consume *runtime wiring* — injected zero-arg callables like
the simulation clock or a live congestion probe — declare the kwarg names
in a ``context_params`` class attribute.  Streaming sessions use it to
inject only what a policy accepts, and ``OffloadEngine.save`` uses it to
strip the callables from the serialized artifact.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.policy import ThresholdPolicy, TokenBucket
from repro.core.reward import topk_offload_mask


@runtime_checkable
class Policy(Protocol):
    name: str
    #: True when ``decide_batch`` enforces an exact PER-BATCH budget (its
    #: decisions depend on how the stream is chunked).  Streaming consumers
    #: (``OffloadSession``) must fall back to per-item ``decide`` for such
    #: policies; buffer-invariant policies may leave the default False.
    batch_budget: bool = False
    #: constructor kwargs that are runtime-injected callables (clock,
    #: congestion probes, ...) — never serialized with the engine artifact.
    context_params: tuple = ()

    def decide(self, estimate: float) -> bool: ...

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray: ...

    def set_ratio(self, ratio: float) -> None: ...

    def spec(self) -> Dict[str, Any]:
        """Extra constructor kwargs (beyond calibration_scores/ratio)."""
        ...


_POLICIES: Dict[str, Callable[..., Policy]] = {}


def register_policy(name: str):
    def deco(cls):
        cls.name = name
        _POLICIES[name] = cls
        return cls

    return deco


def _ensure_plugins() -> None:
    """Import policy plugins living outside repro.api (the netsim queue-aware
    controllers, the video temporal policies) so registry lookups see them.
    Lazy — called at lookup time, when repro.api.policies is fully
    initialized — so there is no import cycle and importing repro.api stays
    cheap."""
    import repro.fleet.budget  # noqa: F401  (registers on import)
    import repro.mobility.policy  # noqa: F401
    import repro.netsim.policy  # noqa: F401
    import repro.online.policy  # noqa: F401
    import repro.video.policy  # noqa: F401


def list_policies() -> List[str]:
    """Registered policy names (for runtime configs and error messages)."""
    _ensure_plugins()
    return sorted(_POLICIES)


def policy_context_params(name: str) -> tuple:
    """The runtime-injected (never serialized) constructor kwargs a policy
    declares — see ``Policy.context_params``."""
    _ensure_plugins()
    if name not in _POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {list_policies()}")
    return tuple(getattr(_POLICIES[name], "context_params", ()))


def make_policy(
    name: str, calibration_scores: np.ndarray, ratio: float, **kwargs
) -> Policy:
    _ensure_plugins()
    if name not in _POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {list_policies()}")
    return _POLICIES[name](calibration_scores, ratio, **kwargs)


def decide_sequential(policy: Policy, estimates: np.ndarray) -> np.ndarray:
    """``decide()`` each estimate in stream order — the ``decide_batch``
    body shared by stateful policies (token buckets, congestion trackers)
    whose decisions evolve item to item."""
    flat = np.asarray(estimates).ravel()
    return np.fromiter(
        (policy.decide(float(e)) for e in flat), dtype=bool, count=flat.size
    )


#: finite sentinels for the degenerate budgets (ratio 0 / 1), kept finite so
#: downstream arithmetic (Bellman backups, penalty subtraction) stays nan-free
NEVER_THRESHOLD = 1e9
ALWAYS_THRESHOLD = -1e9


def quantile_threshold(calibration_scores: np.ndarray, ratio: float) -> float:
    """The (1 - ratio)-quantile of the calibration distribution — the
    threshold every quantile-budget policy (api, netsim, video) derives its
    decision rule from — with finite sentinels at the degenerate budgets."""
    cal = np.asarray(calibration_scores, np.float64)
    r = float(np.clip(ratio, 0.0, 1.0))
    if cal.size == 0 or r >= 1.0:
        return ALWAYS_THRESHOLD
    if r <= 0.0:
        return NEVER_THRESHOLD
    return float(np.quantile(cal, 1.0 - r))


class BudgetTracker:
    """Integral controller on the realized offload ratio, shared by the
    stateful stream policies (netsim ``queue_aware``, the video temporal
    policies): with ``deficit`` the running shortfall in frames
    (``ratio * decided - offloaded``), the effective budget is
    ``ratio + gain * deficit`` clipped to [0, 1].  Because the deficit
    accumulates, any persistent suppression — congestion, stale-result
    credit — is eventually paid back and the realized ratio converges to
    the target.  The target's own degenerate budgets stay hard caps: the
    controller may not push a ratio-0 stream into offloading."""

    def __init__(self, gain: float):
        self.gain = float(gain)
        self._decided = 0
        self._offloaded = 0

    def threshold(self, sorted_calibration: np.ndarray, ratio: float) -> float:
        if ratio <= 0.0:
            return NEVER_THRESHOLD
        if ratio >= 1.0:
            return ALWAYS_THRESHOLD
        deficit = ratio * self._decided - self._offloaded
        r_adj = float(np.clip(ratio + self.gain * deficit, 0.0, 1.0))
        return quantile_threshold(sorted_calibration, r_adj)

    def account(self, offload: bool) -> None:
        self._decided += 1
        self._offloaded += int(offload)


@register_policy("threshold")
class QuantileThresholdPolicy:
    """Offload iff estimate > T, T = (1-r)-quantile of calibration scores."""

    def __init__(self, calibration_scores: np.ndarray, ratio: float):
        self._inner = ThresholdPolicy(calibration_scores, ratio)

    @property
    def ratio(self) -> float:
        return self._inner.ratio

    @property
    def threshold(self) -> float:
        return self._inner.threshold

    def set_ratio(self, ratio: float) -> None:
        self._inner.set_ratio(ratio)

    def decide(self, estimate: float) -> bool:
        return self._inner.decide(estimate)

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        return self._inner.decide_batch(estimates)

    def spec(self) -> Dict[str, Any]:
        return {}


@register_policy("topk")
class TopKPolicy:
    """Exact per-batch budget: offload the top ``ratio`` fraction of the
    batch (ties resolved stably by position).  Single-item ``decide`` falls
    back to the calibration quantile threshold."""

    batch_budget = True  # decide_batch depends on the chunking of the stream

    def __init__(self, calibration_scores: np.ndarray, ratio: float):
        self._threshold = ThresholdPolicy(calibration_scores, ratio)
        self.ratio = self._threshold.ratio

    def set_ratio(self, ratio: float) -> None:
        self._threshold.set_ratio(ratio)
        self.ratio = self._threshold.ratio

    def decide(self, estimate: float) -> bool:
        return self._threshold.decide(estimate)

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        return topk_offload_mask(np.asarray(estimates, np.float64), self.ratio)

    def spec(self) -> Dict[str, Any]:
        return {}


@register_policy("token_bucket")
class TokenBucketPolicy:
    """Hard offload-rate constraint with burst tolerance ``depth``; the rate
    is the target ratio and the base threshold its calibration quantile.

    ``clock`` (optional, not serialized) switches the bucket to time-based
    refill — see :class:`repro.core.policy.TokenBucket`; streaming sessions
    inject their simulation clock here.
    """

    context_params = ("clock",)

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        depth: float = 8.0,
        clock: Optional[Callable[[], float]] = None,
    ):
        self._cal = np.sort(np.asarray(calibration_scores, dtype=np.float64))
        self.depth = float(depth)
        self.clock = clock
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))
        # finite sentinels at the edges: the bucket's scarcity interpolation
        # thr = base + (1-base)*scarcity is nan-free only for finite base
        if self._cal.size == 0 or self.ratio >= 1.0:
            base = -1e30
        elif self.ratio <= 0.0:
            base = 1e30
        else:
            base = float(np.quantile(self._cal, 1.0 - self.ratio))
        # a re-budget must not refill the bucket — carrying the level over
        # keeps the hard rate constraint across runtime ratio changes
        prev = getattr(self, "bucket", None)
        level = min(prev.level, self.depth) if prev is not None else None
        self.bucket = TokenBucket(
            rate=self.ratio, depth=self.depth, base_threshold=base, level=level,
            clock=self.clock,
        )

    def decide(self, estimate: float) -> bool:
        return self.bucket.decide(float(estimate))

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # sequential by construction: estimates arrive in stream order
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, Any]:
        return {"depth": self.depth}
