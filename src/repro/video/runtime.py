"""Temporal offloading over video streams — the serve-time driver.

``VideoRuntime`` extends :class:`repro.runtime.simulate.OffloadRuntime`
with ``serve_clip``: B parallel camera streams step frame-locked through
one engine + edge fleet, and — unlike the per-image driver — offloaded
results have a *temporal afterlife*:

- an admitted offload's strong result comes back after the netsim link's
  queue + transmit + service delay, so it is already ``latency`` frames
  stale on arrival;
- every subsequent frame within ``max_stale`` of the newest delivered
  result is answered by *propagating* that result onto the current frame
  through the stream's tracker (:meth:`~repro.video.track.VideoTracker
  .propagate`) instead of the weak output;
- each frame's **effective accuracy** — the AP of whatever was actually
  served (weak output or propagated edge result) against ground truth,
  via the existing AP engine — lands on the per-step trace, along with
  the serving source and staleness.

Temporal probes are wired per stream exactly like the netsim congestion
probes: ``temporal_hysteresis`` sees the stream's staleness (frames since
the newest covering result was captured, counting admitted in-flight
offloads — a frame about to be covered is worth less), ``keyframe`` sees
the scene-change score (tracker churn + weak-output frame difference).

``default_video_scenario`` builds the seeded 8-stream congested-fleet
acceptance scenario: the engine is fitted on a held-out calibration clip
with true per-frame rewards (strong AP − weak AP, rank-transformed), the
serve clip runs behind Gilbert–Elliott uplinks.  Its headline claim —
``temporal_hysteresis`` beats the per-image ``threshold`` policy in mean
effective accuracy at equal realized offload ratio — is asserted by
``tests/test_video.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.api.engine import OffloadEngine
from repro.detection.batch import (
    DetectionsBatch,
    GroundTruthBatch,
    match_batch,
    to_image_evals,
)
from repro.detection.map_engine import APAccumulator, Detections, GroundTruth
from repro.runtime.dispatch import OUTCOME_LOCAL, OUTCOME_OFFLOADED
from repro.runtime.simulate import OffloadRuntime, StepRecord, StreamTrace, default_congested_fleet
from repro.video.features import frame_difference, scene_change_score
from repro.video.scene import (
    STRONG_PROFILE,
    WEAK_PROFILE,
    DetectionClip,
    SceneConfig,
    VideoClip,
    generate_clip,
    synthesize_detections,
)
from repro.video.track import TrackerConfig, VideoTracker


def fuse_detections(
    primary: Detections, secondary: Detections, iou_thresh: float = 0.5
) -> Detections:
    """Serve-time fusion of a propagated edge result with the current weak
    output (SmartDet-style): every primary (edge) detection is kept, and
    secondary (weak) detections survive only where no primary box overlaps
    them (IoU < ``iou_thresh``, class-agnostic) — the weak output fills in
    objects the stale result cannot know about (entries, lost tracks) while
    the edge result owns everything it still covers."""
    if not len(primary):
        return secondary
    if not len(secondary):
        return primary
    from repro.video.track import _iou_f32

    iou = _iou_f32(secondary.boxes, primary.boxes)
    keep = iou.max(axis=1) < iou_thresh
    return Detections(
        np.concatenate([primary.boxes, secondary.boxes[keep]]),
        np.concatenate([primary.scores, secondary.scores[keep]]),
        np.concatenate([primary.classes, secondary.classes[keep]]),
    )


def frame_accuracies(
    dets: Sequence[Detections],
    gts: Sequence[GroundTruth],
    iou_thresholds: Sequence[float] = (0.5,),
) -> np.ndarray:
    """Per-frame mAP of each detection set against its own ground truth —
    one batched ``match_batch`` call through the Pallas IoU kernel, then the
    standard AP engine per frame."""
    if len(dets) != len(gts):
        raise ValueError(f"{len(dets)} detection sets vs {len(gts)} ground truths")
    if not dets:
        return np.zeros(0)
    db = DetectionsBatch.from_list(list(dets))
    gb = GroundTruthBatch.from_list(list(gts))
    evs = to_image_evals(db, gb, match_batch(db, gb, iou_thresholds))
    out = np.empty(len(evs))
    for i, ev in enumerate(evs):
        acc = APAccumulator(iou_thresholds)
        acc.add(ev)
        out[i] = acc.map()
    return out


@dataclass
class VideoFleetTrace:
    """Per-stream :class:`StreamTrace` records + fleet-level aggregates of
    one ``serve_clip`` run."""

    streams: List[StreamTrace]
    dispatcher: Dict[str, Any]

    @property
    def n_streams(self) -> int:
        return len(self.streams)

    @property
    def n_frames(self) -> int:
        return len(self.streams[0].records) if self.streams else 0

    def realized_ratio(self) -> float:
        """Fraction of frames the policies spent offload budget on (admitted
        or saturated), over all streams."""
        n = sum(len(s.records) for s in self.streams)
        off = sum(sum(r.offload for r in s.records) for s in self.streams)
        return off / n if n else 0.0

    def mean_effective_accuracy(self) -> float:
        accs = [
            r.effective_accuracy
            for s in self.streams
            for r in s.records
            if r.effective_accuracy is not None
        ]
        return float(np.mean(accs)) if accs else 0.0

    def outcome_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for s in self.streams:
            for outcome, n in s.outcome_counts().items():
                counts[outcome] = counts.get(outcome, 0) + n
        return counts

    def staleness_profile(self) -> Dict[str, float]:
        stale = [
            r.staleness
            for s in self.streams
            for r in s.records
            if r.staleness is not None
        ]
        n = sum(len(s.records) for s in self.streams)
        return {
            "covered_fraction": len(stale) / n if n else 0.0,
            "mean_staleness": float(np.mean(stale)) if stale else 0.0,
        }

    def summary(self) -> Dict[str, Any]:
        return {
            "streams": self.n_streams,
            "frames": self.n_frames,
            "realized_ratio": self.realized_ratio(),
            "mean_effective_accuracy": self.mean_effective_accuracy(),
            "outcomes": self.outcome_counts(),
            "staleness": self.staleness_profile(),
            "dispatcher": self.dispatcher,
        }


class VideoRuntime(OffloadRuntime):
    """The served video system: engine + fleet + per-stream temporal state."""

    def serve_clip(
        self,
        weak: DetectionClip,
        strong: DetectionClip,
        clip: VideoClip,
        *,
        features: Optional[np.ndarray] = None,
        ratio: Optional[float] = None,
        max_stale: float = 6.0,
        fuse: bool = True,
        arrival_period: float = 1.0,
        tracker_config: Optional[TrackerConfig] = None,
        iou_thresholds: Sequence[float] = (0.5,),
    ) -> VideoFleetTrace:
        """Serve every stream of a clip end to end; deterministic under the
        seeded fleet.  ``weak`` is what the device sees each frame,
        ``strong`` what an edge would answer for a frame it receives,
        ``clip`` the ground truth the effective output is scored against.

        ``features`` optionally overrides feature extraction with a
        precomputed ``(T * B, F)`` time-major matrix (required when the
        engine has no feature extractor)."""
        T, B = weak.n_frames, weak.n_streams
        if (strong.n_frames, strong.n_streams) != (T, B) or (
            clip.n_frames, clip.n_streams
        ) != (T, B):
            raise ValueError(
                f"clip shape mismatch: weak {(T, B)}, strong "
                f"{(strong.n_frames, strong.n_streams)}, gt "
                f"{(clip.n_frames, clip.n_streams)}"
            )
        if features is None:
            if self.engine.feature_extractor is None:
                raise ValueError(
                    "engine has no feature extractor; pass features=(T*B, F)"
                )
            x = np.asarray(self.engine.features(weak.flatten()), np.float32)
        else:
            x = np.asarray(features, np.float32)
        if x.shape[0] != T * B:
            raise ValueError(f"features rows {x.shape[0]} != T*B = {T * B}")

        tracker = VideoTracker(B, tracker_config)
        streams = [
            {
                "frame": 0,
                "cover_frame": None,   # newest admitted capture (incl. in flight)
                "delivered": None,     # newest delivered capture frame
                "pending": [],         # (t_done, capture_frame) in flight
                "prev": None,          # previous weak Detections
                "scene": 0.0,
            }
            for _ in range(B)
        ]

        def make_staleness(st):
            def probe() -> float:
                if st["cover_frame"] is None:
                    return float("inf")
                return float(st["frame"] - st["cover_frame"])

            return probe

        def make_scene(st):
            return lambda: float(st["scene"])

        sessions = [
            self.open_session(
                ratio=ratio,
                micro_batch=1,
                staleness=make_staleness(st),
                scene_change=make_scene(st),
                tracker=tracker,
                name=str(b),
                tid=1 + b,
            )
            for b, st in enumerate(streams)
        ]
        prof = self.obs.profiler if self.obs is not None else None

        rows: List[List[Dict[str, Any]]] = [[] for _ in range(B)]
        served: List[List[Detections]] = [[] for _ in range(B)]
        for t in range(T):
            now = self.clock()
            self.dispatcher.poll(now)
            if prof is None:
                tf = tracker.update(weak.frame(t))
            else:
                _pt0 = prof.begin()
                tf = tracker.update(weak.frame(t))
                prof.add("video.track", _pt0)
                _pt0 = prof.begin()
            churn = tf.churn()
            for b, (st, session) in enumerate(zip(streams, sessions)):
                st["frame"] = t
                still = []
                for t_done, t0 in st["pending"]:
                    if t_done <= now:
                        if st["delivered"] is None or t0 > st["delivered"]:
                            st["delivered"] = t0
                    else:
                        still.append((t_done, t0))
                st["pending"] = still
                cur = weak.det(t, b)
                st["scene"] = scene_change_score(
                    frame_difference(st["prev"], cur)["overlap"], float(churn[b])
                )
                st["prev"] = cur

                d = session.submit(features=x[t * B + b])[0]
                edge = latency = bd = None
                outcome = OUTCOME_LOCAL
                if d.offload:
                    res = self.dispatcher.dispatch(now, t * B + b, d.estimate)
                    self._record_offload(now, res)
                    outcome, edge, latency, bd = (
                        res.outcome, res.edge, res.latency, res.breakdown,
                    )
                    if res.outcome == OUTCOME_OFFLOADED:
                        session.record_rtt(res.latency)
                        st["pending"].append((now + res.latency, t))
                        if st["cover_frame"] is None or t > st["cover_frame"]:
                            st["cover_frame"] = t
                t0 = st["delivered"]
                if t0 is not None and t - t0 <= max_stale:
                    eff = tracker.propagate(strong.det(t0, b), t0, t, stream=b)
                    if fuse:
                        eff = fuse_detections(eff, cur)
                    source, staleness = "edge", float(t - t0)
                else:
                    eff, source, staleness = cur, "weak", None
                served[b].append(eff)
                rows[b].append(
                    dict(
                        step=t, t_arrival=now, t_decision=now,
                        estimate=d.estimate, offload=d.offload, edge=edge,
                        latency=latency, outcome=outcome,
                        queue_delay=bd.queue if bd is not None else None,
                        transmit_delay=bd.transmit if bd is not None else None,
                        service_delay=bd.service if bd is not None else None,
                        source=source, staleness=staleness,
                    )
                )
            if prof is not None:
                prof.add("video.serve_frames", _pt0)
            self.clock.advance(arrival_period)
        self.dispatcher.poll(self.clock())

        # score what was actually served, one batched matcher call
        if prof is not None:
            _pt0 = prof.begin()
        acc = frame_accuracies(
            [d for per in served for d in per],
            [clip.gt(t, b) for b in range(B) for t in range(T)],
            iou_thresholds,
        ).reshape(B, T)
        if prof is not None:
            prof.add("video.score_accuracy", _pt0)
        traces = []
        for b, session in enumerate(sessions):
            records = []
            for t, row in enumerate(rows[b]):
                if row["staleness"] is not None:
                    session.record_staleness(row["staleness"])
                session.record_effective_accuracy(float(acc[b, t]))
                records.append(
                    StepRecord(effective_accuracy=float(acc[b, t]), **row)
                )
            traces.append(
                StreamTrace(
                    records=records,
                    telemetry=session.telemetry,
                    dispatcher=self.dispatcher.stats(),
                )
            )
        return VideoFleetTrace(streams=traces, dispatcher=self.dispatcher.stats())


# ------------------------------------------------------- seeded scenario


@dataclass
class VideoScenario:
    """A fully seeded video workload: fitted engine, serve clip, the weak /
    strong detection streams, and the congested fleet recipe."""

    engine: OffloadEngine
    clip: VideoClip
    weak: DetectionClip
    strong: DetectionClip
    seed: int = 0
    fleet_size: int = 3
    transmit_time: float = 1.0
    queue_depth: int = 6
    max_stale: float = 6.0

    def fleet(self):
        """A fresh seeded congested fleet (links carry per-run state, so
        every simulation builds its own)."""
        return default_congested_fleet(
            self.fleet_size,
            seed=self.seed,
            transmit_time=self.transmit_time,
            queue_depth=self.queue_depth,
        )


def default_video_scenario(
    n_streams: int = 8,
    n_frames: int = 96,
    *,
    seed: int = 0,
    scene: Optional[SceneConfig] = None,
    calibration_frames: int = 48,
    estimator_epochs: int = 15,
    ratio: float = 0.3,
) -> VideoScenario:
    """The seeded congested-fleet video scenario (8 streams by default).

    The engine is fitted the paper's way, on held-out calibration data: a
    disjoint clip's weak outputs are featurized through
    ``DetectionBoxFeatures`` and regressed (rank-transformed) onto the TRUE
    per-frame reward — strong AP minus weak AP, both against ground truth
    through the batched matcher."""
    from repro.api.features import DetectionBoxFeatures
    from repro.api.reward_model import MLPRewardModel
    from repro.core.estimator import EstimatorConfig
    from repro.data.shapes import NUM_CLASSES

    cfg = scene or SceneConfig()
    cal_clip = generate_clip(4, calibration_frames, seed=seed + 101, config=cfg)
    cal_weak = synthesize_detections(cal_clip, WEAK_PROFILE, seed=seed + 102)
    cal_strong = synthesize_detections(cal_clip, STRONG_PROFILE, seed=seed + 103)
    gts = [
        cal_clip.gt(t, b)
        for t in range(cal_clip.n_frames)
        for b in range(cal_clip.n_streams)
    ]
    weak_list = [
        cal_weak.det(t, b)
        for t in range(cal_clip.n_frames)
        for b in range(cal_clip.n_streams)
    ]
    strong_list = [
        cal_strong.det(t, b)
        for t in range(cal_clip.n_frames)
        for b in range(cal_clip.n_streams)
    ]
    rewards = frame_accuracies(strong_list, gts) - frame_accuracies(weak_list, gts)
    engine = OffloadEngine(
        feature_extractor=DetectionBoxFeatures(
            num_classes=NUM_CLASSES, top_k=8, image_size=float(cfg.size)
        ),
        reward_model=MLPRewardModel(
            config=EstimatorConfig(
                hidden=(32,), epochs=estimator_epochs, batch_size=64, seed=seed
            )
        ),
        ratio=ratio,
    )
    engine.fit(cal_weak.flatten(), rewards)

    clip = generate_clip(n_streams, n_frames, seed=seed, config=cfg)
    return VideoScenario(
        engine=engine,
        clip=clip,
        weak=synthesize_detections(clip, WEAK_PROFILE, seed=seed + 1),
        strong=synthesize_detections(clip, STRONG_PROFILE, seed=seed + 2),
        seed=seed,
    )


def run_video_scenario(
    scenario: VideoScenario,
    policy: Optional[str] = None,
    *,
    ratio: Optional[float] = None,
    policy_kwargs: Optional[Dict[str, Any]] = None,
    strategy: str = "least_loaded",
    seed: Optional[int] = None,
) -> VideoFleetTrace:
    """One deterministic serve of the scenario under a policy (``None``
    keeps the engine's own).  Equal-budget comparisons run this repeatedly
    with different ``policy`` / ``ratio`` over the same scenario."""
    engine = scenario.engine
    if policy is not None:
        engine = engine.with_policy(
            policy,
            ratio=ratio if ratio is not None else engine.ratio,
            policy_kwargs=policy_kwargs,
        )
    runtime = VideoRuntime(
        engine,
        scenario.fleet(),
        strategy=strategy,
        seed=scenario.seed if seed is None else seed,
    )
    return runtime.serve_clip(
        scenario.weak,
        scenario.strong,
        scenario.clip,
        ratio=ratio,
        max_stale=scenario.max_stale,
    )
