"""Temporal features over a stream of weak detector outputs.

The per-image stack scores each frame in isolation; these helpers add the
signals that only exist *between* frames:

- :func:`detection_overlap` / :func:`frame_difference` — how much of the
  current weak output is explained by the previous frame's (greedy IoU,
  class-gated), plus count/score drift;
- :func:`scene_change_score` — a [0, 1] cut detector mixing the overlap
  complement with tracker churn (births + deaths per live track, from
  :meth:`repro.video.track.TrackFrame.churn`);
- :class:`EwmaSmoother` — exponentially-weighted smoothing of the per-frame
  reward estimate, the temporal prior SmartDet-style policies lean on.

Everything is pure host-side arithmetic over already-extracted outputs —
cheap per frame, deterministic, no device round-trips.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.detection.map_engine import Detections
from repro.video.track import greedy_match_boxes


def detection_overlap(
    prev: Detections, cur: Detections, iou_thresh: float = 0.5
) -> float:
    """Fraction of current detections greedy-matched (class-gated, IoU >=
    ``iou_thresh``) to the previous frame's — 1.0 for a static scene, ~0
    across a cut.  Empty current frames count as fully explained."""
    if not len(cur):
        return 1.0
    if not len(prev):
        return 0.0
    match = greedy_match_boxes(
        cur.boxes,
        cur.scores,
        prev.boxes,
        iou_thresh,
        eligible=np.asarray(cur.classes)[:, None]
        == np.asarray(prev.classes)[None, :],
    )
    return float((match >= 0).mean())


def frame_difference(prev: Optional[Detections], cur: Detections) -> Dict[str, float]:
    """Frame-to-frame drift statistics of the weak output: detection-count
    delta, mean-score delta, and the matched-overlap fraction.  ``prev``
    may be None (stream start): treated as a full change."""
    if prev is None:
        return {"count_delta": float(len(cur)), "score_delta": 0.0, "overlap": 0.0}
    mean = lambda d: float(np.mean(d.scores)) if len(d) else 0.0
    return {
        "count_delta": float(len(cur) - len(prev)),
        "score_delta": mean(cur) - mean(prev),
        "overlap": detection_overlap(prev, cur),
    }


def scene_change_score(
    overlap: float, churn: float, *, overlap_weight: float = 0.6
) -> float:
    """Blend the two cut signals into one [0, 1] score: low frame-to-frame
    overlap and high tracker churn both push toward 1."""
    w = float(np.clip(overlap_weight, 0.0, 1.0))
    score = w * (1.0 - float(overlap)) + (1.0 - w) * float(churn)
    return float(np.clip(score, 0.0, 1.0))


@dataclass
class EwmaSmoother:
    """Exponentially-weighted moving average, seeded by the first sample.

    ``alpha`` is the weight on the NEW sample (1.0 = no smoothing)."""

    alpha: float = 0.3
    value: Optional[float] = None

    def update(self, x: float) -> float:
        x = float(x)
        if self.value is None:
            self.value = x
        else:
            self.value = (1.0 - self.alpha) * self.value + self.alpha * x
        return self.value

    def reset(self) -> None:
        self.value = None
