"""Temporal offloading policies over video streams.

Two controllers, registered in the ``repro.api`` policy registry (so
``OffloadEngine(policy="temporal_hysteresis")`` / ``"keyframe"`` and every
runtime built on the engine get them for free):

- ``temporal_hysteresis`` — the quantile-threshold rule with three
  stream-level amendments: (1) an EWMA prior over the estimates (consecutive
  frames are correlated, so the smoothed estimate is the better per-frame
  signal), (2) a Schmitt-trigger hysteresis band around the threshold so the
  decision doesn't chatter on estimate noise, and (3) **stale-result
  credit** — when a fresh edge result already covers the stream (probed via
  the runtime-injected ``staleness`` callable), the estimate is discounted
  by ``stale_credit * freshness``, so the budget the redundant frames would
  have burned is re-spent (via the same integral controller as
  ``queue_aware``) on frames no edge result covers.
- ``keyframe`` — offload on scene changes: the runtime-injected
  ``scene_change`` probe (tracker churn + frame-difference overlap, see
  :mod:`repro.video.features`) boosts the estimate at cuts, and a hard
  refractory period keeps consecutive offloads at least ``refractory``
  frames apart, spreading the budget over the stream.

Both consume *runtime-injected context* (zero-arg callables wired by the
video runtime exactly like the netsim congestion probes) and degrade
gracefully without it: no staleness probe means no credit, no scene probe
means no boost — both collapse to (smoothed) threshold behaviour, and both
track the target ratio through the integral deficit controller.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.api.policies import (
    ALWAYS_THRESHOLD as _ALWAYS,
    NEVER_THRESHOLD as _NEVER,
    BudgetTracker,
    decide_sequential,
    register_policy,
)
from repro.video.features import EwmaSmoother


@register_policy("temporal_hysteresis")
class TemporalHysteresisPolicy:
    """Smoothed threshold with a hysteresis band and stale-result credit.

    Parameters (beyond the registry's ``calibration_scores, ratio``):

    hysteresis : float
        Half-width of the Schmitt band (in estimate units — the engine's
        CDF puts estimates in [0, 1]): after an offload the bar drops by
        ``hysteresis``, after a local decision it rises by ``hysteresis``,
        so a borderline stream doesn't flip decision every frame.
    stale_credit : float
        Max discount subtracted from the estimate while a fresh edge result
        covers the stream; decays linearly to 0 over ``stale_horizon``.
    stale_horizon : float
        Staleness (frames) at which an edge result stops counting as cover.
    gain : float
        Integral gain of the realized-ratio tracker.
    ewma : float
        EWMA weight on the newest estimate (1.0 disables smoothing).
    staleness : callable or None
        Zero-arg probe of the stream's current staleness — frames since the
        newest covering edge result was *captured* (``inf`` when none).
        Runtime wiring, never serialized (stripped like the token-bucket
        clock).
    """

    context_params = ("staleness",)

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        hysteresis: float = 0.04,
        stale_credit: float = 0.5,
        stale_horizon: float = 6.0,
        gain: float = 0.05,
        ewma: float = 0.7,
        staleness: Optional[Callable[[], float]] = None,
    ):
        if stale_horizon <= 0.0:
            raise ValueError(f"stale_horizon must be > 0, got {stale_horizon}")
        if not 0.0 < ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {ewma}")
        self._cal = np.sort(np.asarray(calibration_scores, np.float64))
        self.hysteresis = float(hysteresis)
        self.stale_credit = float(stale_credit)
        self.stale_horizon = float(stale_horizon)
        self.ewma = float(ewma)
        self.staleness = staleness
        self._budget = BudgetTracker(gain)
        self._smoother = EwmaSmoother(alpha=self.ewma)
        self._last_offload = False
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))

    def _credit(self) -> float:
        if self.staleness is None:
            return 0.0
        s = float(self.staleness())
        if not np.isfinite(s):
            return 0.0
        fresh = max(0.0, 1.0 - max(s, 0.0) / self.stale_horizon)
        return self.stale_credit * fresh

    def decide(self, estimate: float) -> bool:
        e = self._smoother.update(float(estimate)) - self._credit()
        thr = self._budget.threshold(self._cal, self.ratio)
        if thr not in (_NEVER, _ALWAYS):  # degenerate budgets stay hard
            thr += -self.hysteresis if self._last_offload else self.hysteresis
        off = bool(e > thr)
        self._budget.account(off)
        self._last_offload = off
        return off

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # sequential by construction: the EWMA, the hysteresis state, and
        # the live staleness probe all evolve decision to decision
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, Any]:
        return {
            "hysteresis": self.hysteresis,
            "stale_credit": self.stale_credit,
            "stale_horizon": self.stale_horizon,
            "gain": self._budget.gain,
            "ewma": self.ewma,
        }


@register_policy("keyframe")
class KeyframePolicy:
    """Offload on scene changes with a refractory period.

    The scene-change probe boosts the estimate by ``change_boost * score``
    (so cuts clear the threshold even when the per-frame estimate alone
    would not), while decisions within ``refractory`` frames of the last
    offload are forced local — offloads spread along the stream instead of
    clustering on one busy scene.  The integral budget tracker keeps the
    realized ratio on target whenever the refractory ceiling
    ``1 / refractory`` allows it.

    ``scene_change`` is runtime wiring (never serialized): a zero-arg probe
    returning the stream's current scene-change score in [0, 1].
    """

    context_params = ("scene_change",)

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        refractory: int = 2,
        change_boost: float = 0.6,
        gain: float = 0.05,
        scene_change: Optional[Callable[[], float]] = None,
    ):
        if refractory < 1:
            raise ValueError(f"refractory must be >= 1, got {refractory}")
        self._cal = np.sort(np.asarray(calibration_scores, np.float64))
        self.refractory = int(refractory)
        self.change_boost = float(change_boost)
        self.scene_change = scene_change
        self._budget = BudgetTracker(gain)
        self._since_offload = np.inf
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))

    def decide(self, estimate: float) -> bool:
        boost = 0.0
        if self.scene_change is not None:
            boost = self.change_boost * float(np.clip(self.scene_change(), 0.0, 1.0))
        thr = self._budget.threshold(self._cal, self.ratio)
        off = bool(float(estimate) + boost > thr)
        # the refractory period is a hard rate cap — except for the
        # degenerate always-offload TARGET, which stays absolute.  Guard on
        # the target ratio, not the threshold sentinel: a saturated deficit
        # controller also yields ALWAYS_THRESHOLD and must NOT break the cap
        if off and self.ratio < 1.0 and self._since_offload < self.refractory:
            off = False
        self._budget.account(off)
        # counting the offload frame itself as 1 elapsed makes consecutive
        # offloads exactly `refractory` frames apart at the cap, so the
        # documented ceiling 1/refractory is exact
        self._since_offload = 1 if off else self._since_offload + 1
        return off

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # sequential by construction: refractory + deficit state evolve
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, Any]:
        return {
            "refractory": self.refractory,
            "change_boost": self.change_boost,
            "gain": self._budget.gain,
        }
