"""Seeded synthetic video: moving coloured shapes + correlated detections.

Extends the procedural :mod:`repro.data.shapes` dataset along the time axis
— the deployment picture the paper targets is a camera *stream*, not i.i.d.
images.  Per stream, objects move with constant velocity plus seeded jitter
(bouncing off the frame), enter and exit, get briefly occluded, and the
whole scene occasionally cuts to a fresh layout (the events keyframe
policies key on).  Everything is a pure function of the seed.

Containers follow the PR 3 padded ``DetectionsBatch`` convention with a
leading time axis:

* :class:`VideoClip` — ground truth, ``(T, B, N, ...)`` padded
  struct-of-arrays (``B`` parallel streams) with per-object identities and
  per-frame cut flags; ``gt_frame(t)`` is a ``GroundTruthBatch`` over the
  streams, ``gt(t, b)`` the host ``GroundTruth``.
* :class:`DetectionClip` — synthesized detector output on the same layout;
  ``frame(t)`` is a ``DetectionsBatch``, ready for the batched feature /
  matching kernels with zero per-image Python.

``synthesize_detections`` derives weak/strong detector streams from a clip
geometrically (no pixel rendering): noise is *temporally correlated* —
each object carries a persistent class-flip and a persistent miss
propensity, so weak-output quality varies scene to scene the way a real
weak detector's does.  ``render_frame`` rasterizes a frame through the
shapes painter when pixels are actually wanted (examples, demos).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.data.shapes import IMAGE_SIZE, NUM_CLASSES, _background, class_colour, paint_object
from repro.detection.batch import DetectionsBatch, GroundTruthBatch, _pad_dim
from repro.detection.map_engine import Detections, GroundTruth


@dataclass(frozen=True)
class SceneConfig:
    """Knobs of the per-stream motion simulation (all rates per frame)."""

    size: int = IMAGE_SIZE
    min_objects: int = 2
    max_objects: int = 6
    speed: float = 1.5          # max |velocity| component at spawn, px/frame
    jitter: float = 0.15        # per-frame velocity perturbation (sigma)
    p_enter: float = 0.04       # new object appears (below max_objects)
    p_exit: float = 0.02        # object leaves the scene
    p_occlude: float = 0.03     # object hidden for a few frames
    occlude_max: int = 3        # max occlusion length, frames
    p_cut: float = 0.03         # full scene change (all objects replaced)

    def __post_init__(self) -> None:
        if not 1 <= self.min_objects <= self.max_objects:
            raise ValueError(
                f"need 1 <= min_objects <= max_objects, got "
                f"{self.min_objects}..{self.max_objects}"
            )


@dataclass(frozen=True)
class DetectorProfile:
    """Noise model of one detector tier over a clip.  ``flip`` and ``miss``
    are *persistent per object* (sampled once from the object identity), so
    the induced quality signal is temporally correlated like a real weak
    detector's failure modes.

    ``hard_classes`` makes localization quality *class-conditional*:
    objects whose true class is listed get ``hard_box_jitter`` corner noise
    instead of ``box_jitter`` — a detector that is simply bad at certain
    categories.  Two profiles differing only in the hard set draw identical
    noise streams (the gaussian scale rescales the same draws), which is
    what lets a mid-stream swap of the hard set model a pure distribution
    shift without perturbing anything else in the clip."""

    box_jitter: float = 0.6     # per-corner gaussian noise, px
    flip: float = 0.05          # P(object's class is persistently wrong)
    miss: float = 0.05          # mean per-frame miss probability
    hallucinate: float = 0.02   # P(extra spurious detection per frame)
    score_lo: float = 0.55
    score_hi: float = 0.95
    hard_classes: tuple = ()    # true classes with degraded localization
    hard_box_jitter: Optional[float] = None  # their corner noise, px

    def jitter_for(self, true_cls: int) -> float:
        if self.hard_box_jitter is not None and int(true_cls) in self.hard_classes:
            return self.hard_box_jitter
        return self.box_jitter


#: the two tiers of the paper's weak-device / strong-edge pair
WEAK_PROFILE = DetectorProfile(
    box_jitter=2.0, flip=0.35, miss=0.3, hallucinate=0.12,
    score_lo=0.35, score_hi=0.9,
)
STRONG_PROFILE = DetectorProfile()


@dataclass(kw_only=True)
class VideoClip:
    """Padded ground-truth video: ``boxes (T, B, N, 4)`` float32, ``classes``
    / ``ids (T, B, N)`` int32, ``mask (T, B, N)`` bool, ``cuts (T, B)``
    bool.  ``ids`` are per-stream object identities (stable across frames,
    -1 on padded slots); ``cuts[t, b]`` marks a scene change at frame t."""

    boxes: np.ndarray
    classes: np.ndarray
    ids: np.ndarray
    mask: np.ndarray
    cuts: np.ndarray
    size: int = IMAGE_SIZE

    @property
    def n_frames(self) -> int:
        return self.boxes.shape[0]

    @property
    def n_streams(self) -> int:
        return self.boxes.shape[1]

    @property
    def max_objects(self) -> int:
        return self.boxes.shape[2]

    def gt_frame(self, t: int) -> GroundTruthBatch:
        """Frame ``t`` across all streams as a padded batch."""
        return GroundTruthBatch(
            boxes=self.boxes[t], classes=self.classes[t], mask=self.mask[t]
        )

    def gt(self, t: int, b: int) -> GroundTruth:
        m = self.mask[t, b]
        return GroundTruth(self.boxes[t, b][m], self.classes[t, b][m])

    def gt_stream(self, b: int) -> List[GroundTruth]:
        return [self.gt(t, b) for t in range(self.n_frames)]


@dataclass(kw_only=True)
class DetectionClip:
    """Padded detector output over a clip: the ``DetectionsBatch`` fields
    with a leading time axis — ``boxes (T, B, K, 4)``, ``scores`` /
    ``classes`` / ``mask (T, B, K)``."""

    boxes: np.ndarray
    scores: np.ndarray
    classes: np.ndarray
    mask: np.ndarray

    @property
    def n_frames(self) -> int:
        return self.boxes.shape[0]

    @property
    def n_streams(self) -> int:
        return self.boxes.shape[1]

    @property
    def max_boxes(self) -> int:
        return self.boxes.shape[2]

    def frame(self, t: int) -> DetectionsBatch:
        return DetectionsBatch(
            boxes=self.boxes[t], scores=self.scores[t],
            classes=self.classes[t], mask=self.mask[t],
        )

    def det(self, t: int, b: int) -> Detections:
        m = self.mask[t, b]
        return Detections(
            self.boxes[t, b][m], self.scores[t, b][m], self.classes[t, b][m]
        )

    def flatten(self) -> DetectionsBatch:
        """All ``T * B`` frames as one batch (time-major: row ``t * B + b``)
        — the zero-copy entry into the batched feature/matching kernels."""
        T, B, K = self.boxes.shape[:3]
        return DetectionsBatch(
            boxes=self.boxes.reshape(T * B, K, 4),
            scores=self.scores.reshape(T * B, K),
            classes=self.classes.reshape(T * B, K),
            mask=self.mask.reshape(T * B, K),
        )

    @classmethod
    def from_frames(cls, frames: Sequence[Sequence[Detections]]) -> "DetectionClip":
        """Pad ragged per-frame-per-stream detections (``frames[t][b]``)."""
        top = max(
            (len(d) for fr in frames for d in fr), default=0
        )
        k = _pad_dim(top)
        batches = [DetectionsBatch.from_list(list(fr), max_boxes=k) for fr in frames]
        return cls(
            boxes=np.stack([fb.boxes for fb in batches]),
            scores=np.stack([fb.scores for fb in batches]),
            classes=np.stack([fb.classes for fb in batches]),
            mask=np.stack([fb.mask for fb in batches]),
        )


# --------------------------------------------------------------- generation


@dataclass
class _Object:
    box: np.ndarray      # (4,) float
    vel: np.ndarray      # (2,) float
    cls: int
    oid: int
    hidden_for: int = 0  # occlusion countdown


def _spawn(rng: np.random.Generator, cfg: SceneConfig, oid: int) -> _Object:
    cls = int(rng.integers(0, NUM_CLASSES))
    w = int(rng.integers(10, 31))
    h = int(rng.integers(10, 31))
    x1 = float(rng.integers(0, cfg.size - w))
    y1 = float(rng.integers(0, cfg.size - h))
    vel = rng.uniform(-cfg.speed, cfg.speed, 2)
    return _Object(
        box=np.array([x1, y1, x1 + w, y1 + h], float), vel=vel, cls=cls, oid=oid
    )


def _step_object(rng: np.random.Generator, cfg: SceneConfig, o: _Object) -> None:
    o.vel = o.vel + rng.normal(0.0, cfg.jitter, 2)
    o.vel = np.clip(o.vel, -2.0 * cfg.speed, 2.0 * cfg.speed)
    o.box = o.box + np.array([o.vel[0], o.vel[1], o.vel[0], o.vel[1]])
    # bounce off the frame, reflecting the velocity
    for ax, (lo_i, hi_i) in enumerate(((0, 2), (1, 3))):
        if o.box[lo_i] < 0.0:
            shift = -o.box[lo_i]
            o.box[lo_i] += shift
            o.box[hi_i] += shift
            o.vel[ax] = abs(o.vel[ax])
        elif o.box[hi_i] > cfg.size:
            shift = o.box[hi_i] - cfg.size
            o.box[lo_i] -= shift
            o.box[hi_i] -= shift
            o.vel[ax] = -abs(o.vel[ax])
    if o.hidden_for > 0:
        o.hidden_for -= 1


def generate_clip(
    n_streams: int,
    n_frames: int,
    *,
    seed: int = 0,
    config: Optional[SceneConfig] = None,
) -> VideoClip:
    """``B`` independent seeded streams of ``T`` frames each.  Streams use
    disjoint seed sequences ``(seed, b)``, so a clip is bit-identical for a
    given ``(n_streams, n_frames, seed, config)``."""
    cfg = config or SceneConfig()
    n = _pad_dim(cfg.max_objects)
    boxes = np.zeros((n_frames, n_streams, n, 4), np.float32)
    classes = np.full((n_frames, n_streams, n), -1, np.int32)
    ids = np.full((n_frames, n_streams, n), -1, np.int32)
    mask = np.zeros((n_frames, n_streams, n), bool)
    cuts = np.zeros((n_frames, n_streams), bool)
    for b in range(n_streams):
        rng = np.random.default_rng((seed, b))
        next_id = 0
        objects: List[_Object] = []

        def fresh_scene():
            nonlocal next_id, objects
            objects = []
            for _ in range(int(rng.integers(cfg.min_objects, cfg.max_objects + 1))):
                objects.append(_spawn(rng, cfg, next_id))
                next_id += 1

        fresh_scene()
        for t in range(n_frames):
            if t > 0:
                if rng.uniform() < cfg.p_cut:
                    fresh_scene()
                    cuts[t, b] = True
                else:
                    for o in objects:
                        _step_object(rng, cfg, o)
                    objects = [o for o in objects if rng.uniform() >= cfg.p_exit]
                    for o in objects:
                        if o.hidden_for == 0 and rng.uniform() < cfg.p_occlude:
                            o.hidden_for = int(rng.integers(1, cfg.occlude_max + 1))
                    if len(objects) < cfg.max_objects and rng.uniform() < cfg.p_enter:
                        objects.append(_spawn(rng, cfg, next_id))
                        next_id += 1
            visible = [o for o in objects if o.hidden_for == 0]
            for slot, o in enumerate(visible):
                boxes[t, b, slot] = o.box
                classes[t, b, slot] = o.cls
                ids[t, b, slot] = o.oid
                mask[t, b, slot] = True
    return VideoClip(
        boxes=boxes, classes=classes, ids=ids, mask=mask, cuts=cuts, size=cfg.size
    )


# ----------------------------------------------------- detection synthesis


def synthesize_detections(
    clip: VideoClip,
    profile: DetectorProfile = WEAK_PROFILE,
    *,
    seed: int = 0,
) -> DetectionClip:
    """Simulate one detector tier over a clip (geometrically, no pixels).

    Per stream, every object identity draws a persistent flipped class
    (prob ``flip``) and a persistent miss propensity ``U(0, 2 * miss)`` —
    the *same* object keeps failing the same way frame after frame, which
    is exactly the temporal correlation the video policies exploit.
    Box corners get i.i.d. gaussian jitter and scores are uniform in
    ``[score_lo, score_hi]``; occasional hallucinated boxes round it out.
    """
    T, B = clip.n_frames, clip.n_streams
    frames: List[List[Detections]] = [[] for _ in range(T)]
    for b in range(B):
        rng = np.random.default_rng((seed, b, 1))
        flip_cls: dict = {}
        miss_p: dict = {}
        for t in range(T):
            d_boxes, d_scores, d_cls = [], [], []
            for slot in np.flatnonzero(clip.mask[t, b]):
                oid = int(clip.ids[t, b, slot])
                if oid not in flip_cls:
                    if rng.uniform() < profile.flip:
                        wrong = int(rng.integers(0, NUM_CLASSES - 1))
                        true = int(clip.classes[t, b, slot])
                        flip_cls[oid] = wrong + (wrong >= true)
                    else:
                        flip_cls[oid] = int(clip.classes[t, b, slot])
                    miss_p[oid] = float(rng.uniform(0.0, 2.0 * profile.miss))
                if rng.uniform() < miss_p[oid]:
                    continue
                # scale is applied to the same unit draws, so profiles
                # differing only in jitter consume identical RNG streams
                sigma = profile.jitter_for(int(clip.classes[t, b, slot]))
                d_boxes.append(
                    clip.boxes[t, b, slot] + rng.normal(0.0, sigma, 4)
                )
                d_scores.append(rng.uniform(profile.score_lo, profile.score_hi))
                d_cls.append(flip_cls[oid])
            if rng.uniform() < profile.hallucinate:
                x1, y1 = rng.uniform(0, clip.size - 16, 2)
                w, h = rng.uniform(8, 24, 2)
                d_boxes.append(np.array([x1, y1, x1 + w, y1 + h]))
                d_scores.append(rng.uniform(0.1, 0.5))
                d_cls.append(int(rng.integers(0, NUM_CLASSES)))
            frames[t].append(
                Detections(
                    np.asarray(d_boxes, float).reshape(-1, 4),
                    np.asarray(d_scores, float),
                    np.asarray(d_cls, np.int64),
                )
            )
    return DetectionClip.from_frames(frames)


# ----------------------------------------------------------------- raster


def render_frame(
    clip: VideoClip, t: int, b: int, *, seed: int = 0
) -> np.ndarray:
    """Rasterize one frame through the shapes painter (for demos — the
    decision pipeline itself is purely geometric).  Background and object
    colours are functions of ``(seed, b)`` and the object identity, so a
    frame renders identically no matter which frames were drawn before."""
    rng = np.random.default_rng((seed, b, 2))
    img = _background(rng, clip.size)
    for slot in np.flatnonzero(clip.mask[t, b]):
        cls = int(clip.classes[t, b, slot])
        colour_rng = np.random.default_rng((seed, b, 3, int(clip.ids[t, b, slot])))
        paint_object(
            img, clip.boxes[t, b, slot], cls, class_colour(cls, colour_rng), colour_rng
        )
    return img
