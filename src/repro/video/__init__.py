"""Temporal offloading over video streams, on the batched data plane.

The paper scores each image independently; this package is the stream-level
layer its deployment setting actually needs — consecutive frames are
correlated, so both the reward estimate and an already-offloaded edge
result stay informative for several frames:

- :mod:`repro.video.scene` — seeded synthetic video (moving shapes with
  entry/exit/occlusion/cuts) + temporally-correlated weak/strong detection
  synthesis, on padded ``(T, B, ...)`` containers (:class:`VideoClip` /
  :class:`DetectionClip`),
- :mod:`repro.video.track` — the device-resident tracker: one jitted
  ``lax.scan`` over T on the ``iou_matrix`` Pallas kernel
  (:func:`track_clip`, streaming :class:`VideoTracker`), the Python
  reference oracle (:func:`track_clip_ref`), and stale-edge-result
  ``propagate``,
- :mod:`repro.video.features` — frame-difference / churn / EWMA temporal
  features,
- :mod:`repro.video.policy` — the ``temporal_hysteresis`` and ``keyframe``
  policies, registered in the ``repro.api`` engine registry,
- :mod:`repro.video.runtime` — :class:`VideoRuntime.serve_clip` (stale-
  result reuse + per-frame effective accuracy through the AP engine) and
  the seeded 8-stream congested scenario
  (:func:`default_video_scenario` / :func:`run_video_scenario`).

See docs/API.md ("Video & temporal offloading").
"""
from repro.video.features import (
    EwmaSmoother,
    detection_overlap,
    frame_difference,
    scene_change_score,
)
from repro.video.policy import KeyframePolicy, TemporalHysteresisPolicy
from repro.video.runtime import (
    VideoFleetTrace,
    VideoRuntime,
    VideoScenario,
    default_video_scenario,
    frame_accuracies,
    run_video_scenario,
)
from repro.video.scene import (
    STRONG_PROFILE,
    WEAK_PROFILE,
    DetectionClip,
    DetectorProfile,
    SceneConfig,
    VideoClip,
    generate_clip,
    render_frame,
    synthesize_detections,
)
from repro.video.track import (
    TrackerConfig,
    TrackFrame,
    TrackHistory,
    VideoTracker,
    greedy_match_boxes,
    propagate_rematch_ref,
    track_clip,
    track_clip_ref,
)

__all__ = [
    "SceneConfig",
    "DetectorProfile",
    "WEAK_PROFILE",
    "STRONG_PROFILE",
    "VideoClip",
    "DetectionClip",
    "generate_clip",
    "synthesize_detections",
    "render_frame",
    "TrackerConfig",
    "TrackFrame",
    "TrackHistory",
    "VideoTracker",
    "track_clip",
    "track_clip_ref",
    "greedy_match_boxes",
    "propagate_rematch_ref",
    "EwmaSmoother",
    "detection_overlap",
    "frame_difference",
    "scene_change_score",
    "TemporalHysteresisPolicy",
    "KeyframePolicy",
    "VideoRuntime",
    "VideoFleetTrace",
    "VideoScenario",
    "frame_accuracies",
    "default_video_scenario",
    "run_video_scenario",
]
