"""Device-resident multi-object tracker over the batched detection plane.

The temporal subsystem needs two primitives the per-image stack lacks:

* **association** — which detection in frame ``t`` is the same object as a
  detection in frame ``t-1`` (greedy IoU, class-gated, score order — the
  matching idiom of ``repro.detection.batch`` turned along time), and
* **propagation** — placing a *stale* result (an edge response that took a
  few frames to come back over the netsim link) onto the current frame.

Both run on the PR 3 data plane: per-frame IoU goes through the
``iou_matrix`` Pallas kernel (``iou_matrix_batch`` — one (B, K, N) tile
block per step over all streams), and a whole clip is tracked by ONE jitted
``lax.scan`` over T — no per-frame Python.  The track state is a fixed
``max_tracks`` padded struct-of-arrays per stream: box, constant-velocity
estimate, confidence, age, class, identity, active mask.

Update rules (all float32, deterministic):

- matched track: box := detection box, velocity := EMA of per-frame box
  deltas (``vel_smooth``), confidence pulled toward the detection score
  (``conf_update``), age reset;
- unmatched track: box coasts at constant velocity, confidence decays by
  ``conf_decay``, age grows; tracks die past ``max_age`` or below
  ``min_conf`` (dead slots are zeroed so state stays exactly reproducible);
- unmatched detections above ``spawn_score`` spawn into the lowest free
  slots in score order with fresh identities.

``track_clip_ref`` is the per-frame pure-Python reference associator —
the hypothesis oracle: the jitted scan must produce identical association
(identities, active masks, matches) on any clip.

``VideoTracker`` is the streaming form (one jitted step per frame, same
function the scan uses) and carries ``propagate(edge_dets, t0, t1)``:
stale edge detections are greedy-matched onto the *current* tracks and
snapped to their constant-velocity-updated boxes, with scores decayed by
``stale_decay`` per frame of staleness — the stale-result reuse primitive
the video policies credit.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.detection.batch import DetectionsBatch, _pad_dim
from repro.detection.map_engine import Detections
from repro.kernels.iou_matrix.ops import iou_matrix_batch, resolve_interpret


@dataclass(frozen=True)
class TrackerConfig:
    """Tracker knobs; frozen (and hashable) so it rides jit static args."""

    max_tracks: int = 16
    max_dets: int = 16          # per-frame detection slots (streaming pad)
    iou_thresh: float = 0.3     # association gate
    vel_smooth: float = 0.5     # EMA weight on the previous velocity
    conf_update: float = 0.5    # pull toward the matched detection score
    conf_decay: float = 0.75    # per unmatched frame
    max_age: int = 3            # unmatched frames before a track dies
    min_conf: float = 0.05
    spawn_score: float = 0.1    # min detection score to open a track
    stale_decay: float = 0.9    # propagate(): score decay per stale frame
    prop_iou: float = 0.2       # propagate(): stale-det -> track gate


@dataclass(kw_only=True)
class TrackFrame:
    """One frame's track state across ``B`` streams (host arrays).

    ``det_track[b, k]`` is the track slot detection ``k`` matched (-1 for
    unmatched/padded detections); ``n_active``/``n_matched``/``n_new``/
    ``n_dead`` are per-stream counts after the update.
    """

    boxes: np.ndarray      # (B, N, 4) float32
    vel: np.ndarray        # (B, N, 4) float32
    conf: np.ndarray       # (B, N) float32
    age: np.ndarray        # (B, N) int32
    classes: np.ndarray    # (B, N) int32, -1 inactive
    ids: np.ndarray        # (B, N) int32, -1 inactive
    active: np.ndarray     # (B, N) bool
    det_track: np.ndarray  # (B, K) int32
    n_active: np.ndarray   # (B,) int32
    n_matched: np.ndarray  # (B,) int32
    n_new: np.ndarray      # (B,) int32
    n_dead: np.ndarray     # (B,) int32

    def churn(self) -> np.ndarray:
        """Per-stream track churn in [0, 1]: births + deaths over the live
        population — the scene-change signal the keyframe policy probes."""
        turn = self.n_new + self.n_dead
        return turn / np.maximum(self.n_active + self.n_dead, 1)


@dataclass(kw_only=True)
class TrackHistory:
    """Stacked per-frame track state over a clip: the :class:`TrackFrame`
    arrays with a leading time axis."""

    boxes: np.ndarray
    vel: np.ndarray
    conf: np.ndarray
    age: np.ndarray
    classes: np.ndarray
    ids: np.ndarray
    active: np.ndarray
    det_track: np.ndarray
    n_active: np.ndarray
    n_matched: np.ndarray
    n_new: np.ndarray
    n_dead: np.ndarray

    @property
    def n_frames(self) -> int:
        return self.boxes.shape[0]

    def frame(self, t: int) -> TrackFrame:
        return TrackFrame(
            **{f: getattr(self, f)[t] for f in _FRAME_FIELDS}
        )


_FRAME_FIELDS = (
    "boxes", "vel", "conf", "age", "classes", "ids", "active", "det_track",
    "n_active", "n_matched", "n_new", "n_dead",
)


# ------------------------------------------------------------ jitted step


def _init_state(n_streams: int, cfg: TrackerConfig):
    B, N = n_streams, cfg.max_tracks
    return (
        jnp.zeros((B, N, 4), jnp.float32),          # boxes
        jnp.zeros((B, N, 4), jnp.float32),          # vel
        jnp.zeros((B, N), jnp.float32),             # conf
        jnp.zeros((B, N), jnp.int32),               # age
        jnp.full((B, N), -1, jnp.int32),            # classes
        jnp.full((B, N), -1, jnp.int32),            # ids
        jnp.zeros((B, N), bool),                    # active
        jnp.zeros((B,), jnp.int32),                 # next_id
    )


def _step(state, frame, cfg: TrackerConfig, interpret: bool):
    """One tracker update over all B streams — pure jnp/lax + the Pallas
    IoU kernel, scanned over T by :func:`track_clip`."""
    boxes, vel, conf, age, cls, ids, active, next_id = state
    d_boxes, d_scores, d_cls, d_mask = frame
    B, N = conf.shape
    K = d_scores.shape[1]

    # associate: IoU of detections vs constant-velocity-predicted tracks
    pred = boxes + vel
    iou = iou_matrix_batch(
        d_boxes, pred,
        tile_b=_pad_dim(B), tile_n=_pad_dim(K), tile_m=_pad_dim(N),
        interpret=interpret,
    )
    eligible = (
        d_mask[:, :, None]
        & active[:, None, :]
        & (d_cls[:, :, None] == cls[:, None, :])
    )
    miou = jnp.where(eligible, iou, -1.0)
    keys = jnp.where(d_mask, d_scores, -jnp.inf)
    order = jnp.argsort(-keys, axis=1, stable=True)            # (B, K)
    iou_s = jnp.take_along_axis(miou, order[:, :, None], axis=1)

    def assoc(taken, row):  # taken (B, N); row (B, N)
        avail = jnp.where(taken, -1.0, row)
        j = jnp.argmax(avail, axis=-1)
        best = jnp.take_along_axis(avail, j[:, None], axis=-1)[:, 0]
        hit = best >= cfg.iou_thresh
        slot = lax.broadcasted_iota(jnp.int32, (B, N), 1)
        taken = taken | (hit[:, None] & (slot == j[:, None].astype(jnp.int32)))
        return taken, (hit, jnp.where(hit, j.astype(jnp.int32), -1))

    _, (hit_s, tj_s) = lax.scan(
        assoc, jnp.zeros((B, N), bool), jnp.moveaxis(iou_s, 1, 0)
    )
    inv = jnp.argsort(order, axis=1)
    hit = jnp.take_along_axis(jnp.moveaxis(hit_s, 0, 1), inv, axis=1)  # (B, K)
    tj = jnp.take_along_axis(jnp.moveaxis(tj_s, 0, 1), inv, axis=1)

    # track-side inverse map: which detection matched each track slot
    bidx = lax.broadcasted_iota(jnp.int32, (B, K), 0)
    kidx = lax.broadcasted_iota(jnp.int32, (B, K), 1)
    det_of = (
        jnp.full((B, N), -1, jnp.int32)
        .at[bidx, jnp.where(hit, tj, N)]
        .set(kidx, mode="drop")
    )

    # update matched / coast unmatched
    matched = det_of >= 0
    sd = jnp.maximum(det_of, 0)
    dbox_t = jnp.take_along_axis(d_boxes, sd[:, :, None], axis=1)
    dscore_t = jnp.take_along_axis(d_scores, sd, axis=1)
    new_vel = cfg.vel_smooth * vel + (1.0 - cfg.vel_smooth) * (dbox_t - boxes)
    boxes = jnp.where(matched[:, :, None], dbox_t, pred)
    vel = jnp.where(matched[:, :, None], new_vel, vel)
    conf = jnp.where(
        matched,
        (1.0 - cfg.conf_update) * conf + cfg.conf_update * dscore_t,
        conf * cfg.conf_decay,
    )
    age = jnp.where(matched, 0, age + 1)
    survive = active & (matched | ((age <= cfg.max_age) & (conf >= cfg.min_conf)))
    n_dead = (active & ~survive).sum(axis=1).astype(jnp.int32)
    active = survive
    # zero dead/inactive slots so state is exactly reproducible
    boxes = jnp.where(active[:, :, None], boxes, 0.0)
    vel = jnp.where(active[:, :, None], vel, 0.0)
    conf = jnp.where(active, conf, 0.0)
    age = jnp.where(active, age, 0)
    cls = jnp.where(active, cls, -1)
    ids = jnp.where(active, ids, -1)

    # spawn unmatched detections into the lowest free slots, score order
    spawn = d_mask & ~hit & (d_scores >= cfg.spawn_score)
    spawn_s = jnp.take_along_axis(spawn, order, axis=1)
    rank = jnp.take_along_axis(
        jnp.cumsum(spawn_s.astype(jnp.int32), axis=1) - 1, inv, axis=1
    )
    slot_iota = lax.broadcasted_iota(jnp.int32, (B, N), 1)
    free_sorted = jnp.sort(jnp.where(~active, slot_iota, N), axis=1)
    free_padded = jnp.concatenate(
        [free_sorted, jnp.full((B, 1), N, jnp.int32)], axis=1
    )
    target = jnp.where(
        spawn,
        jnp.take_along_axis(free_padded, jnp.clip(rank, 0, N), axis=1),
        N,
    )
    placed = spawn & (target < N)
    n_new = placed.sum(axis=1).astype(jnp.int32)
    boxes = boxes.at[bidx, target].set(d_boxes, mode="drop")
    vel = vel.at[bidx, target].set(jnp.zeros_like(d_boxes), mode="drop")
    conf = conf.at[bidx, target].set(d_scores, mode="drop")
    age = age.at[bidx, target].set(jnp.zeros((B, K), jnp.int32), mode="drop")
    cls = cls.at[bidx, target].set(d_cls, mode="drop")
    ids = ids.at[bidx, target].set(next_id[:, None] + rank, mode="drop")
    active = active.at[bidx, target].set(jnp.ones((B, K), bool), mode="drop")
    next_id = next_id + n_new

    out = dict(
        boxes=boxes, vel=vel, conf=conf, age=age, classes=cls, ids=ids,
        active=active, det_track=jnp.where(hit, tj, -1),
        n_active=active.sum(axis=1).astype(jnp.int32),
        n_matched=(hit & d_mask).sum(axis=1).astype(jnp.int32),
        n_new=n_new, n_dead=n_dead,
    )
    return (boxes, vel, conf, age, cls, ids, active, next_id), out


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _step_jit(state, frame, cfg, interpret):
    return _step(state, frame, cfg, interpret)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _scan_jit(state, frames, cfg, interpret):
    return lax.scan(lambda s, fr: _step(s, fr, cfg, interpret), state, frames)


def _frame_arrays(batch: DetectionsBatch, max_dets: int):
    k = batch.max_boxes
    if k > max_dets:
        raise ValueError(
            f"frame has {k} detection slots, tracker pads to max_dets={max_dets}"
        )
    pad = ((0, 0), (0, max_dets - k))
    return (
        jnp.asarray(np.pad(batch.boxes, pad + ((0, 0),))),
        jnp.asarray(np.pad(batch.scores, pad)),
        jnp.asarray(np.pad(batch.classes, pad, constant_values=-1)),
        jnp.asarray(np.pad(batch.mask, pad)),
    )


def track_clip(
    dets: "DetectionClip",
    config: Optional[TrackerConfig] = None,
    *,
    interpret: Optional[bool] = None,
) -> TrackHistory:
    """Track a whole clip as ONE jitted ``lax.scan`` over T — per-frame IoU
    through the Pallas kernel, association + state update as masked lax ops
    over all streams at once."""
    cfg = config or TrackerConfig()
    frames = (
        jnp.asarray(dets.boxes, jnp.float32),
        jnp.asarray(dets.scores, jnp.float32),
        jnp.asarray(dets.classes, jnp.int32),
        jnp.asarray(dets.mask),
    )
    _, out = _scan_jit(
        _init_state(dets.n_streams, cfg), frames, cfg, resolve_interpret(interpret)
    )
    return TrackHistory(**{k: np.asarray(v) for k, v in out.items()})


# -------------------------------------------------------- Python reference


def _iou_f32(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """The Pallas kernel's IoU arithmetic, elementwise in float32 — the
    reference associator must round identically to the device path."""
    a = np.asarray(a, np.float32).reshape(-1, 4)
    b = np.asarray(b, np.float32).reshape(-1, 4)
    lt = np.maximum(a[:, None, :2], b[None, :, :2])
    rb = np.minimum(a[:, None, 2:], b[None, :, 2:])
    wh = np.maximum(rb - lt, np.float32(0.0))
    inter = wh[..., 0] * wh[..., 1]
    area_a = np.maximum(a[:, 2] - a[:, 0], 0) * np.maximum(a[:, 3] - a[:, 1], 0)
    area_b = np.maximum(b[:, 2] - b[:, 0], 0) * np.maximum(b[:, 3] - b[:, 1], 0)
    union = area_a[:, None] + area_b[None, :] - inter
    return np.where(
        union > 0, inter / np.maximum(union, np.float32(1e-12)), np.float32(0.0)
    ).astype(np.float32)


def greedy_match_boxes(
    boxes: np.ndarray,
    scores: np.ndarray,
    targets: np.ndarray,
    iou_thresh: float,
    eligible: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Host-side greedy IoU assignment — the association idiom shared by
    ``propagate``, its rematch baseline, and the frame-difference feature:
    boxes claim targets in descending score order (stable), one target per
    box, gated by ``iou_thresh`` (and an optional ``(n_boxes, n_targets)``
    eligibility mask).  Returns the matched target index per box, -1 for
    unmatched."""
    match = np.full(len(boxes), -1, np.int32)
    if not len(boxes) or not len(targets):
        return match
    miou = _iou_f32(boxes, targets)
    if eligible is not None:
        miou = np.where(eligible, miou, np.float32(-1.0))
    taken = np.zeros(len(targets), bool)
    for k in np.argsort(-np.asarray(scores), kind="stable"):
        avail = np.where(taken, np.float32(-1.0), miou[k])
        j = int(np.argmax(avail))
        if avail[j] >= iou_thresh:
            taken[j] = True
            match[k] = j
    return match


def track_clip_ref(
    dets: "DetectionClip", config: Optional[TrackerConfig] = None
) -> TrackHistory:
    """Per-frame pure-Python/numpy reference tracker (float32 throughout) —
    the correctness oracle for the jitted scan."""
    cfg = config or TrackerConfig()
    f32 = np.float32
    T, B, K = dets.n_frames, dets.n_streams, dets.max_boxes
    N = cfg.max_tracks
    out = {
        "boxes": np.zeros((T, B, N, 4), f32),
        "vel": np.zeros((T, B, N, 4), f32),
        "conf": np.zeros((T, B, N), f32),
        "age": np.zeros((T, B, N), np.int32),
        "classes": np.full((T, B, N), -1, np.int32),
        "ids": np.full((T, B, N), -1, np.int32),
        "active": np.zeros((T, B, N), bool),
        "det_track": np.full((T, B, K), -1, np.int32),
        "n_active": np.zeros((T, B), np.int32),
        "n_matched": np.zeros((T, B), np.int32),
        "n_new": np.zeros((T, B), np.int32),
        "n_dead": np.zeros((T, B), np.int32),
    }
    for b in range(B):
        boxes = np.zeros((N, 4), f32)
        vel = np.zeros((N, 4), f32)
        conf = np.zeros(N, f32)
        age = np.zeros(N, np.int32)
        cls = np.full(N, -1, np.int32)
        ids = np.full(N, -1, np.int32)
        active = np.zeros(N, bool)
        next_id = 0
        for t in range(T):
            d_boxes = dets.boxes[t, b].astype(f32)
            d_scores = dets.scores[t, b].astype(f32)
            d_cls = dets.classes[t, b]
            d_mask = dets.mask[t, b]
            pred = boxes + vel
            iou = _iou_f32(d_boxes, pred)
            eligible = (
                d_mask[:, None] & active[None, :] & (d_cls[:, None] == cls[None, :])
            )
            miou = np.where(eligible, iou, f32(-1.0))
            keys = np.where(d_mask, d_scores, -np.inf)
            order = np.argsort(-keys, kind="stable")
            taken = np.zeros(N, bool)
            hit = np.zeros(K, bool)
            tj = np.full(K, -1, np.int32)
            for k in order:
                avail = np.where(taken, f32(-1.0), miou[k])
                j = int(np.argmax(avail))
                if avail[j] >= cfg.iou_thresh:
                    taken[j] = True
                    hit[k] = True
                    tj[k] = j
            det_of = np.full(N, -1, np.int32)
            det_of[tj[hit]] = np.flatnonzero(hit)
            matched = det_of >= 0
            sd = np.maximum(det_of, 0)
            new_vel = f32(cfg.vel_smooth) * vel + f32(1.0 - cfg.vel_smooth) * (
                d_boxes[sd] - boxes
            )
            boxes = np.where(matched[:, None], d_boxes[sd], pred)
            vel = np.where(matched[:, None], new_vel, vel)
            conf = np.where(
                matched,
                f32(1.0 - cfg.conf_update) * conf + f32(cfg.conf_update) * d_scores[sd],
                conf * f32(cfg.conf_decay),
            ).astype(f32)
            age = np.where(matched, 0, age + 1).astype(np.int32)
            survive = active & (
                matched | ((age <= cfg.max_age) & (conf >= cfg.min_conf))
            )
            n_dead = int((active & ~survive).sum())
            active = survive
            boxes = np.where(active[:, None], boxes, f32(0.0))
            vel = np.where(active[:, None], vel, f32(0.0))
            conf = np.where(active, conf, f32(0.0)).astype(f32)
            age = np.where(active, age, 0).astype(np.int32)
            cls = np.where(active, cls, -1).astype(np.int32)
            ids = np.where(active, ids, -1).astype(np.int32)
            spawn = d_mask & ~hit & (d_scores >= cfg.spawn_score)
            free = np.flatnonzero(~active)
            n_new = 0
            for r, k in enumerate(order[spawn[order]]):
                if r >= free.size:
                    break
                slot = free[r]
                boxes[slot] = d_boxes[k]
                vel[slot] = 0.0
                conf[slot] = d_scores[k]
                age[slot] = 0
                cls[slot] = d_cls[k]
                ids[slot] = next_id + r
                active[slot] = True
                n_new += 1
            next_id += n_new
            out["boxes"][t, b] = boxes
            out["vel"][t, b] = vel
            out["conf"][t, b] = conf
            out["age"][t, b] = age
            out["classes"][t, b] = cls
            out["ids"][t, b] = ids
            out["active"][t, b] = active
            out["det_track"][t, b] = np.where(hit, tj, -1)
            out["n_active"][t, b] = int(active.sum())
            out["n_matched"][t, b] = int((hit & d_mask).sum())
            out["n_new"][t, b] = n_new
            out["n_dead"][t, b] = n_dead
    return TrackHistory(**out)


# ------------------------------------------------------------- streaming


class VideoTracker:
    """Streaming tracker over ``n_streams`` parallel streams: one jitted
    step per arriving frame (the same function :func:`track_clip` scans),
    plus the stale-result ``propagate`` primitive."""

    def __init__(
        self,
        n_streams: int = 1,
        config: Optional[TrackerConfig] = None,
        *,
        interpret: Optional[bool] = None,
    ):
        self.config = config or TrackerConfig()
        self.n_streams = int(n_streams)
        self._interpret = resolve_interpret(interpret)
        self.reset()

    def reset(self) -> None:
        self._state = _init_state(self.n_streams, self.config)
        self.frame_index = 0
        self._last: Optional[TrackFrame] = None

    @property
    def snapshot(self) -> Optional[TrackFrame]:
        """Track state after the most recent ``update`` (None before)."""
        return self._last

    def update(self, frame: DetectionsBatch) -> TrackFrame:
        """Advance every stream by one frame of detections (``len(frame)``
        must equal ``n_streams``)."""
        if len(frame) != self.n_streams:
            raise ValueError(
                f"frame batch has {len(frame)} streams, tracker {self.n_streams}"
            )
        self._state, out = _step_jit(
            self._state,
            _frame_arrays(frame, self.config.max_dets),
            self.config,
            self._interpret,
        )
        self.frame_index += 1
        self._last = TrackFrame(**{k: np.asarray(v) for k, v in out.items()})
        return self._last

    def propagate(
        self, dets: Detections, t0: float, t1: float, *, stream: int = 0
    ) -> Detections:
        """Reuse a stale edge result: place detections observed at frame
        ``t0`` onto frame ``t1`` by snapping them to the current tracks.

        Each stale detection greedy-matches by pure IoU (>= ``prop_iou``,
        score order) against the stream's active track boxes — which the
        tracker has been coasting/correcting since ``t0`` — and takes the
        matched track's box while KEEPING its own class: the tracks supply
        up-to-date geometry, the edge result supplies the (better) labels,
        so the association is deliberately class-agnostic — the weak
        detector's misclassified objects are exactly the ones a stale edge
        result must still land on.  Unmatched detections keep their stale
        geometry.  Scores decay by ``stale_decay ** (t1 - t0)``.
        """
        dt = float(t1) - float(t0)
        if dt < 0:
            raise ValueError(f"propagate backwards in time: t0={t0} > t1={t1}")
        scores = np.asarray(dets.scores, np.float64) * (self.config.stale_decay ** dt)
        out_boxes = np.asarray(dets.boxes, np.float64).copy()
        snap = self._last
        if len(dets) and snap is not None and snap.active[stream].any():
            t_boxes = snap.boxes[stream][np.flatnonzero(snap.active[stream])]
            match = greedy_match_boxes(
                dets.boxes, scores, t_boxes, self.config.prop_iou
            )
            hit = match >= 0
            out_boxes[hit] = t_boxes[match[hit]]
        return Detections(out_boxes, scores, np.asarray(dets.classes).copy())


def propagate_rematch_ref(
    edge_dets: Detections,
    weak_frames: Sequence[Detections],
    *,
    stale_decay: float = 0.9,
    iou_thresh: float = 0.2,
) -> Detections:
    """The naive alternative to tracked propagation: carry a stale result
    forward by re-matching it against EVERY intermediate frame's weak
    detections (per-frame Python greedy matching) — the O(T · N · M)
    baseline ``bench_video_pipeline`` compares the tracker against."""
    boxes = np.asarray(edge_dets.boxes, np.float64).copy()
    classes = np.asarray(edge_dets.classes)
    scores = np.asarray(edge_dets.scores, np.float64).copy()
    for wdet in weak_frames:
        # class-agnostic like VideoTracker.propagate: geometry from the
        # weak stream, labels from the edge result
        match = greedy_match_boxes(boxes, scores, wdet.boxes, iou_thresh)
        hit = match >= 0
        boxes[hit] = np.asarray(wdet.boxes, np.float64)[match[hit]]
    scores = scores * (stale_decay ** len(weak_frames))
    return Detections(boxes, scores, classes.copy())
