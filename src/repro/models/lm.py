"""Unified LM assembly covering all assigned architecture families.

One ``LMConfig`` drives six block patterns: dense decoder (GQA), MoE decoder
(shared+routed experts, optional MLA), RWKV6 stack, Mamba2/attention hybrid
(Zamba2), encoder-decoder (Whisper), and VLM decoder (M-RoPE, vision-prefix
splice).  Layer stacks are ``jax.lax.scan`` over stacked params so HLO size
is depth-independent; per-layer bodies are ``jax.checkpoint``-ed for
training when ``cfg.remat``.

API (all functional):
  init_params(cfg, key)       real params (smoke scale)
  abstract_params(cfg)        ShapeDtypeStruct pytree (dry-run, no alloc)
  forward(params, cfg, batch) logits (B, S, V) — train/prefill path
  loss_fn(params, cfg, batch) scalar CE (+ MoE aux)
  init_cache(cfg, B, capacity[, abstract]) decode cache pytree
  prefill(params, cfg, batch, capacity) -> (last_logits, cache)
  decode_step(params, cfg, cache, tokens, pos) -> (logits, cache)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.meshctx import constrain
from repro.models.layers import (
    AttnConfig,
    Mamba2Config,
    MLAConfig,
    MoEConfig,
    RWKV6Config,
    attention_apply,
    attention_decode,
    attention_init,
    dense_init,
    gelu_mlp,
    gelu_mlp_init,
    layernorm,
    layernorm_init,
    mamba2_apply,
    mamba2_init,
    mla_apply,
    mla_decode,
    mla_init,
    moe_apply,
    moe_init,
    rmsnorm,
    rmsnorm_init,
    rwkv6_channel_mix,
    rwkv6_init,
    rwkv6_time_mix,
    swiglu,
    swiglu_init,
)

PyTree = Any


def _scan(cfg: "LMConfig"):
    """Layer-stack scan with a config-controlled unroll factor.

    ``layer_unroll = 1``: normal scan (depth-independent HLO).
    ``layer_unroll = -1``: fully unrolled — used ONLY by the dry-run's
    cost probes, because XLA's cost_analysis counts a while-loop body
    once regardless of trip count (see EXPERIMENTS.md §Roofline).
    """
    unroll = True if cfg.layer_unroll == -1 else max(cfg.layer_unroll, 1)
    return functools.partial(jax.lax.scan, unroll=unroll)



@dataclass(frozen=True)
class LMConfig:
    name: str
    arch_type: str  # dense | moe | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    window: int = 0  # >0: sliding-window attention (long-context variant)
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    first_k_dense: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 0  # >0: group-local dispatch (§Perf hillclimb #1)
    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    # RWKV6
    rwkv_head_size: int = 64
    # hybrid (zamba2)
    ssm_state: int = 64
    mamba_head_dim: int = 64
    shared_attn_period: int = 6  # every Nth layer = shared attention block
    # encdec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # vlm (qwen2-vl)
    mrope_sections: Optional[Tuple[int, int, int]] = None
    vision_tokens: int = 0  # vision-prefix length in the token stream
    use_rope: bool = True  # False: absolute positions only (whisper)
    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    scan_chunk: int = 128  # recurrent-scan remat chunk
    attn_chunk: int = 1024  # query-chunked SDPA block (0 = unchunked)
    attn_seq_shard: bool = False  # context-parallel attention (§Perf #2)
    kv_quant: bool = False  # int8 KV cache with per-slot scales (§Perf #3)
    layer_unroll: int = 1  # -1 = full unroll (dry-run cost probes only)

    # ---- derived sub-configs -------------------------------------------
    @property
    def act_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def attn(self, window: Optional[int] = None) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model,
            num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads,
            head_dim=self.head_dim,
            qkv_bias=self.qkv_bias,
            qk_norm=self.qk_norm,
            window=self.window if window is None else window,
            rope_theta=self.rope_theta,
            use_rope=self.use_rope,
            mrope_sections=self.mrope_sections,
            attn_chunk=self.attn_chunk,
            seq_shard=self.attn_seq_shard,
        )

    def moe(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model,
            d_ff_expert=self.d_ff_expert,
            num_experts=self.num_experts,
            top_k=self.top_k,
            num_shared=self.num_shared_experts,
            capacity_factor=self.capacity_factor,
            groups=self.moe_groups,
        )

    def mla(self) -> MLAConfig:
        return MLAConfig(
            d_model=self.d_model,
            num_heads=self.num_heads,
            kv_lora_rank=self.kv_lora_rank,
            qk_nope_dim=self.qk_nope_dim,
            qk_rope_dim=self.qk_rope_dim,
            v_dim=self.head_dim,
            rope_theta=self.rope_theta,
            attn_chunk=self.attn_chunk,
        )

    def rwkv(self) -> RWKV6Config:
        return RWKV6Config(
            d_model=self.d_model,
            head_size=self.rwkv_head_size,
            ffn_mult=self.d_ff / self.d_model,
        )

    def mamba(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model,
            d_state=self.ssm_state,
            head_dim=self.mamba_head_dim,
        )

    @property
    def num_shared_attn(self) -> int:
        """#shared-attention applications in a hybrid stack."""
        if self.arch_type != "hybrid":
            return 0
        return self.num_layers // self.shared_attn_period

    @property
    def num_mamba_layers(self) -> int:
        return self.num_layers - self.num_shared_attn


def reduced(cfg: LMConfig, **overrides) -> LMConfig:
    """Smoke-test variant: 2 layers, d_model<=256, <=4 experts."""
    small: Dict[str, Any] = dict(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
        scan_chunk=16,
        encoder_frames=32 if cfg.arch_type == "encdec" else cfg.encoder_frames,
        vision_tokens=8 if cfg.arch_type == "vlm" else 0,
    )
    if cfg.arch_type == "encdec":
        small["encoder_layers"] = 2
    if cfg.num_experts:
        small.update(num_experts=4, top_k=2, d_ff_expert=64,
                     num_shared_experts=min(cfg.num_shared_experts, 1),
                     first_k_dense=min(cfg.first_k_dense, 1),
                     capacity_factor=8.0)  # no token drops at smoke scale
    if cfg.use_mla:
        small.update(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, head_dim=32)
    if cfg.arch_type == "rwkv":
        small.update(rwkv_head_size=32, num_heads=4)
    if cfg.arch_type == "hybrid":
        small.update(num_layers=4, shared_attn_period=2, mamba_head_dim=32,
                     ssm_state=16, head_dim=32)
    if cfg.mrope_sections is not None:
        small["mrope_sections"] = (4, 6, 6)  # sums to head_dim/2 = 16
    small.update(overrides)
    return replace(cfg, name=cfg.name + "-smoke", **small)


# ===========================================================================
# init
# ===========================================================================

def _block_init(key, cfg: LMConfig, kind: str) -> PyTree:
    """One layer's params.  kind: dense|moe|moe_dense|rwkv|mamba|shared_attn|enc|dec."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    if kind in ("dense", "moe_dense"):
        d_ff = cfg.d_ff
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": mla_init(k1, cfg.mla()) if cfg.use_mla else attention_init(k1, cfg.attn()),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": swiglu_init(k2, cfg.d_model, d_ff),
        }
    if kind == "moe":
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": mla_init(k1, cfg.mla()) if cfg.use_mla else attention_init(k1, cfg.attn()),
            "norm2": rmsnorm_init(cfg.d_model),
            "moe": moe_init(k2, cfg.moe()),
        }
    if kind == "rwkv":
        return {
            "ln1": layernorm_init(cfg.d_model),
            "tm": rwkv6_init(k1, cfg.rwkv()),
            "ln2": layernorm_init(cfg.d_model),
        }
    if kind == "mamba":
        return {
            "norm": rmsnorm_init(cfg.d_model),
            "mamba": mamba2_init(k1, cfg.mamba()),
        }
    if kind == "shared_attn":
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attention_init(k1, cfg.attn()),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff),
        }
    if kind == "enc":
        return {
            "norm1": layernorm_init(cfg.d_model),
            "attn": attention_init(k1, cfg.attn()._replace(mrope_sections=None)),
            "norm2": layernorm_init(cfg.d_model),
            "mlp": gelu_mlp_init(k2, cfg.d_model, cfg.d_ff),
        }
    if kind == "dec":
        return {
            "norm1": layernorm_init(cfg.d_model),
            "self_attn": attention_init(k1, cfg.attn()),
            "norm_x": layernorm_init(cfg.d_model),
            "cross_attn": attention_init(k2, cfg.attn()),
            "norm2": layernorm_init(cfg.d_model),
            "mlp": gelu_mlp_init(k3, cfg.d_model, cfg.d_ff),
        }
    raise ValueError(kind)


def _stack_init(key, cfg: LMConfig, kind: str, n: int) -> PyTree:
    keys = jax.random.split(key, max(n, 1))
    layers = [_block_init(keys[i], cfg, kind) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers) if n else None


def init_params(cfg: LMConfig, key) -> PyTree:
    ks = jax.random.split(key, 8)
    p: PyTree = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02),
        "final_norm": (
            layernorm_init(cfg.d_model)
            if cfg.arch_type in ("rwkv", "encdec")
            else rmsnorm_init(cfg.d_model)
        ),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), scale=0.02)
    at = cfg.arch_type
    if at in ("dense", "vlm"):
        p["layers"] = _stack_init(ks[2], cfg, "dense", cfg.num_layers)
    elif at == "moe":
        if cfg.first_k_dense:
            p["dense_layers"] = _stack_init(ks[2], cfg, "moe_dense", cfg.first_k_dense)
        p["moe_layers"] = _stack_init(
            ks[3], cfg, "moe", cfg.num_layers - cfg.first_k_dense
        )
    elif at == "rwkv":
        p["layers"] = _stack_init(ks[2], cfg, "rwkv", cfg.num_layers)
    elif at == "hybrid":
        G = cfg.num_shared_attn
        per = cfg.shared_attn_period - 1  # mamba layers per group
        p["mamba_groups"] = jax.tree.map(
            lambda a: a.reshape(G, per, *a.shape[1:]),
            _stack_init(ks[2], cfg, "mamba", G * per),
        )
        p["shared_block"] = _block_init(ks[3], cfg, "shared_attn")
    elif at == "encdec":
        p["enc_layers"] = _stack_init(ks[2], cfg, "enc", cfg.encoder_layers)
        p["dec_layers"] = _stack_init(ks[3], cfg, "dec", cfg.num_layers)
        p["enc_norm"] = layernorm_init(cfg.d_model)
    else:
        raise ValueError(at)
    return p


def abstract_params(cfg: LMConfig) -> PyTree:
    """ShapeDtypeStruct pytree — no allocation (dry-run)."""
    out = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    # dry-run params are bf16-weight: re-type leaves to act dtype except norms
    def retype(x):
        return jax.ShapeDtypeStruct(x.shape, cfg.act_dtype if x.dtype == jnp.float32 else x.dtype)
    return jax.tree.map(retype, out)


# ===========================================================================
# forward (train / prefill)
# ===========================================================================

def _positions(batch: Dict, B: int, S: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def _maybe_remat(fn, cfg: LMConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _embed(params, cfg: LMConfig, batch) -> jnp.ndarray:
    tok = batch["tokens"]
    emb = params["embed"].astype(cfg.act_dtype)[tok]
    if cfg.arch_type == "vlm" and cfg.vision_tokens:
        ve = batch["vision_embeds"].astype(cfg.act_dtype)
        emb = jnp.concatenate([ve, emb[:, cfg.vision_tokens :]], axis=1)
    return constrain(emb, "batch", None, None)


def _logits(params, cfg: LMConfig, h) -> jnp.ndarray:
    h = (
        layernorm(params["final_norm"], h)
        if cfg.arch_type in ("rwkv", "encdec")
        else rmsnorm(params["final_norm"], h)
    )
    if cfg.tie_embeddings:
        # the tied table is d_model-sharded for the lookup; reshard it
        # vocab-sharded here (one table-sized collective) so the logits
        # matmul partitions on vocab instead of all-reducing (B,S,V)
        w = constrain(params["embed"].astype(h.dtype).T, None, "model")
    else:
        w = params["unembed"].astype(h.dtype)
    logits = h @ w
    return constrain(logits, "batch", None, "model")


def _dense_block(lp, cfg: LMConfig, h, positions, positions_3d):
    if cfg.use_mla:
        a = mla_apply(lp["attn"], cfg.mla(), rmsnorm(lp["norm1"], h), positions)
    else:
        a = attention_apply(
            lp["attn"], cfg.attn(), rmsnorm(lp["norm1"], h), positions, positions_3d
        )
    h = h + constrain(a, "batch", None, None)
    h = h + swiglu(lp["mlp"], rmsnorm(lp["norm2"], h))
    return h


def _moe_block(lp, cfg: LMConfig, h, positions, aux):
    if cfg.use_mla:
        a = mla_apply(lp["attn"], cfg.mla(), rmsnorm(lp["norm1"], h), positions)
    else:
        a = attention_apply(lp["attn"], cfg.attn(), rmsnorm(lp["norm1"], h), positions)
    h = h + constrain(a, "batch", None, None)
    out, aux_l = moe_apply(lp["moe"], cfg.moe(), rmsnorm(lp["norm2"], h))
    return h + out, aux + aux_l


def _rwkv_block(lp, cfg: LMConfig, h, state, x_tm, x_cm):
    a, state, x_tm = rwkv6_time_mix(
        lp["tm"], cfg.rwkv(), layernorm(lp["ln1"], h), state, x_tm,
        chunk=cfg.scan_chunk,
    )
    h = h + a
    c, x_cm = rwkv6_channel_mix(lp["tm"], layernorm(lp["ln2"], h), x_cm)
    return h + c, state, x_tm, x_cm


def _mamba_block(lp, cfg: LMConfig, h, ssm, conv):
    out, ssm, conv = mamba2_apply(
        lp["mamba"], cfg.mamba(), rmsnorm(lp["norm"], h), ssm, conv,
        chunk=cfg.scan_chunk,
    )
    return h + out, ssm, conv


def _shared_attn_block(sp, cfg: LMConfig, h, positions):
    a = attention_apply(sp["attn"], cfg.attn(), rmsnorm(sp["norm1"], h), positions)
    h = h + constrain(a, "batch", None, None)
    return h + swiglu(sp["mlp"], rmsnorm(sp["norm2"], h))


def forward(params: PyTree, cfg: LMConfig, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits, moe_aux)."""
    h = _embed(params, cfg, batch)
    B, S, _ = h.shape
    positions = _positions(batch, B, S)
    positions_3d = batch.get("positions_3d")
    aux = jnp.zeros((), jnp.float32)
    at = cfg.arch_type

    if at in ("dense", "vlm"):
        body = _maybe_remat(
            lambda hh, lp: (_dense_block(lp, cfg, hh, positions, positions_3d), None),
            cfg,
        )
        h, _ = _scan(cfg)(body, h, params["layers"])
    elif at == "moe":
        if cfg.first_k_dense:
            body_d = _maybe_remat(
                lambda hh, lp: (_dense_block(lp, cfg, hh, positions, None), None), cfg
            )
            h, _ = _scan(cfg)(body_d, h, params["dense_layers"])

        def moe_body(carry, lp):
            hh, ax = carry
            hh, ax = _moe_block(lp, cfg, hh, positions, ax)
            return (hh, ax), None

        (h, aux), _ = _scan(cfg)(_maybe_remat(moe_body, cfg), (h, aux), params["moe_layers"])
    elif at == "rwkv":
        def rwkv_body(hh, lp):
            hh, _, _, _ = _rwkv_block(lp, cfg, hh, None, None, None)
            return hh, None

        h, _ = _scan(cfg)(_maybe_remat(rwkv_body, cfg), h, params["layers"])
    elif at == "hybrid":
        sp = params["shared_block"]

        def group_body(hh, gp):
            def mamba_body(hhh, lp):
                hhh, _, _ = _mamba_block(lp, cfg, hhh, None, None)
                return hhh, None

            hh, _ = _scan(cfg)(mamba_body, hh, gp)
            hh = _shared_attn_block(sp, cfg, hh, positions)
            return hh, None

        h, _ = _scan(cfg)(_maybe_remat(group_body, cfg), h, params["mamba_groups"])
    elif at == "encdec":
        enc = _encode(params, cfg, batch)
        h = _decode_stack(params, cfg, h, enc, positions)
    else:
        raise ValueError(at)
    return _logits(params, cfg, h), aux


def _encode(params, cfg: LMConfig, batch) -> jnp.ndarray:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    x = batch["audio_frames"].astype(cfg.act_dtype)
    B, F, _ = x.shape
    pos = _sinusoid(F, cfg.d_model, x.dtype)
    h = x + pos[None]
    full = jnp.ones((1, F, F), bool)  # bidirectional
    positions = None  # whisper: no rope; absolute sinusoid added above

    def body(hh, lp):
        a = attention_apply(
            lp["attn"], cfg.attn()._replace(rope_theta=0.0, mrope_sections=None),
            layernorm(lp["norm1"], hh), None, None, mask=full,
        )
        hh = hh + a
        hh = hh + gelu_mlp(lp["mlp"], layernorm(lp["norm2"], hh))
        return hh, None

    h, _ = _scan(cfg)(_maybe_remat(body, cfg), h, params["enc_layers"])
    return layernorm(params["enc_norm"], h)


def _sinusoid(n: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _decode_stack(params, cfg: LMConfig, h, enc, positions):
    """Whisper decoder: causal self-attn + cross-attn + GELU MLP.
    Sinusoidal decoder positions (deviation from learned; see DESIGN.md)."""
    B, S, _ = h.shape
    F = enc.shape[1]
    h = h + _sinusoid(S, cfg.d_model, h.dtype)[None]
    cross_mask = jnp.ones((1, S, F), bool)

    def body(hh, lp):
        a = attention_apply(
            lp["self_attn"], cfg.attn()._replace(mrope_sections=None),
            layernorm(lp["norm1"], hh), None, None, mask=None,
        )
        hh = hh + a
        x = _cross_attention(lp["cross_attn"], cfg, layernorm(lp["norm_x"], hh), enc, cross_mask)
        hh = hh + x
        hh = hh + gelu_mlp(lp["mlp"], layernorm(lp["norm2"], hh))
        return hh, None

    h, _ = _scan(cfg)(_maybe_remat(body, cfg), h, params["dec_layers"])
    return h


def _cross_attention(ap, cfg: LMConfig, x, enc, mask):
    """Cross-attention reusing GQA projections (q from x, k/v from enc)."""
    from repro.models.layers import _project_qkv, _sdpa  # internal reuse

    acfg = cfg.attn()._replace(mrope_sections=None)
    B, S, _ = x.shape
    q = (x @ ap["wq"].astype(x.dtype))
    if acfg.qkv_bias:
        q = q + ap["bq"].astype(x.dtype)
    q = q.reshape(B, S, acfg.num_heads, acfg.head_dim)
    k = (enc @ ap["wk"].astype(x.dtype)).reshape(B, -1, acfg.num_kv_heads, acfg.head_dim)
    v = (enc @ ap["wv"].astype(x.dtype)).reshape(B, -1, acfg.num_kv_heads, acfg.head_dim)
    out = _sdpa(q, k, v, mask, acfg.num_kv_heads, acfg.num_heads)
    return out @ ap["wo"].astype(x.dtype)


def loss_fn(params: PyTree, cfg: LMConfig, batch: Dict) -> jnp.ndarray:
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    # vocab-sharded CE: keep the f32 logits sharded on the vocab axis and
    # select the gold logit with an iota mask (a take_along_axis over a
    # sharded vocab dim forces an all-gather of the full-vocab f32 logits
    # in the backward pass — found via the §Perf HLO forensics)
    logits = constrain(logits.astype(jnp.float32), "batch", None, "model")
    lse = jax.nn.logsumexp(logits, axis=-1)
    v_iota = jnp.arange(logits.shape[-1])[None, None, :]
    masked = constrain(
        jnp.where(v_iota == safe[..., None], logits, 0.0),
        "batch", None, "model",
    )
    gold = jnp.sum(masked, axis=-1)
    nll = (lse - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1) + aux


# ===========================================================================
# decode path (serve_step)
# ===========================================================================

def init_cache(
    cfg: LMConfig, batch: int, capacity: int, abstract: bool = False
) -> PyTree:
    """Decode-cache pytree.  ``capacity`` = KV slots (window size when
    cfg.window>0).  Includes a scalar position is NOT stored here — the
    caller passes ``pos`` each step."""
    L, B, C = cfg.num_layers, batch, capacity
    K, D = cfg.num_kv_heads, cfg.head_dim
    dt = cfg.act_dtype

    def z(shape, dtype):
        return (
            jax.ShapeDtypeStruct(shape, dtype)
            if abstract
            else jnp.zeros(shape, dtype)
        )

    at = cfg.arch_type
    if at in ("dense", "vlm") or (at == "moe" and not cfg.use_mla):
        if cfg.kv_quant:
            return {
                "k": z((L, B, C, K, D), jnp.int8),
                "v": z((L, B, C, K, D), jnp.int8),
                "k_s": z((L, B, C, K), jnp.float32),
                "v_s": z((L, B, C, K), jnp.float32),
            }
        cache = {"k": z((L, B, C, K, D), dt), "v": z((L, B, C, K, D), dt)}
        return cache
    if at == "moe" and cfg.use_mla:
        return {
            "c": z((L, B, C, cfg.kv_lora_rank), dt),
            "kr": z((L, B, C, cfg.qk_rope_dim), dt),
        }
    if at == "rwkv":
        H = cfg.d_model // cfg.rwkv_head_size
        hd = cfg.rwkv_head_size
        M = cfg.d_model
        return {
            "state": z((L, B, H, hd, hd), jnp.float32),
            "tm_x": z((L, B, M), dt),
            "cm_x": z((L, B, M), dt),
        }
    if at == "hybrid":
        mc = cfg.mamba()
        G, per = cfg.num_shared_attn, cfg.shared_attn_period - 1
        return {
            "ssm": z((G, per, B, mc.num_heads, mc.head_dim, mc.d_state), jnp.float32),
            "conv": z((G, per, B, mc.conv_width - 1, mc.d_inner + 2 * mc.d_state), dt),
            "shared_k": z((G, B, C, K, D), dt),
            "shared_v": z((G, B, C, K, D), dt),
        }
    if at == "encdec":
        Ld = cfg.num_layers
        F = cfg.encoder_frames
        return {
            "k": z((Ld, B, C, K, D), dt),
            "v": z((Ld, B, C, K, D), dt),
            "xk": z((Ld, B, F, K, D), dt),
            "xv": z((Ld, B, F, K, D), dt),
        }
    raise ValueError(at)


def decode_step(
    params: PyTree,
    cfg: LMConfig,
    cache: PyTree,
    tokens: jnp.ndarray,  # (B,) next input token ids
    pos: jnp.ndarray,  # () int32 current position
    positions_3d: Optional[jnp.ndarray] = None,  # (3, B, 1) for vlm
) -> Tuple[jnp.ndarray, PyTree]:
    """One-token decode; returns (logits (B, V), new cache)."""
    h = params["embed"].astype(cfg.act_dtype)[tokens][:, None, :]  # (B,1,M)
    h = constrain(h, "batch", None, None)
    at = cfg.arch_type

    if at in ("dense", "vlm") or (at == "moe" and not cfg.use_mla):
        quant = cfg.kv_quant

        def body(hh, inp):
            if quant:
                lp, ck, cv, ks, vs = inp
            else:
                lp, ck, cv = inp
            hn = rmsnorm(lp["norm1"], hh)
            if quant:
                a, ck, cv, (ks, vs) = attention_decode(
                    lp["attn"], cfg.attn(), hn, ck, cv, pos, positions_3d,
                    cache_scales=(ks, vs),
                )
            else:
                a, ck, cv = attention_decode(
                    lp["attn"], cfg.attn(), hn, ck, cv, pos, positions_3d
                )
            hh = hh + a
            if "mlp" in lp:
                hh = hh + swiglu(lp["mlp"], rmsnorm(lp["norm2"], hh))
            else:
                out, _ = moe_apply(lp["moe"], cfg.moe(), rmsnorm(lp["norm2"], hh))
                hh = hh + out
            return hh, (ck, cv, ks, vs) if quant else (ck, cv)

        def cache_slices(sl):
            fields = ("k", "v", "k_s", "v_s") if quant else ("k", "v")
            return tuple(cache[f][sl] for f in fields)

        def pack(ys):
            fields = ("k", "v", "k_s", "v_s") if quant else ("k", "v")
            return dict(zip(fields, ys))

        if at == "moe" and cfg.first_k_dense:
            nD = cfg.first_k_dense
            h, ys0 = _scan(cfg)(body, h, (params["dense_layers"],) + cache_slices(slice(None, nD)))
            h, ys1 = _scan(cfg)(body, h, (params["moe_layers"],) + cache_slices(slice(nD, None)))
            cache = {
                f: jnp.concatenate([a, b])
                for f, a, b in zip(pack(ys0).keys(), ys0, ys1)
            }
        else:
            h, ys = _scan(cfg)(body, h, (params["layers"],) + cache_slices(slice(None)))
            cache = pack(ys)
    elif at == "moe" and cfg.use_mla:
        def body(hh, inp):
            lp, cc, ckr = inp
            hn = rmsnorm(lp["norm1"], hh)
            a, cc, ckr = mla_decode(lp["attn"], cfg.mla(), hn, cc, ckr, pos)
            hh = hh + a
            if "mlp" in lp:
                hh = hh + swiglu(lp["mlp"], rmsnorm(lp["norm2"], hh))
            else:
                out, _ = moe_apply(lp["moe"], cfg.moe(), rmsnorm(lp["norm2"], hh))
                hh = hh + out
            return hh, (cc, ckr)

        nD = cfg.first_k_dense
        if nD:
            h, (c0, r0) = _scan(cfg)(
                body, h, (params["dense_layers"], cache["c"][:nD], cache["kr"][:nD])
            )
            h, (c1, r1) = _scan(cfg)(
                body, h, (params["moe_layers"], cache["c"][nD:], cache["kr"][nD:])
            )
            cache = {"c": jnp.concatenate([c0, c1]), "kr": jnp.concatenate([r0, r1])}
        else:
            h, (cc, ckr) = _scan(cfg)(body, h, (params["layers"], cache["c"], cache["kr"]))
            cache = {"c": cc, "kr": ckr}
    elif at == "rwkv":
        def body(hh, inp):
            lp, st, xt, xc = inp
            hh2, st, xt, xc = _rwkv_block(lp, cfg, hh, st, xt, xc)
            return hh2, (st, xt, xc)

        h, (st, xt, xc) = _scan(cfg)(
            body, h, (params["layers"], cache["state"], cache["tm_x"], cache["cm_x"])
        )
        cache = {"state": st, "tm_x": xt, "cm_x": xc}
    elif at == "hybrid":
        sp = params["shared_block"]

        def group(hh, inp):
            gp, ssm_g, conv_g, sk, sv = inp

            def mb(hhh, minp):
                lp, s1, c1 = minp
                hhh, s1, c1 = _mamba_block(lp, cfg, hhh, s1, c1)
                return hhh, (s1, c1)

            hh, (ssm_g, conv_g) = _scan(cfg)(mb, hh, (gp, ssm_g, conv_g))
            hn = rmsnorm(sp["norm1"], hh)
            a, sk, sv = attention_decode(sp["attn"], cfg.attn(), hn, sk, sv, pos)
            hh = hh + a
            hh = hh + swiglu(sp["mlp"], rmsnorm(sp["norm2"], hh))
            return hh, (ssm_g, conv_g, sk, sv)

        h, (ssm, conv, sk, sv) = _scan(cfg)(
            group,
            h,
            (params["mamba_groups"], cache["ssm"], cache["conv"],
             cache["shared_k"], cache["shared_v"]),
        )
        cache = {"ssm": ssm, "conv": conv, "shared_k": sk, "shared_v": sv}
    elif at == "encdec":
        h = h + _sinusoid_at(pos, cfg.d_model, h.dtype)[None, None]
        F = cfg.encoder_frames
        cross_mask = jnp.ones((1, 1, F), bool)

        def body(hh, inp):
            lp, ck, cv, xk, xv = inp
            hn = layernorm(lp["norm1"], hh)
            a, ck, cv = attention_decode(
                lp["self_attn"], cfg.attn()._replace(mrope_sections=None),
                hn, ck, cv, pos,
            )
            hh = hh + a
            xq = layernorm(lp["norm_x"], hh)
            x = _cross_attention_cached(lp["cross_attn"], cfg, xq, xk, xv, cross_mask)
            hh = hh + x
            hh = hh + gelu_mlp(lp["mlp"], layernorm(lp["norm2"], hh))
            return hh, (ck, cv)

        h, (ck, cv) = _scan(cfg)(
            body, h, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        cache = dict(cache, k=ck, v=cv)
    else:
        raise ValueError(at)

    logits = _logits(params, cfg, h)[:, 0, :]
    return logits, cache


def _sinusoid_at(pos, d: int, dtype) -> jnp.ndarray:
    dim = jnp.arange(d // 2).astype(jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)


def _cross_attention_cached(ap, cfg: LMConfig, x, xk, xv, mask):
    from repro.models.layers import _sdpa

    acfg = cfg.attn()
    B, S, _ = x.shape
    q = x @ ap["wq"].astype(x.dtype)
    if acfg.qkv_bias:
        q = q + ap["bq"].astype(x.dtype)
    q = q.reshape(B, S, acfg.num_heads, acfg.head_dim)
    out = _sdpa(q, xk, xv, mask, acfg.num_kv_heads, acfg.num_heads)
    return out @ ap["wo"].astype(x.dtype)


def _fill_slots(arr: jnp.ndarray, C: int) -> jnp.ndarray:
    """(B, S, ...) sequence -> (B, C, ...) cache slots.  When S > C (sliding
    window prefill) the last C tokens land at their ring slots pos % C."""
    B, S = arr.shape[0], arr.shape[1]
    if S == C:
        return arr  # exact fit: the sequence IS the cache
    out = jnp.zeros((B, C) + arr.shape[2:], arr.dtype)
    if S > C:
        tail = arr[:, S - C :]
        slots = jnp.arange(S - C, S) % C
        return out.at[:, slots].set(tail)
    return out.at[:, :S].set(arr)


def prefill(
    params: PyTree, cfg: LMConfig, batch: Dict, capacity: Optional[int] = None
) -> Tuple[jnp.ndarray, PyTree]:
    """Parallel prefill: full forward for logits, collecting the decode
    cache (rotated K/V, SSM/conv states, or MLA latents) in the same pass.
    Returns (last-token logits (B, V), cache ready for ``decode_step`` at
    position S)."""
    h = _embed(params, cfg, batch)
    B, S, _ = h.shape
    C = capacity or S
    positions = _positions(batch, B, S)
    positions_3d = batch.get("positions_3d")
    at = cfg.arch_type

    if at in ("dense", "vlm", "moe") and not cfg.use_mla:
        from repro.models.layers import kv_quantize

        quant = cfg.kv_quant
        fields = ("k", "v", "k_s", "v_s") if quant else ("k", "v")

        def body(hh, lp):
            hn = rmsnorm(lp["norm1"], hh)
            a, (k, v) = attention_apply(
                lp["attn"], cfg.attn(), hn, positions, positions_3d, return_kv=True
            )
            hh = hh + constrain(a, "batch", None, None)
            if "mlp" in lp:
                hh = hh + swiglu(lp["mlp"], rmsnorm(lp["norm2"], hh))
            else:
                out, _ = moe_apply(lp["moe"], cfg.moe(), rmsnorm(lp["norm2"], hh))
                hh = hh + out
            if quant:
                k_q, k_s = kv_quantize(k)
                v_q, v_s = kv_quantize(v)
                return hh, (_fill_slots(k_q, C), _fill_slots(v_q, C),
                            _fill_slots(k_s, C), _fill_slots(v_s, C))
            return hh, (_fill_slots(k, C), _fill_slots(v, C))

        if at == "moe" and cfg.first_k_dense:
            h, ys0 = _scan(cfg)(body, h, params["dense_layers"])
            h, ys1 = _scan(cfg)(body, h, params["moe_layers"])
            cache = {f: jnp.concatenate([a, b]) for f, a, b in zip(fields, ys0, ys1)}
        else:
            h, ys = _scan(cfg)(body, h, params["layers"])
            cache = dict(zip(fields, ys))
    elif at == "moe" and cfg.use_mla:
        def body(hh, lp):
            hn = rmsnorm(lp["norm1"], hh)
            a, (ckv, kr) = mla_apply(lp["attn"], cfg.mla(), hn, positions, return_kv=True)
            hh = hh + constrain(a, "batch", None, None)
            if "mlp" in lp:
                hh = hh + swiglu(lp["mlp"], rmsnorm(lp["norm2"], hh))
            else:
                out, _ = moe_apply(lp["moe"], cfg.moe(), rmsnorm(lp["norm2"], hh))
                hh = hh + out
            return hh, (_fill_slots(ckv, C), _fill_slots(kr, C))

        if cfg.first_k_dense:
            h, (c0, r0) = _scan(cfg)(body, h, params["dense_layers"])
            h, (c1, r1) = _scan(cfg)(body, h, params["moe_layers"])
            cache = {"c": jnp.concatenate([c0, c1]), "kr": jnp.concatenate([r0, r1])}
        else:
            h, (cc, rr) = _scan(cfg)(body, h, params["layers"])
            cache = {"c": cc, "kr": rr}
    elif at == "rwkv":
        def body(hh, lp):
            hh, st, xt, xc = _rwkv_block(lp, cfg, hh, None, None, None)
            return hh, (st, xt, xc)

        h, (st, xt, xc) = _scan(cfg)(body, h, params["layers"])
        cache = {"state": st, "tm_x": xt, "cm_x": xc}
    elif at == "hybrid":
        sp = params["shared_block"]

        def group(hh, gp):
            def mb(hhh, lp):
                hhh, s1, c1 = _mamba_block(lp, cfg, hhh, None, None)
                return hhh, (s1, c1)

            hh, (ssm_g, conv_g) = _scan(cfg)(mb, hh, gp)
            hn = rmsnorm(sp["norm1"], hh)
            a, (k, v) = attention_apply(
                sp["attn"], cfg.attn(), hn, positions, return_kv=True
            )
            hh = hh + a
            hh = hh + swiglu(sp["mlp"], rmsnorm(sp["norm2"], hh))
            return hh, (ssm_g, conv_g, _fill_slots(k, C), _fill_slots(v, C))

        h, (ssm, conv, sk, sv) = _scan(cfg)(group, h, params["mamba_groups"])
        cache = {"ssm": ssm, "conv": conv, "shared_k": sk, "shared_v": sv}
    elif at == "encdec":
        enc = _encode(params, cfg, batch)
        F = enc.shape[1]
        h = h + _sinusoid(S, cfg.d_model, h.dtype)[None]
        cross_mask = jnp.ones((1, S, F), bool)
        acfg = cfg.attn()._replace(mrope_sections=None)
        K_, D_ = acfg.num_kv_heads, acfg.head_dim

        def body(hh, lp):
            hn = layernorm(lp["norm1"], hh)
            a, (k, v) = attention_apply(lp["self_attn"], acfg, hn, None, None,
                                        mask=None, return_kv=True)
            hh = hh + a
            xq = layernorm(lp["norm_x"], hh)
            x = _cross_attention(lp["cross_attn"], cfg, xq, enc, cross_mask)
            hh = hh + x
            hh = hh + gelu_mlp(lp["mlp"], layernorm(lp["norm2"], hh))
            xk = (enc @ lp["cross_attn"]["wk"].astype(hh.dtype)).reshape(B, F, K_, D_)
            xv = (enc @ lp["cross_attn"]["wv"].astype(hh.dtype)).reshape(B, F, K_, D_)
            return hh, (_fill_slots(k, C), _fill_slots(v, C), xk, xv)

        h, (ck, cv, xk, xv) = _scan(cfg)(body, h, params["dec_layers"])
        cache = {"k": ck, "v": cv, "xk": xk, "xv": xv}
    else:
        raise ValueError(at)

    logits = _logits(params, cfg, h)
    return logits[:, -1, :], cache


__all__ = [
    "LMConfig",
    "reduced",
    "init_params",
    "abstract_params",
    "forward",
    "loss_fn",
    "init_cache",
    "decode_step",
    "prefill",
]
