"""Transformer / SSM layer zoo for the assigned architectures.

Everything is functional: ``*_init(key, ...) -> params`` (nested dicts) and
``*_apply(params, x, ...)``.  Dtype policy: params in ``param_dtype``
(default float32), activations cast to ``dtype`` (bf16 for the production
dry-run).  All sequence stacks use ``jax.lax.scan``-compatible shapes so a
64-layer model lowers to depth-independent HLO.

Covered: RMSNorm/LayerNorm, RoPE + M-RoPE, GQA attention (bias, qk_norm,
sliding window, KV-cache decode), SwiGLU/GELU MLP, capacity-based MoE
(shared + routed top-k, load-balance aux), MLA (compressed KV), RWKV6
time/channel mix, Mamba2 (SSD) block.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape: Tuple[int, ...], dtype=jnp.float32, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else (1.0 / fan_in) ** 0.5
    return jax.random.normal(key, shape, dtype) * scale


def rmsnorm_init(dim: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: PyTree, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * params["scale"].astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: PyTree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked scan: O(S/chunk) residency for recurrent backward passes
# ---------------------------------------------------------------------------

def chunked_scan(step, init, xs, chunk: int = 0):
    """``jax.lax.scan`` with chunk-level activation checkpointing.

    A plain scan saves every per-step carry for the backward pass — for a
    4k-token RWKV/Mamba layer that is seq_len × state bytes.  Chunking nests
    an inner scan (rematerialised via ``jax.checkpoint``) inside an outer
    scan, so only chunk-boundary states are saved: memory drops by ``chunk``×
    at the cost of one extra forward over each chunk in the backward pass.
    Exact (bitwise same forward); ``chunk=0`` or non-divisible S falls back.
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    if chunk <= 1 or S <= chunk or S % chunk != 0:
        return jax.lax.scan(step, init, xs)
    xs_c = jax.tree.map(lambda a: a.reshape(S // chunk, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def outer(carry, xc):
        return jax.lax.scan(step, carry, xc)

    carry, ys = jax.lax.scan(outer, init, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(S, *a.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e6) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e6) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out1 = x1 * cos.astype(x.dtype) - x2 * sin.astype(x.dtype)
    out2 = x2 * cos.astype(x.dtype) + x1 * sin.astype(x.dtype)
    return jnp.concatenate([out1, out2], axis=-1)


def apply_mrope(
    x: jnp.ndarray,
    positions_3d: jnp.ndarray,
    sections: Tuple[int, int, int],
    theta: float = 1e6,
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  ``positions_3d``: (3, B, S) —
    (temporal, height, width) position ids; ``sections`` split the D/2
    frequency bands among the three axes (e.g. (16, 24, 24) for D=128).
    Text tokens carry identical t/h/w ids, recovering 1-D RoPE exactly.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    # angles per axis, then band-select by section
    ang = positions_3d[..., None].astype(jnp.float32) * freqs  # (3, B, S, D/2)
    sec = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (D/2,)
    angles = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1), sec[None, None, :, None], axis=-1
    )[..., 0]  # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out1 = x1 * cos.astype(x.dtype) - x2 * sin.astype(x.dtype)
    out2 = x2 * cos.astype(x.dtype) + x1 * sin.astype(x.dtype)
    return jnp.concatenate([out1, out2], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

class AttnConfig(NamedTuple):
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int = 0  # 0 = full causal; >0 = sliding window
    rope_theta: float = 1e6
    use_rope: bool = True  # False: absolute positions only (whisper)
    mrope_sections: Optional[Tuple[int, int, int]] = None
    attn_chunk: int = 0  # >0: query-chunked SDPA (bounds the S×T logits
    #                      working set — flash-attention-style residency)
    seq_shard: bool = False  # shard the query dim over `model` inside each
    #   chunk (sequence/context parallelism). Use when num_heads is not
    #   divisible by the model axis: head-sharded attention then forces
    #   SPMD to all-reduce the full S×T logits (§Perf hillclimb #2).


def attention_init(key, cfg: AttnConfig, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 4)
    H, K, D, M = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    p: PyTree = {
        "wq": dense_init(ks[0], (M, H * D), dtype),
        "wk": dense_init(ks[1], (M, K * D), dtype),
        "wv": dense_init(ks[2], (M, K * D), dtype),
        "wo": dense_init(ks[3], (H * D, M), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * D,), dtype)
        p["bk"] = jnp.zeros((K * D,), dtype)
        p["bv"] = jnp.zeros((K * D,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(D, dtype)
        p["k_norm"] = rmsnorm_init(D, dtype)
    return p


def _project_qkv(params, cfg: AttnConfig, x, positions, positions_3d=None):
    B, S, _ = x.shape
    H, K, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, K, D)
    v = v.reshape(B, S, K, D)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if not cfg.use_rope:
        pass
    elif cfg.mrope_sections is not None and positions_3d is not None:
        q = apply_mrope(q, positions_3d, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions_3d, cfg.mrope_sections, cfg.rope_theta)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, num_kv_heads: int, num_heads: int):
    """q: (B,S,H,D), k/v: (B,T,K,D); GQA via head grouping."""
    B, S, H, D = q.shape
    T = k.shape[1]
    G = H // num_kv_heads
    q = q.reshape(B, S, num_kv_heads, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k) / jnp.sqrt(D).astype(q.dtype)
    logits = jnp.where(mask[:, None, None, :, :], logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H * D)


def _chunked_sdpa(
    q, k, v, mask, num_kv_heads: int, num_heads: int, chunk: int,
    seq_shard: bool = False,
):
    """Query-chunked SDPA: the (S, T) logits tensor never materialises —
    only (chunk, T) per map step.  Exact; used when S is large (prefill/
    train at long seq_len) so per-device attention residency is bounded.

    ``seq_shard``: shard each chunk's query rows over `model` and keep K/V
    replicated — context parallelism.  Attention becomes fully local per
    device regardless of head-count divisibility (the head-sharded layout
    degenerates to a full-logits all-reduce when H % mesh_model != 0)."""
    from repro.launch.meshctx import constrain  # local import (no cycle)

    B, S, H, D = q.shape
    n = S // chunk
    qc = jnp.moveaxis(q.reshape(B, n, chunk, H, D), 1, 0)  # (n, B, c, H, D)
    mb = jnp.broadcast_to(mask, (mask.shape[0], S, mask.shape[2]))
    mc = jnp.moveaxis(mb.reshape(mask.shape[0], n, chunk, mask.shape[2]), 1, 0)
    if seq_shard:
        k = constrain(k, "batch", None, None, None)  # replicated over model
        v = constrain(v, "batch", None, None, None)

    def f(args):
        qi, mi = args
        if seq_shard:
            qi = constrain(qi, "batch", "model", None, None)
            out_i = _sdpa(qi, k, v, mi, num_kv_heads, num_heads)
            return constrain(out_i, "batch", "model", None)
        return _sdpa(qi, k, v, mi, num_kv_heads, num_heads)

    out = jax.lax.map(f, (qc, mc))  # (n, B, c, H*D)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H * D)


def causal_mask(S: int, T: int, offset: int, window: int = 0) -> jnp.ndarray:
    """(1, S, T) bool; query i (global pos offset+i) sees key j iff
    j <= offset+i and (window == 0 or j > offset+i-window)."""
    qpos = offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None]


def attention_apply(
    params: PyTree,
    cfg: AttnConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    positions_3d: Optional[jnp.ndarray] = None,
    mask: Optional[jnp.ndarray] = None,
    return_kv: bool = False,
):
    """Full (training/prefill) self-attention.  ``return_kv`` additionally
    returns the rotated (k, v) for prefill cache construction."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions, positions_3d)
    if mask is None:
        mask = causal_mask(S, S, 0, cfg.window)
    if cfg.attn_chunk and S > cfg.attn_chunk and S % cfg.attn_chunk == 0:
        out = _chunked_sdpa(q, k, v, mask, cfg.num_kv_heads, cfg.num_heads,
                            cfg.attn_chunk, seq_shard=cfg.seq_shard)
    elif cfg.seq_shard:
        from repro.launch.meshctx import constrain

        q = constrain(q, "batch", "model", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
        out = _sdpa(q, k, v, mask, cfg.num_kv_heads, cfg.num_heads)
        out = constrain(out, "batch", "model", None)
    else:
        out = _sdpa(q, k, v, mask, cfg.num_kv_heads, cfg.num_heads)
    out = out @ params["wo"].astype(x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def kv_quantize(k: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(slot, head) symmetric int8 quantisation: k ≈ q · s.
    k: (..., K, D) -> (q int8 (..., K, D), s f32 (..., K))."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def kv_dequantize(q: jnp.ndarray, s: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * s[..., None].astype(jnp.float32)).astype(dtype)


def attention_decode(
    params: PyTree,
    cfg: AttnConfig,
    x: jnp.ndarray,  # (B, 1, M)
    cache_k: jnp.ndarray,  # (B, C, K, D) — C = cache capacity (window or max)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,  # () int32 — global position of this token
    positions_3d: Optional[jnp.ndarray] = None,
    cache_scales: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
):
    """One-token decode against a (possibly rotating) KV cache.

    For ``cfg.window > 0`` the cache has capacity ``window`` and is written
    at ``pos % window`` (ring buffer); keys carry their true positions via
    RoPE applied at write time, so no re-rotation is needed.

    ``cache_scales`` = (k_s, v_s) (B, C, K) enables the int8-quantised
    cache (§Perf hillclimb #3): k/v are stored int8 with per-(slot, head)
    scales — half the HBM residency and copy traffic of bf16.  Returns
    (out, cache_k, cache_v[, new_scales]).
    """
    B = x.shape[0]
    C = cache_k.shape[1]
    q, k, v = _project_qkv(
        params, cfg, x, jnp.full((B, 1), pos), positions_3d
    )
    slot = (pos % C) if cfg.window > 0 else pos
    quant = cache_scales is not None
    if quant:
        k_s, v_s = cache_scales
        k_q, k_sc = kv_quantize(k)  # (B,1,K,D) int8, (B,1,K)
        v_q, v_sc = kv_quantize(v)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_q, slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_q, slot, axis=1)
        k_s = jax.lax.dynamic_update_slice_in_dim(k_s, k_sc, slot, axis=1)
        v_s = jax.lax.dynamic_update_slice_in_dim(v_s, v_sc, slot, axis=1)
        k_full = kv_dequantize(cache_k, k_s, x.dtype)
        v_full = kv_dequantize(cache_v, v_s, x.dtype)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
        k_full, v_full = cache_k, cache_v
    # valid = slots holding tokens in [max(0, pos-window+1), pos]
    slot_ids = jnp.arange(C)
    if cfg.window > 0:
        valid = slot_ids <= jnp.minimum(pos, C - 1)  # ring filled up to min(pos, C-1)
    else:
        valid = slot_ids <= pos
    mask = valid[None, None, :]  # (1, 1, C)
    out = _sdpa(q, k_full, v_full, mask, cfg.num_kv_heads, cfg.num_heads)
    out = out @ params["wo"].astype(x.dtype)
    if quant:
        return out, cache_k, cache_v, (k_s, v_s)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, (d_model, d_ff), dtype),
        "up": dense_init(k2, (d_model, d_ff), dtype),
        "down": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(params: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(x @ params["gate"].astype(x.dtype))
    u = x @ params["up"].astype(x.dtype)
    return (g * u) @ params["down"].astype(x.dtype)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "up": dense_init(k1, (d_model, d_ff), dtype),
        "up_b": jnp.zeros((d_ff,), dtype),
        "down": dense_init(k2, (d_ff, d_model), dtype),
        "down_b": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(x @ params["up"].astype(x.dtype) + params["up_b"].astype(x.dtype))
    return h @ params["down"].astype(x.dtype) + params["down_b"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based static dispatch; shared + routed)
# ---------------------------------------------------------------------------

class MoEConfig(NamedTuple):
    d_model: int
    d_ff_expert: int
    num_experts: int
    top_k: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    aux_weight: float = 0.001
    groups: int = 0  # >0: group-local dispatch (see moe_apply_grouped)


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32) -> PyTree:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    E, M, F = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    p: PyTree = {
        "router": dense_init(k1, (M, E), jnp.float32),  # router in fp32
        "w_gate": dense_init(k2, (E, M, F), dtype),
        "w_up": dense_init(k3, (E, M, F), dtype),
        "w_down": dense_init(k4, (E, F, M), dtype),
    }
    if cfg.num_shared:
        p["shared"] = swiglu_init(k5, M, cfg.num_shared * F, dtype)
    return p


def moe_apply(
    params: PyTree, cfg: MoEConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatches to the grouped (SPMD-shardable) path when cfg.groups > 0
    and the token count divides; otherwise the flat-capacity path below."""
    if cfg.groups and (x.shape[0] * x.shape[1]) % cfg.groups == 0:
        return moe_apply_grouped(params, cfg, x)
    return moe_apply_flat(params, cfg, x)


def moe_apply_flat(
    params: PyTree, cfg: MoEConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, M) -> (out, aux_loss).  Static-capacity dispatch:
    tokens route to top-k experts; each expert processes at most
    C = ceil(T·k/E · capacity_factor) tokens (overflow dropped), giving a
    fixed (E, C, M) compute shape that shards expert-parallel on `model`.

    CAVEAT (found by the dry-run, fixed by moe_apply_grouped): the global
    scatter's position indices depend on a cumsum over ALL tokens, so the
    SPMD partitioner cannot shard the dispatch — on a 256-chip mesh every
    device re-does near-global work.  Kept as the paper-faithful/naive
    baseline for §Perf.
    """
    B, S, M = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.top_k
    cap = int((T * K / E) * cfg.capacity_factor) + 1
    tok = x.reshape(T, M)
    logits = tok.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    flat_e = expert_ids.reshape(-1)  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1  # (T*K, E)
    pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]  # (T*K,)
    keep = pos < cap
    # scatter tokens into (E, cap, M)
    tok_rep = jnp.repeat(tok, K, axis=0)  # (T*K, M)
    safe_pos = jnp.where(keep, pos, cap - 1)
    buf = jnp.zeros((E, cap, M), x.dtype)
    buf = buf.at[flat_e, safe_pos].add(
        tok_rep * keep[:, None].astype(x.dtype), mode="drop"
    )
    # expert-parallel: dispatch buffer sharded on experts -> the scatter
    # above lowers to the MoE all-to-all under SPMD
    from repro.launch.meshctx import constrain  # local import (no cycle)

    buf = constrain(buf, "expert", None, None)
    # expert FFN: (E, cap, M) x (E, M, F)
    g = jax.nn.silu(jnp.einsum("ecm,emf->ecf", buf, params["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecm,emf->ecf", buf, params["w_up"].astype(x.dtype))
    y = jnp.einsum("ecf,efm->ecm", g * u, params["w_down"].astype(x.dtype))
    y = constrain(y, "expert", None, None)
    # gather back and combine with gates
    out_tok = y[flat_e, safe_pos] * (gate.reshape(-1, 1) * keep[:, None].astype(x.dtype))
    out = out_tok.reshape(T, K, M).sum(axis=1)
    if cfg.num_shared and "shared" in params:
        out = out + swiglu(params["shared"], tok)
    # load-balance aux loss (Switch): E * Σ_e f_e·p̄_e
    f = jnp.mean(
        (jax.nn.one_hot(expert_ids, E).sum(axis=1) > 0).astype(jnp.float32), axis=0
    )
    pbar = probs.mean(axis=0)
    aux = cfg.aux_weight * E * jnp.sum(f * pbar)
    return out.reshape(B, S, M), aux


def moe_apply_grouped(
    params: PyTree, cfg: MoEConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Group-local MoE dispatch (§Perf hillclimb #1; GShard-style groups).

    Tokens are reshaped to (G, Tg, M) with the group axis sharded over
    `data`; routing, the position-in-expert cumsum, the dispatch scatter
    and the combine gather are all BATCHED over g, so SPMD keeps them
    local to each data shard.  The only cross-device traffic is the
    (g→data)⇄(e→model) resharding of the (G, E, Cg, M) expert buffer —
    the canonical MoE all-to-all — plus the usual output reduction.
    Per-group capacity Cg = Tg·k/E · capacity_factor bounds imbalance
    within a group (drops are per-group, slightly stricter than the flat
    path's global capacity; aux load-balance loss unchanged).
    """
    from repro.launch.meshctx import constrain  # local import (no cycle)

    B, S, M = x.shape
    T = B * S
    G = cfg.groups
    Tg = T // G
    E, K = cfg.num_experts, cfg.top_k
    cap = int((Tg * K / E) * cfg.capacity_factor) + 1
    tok = constrain(x.reshape(G, Tg, M), "batch", None, None)
    tok_f32 = constrain(tok.astype(jnp.float32), "batch", None, None)
    logits = tok_f32 @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)
    gate, expert_ids = jax.lax.top_k(probs, K)  # (G, Tg, K)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    flat_e = expert_ids.reshape(G, Tg * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, TgK, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1  # group-local positions
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=2)[..., 0]
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)
    tok_rep = constrain(jnp.repeat(tok, K, axis=1), "batch", None, None)  # (G, TgK, M)
    g_idx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tg * K))
    buf = jnp.zeros((G, E, cap, M), x.dtype)
    buf = buf.at[g_idx, flat_e, safe_pos].add(
        tok_rep * keep[..., None].astype(x.dtype), mode="drop"
    )
    # pin the scatter result g-sharded BEFORE the expert reshard so neither
    # the forward scatter nor its transpose (a gather) replicates
    buf = constrain(buf, "batch", None, None, None)
    # slot-side bookkeeping for the combine scatter: the owning token id and
    # the routing gate per (e, cap) slot (0 on dropped/empty slots)
    tok_ids = jnp.broadcast_to(
        (jnp.arange(Tg * K) // K)[None, :], (G, Tg * K)
    )
    # dropped entries scatter to index `cap` (out of bounds -> mode="drop"),
    # so they cannot clobber a legitimate occupant of the last slot
    oob_pos = jnp.where(keep, pos, cap)
    slot_tok = constrain(
        jnp.zeros((G, E, cap), jnp.int32).at[g_idx, flat_e, oob_pos].set(
            tok_ids, mode="drop"
        ),
        "batch", "expert", None,
    )
    slot_gate = constrain(
        jnp.zeros((G, E, cap), x.dtype).at[g_idx, flat_e, oob_pos].set(
            gate.reshape(G, Tg * K), mode="drop"
        ),
        "batch", "expert", None,
    )
    # (g→data) ⇄ (e→model): the MoE all-to-all happens at this constraint
    buf = constrain(buf, "batch", "expert", None, None)
    g_ = jax.nn.silu(jnp.einsum("gecm,emf->gecf", buf, params["w_gate"].astype(x.dtype)))
    u_ = jnp.einsum("gecm,emf->gecf", buf, params["w_up"].astype(x.dtype))
    y = jnp.einsum("gecf,efm->gecm", g_ * u_, params["w_down"].astype(x.dtype))
    y = constrain(y, "batch", "expert", None, None)
    # combine by scattering slots back to their tokens (partial per expert
    # shard + reduction) instead of gathering the e-sharded buffer — avoids
    # an all-gather of y over the model axis (§Perf hillclimb #1, iter 2)
    g_idx3 = jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, E, cap))
    contrib = y * slot_gate[..., None]
    out = jnp.zeros((G, Tg, M), x.dtype).at[g_idx3, slot_tok].add(contrib, mode="drop")
    out = constrain(out, "batch", None, None).reshape(B, S, M)
    if cfg.num_shared and "shared" in params:
        out = out + swiglu(params["shared"], tok.reshape(T, M)).reshape(B, S, M)
    f = jnp.mean(
        (jax.nn.one_hot(expert_ids, E).sum(axis=2) > 0).astype(jnp.float32),
        axis=(0, 1),
    )
    pbar = probs.mean(axis=(0, 1))
    aux = cfg.aux_weight * E * jnp.sum(f * pbar)
    return out, aux


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

class MLAConfig(NamedTuple):
    d_model: int
    num_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    rope_theta: float = 1e6
    attn_chunk: int = 0  # query chunking, as in AttnConfig


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 6)
    M, H = cfg.d_model, cfg.num_heads
    R, N, P, V = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_dim
    return {
        "wq": dense_init(ks[0], (M, H * (N + P)), dtype),
        "w_dkv": dense_init(ks[1], (M, R), dtype),  # compress
        "w_kr": dense_init(ks[2], (M, P), dtype),  # shared rope key
        "w_uk": dense_init(ks[3], (R, H * N), dtype),  # decompress K
        "w_uv": dense_init(ks[4], (R, H * V), dtype),  # decompress V
        "wo": dense_init(ks[5], (H * V, M), dtype),
        "kv_norm": rmsnorm_init(R, dtype),
    }


def mla_apply(
    params: PyTree, cfg: MLAConfig, x: jnp.ndarray, positions: jnp.ndarray,
    return_kv: bool = False,
):
    """Training/prefill: expanded-KV form (matches reference semantics)."""
    B, S, M = x.shape
    H, N, P, V = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, H, N + P)
    q_nope, q_rope = q[..., :N], q[..., N:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"].astype(x.dtype))  # (B,S,R)
    k_rope = apply_rope(
        (x @ params["w_kr"].astype(x.dtype))[:, :, None, :], positions, cfg.rope_theta
    )  # (B,S,1,P) shared across heads
    k_nope = (c_kv @ params["w_uk"].astype(x.dtype)).reshape(B, S, H, N)
    v = (c_kv @ params["w_uv"].astype(x.dtype)).reshape(B, S, H, V)
    scale = 1.0 / jnp.sqrt(N + P).astype(x.dtype)
    mask = causal_mask(S, S, 0)  # (1, S, T)

    def _attend(qn, qr, m):
        # qn: (B, s, H, N), qr: (B, s, H, P), m: (1, s, T)
        logits = (
            jnp.einsum("bshn,bthn->bhst", qn, k_nope)
            + jnp.einsum("bshp,btp->bhst", qr, k_rope[:, :, 0])
        ) * scale
        logits = jnp.where(m[:, None], logits, jnp.finfo(logits.dtype).min)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        s_len = qn.shape[1]
        return jnp.einsum("bhst,bthv->bshv", probs, v).reshape(B, s_len, H * V)

    chunk = cfg.attn_chunk
    if chunk and S > chunk and S % chunk == 0:
        n = S // chunk
        qn_c = jnp.moveaxis(q_nope.reshape(B, n, chunk, H, N), 1, 0)
        qr_c = jnp.moveaxis(q_rope.reshape(B, n, chunk, H, P), 1, 0)
        m_c = jnp.moveaxis(mask.reshape(1, n, chunk, S), 1, 0)
        out = jax.lax.map(lambda a: _attend(*a), (qn_c, qr_c, m_c))
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H * V)
    else:
        out = _attend(q_nope, q_rope, mask)
    out = out @ params["wo"].astype(x.dtype)
    if return_kv:
        return out, (c_kv, k_rope[:, :, 0])
    return out


def mla_decode(
    params: PyTree,
    cfg: MLAConfig,
    x: jnp.ndarray,  # (B, 1, M)
    cache_c: jnp.ndarray,  # (B, C, R)   compressed latent cache
    cache_kr: jnp.ndarray,  # (B, C, P)  shared rope-key cache
    pos: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Decode with the **absorbed** form: the cache stores only the R-dim
    latent + P-dim rope key per token (the paper-headline KV saving);
    W_uk is absorbed into the query, W_uv into the output projection.
    """
    B, _, M = x.shape
    H, N, P, V, R = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_dim, cfg.kv_lora_rank
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, 1, H, N + P)
    q_nope, q_rope = q[..., :N], q[..., N:]
    q_rope = apply_rope(q_rope, jnp.full((B, 1), pos), cfg.rope_theta)
    c_new = rmsnorm(params["kv_norm"], x @ params["w_dkv"].astype(x.dtype))  # (B,1,R)
    kr_new = apply_rope(
        (x @ params["w_kr"].astype(x.dtype))[:, :, None, :], jnp.full((B, 1), pos),
        cfg.rope_theta,
    )[:, :, 0]  # (B,1,P)
    cache_c = jax.lax.dynamic_update_slice_in_dim(cache_c, c_new, pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(cache_kr, kr_new, pos, axis=1)
    # absorb W_uk into q: q_lat (B,H,R)
    w_uk = params["w_uk"].astype(x.dtype).reshape(R, H, N)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / jnp.sqrt(N + P).astype(x.dtype)
    logits = (
        jnp.einsum("bhr,bcr->bhc", q_lat, cache_c)
        + jnp.einsum("bhp,bcp->bhc", q_rope[:, 0], cache_kr)
    ) * scale
    C = cache_c.shape[1]
    valid = (jnp.arange(C) <= pos)[None, None, :]
    logits = jnp.where(valid, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhc,bcr->bhr", probs, cache_c)  # attend in latent space
    w_uv = params["w_uv"].astype(x.dtype).reshape(R, H, V)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv).reshape(B, 1, H * V)
    return out @ params["wo"].astype(x.dtype), cache_c, cache_kr


# ---------------------------------------------------------------------------
# RWKV6 ("Finch") — data-dependent decay linear attention
# ---------------------------------------------------------------------------

class RWKV6Config(NamedTuple):
    d_model: int
    head_size: int = 64
    lora_rank: int = 32
    ffn_mult: float = 3.5  # d_ff = 7168 for d=2048

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_size


def rwkv6_init(key, cfg: RWKV6Config, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 12)
    M, Hd = cfg.d_model, cfg.head_size
    H = cfg.num_heads
    r = cfg.lora_rank
    d_ff = int(cfg.ffn_mult * M)
    return {
        # time-mix lerp factors (data-independent base + lora modulation)
        "mix_base": jax.random.uniform(ks[0], (5, M), dtype, 0.0, 1.0),
        "mix_lora_a": dense_init(ks[1], (M, 5 * r), dtype),
        "mix_lora_b": dense_init(ks[2], (5 * r, 5 * M), dtype, scale=0.01),
        "wr": dense_init(ks[3], (M, M), dtype),
        "wk": dense_init(ks[4], (M, M), dtype),
        "wv": dense_init(ks[5], (M, M), dtype),
        "wg": dense_init(ks[6], (M, M), dtype),
        "wo": dense_init(ks[7], (M, M), dtype),
        # data-dependent decay lora
        "decay_base": jnp.zeros((M,), dtype),
        "decay_lora_a": dense_init(ks[8], (M, 2 * r), dtype),
        "decay_lora_b": dense_init(ks[9], (2 * r, M), dtype, scale=0.01),
        "bonus": jnp.zeros((H, Hd), dtype),  # per-head u term
        "ln_x": layernorm_init(M, dtype),  # group-norm-ish output norm
        # channel mix
        "cm_mix": jax.random.uniform(ks[10], (M,), dtype, 0.0, 1.0),
        "cm_k": dense_init(ks[11], (M, d_ff), dtype),
        "cm_v": dense_init(jax.random.fold_in(key, 99), (d_ff, M), dtype),
        "cm_r": dense_init(jax.random.fold_in(key, 98), (M, M), dtype),
    }


def _rwkv6_mix(params, x, x_prev):
    """Data-dependent token-shift lerp producing the 5 mixed streams
    (r, k, v, g, w).  x: (B,S,M); x_prev: x shifted right by one."""
    B, S, M = x.shape
    dx = x_prev - x
    base = params["mix_base"].astype(x.dtype)  # (5, M)
    lora = jnp.tanh(x @ params["mix_lora_a"].astype(x.dtype))  # (B,S,5r)
    lora = (lora @ params["mix_lora_b"].astype(x.dtype)).reshape(B, S, 5, M)
    mix = base[None, None] + lora  # (B,S,5,M)
    return x[:, :, None, :] + dx[:, :, None, :] * mix  # (B,S,5,M)


def rwkv6_time_mix(
    params: PyTree,
    cfg: RWKV6Config,
    x: jnp.ndarray,
    state: Optional[jnp.ndarray] = None,  # (B, H, Hd, Hd) wkv state
    x_last: Optional[jnp.ndarray] = None,  # (B, M) last token (for decode)
    chunk: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (out, new_state, new_x_last).  Handles S>=1 via scan."""
    B, S, M = x.shape
    H, Hd = cfg.num_heads, cfg.head_size
    if x_last is None:
        x_last = jnp.zeros((B, M), x.dtype)
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    mixed = _rwkv6_mix(params, x, x_prev)  # (B,S,5,M)
    xr, xk, xv, xg, xw = [mixed[:, :, i] for i in range(5)]
    r = (xr @ params["wr"].astype(x.dtype)).reshape(B, S, H, Hd)
    k = (xk @ params["wk"].astype(x.dtype)).reshape(B, S, H, Hd)
    v = (xv @ params["wv"].astype(x.dtype)).reshape(B, S, H, Hd)
    g = jax.nn.silu(xg @ params["wg"].astype(x.dtype))
    # data-dependent decay w_t = exp(-exp(base + lora(xw)))
    dl = jnp.tanh(xw @ params["decay_lora_a"].astype(x.dtype))
    dl = dl @ params["decay_lora_b"].astype(x.dtype)
    w = jnp.exp(-jnp.exp((params["decay_base"].astype(x.dtype) + dl).astype(jnp.float32)))
    w = w.reshape(B, S, H, Hd).astype(jnp.float32)
    u = params["bonus"].astype(jnp.float32)  # (H, Hd)
    if state is None:
        state = jnp.zeros((B, H, Hd, Hd), jnp.float32)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    rf = r.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,Hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out_t = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out_t

    state, out = chunked_scan(
        step,
        state,
        (
            jnp.moveaxis(rf, 1, 0),
            jnp.moveaxis(kf, 1, 0),
            jnp.moveaxis(vf, 1, 0),
            jnp.moveaxis(w, 1, 0),
        ),
        chunk=chunk,
    )
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, M).astype(x.dtype)
    out = layernorm(params["ln_x"], out) * g
    out = out @ params["wo"].astype(x.dtype)
    return out, state, x[:, -1, :]


def rwkv6_channel_mix(
    params: PyTree, x: jnp.ndarray, x_last: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, M = x.shape
    if x_last is None:
        x_last = jnp.zeros((B, M), x.dtype)
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    mix = params["cm_mix"].astype(x.dtype)
    xk = x + (x_prev - x) * mix
    k = jnp.square(jax.nn.relu(xk @ params["cm_k"].astype(x.dtype)))
    rgate = jax.nn.sigmoid(xk @ params["cm_r"].astype(x.dtype))
    return rgate * (k @ params["cm_v"].astype(x.dtype)), x[:, -1, :]


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

class Mamba2Config(NamedTuple):
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_init(key, cfg: Mamba2Config, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 5)
    M, Di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.num_heads
    # in_proj -> [z (Di), x (Di), B (N), C (N), dt (H)]
    return {
        "in_proj": dense_init(ks[0], (M, 2 * Di + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, Di + 2 * N), dtype, scale=0.5),
        "conv_b": jnp.zeros((Di + 2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": rmsnorm_init(Di, dtype),
        "out_proj": dense_init(ks[2], (Di, M), dtype),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv over time. x: (B,S,C), w: (W,C).
    conv_state: (B, W-1, C) trailing context (decode)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1) :, :]
    return out + b[None, None, :], new_state


def mamba2_apply(
    params: PyTree,
    cfg: Mamba2Config,
    x: jnp.ndarray,
    ssm_state: Optional[jnp.ndarray] = None,  # (B, H, head_dim, N)
    conv_state: Optional[jnp.ndarray] = None,  # (B, W-1, Di+2N)
    chunk: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (out, new_ssm_state, new_conv_state)."""
    B, S, M = x.shape
    Di, N, H, P = cfg.d_inner, cfg.d_state, cfg.num_heads, cfg.head_dim
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :Di]
    xbc = zxbcdt[..., Di : Di + Di + 2 * N]
    dt = zxbcdt[..., -H:]
    xbc, new_conv = _causal_conv(
        xbc, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
        conv_state,
    )
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :Di].reshape(B, S, H, P)
    Bmat = xbc[..., Di : Di + N]  # (B,S,N)
    Cmat = xbc[..., Di + N :]  # (B,S,N)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    A = -jnp.exp(params["A_log"])  # (H,)
    decay = jnp.exp(dt * A[None, None, :])  # (B,S,H)
    if ssm_state is None:
        ssm_state = jnp.zeros((B, H, P, N), jnp.float32)

    xf = xs.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)

    def step(s, inp):
        xt, bt, ct, dct, dtt = inp  # (B,H,P), (B,N), (B,N), (B,H), (B,H)
        dbx = jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt)
        s = dct[..., None, None] * s + dbx
        yt = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, yt

    ssm_state, y = chunked_scan(
        step,
        ssm_state,
        (
            jnp.moveaxis(xf, 1, 0),
            jnp.moveaxis(Bf, 1, 0),
            jnp.moveaxis(Cf, 1, 0),
            jnp.moveaxis(decay, 1, 0),
            jnp.moveaxis(dt, 1, 0),
        ),
        chunk=chunk,
    )
    y = jnp.moveaxis(y, 0, 1)  # (B,S,H,P)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xf
    y = y.reshape(B, S, Di).astype(x.dtype)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, ssm_state, new_conv
