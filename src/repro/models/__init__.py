"""Model definitions: the weak/strong detectors of the repro, and the
transformer/SSM/MoE layer zoo used by the assigned architectures."""
