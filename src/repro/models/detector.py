"""Single-stage grid detector (YOLOv1/FCOS-lite hybrid) in pure JAX.

The weak/strong pair of the paper (YOLOv5n / YOLOv5m) becomes a narrow vs
wide+deeper instance of this model; the repro's relative claims only require
a genuine accuracy gap, which width/depth provides.

Per grid cell the head predicts: objectness logit, C class logits, and a box
(sigmoid cx,cy offset within the cell; sigmoid w,h as image fraction).  GT
assignment: object centre -> cell (ties: larger object wins).  Loss = BCE
objectness + CE class + SmoothL1 box on assigned cells.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.detection.map_engine import Detections
from repro.detection.nms import nms

PyTree = Dict


@dataclass(frozen=True)
class DetectorConfig:
    name: str
    widths: Tuple[int, ...]  # conv channels; len = #stride-2 stages
    head_width: int
    num_classes: int = 8
    image_size: int = 64

    @property
    def grid(self) -> int:
        return self.image_size // (2 ** len(self.widths))


WEAK = DetectorConfig("weak", widths=(12, 24, 48), head_width=48)
STRONG = DetectorConfig("strong", widths=(32, 64, 128), head_width=192)


def _conv_init(key, cin: int, cout: int, k: int = 3) -> PyTree:
    return {
        "w": jax.random.normal(key, (k, k, cin, cout), jnp.float32)
        * jnp.sqrt(2.0 / (k * k * cin)),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def detector_init(key, cfg: DetectorConfig) -> PyTree:
    params: PyTree = {}
    cin = 3
    keys = jax.random.split(key, len(cfg.widths) * 2 + 2)
    ki = 0
    for i, w in enumerate(cfg.widths):
        params[f"stage{i}_a"] = _conv_init(keys[ki], cin, w); ki += 1
        params[f"stage{i}_b"] = _conv_init(keys[ki], w, w); ki += 1
        cin = w
    params["head_hidden"] = _conv_init(keys[ki], cin, cfg.head_width, k=1); ki += 1
    out_ch = 1 + cfg.num_classes + 4
    params["head_out"] = _conv_init(keys[ki], cfg.head_width, out_ch, k=1)
    return params


def _conv(x, p, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + p["b"]


def detector_apply(params: PyTree, cfg: DetectorConfig, images: jnp.ndarray):
    """images (B, S, S, 3) -> raw head (B, G, G, 1 + C + 4) and the backbone
    feature map (for the §V-A hidden-layer estimator study)."""
    h = images
    n_stages = sum(1 for k in params if k.startswith("stage")) // 2
    for i in range(n_stages):
        h = jax.nn.gelu(_conv(h, params[f"stage{i}_a"], stride=2))
        h = jax.nn.gelu(_conv(h, params[f"stage{i}_b"], stride=1))
    feat = h
    h = jax.nn.gelu(_conv(h, params["head_hidden"]))
    out = _conv(h, params["head_out"])
    return out, feat


def build_targets(
    cfg: DetectorConfig, boxes: np.ndarray, classes: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side target assignment for a padded batch.

    boxes (B, M, 4) pixels, classes (B, M) with -1 padding ->
    obj (B, G, G), cls (B, G, G) int, box (B, G, G, 4) normalized targets.
    """
    B, M, _ = boxes.shape
    G = cfg.grid
    cell = cfg.image_size / G
    obj = np.zeros((B, G, G), dtype=np.float32)
    cls_t = np.zeros((B, G, G), dtype=np.int32)
    box_t = np.zeros((B, G, G, 4), dtype=np.float32)
    area = np.clip(boxes[..., 2] - boxes[..., 0], 0, None) * np.clip(
        boxes[..., 3] - boxes[..., 1], 0, None
    )
    order = np.argsort(area, axis=1)  # small first so large overwrite
    for b in range(B):
        for m in order[b]:
            if classes[b, m] < 0:
                continue
            x1, y1, x2, y2 = boxes[b, m]
            cx, cy = (x1 + x2) / 2, (y1 + y2) / 2
            gx = min(int(cx / cell), G - 1)
            gy = min(int(cy / cell), G - 1)
            obj[b, gy, gx] = 1.0
            cls_t[b, gy, gx] = classes[b, m]
            box_t[b, gy, gx] = [
                cx / cell - gx,  # offset in cell, (0,1)
                cy / cell - gy,
                (x2 - x1) / cfg.image_size,  # size as image fraction
                (y2 - y1) / cfg.image_size,
            ]
    return obj, cls_t, box_t


def detector_loss(
    params: PyTree,
    cfg: DetectorConfig,
    images: jnp.ndarray,
    obj_t: jnp.ndarray,
    cls_t: jnp.ndarray,
    box_t: jnp.ndarray,
) -> jnp.ndarray:
    out, _ = detector_apply(params, cfg, images)
    obj_logit = out[..., 0]
    cls_logit = out[..., 1 : 1 + cfg.num_classes]
    box_raw = out[..., 1 + cfg.num_classes :]
    # objectness BCE (positives upweighted: grid is sparse)
    obj_bce = jnp.maximum(obj_logit, 0) - obj_logit * obj_t + jnp.log1p(
        jnp.exp(-jnp.abs(obj_logit))
    )
    w_pos = 5.0
    obj_loss = jnp.mean(obj_bce * jnp.where(obj_t > 0, w_pos, 1.0))
    # class CE on positive cells
    logp = jax.nn.log_softmax(cls_logit, axis=-1)
    ce = -jnp.take_along_axis(logp, cls_t[..., None], axis=-1)[..., 0]
    cls_loss = jnp.sum(ce * obj_t) / jnp.maximum(jnp.sum(obj_t), 1.0)
    # box smooth-L1 on positive cells (predictions squashed to (0,1))
    box_pred = jax.nn.sigmoid(box_raw)
    diff = jnp.abs(box_pred - box_t)
    sl1 = jnp.where(diff < 1.0, 0.5 * diff * diff, diff - 0.5).sum(-1)
    box_loss = jnp.sum(sl1 * obj_t) / jnp.maximum(jnp.sum(obj_t), 1.0)
    return obj_loss + cls_loss + 2.0 * box_loss


@functools.partial(jax.jit, static_argnames=("cfg",))
def detector_forward(params: PyTree, cfg: DetectorConfig, images: jnp.ndarray):
    """Decoded (boxes_px (B,G*G,4), scores (B,G*G), classes (B,G*G))."""
    out, feat = detector_apply(params, cfg, images)
    B = out.shape[0]
    G = cfg.grid
    cell = cfg.image_size / G
    obj = jax.nn.sigmoid(out[..., 0])
    cls_prob = jax.nn.softmax(out[..., 1 : 1 + cfg.num_classes], axis=-1)
    box = jax.nn.sigmoid(out[..., 1 + cfg.num_classes :])
    gy, gx = jnp.mgrid[0:G, 0:G]
    cx = (box[..., 0] + gx) * cell
    cy = (box[..., 1] + gy) * cell
    w = box[..., 2] * cfg.image_size
    h = box[..., 3] * cfg.image_size
    boxes = jnp.stack([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2], axis=-1)
    score = obj * jnp.max(cls_prob, axis=-1)
    cls = jnp.argmax(cls_prob, axis=-1)
    return (
        boxes.reshape(B, G * G, 4),
        score.reshape(B, G * G),
        cls.reshape(B, G * G),
        feat,
    )


def decode_detections(
    params: PyTree,
    cfg: DetectorConfig,
    images: np.ndarray,
    score_threshold: float = 0.25,
    nms_iou: float = 0.45,
    batch_size: int = 256,
) -> List[Detections]:
    """Full inference: forward + per-image NMS -> host Detections list."""
    results: List[Detections] = []
    nms_fn = jax.jit(functools.partial(nms, iou_threshold=nms_iou,
                                       score_threshold=score_threshold))
    for s in range(0, images.shape[0], batch_size):
        chunk = jnp.asarray(images[s : s + batch_size])
        boxes, scores, classes, _ = detector_forward(params, cfg, chunk)
        boxes, scores, classes = map(np.asarray, (boxes, scores, classes))
        for b in range(boxes.shape[0]):
            keep = np.asarray(
                nms_fn(jnp.asarray(boxes[b]), jnp.asarray(scores[b]),
                       jnp.asarray(classes[b]))
            )
            results.append(
                Detections(boxes[b][keep], scores[b][keep], classes[b][keep])
            )
    return results
