"""ORIC / ORI offloading reward metrics (paper §IV) + MORIC transform (§V-B).

``RewardOracle`` owns the context set ``E`` (weak-detector results on images
sampled uniformly without replacement from a reference pool — the paper uses
the detector's training distribution) and computes, per image ``i``:

    mAPC_i(d)  = mAP({h_{i,d}} ∪ H_{E,w})                       (Eq. 4)
    ORIC_i     = (|E|+1) · (mAPC_i(s) − mAPC_i(w))              (Eq. 5)
    ORI_i      = mAPI_i(s) − mAPI_i(w)    (E = ∅ special case)  (Eq. 1)

and the rank transform MORIC_i = cdf(ORIC_i)                    (Eq. 6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.detection.batch import (
    DetectionsBatch,
    GroundTruthBatch,
    match_batch,
    to_image_evals,
)
from repro.detection.map_engine import (
    APAccumulator,
    Detections,
    GroundTruth,
    ImageEval,
    match_detections,
)


@dataclass
class MatchedImage:
    """Pre-matched weak/strong evaluations for one image (matching is
    per-image, so it is done once and reused across context draws)."""

    weak: ImageEval
    strong: ImageEval


def match_pairs(
    weak_dets: Sequence[Detections],
    strong_dets: Sequence[Detections],
    gts: Sequence[GroundTruth],
    iou_thresholds: Sequence[float] = (0.5,),
) -> List[MatchedImage]:
    out = []
    for dw, ds, gt in zip(weak_dets, strong_dets, gts):
        out.append(
            MatchedImage(
                weak=match_detections(dw, gt, iou_thresholds),
                strong=match_detections(ds, gt, iou_thresholds),
            )
        )
    return out


def match_pairs_batched(
    weak_dets: Union[Sequence[Detections], DetectionsBatch],
    strong_dets: Union[Sequence[Detections], DetectionsBatch],
    gts: Union[Sequence[GroundTruth], GroundTruthBatch],
    iou_thresholds: Sequence[float] = (0.5,),
    *,
    interpret: Optional[bool] = None,
) -> List[MatchedImage]:
    """Batched :func:`match_pairs`: both detector outputs are matched on
    device in two :func:`repro.detection.batch.match_batch` calls (per-image
    IoU through the ``iou_matrix`` Pallas kernel, greedy assignment as one
    ``lax.scan``) instead of 2·N per-image Python matches.  The returned
    ``MatchedImage`` evals are structurally identical to the per-image path
    and feed ``oric_batch`` / ``APAccumulator`` unchanged."""
    wb = (
        weak_dets
        if isinstance(weak_dets, DetectionsBatch)
        else DetectionsBatch.from_list(weak_dets)
    )
    sb = (
        strong_dets
        if isinstance(strong_dets, DetectionsBatch)
        else DetectionsBatch.from_list(strong_dets)
    )
    gb = gts if isinstance(gts, GroundTruthBatch) else GroundTruthBatch.from_list(gts)
    rw = match_batch(wb, gb, iou_thresholds, interpret=interpret)
    rs = match_batch(sb, gb, iou_thresholds, interpret=interpret)
    return [
        MatchedImage(weak=w, strong=s)
        for w, s in zip(to_image_evals(wb, gb, rw), to_image_evals(sb, gb, rs))
    ]


class RewardOracle:
    """Computes exact ORIC (and ORI as the E=∅ degenerate case)."""

    def __init__(
        self,
        context_evals: Sequence[ImageEval],
        iou_thresholds: Sequence[float] = (0.5,),
    ) -> None:
        self.iou_thresholds = tuple(iou_thresholds)
        self.context_size = len(context_evals)
        self._acc = APAccumulator(self.iou_thresholds)
        for ev in context_evals:
            self._acc.add(ev)

    @classmethod
    def from_pool(
        cls,
        pool_weak_evals: Sequence[ImageEval],
        context_size: int,
        rng: np.random.Generator,
        iou_thresholds: Sequence[float] = (0.5,),
    ) -> "RewardOracle":
        """Sample E uniformly without replacement from a weak-result pool."""
        n = len(pool_weak_evals)
        k = min(context_size, n)
        idx = rng.choice(n, size=k, replace=False)
        return cls([pool_weak_evals[int(i)] for i in idx], iou_thresholds)

    def mapc(self, ev: ImageEval) -> float:
        """mAP of {image} ∪ context (Eq. 4)."""
        return self._acc.map_with_image(ev)

    def oric(self, img: MatchedImage) -> float:
        """Eq. 5 — (|E|+1)·(mAPC_s − mAPC_w)."""
        scale = self.context_size + 1
        return scale * (self.mapc(img.strong) - self.mapc(img.weak))

    def oric_batch(self, imgs: Sequence[MatchedImage]) -> np.ndarray:
        """Batched Eq. 5: the context accumulator's base AP sums are hoisted
        out of the loop (two passes total instead of O(N) per-image passes)."""
        scale = self.context_size + 1
        strong = self._acc.map_with_images([im.strong for im in imgs])
        weak = self._acc.map_with_images([im.weak for im in imgs])
        return scale * (strong - weak)


def ori(img: MatchedImage, iou_thresholds: Sequence[float] = (0.5,)) -> float:
    """ORI (Eq. 1 difference): per-image mAPI_s − mAPI_w, no context."""
    empty = APAccumulator(iou_thresholds)
    return empty.map_with_image(img.strong) - empty.map_with_image(img.weak)


def ori_batch(
    imgs: Sequence[MatchedImage], iou_thresholds: Sequence[float] = (0.5,)
) -> np.ndarray:
    """Vectorized ORI via the same hoisted two-pass trick as ``oric_batch``:
    the empty-context accumulator's base AP terms are shared across images
    (trivially zero here), so the whole batch costs two
    ``map_with_images`` passes instead of 2·N accumulator constructions."""
    empty = APAccumulator(iou_thresholds)
    strong = empty.map_with_images([im.strong for im in imgs])
    weak = empty.map_with_images([im.weak for im in imgs])
    return strong - weak


class CdfTransform:
    """Empirical-CDF rank transform (Eq. 6): MORIC = cdf(ORIC) ∈ [0, 1].

    Fit on training rewards; evaluation rewards are mapped by interpolating
    the fitted CDF (mid-rank convention so ties at 0 spread evenly is NOT
    applied — the paper notes exact-0 mass defeats the transform for ORI,
    which we reproduce)."""

    def __init__(self, train_rewards: np.ndarray) -> None:
        r = np.sort(np.asarray(train_rewards, dtype=np.float64))
        self._sorted = r
        self._n = r.size

    def __call__(self, rewards: np.ndarray) -> np.ndarray:
        rewards = np.asarray(rewards, dtype=np.float64)
        # P(R <= r): right-continuous empirical CDF
        ranks = np.searchsorted(self._sorted, rewards, side="right")
        return ranks / max(self._n, 1)

    def state(self) -> dict:
        """Serializable fit state — the public checkpoint surface (callers
        must not reach into ``_sorted``)."""
        return {"sorted_rewards": self._sorted.copy()}

    @classmethod
    def from_state(cls, state: dict) -> "CdfTransform":
        return cls(np.asarray(state["sorted_rewards"], dtype=np.float64))


def cascade_map(
    imgs: Sequence[MatchedImage],
    offload_mask: np.ndarray,
    iou_thresholds: Sequence[float] = (0.5,),
) -> float:
    """Overall mAP of the weak/strong combination given offload decisions
    (the objective of Eq. 2/3)."""
    acc = APAccumulator(iou_thresholds)
    for im, off in zip(imgs, offload_mask):
        acc.add(im.strong if off else im.weak)
    return acc.map()


def topk_offload_mask(scores: np.ndarray, ratio: float) -> np.ndarray:
    """Offload the images whose score is in the top ``ratio`` fraction
    (threshold T = (1-r)-quantile of the scores, paper §III)."""
    n = scores.size
    k = int(round(ratio * n))
    mask = np.zeros(n, dtype=bool)
    if k > 0:
        idx = np.argsort(-scores, kind="stable")[:k]
        mask[idx] = True
    return mask
