"""Reward estimators (paper §V): MLP regressor with MORIC weighted-MSE loss.

The deployable artifact is a small MLP that maps weak-detector features to a
predicted (M)ORIC value.  Training uses the paper's Eq. 7 weighted MSE
(weights = targets, emphasising high-reward images) when ``weighted=True``;
the "vanilla" ablation of Fig. 9 sets ``weighted=False`` and regresses the
untransformed reward.

A small CNN estimator over feature maps is also provided for the §V-A input
study (hidden-layer inputs / early-exit integration).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.adamw import adamw_init, adamw_update
from repro.train.schedule import warmup_cosine

PyTree = dict


def _dense_init(key, fan_in: int, fan_out: int) -> Dict[str, jnp.ndarray]:
    wkey, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / fan_in)
    return {
        "w": jax.random.normal(wkey, (fan_in, fan_out), jnp.float32) * scale,
        "b": jnp.zeros((fan_out,), jnp.float32),
    }


def mlp_init(key, in_dim: int, hidden: Sequence[int] = (128, 64)) -> PyTree:
    params = {}
    dims = [in_dim, *hidden, 1]
    keys = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"layer{i}"] = _dense_init(keys[i], a, b)
    return params


def mlp_apply(params: PyTree, x: jnp.ndarray, *, sigmoid_out: bool) -> jnp.ndarray:
    n_layers = len(params)
    h = x
    for i in range(n_layers):
        p = params[f"layer{i}"]
        h = h @ p["w"] + p["b"]
        if i < n_layers - 1:
            h = jax.nn.gelu(h)
    out = h[..., 0]
    return jax.nn.sigmoid(out) if sigmoid_out else out


def weighted_mse_loss(
    params: PyTree, x: jnp.ndarray, y: jnp.ndarray, *, weighted: bool, sigmoid_out: bool
) -> jnp.ndarray:
    """Eq. 7: Σ_i y_i · (e(x_i) − y_i)² (mean-reduced); plain MSE if unweighted."""
    pred = mlp_apply(params, x, sigmoid_out=sigmoid_out)
    err = jnp.square(pred - y)
    if weighted:
        err = jnp.maximum(y, 0.0) * err
    return jnp.mean(err)


@dataclass
class EstimatorConfig:
    hidden: Tuple[int, ...] = (256, 128)
    weighted: bool = True  # Eq. 7 loss
    sigmoid_out: bool = True  # targets are MORIC ranks in [0, 1]
    lr: float = 2e-3
    weight_decay: float = 1e-4
    epochs: int = 80
    batch_size: int = 256
    standardize: bool = True
    seed: int = 0


class RewardEstimator:
    """Train/eval wrapper around the MLP; the on-device inference path is
    mirrored by the fused Pallas kernel in ``repro.kernels.estimator_mlp``."""

    def __init__(self, in_dim: int, config: Optional[EstimatorConfig] = None):
        # default must be constructed per instance: EstimatorConfig is
        # mutable, so a shared default would leak state across estimators
        self.config = config = config if config is not None else EstimatorConfig()
        self.in_dim = in_dim
        key = jax.random.PRNGKey(config.seed)
        self.params = mlp_init(key, in_dim, config.hidden)
        self._predict = jax.jit(
            functools.partial(mlp_apply, sigmoid_out=config.sigmoid_out)
        )
        self._mu = np.zeros((in_dim,), np.float32)
        self._sigma = np.ones((in_dim,), np.float32)

    def fit(
        self,
        x: np.ndarray,
        y: np.ndarray,
        log_every: int = 0,
    ) -> List[float]:
        cfg = self.config
        if cfg.standardize:
            self._mu = np.asarray(x, np.float32).mean(axis=0)
            self._sigma = np.asarray(x, np.float32).std(axis=0) + 1e-6
            x = (x - self._mu) / self._sigma
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        n = x.shape[0]
        steps_per_epoch = max(n // cfg.batch_size, 1)
        total = cfg.epochs * steps_per_epoch
        sched = warmup_cosine(cfg.lr, max(total // 20, 1), total)
        opt_state = adamw_init(self.params)
        loss_fn = functools.partial(
            weighted_mse_loss, weighted=cfg.weighted, sigmoid_out=cfg.sigmoid_out
        )

        @jax.jit
        def step(params, opt_state, xb, yb, lr):
            loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
            params, opt_state = adamw_update(
                grads, opt_state, params, lr, weight_decay=cfg.weight_decay
            )
            return params, opt_state, loss

        rng = np.random.default_rng(cfg.seed)
        losses: List[float] = []
        params = self.params
        it = 0
        for _ in range(cfg.epochs):
            perm = rng.permutation(n)
            for s in range(steps_per_epoch):
                idx = perm[s * cfg.batch_size : (s + 1) * cfg.batch_size]
                params, opt_state, loss = step(
                    params, opt_state, x[idx], y[idx], sched(it)
                )
                it += 1
                losses.append(float(loss))
                if log_every and it % log_every == 0:
                    print(f"  estimator step {it}/{total} loss {float(loss):.5f}")
        self.params = params
        return losses

    def predict(self, x: np.ndarray) -> np.ndarray:
        if self.config.standardize:
            x = (x - self._mu) / self._sigma
        return np.asarray(self._predict(self.params, jnp.asarray(x, jnp.float32)))


# ---------------------------------------------------------------------------
# CNN estimator over feature maps (paper §V-A hidden-layer input study)
# ---------------------------------------------------------------------------

def cnn_init(key, in_channels: int, width: int = 16) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    def conv(key, cin, cout):
        return {
            "w": jax.random.normal(key, (3, 3, cin, cout), jnp.float32)
            * jnp.sqrt(2.0 / (9 * cin)),
            "b": jnp.zeros((cout,), jnp.float32),
        }
    return {
        "conv0": conv(k1, in_channels, width),
        "conv1": conv(k2, width, 2 * width),
        "head": _dense_init(k3, 2 * width, 1),
    }


def cnn_apply(params: PyTree, fmap: jnp.ndarray) -> jnp.ndarray:
    """fmap: (B, H, W, C) -> (B,) sigmoid reward estimate."""
    h = fmap
    for name in ("conv0", "conv1"):
        p = params[name]
        h = jax.lax.conv_general_dilated(
            h, p["w"], window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p["b"]
        h = jax.nn.gelu(h)
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    out = h @ params["head"]["w"] + params["head"]["b"]
    return jax.nn.sigmoid(out[..., 0])
