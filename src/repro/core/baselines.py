"""Offloading baselines the paper compares against (§V-C):

  * Adaptive Feeding [13] — linear SVM binary classifier (easy/difficult by
    sign of ORI), class weight c₊₁ controls the offload fraction at TRAIN
    time (not runtime); we train one SVM per c₊₁ as the paper does.
  * DCSB [14] — rule policy thresholding (#objects, min box area) from the
    weak output, thresholds grid-searched to maximise prediction accuracy of
    "strong detects more objects"; offload ratio is whatever the rule yields.
  * Random — offloads a uniform random subset at the target ratio.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.detection.map_engine import Detections
from repro.train.adamw import adamw_init, adamw_update


class AdaptiveFeedingSVM:
    """Linear SVM with hinge loss and positive-class weight c₊₁ [13].

    Labels: +1 (difficult, offload) iff ORI > 0.  Trained by subgradient
    descent on ``mean(w_y · max(0, 1 − y·(xw+b))) + λ‖w‖²``.
    """

    def __init__(
        self, c_plus: float = 1.0, l2: float = 1e-4, lr: float = 1e-2,
        epochs: int = 80, seed: int = 0,
    ) -> None:
        self.c_plus = c_plus
        self.l2 = l2
        self.lr = lr
        self.epochs = epochs
        self.seed = seed
        self.w: Optional[np.ndarray] = None
        self.b: float = 0.0

    def fit(self, x: np.ndarray, difficult: np.ndarray) -> "AdaptiveFeedingSVM":
        self._mu = np.asarray(x, np.float32).mean(axis=0)
        self._sigma = np.asarray(x, np.float32).std(axis=0) + 1e-6
        x = (x - self._mu) / self._sigma
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(np.where(difficult, 1.0, -1.0), jnp.float32)
        wgt = jnp.where(y > 0, self.c_plus, 1.0)
        params = {
            "w": jnp.zeros((x.shape[1],), jnp.float32),
            "b": jnp.zeros((), jnp.float32),
        }

        def loss_fn(p):
            margin = y * (x @ p["w"] + p["b"])
            hinge = jnp.maximum(0.0, 1.0 - margin)
            return jnp.mean(wgt * hinge) + self.l2 * jnp.sum(jnp.square(p["w"]))

        opt = adamw_init(params)
        step = jax.jit(
            lambda p, o: (lambda l, g: adamw_update(g, o, p, self.lr, weight_decay=0.0) + (l,))(
                *jax.value_and_grad(loss_fn)(p)
            )
        )
        for _ in range(self.epochs):
            params, opt, _ = step(params, opt)
        self.w = np.asarray(params["w"])
        self.b = float(params["b"])
        return self

    def decision(self, x: np.ndarray) -> np.ndarray:
        x = (x - self._mu) / self._sigma
        return x @ self.w + self.b

    def predict(self, x: np.ndarray) -> np.ndarray:
        """True = offload."""
        return self.decision(x) > 0


@dataclass
class DCSBRule:
    """Offload iff #detected objects <= thr_count OR min box area <= thr_area."""

    thr_count: float
    thr_area: float

    def predict_signals(self, counts: np.ndarray, min_areas: np.ndarray) -> np.ndarray:
        return (counts <= self.thr_count) | (min_areas <= self.thr_area)


def dcsb_signals(dets: Sequence[Detections], score_floor: float = 0.1) -> Tuple[np.ndarray, np.ndarray]:
    """(num objects, smallest box area) from weak outputs."""
    counts, areas = [], []
    for d in dets:
        keep = d.scores >= score_floor
        counts.append(int(keep.sum()))
        if keep.any():
            b = d.boxes[keep]
            a = np.clip(b[:, 2] - b[:, 0], 0, None) * np.clip(b[:, 3] - b[:, 1], 0, None)
            areas.append(float(a.min()))
        else:
            areas.append(0.0)
    return np.array(counts, dtype=np.float64), np.array(areas, dtype=np.float64)


def fit_dcsb(
    weak_dets: Sequence[Detections],
    strong_dets: Sequence[Detections],
    score_floor: float = 0.1,
) -> DCSBRule:
    """Grid-search thresholds maximising accuracy of predicting
    "strong detects more objects than weak" [14]."""
    counts, areas = dcsb_signals(weak_dets, score_floor)
    s_counts, _ = dcsb_signals(strong_dets, score_floor)
    label = s_counts > counts  # strong finds more -> should offload
    count_grid = np.unique(np.concatenate([[-1.0], counts]))
    area_grid = np.unique(np.concatenate([[-1.0], np.quantile(areas, np.linspace(0, 1, 33))]))
    best = (-1.0, DCSBRule(-1.0, -1.0))
    for tc in count_grid:
        pred_c = counts <= tc
        for ta in area_grid:
            pred = pred_c | (areas <= ta)
            acc = float(np.mean(pred == label))
            if acc > best[0]:
                best = (acc, DCSBRule(float(tc), float(ta)))
    return best[1]


def random_offload_mask(n: int, ratio: float, rng: np.random.Generator) -> np.ndarray:
    k = int(round(ratio * n))
    mask = np.zeros(n, dtype=bool)
    mask[rng.choice(n, size=k, replace=False)] = True
    return mask
