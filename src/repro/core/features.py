"""Estimator input features from the weak detector's output (paper §V-A).

Mirrors [13]: features of the top-K (default 25) bounding boxes ranked by
confidence, concatenated with global summary statistics.  Per box:
``[score, cx, cy, w, h, area, aspect, onehot(class)]``; global:
``[num_boxes/K, mean score, max score, score entropy, class histogram]``.
Everything is derived exclusively from the weak detector's result — the
constraint the paper imposes on a deployable estimator.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.detection.map_engine import Detections


def feature_dim(num_classes: int, top_k: int = 25) -> int:
    per_box = 7 + num_classes
    global_dim = 4 + num_classes
    return top_k * per_box + global_dim


def extract_features(
    det: Detections,
    num_classes: int,
    top_k: int = 25,
    image_size: float = 1.0,
) -> np.ndarray:
    """Fixed-size feature vector for one image's weak detections."""
    det = det.top_k(top_k)
    n = len(det)
    per_box = 7 + num_classes
    feats = np.zeros((top_k, per_box), dtype=np.float32)
    if n:
        b = det.boxes / image_size
        cx = (b[:, 0] + b[:, 2]) / 2
        cy = (b[:, 1] + b[:, 3]) / 2
        w = np.maximum(b[:, 2] - b[:, 0], 0)
        h = np.maximum(b[:, 3] - b[:, 1], 0)
        area = w * h
        aspect = w / np.maximum(h, 1e-6)
        feats[:n, 0] = det.scores
        feats[:n, 1] = cx
        feats[:n, 2] = cy
        feats[:n, 3] = w
        feats[:n, 4] = h
        feats[:n, 5] = area
        feats[:n, 6] = np.clip(aspect, 0, 10) / 10.0
        cls = np.clip(det.classes, 0, num_classes - 1)
        feats[np.arange(n), 7 + cls] = 1.0
    hist = np.zeros(num_classes, dtype=np.float32)
    if n:
        np.add.at(hist, np.clip(det.classes, 0, num_classes - 1), 1.0)
        hist /= n
        s = det.scores / max(det.scores.sum(), 1e-9)
        entropy = float(-(s * np.log(np.maximum(s, 1e-12))).sum())
        glob = np.array(
            [n / top_k, float(det.scores.mean()), float(det.scores.max()), entropy],
            dtype=np.float32,
        )
    else:
        glob = np.zeros(4, dtype=np.float32)
    return np.concatenate([feats.reshape(-1), glob, hist])


def extract_features_batch(
    dets: Sequence[Detections], num_classes: int, top_k: int = 25, image_size: float = 1.0
) -> np.ndarray:
    return np.stack(
        [extract_features(d, num_classes, top_k, image_size) for d in dets]
    )
