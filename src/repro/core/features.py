"""Estimator input features from the weak detector's output (paper §V-A).

Mirrors [13]: features of the top-K (default 25) bounding boxes ranked by
confidence, concatenated with global summary statistics.  Per box:
``[score, cx, cy, w, h, area, aspect, onehot(class)]``; global:
``[num_boxes/K, mean score, max score, score entropy, class histogram]``.
Everything is derived exclusively from the weak detector's result — the
constraint the paper imposes on a deployable estimator.

``extract_features`` is the per-image numpy reference; the batched path
(``extract_features_batch``) is one jitted kernel over a padded
:class:`repro.detection.batch.DetectionsBatch` — no per-image Python.
"""
from __future__ import annotations

import functools
from typing import Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.detection.batch import DetectionsBatch
from repro.detection.map_engine import Detections
from repro.obs.jit_stats import register_jit


def feature_dim(num_classes: int, top_k: int = 25) -> int:
    per_box = 7 + num_classes
    global_dim = 4 + num_classes
    return top_k * per_box + global_dim


def extract_features(
    det: Detections,
    num_classes: int,
    top_k: int = 25,
    image_size: float = 1.0,
) -> np.ndarray:
    """Fixed-size feature vector for one image's weak detections."""
    det = det.top_k(top_k)
    n = len(det)
    per_box = 7 + num_classes
    feats = np.zeros((top_k, per_box), dtype=np.float32)
    if n:
        b = det.boxes / image_size
        cx = (b[:, 0] + b[:, 2]) / 2
        cy = (b[:, 1] + b[:, 3]) / 2
        w = np.maximum(b[:, 2] - b[:, 0], 0)
        h = np.maximum(b[:, 3] - b[:, 1], 0)
        area = w * h
        aspect = w / np.maximum(h, 1e-6)
        feats[:n, 0] = det.scores
        feats[:n, 1] = cx
        feats[:n, 2] = cy
        feats[:n, 3] = w
        feats[:n, 4] = h
        feats[:n, 5] = area
        feats[:n, 6] = np.clip(aspect, 0, 10) / 10.0
        cls = np.clip(det.classes, 0, num_classes - 1)
        feats[np.arange(n), 7 + cls] = 1.0
    hist = np.zeros(num_classes, dtype=np.float32)
    if n:
        np.add.at(hist, np.clip(det.classes, 0, num_classes - 1), 1.0)
        hist /= n
        s = det.scores / max(det.scores.sum(), 1e-9)
        entropy = float(-(s * np.log(np.maximum(s, 1e-12))).sum())
        glob = np.array(
            [n / top_k, float(det.scores.mean()), float(det.scores.max()), entropy],
            dtype=np.float32,
        )
    else:
        glob = np.zeros(4, dtype=np.float32)
    return np.concatenate([feats.reshape(-1), glob, hist])


def box_feature_stack(boxes, scores, classes, mask, image_size, num_classes, top_k):
    """One batched pass: top-k selection + per-box features + global stats,
    all masked ops over the padded (B, K) struct-of-arrays.

    Pure traceable jnp (unjitted) so the fused score pipeline
    (``repro.kernels.score_pipeline``) can inline it into one end-to-end
    jit with the standardize + MLP stages; ``_features_kernel`` below is
    the standalone jitted form everything else calls."""
    # top-k by confidence; invalid slots sink with -inf keys, ties keep the
    # original slot order (stable)
    keys = jnp.where(mask, scores, -jnp.inf)
    order = jnp.argsort(-keys, axis=1, stable=True)[:, :top_k]  # (B, top_k)
    m = jnp.take_along_axis(mask, order, axis=1).astype(jnp.float32)
    s = jnp.take_along_axis(scores, order, axis=1) * m
    cls = jnp.clip(jnp.take_along_axis(classes, order, axis=1), 0, num_classes - 1)
    b = jnp.take_along_axis(boxes, order[:, :, None], axis=1) / image_size
    cx = (b[..., 0] + b[..., 2]) / 2
    cy = (b[..., 1] + b[..., 3]) / 2
    w = jnp.maximum(b[..., 2] - b[..., 0], 0.0)
    h = jnp.maximum(b[..., 3] - b[..., 1], 0.0)
    area = w * h
    aspect = jnp.clip(w / jnp.maximum(h, 1e-6), 0.0, 10.0) / 10.0
    onehot = jax.nn.one_hot(cls, num_classes, dtype=jnp.float32) * m[..., None]
    feats = jnp.concatenate(
        [
            jnp.stack(
                [s, cx * m, cy * m, w * m, h * m, area * m, aspect * m], axis=-1
            ),
            onehot,
        ],
        axis=-1,
    )  # (B, top_k, 7 + C)

    n = m.sum(axis=1)  # (B,) number of selected valid boxes
    nonempty = n > 0
    safe_n = jnp.maximum(n, 1.0)
    hist = jnp.where(nonempty[:, None], onehot.sum(axis=1) / safe_n[:, None], 0.0)
    s_sum = s.sum(axis=1)
    p = s / jnp.maximum(s_sum, 1e-9)[:, None]
    entropy = -(p * jnp.log(jnp.maximum(p, 1e-12))).sum(axis=1)
    s_max = jnp.max(jnp.where(m > 0, s, -jnp.inf), axis=1)
    glob = jnp.stack(
        [n / top_k, s_sum / safe_n, jnp.where(nonempty, s_max, 0.0), entropy],
        axis=-1,
    )
    glob = jnp.where(nonempty[:, None], glob, 0.0)
    B = scores.shape[0]
    return jnp.concatenate([feats.reshape(B, -1), glob, hist], axis=1)


_features_kernel = register_jit(
    "features.box_feature_stack",
    functools.partial(
        jax.jit, static_argnames=("num_classes", "top_k")
    )(box_feature_stack),
)


def extract_features_batch(
    dets: Union[Sequence[Detections], DetectionsBatch],
    num_classes: int,
    top_k: int = 25,
    image_size: float = 1.0,
) -> np.ndarray:
    """(B, F) feature matrix in one jitted batched kernel.

    Accepts a padded :class:`DetectionsBatch` directly (the device-resident
    data plane) or a ragged list of ``Detections`` (padded here); both run
    the same masked kernel — numerically the batched float32 computation of
    the per-image reference.
    """
    batch = dets if isinstance(dets, DetectionsBatch) else DetectionsBatch.from_list(dets)
    boxes, scores, classes, mask = batch.boxes, batch.scores, batch.classes, batch.mask
    if batch.max_boxes < top_k:  # kernel slices a fixed top_k window
        pad = top_k - batch.max_boxes
        boxes = np.pad(boxes, ((0, 0), (0, pad), (0, 0)))
        scores = np.pad(scores, ((0, 0), (0, pad)))
        classes = np.pad(classes, ((0, 0), (0, pad)), constant_values=-1)
        mask = np.pad(mask, ((0, 0), (0, pad)))
    out = _features_kernel(
        jnp.asarray(boxes), jnp.asarray(scores), jnp.asarray(classes),
        jnp.asarray(mask), jnp.float32(image_size), int(num_classes), int(top_k),
    )
    return np.asarray(out)
