"""The paper's primary contribution: ORIC/MORIC offloading rewards, the
frugal reward estimator, decision policies, and the baselines it is
evaluated against."""
from repro.core.reward import (
    CdfTransform,
    MatchedImage,
    RewardOracle,
    cascade_map,
    match_pairs,
    match_pairs_batched,
    ori,
    ori_batch,
    topk_offload_mask,
)
from repro.core.features import extract_features, extract_features_batch, feature_dim
from repro.core.estimator import (
    EstimatorConfig,
    RewardEstimator,
    cnn_apply,
    cnn_init,
    mlp_apply,
    mlp_init,
    weighted_mse_loss,
)
from repro.core.policy import ThresholdPolicy, TokenBucket
from repro.core.baselines import (
    AdaptiveFeedingSVM,
    DCSBRule,
    dcsb_signals,
    fit_dcsb,
    random_offload_mask,
)
from repro.core.cascade import Cascade, CascadeRecord

__all__ = [
    "CdfTransform",
    "MatchedImage",
    "RewardOracle",
    "cascade_map",
    "match_pairs",
    "match_pairs_batched",
    "ori",
    "ori_batch",
    "topk_offload_mask",
    "extract_features",
    "extract_features_batch",
    "feature_dim",
    "EstimatorConfig",
    "RewardEstimator",
    "cnn_apply",
    "cnn_init",
    "mlp_apply",
    "mlp_init",
    "weighted_mse_loss",
    "ThresholdPolicy",
    "TokenBucket",
    "AdaptiveFeedingSVM",
    "DCSBRule",
    "dcsb_signals",
    "fit_dcsb",
    "random_offload_mask",
    "Cascade",
    "CascadeRecord",
]
