"""Generic weak/strong cascade orchestration (the paper's Fig. 4 pipeline).

Domain-agnostic: a ``Cascade`` pairs a weak inference fn, a reward-estimate
fn (reading only weak output), a strong inference fn, and a decision policy.
Used (a) by the detection repro and (b) by LM cascade/early-exit serving in
``repro.serving.cascade_serving``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.policy import ThresholdPolicy


@dataclass
class CascadeRecord:
    """Per-item trace for accounting/latency breakdown (paper Table III)."""

    estimate: float
    offloaded: bool
    weak_output: Any
    final_output: Any


@dataclass
class Cascade:
    weak_fn: Callable[[Any], Any]
    estimate_fn: Callable[[Any], float]  # weak output -> reward estimate
    strong_fn: Callable[[Any], Any]
    policy: ThresholdPolicy

    def process(self, item: Any) -> CascadeRecord:
        weak_out = self.weak_fn(item)
        est = float(self.estimate_fn(weak_out))
        offload = self.policy.decide(est)
        final = self.strong_fn(item) if offload else weak_out
        return CascadeRecord(est, offload, weak_out, final)

    def run(self, items: Iterable[Any]) -> List[CascadeRecord]:
        return [self.process(it) for it in items]

    def offload_ratio(self, records: List[CascadeRecord]) -> float:
        if not records:
            return 0.0
        return float(np.mean([r.offloaded for r in records]))
