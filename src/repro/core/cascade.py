"""Generic weak/strong cascade orchestration (the paper's Fig. 4 pipeline).

Domain-agnostic: a ``Cascade`` pairs a weak inference fn, a reward-estimate
fn (reading only weak output), a strong inference fn, and a decision policy.
The canonical construction path is :meth:`Cascade.from_engine`, which wires
the estimate fn and policy from a fitted :class:`repro.api.OffloadEngine`;
the explicit-field form remains for hand-rolled stacks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterable, List

import numpy as np


@dataclass
class CascadeRecord:
    """Per-item trace for accounting/latency breakdown (paper Table III)."""

    estimate: float
    offloaded: bool
    weak_output: Any
    final_output: Any


@dataclass
class Cascade:
    weak_fn: Callable[[Any], Any]
    estimate_fn: Callable[[Any], float]  # weak output -> reward estimate
    strong_fn: Callable[[Any], Any]
    policy: Any  # anything with decide(estimate) -> bool

    @classmethod
    def from_engine(
        cls,
        weak_fn: Callable[[Any], Any],
        strong_fn: Callable[[Any], Any],
        engine,
    ) -> "Cascade":
        """Item-at-a-time cascade driven by a fitted ``OffloadEngine``: the
        engine's reward model scores each weak output and its policy decides."""
        if engine.policy is None:
            raise ValueError("engine must be fit() before building a Cascade")

        def estimate(weak_out: Any) -> float:
            return float(engine.score([weak_out])[0])

        return cls(
            weak_fn=weak_fn,
            estimate_fn=estimate,
            strong_fn=strong_fn,
            policy=engine.policy,
        )

    def process(self, item: Any) -> CascadeRecord:
        weak_out = self.weak_fn(item)
        est = float(self.estimate_fn(weak_out))
        offload = self.policy.decide(est)
        final = self.strong_fn(item) if offload else weak_out
        return CascadeRecord(est, offload, weak_out, final)

    def run(self, items: Iterable[Any]) -> List[CascadeRecord]:
        return [self.process(it) for it in items]

    def offload_ratio(self, records: List[CascadeRecord]) -> float:
        if not records:
            return 0.0
        return float(np.mean([r.offloaded for r in records]))
