"""Offloading decision policies (paper §III, §V).

The paper's deployable policy is a fixed threshold T on the reward estimate,
with T = the (1-r)-quantile of calibration-set estimates for a target
offloading ratio r — adjustable at runtime (the key advantage over the
train-time-fixed baselines).  A token-bucket variant ([23]-style) enforces a
hard rate constraint with burst tolerance for the dynamic-budget setting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class ThresholdPolicy:
    """Offload iff estimate > T; T derived from a calibration distribution."""

    def __init__(self, calibration_scores: np.ndarray, ratio: float) -> None:
        self._cal = np.sort(np.asarray(calibration_scores, dtype=np.float64))
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        """Runtime-adjustable offloading ratio (paper Table I row 3)."""
        self.ratio = float(np.clip(ratio, 0.0, 1.0))
        if self.ratio >= 1.0:
            self.threshold = -np.inf
        elif self.ratio <= 0.0:
            self.threshold = np.inf
        else:
            self.threshold = float(np.quantile(self._cal, 1.0 - self.ratio))

    def decide(self, estimate: float) -> bool:
        return bool(estimate > self.threshold)

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        return np.asarray(estimates) > self.threshold


@dataclass
class TokenBucket:
    """Token-bucket rate limiter for offloading under hard budget (cf. [23]).

    ``rate`` tokens arrive per image; bucket depth ``depth``; an offload
    consumes one token.  The effective threshold rises as the bucket drains,
    making the policy spend scarce tokens only on the highest estimates.

    With ``clock`` (any zero-arg callable returning a monotone float, e.g. a
    simulation's manual clock), refill becomes ``rate`` tokens per *time
    unit* instead of per arrival.  ``decide`` never reads the wall clock
    itself, so streaming simulations and tests stay reproducible.
    """

    rate: float
    depth: float
    base_threshold: float
    level: Optional[float] = None  # None -> starts full (= depth)
    clock: Optional[Callable[[], float]] = field(default=None, repr=False)

    def __post_init__(self) -> None:
        if self.level is None:
            self.level = self.depth
        self._last_t = self.clock() if self.clock is not None else 0.0

    def _refill(self) -> None:
        if self.clock is None:
            self.level = min(self.level + self.rate, self.depth)
            return
        now = self.clock()
        dt = max(now - self._last_t, 0.0)
        self._last_t = now
        self.level = min(self.level + self.rate * dt, self.depth)

    def try_take(self) -> bool:
        """Plain rate-limiter admission: consume a token if one is available.
        Unlike ``decide`` there is no scarcity threshold — this is the
        estimate-independent form edge servers use to cap admissions."""
        self._refill()
        if self.level >= 1.0:
            self.level -= 1.0
            return True
        return False

    def decide(self, estimate: float) -> bool:
        self._refill()
        if self.level < 1.0:
            return False
        # scarcity-adjusted threshold: full bucket -> base threshold,
        # nearly-empty bucket -> demand estimates near the top of [0, 1]
        scarcity = 1.0 - (self.level - 1.0) / max(self.depth - 1.0, 1e-9)
        thr = self.base_threshold + (1.0 - self.base_threshold) * scarcity
        if estimate > thr:
            self.level -= 1.0
            return True
        return False
