"""Online decision policy: self-calibrating quantile threshold.

``adaptive_threshold`` is the streaming counterpart of the paper's
deployable quantile threshold: instead of freezing the calibration score
distribution at fit time, it tracks the estimates it actually decides on
with a :class:`~repro.online.cdf.StreamingQuantiles` grid (warm-started
from the fitted calibration scores) and rederives the ``(1-ratio)``
threshold from the *live* distribution every decision.  The shared
:class:`~repro.api.policies.BudgetTracker` integral controller closes the
realized-ratio loop on top, so the target budget holds both through the
tracker's warmup and through genuine distribution shifts.

Registered through the same lazy ``_ensure_plugins`` pattern as the netsim
and video policies, so ``OffloadEngine(policy="adaptive_threshold")`` works
without importing ``repro.online`` anywhere.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.api.policies import (
    BudgetTracker,
    decide_sequential,
    register_policy,
)
from repro.online.cdf import StreamingQuantiles


@register_policy("adaptive_threshold")
class AdaptiveThresholdPolicy:
    """Quantile threshold against the live estimate distribution.

    Parameters (beyond the registry's ``calibration_scores, ratio``):

    n_markers : int
        Resolution of the streaming quantile grid.
    gain : float
        Integral gain of the realized-ratio tracker.
    """

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        n_markers: int = 33,
        gain: float = 0.05,
    ):
        cal = np.sort(np.asarray(calibration_scores, np.float64))
        self._tracker = StreamingQuantiles(int(n_markers)).warm_start(cal)
        self._fallback_cal = cal  # until the tracker markers initialize
        self._budget = BudgetTracker(gain)
        self.n_markers = int(n_markers)
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))

    def _calibration(self) -> np.ndarray:
        if self._tracker.initialized:
            return self._tracker.calibration_scores()
        return self._fallback_cal

    def decide(self, estimate: float) -> bool:
        e = float(estimate)
        self._tracker.update(e)
        off = bool(e > self._budget.threshold(self._calibration(), self.ratio))
        self._budget.account(off)
        return off

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # sequential by construction: the tracker and the deficit controller
        # both evolve decision to decision
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, Any]:
        return {"n_markers": self.n_markers, "gain": self._budget.gain}
