"""Estimator-confidence drift detection on realized-vs-predicted residuals.

Each observed offload yields a residual ``r = realized − predicted`` in the
engine's rank space ([0, 1] after the CDF transform).  The residual stream
over the *offloaded subset* is NOT zero-mean even for a perfectly
calibrated estimator — offloaded frames are the predicted-high tail, so
selection bias (regression to the mean) gives a steady negative offset.
What a distribution shift changes is the residual *level*.
:class:`DriftDetector` is therefore self-starting:

- an **EWMA** baseline of the residual and its deviation variance (the
  running level and scale — steady selection bias lives here), and
- a standardized **two-sided CUSUM** over deviations *from that baseline*:
  ``z = (r − mean) / sigma``, ``S⁺ = max(0, S⁺ + z − k)``,
  ``S⁻ = max(0, S⁻ − z − k)`` — the classic change-point statistic that
  accumulates evidence of a level *change* while shrugging off isolated
  outliers and absorbing any constant offset into the baseline.

``drifted`` fires when either side exceeds the threshold ``h`` (after a
minimum observation count), which the :class:`AdaptiveEngine` answers with a
forced refit; ``ratio_multiplier()`` maps accumulated drift evidence to a
widened offload ratio in [1, ``widen``] — when confidence decays, buy more
strong supervision, which is exactly what re-fits the model fastest.

Pure scalar state, no RNG; serializes via ``state()/from_state`` for
bit-identical replay from checkpoints.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class DriftConfig:
    alpha: float = 0.05  # EWMA weight on the newest residual (baseline speed)
    k: float = 0.5  # CUSUM allowance (in residual-sigma units)
    h: float = 8.0  # CUSUM decision threshold (sigma units)
    min_obs: int = 16  # observations before drift can fire
    widen: float = 1.25  # max offload-ratio multiplier at full drift evidence
    sigma_floor: float = 0.02  # residual-scale floor (rank space)


class DriftDetector:
    """Self-starting residual CUSUM: EWMA baseline + two-sided standardized
    CUSUM over deviations from it."""

    def __init__(self, config: DriftConfig = DriftConfig()):
        if not 0.0 < config.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {config.alpha}")
        if config.widen < 1.0:
            raise ValueError(f"widen must be >= 1, got {config.widen}")
        self.config = config
        self.mean = 0.0  # EWMA baseline of residuals
        self.var = 0.0  # EWMA of squared deviations from the baseline
        self.cusum_pos = 0.0
        self.cusum_neg = 0.0
        self.n = 0
        self.events = 0  # drift detections over the detector's lifetime
        # CUSUM accumulation pauses until the baseline has settled — after
        # construction AND after every reset() (a refit changes the model,
        # so the residual level legitimately moves and must re-baseline
        # without counting as fresh drift)
        self.settle_until = self.config.min_obs
        self._reseed = False  # next sample re-anchors the baseline

    def update(self, predicted: float, realized: float) -> float:
        """Fold one (predicted, realized) pair; returns the residual."""
        r = float(realized) - float(predicted)
        a = self.config.alpha
        if self.n == 0 or self._reseed:
            # anchor the baseline at the current residual level — at start,
            # and after a handled drift (the refit legitimately moved the
            # level; chasing it with the slow EWMA would re-trigger).  The
            # scale estimate is kept: refits move the level, not the noise.
            self.mean = r
            self._reseed = False
        else:
            # score against the baseline BEFORE it absorbs this sample —
            # a level change accumulates in the CUSUM while the baseline
            # slowly catches up
            if self.n >= self.settle_until:
                z = (r - self.mean) / self.sigma
                k = self.config.k
                self.cusum_pos = max(0.0, self.cusum_pos + z - k)
                self.cusum_neg = max(0.0, self.cusum_neg - z - k)
            dev = r - self.mean
            self.mean += a * dev
            self.var = (1.0 - a) * self.var + a * dev * dev
        self.n += 1
        return r

    @property
    def sigma(self) -> float:
        """Residual scale estimate (EWMA deviation std, floored)."""
        return max(float(np.sqrt(max(self.var, 0.0))), self.config.sigma_floor)

    @property
    def statistic(self) -> float:
        """The larger of the two CUSUM sides — accumulated drift evidence."""
        return max(self.cusum_pos, self.cusum_neg)

    @property
    def drifted(self) -> bool:
        return self.n >= self.config.min_obs and self.statistic > self.config.h

    def confidence(self) -> float:
        """Estimator confidence in (0, 1]: 1 with no drift evidence,
        → 0 as the CUSUM statistic blows past the threshold."""
        return 1.0 / (1.0 + self.statistic / self.config.h)

    def ratio_multiplier(self) -> float:
        """Offload-ratio widening in [1, widen].  Gated: widening starts
        only once the CUSUM statistic passes half the decision threshold —
        the sub-h/2 band is where the statistic wanders under steady-state
        noise (residuals are autocorrelated across concurrent streams), and
        widening there would chronically inflate the realized offload
        ratio.  Above the gate it ramps linearly to ``widen`` at ``h``."""
        half = 0.5 * self.config.h
        frac = min(max(self.statistic - half, 0.0) / half, 1.0)
        return 1.0 + (self.config.widen - 1.0) * frac

    def rebaseline(self) -> None:
        """Re-anchor after a *planned* incremental model update: the
        prediction level legitimately moved, so the baseline mean re-seeds
        at the next sample instead of slowly chasing it (which would read
        the loop's own updates as drift).  The CUSUM sides are kept: they
        decay on their own (−k per in-control sample), so surviving
        evidence means mispredictions persist *despite* the incremental
        path keeping up — exactly the condition for a drift-forced full
        refit."""
        self._reseed = True

    def reset(self, count_event: bool = True) -> None:
        """Re-arm after a full refit landed: clear the CUSUM evidence and
        pause accumulation while the baseline re-settles on the refreshed
        model's residual level.  ``count_event=False`` for periodic
        (schedule-driven) refits that were not drift-forced."""
        self.cusum_pos = 0.0
        self.cusum_neg = 0.0
        self.settle_until = self.n + self.config.min_obs
        self._reseed = True
        if count_event:
            self.events += 1

    def state(self) -> Dict[str, np.ndarray]:
        return {
            "mean": np.asarray(self.mean, np.float64),
            "var": np.asarray(self.var, np.float64),
            "cusum_pos": np.asarray(self.cusum_pos, np.float64),
            "cusum_neg": np.asarray(self.cusum_neg, np.float64),
            "n": np.asarray(self.n, np.int64),
            "events": np.asarray(self.events, np.int64),
            "settle_until": np.asarray(self.settle_until, np.int64),
            "reseed": np.asarray(int(self._reseed), np.int64),
        }

    @classmethod
    def from_state(
        cls, state: Dict[str, np.ndarray], config: DriftConfig = DriftConfig()
    ) -> "DriftDetector":
        det = cls(config)
        det.mean = float(np.asarray(state["mean"]))
        det.var = float(np.asarray(state["var"]))
        det.cusum_pos = float(np.asarray(state["cusum_pos"]))
        det.cusum_neg = float(np.asarray(state["cusum_neg"]))
        det.n = int(np.asarray(state["n"]))
        det.events = int(np.asarray(state["events"]))
        if "settle_until" in state:
            det.settle_until = int(np.asarray(state["settle_until"]))
        if "reseed" in state:
            det._reseed = bool(int(np.asarray(state["reseed"])))
        return det
