"""Closed-loop adaptation over the frozen offloading engine.

The paper fits its reward estimator once and freezes it — but in deployment
every offloaded frame returns the strong detection, a free supervision
signal.  This subsystem closes the loop on the existing engine seams:

- :mod:`repro.online.updates` — streaming reward-model refit from realized
  strong−weak per-frame AP: an incremental last-layer least-squares path on
  the fused estimator MLP and a periodic jitted mini-refit, over a replay
  ring buffer of feature blocks.
- :mod:`repro.online.cdf` — P²-style streaming quantile tracking keeping
  the score calibration and the MORIC rank transform live as distributions
  move (round-trips through ``CdfTransform.state()/from_state``).
- :mod:`repro.online.drift` — realized-vs-predicted residual CUSUM/EWMA
  drift detection that widens the offload ratio and forces refits when
  estimator confidence decays.
- :mod:`repro.online.netstate` — measured rolling RTT / bandwidth /
  queue-sojourn estimators fed from completed round trips, replacing the
  oracle ``congestion``/``state_probe`` context probes
  (``OffloadRuntime(net_state=...)``).
- :mod:`repro.online.engine` — :class:`AdaptiveEngine`, the wrapper tying
  them together with explicit ``observe()``/``maybe_update()`` hooks on the
  manual clock; fully seeded, checkpointable, bit-identical on replay.
- :mod:`repro.online.experiment` — the seeded mid-stream distribution-shift
  headline: the adaptive engine recovers post-shift effective accuracy the
  frozen engine permanently loses, at equal realized offload ratio.

The ``adaptive_threshold`` policy registers through the same lazy registry
hook as the netsim/video policies.  See docs/API.md "Online adaptation".
"""
from repro.online.cdf import StreamingQuantiles
from repro.online.drift import DriftConfig, DriftDetector
from repro.online.engine import (
    AdaptiveEngine,
    OnlineConfig,
    UpdateReport,
    clone_engine,
)
from repro.online.experiment import (
    POST_SHIFT_PROFILE,
    PRE_SHIFT_PROFILE,
    ShiftRunResult,
    ShiftScenario,
    default_shift_scenario,
    run_shift_scenario,
)
from repro.online.netstate import NetworkEstimator
from repro.online.policy import AdaptiveThresholdPolicy
from repro.online.updates import (
    LastLayerSolver,
    ReplayBuffer,
    apply_last_layer,
    hidden_features,
    mini_refit,
    reward_to_logit,
)

__all__ = [
    "AdaptiveEngine",
    "AdaptiveThresholdPolicy",
    "DriftConfig",
    "DriftDetector",
    "LastLayerSolver",
    "NetworkEstimator",
    "OnlineConfig",
    "POST_SHIFT_PROFILE",
    "PRE_SHIFT_PROFILE",
    "ReplayBuffer",
    "ShiftRunResult",
    "ShiftScenario",
    "StreamingQuantiles",
    "UpdateReport",
    "apply_last_layer",
    "clone_engine",
    "default_shift_scenario",
    "hidden_features",
    "mini_refit",
    "reward_to_logit",
    "run_shift_scenario",
]
