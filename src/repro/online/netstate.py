"""Measured network-state estimation from realized offload completions.

The netsim policies (``queue_aware``, ``value_iteration``) consume
*oracle* probes today: ``OffloadRuntime._congestion`` reads the simulator's
own ``predicted_uplink_delay`` and ``_state_probe`` reads the true
``(queue_depth, channel_state)`` — signals a real device cannot see.  What
a device *can* see is each offload's round trip: when it sent the frame,
when the result came back, and (from response metadata) how the latency
decomposed.  SmartDet's conclusion (PAPERS.md) is that exactly these
context signals must be tracked at runtime rather than probed.

:class:`NetworkEstimator` is that tracker:

- **RTT**: TCP-style SRTT/RTTVAR exponential estimators over completed
  round trips (RFC 6298 weighting).
- **Bandwidth**: EWMA of ``bits / transmit_delay`` per delivered frame.
- **Queue sojourn**: EWMA of the uplink queue wait component of each
  round trip (telemetry / diagnostics).
- **Congestion**: an in-flight census — 0 while any uplink is free, else
  the per-link backlog times the smoothed transmission time.  Frames in
  flight are known at *send* time, so this leads the round-trip evidence
  by a full RTT and tracks the oracle probe's sharp on/off shape.

**Causality on the manual clock**: a completion recorded at send time
``t_sent`` with round trip ``rtt`` only becomes *visible* to the estimators
at ``t_sent + rtt`` — samples sit in a pending heap and drain against the
injected clock, so the estimator never sees the future and seeded replays
are exact.  ``congestion()`` / ``state_probe()`` are drop-in replacements
for the runtime's oracle probes (``OffloadRuntime(net_state=...)`` swaps
them in).
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.edge import LatencyBreakdown

# RFC 6298 smoothing weights
_SRTT_ALPHA = 0.125
_RTTVAR_BETA = 0.25


class NetworkEstimator:
    """Rolling RTT / bandwidth / queue-sojourn estimators over completed
    offloads, with send-time causality on an injected manual clock.

    Parameters
    ----------
    alpha : float
        EWMA weight on the newest queue/transmit/bandwidth sample.
    parallelism : int
        Uplinks the fleet serves in parallel (frames in flight up to this
        count imply no queueing).  ``bind_fleet`` sets it from the runtime.
    pressure : float
        Weight of the in-flight backlog term in :meth:`congestion`: with
        every uplink busy, the estimate is ``pressure * (outstanding /
        parallelism) * transmit_ewma``.
    clock : callable or None
        Zero-arg time source (the runtime's ``ManualClock``); samples only
        become visible once the clock passes their delivery time.
    """

    def __init__(
        self,
        *,
        alpha: float = 0.3,
        parallelism: int = 1,
        pressure: float = 1.0,
        clock: Optional[Callable[[], float]] = None,
    ):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self.parallelism = max(int(parallelism), 1)
        self.pressure = float(pressure)
        self._clock = clock
        # pending completions: (t_visible, seq, rtt, queue, transmit, service, bits)
        self._pending: List[tuple] = []
        self._seq = 0  # heap tie-breaker, part of serialized state
        self.srtt: Optional[float] = None
        self.rttvar = 0.0
        self.queue_ewma = 0.0
        self.transmit_ewma = 0.0
        self.service_ewma = 0.0
        self.bw_ewma = 0.0
        self.min_transmit = np.inf
        self.delivered = 0

    # --------------------------------------------------------------- wiring

    def bind_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    def bind_fleet(self, n_edges: int) -> None:
        self.parallelism = max(int(n_edges), 1)

    # -------------------------------------------------------------- feeding

    def record(
        self,
        t_sent: float,
        rtt: float,
        breakdown: Optional[LatencyBreakdown] = None,
        bits: float = 1.0,
    ) -> None:
        """Register one completed offload sent at ``t_sent`` with round trip
        ``rtt``.  The sample becomes visible at ``t_sent + rtt`` — the
        moment the result (and its latency metadata) physically arrives."""
        rtt = float(rtt)
        if not np.isfinite(rtt) or rtt < 0.0:
            return
        q = float(breakdown.queue) if breakdown is not None else 0.0
        tx = float(breakdown.transmit) if breakdown is not None else 0.0
        sv = float(breakdown.service) if breakdown is not None else rtt
        heapq.heappush(
            self._pending,
            (float(t_sent) + rtt, self._seq, rtt, q, tx, sv, float(bits)),
        )
        self._seq += 1

    def _now(self) -> float:
        if self._clock is None:
            return np.inf  # unclocked: everything recorded is visible
        return float(self._clock())

    def poll(self, now: Optional[float] = None) -> int:
        """Fold every pending sample delivered by ``now`` into the
        estimators (in delivery order); returns how many arrived."""
        t = self._now() if now is None else float(now)
        n = 0
        a = self.alpha
        while self._pending and self._pending[0][0] <= t:
            _, _, rtt, q, tx, sv, bits = heapq.heappop(self._pending)
            if self.srtt is None:
                self.srtt = rtt
                self.rttvar = rtt / 2.0
                self.queue_ewma, self.transmit_ewma, self.service_ewma = q, tx, sv
            else:
                self.rttvar = (1.0 - _RTTVAR_BETA) * self.rttvar + _RTTVAR_BETA * abs(
                    self.srtt - rtt
                )
                self.srtt = (1.0 - _SRTT_ALPHA) * self.srtt + _SRTT_ALPHA * rtt
                self.queue_ewma = (1.0 - a) * self.queue_ewma + a * q
                self.transmit_ewma = (1.0 - a) * self.transmit_ewma + a * tx
                self.service_ewma = (1.0 - a) * self.service_ewma + a * sv
            if tx > 0.0:
                bw = bits / tx
                self.bw_ewma = bw if self.delivered == 0 or self.bw_ewma == 0.0 else (
                    (1.0 - a) * self.bw_ewma + a * bw
                )
                self.min_transmit = min(self.min_transmit, tx)
            self.delivered += 1
            n += 1
        return n

    # -------------------------------------------------------------- queries

    @property
    def outstanding(self) -> int:
        """Offloads sent whose results have not yet arrived (at the bound
        clock's current time)."""
        t = self._now()
        return sum(1 for p in self._pending if p[0] > t)

    def rtt(self) -> float:
        """Smoothed round-trip estimate (0 before any completion)."""
        self.poll()
        return float(self.srtt) if self.srtt is not None else 0.0

    def rto(self) -> float:
        """RFC 6298 retransmission-style timeout: ``srtt + 4·rttvar``."""
        self.poll()
        if self.srtt is None:
            return 0.0
        return float(self.srtt + 4.0 * self.rttvar)

    def bandwidth(self) -> float:
        """Smoothed goodput estimate in bits per time unit (0 before any
        link-fronted completion)."""
        self.poll()
        return float(self.bw_ewma)

    def congestion(self) -> float:
        """Measured stand-in for the oracle ``predicted_uplink_delay``
        probe, built entirely from what the device knows *at send time*:
        how many offloads are in flight and how long a transmission has
        been taking.  While any uplink is free a new frame starts
        immediately — congestion 0, exactly like the oracle's empty-queue
        reading.  Once every uplink is busy, the per-link backlog is
        ``outstanding / parallelism`` transmissions of ``transmit_ewma``
        each.  The gate matters: smoothing past queue waits into the
        estimate (the obvious choice) keeps it elevated after queues
        drain, deferring the budget controller's payback into the next
        burst — the sharp in-flight census tracks the oracle's shape."""
        self.poll()
        if self.outstanding < self.parallelism:
            return 0.0
        backlog = self.outstanding / self.parallelism
        return float(self.pressure * backlog * self.transmit_ewma)

    def state_probe(self) -> Tuple[int, int]:
        """Measured stand-in for the oracle ``(queue_depth, channel_state)``
        probe: queue depth from the congestion estimate in units of one
        transmission, channel bad when smoothed transmit times run well
        above the best observed (a fade roughly multiplies them)."""
        self.poll()
        if self.transmit_ewma <= 0.0:
            return 0, 0
        depth = int(round(self.congestion() / self.transmit_ewma))
        bad = int(
            np.isfinite(self.min_transmit)
            and self.transmit_ewma > 1.5 * self.min_transmit
        )
        return depth, bad

    def telemetry(self) -> Dict[str, float]:
        self.poll()
        return {
            "rtt": self.rtt(),
            "rttvar": float(self.rttvar),
            "bandwidth": self.bandwidth(),
            "queue_sojourn": float(self.queue_ewma),
            "congestion": self.congestion(),
            "outstanding": float(self.outstanding),
            "delivered": float(self.delivered),
        }

    # ------------------------------------------------------------ persistence

    def state(self) -> Dict[str, np.ndarray]:
        pending = np.asarray(
            sorted(self._pending), np.float64
        ).reshape(-1, 7)
        return {
            "pending": pending,
            "scalars": np.asarray(
                [
                    self.srtt if self.srtt is not None else np.nan,
                    self.rttvar,
                    self.queue_ewma,
                    self.transmit_ewma,
                    self.service_ewma,
                    self.bw_ewma,
                    self.min_transmit,
                ],
                np.float64,
            ),
            "counters": np.asarray([self._seq, self.delivered], np.int64),
        }

    @classmethod
    def from_state(
        cls,
        state: Dict[str, np.ndarray],
        *,
        alpha: float = 0.3,
        parallelism: int = 1,
        pressure: float = 1.0,
        clock: Optional[Callable[[], float]] = None,
    ) -> "NetworkEstimator":
        est = cls(alpha=alpha, parallelism=parallelism, pressure=pressure, clock=clock)
        pending = np.asarray(state["pending"], np.float64).reshape(-1, 7)
        est._pending = [
            (row[0], int(row[1]), row[2], row[3], row[4], row[5], row[6])
            for row in pending
        ]
        heapq.heapify(est._pending)
        s = np.asarray(state["scalars"], np.float64)
        est.srtt = None if np.isnan(s[0]) else float(s[0])
        est.rttvar = float(s[1])
        est.queue_ewma = float(s[2])
        est.transmit_ewma = float(s[3])
        est.service_ewma = float(s[4])
        est.bw_ewma = float(s[5])
        est.min_transmit = float(s[6])
        c = np.asarray(state["counters"], np.int64)
        est._seq = int(c[0])
        est.delivered = int(c[1])
        return est
