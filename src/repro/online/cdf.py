"""Streaming CDF / rank-transform updates (P²-style quantile tracking).

The engine's decision thresholds are quantiles of two fitted distributions:
the calibration *score* distribution (``set_ratio`` / ``quantile_threshold``)
and the calibration *reward* distribution (the MORIC ``CdfTransform``).  Both
are frozen at fit time, so when deployment distributions move, the realized
offload ratio drifts off target and the rank targets decalibrate.

:class:`StreamingQuantiles` tracks a whole quantile grid of a scalar stream
in O(markers) memory and O(markers) time per observation — the multi-marker
extension of the Jain & Chlamtac P² algorithm (piecewise-parabolic marker
updates, no sample storage).  It warm-starts from the engine's fitted
calibration sample and round-trips through the existing
``CdfTransform.state()/from_state`` surface:

    tracker = StreamingQuantiles.from_transform(engine.transform)
    tracker.update(realized_reward)            # per observed frame
    engine.transform = tracker.to_transform()  # periodic refresh

``calibration_scores()`` exposes the live marker heights as a sorted array —
a drop-in replacement for ``engine.calibration_scores`` wherever quantile
thresholds are derived (``make_policy``, ``quantile_threshold``,
``BudgetTracker``), which is how ``set_ratio`` stays calibrated as the score
distribution moves.

Everything is deterministic (no RNG) and the full tracker state serializes
as plain arrays (``state()``/``from_state``) so adaptive engines replay
bit-identically from a checkpoint.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.reward import CdfTransform


class StreamingQuantiles:
    """P²-style tracker of ``n_markers`` evenly spaced quantiles.

    Markers sit at probs ``linspace(0, 1, n_markers)`` (endpoints track the
    running min/max).  Until ``n_markers`` observations (or a warm start)
    arrive, samples are buffered exactly; the first sufficient batch
    initializes the markers and the tracker goes streaming.
    """

    def __init__(self, n_markers: int = 65):
        if n_markers < 5:
            raise ValueError(f"need >= 5 markers, got {n_markers}")
        self.n_markers = int(n_markers)
        self.probs = np.linspace(0.0, 1.0, self.n_markers)
        self.heights: Optional[np.ndarray] = None  # marker values, sorted
        self.positions: Optional[np.ndarray] = None  # 1-based marker ranks
        self.count = 0
        self._seed_buffer: list = []

    # ---------------------------------------------------------- construction

    def warm_start(self, samples: np.ndarray) -> "StreamingQuantiles":
        """Initialize the markers from a sample (the fitted calibration set).
        Requires at least ``n_markers`` values; fewer land in the seed buffer
        and streaming starts once enough have arrived."""
        s = np.sort(np.asarray(samples, np.float64).ravel())
        s = s[np.isfinite(s)]
        if s.size < self.n_markers:
            self._seed_buffer.extend(float(v) for v in s)
            self._maybe_seed()
            return self
        self.heights = np.quantile(s, self.probs)
        self.count = int(s.size)
        # desired 1-based ranks, forced strictly increasing from 1 to count
        pos = np.rint(1.0 + self.probs * (self.count - 1)).astype(np.int64)
        pos = np.maximum.accumulate(np.maximum(pos, np.arange(self.n_markers) + 1))
        pos = np.minimum(pos, self.count - self.n_markers + 1 + np.arange(self.n_markers))
        self.positions = pos.astype(np.float64)
        self._seed_buffer = []
        return self

    @classmethod
    def from_transform(
        cls, transform: CdfTransform, n_markers: int = 65
    ) -> "StreamingQuantiles":
        """Warm-start from a fitted ``CdfTransform`` via its public
        ``state()`` surface."""
        t = cls(n_markers)
        t.warm_start(np.asarray(transform.state()["sorted_rewards"]))
        return t

    def _maybe_seed(self) -> None:
        if self.heights is None and len(self._seed_buffer) >= self.n_markers:
            buf = self._seed_buffer
            self._seed_buffer = []
            self.warm_start(np.asarray(buf))

    # -------------------------------------------------------------- updates

    def update(self, x: float) -> None:
        """Fold one observation into the marker grid (P² marker moves)."""
        x = float(x)
        if not np.isfinite(x):
            return
        if self.heights is None:
            self._seed_buffer.append(x)
            self._maybe_seed()
            return
        h, n = self.heights, self.positions
        m = self.n_markers
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[m - 1]:
            h[m - 1] = x
            k = m - 2
        else:
            k = int(np.searchsorted(h, x, side="right")) - 1
            k = min(max(k, 0), m - 2)
        n[k + 1 :] += 1.0
        self.count += 1
        desired = 1.0 + self.probs * (self.count - 1)
        for i in range(1, m - 1):
            d = desired[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or (
                d <= -1.0 and n[i - 1] - n[i] < -1.0
            ):
                s = 1.0 if d >= 1.0 else -1.0
                # piecewise-parabolic (P²) candidate
                hp = h[i] + (s / (n[i + 1] - n[i - 1])) * (
                    (n[i] - n[i - 1] + s) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - s) * (h[i] - h[i - 1]) / (n[i] - n[i - 1])
                )
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:  # linear fallback keeps monotonicity
                    j = i + int(s)
                    h[i] = h[i] + s * (h[j] - h[i]) / (n[j] - n[i])
                n[i] += s

    def update_batch(self, xs: np.ndarray) -> None:
        for x in np.asarray(xs, np.float64).ravel():
            self.update(float(x))

    # -------------------------------------------------------------- queries

    @property
    def initialized(self) -> bool:
        return self.heights is not None

    def quantile(self, q: float) -> float:
        """Interpolated quantile estimate at ``q`` in [0, 1]."""
        if self.heights is None:
            if not self._seed_buffer:
                raise RuntimeError("quantile() on an empty tracker")
            return float(np.quantile(np.asarray(self._seed_buffer), q))
        return float(np.interp(float(q), self.probs, self.heights))

    def calibration_scores(self) -> np.ndarray:
        """The live marker heights as a sorted sample of the tracked
        distribution — a drop-in ``calibration_scores`` array for
        ``make_policy`` / ``quantile_threshold``."""
        if self.heights is None:
            if not self._seed_buffer:
                raise RuntimeError("calibration_scores() on an empty tracker")
            return np.sort(np.asarray(self._seed_buffer, np.float64))
        return self.heights.copy()

    def to_transform(self) -> CdfTransform:
        """The tracked distribution as a ``CdfTransform`` (via its public
        ``from_state``) — the streaming refresh of the engine's MORIC
        transform."""
        return CdfTransform.from_state({"sorted_rewards": self.calibration_scores()})

    # ------------------------------------------------------------ persistence

    def state(self) -> Dict[str, np.ndarray]:
        return {
            "probs": self.probs.copy(),
            "heights": (
                self.heights.copy() if self.heights is not None else np.zeros(0)
            ),
            "positions": (
                self.positions.copy() if self.positions is not None else np.zeros(0)
            ),
            "count": np.asarray(self.count, np.int64),
            "seed_buffer": np.asarray(self._seed_buffer, np.float64),
        }

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray]) -> "StreamingQuantiles":
        probs = np.asarray(state["probs"], np.float64)
        t = cls(n_markers=probs.size)
        t.probs = probs
        heights = np.asarray(state["heights"], np.float64)
        if heights.size:
            t.heights = heights.copy()
            t.positions = np.asarray(state["positions"], np.float64).copy()
        t.count = int(np.asarray(state["count"]))
        t._seed_buffer = [float(v) for v in np.asarray(state["seed_buffer"])]
        return t
