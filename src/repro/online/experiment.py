"""The distribution-shift headline experiment: adaptive vs frozen engines.

Scenario: B camera streams served through one engine + edge fleet, with a
**mid-stream distribution shift** in the weak detector — the set of object
classes it localizes badly flips at ``shift_at`` (pre-shift hard classes
become easy and vice versa), modeled by the class-conditional
``DetectorProfile.hard_classes`` noise in the seeded scene generator.  The
flip changes *which frames are worth offloading*: the reward estimator was
fit pre-shift, so the frozen engine keeps spending its budget on frames
that no longer benefit, while the adaptive engine relearns from the
realized strong−weak rewards its own offloads return.

Both arms run the same ``queue_aware`` policy (its integral budget
controller pins the realized offload ratio to the target, making the
comparison equal-budget by construction); the adaptive arm additionally
feeds every completed offload back through :class:`AdaptiveEngine`.
Effective accuracy is per-frame: the strong detector's AP where the frame
was actually offloaded, the weak detector's AP otherwise.

The headline claim — asserted by ``tests/test_online.py`` — is that the
adaptive arm's *post-shift* mean effective accuracy strictly exceeds the
frozen arm's at equal realized offload ratio.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.api.engine import OffloadEngine
from repro.online.engine import AdaptiveEngine, OnlineConfig, clone_engine
from repro.runtime.dispatch import OUTCOME_OFFLOADED
from repro.runtime.edge import EdgeLatencyModel, EdgeWorker
from repro.runtime.simulate import OffloadRuntime
from repro.video.runtime import frame_accuracies
from repro.video.scene import (
    STRONG_PROFILE,
    DetectionClip,
    DetectorProfile,
    SceneConfig,
    generate_clip,
    synthesize_detections,
)

#: the weak detector's two regimes: same global noise, opposite hard sets
PRE_SHIFT_PROFILE = DetectorProfile(
    box_jitter=0.8, flip=0.05, miss=0.08, hallucinate=0.05,
    score_lo=0.4, score_hi=0.9,
    hard_classes=(0, 1, 2, 3), hard_box_jitter=6.0,
)
POST_SHIFT_PROFILE = DetectorProfile(
    box_jitter=0.8, flip=0.05, miss=0.08, hallucinate=0.05,
    score_lo=0.4, score_hi=0.9,
    hard_classes=(4, 5, 6, 7), hard_box_jitter=6.0,
)


@dataclass
class ShiftScenario:
    """A fully seeded shift workload: engine fitted on the pre-shift
    distribution, spliced weak stream, and precomputed per-frame APs."""

    engine: OffloadEngine
    features: np.ndarray  # (T*B, F) time-major, row t*B + b
    weak_ap: np.ndarray  # (T, B) weak detector AP vs ground truth
    strong_ap: np.ndarray  # (T, B) strong detector AP vs ground truth
    shift_at: int
    seed: int = 0
    fleet_size: int = 3
    edge_latency: float = 2.0

    @property
    def n_frames(self) -> int:
        return self.weak_ap.shape[0]

    @property
    def n_streams(self) -> int:
        return self.weak_ap.shape[1]

    @property
    def rewards(self) -> np.ndarray:
        """(T, B) realized offload reward: strong − weak per-frame AP."""
        return self.strong_ap - self.weak_ap

    def fleet(self) -> List[EdgeWorker]:
        """A fresh modest fleet: latency-only (no links, no rate limits),
        so offloads admit and the experiment isolates the *decision*
        quality — but results still arrive ``edge_latency`` frames late,
        so supervision is delayed like in deployment."""
        return [
            EdgeWorker(
                f"edge{i}",
                capacity=max(self.n_streams, 4),
                latency=EdgeLatencyModel(base=self.edge_latency, jitter=0.1),
                seed=self.seed + i,
            )
            for i in range(self.fleet_size)
        ]


def default_shift_scenario(
    n_streams: int = 4,
    n_frames: int = 160,
    shift_at: int = 64,
    *,
    seed: int = 0,
    ratio: float = 0.35,
    calibration_frames: int = 48,
    estimator_epochs: int = 15,
    scene: Optional[SceneConfig] = None,
) -> ShiftScenario:
    """Build the seeded headline scenario.

    The engine is fitted the paper's way on a held-out pre-shift
    calibration clip (true strong−weak rewards, rank-transformed); the
    serve clip's weak stream is spliced: :data:`PRE_SHIFT_PROFILE` frames
    before ``shift_at``, :data:`POST_SHIFT_PROFILE` after.  Both profiles
    draw identical noise streams, so the shift is purely the hard-class
    flip."""
    from repro.api.features import DetectionBoxFeatures
    from repro.api.reward_model import MLPRewardModel
    from repro.core.estimator import EstimatorConfig
    from repro.data.shapes import NUM_CLASSES

    if not 0 < shift_at < n_frames:
        raise ValueError(f"need 0 < shift_at < n_frames, got {shift_at}/{n_frames}")
    cfg = scene or SceneConfig()

    # ---- calibration on the pre-shift distribution
    cal_clip = generate_clip(4, calibration_frames, seed=seed + 101, config=cfg)
    cal_weak = synthesize_detections(cal_clip, PRE_SHIFT_PROFILE, seed=seed + 102)
    cal_strong = synthesize_detections(cal_clip, STRONG_PROFILE, seed=seed + 103)
    order = [
        (t, b)
        for t in range(cal_clip.n_frames)
        for b in range(cal_clip.n_streams)
    ]
    gts = [cal_clip.gt(t, b) for t, b in order]
    cal_rewards = frame_accuracies(
        [cal_strong.det(t, b) for t, b in order], gts
    ) - frame_accuracies([cal_weak.det(t, b) for t, b in order], gts)
    engine = OffloadEngine(
        feature_extractor=DetectionBoxFeatures(
            num_classes=NUM_CLASSES, top_k=8, image_size=float(cfg.size)
        ),
        reward_model=MLPRewardModel(
            config=EstimatorConfig(
                hidden=(32,), epochs=estimator_epochs, batch_size=64, seed=seed
            )
        ),
        policy="queue_aware",
        ratio=ratio,
    )
    engine.fit(cal_weak.flatten(), cal_rewards)

    # ---- serve clip with the mid-stream splice
    clip = generate_clip(n_streams, n_frames, seed=seed, config=cfg)
    weak_pre = synthesize_detections(clip, PRE_SHIFT_PROFILE, seed=seed + 1)
    weak_post = synthesize_detections(clip, POST_SHIFT_PROFILE, seed=seed + 1)
    weak = DetectionClip.from_frames(
        [
            [
                (weak_pre if t < shift_at else weak_post).det(t, b)
                for b in range(n_streams)
            ]
            for t in range(n_frames)
        ]
    )
    strong = synthesize_detections(clip, STRONG_PROFILE, seed=seed + 2)
    serve_order = [(t, b) for t in range(n_frames) for b in range(n_streams)]
    serve_gts = [clip.gt(t, b) for t, b in serve_order]
    weak_ap = frame_accuracies(
        [weak.det(t, b) for t, b in serve_order], serve_gts
    ).reshape(n_frames, n_streams)
    strong_ap = frame_accuracies(
        [strong.det(t, b) for t, b in serve_order], serve_gts
    ).reshape(n_frames, n_streams)
    return ShiftScenario(
        engine=engine,
        features=np.asarray(engine.features(weak.flatten()), np.float32),
        weak_ap=weak_ap,
        strong_ap=strong_ap,
        shift_at=shift_at,
        seed=seed,
    )


@dataclass
class ShiftRunResult:
    """One arm's full trajectory over the shift scenario."""

    effective: np.ndarray  # (T, B) per-frame effective accuracy
    offload: np.ndarray  # (T, B) decision mask (budget spent)
    served_strong: np.ndarray  # (T, B) frames actually answered by an edge
    shift_at: int
    updates: Dict[str, int] = field(default_factory=dict)
    telemetry: List[Dict[str, Any]] = field(default_factory=list)
    adaptive: Optional[AdaptiveEngine] = None  # the adapted engine (adaptive arm)

    def realized_ratio(self) -> float:
        return float(np.mean(self.offload))

    def mean_effective(self, *, post_shift: Optional[bool] = None) -> float:
        if post_shift is None:
            return float(np.mean(self.effective))
        sl = slice(self.shift_at, None) if post_shift else slice(0, self.shift_at)
        return float(np.mean(self.effective[sl]))

    def summary(self) -> Dict[str, Any]:
        return {
            "realized_ratio": self.realized_ratio(),
            "mean_effective": self.mean_effective(),
            "pre_shift_effective": self.mean_effective(post_shift=False),
            "post_shift_effective": self.mean_effective(post_shift=True),
            "updates": dict(self.updates),
        }


def run_shift_scenario(
    scenario: ShiftScenario,
    *,
    adaptive: bool = False,
    config: Optional[OnlineConfig] = None,
    ratio: Optional[float] = None,
    seed: Optional[int] = None,
) -> ShiftRunResult:
    """Serve the scenario end to end with one arm.

    The scenario's engine is cloned per run (adaptive runs mutate model
    params in place), so arms are independent and the scenario reusable.
    Deterministic: the manual clock drives everything, completed offloads
    feed back in delivery order, and the update cadence is counted in
    observations."""
    T, B = scenario.n_frames, scenario.n_streams
    engine = clone_engine(scenario.engine)
    ada = AdaptiveEngine(engine, config) if adaptive else None
    runtime = OffloadRuntime(
        engine,
        scenario.fleet(),
        strategy="least_loaded",
        seed=scenario.seed if seed is None else seed,
    )
    sessions = [runtime.open_session(ratio=ratio, micro_batch=1) for _ in range(B)]
    base_ratio = float(sessions[0].ratio)
    cur_scale = 1.0
    x = scenario.features
    rewards = scenario.rewards
    effective = np.array(scenario.weak_ap, np.float64)  # default: served weak
    offload = np.zeros((T, B), bool)
    served_strong = np.zeros((T, B), bool)
    pending: List[tuple] = []  # (t_done, t, b, estimate, rtt)

    for t in range(T):
        now = runtime.clock()
        runtime.dispatcher.poll(now)
        # deliver completed offloads -> feed the closed loop
        still: List[tuple] = []
        for t_done, t0, b0, est0, rtt0 in pending:
            if t_done <= now:
                if ada is not None:
                    ada.observe(x[t0 * B + b0], est0, rewards[t0, b0])
                sessions[b0].record_rtt(rtt0)
            else:
                still.append((t_done, t0, b0, est0, rtt0))
        pending = still
        for b in range(B):
            d = sessions[b].submit(features=x[t * B + b])[0]
            if ada is not None:
                ada.observe_estimate(d.estimate)
            if not d.offload:
                continue
            offload[t, b] = True
            res = runtime.dispatcher.dispatch(now, t * B + b, d.estimate)
            if res.outcome == OUTCOME_OFFLOADED:
                served_strong[t, b] = True
                effective[t, b] = scenario.strong_ap[t, b]
                pending.append((now + res.latency, t, b, d.estimate, res.latency))
        if ada is not None:
            report = ada.maybe_update(now)
            if report.recalibrated:
                for s in sessions:
                    s.recalibrate()
                    s.record_update()
            if report.ratio_scale != cur_scale:
                cur_scale = report.ratio_scale
                widened = float(np.clip(base_ratio * cur_scale, 0.0, 1.0))
                for s in sessions:
                    s.set_ratio(widened)
        runtime.clock.advance(1.0)

    updates = (
        {
            "observations": ada.observations,
            "incremental_updates": ada.incremental_updates,
            "refits": ada.refits,
            "drift_events": ada.drift_events,
        }
        if ada is not None
        else {}
    )
    return ShiftRunResult(
        effective=effective,
        offload=offload,
        served_strong=served_strong,
        shift_at=scenario.shift_at,
        updates=updates,
        telemetry=[s.telemetry.as_dict(include_online=True) for s in sessions],
        adaptive=ada,
    )
