"""`AdaptiveEngine` — closed-loop adaptation over a fitted `OffloadEngine`.

The wrapper owns the four online components and the cadence that ties them
together on the runtime's manual clock:

- every completed offload is fed back via :meth:`observe` (features,
  predicted estimate, realized strong−weak reward) into the replay ring
  buffer, the streaming reward-CDF tracker, and the drift detector;
- every scored frame's estimate feeds :meth:`observe_estimate` into the
  streaming score-quantile tracker (so ``set_ratio`` thresholds stay
  calibrated as the score distribution moves);
- :meth:`maybe_update` runs the cadence: an incremental last-layer solve
  every ``update_every`` observations, a jitted mini-refit every
  ``refit_every`` (or immediately when the drift detector fires), followed
  by a transform/calibration refresh and a policy rebuild.

All model mutation is **in place** on the wrapped engine's estimator
params, so every session scoring through the shared engine sees updates at
its next micro-batch flush — no session rewiring.  Nothing here reads a
wall clock or an unseeded RNG: given the same observation sequence the
update trajectory is bit-identical, and :meth:`save`/:meth:`load` extend
the engine's own artifact with the full online state (ring buffer +
cursor, quantile markers, drift statistics, counters) so a restored run
replays exactly.  Runtime probe callables are never serialized — the
engine's artifact already strips policy ``context_params``, and the
adaptive layer holds none of its own.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

from repro.api.engine import OffloadEngine
from repro.api.policies import make_policy
from repro.api.reward_model import MLPRewardModel, reward_model_from_state
from repro.online.cdf import StreamingQuantiles
from repro.online.drift import DriftConfig, DriftDetector
from repro.online.updates import (
    LastLayerSolver,
    ReplayBuffer,
    apply_last_layer,
    hidden_features,
    mini_refit,
    reward_to_logit,
)
from repro.train.checkpoint import load_flat, save_flat

_ADAPTIVE_KIND = "adaptive_engine"


@dataclass(frozen=True)
class OnlineConfig:
    """Cadence and strength of the closed loop."""

    buffer_capacity: int = 512  # replay ring size (observations)
    min_observations: int = 32  # warmup before any model update
    update_every: int = 8  # observations between last-layer solves
    refit_every: int = 128  # observations between jitted mini-refits
    refit_epochs: int = 8
    refit_lr: float = 5e-4
    refit_batch_size: int = 128
    l2: float = 1e-2  # last-layer ridge strength
    forget: float = 0.98  # solver forgetting per ingested block
    n_markers: int = 65  # quantile-tracker resolution
    update_transform: bool = True  # refresh the reward CDF from the stream
    recalibrate: bool = True  # refresh calibration scores + policy
    seed: int = 0  # mini-refit shuffle seed
    drift: DriftConfig = field(default_factory=DriftConfig)

    def as_meta(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["drift"] = dataclasses.asdict(self.drift)
        return d

    @classmethod
    def from_meta(cls, meta: Dict[str, Any]) -> "OnlineConfig":
        kw = dict(meta)
        kw["drift"] = DriftConfig(**kw["drift"])
        return cls(**kw)


@dataclass(frozen=True)
class UpdateReport:
    """What one ``maybe_update`` call did."""

    incremental: bool = False  # last-layer solve applied
    refit: bool = False  # jitted mini-refit applied
    drift: bool = False  # the refit was drift-forced
    recalibrated: bool = False  # transform/calibration/policy refreshed
    ratio_scale: float = 1.0  # drift-widened offload-ratio multiplier

    @property
    def changed(self) -> bool:
        return self.incremental or self.refit


class AdaptiveEngine:
    """Closed-loop wrapper around a fitted :class:`OffloadEngine`.

    Scoring/deciding delegate to the wrapped engine (sessions keep working
    against ``adaptive.engine`` unchanged); the wrapper adds the feedback
    path.  The incremental path requires the deployable fused MLP shape
    (single hidden layer + sigmoid head); other reward models fall back to
    mini-refits only.
    """

    def __init__(
        self,
        engine: OffloadEngine,
        config: Optional[OnlineConfig] = None,
        *,
        obs: Optional[Any] = None,
    ):
        if engine.calibration_scores is None:
            raise RuntimeError("AdaptiveEngine wraps a *fitted* engine")
        self.engine = engine
        self.config = config if config is not None else OnlineConfig()
        feature_dim = self._feature_dim()
        self.buffer = ReplayBuffer(self.config.buffer_capacity, feature_dim)
        self.score_tracker = StreamingQuantiles(self.config.n_markers).warm_start(
            np.asarray(engine.calibration_scores)
        )
        self.reward_tracker: Optional[StreamingQuantiles] = (
            StreamingQuantiles.from_transform(engine.transform, self.config.n_markers)
            if engine.transform is not None
            else None
        )
        self.drift = DriftDetector(self.config.drift)
        self.solver: Optional[LastLayerSolver] = (
            LastLayerSolver(
                self._hidden_dim(), l2=self.config.l2, forget=self.config.forget
            )
            if self._incremental_capable()
            else None
        )
        self.base_ratio = float(engine.ratio)
        self.observations = 0
        self.incremental_updates = 0
        self.refits = 0
        self.drift_events = 0
        self._since_update = 0
        self._since_refit = 0
        self._unsolved_lo = 0  # buffer offset of rows not yet ingested
        # observability: update counters by kind, the live drift ratio
        # multiplier as a callback gauge, and one traced span per applied
        # update (stamped from whatever clock the obs handle is bound to)
        self._profiler = obs.profiler if obs is not None else None
        self._tracer = obs.tracer if obs is not None else None
        self._update_counters: Optional[Dict[str, Any]] = None
        reg = obs.metrics if obs is not None else None
        if reg is not None:
            self._update_counters = {
                kind: reg.counter(
                    "repro_adaptive_updates_total", {"kind": kind},
                    help="closed-loop model updates applied, by kind",
                )
                for kind in ("incremental", "refit", "drift")
            }
            reg.gauge(
                "repro_adaptive_ratio_scale",
                help="drift-gated offload ratio multiplier",
                fn=self.drift.ratio_multiplier,
            )
            reg.gauge(
                "repro_adaptive_observations",
                help="realized rewards observed so far",
                fn=lambda: self.observations,
            )

    # ------------------------------------------------------------- plumbing

    def _feature_dim(self) -> int:
        model = self.engine.reward_model
        in_dim = getattr(model, "in_dim", None)
        if in_dim is None:
            raise ValueError("adaptive updates need a feature-vector reward model")
        return int(in_dim)

    def _incremental_capable(self) -> bool:
        model = self.engine.reward_model
        return isinstance(model, MLPRewardModel) and len(model.config.hidden) == 1

    def _hidden_dim(self) -> int:
        return int(self.engine.reward_model.config.hidden[0])

    def _transform_rewards(self, rewards: np.ndarray) -> np.ndarray:
        """Raw realized rewards -> the rank space the model regresses."""
        r = np.asarray(rewards, np.float64)
        if self.engine.transform is not None:
            return np.asarray(self.engine.transform(r), np.float64)
        return r

    # ------------------------------------------------------------- feedback

    def observe(
        self,
        features: np.ndarray,
        estimates: np.ndarray,
        rewards: np.ndarray,
    ) -> None:
        """Feed back one block of completed offloads: the features the
        session scored, the estimates the policy acted on, and the realized
        strong−weak rewards (raw scale, as ``fit`` received them)."""
        x = np.asarray(features, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        est = np.atleast_1d(np.asarray(estimates, np.float64))
        raw = np.atleast_1d(np.asarray(rewards, np.float64))
        if not (x.shape[0] == est.shape[0] == raw.shape[0]):
            raise ValueError(
                f"block mismatch: {x.shape[0]} features, {est.shape[0]} estimates, "
                f"{raw.shape[0]} rewards"
            )
        self.buffer.append(x, raw)
        targets = self._transform_rewards(raw)
        for e, y, r in zip(est, targets, raw):
            self.drift.update(predicted=float(e), realized=float(y))
            if self.reward_tracker is not None:
                self.reward_tracker.update(float(r))
        self.observations += x.shape[0]
        self._since_update += x.shape[0]
        self._since_refit += x.shape[0]

    def observe_estimate(self, estimate: float) -> None:
        """Track one scored estimate (offloaded or not) so the live score
        distribution — and therefore ``set_ratio`` quantiles — follows the
        stream."""
        self.score_tracker.update(float(estimate))

    def observe_estimates(self, estimates: np.ndarray) -> None:
        self.score_tracker.update_batch(np.asarray(estimates, np.float64))

    # -------------------------------------------------------------- updates

    def _recent_block(self):
        """Buffer rows appended since the last solver ingestion."""
        x, y = self.buffer.data()
        n_total = self.buffer.count
        lo = max(self._unsolved_lo, n_total - len(x))
        take = n_total - lo
        if take <= 0:
            return None
        return x[-take:], y[-take:]

    def _incremental_update(self) -> bool:
        if self.solver is None:
            return False
        block = self._recent_block()
        if block is None:
            return False
        x, raw = block
        model = self.engine.reward_model
        h = hidden_features(model, x)
        y_logit = reward_to_logit(self._transform_rewards(raw))
        self.solver.ingest(h, y_logit)
        w, b = self.solver.solve()
        apply_last_layer(model, w, b)
        self._unsolved_lo = self.buffer.count
        self.incremental_updates += 1
        return True

    def _full_refit(self) -> bool:
        x, raw = self.buffer.data()
        if x.shape[0] == 0:
            return False
        y = np.asarray(self._transform_rewards(raw), np.float32)
        mini_refit(
            self.engine.reward_model,
            x,
            y,
            epochs=self.config.refit_epochs,
            lr=self.config.refit_lr,
            batch_size=self.config.refit_batch_size,
            seed=self.config.seed + self.refits,
        )
        if self.solver is not None:
            # the hidden layer moved: the accumulated design matrix no
            # longer describes it, so evidence restarts from this refit
            self.solver.reset()
        self._unsolved_lo = self.buffer.count
        self.refits += 1
        return True

    def _refresh_calibration(self) -> bool:
        """Push the live distributions back into the engine: reward CDF from
        the reward tracker, calibration scores from the streaming score
        tracker (it sees *every* scored frame — the replay buffer only holds
        the offloaded, high-estimate tail and would bias the quantiles), and
        a rebuilt policy."""
        eng = self.engine
        if self.config.update_transform and self.reward_tracker is not None:
            eng.transform = self.reward_tracker.to_transform()
        if not self.config.recalibrate:
            return False
        eng.calibration_scores = self.score_tracker.calibration_scores()
        live_ratio = float(getattr(eng.policy, "ratio", eng.ratio))
        eng.policy = make_policy(
            eng.policy_name, eng.calibration_scores, live_ratio, **eng.policy_kwargs
        )
        return True

    def maybe_update(self, now: Optional[float] = None) -> UpdateReport:
        """Run the update cadence; call once per arrival step (cheap when
        nothing is due).  ``now`` is accepted for symmetry with the manual
        clock but cadence is observation-counted, not time-counted."""
        del now
        if self.observations < self.config.min_observations:
            return UpdateReport(ratio_scale=self.drift.ratio_multiplier())
        drift_forced = self.drift.drifted
        refit = False
        incremental = False
        prof = self._profiler
        if drift_forced or self._since_refit >= self.config.refit_every:
            t0 = prof.begin() if prof is not None else 0.0
            refit = self._full_refit()
            if prof is not None:
                prof.add("online.refit", t0)
            if refit:
                self._since_refit = 0
                self._since_update = 0
                if drift_forced:
                    self.drift.reset()
                    self.drift_events += 1
                else:
                    self.drift.reset(count_event=False)
        elif self._since_update >= self.config.update_every:
            t0 = prof.begin() if prof is not None else 0.0
            incremental = self._incremental_update()
            if prof is not None:
                prof.add("online.incremental", t0)
            if incremental:
                self._since_update = 0
                # the model just moved under the detector's feet — re-anchor
                # so the loop's own updates don't register as drift
                self.drift.rebaseline()
        recalibrated = False
        if refit or incremental:
            recalibrated = self._refresh_calibration()
            if self._update_counters is not None:
                if refit:
                    self._update_counters["refit"].inc()
                    if drift_forced:
                        self._update_counters["drift"].inc()
                else:
                    self._update_counters["incremental"].inc()
            if self._tracer is not None:
                t = self._tracer.clock()
                self._tracer.instant(
                    "online.update", t=t,
                    args={
                        "kind": "refit" if refit else "incremental",
                        "drift": bool(drift_forced and refit),
                        "recalibrated": bool(recalibrated),
                    },
                )
        return UpdateReport(
            incremental=incremental,
            refit=refit,
            drift=drift_forced and refit,
            recalibrated=recalibrated,
            ratio_scale=self.drift.ratio_multiplier(),
        )

    # ------------------------------------------------------------ delegation

    def score(self, weak_outputs: Any = None, **kw) -> np.ndarray:
        return self.engine.score(weak_outputs, **kw)

    def decide(self, weak_outputs: Any = None, **kw):
        return self.engine.decide(weak_outputs, **kw)

    def features(self, weak_outputs: Any = None, **kw) -> np.ndarray:
        return self.engine.features(weak_outputs, **kw)

    def set_ratio(self, ratio: float) -> None:
        self.base_ratio = float(ratio)
        self.engine.set_ratio(ratio)

    # ------------------------------------------------------------ save/load

    def save(self, path: str) -> None:
        """One artifact: the wrapped engine's checkpoint plus the full
        online state.  Runtime probes are stripped exactly as the engine
        strips policy ``context_params``."""
        arrays, meta = self.engine.artifact_state()
        arrays["online"] = {
            "buffer": self.buffer.state(),
            "score_tracker": self.score_tracker.state(),
            "drift": self.drift.state(),
            "counters": np.asarray(
                [
                    self.observations,
                    self.incremental_updates,
                    self.refits,
                    self.drift_events,
                    self._since_update,
                    self._since_refit,
                    self._unsolved_lo,
                ],
                np.int64,
            ),
        }
        if self.reward_tracker is not None:
            arrays["online"]["reward_tracker"] = self.reward_tracker.state()
        if self.solver is not None:
            arrays["online"]["solver"] = self.solver.state()
        meta["kind"] = _ADAPTIVE_KIND
        meta["online"] = {
            "config": self.config.as_meta(),
            "base_ratio": self.base_ratio,
            "engine_kind": "offload_engine",
        }
        save_flat(path, arrays, meta)

    @classmethod
    def load(cls, path: str) -> "AdaptiveEngine":
        arrays, meta = load_flat(path)
        if meta is None or meta.get("kind") != _ADAPTIVE_KIND:
            raise ValueError(f"{path} is not an AdaptiveEngine checkpoint")
        engine_meta = dict(meta)
        engine_meta["kind"] = engine_meta["online"]["engine_kind"]
        engine = OffloadEngine.from_artifact_state(arrays, engine_meta)
        config = OnlineConfig.from_meta(meta["online"]["config"])
        adaptive = cls(engine, config)
        online = arrays["online"]
        adaptive.buffer = ReplayBuffer.from_state(online["buffer"])
        adaptive.score_tracker = StreamingQuantiles.from_state(online["score_tracker"])
        adaptive.drift = DriftDetector.from_state(online["drift"], config.drift)
        if "reward_tracker" in online:
            adaptive.reward_tracker = StreamingQuantiles.from_state(
                online["reward_tracker"]
            )
        if "solver" in online and adaptive.solver is not None:
            adaptive.solver = LastLayerSolver.from_state(
                online["solver"], l2=config.l2, forget=config.forget
            )
        c = np.asarray(online["counters"], np.int64)
        (
            adaptive.observations,
            adaptive.incremental_updates,
            adaptive.refits,
            adaptive.drift_events,
            adaptive._since_update,
            adaptive._since_refit,
            adaptive._unsolved_lo,
        ) = (int(v) for v in c)
        adaptive.base_ratio = float(meta["online"]["base_ratio"])
        return adaptive


def clone_engine(engine: OffloadEngine) -> OffloadEngine:
    """A deep, independent copy of a fitted engine via its own artifact
    round-trip (in memory, no disk) — adaptive runs mutate model params in
    place, so experiments clone before adapting to keep the frozen arm
    pristine."""
    import copy

    arrays, meta = engine.artifact_state()
    return OffloadEngine.from_artifact_state(
        copy.deepcopy(arrays), copy.deepcopy(meta)
    )
