"""Streaming reward-model refit from realized offload outcomes.

Every offloaded frame comes back with the strong detection, so the realized
reward (strong−weak per-frame AP, the exact quantity the estimator was fit
to predict) is observable for free on the offloaded subset.  This module
turns that signal into model updates along two paths:

- **Incremental last-layer least-squares** (:class:`LastLayerSolver`): the
  fused estimator is ``sigmoid(gelu(x W0 + b0) W1 + b1)``, so with the
  hidden layer frozen the head is a linear model in logit space.  Recursive
  ridge with a forgetting factor folds each observed block into sufficient
  statistics ``(A, b)`` in O(H²) and re-solves the head in O(H³) for H
  hidden units — microseconds per update, no gradient steps, no JIT traces.
- **Periodic jitted mini-refit** (:func:`mini_refit`): a few AdamW epochs of
  the paper's Eq. 7 weighted-MSE loss over the replay ring buffer, warm-
  started from the current params.  This also moves the hidden layer, which
  the incremental path cannot; the jitted step is cached per loss config so
  repeated refits don't retrace.

:class:`ReplayBuffer` is the ring buffer of realized ``(features, reward)``
blocks feeding both paths — feature rows are exactly what
``engine.features`` extracts from the padded ``DetectionsBatch`` plane, so
`AdaptiveEngine.observe` can pass through what the session already scored.
All state (ring contents, cursor) serializes as flat arrays for replayable
checkpoints.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.reward_model import MLPRewardModel
from repro.core.estimator import weighted_mse_loss
from repro.train.adamw import adamw_init, adamw_update

_LOGIT_EPS = 1e-4


class ReplayBuffer:
    """Fixed-capacity ring buffer of realized (features, reward) rows.

    Rows are overwritten oldest-first once full; ``data()`` returns the
    valid rows in chronological order.  ``cursor``/``count`` are part of the
    serialized state so a restored buffer keeps overwriting from the same
    slot (bit-identical replay).
    """

    def __init__(self, capacity: int, feature_dim: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.feature_dim = int(feature_dim)
        self.x = np.zeros((self.capacity, self.feature_dim), np.float32)
        self.y = np.zeros((self.capacity,), np.float32)
        self.cursor = 0
        self.count = 0

    def __len__(self) -> int:
        return min(self.count, self.capacity)

    def append(self, x: np.ndarray, y: np.ndarray) -> None:
        """Append a block of rows (x: (N, F) or (F,), y: (N,) or scalar)."""
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        y = np.atleast_1d(np.asarray(y, np.float32))
        if x.shape[0] != y.shape[0]:
            raise ValueError(f"block mismatch: {x.shape[0]} rows vs {y.shape[0]} rewards")
        if x.shape[1] != self.feature_dim:
            raise ValueError(f"feature dim {x.shape[1]} != buffer dim {self.feature_dim}")
        for i in range(x.shape[0]):
            self.x[self.cursor] = x[i]
            self.y[self.cursor] = y[i]
            self.cursor = (self.cursor + 1) % self.capacity
            self.count += 1

    def data(self) -> Tuple[np.ndarray, np.ndarray]:
        """Valid rows, oldest first."""
        n = len(self)
        if self.count <= self.capacity:
            return self.x[:n].copy(), self.y[:n].copy()
        order = (np.arange(self.capacity) + self.cursor) % self.capacity
        return self.x[order].copy(), self.y[order].copy()

    def state(self) -> Dict[str, np.ndarray]:
        return {
            "x": self.x.copy(),
            "y": self.y.copy(),
            "cursor": np.asarray(self.cursor, np.int64),
            "count": np.asarray(self.count, np.int64),
        }

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray]) -> "ReplayBuffer":
        x = np.asarray(state["x"], np.float32)
        buf = cls(capacity=x.shape[0], feature_dim=x.shape[1])
        buf.x = x.copy()
        buf.y = np.asarray(state["y"], np.float32).copy()
        buf.cursor = int(np.asarray(state["cursor"]))
        buf.count = int(np.asarray(state["count"]))
        return buf


# ---------------------------------------------------------------------------
# incremental last-layer least squares
# ---------------------------------------------------------------------------

def hidden_features(model: MLPRewardModel, x: np.ndarray) -> np.ndarray:
    """Hidden activations ``gelu(x_std W0 + b0)`` of the fused MLP — the
    design matrix of the last-layer linear model."""
    est = model.estimator
    if est is None:
        raise RuntimeError("hidden_features() before fit()")
    if len(est.params) != 2:
        raise ValueError("last-layer solve needs a single-hidden-layer MLP")
    x = np.asarray(x, np.float32)
    if model.config.standardize:
        x = (x - est._mu) / est._sigma
    p0 = est.params["layer0"]
    return np.asarray(jax.nn.gelu(jnp.asarray(x) @ p0["w"] + p0["b"]))


def reward_to_logit(y: np.ndarray) -> np.ndarray:
    """Map sigmoid-head targets in [0, 1] to the pre-sigmoid logit scale the
    linear head operates on."""
    y = np.clip(np.asarray(y, np.float64), _LOGIT_EPS, 1.0 - _LOGIT_EPS)
    return np.log(y / (1.0 - y))


class LastLayerSolver:
    """Recursive ridge regression for the sigmoid head, with forgetting.

    Maintains sufficient statistics ``A = Σ λ^age Φᵀ Φ`` and
    ``b = Σ λ^age Φᵀ y`` over augmented hidden features ``Φ = [h, 1]`` and
    logit-space targets; ``solve()`` returns the ridge head
    ``(A + l2·I)⁻¹ b`` split into weights and bias.  ``forget`` < 1 decays
    old evidence per ingested block, so post-shift observations dominate.
    """

    def __init__(self, hidden_dim: int, l2: float = 1e-2, forget: float = 1.0):
        if not 0.0 < forget <= 1.0:
            raise ValueError(f"forget must be in (0, 1], got {forget}")
        self.hidden_dim = int(hidden_dim)
        self.l2 = float(l2)
        self.forget = float(forget)
        d = self.hidden_dim + 1
        self.A = np.zeros((d, d), np.float64)
        self.b = np.zeros((d,), np.float64)
        self.n_ingested = 0

    def ingest(self, h: np.ndarray, y_logit: np.ndarray) -> None:
        """Fold one block of hidden features / logit targets into (A, b)."""
        h = np.asarray(h, np.float64)
        if h.ndim == 1:
            h = h[None, :]
        y = np.atleast_1d(np.asarray(y_logit, np.float64))
        phi = np.concatenate([h, np.ones((h.shape[0], 1))], axis=1)
        self.A = self.forget * self.A + phi.T @ phi
        self.b = self.forget * self.b + phi.T @ y
        self.n_ingested += h.shape[0]

    def solve(self) -> Tuple[np.ndarray, float]:
        """Ridge solution as (w: (H,), b: scalar) for the sigmoid head."""
        if self.n_ingested == 0:
            raise RuntimeError("solve() before any ingest()")
        d = self.hidden_dim + 1
        w = np.linalg.solve(self.A + self.l2 * np.eye(d), self.b)
        return w[:-1], float(w[-1])

    def reset(self) -> None:
        """Drop accumulated evidence (after a full refit moves the hidden
        layer, the old design matrix no longer applies)."""
        self.A[:] = 0.0
        self.b[:] = 0.0
        self.n_ingested = 0

    def state(self) -> Dict[str, np.ndarray]:
        return {
            "A": self.A.copy(),
            "b": self.b.copy(),
            "n_ingested": np.asarray(self.n_ingested, np.int64),
        }

    @classmethod
    def from_state(
        cls, state: Dict[str, np.ndarray], l2: float = 1e-2, forget: float = 1.0
    ) -> "LastLayerSolver":
        A = np.asarray(state["A"], np.float64)
        solver = cls(hidden_dim=A.shape[0] - 1, l2=l2, forget=forget)
        solver.A = A.copy()
        solver.b = np.asarray(state["b"], np.float64).copy()
        solver.n_ingested = int(np.asarray(state["n_ingested"]))
        return solver


def apply_last_layer(model: MLPRewardModel, w: np.ndarray, b: float) -> None:
    """Install a solved head into the live estimator params (in place, so
    every session scoring through the engine sees it immediately)."""
    est = model.estimator
    if est is None:
        raise RuntimeError("apply_last_layer() before fit()")
    est.params["layer1"] = {
        "w": jnp.asarray(np.asarray(w, np.float32)[:, None]),
        "b": jnp.asarray(np.asarray([b], np.float32)),
    }


# ---------------------------------------------------------------------------
# periodic jitted mini-refit
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _refit_step(weighted: bool, sigmoid_out: bool, weight_decay: float):
    """Jitted AdamW step for the mini-refit, cached per loss config so
    periodic refits reuse one trace."""
    loss_fn = functools.partial(
        weighted_mse_loss, weighted=weighted, sigmoid_out=sigmoid_out
    )

    @jax.jit
    def step(params, opt_state, xb, yb, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr, weight_decay=weight_decay
        )
        return params, opt_state, loss

    return step


def mini_refit(
    model: MLPRewardModel,
    x: np.ndarray,
    y: np.ndarray,
    *,
    epochs: int = 8,
    lr: float = 5e-4,
    batch_size: int = 128,
    seed: int = 0,
) -> List[float]:
    """Warm-started AdamW refit over a replay block (moves all layers).

    Keeps the fitted standardization moments (``_mu``/``_sigma``) — the
    feature extractor is unchanged, only the reward mapping moved — and
    shuffles with a dedicated seeded generator so replays are bit-identical.
    """
    est = model.estimator
    if est is None:
        raise RuntimeError("mini_refit() before fit()")
    cfg = model.config
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    if cfg.standardize:
        x = (x - est._mu) / est._sigma
    xj = jnp.asarray(x)
    yj = jnp.asarray(y)
    step = _refit_step(cfg.weighted, cfg.sigmoid_out, cfg.weight_decay)
    params, opt_state = est.params, adamw_init(est.params)
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    losses: List[float] = []
    for _ in range(int(epochs)):
        perm = rng.permutation(n)
        for s in range(0, n, batch_size):
            sel = perm[s : s + batch_size]
            params, opt_state, loss = step(params, opt_state, xj[sel], yj[sel], lr)
            losses.append(float(loss))
    est.params = params
    return losses
