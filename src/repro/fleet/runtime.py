"""City-scale serving: thousands of streams partitioned into shards, each
shard owning its own edge fleet on the shared manual clock.

``simulate()`` (repro.runtime) serves one device's stream against one
fleet; :class:`FleetRuntime` is its city-scale shape — ``n_streams``
concurrent streams split contiguously into ``n_shards`` logical shards.
Per tick, *all* streams are scored in one call through the sharded data
plane (:class:`~repro.fleet.plane.FleetPlane`), the estimates fan out to
one per-shard :class:`~repro.runtime.session.OffloadSession` via the
``submit_scored`` seam (``fleet_fair`` policy, coordinated through a
shared :class:`~repro.fleet.budget.FleetBudget`), and accepted offloads
dispatch to the shard's own ``MultiEdgeDispatcher``.  Everything runs on
one :class:`~repro.runtime.clock.ManualClock`, so runs are deterministic
record-for-record; per-shard telemetry reduces into one
:class:`FleetTelemetry`.

Logical shards are independent of the device mesh: a 1-device host still
runs 4-shard fleets (the plane just scores single-device), while under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` scoring genuinely
spreads over N host devices.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.engine import OffloadEngine
from repro.fleet.budget import FleetBudget
from repro.fleet.plane import FleetPlane
from repro.runtime.clock import ManualClock
from repro.runtime.dispatch import (
    OUTCOME_DEGRADED,
    OUTCOME_DROPPED,
    OUTCOME_LOCAL,
    OUTCOME_OFFLOADED,
    MultiEdgeDispatcher,
)
from repro.runtime.edge import EdgeWorker
from repro.runtime.session import OffloadSession, SessionTelemetry
from repro.runtime.simulate import default_linked_fleet

#: compact per-stream outcome codes for the array-valued step records
OUTCOME_CODES: Tuple[str, ...] = (
    OUTCOME_LOCAL, OUTCOME_OFFLOADED, OUTCOME_DEGRADED, OUTCOME_DROPPED
)
_CODE = {name: i for i, name in enumerate(OUTCOME_CODES)}


@dataclass(frozen=True)
class FleetStep:
    """One clock tick across the whole fleet, as arrays over streams."""

    t: float
    estimates: np.ndarray  # (S,) float64 reward estimates
    offload: np.ndarray  # (S,) bool policy decisions (budget spent)
    outcome: np.ndarray  # (S,) int8 index into OUTCOME_CODES
    latency: np.ndarray  # (S,) float64, nan where not offloaded

    def served_strong(self) -> np.ndarray:
        """Streams actually answered by an edge this tick."""
        return self.outcome == _CODE[OUTCOME_OFFLOADED]


@dataclass(frozen=True)
class FleetTelemetry:
    """Sharded telemetry reduced fleet-wide; ``per_shard`` keeps the full
    per-shard :class:`SessionTelemetry` payloads (fleet fields included)."""

    n_streams: int
    n_shards: int
    processed: int
    offloaded: int
    realized_ratio: float
    target_ratio: float
    mean_estimate: float
    reward_sum: float
    rewards_recorded: int
    budget_redistributions: int
    shard_shares: Tuple[float, ...]
    shard_ratios: Tuple[float, ...]  # per-shard realized offload ratios
    per_shard: Tuple[Dict[str, Any], ...] = field(default=())

    def as_dict(self, include_per_shard: bool = False) -> Dict[str, Any]:
        out = {
            "n_streams": self.n_streams,
            "n_shards": self.n_shards,
            "processed": self.processed,
            "offloaded": self.offloaded,
            "realized_ratio": self.realized_ratio,
            "target_ratio": self.target_ratio,
            "mean_estimate": self.mean_estimate,
            "reward_sum": self.reward_sum,
            "rewards_recorded": self.rewards_recorded,
            "budget_redistributions": self.budget_redistributions,
            "shard_shares": list(self.shard_shares),
            "shard_ratios": list(self.shard_ratios),
        }
        if include_per_shard:
            out["per_shard"] = list(self.per_shard)
        return out


def reduce_telemetry(
    telemetries: Sequence[SessionTelemetry],
    *,
    n_streams: int,
    target_ratio: float,
) -> FleetTelemetry:
    """Fold per-shard session telemetry into one fleet snapshot (counts sum,
    ratios re-derive from the summed counts, never averaged averages)."""
    processed = sum(t.processed for t in telemetries)
    offloaded = sum(t.offloaded for t in telemetries)
    est_sum = sum(t.mean_estimate * t.processed for t in telemetries)
    return FleetTelemetry(
        n_streams=n_streams,
        n_shards=len(telemetries),
        processed=processed,
        offloaded=offloaded,
        realized_ratio=offloaded / processed if processed else 0.0,
        target_ratio=float(target_ratio),
        mean_estimate=est_sum / processed if processed else 0.0,
        reward_sum=float(sum(t.reward_sum for t in telemetries)),
        rewards_recorded=sum(t.rewards_recorded for t in telemetries),
        budget_redistributions=max(
            (t.budget_redistributions for t in telemetries), default=0
        ),
        shard_shares=tuple(t.budget_share for t in telemetries),
        shard_ratios=tuple(
            t.offloaded / t.processed if t.processed else 0.0
            for t in telemetries
        ),
        per_shard=tuple(t.as_dict(include_fleet=True) for t in telemetries),
    )


@dataclass
class _Shard:
    """One logical shard: its stream slice, session, and private fleet."""

    index: int
    sl: slice
    session: OffloadSession
    dispatcher: MultiEdgeDispatcher


class FleetRuntime:
    """The city-scale served system — see the module docstring.

    Parameters
    ----------
    engine : OffloadEngine
        The fitted artifact; cloned per shard under the ``fleet_fair``
        policy (fitted components shared, policy state per shard).
    n_streams : int
        Total concurrent streams, partitioned contiguously into shards.
    n_shards : int
        Logical shard count (independent of the device mesh size).
    plane : FleetPlane or None
        The sharded scoring plane (``None`` builds one over all visible
        devices).
    ratio : float or None
        Fleet-wide target offload ratio (defaults to the engine's).
    redistribute_every : float or None
        Budget redistribution cadence in clock time units; ``None`` = the
        static equal split.
    bucket_depth : float or None
        Per-shard token-bucket burst depth; ``None`` scales with the
        shard's stream count (2 ticks of its equal-split budget, >= 8).
    fleet_factory : callable or None
        ``shard_index -> list[EdgeWorker]`` building each shard's private
        edge fleet; defaults to ``default_linked_fleet(edges_per_shard)``
        — the heterogeneous profiles behind real ``ConstantRateLink``
        uplinks — with shard-prefixed names and shard-offset seeds, so
        city runs genuinely pay (and report) transit per frame.
    staleness_probe : callable or None
        ``shard_index -> staleness (frames)`` sampled every tick into the
        budget's redistribution signal (``FleetBudget.record_staleness``)
        — the seam video-serving fleets feed their served-result age
        through.  ``None`` leaves the staleness signal silent.
    """

    def __init__(
        self,
        engine: OffloadEngine,
        n_streams: int,
        *,
        n_shards: int = 4,
        plane: Optional[FleetPlane] = None,
        ratio: Optional[float] = None,
        gain: float = 0.05,
        redistribute_every: Optional[float] = None,
        min_share: float = 0.25,
        smooth: float = 0.5,
        congestion_weight: float = 0.5,
        staleness_weight: float = 0.5,
        bucket_depth: Optional[float] = None,
        edges_per_shard: int = 3,
        fleet_factory: Optional[Callable[[int], List[EdgeWorker]]] = None,
        staleness_probe: Optional[Callable[[int], float]] = None,
        strategy: str = "least_loaded",
        on_saturation: str = "degrade",
        arrival_period: float = 1.0,
        seed: int = 0,
        obs: Optional[Any] = None,
    ):
        if n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {n_streams}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if n_shards > n_streams:
            raise ValueError(
                f"n_shards={n_shards} exceeds n_streams={n_streams}"
            )
        self.engine = engine
        self.n_streams = int(n_streams)
        self.n_shards = int(n_shards)
        self.plane = plane if plane is not None else FleetPlane()
        self.ratio = float(engine.ratio if ratio is None else ratio)
        self.arrival_period = float(arrival_period)
        self.clock = ManualClock()
        per = -(-self.n_streams // self.n_shards)
        streams_per_shard = per
        if bucket_depth is None:
            # two ticks of a shard's equal-split budget of burst headroom
            bucket_depth = max(8.0, 2.0 * self.ratio * streams_per_shard)
        # global token rate: the fleet-wide budget in offloads per time unit
        self.budget = FleetBudget(
            self.ratio * self.n_streams / self.arrival_period,
            self.n_shards,
            depth=float(bucket_depth),
            clock=self.clock,
            redistribute_every=redistribute_every,
            min_share=min_share,
            smooth=smooth,
            congestion_weight=congestion_weight,
            staleness_weight=staleness_weight,
        )
        if fleet_factory is None:
            def fleet_factory(s: int) -> List[EdgeWorker]:
                return default_linked_fleet(
                    edges_per_shard, seed=seed + 1000 * s, prefix=f"s{s}_edge",
                    queue_depth=max(64, streams_per_shard),
                )
        self.staleness_probe = staleness_probe
        # observability: the fleet stamps spans in simulated time — tick
        # spans on track 0, one session track per shard (1+s), edge tracks
        # blocked out per shard from 100 in steps of 100
        self.obs = obs
        self._profiler = obs.profiler if obs is not None else None
        self._tracer = obs.tracer if obs is not None else None
        if obs is not None:
            obs.bind_clock(self.clock)
            if obs.tracer is not None:
                obs.tracer.thread_name(0, "fleet")
        self.shards: List[_Shard] = []
        for s in range(self.n_shards):
            sl = slice(s * per, min((s + 1) * per, self.n_streams))
            shard_engine = engine.with_policy(
                "fleet_fair",
                ratio=self.ratio,
                policy_kwargs={"gain": gain, "budget": self.budget, "shard": s},
            )
            if self._tracer is not None:
                self._tracer.thread_name(1 + s, f"shard:{s}")
            session = OffloadSession(
                shard_engine, micro_batch=1, clock=self.clock,
                obs=obs, name=f"shard{s}", tid=1 + s,
            )
            session.record_budget_share(float(self.budget.shares[s]))
            dispatcher = MultiEdgeDispatcher(
                fleet_factory(s), strategy,
                on_saturation=on_saturation, seed=seed + s,
            )
            dispatcher.attach_obs(obs, tid_base=100 + 100 * s)
            self.shards.append(
                _Shard(index=s, sl=sl, session=session, dispatcher=dispatcher)
            )
        self._tick = 0

    # ----------------------------------------------------------------- serve

    def step(self, features: np.ndarray) -> FleetStep:
        """Serve one tick: ``features`` is the (n_streams, F) matrix of this
        arrival across every stream.  Scores once through the sharded plane,
        decides per shard, dispatches to each shard's own fleet, then
        advances the shared clock by one arrival period."""
        x = np.asarray(features, np.float32)
        if x.shape[0] != self.n_streams:
            raise ValueError(
                f"expected {self.n_streams} stream rows, got {x.shape[0]}"
            )
        now = self.clock()
        prof = self._profiler
        if prof is None:
            for sh in self.shards:
                sh.dispatcher.poll(now)
            estimates = np.asarray(
                self.plane.score(self.engine, x), np.float64
            ).ravel()
        else:
            t0 = prof.begin()
            for sh in self.shards:
                sh.dispatcher.poll(now)
            prof.add("fleet.poll", t0)
            t0 = prof.begin()
            estimates = np.asarray(
                self.plane.score(self.engine, x), np.float64
            ).ravel()
            prof.add("fleet.score", t0)
            t0 = prof.begin()
        offload = np.zeros(self.n_streams, bool)
        outcome = np.zeros(self.n_streams, np.int8)
        latency = np.full(self.n_streams, np.nan)
        for sh in self.shards:
            decisions = sh.session.submit_scored(estimates[sh.sl])
            for i, d in enumerate(decisions):
                stream = sh.sl.start + i
                if not d.offload:
                    continue
                offload[stream] = True
                res = sh.dispatcher.dispatch(
                    now, self._tick * self.n_streams + stream, d.estimate
                )
                outcome[stream] = _CODE[res.outcome]
                if res.outcome == OUTCOME_OFFLOADED:
                    latency[stream] = res.latency
                    sh.session.record_rtt(res.latency)
                    # realized spend feeds the redistribution signal with
                    # the engine's own reward score for the frame
                    self.budget.record_reward(sh.index, d.estimate)
                    sh.session.record_reward(d.estimate)
                    bd = res.breakdown
                    if bd is not None and (bd.queue or bd.transmit):
                        # realized uplink sojourn — the congestion side of
                        # the redistribution signal on link-fronted fleets
                        self.budget.record_congestion(
                            sh.index, bd.queue + bd.transmit
                        )
        if prof is not None:
            prof.add("fleet.decide_dispatch", t0)
            t0 = prof.begin()
        if self.staleness_probe is not None:
            for sh in self.shards:
                self.budget.record_staleness(
                    sh.index, float(self.staleness_probe(sh.index))
                )
        if self.budget.maybe_redistribute(now):
            for sh in self.shards:
                sh.session.record_redistribution()
                sh.session.record_budget_share(
                    float(self.budget.shares[sh.index])
                )
        if prof is not None:
            prof.add("fleet.redistribute", t0)
        if self._tracer is not None:
            self._tracer.add_span(
                "fleet.tick", now, now + self.arrival_period, tid=0,
                args={
                    "tick": self._tick,
                    "offloaded": int(offload.sum()),
                },
            )
        self.clock.advance(self.arrival_period)
        self._tick += 1
        return FleetStep(
            t=now, estimates=estimates, offload=offload,
            outcome=outcome, latency=latency,
        )

    # ------------------------------------------------------------- telemetry

    @property
    def telemetry(self) -> FleetTelemetry:
        return reduce_telemetry(
            [sh.session.telemetry for sh in self.shards],
            n_streams=self.n_streams,
            target_ratio=self.ratio,
        )

    def dispatcher_stats(self) -> Dict[str, Any]:
        return {
            f"shard{sh.index}": sh.dispatcher.stats() for sh in self.shards
        }


@dataclass
class FleetTrace:
    """A full fleet run: per-tick array records + reduced telemetry."""

    steps: List[FleetStep]
    telemetry: FleetTelemetry
    dispatcher: Dict[str, Any]
    budget: Dict[str, Any]

    def offload_mask(self) -> np.ndarray:
        """(T, S) — streams actually served by an edge, per tick."""
        return np.stack([s.served_strong() for s in self.steps])

    def decision_mask(self) -> np.ndarray:
        """(T, S) — policy said offload (budget spent), per tick."""
        return np.stack([s.offload for s in self.steps])

    def realized_ratio(self) -> float:
        return float(np.mean(self.decision_mask()))

    def outcome_counts(self) -> Dict[str, int]:
        counts = np.zeros(len(OUTCOME_CODES), np.int64)
        for s in self.steps:
            counts += np.bincount(s.outcome, minlength=len(OUTCOME_CODES))
        return {
            name: int(c) for name, c in zip(OUTCOME_CODES, counts) if c
        }

    def summary(self) -> Dict[str, Any]:
        lats = np.concatenate([s.latency for s in self.steps])
        lats = lats[~np.isnan(lats)]
        return {
            "ticks": len(self.steps),
            "outcomes": self.outcome_counts(),
            "telemetry": self.telemetry.as_dict(),
            "budget": self.budget,
            "mean_offload_latency": float(np.mean(lats)) if lats.size else None,
        }


def simulate_fleet(
    engine: OffloadEngine,
    features: np.ndarray,
    **kwargs: Any,
) -> FleetTrace:
    """One-call deterministic city-scale simulation: ``features`` is a
    (T, n_streams, F) tensor — tick-major arrivals across every stream.
    Remaining kwargs go to :class:`FleetRuntime`."""
    x = np.asarray(features, np.float32)
    if x.ndim != 3:
        raise ValueError(f"features must be (T, n_streams, F), got {x.shape}")
    runtime = FleetRuntime(engine, x.shape[1], **kwargs)
    steps = [runtime.step(x[t]) for t in range(x.shape[0])]
    return FleetTrace(
        steps=steps,
        telemetry=runtime.telemetry,
        dispatcher=runtime.dispatcher_stats(),
        budget=runtime.budget.stats(),
    )
