"""Fleet-wide offload-budget coordination.

The paper's budget is per-device; a city deployment shares one rate-limited
edge tier across many shards (Qiu et al. make the shared-rate constraint
explicit).  :class:`FleetBudget` holds one *global* token rate split into
per-shard :class:`~repro.core.policy.TokenBucket`\\ s on the shared manual
clock, and periodically **redistributes** the split toward shards whose
realized offloads carry higher engine reward scores — the global rate is
conserved exactly, only its division moves.  ``redistribute_every=None``
freezes the equal split, so the static arm of the city experiment runs the
*identical* token-bucket mechanics and the comparison is equal-budget by
construction.

``fleet_fair`` is the per-shard decision policy over a coordinated budget:
a quantile threshold on the shard's *allocated* ratio (its share of the
global budget, integral-tracked so the realized shard ratio converges to
the allocation), gated by the shard's token bucket.  It registers through
the lazy ``_ensure_plugins`` hook like the netsim/video/online policies;
``budget``/``shard``/``clock`` are runtime wiring (``context_params``),
never serialized with the engine artifact.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from repro.api.policies import (
    BudgetTracker,
    decide_sequential,
    register_policy,
)
from repro.core.policy import TokenBucket


class FleetBudget:
    """A global token-bucket offload budget split across ``n_shards``.

    Parameters
    ----------
    total_rate : float
        Fleet-wide token arrivals per time unit (one offload = one token).
        Conserved across redistributions: ``sum(shard rates) == total_rate``.
    n_shards : int
        Number of shards sharing the budget.
    depth : float
        Per-shard bucket depth (burst tolerance), in tokens.
    clock : callable or None
        Shared time source (the runtime's manual clock).  ``None`` falls
        back to per-arrival refill — fine for unit tests, never for the
        clocked runtime.
    redistribute_every : float or None
        Cadence (in clock time units) of share recomputation toward
        higher-realized-reward shards; ``None`` = static equal split (the
        baseline arm — same buckets, frozen shares).
    min_share : float
        Floor on a shard's share as a fraction of the equal split (0.25 =
        no shard drops below a quarter of ``total_rate / n_shards``) — a
        starved shard keeps enough budget to keep measuring its rewards.
    smooth : float
        EMA step toward the reward-proportional target shares per
        redistribution (1.0 = jump straight to the target).
    reward_halflife : int
        Per-shard realized-reward EMA halflife, in recorded offloads.
    congestion_weight : float
        How strongly a shard's relative uplink congestion (EMA of realized
        queue+transmit sojourns, ``record_congestion``) *discounts* its
        redistribution score: tokens moved to a drowning shard's uplink
        buy latency, not accuracy.  0 disables the signal.
    staleness_weight : float
        How strongly a shard's relative served-result staleness (EMA via
        ``record_staleness``, frames) *boosts* its score: a shard living
        off old edge results needs fresh offloads more than its reward EMA
        alone says.  0 disables the signal.
    """

    def __init__(
        self,
        total_rate: float,
        n_shards: int,
        *,
        depth: float = 8.0,
        clock: Optional[Callable[[], float]] = None,
        redistribute_every: Optional[float] = None,
        min_share: float = 0.25,
        smooth: float = 0.5,
        reward_halflife: int = 32,
        congestion_weight: float = 0.5,
        staleness_weight: float = 0.5,
    ):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if total_rate < 0.0:
            raise ValueError(f"total_rate must be >= 0, got {total_rate}")
        if not 0.0 <= min_share <= 1.0:
            raise ValueError(f"min_share must be in [0, 1], got {min_share}")
        self.total_rate = float(total_rate)
        self.n_shards = int(n_shards)
        self.depth = float(depth)
        self.clock = clock
        self.redistribute_every = (
            None if redistribute_every is None else float(redistribute_every)
        )
        self.min_share = float(min_share)
        self.smooth = float(np.clip(smooth, 0.0, 1.0))
        if congestion_weight < 0 or staleness_weight < 0:
            raise ValueError("congestion_weight/staleness_weight must be >= 0")
        self._alpha = 1.0 - 0.5 ** (1.0 / max(int(reward_halflife), 1))
        self.congestion_weight = float(congestion_weight)
        self.staleness_weight = float(staleness_weight)
        self.shares = np.full(self.n_shards, 1.0 / self.n_shards)
        self._reward_ema = np.zeros(self.n_shards)
        self._reward_seen = np.zeros(self.n_shards, bool)
        self._cong_ema = np.zeros(self.n_shards)
        self._cong_seen = np.zeros(self.n_shards, bool)
        self._stale_ema = np.zeros(self.n_shards)
        self._stale_seen = np.zeros(self.n_shards, bool)
        self._last_redistribution: Optional[float] = None
        self.redistributions = 0
        self.buckets: List[TokenBucket] = [
            TokenBucket(
                rate=self.total_rate * s, depth=self.depth,
                base_threshold=0.0, clock=clock,
            )
            for s in self.shares
        ]

    # ------------------------------------------------------------ admission

    def try_take(self, shard: int) -> bool:
        """Consume one token from ``shard``'s split of the global budget."""
        return self.buckets[shard].try_take()

    def allocated_ratio(self, shard: int, base_ratio: float) -> float:
        """``base_ratio`` scaled by the shard's share relative to the equal
        split — what a ``fleet_fair`` policy budgets its threshold for.
        Equal shares leave the ratio untouched."""
        return float(
            np.clip(base_ratio * self.shares[shard] * self.n_shards, 0.0, 1.0)
        )

    # --------------------------------------------------------- coordination

    def record_reward(self, shard: int, score: float) -> None:
        """Account one realized offload's engine reward score against the
        shard that spent the token — the redistribution signal."""
        if self._reward_seen[shard]:
            self._reward_ema[shard] += self._alpha * (
                float(score) - self._reward_ema[shard]
            )
        else:
            self._reward_ema[shard] = float(score)
            self._reward_seen[shard] = True

    def _ema(self, ema: np.ndarray, seen: np.ndarray, shard: int, v: float) -> None:
        if seen[shard]:
            ema[shard] += self._alpha * (float(v) - ema[shard])
        else:
            ema[shard] = float(v)
            seen[shard] = True

    def record_congestion(self, shard: int, sojourn: float) -> None:
        """Account one realized uplink sojourn (queue + transmit, time
        units) against the shard that paid it — wired by
        :class:`~repro.fleet.runtime.FleetRuntime` from each admitted
        offload's latency breakdown on link-fronted fleets."""
        self._ema(self._cong_ema, self._cong_seen, shard, sojourn)

    def record_staleness(self, shard: int, staleness: float) -> None:
        """Account one served-result staleness sample (frames) against a
        shard — wired from video-serving runtimes whose streams live off
        propagated edge results."""
        self._ema(self._stale_ema, self._stale_seen, shard, staleness)

    def _signal_multiplier(self) -> np.ndarray:
        """Congestion/staleness modifier on the reward scores: relative
        (per-shard EMA over the seen-shard mean), so the signals are
        scale-free — ``(1 + w_s * rel_stale) / (1 + w_c * rel_cong)``.
        Shards with no samples sit at the neutral 1.0."""

        def rel(ema: np.ndarray, seen: np.ndarray) -> np.ndarray:
            if not seen.any():
                return np.ones(self.n_shards)
            mean = float(ema[seen].mean())
            if mean <= 0.0:
                return np.ones(self.n_shards)
            return np.where(seen, ema / mean, 1.0)

        out = np.ones(self.n_shards)
        if self.staleness_weight > 0.0:
            out = out * (
                1.0 + self.staleness_weight * rel(self._stale_ema, self._stale_seen)
            )
        if self.congestion_weight > 0.0:
            out = out / (
                1.0 + self.congestion_weight * rel(self._cong_ema, self._cong_seen)
            )
        return out

    def maybe_redistribute(self, now: float) -> bool:
        """At the configured cadence, move shares toward the
        reward-proportional split (EMA-smoothed, floored at ``min_share`` of
        equal) and retarget the bucket rates.  Levels carry over — a
        redistribution never mints or burns already-accrued tokens — and the
        rates always sum to ``total_rate``."""
        if self.redistribute_every is None:
            return False
        if self._last_redistribution is None:
            self._last_redistribution = float(now)
            return False
        if now - self._last_redistribution < self.redistribute_every:
            return False
        self._last_redistribution = float(now)
        rewards = np.where(
            self._reward_seen, np.maximum(self._reward_ema, 0.0), 0.0
        )
        if rewards.sum() <= 0.0:
            return False
        rewards = rewards * self._signal_multiplier()
        if rewards.sum() <= 0.0:  # pragma: no cover - multiplier is positive
            return False
        # every shard keeps the floor; only the remainder is contested, so
        # the floor survives normalization exactly and the sum stays 1
        floor = self.min_share / self.n_shards
        target = floor + (1.0 - self.min_share) * rewards / rewards.sum()
        self.shares = self.shares + self.smooth * (target - self.shares)
        self.shares /= self.shares.sum()
        for bucket, share in zip(self.buckets, self.shares):
            bucket._refill()  # settle accrual at the old rate first
            bucket.rate = self.total_rate * share
        self.redistributions += 1
        return True

    def stats(self) -> Dict[str, object]:
        return {
            "total_rate": self.total_rate,
            "shares": [float(s) for s in self.shares],
            "levels": [float(b.level) for b in self.buckets],
            "reward_ema": [float(r) for r in self._reward_ema],
            "congestion_ema": [float(c) for c in self._cong_ema],
            "staleness_ema": [float(s) for s in self._stale_ema],
            "redistributions": self.redistributions,
        }


@register_policy("fleet_fair")
class FleetFairPolicy:
    """Shard-local decisions under a coordinated fleet budget.

    The threshold is the quantile at the shard's *allocated* ratio (its
    current share of the global budget) over the shard's **own recent
    scores** — a rolling window of the last ``window`` estimates this
    policy has seen.  That locality matters: a city shard's score
    distribution is skewed relative to the fleet-wide calibration set (an
    easy district's best frame ranks mid-pack globally), and a global
    quantile would leave easy shards hoarding tokens below an unreachable
    threshold while hard shards' integral controllers wind up and spend
    tokens on mediocre frames.  Until the window warms up, the fleet-wide
    calibration distribution stands in.  The shared
    :class:`BudgetTracker` integral controller corrects the residual
    mismatch so the realized shard ratio converges to the allocation; an
    offload additionally consumes a token from the shard's split.  With no
    ``budget`` wired the policy degrades to the integral-tracked local
    quantile threshold on its own ratio (single-device behavior).

    ``budget`` / ``shard`` / ``clock`` are runtime wiring — declared in
    ``context_params`` so ``OffloadEngine.save`` strips them from
    artifacts.  ``clock`` is accepted (sessions inject it) but unused: time
    lives in the budget's buckets.
    """

    context_params = ("budget", "shard", "clock")

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        gain: float = 0.05,
        window: int = 512,
        warmup: int = 64,
        budget: Optional[FleetBudget] = None,
        shard: int = 0,
        clock: Optional[Callable[[], float]] = None,
    ):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._cal = np.sort(np.asarray(calibration_scores, dtype=np.float64))
        self.gain = float(gain)
        self.window = int(window)
        self.warmup = max(1, min(int(warmup), self.window))
        self._recent = np.zeros(self.window)
        self._recent_n = 0  # filled entries
        self._recent_pos = 0  # ring-buffer write head
        self.budget = budget
        self.shard = int(shard)
        if budget is not None and not 0 <= self.shard < budget.n_shards:
            raise ValueError(
                f"shard {shard} outside budget's {budget.n_shards} shards"
            )
        self._tracker = BudgetTracker(self.gain)
        self.denied = 0  # wants refused by the token bucket
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))

    @property
    def allocated_ratio(self) -> float:
        """The ratio this shard currently budgets for: its share-scaled
        slice of the fleet target (just the target when uncoordinated)."""
        if self.budget is None:
            return self.ratio
        return self.budget.allocated_ratio(self.shard, self.ratio)

    def _score_distribution(self) -> np.ndarray:
        """The shard-local recent-score window once warmed up, else the
        fleet-wide calibration distribution."""
        if self._recent_n >= self.warmup:
            return self._recent[: self._recent_n]
        return self._cal

    def _observe(self, estimate: float) -> None:
        self._recent[self._recent_pos] = estimate
        self._recent_pos = (self._recent_pos + 1) % self.window
        self._recent_n = min(self._recent_n + 1, self.window)

    def decide(self, estimate: float) -> bool:
        est = float(estimate)
        want = est > self._tracker.threshold(
            self._score_distribution(), self.allocated_ratio
        )
        self._observe(est)
        offload = want and (
            self.budget is None or self.budget.try_take(self.shard)
        )
        # the controller tracks the WANT rate to the allocation; a token
        # refusal is the bucket's hard cap doing its job, not a shortfall
        # to chase — accounting refusals would wind the threshold down and
        # hand the next refill to whichever mediocre frames arrive first
        self._tracker.account(want)
        self.denied += int(want and not offload)
        return offload

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # sequential by construction: the bucket level and integral state
        # evolve decision to decision
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, object]:
        return {"gain": self.gain, "window": self.window, "warmup": self.warmup}
