"""The sharded serve-time data plane: the batched offload hot path
(``DetectionsBatch`` scoring, ``match_batch``, ``extract_features_batch``,
the fused estimator MLP) on a :func:`repro.launch.mesh.make_fleet_mesh`
device mesh via ``shard_map``, with **streams as the sharded axis** —
numerically identical to the single-device path.

Bit-exactness is a hard contract here (the fleet runtime compares shards'
decisions against single-device traces), and it is not free: XLA:CPU
compiles the ``iou_matrix`` Pallas grid loop differently for a
single-iteration batch grid than for a multi-iteration one (a 1-ulp
FMA/vectorization difference between ``grid_b == 1`` and ``grid_b >= 2``
programs; within a regime, runs agree bit-for-bit at the same tile shape).
So the sharded matcher mirrors :func:`repro.detection.batch.match_batch`'s
tile selection computed from the *global* batch, and pads each shard-local
block so its batch grid falls in the same regime as the global call's:

* global grid_b == 1 (small batches): every shard block pads to one
  ``tile_b`` tile — same grid, same tile shape, bit-identical.
* global grid_b >= 2: shard blocks pad to at least two ``tile_b`` tiles,
  landing in the multi-tile compilation regime — bit-identical again.

The regime machinery only matters on the Pallas paths; on the auto
``"reference"`` path (CPU default, see ``repro.kernels.dispatch``) the IoU
is an elementwise ``vmap`` over box pairs, which is trivially shard-safe
and needs no extra padding.

Downstream of the IoU kernel, greedy matching (``_match_inputs`` /
``_greedy_match``) and the feature/MLP kernels are comparisons, sorts and
per-image/per-row arithmetic, which the equivalence property in
``tests/test_sharding.py`` pins down across ragged shard boundaries.

Everything degrades to the exact single-device functions on a 1-device
mesh, so code written against the plane runs unchanged on laptop CI.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.features import _features_kernel, extract_features_batch
from repro.detection.batch import (
    DetectionsBatch,
    GroundTruthBatch,
    MatchResult,
    _greedy_match,
    _match_inputs,
    _pad_dim,
    match_batch,
)
from repro.kernels.estimator_mlp import estimator_mlp
from repro.kernels.iou_matrix.ops import iou_matrix_batch, resolve_path
from repro.launch.mesh import make_fleet_mesh
from repro.obs.jit_stats import count_call


def _ceil_to(n: int, multiple: int) -> int:
    return -(-max(n, 1) // multiple) * multiple


class FleetPlane:
    """The offload data plane on a 1-D ``"shard"`` device mesh.

    Parameters
    ----------
    mesh : jax.sharding.Mesh or None
        An existing 1-axis mesh (typically from ``make_fleet_mesh``);
        ``None`` builds one over ``n_shards`` visible devices.
    n_shards : int or None
        Device count for the constructed mesh (``None`` = all visible);
        ignored when ``mesh`` is given.
    """

    def __init__(
        self, mesh: Optional[Mesh] = None, *, n_shards: Optional[int] = None
    ):
        self.mesh = mesh if mesh is not None else make_fleet_mesh(n_shards)
        axes = tuple(self.mesh.axis_names)
        if len(axes) != 1:
            raise ValueError(f"fleet mesh must have exactly one axis, got {axes}")
        self.axis = axes[0]

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    def shard_sizes(self, n: int) -> Tuple[int, int]:
        """(rows per shard, padded total) for ``n`` items over the mesh —
        the last shard is ragged; padding fills it."""
        per = -(-n // self.n_devices)
        return per, per * self.n_devices

    def _shard1d(self, fn, n_in: int, n_out: int):
        """``shard_map`` ``fn`` with every input/output sharded on axis 0."""
        return shard_map(
            fn,
            mesh=self.mesh,
            in_specs=(P(self.axis),) * n_in,
            out_specs=(P(self.axis),) * n_out if n_out > 1 else P(self.axis),
            check_rep=False,
        )

    # ------------------------------------------------------------- scoring

    def score(self, engine, features: np.ndarray) -> np.ndarray:
        """Batched reward estimates with rows sharded over the mesh —
        bit-identical to ``engine.score``.  Non-fused reward models (and
        1-device meshes) fall through to the engine's own path."""
        x = np.asarray(features, np.float32)
        model = engine.reward_model
        if self.n_devices == 1 or not getattr(model, "fused", False):
            return np.asarray(engine.score(features=x))
        # the shard_map closure below is rebuilt per call (params close
        # over fresh arrays), so retraces can't be read off a stable jit
        # object — count dispatches instead
        count_call("fleet_plane.score")
        est = model.estimator
        if model.config.standardize:
            x = (x - est._mu) / est._sigma
        p = est.params
        w1, b1 = p["layer0"]["w"], p["layer0"]["b"]
        w2, b2 = p["layer1"]["w"][:, 0], p["layer1"]["b"][0]
        interpret = model.interpret
        B = x.shape[0]
        _, total = self.shard_sizes(max(B, 1))
        xp = np.zeros((total, x.shape[1]), np.float32)
        xp[:B] = x

        def local(xs):
            return estimator_mlp(xs, w1, b1, w2, b2, interpret=interpret)

        out = self._shard1d(local, 1, 1)(jnp.asarray(xp))
        return np.asarray(out)[:B]

    def score_detections(self, engine, batch: DetectionsBatch) -> np.ndarray:
        """Device-resident boxes→estimates scoring with images sharded over
        the mesh — bit-identical to ``engine.score_device(batch)`` (and so
        to the composed ``engine.score`` route).

        The per-image feature kernel (the parallel bulk of the pipeline)
        runs sharded; the standardize + MLP head then runs as ONE
        replicated dispatch on the cropped device-resident features — the
        same trace the single-device path executes.  Fusing the head into
        the shard_map body would put XLA:CPU's gemm at shard-local row
        counts, which compiles to a different reduction schedule than the
        global call at some shapes (1-ulp drift) — the split keeps the
        plane's bit-exactness contract without a host exit between stages.
        Engines without the fused MLP + box feature extractor, and
        1-device meshes, fall through to the engine's own device path."""
        fx = engine.feature_extractor
        model = engine.reward_model
        fused = (
            getattr(model, "fused", False)
            and hasattr(model, "predict_device")
            and all(hasattr(fx, a) for a in ("num_classes", "top_k", "image_size"))
        )
        if self.n_devices == 1 or not fused:
            return np.asarray(engine.score_device(batch))
        count_call("fleet_plane.score_detections")
        B = len(batch)
        _, total = self.shard_sizes(max(B, 1))
        padded = batch.pad_images(total)
        boxes, scores = padded.boxes, padded.scores
        classes, mask = padded.classes, padded.mask
        top_k = int(fx.top_k)
        if padded.max_boxes < top_k:  # the kernel slices a fixed top_k window
            pad = top_k - padded.max_boxes
            boxes = np.pad(boxes, ((0, 0), (0, pad), (0, 0)))
            scores = np.pad(scores, ((0, 0), (0, pad)))
            classes = np.pad(classes, ((0, 0), (0, pad)), constant_values=-1)
            mask = np.pad(mask, ((0, 0), (0, pad)))
        image_size = jnp.float32(fx.image_size)
        num_classes = int(fx.num_classes)

        def local(b, s, c, m):
            return _features_kernel(b, s, c, m, image_size, num_classes, top_k)

        f = self._shard1d(local, 4, 1)(
            jnp.asarray(boxes), jnp.asarray(scores),
            jnp.asarray(classes), jnp.asarray(mask),
        )
        # gather the cropped features onto one device before the head: a
        # jit over still-sharded inputs auto-partitions the gemm back to
        # shard-local row counts (the drift the split exists to avoid)
        f = jax.device_put(f[:B], self.mesh.devices.flat[0])
        return np.asarray(model.predict_device(f))

    # ------------------------------------------------------------ matching

    def match(
        self,
        det: DetectionsBatch,
        gt: GroundTruthBatch,
        iou_thresholds: Sequence[float] = (0.5,),
        *,
        interpret: Union[None, bool, str] = None,
        tile_b: int = 8,
        tile_n: int = 128,
        tile_m: int = 128,
    ) -> MatchResult:
        """Batched COCO greedy matching with images sharded over the mesh
        — bit-identical to single-device :func:`match_batch` (see the
        module docstring for the grid-regime padding that guarantees it)."""
        if len(det) != len(gt):
            raise ValueError(f"batch size mismatch: {len(det)} dets vs {len(gt)} gts")
        if self.n_devices == 1:
            return match_batch(
                det, gt, iou_thresholds, interpret=interpret,
                tile_b=tile_b, tile_n=tile_n, tile_m=tile_m,
            )
        B = len(det)
        interp = resolve_path(interpret)
        if interp == "interpret":
            # mirror match_batch's interpreter-mode tile shrink, computed
            # from the GLOBAL batch — shard-local tiles must not differ
            tile_n = min(tile_n, _pad_dim(det.max_boxes))
            tile_m = min(tile_m, _pad_dim(gt.max_boxes))
            tile_b = min(64, _pad_dim(B))
        per, total = self.shard_sizes(B)
        det_p, gt_p = det.pad_images(total), gt.pad_images(total)
        if interp == "reference":
            # elementwise vmap IoU: no grid regimes, no extra padding
            local_rows = per
        else:
            grid_ref = _ceil_to(B, tile_b) // tile_b
            # shard blocks must compile in the single-device call's batch-grid
            # regime: one tile when the global grid has one, >= 2 tiles otherwise
            local_rows = _ceil_to(per, tile_b) if grid_ref == 1 else max(
                _ceil_to(per, tile_b), 2 * tile_b
            )
        thresholds = jnp.asarray(iou_thresholds, jnp.float32)

        def local(d_boxes, d_scores, d_classes, d_mask, g_boxes, g_classes, g_mask):
            pad = local_rows - d_boxes.shape[0]
            if pad:
                widths = ((0, pad),)
                d_boxes = jnp.pad(d_boxes, widths + ((0, 0), (0, 0)))
                g_boxes = jnp.pad(g_boxes, widths + ((0, 0), (0, 0)))
                d_scores = jnp.pad(d_scores, widths + ((0, 0),))
                d_classes = jnp.pad(
                    d_classes, widths + ((0, 0),), constant_values=-1
                )
                g_classes = jnp.pad(
                    g_classes, widths + ((0, 0),), constant_values=-1
                )
                d_mask = jnp.pad(d_mask, widths + ((0, 0),))
                g_mask = jnp.pad(g_mask, widths + ((0, 0),))
            iou = iou_matrix_batch(
                d_boxes, g_boxes,
                tile_b=tile_b, tile_n=tile_n, tile_m=tile_m, interpret=interp,
            )
            masked, order = _match_inputs(
                d_scores, d_classes, d_mask, g_classes, g_mask, iou
            )
            tp, mj = _greedy_match(masked, order, thresholds)
            return tp[:per], mj[:per]

        tp, mj = self._shard1d(local, 7, 2)(
            jnp.asarray(det_p.boxes), jnp.asarray(det_p.scores),
            jnp.asarray(det_p.classes), jnp.asarray(det_p.mask),
            jnp.asarray(gt_p.boxes), jnp.asarray(gt_p.classes),
            jnp.asarray(gt_p.mask),
        )
        return MatchResult(
            tp=np.asarray(tp)[:B],
            match_gt=np.asarray(mj, np.int32)[:B],
            iou_thresholds=tuple(float(t) for t in iou_thresholds),
        )

    # ------------------------------------------------------------ features

    def extract_features(
        self,
        batch: DetectionsBatch,
        num_classes: int,
        top_k: int = 25,
        image_size: float = 1.0,
    ) -> np.ndarray:
        """The weak-output feature kernel with images sharded over the mesh
        — bit-identical to :func:`extract_features_batch`."""
        if self.n_devices == 1:
            return extract_features_batch(batch, num_classes, top_k, image_size)
        B = len(batch)
        _, total = self.shard_sizes(max(B, 1))
        padded = batch.pad_images(total)
        boxes, scores = padded.boxes, padded.scores
        classes, mask = padded.classes, padded.mask
        if padded.max_boxes < top_k:  # the kernel slices a fixed top_k window
            pad = top_k - padded.max_boxes
            boxes = np.pad(boxes, ((0, 0), (0, pad), (0, 0)))
            scores = np.pad(scores, ((0, 0), (0, pad)))
            classes = np.pad(classes, ((0, 0), (0, pad)), constant_values=-1)
            mask = np.pad(mask, ((0, 0), (0, pad)))

        def local(b, s, c, m):
            return _features_kernel(
                b, s, c, m, jnp.float32(image_size), int(num_classes), int(top_k)
            )

        out = self._shard1d(local, 4, 1)(
            jnp.asarray(boxes), jnp.asarray(scores),
            jnp.asarray(classes), jnp.asarray(mask),
        )
        return np.asarray(out)[:B]
