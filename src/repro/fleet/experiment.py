"""The city-scale headline experiment: coordinated budget redistribution
vs static equal-split at equal total offload budget.

Scenario: ``n_streams`` camera streams partitioned into ``n_shards`` city
districts, each district's weak detector operating at a different
**hardness** — how much accuracy an offload to the strong model recovers.
An easy district's frames gain almost nothing from offloading; a hard
district's frames gain a lot.  Every frame's weak output leaks its own
difficulty into the feature vector (the paper's deployability constraint:
the estimator sees only the weak result), so the engine's reward scores
carry the district-level signal.

Both arms run the identical :class:`~repro.fleet.budget.FleetBudget`
token-bucket mechanics at the same global rate — the *only* difference is
``redistribute_every``: the coordinated arm periodically moves bucket
shares toward districts whose realized offloads score higher, the static
arm keeps the equal split.  The per-shard ``fleet_fair`` integral
controllers pin each arm's realized ratio to the same fleet target, so the
comparison is equal-budget by construction.  Effective accuracy is
per-frame: the strong detector's AP where the frame was actually served by
an edge, the weak detector's AP otherwise.

The headline claim — asserted by ``tests/test_fleet.py`` — is that the
coordinated arm's mean effective accuracy strictly exceeds the static
arm's at (approximately) equal total realized offload ratio.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.api.engine import OffloadEngine
from repro.fleet.plane import FleetPlane
from repro.fleet.runtime import FleetTrace, simulate_fleet
from repro.runtime.edge import EdgeLatencyModel, EdgeWorker

#: per-district hardness: max AP a strong-model offload recovers on the
#: district's frames (scaled by the frame's latent difficulty)
DEFAULT_HARDNESS: Tuple[float, ...] = (0.05, 0.25, 0.6, 1.0)

#: AP both detectors agree on for a trivially easy frame
BASE_AP = 0.9
#: reward scale: full-hardness, full-difficulty frames gain this much AP
REWARD_SCALE = 0.4


def _district_frames(
    rng: np.random.Generator, n: int, hardness: float, noise: float, n_features: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(features (n, F), rewards (n,)) for one district's frames.  The
    latent difficulty ``u`` and the district hardness leak into the first
    feature columns through weak-output-style noisy proxies; the rest is
    distractor noise."""
    u = rng.uniform(0.0, 1.0, n)
    r = REWARD_SCALE * hardness * u
    x = rng.normal(0.0, 1.0, (n, n_features)).astype(np.float32)
    eps = rng.normal(0.0, noise, (3, n))
    x[:, 0] = 1.0 - r + eps[0]  # weak mean-confidence proxy
    x[:, 1] = u + eps[1]  # clutter / box-count proxy
    x[:, 2] = hardness + eps[2]  # district appearance statistics
    return x, r


@dataclass
class CityScenario:
    """A fully seeded city workload: fitted engine, tick-major features,
    and precomputed per-frame weak/strong APs.  Stream ``s`` belongs to
    district ``s * n_shards // n_streams`` (contiguous blocks, matching
    :class:`~repro.fleet.runtime.FleetRuntime`'s partition)."""

    engine: OffloadEngine
    features: np.ndarray  # (T, S, F)
    weak_ap: np.ndarray  # (T, S)
    strong_ap: np.ndarray  # (T, S)
    hardness: Tuple[float, ...]
    seed: int = 0

    @property
    def n_ticks(self) -> int:
        return self.weak_ap.shape[0]

    @property
    def n_streams(self) -> int:
        return self.weak_ap.shape[1]

    @property
    def n_shards(self) -> int:
        return len(self.hardness)

    @property
    def rewards(self) -> np.ndarray:
        """(T, S) realized offload reward: strong − weak per-frame AP."""
        return self.strong_ap - self.weak_ap

    def fleet_factory(self, shard: int) -> List[EdgeWorker]:
        """A generous per-district fleet behind *real* netsim uplinks: a
        seeded Gilbert–Elliott fading channel per edge, provisioned so the
        whole district's budgeted offload rate transmits in a fraction of a
        tick even through a fade — admission still almost never refuses,
        so the experiment keeps isolating *decision* quality (the budget is
        the binding constraint) while every offload now pays and reports
        genuine per-frame transit."""
        from repro.netsim import GilbertElliottLink

        per = -(-self.n_streams // self.n_shards)
        bandwidth = 8.0 * max(per, 4)  # frames per tick at full signal
        return [
            EdgeWorker(
                f"s{shard}e{i}",
                capacity=max(per, 4),
                latency=EdgeLatencyModel(base=1.0, jitter=0.05),
                link=GilbertElliottLink(
                    bandwidth=bandwidth,
                    bad_bandwidth=bandwidth / 4.0,
                    p_gb=0.05,
                    p_bg=0.4,
                    slot=1.0,
                    seed=self.seed * 131 + 7 * shard + i,
                ),
                queue_depth=2 * max(per, 4),
                frame_bits=1.0,
                seed=self.seed + 7 * shard + i,
            )
            for i in range(2)
        ]


def default_city_scenario(
    n_streams: int = 1024,
    n_ticks: int = 48,
    *,
    hardness: Tuple[float, ...] = DEFAULT_HARDNESS,
    seed: int = 0,
    noise: float = 0.05,
    n_features: int = 12,
    calibration_frames: int = 4096,
    estimator_epochs: int = 40,
) -> CityScenario:
    """Build the seeded headline scenario.  The engine is fitted the
    paper's way on a held-out mixed-district calibration set (true rewards,
    rank-transformed), then serves the city frozen."""
    from repro.api.reward_model import MLPRewardModel
    from repro.core.estimator import EstimatorConfig

    n_shards = len(hardness)
    if n_streams % n_shards:
        raise ValueError(
            f"n_streams={n_streams} must divide into {n_shards} districts"
        )
    per = n_streams // n_shards

    # ---- calibration on the same district mixture (held-out seed)
    cal_rng = np.random.default_rng(seed + 101)
    cal_x, cal_r = zip(*(
        _district_frames(
            cal_rng, calibration_frames // n_shards, h, noise, n_features
        )
        for h in hardness
    ))
    engine = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(
                hidden=(32,), epochs=estimator_epochs, batch_size=128, seed=seed
            )
        ),
        policy="threshold",
        ratio=0.25,
    )
    engine.fit(features=np.concatenate(cal_x), rewards=np.concatenate(cal_r))

    # ---- the served city, tick-major
    rng = np.random.default_rng(seed)
    features = np.zeros((n_ticks, n_streams, n_features), np.float32)
    rewards = np.zeros((n_ticks, n_streams))
    for k, h in enumerate(hardness):
        sl = slice(k * per, (k + 1) * per)
        x, r = _district_frames(rng, n_ticks * per, h, noise, n_features)
        features[:, sl] = x.reshape(n_ticks, per, n_features)
        rewards[:, sl] = r.reshape(n_ticks, per)
    weak_ap = BASE_AP - rewards
    strong_ap = np.full_like(weak_ap, BASE_AP)
    return CityScenario(
        engine=engine,
        features=features,
        weak_ap=weak_ap,
        strong_ap=strong_ap,
        hardness=tuple(float(h) for h in hardness),
        seed=seed,
    )


@dataclass
class CityRunResult:
    """One arm's full trajectory over the city scenario."""

    effective: np.ndarray  # (T, S) per-frame effective accuracy
    decision: np.ndarray  # (T, S) policy decisions (budget spent)
    served: np.ndarray  # (T, S) frames actually answered by an edge
    trace: FleetTrace

    def realized_ratio(self) -> float:
        return float(np.mean(self.decision))

    def mean_effective(self) -> float:
        return float(np.mean(self.effective))

    def shard_ratios(self) -> Tuple[float, ...]:
        return self.trace.telemetry.shard_ratios

    def summary(self) -> Dict[str, Any]:
        return {
            "realized_ratio": self.realized_ratio(),
            "served_ratio": float(np.mean(self.served)),
            "mean_effective": self.mean_effective(),
            "shard_ratios": list(self.shard_ratios()),
            "shard_shares": list(self.trace.telemetry.shard_shares),
            "redistributions": self.trace.telemetry.budget_redistributions,
        }


def run_city_scenario(
    scenario: CityScenario,
    *,
    coordinated: bool,
    ratio: float = 0.25,
    redistribute_every: float = 8.0,
    min_share: float = 0.25,
    smooth: float = 0.5,
    plane: Optional[FleetPlane] = None,
    seed: Optional[int] = None,
) -> CityRunResult:
    """Serve the city end to end with one arm.  Arms differ only in
    whether the shared budget redistributes; everything else — scenario,
    engine, fleets, clock, seeds — is identical."""
    trace = simulate_fleet(
        scenario.engine,
        scenario.features,
        n_shards=scenario.n_shards,
        plane=plane,
        ratio=ratio,
        redistribute_every=redistribute_every if coordinated else None,
        min_share=min_share,
        smooth=smooth,
        fleet_factory=scenario.fleet_factory,
        seed=scenario.seed if seed is None else seed,
    )
    served = trace.offload_mask()
    return CityRunResult(
        effective=np.where(served, scenario.strong_ap, scenario.weak_ap),
        decision=trace.decision_mask(),
        served=served,
        trace=trace,
    )
