"""repro.fleet — city-scale sharded serving.

The offload data plane on a device mesh (:class:`FleetPlane`, bit-identical
to single-device), thousands of streams partitioned into logical shards
each owning a private edge fleet (:class:`FleetRuntime` /
:func:`simulate_fleet`), and fleet-wide token-budget coordination with
reward-driven redistribution (:class:`FleetBudget`, the ``fleet_fair``
policy).  ``repro.fleet.experiment`` holds the city-scale headline:
coordinated redistribution beats the static equal split at equal total
offload budget.
"""
from repro.fleet.budget import FleetBudget, FleetFairPolicy
from repro.fleet.experiment import (
    CityRunResult,
    CityScenario,
    default_city_scenario,
    run_city_scenario,
)
from repro.fleet.plane import FleetPlane
from repro.fleet.runtime import (
    FleetRuntime,
    FleetStep,
    FleetTelemetry,
    FleetTrace,
    reduce_telemetry,
    simulate_fleet,
)

__all__ = [
    "CityRunResult",
    "CityScenario",
    "FleetBudget",
    "FleetFairPolicy",
    "FleetPlane",
    "FleetRuntime",
    "FleetStep",
    "FleetTelemetry",
    "FleetTrace",
    "default_city_scenario",
    "reduce_telemetry",
    "run_city_scenario",
    "simulate_fleet",
]
