"""Deterministic time sources for the streaming runtime.

Nothing in the runtime reads the wall clock: every time-dependent component
(token-bucket refill, edge service completion) takes either an explicit
``now`` argument or an injected zero-arg clock callable.  ``ManualClock`` is
the canonical injectable clock for simulations and tests.
"""
from __future__ import annotations


class ManualClock:
    """A hand-advanced monotone clock: ``clock()`` reads, ``advance`` moves."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        dt = float(dt)
        # NaN poisons every downstream schedule silently; `not (dt >= 0)`
        # catches it along with negative steps
        if not (dt >= 0):
            raise ValueError(f"clock cannot go backwards or take NaN (dt={dt})")
        self.t += dt
        return self.t
