"""Streaming serve-time API on top of the frozen ``OffloadEngine``.

The engine (repro.api) is the fitted decision artifact; this package is the
*served system* around it — the paper's deployment setting made explicit:

- :class:`OffloadSession` — stateful per-stream wrapper (micro-batched
  scoring through the fused Pallas path, arrival-order policy state,
  rolling telemetry, mid-stream ``set_ratio``),
- :class:`EdgeWorker` / :class:`EdgeLatencyModel` — a constrained edge
  server (capacity, clock-driven token-bucket rate limit, latency model,
  optional ``link=`` uplink front-end from :mod:`repro.netsim` with a
  bounded FIFO queue and per-frame :class:`LatencyBreakdown`),
- :class:`MultiEdgeDispatcher` — routes accepted offloads across a
  heterogeneous fleet (``round_robin`` / ``least_loaded`` /
  ``score_weighted``) with drop-or-degrade on saturation,
- :class:`OffloadRuntime` / :func:`simulate` — the deterministic seeded
  end-to-end driver producing exact per-step :class:`StreamTrace` records.

Every layer accepts an optional ``obs=`` :class:`repro.obs.Obs` handle
(re-exported here): metrics registry + manual-clock span tracing +
host-phase profiling, noop-by-default.

See docs/API.md ("The streaming runtime") for the lifecycle and a migration
note from direct ``engine.decide()`` loops.
"""
from repro.obs import Obs
from repro.runtime.clock import ManualClock
from repro.runtime.dispatch import (
    OUTCOME_DEGRADED,
    OUTCOME_DROPPED,
    OUTCOME_LOCAL,
    OUTCOME_OFFLOADED,
    DispatchResult,
    MultiEdgeDispatcher,
    list_strategies,
)
from repro.runtime.edge import (
    CompletedJob,
    EdgeLatencyModel,
    EdgeWorker,
    LatencyBreakdown,
)
from repro.runtime.session import OffloadSession, SessionTelemetry, StepDecision
from repro.runtime.simulate import (
    OffloadRuntime,
    StepRecord,
    StreamTrace,
    default_congested_fleet,
    default_edge_fleet,
    simulate,
)

__all__ = [
    "ManualClock",
    "Obs",
    "OffloadSession",
    "SessionTelemetry",
    "StepDecision",
    "EdgeWorker",
    "EdgeLatencyModel",
    "LatencyBreakdown",
    "CompletedJob",
    "MultiEdgeDispatcher",
    "DispatchResult",
    "list_strategies",
    "OUTCOME_LOCAL",
    "OUTCOME_OFFLOADED",
    "OUTCOME_DEGRADED",
    "OUTCOME_DROPPED",
    "OffloadRuntime",
    "StepRecord",
    "StreamTrace",
    "default_edge_fleet",
    "default_congested_fleet",
    "simulate",
]
