"""`EdgeWorker` — one constrained edge server in the serve-time topology.

Models the three resource constraints the paper's deployment setting puts on
the strong detector's side of the link:

- **capacity**: at most ``capacity`` offloaded frames in flight at once
  (the edge GPU's concurrency budget),
- **rate**: a token bucket admitting at most ``rate`` offloads per time
  unit with burst tolerance ``burst`` — a plain
  :class:`repro.core.policy.TokenBucket` in its estimate-independent
  ``try_take`` form, refilled by the simulation clock (injected, never the
  wall clock),
- **latency model**: completion time ``base + per_inflight * load`` plus
  seeded jitter, so heterogeneous edges (fast/near vs big/far) and load-
  dependent queueing are expressible,
- **link** (optional): a :class:`repro.netsim.NetworkLink` fronted by a
  bounded FIFO :class:`repro.netsim.UplinkQueue` — offloads first queue for
  and occupy the device→edge uplink, then run on the edge, so every
  admitted frame's latency decomposes into queue + transmit + service
  (surfaced as :class:`LatencyBreakdown` and stamped onto dispatch traces).

All timekeeping flows through the ``now`` argument of ``poll``/``try_admit``
— the worker is fully deterministic under a seeded driver.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.policy import TokenBucket


@dataclass(frozen=True)
class EdgeLatencyModel:
    """Offload completion latency: ``base + per_inflight * inflight`` plus
    uniform seeded jitter in ``[0, jitter)``."""

    base: float = 1.0
    per_inflight: float = 0.0
    jitter: float = 0.0

    def __post_init__(self) -> None:
        for name in ("base", "per_inflight", "jitter"):
            v = getattr(self, name)
            if not np.isfinite(v) or v < 0.0:
                raise ValueError(
                    f"EdgeLatencyModel.{name} must be finite and >= 0, got {v}"
                )

    def sample(self, inflight: int, rng: np.random.Generator) -> float:
        lat = self.base + self.per_inflight * inflight
        if self.jitter > 0.0:
            lat += self.jitter * float(rng.uniform())
        return lat


@dataclass(frozen=True)
class LatencyBreakdown:
    """Where one offload's latency went: uplink queue wait, transmission,
    edge service, and (on downlink-fronted edges) the return transit of the
    result — the detections also pay transmission before they count.
    Link-free edges report pure service."""

    queue: float
    transmit: float
    service: float
    downlink: float = 0.0

    @property
    def total(self) -> float:
        return self.queue + self.transmit + self.service + self.downlink

    def as_dict(self) -> Dict[str, float]:
        return {
            "queue": self.queue,
            "transmit": self.transmit,
            "service": self.service,
            "downlink": self.downlink,
        }


@dataclass(frozen=True)
class CompletedJob:
    """One finished offload: arrival step, admit/finish times, serving edge."""

    step: int
    edge: str
    t_admit: float
    t_done: float


class EdgeWorker:
    """One edge server with capacity, rate limit, and a latency model.

    Parameters
    ----------
    name : str
        Unique id within a dispatcher fleet.
    capacity : int
        Max concurrent in-flight offloads.
    rate : float or None
        Admissions per time unit (token bucket, burst ``burst``); ``None``
        disables rate limiting.
    burst : float
        Token-bucket depth (burst tolerance) when ``rate`` is set.
    latency : EdgeLatencyModel
    link : repro.netsim.NetworkLink or None
        Optional uplink model.  When set, every admission first traverses a
        bounded FIFO :class:`repro.netsim.UplinkQueue` over this link:
        admission can additionally fail because the uplink queue is full
        (``queue_depth``), and the returned latency is queue wait +
        transmission + service (breakdown in ``last_breakdown``).
    queue_depth : int
        Uplink queue bound (frames queued-or-transmitting) when ``link`` is
        set.
    frame_bits : float
        Default offloaded-frame size on the link (``try_admit`` may
        override per frame).
    downlink : repro.netsim.NetworkLink or None
        Optional edge→device **return** channel.  When set, each completed
        offload's result (``result_bits``) traverses a bounded FIFO
        :class:`repro.netsim.DownlinkQueue` before the device counts it:
        the returned latency additionally includes the downlink sojourn
        (``breakdown.downlink``), and admission pre-checks the downlink
        queue the same way it pre-checks the uplink.
    downlink_depth : int
        Downlink queue bound (results queued-or-transmitting) when
        ``downlink`` is set.
    result_bits : float
        Returned-result size on the downlink (detections are far smaller
        than the frames that produced them).
    seed : int
        Seeds the jitter stream; two workers with equal config + seed are
        step-for-step identical.
    """

    def __init__(
        self,
        name: str,
        *,
        capacity: int = 4,
        rate: Optional[float] = None,
        burst: float = 4.0,
        latency: Optional[EdgeLatencyModel] = None,
        link: Optional["NetworkLink"] = None,
        queue_depth: int = 16,
        frame_bits: float = 1.0,
        downlink: Optional["NetworkLink"] = None,
        downlink_depth: int = 32,
        result_bits: float = 0.25,
        seed: int = 0,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = str(name)
        self.capacity = int(capacity)
        self.latency = latency if latency is not None else EdgeLatencyModel()
        if link is not None:
            from repro.netsim.queue import UplinkQueue

            self.uplink: Optional[UplinkQueue] = UplinkQueue(
                link, depth=queue_depth, frame_bits=frame_bits
            )
        else:
            self.uplink = None
        if downlink is not None:
            from repro.netsim.queue import DownlinkQueue

            self.downlink: Optional[DownlinkQueue] = DownlinkQueue(
                downlink, depth=downlink_depth, frame_bits=result_bits
            )
        else:
            self.downlink = None
        self.last_breakdown: Optional[LatencyBreakdown] = None
        self._tracer: Optional[Any] = None
        self._tid = 0
        self._rng = np.random.default_rng(seed)
        self._now = 0.0
        # min-heap of (t_done, step, t_admit); admit time rides in the entry
        # so concurrent sessions may reuse step indices without collisions
        self._inflight: List[tuple] = []
        self.completed: List[CompletedJob] = []
        self.accepted = 0
        self.rejected = 0
        self.cancelled = 0
        self._bucket: Optional[TokenBucket] = (
            TokenBucket(
                rate=float(rate),
                depth=float(burst),
                base_threshold=0.0,
                clock=lambda: self._now,
            )
            if rate is not None
            else None
        )

    # --------------------------------------------------------------- obs

    def attach_obs(self, obs: Optional[Any], tid: int = 0) -> None:
        """Wire this edge into an observability handle: live callback
        gauges over its existing counters (no hot-path mutation anywhere)
        and a trace track (``tid``) for its offload span groups."""
        if obs is None:
            return
        self._tracer = obs.tracer
        self._tid = int(tid)
        if self._tracer is not None:
            self._tracer.thread_name(self._tid, f"edge:{self.name}")
        reg = obs.metrics
        if reg is not None:
            labels = {"edge": self.name}
            reg.gauge(
                "repro_edge_inflight", labels,
                help="offloads currently running on the edge",
                fn=lambda: len(self._inflight),
            )
            reg.gauge(
                "repro_edge_queue_depth", labels,
                help="frames queued or transmitting on the uplink",
                fn=lambda: self.uplink.occupancy if self.uplink is not None else 0,
            )
            reg.gauge(
                "repro_edge_accepted", labels,
                help="offloads admitted so far", fn=lambda: self.accepted,
            )
            reg.gauge(
                "repro_edge_rejected", labels,
                help="offloads refused so far", fn=lambda: self.rejected,
            )

    # ------------------------------------------------------------------ time

    def _advance(self, now: float) -> None:
        self._now = max(self._now, float(now))

    def poll(self, now: float) -> List[CompletedJob]:
        """Complete every in-flight offload with finish time <= ``now``."""
        self._advance(now)
        if self.uplink is not None:
            self.uplink.poll(self._now)
        if self.downlink is not None:
            self.downlink.poll(self._now)
        done: List[CompletedJob] = []
        while self._inflight and self._inflight[0][0] <= self._now:
            t_done, step, t_admit = heapq.heappop(self._inflight)
            job = CompletedJob(
                step=step, edge=self.name, t_admit=t_admit, t_done=t_done,
            )
            done.append(job)
            self.completed.append(job)
            if self._tracer is not None:
                self._tracer.instant(
                    "result.return", t=t_done, tid=self._tid,
                    args={"step": step},
                )
        return done

    # ------------------------------------------------------------- admission

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    @property
    def load(self) -> float:
        """Fraction of capacity in use (0 = idle, 1 = saturated)."""
        return len(self._inflight) / self.capacity

    def expected_latency(self) -> float:
        """Deterministic part of the next job's latency (dispatch weighting);
        includes the predicted uplink sojourn on link-fronted edges."""
        service = self.latency.base + self.latency.per_inflight * len(self._inflight)
        if self.uplink is not None:
            service += self.uplink.predicted_sojourn(self._now)
        if self.downlink is not None:
            service += self.downlink.predicted_sojourn(self._now)
        return service

    def predicted_uplink_delay(self, now: float) -> float:
        """Predicted uplink *queueing* wait for a frame offered now — the
        avoidable part of the sojourn (a frame's own transmission is paid
        regardless of when it offloads).  0 on link-free edges.  The
        congestion signal queue-aware policies discount by."""
        if self.uplink is None:
            return 0.0
        return self.uplink.predicted_wait(max(self._now, float(now)))

    def uplink_state(self, now: float) -> Tuple[int, int]:
        """Observed ``(queue_depth, channel_state)`` at ``now`` — the MDP
        state the ``value_iteration`` policy conditions on.  Link-free edges
        report ``(0, good)``."""
        if self.uplink is None:
            return 0, 0
        t = max(self._now, float(now))
        self.uplink.poll(t)
        return self.uplink.occupancy, self.uplink.link.state_at(t)

    def try_admit(
        self,
        now: float,
        step: int,
        estimate: float,
        size_bits: Optional[float] = None,
    ) -> Optional[float]:
        """Admit one offload; returns its latency, or ``None`` when the edge
        refuses (capacity full, the rate limiter withholds a token, or the
        uplink/downlink queue is full).  The estimate is recorded on the
        trace, not used for admission.  On success ``last_breakdown`` holds
        the queue/transmit/service(/downlink) decomposition of the returned
        latency — on downlink-fronted edges the result's return transit is
        part of the latency, because a detection the device has not received
        yet serves nothing."""
        self.poll(now)
        if len(self._inflight) >= self.capacity:
            self.rejected += 1
            return None
        # pre-check the queues BEFORE the rate limiter: a full queue must
        # not burn a token on a frame it is about to refuse
        if self.uplink is not None and self.uplink.full(self._now):
            self.rejected += 1
            return None
        if self.downlink is not None and self.downlink.full(self._now):
            self.rejected += 1
            return None
        if self._bucket is not None and not self._bucket.try_take():
            self.rejected += 1
            return None
        if self.uplink is not None:
            frame = self.uplink.enqueue(self._now, int(step), size_bits)
            if frame is None:  # unreachable: fullness checked at this `now`
                self.rejected += 1
                return None
            service = self.latency.sample(len(self._inflight), self._rng)
            queue_delay, transmit_delay = frame.queue_delay, frame.transmit_delay
            t_ready = frame.t_delivered + service
        else:
            service = self.latency.sample(len(self._inflight), self._rng)
            queue_delay = transmit_delay = 0.0
            t_ready = self._now + service
        downlink_delay = 0.0
        if self.downlink is not None:
            # the whole schedule is known at admit time (deterministic
            # links), so the result's return leg is priced now: it enters
            # the downlink when service completes and pays FIFO transit
            result = self.downlink.enqueue(t_ready, int(step))
            if result is None:  # unreachable: fullness checked above
                self.rejected += 1
                return None
            downlink_delay = result.sojourn
            t_ready = result.t_delivered
        self.last_breakdown = LatencyBreakdown(
            queue=queue_delay,
            transmit=transmit_delay,
            service=service,
            downlink=downlink_delay,
        )
        lat = t_ready - self._now
        heapq.heappush(self._inflight, (self._now + lat, int(step), self._now))
        self.accepted += 1
        if self._tracer is not None:
            # the simulator knows the job's whole extent at admit time, so
            # the span group is synthesized here: an async `offload` slice
            # with nested queue → transmit → service children (async so
            # concurrent jobs on one edge can overlap without mis-nesting)
            tr = self._tracer
            bd = self.last_breakdown
            t0, t1 = self._now, self._now + lat
            jid = tr.next_id()
            tr.add_async_span(
                "offload", t0, t1, id=jid, tid=self._tid,
                args={"step": int(step), "edge": self.name},
            )
            tq = t0 + bd.queue
            tt = tq + bd.transmit
            ts = tt + bd.service
            tr.add_async_span("queue", t0, tq, id=jid, tid=self._tid)
            tr.add_async_span("transmit", tq, tt, id=jid, tid=self._tid)
            tr.add_async_span("service", tt, ts, id=jid, tid=self._tid)
            if bd.downlink > 0.0:
                tr.add_async_span("downlink", ts, t1, id=jid, tid=self._tid)
        return lat

    def cancel_steps(self, steps: "set[int]") -> int:
        """Drop the in-flight offloads whose step ids are in ``steps`` —
        the *die* in-flight semantics of a mid-stream edge handover: results
        still being computed for (or transiting back to) a client that left
        this edge's coverage are abandoned, never delivered.  Returns the
        number cancelled.  Queue occupancy is left untouched: the frames
        already crossed (or are crossing) the radio — only the delivery is
        suppressed."""
        keep = [e for e in self._inflight if e[1] not in steps]
        n = len(self._inflight) - len(keep)
        if n:
            self._inflight = keep
            heapq.heapify(self._inflight)
            self.cancelled += n
        return n

    # ----------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        out = {
            "capacity": self.capacity,
            "accepted": self.accepted,
            "rejected": self.rejected,
            "completed": len(self.completed),
            "inflight": len(self._inflight),
        }
        if self.cancelled:
            out["cancelled"] = self.cancelled
        if self.uplink is not None:
            out["uplink"] = self.uplink.stats()
        if self.downlink is not None:
            out["downlink"] = self.downlink.stats()
        return out
