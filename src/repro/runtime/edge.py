"""`EdgeWorker` — one constrained edge server in the serve-time topology.

Models the three resource constraints the paper's deployment setting puts on
the strong detector's side of the link:

- **capacity**: at most ``capacity`` offloaded frames in flight at once
  (the edge GPU's concurrency budget),
- **rate**: a token bucket admitting at most ``rate`` offloads per time
  unit with burst tolerance ``burst`` — a plain
  :class:`repro.core.policy.TokenBucket` in its estimate-independent
  ``try_take`` form, refilled by the simulation clock (injected, never the
  wall clock),
- **latency model**: completion time ``base + per_inflight * load`` plus
  seeded jitter, so heterogeneous edges (fast/near vs big/far) and load-
  dependent queueing are expressible.

All timekeeping flows through the ``now`` argument of ``poll``/``try_admit``
— the worker is fully deterministic under a seeded driver.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.policy import TokenBucket


@dataclass(frozen=True)
class EdgeLatencyModel:
    """Offload completion latency: ``base + per_inflight * inflight`` plus
    uniform seeded jitter in ``[0, jitter)``."""

    base: float = 1.0
    per_inflight: float = 0.0
    jitter: float = 0.0

    def sample(self, inflight: int, rng: np.random.Generator) -> float:
        lat = self.base + self.per_inflight * inflight
        if self.jitter > 0.0:
            lat += self.jitter * float(rng.uniform())
        return lat


@dataclass(frozen=True)
class CompletedJob:
    """One finished offload: arrival step, admit/finish times, serving edge."""

    step: int
    edge: str
    t_admit: float
    t_done: float


class EdgeWorker:
    """One edge server with capacity, rate limit, and a latency model.

    Parameters
    ----------
    name : str
        Unique id within a dispatcher fleet.
    capacity : int
        Max concurrent in-flight offloads.
    rate : float or None
        Admissions per time unit (token bucket, burst ``burst``); ``None``
        disables rate limiting.
    burst : float
        Token-bucket depth (burst tolerance) when ``rate`` is set.
    latency : EdgeLatencyModel
    seed : int
        Seeds the jitter stream; two workers with equal config + seed are
        step-for-step identical.
    """

    def __init__(
        self,
        name: str,
        *,
        capacity: int = 4,
        rate: Optional[float] = None,
        burst: float = 4.0,
        latency: Optional[EdgeLatencyModel] = None,
        seed: int = 0,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = str(name)
        self.capacity = int(capacity)
        self.latency = latency if latency is not None else EdgeLatencyModel()
        self._rng = np.random.default_rng(seed)
        self._now = 0.0
        # min-heap of (t_done, step, t_admit); admit time rides in the entry
        # so concurrent sessions may reuse step indices without collisions
        self._inflight: List[tuple] = []
        self.completed: List[CompletedJob] = []
        self.accepted = 0
        self.rejected = 0
        self._bucket: Optional[TokenBucket] = (
            TokenBucket(
                rate=float(rate),
                depth=float(burst),
                base_threshold=0.0,
                clock=lambda: self._now,
            )
            if rate is not None
            else None
        )

    # ------------------------------------------------------------------ time

    def _advance(self, now: float) -> None:
        self._now = max(self._now, float(now))

    def poll(self, now: float) -> List[CompletedJob]:
        """Complete every in-flight offload with finish time <= ``now``."""
        self._advance(now)
        done: List[CompletedJob] = []
        while self._inflight and self._inflight[0][0] <= self._now:
            t_done, step, t_admit = heapq.heappop(self._inflight)
            job = CompletedJob(
                step=step, edge=self.name, t_admit=t_admit, t_done=t_done,
            )
            done.append(job)
            self.completed.append(job)
        return done

    # ------------------------------------------------------------- admission

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    @property
    def load(self) -> float:
        """Fraction of capacity in use (0 = idle, 1 = saturated)."""
        return len(self._inflight) / self.capacity

    def expected_latency(self) -> float:
        """Deterministic part of the next job's latency (dispatch weighting)."""
        return self.latency.base + self.latency.per_inflight * len(self._inflight)

    def try_admit(self, now: float, step: int, estimate: float) -> Optional[float]:
        """Admit one offload; returns its latency, or ``None`` when the edge
        refuses (capacity full, or the rate limiter withholds a token).  The
        estimate is recorded on the trace, not used for admission."""
        self.poll(now)
        if len(self._inflight) >= self.capacity:
            self.rejected += 1
            return None
        if self._bucket is not None and not self._bucket.try_take():
            self.rejected += 1
            return None
        lat = self.latency.sample(len(self._inflight), self._rng)
        heapq.heappush(self._inflight, (self._now + lat, int(step), self._now))
        self.accepted += 1
        return lat

    # ----------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        return {
            "capacity": self.capacity,
            "accepted": self.accepted,
            "rejected": self.rejected,
            "completed": len(self.completed),
            "inflight": len(self._inflight),
        }
