"""`MultiEdgeDispatcher` — routes accepted offloads across N heterogeneous
edges, with drop-or-degrade on saturation.

Strategies (``list_strategies()``):

- ``round_robin``   — rotate through the fleet, take the first that admits,
- ``least_loaded``  — prefer the lowest in-flight/capacity fraction,
- ``score_weighted``— seeded sampling of the probe order with weights
  ``free_slots / expected_latency`` sharpened by the frame's reward
  estimate (high-value frames concentrate on the fastest free edges,
  low-value frames spread for load balance), so fast idle edges absorb
  most traffic while loaded ones still get a share (power-of-choices
  flavor).

When no edge admits a frame, the saturation policy decides its fate:
``degrade`` serves the weak result locally (frame is answered, quality
degrades), ``drop`` discards it.  Both are counted; the per-step outcome is
recorded on the :class:`DispatchResult` so traces stay exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.runtime.edge import EdgeWorker, LatencyBreakdown

_STRATEGIES = ("round_robin", "least_loaded", "score_weighted")
_ON_SATURATION = ("degrade", "drop")

#: trace outcome labels
OUTCOME_LOCAL = "local"          # policy kept the frame on the weak device
OUTCOME_OFFLOADED = "offloaded"  # admitted by an edge
OUTCOME_DEGRADED = "degraded"    # wanted to offload, fleet saturated -> weak
OUTCOME_DROPPED = "dropped"      # wanted to offload, fleet saturated -> lost


def list_strategies() -> List[str]:
    """Registered dispatch strategies (for configs and error messages)."""
    return list(_STRATEGIES)


@dataclass(frozen=True)
class DispatchResult:
    """Where one accepted offload went (or why it didn't).  ``breakdown``
    decomposes the latency of admitted frames into uplink queue wait,
    transmission, and edge service (pure service on link-free edges)."""

    step: int
    estimate: float
    edge: Optional[str]
    latency: Optional[float]
    outcome: str
    breakdown: Optional[LatencyBreakdown] = None


class MultiEdgeDispatcher:
    def __init__(
        self,
        edges: Sequence[EdgeWorker],
        strategy: str = "least_loaded",
        *,
        on_saturation: str = "degrade",
        seed: int = 0,
    ):
        if strategy not in _STRATEGIES:
            raise KeyError(f"unknown strategy {strategy!r}; have {list_strategies()}")
        if on_saturation not in _ON_SATURATION:
            raise KeyError(
                f"unknown saturation policy {on_saturation!r}; have {list(_ON_SATURATION)}"
            )
        self.edges = list(edges)
        if not self.edges:
            raise ValueError("dispatcher needs at least one edge")
        names = [e.name for e in self.edges]
        if len(set(names)) != len(names):
            raise ValueError(f"edge names must be unique, got {names}")
        self.strategy = strategy
        self.on_saturation = on_saturation
        self._rr = 0
        self._rng = np.random.default_rng(seed)
        self.dropped = 0
        self.degraded = 0
        self._profiler: Optional[Any] = None
        self._outcomes: Optional[Dict[str, Any]] = None

    # --------------------------------------------------------------- obs

    def attach_obs(self, obs: Optional[Any], tid_base: int = 100) -> None:
        """Wire the dispatcher and its fleet into an observability handle:
        per-outcome dispatch counters, the host-phase profiler, and one
        trace track per edge starting at ``tid_base``."""
        if obs is None:
            return
        self._profiler = obs.profiler
        reg = obs.metrics
        if reg is not None:
            self._outcomes = {
                outcome: reg.counter(
                    "repro_dispatch_total", {"outcome": outcome},
                    help="dispatch decisions by outcome",
                )
                for outcome in (
                    OUTCOME_OFFLOADED, OUTCOME_DEGRADED, OUTCOME_DROPPED
                )
            }
        for i, e in enumerate(self.edges):
            e.attach_obs(obs, tid=tid_base + i)

    # --------------------------------------------------------------- routing

    def poll(self, now: float) -> None:
        """Advance all edges to ``now``, completing finished offloads."""
        for e in self.edges:
            e.poll(now)

    def _probe_order(self, estimate: float) -> List[int]:
        n = len(self.edges)
        if self.strategy == "round_robin":
            start = self._rr
            self._rr = (self._rr + 1) % n
            return [(start + i) % n for i in range(n)]
        if self.strategy == "least_loaded":
            return sorted(range(n), key=lambda i: (self.edges[i].load, i))
        # score_weighted: seeded sampling without replacement, weight =
        # free slots per unit of expected latency, sharpened by the frame's
        # reward estimate — exponent 1 + clip(estimate, 0, 1), so a
        # high-value frame concentrates its probe order on the best edges
        # while a low-value frame spreads more evenly (weights are
        # normalized, so only a *shape* change can use the estimate)
        w = np.array(
            [
                max(e.capacity - e.inflight, 0) / max(e.expected_latency(), 1e-9)
                for e in self.edges
            ],
            dtype=np.float64,
        )
        pos = np.flatnonzero(w > 0.0)
        if pos.size == 0:
            return list(range(n))
        sharp = w[pos] ** (1.0 + float(np.clip(estimate, 0.0, 1.0)))
        order = [
            int(i)
            for i in self._rng.choice(
                pos, size=pos.size, replace=False, p=sharp / sharp.sum()
            )
        ]
        # saturated edges last, in index order (their buckets may still admit
        # once try_admit polls completions at dispatch time)
        return order + [i for i in range(n) if w[i] <= 0.0]

    def dispatch(
        self,
        now: float,
        step: int,
        estimate: float,
        *,
        prefer: Optional[int] = None,
        pin: bool = False,
        size_bits: Optional[float] = None,
    ) -> DispatchResult:
        """Route one accepted offload; on fleet saturation apply the
        drop-or-degrade policy.

        ``prefer`` (an edge index) probes that edge first and only then
        falls back to the strategy's order — the seam mobility-aware
        dispatchers use to favor a stream's serving base station while
        keeping the fleet as backup.  ``pin=True`` hardens that to *only*
        that edge (a mobile client's single radio talks to one station;
        refusal degrades/drops rather than teleporting the frame).
        ``size_bits`` overrides the frame's size on the uplink
        (coverage-dependent links price a far client's frame higher)."""
        prof = self._profiler
        if prof is None:
            self.poll(now)
        else:
            t0 = prof.begin()
            self.poll(now)
            prof.add("dispatch.poll", t0)
            t0 = prof.begin()
        if pin and prefer is None:
            raise ValueError("pin=True needs prefer=<edge index>")
        order = self._probe_order(estimate)
        if prefer is not None:
            if not 0 <= prefer < len(self.edges):
                raise IndexError(
                    f"prefer={prefer} outside fleet of {len(self.edges)}"
                )
            order = [prefer] if pin else (
                [prefer] + [i for i in order if i != prefer]
            )
        if prof is not None:
            prof.add("dispatch.probe_order", t0)
            t0 = prof.begin()
        for i in order:
            lat = self.edges[i].try_admit(now, step, estimate, size_bits)
            if lat is not None:
                if prof is not None:
                    prof.add("dispatch.admit", t0)
                if self._outcomes is not None:
                    self._outcomes[OUTCOME_OFFLOADED].inc()
                return DispatchResult(
                    step=step, estimate=estimate, edge=self.edges[i].name,
                    latency=lat, outcome=OUTCOME_OFFLOADED,
                    breakdown=self.edges[i].last_breakdown,
                )
        if prof is not None:
            prof.add("dispatch.admit", t0)
        if self.on_saturation == "degrade":
            self.degraded += 1
            outcome = OUTCOME_DEGRADED
        else:
            self.dropped += 1
            outcome = OUTCOME_DROPPED
        if self._outcomes is not None:
            self._outcomes[outcome].inc()
        return DispatchResult(
            step=step, estimate=estimate, edge=None, latency=None, outcome=outcome
        )

    # ----------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        return {
            "strategy": self.strategy,
            "on_saturation": self.on_saturation,
            "dropped": self.dropped,
            "degraded": self.degraded,
            "edges": {e.name: e.stats() for e in self.edges},
        }
