"""`OffloadRuntime` + the seeded `simulate()` driver.

The runtime is the top-level serve-time object: one frozen
:class:`repro.api.OffloadEngine` artifact, a fleet of
:class:`~repro.runtime.edge.EdgeWorker`, and a
:class:`~repro.runtime.dispatch.MultiEdgeDispatcher` strategy.  Sessions
opened from it decide in arrival order; frames the policy offloads are
routed across the fleet; saturation degrades (or drops) them.

``simulate`` is the deterministic end-to-end driver of the paper's
deployment picture — one weak embedded device emitting a stream of frames
toward N constrained edges — producing an exact per-step
:class:`StreamTrace`.  Everything is seeded and clocked manually, so two
runs with the same inputs are identical record-for-record.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.api.engine import OffloadEngine
from repro.runtime.clock import ManualClock
from repro.runtime.dispatch import (
    OUTCOME_LOCAL,
    OUTCOME_OFFLOADED,
    DispatchResult,
    MultiEdgeDispatcher,
)
from repro.runtime.edge import EdgeLatencyModel, EdgeWorker
from repro.runtime.session import OffloadSession, SessionTelemetry, StepDecision


@dataclass(frozen=True)
class StepRecord:
    """One frame's full serve-time story, in arrival order.

    For offloaded frames the latency decomposes exactly:
    ``latency == queue_delay + transmit_delay + service_delay +
    downlink_delay`` (the uplink queue wait, the transmission over the
    link, the edge service time, and the result's return transit — the
    first two are 0 on link-free edges, the last is 0 on edges without a
    downlink).  Non-offloaded frames carry ``None`` for all four.

    Video streams (``repro.video``) additionally stamp temporal fields:
    ``source`` is what was actually served for the frame (``"weak"`` or
    ``"edge"`` for a propagated stale edge result), ``staleness`` the age of
    that result in frames (None when served weak), ``effective_accuracy``
    the frame's AP against ground truth.  Per-image simulations leave all
    three None."""

    step: int
    t_arrival: float
    t_decision: float
    estimate: float
    offload: bool
    edge: Optional[str]
    latency: Optional[float]
    outcome: str
    queue_delay: Optional[float] = None
    transmit_delay: Optional[float] = None
    service_delay: Optional[float] = None
    downlink_delay: Optional[float] = None
    source: Optional[str] = None
    staleness: Optional[float] = None
    effective_accuracy: Optional[float] = None

    def as_dict(self) -> Dict[str, Any]:
        return {
            "step": self.step,
            "t_arrival": self.t_arrival,
            "t_decision": self.t_decision,
            "estimate": self.estimate,
            "offload": self.offload,
            "edge": self.edge,
            "latency": self.latency,
            "outcome": self.outcome,
            "queue_delay": self.queue_delay,
            "transmit_delay": self.transmit_delay,
            "service_delay": self.service_delay,
            "downlink_delay": self.downlink_delay,
            "source": self.source,
            "staleness": self.staleness,
            "effective_accuracy": self.effective_accuracy,
        }


@dataclass
class StreamTrace:
    """Per-step records + end-of-stream telemetry and dispatcher stats."""

    records: List[StepRecord]
    telemetry: SessionTelemetry
    dispatcher: Dict[str, Any]

    def outcome_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self.records:
            counts[r.outcome] = counts.get(r.outcome, 0) + 1
        return counts

    def offload_mask(self) -> np.ndarray:
        """Frames actually served by an edge, in arrival order (degraded and
        dropped frames are False — they never reached the strong model)."""
        return np.array([r.outcome == OUTCOME_OFFLOADED for r in self.records])

    def effective_accuracy(self) -> Optional[float]:
        """Mean per-frame effective accuracy over the records that carry it
        (video streams; ``None`` for per-image simulations)."""
        vals = [
            r.effective_accuracy
            for r in self.records
            if r.effective_accuracy is not None
        ]
        return float(np.mean(vals)) if vals else None

    def staleness_profile(self) -> Dict[str, float]:
        """How the stream was actually served: fraction of frames answered
        from a propagated edge result and their mean staleness."""
        stale = [r.staleness for r in self.records if r.staleness is not None]
        n = len(self.records)
        return {
            "covered_fraction": len(stale) / n if n else 0.0,
            "mean_staleness": float(np.mean(stale)) if stale else 0.0,
        }

    def latency_decomposition(self) -> Optional[Dict[str, float]]:
        """Mean queue/transmit/service/downlink components over the
        offloaded frames (``None`` when nothing was offloaded)."""
        rows = [
            (
                r.queue_delay,
                r.transmit_delay,
                r.service_delay,
                r.downlink_delay if r.downlink_delay is not None else 0.0,
            )
            for r in self.records
            if r.queue_delay is not None
        ]
        if not rows:
            return None
        q, t, s, d = (float(np.mean(col)) for col in zip(*rows))
        return {
            "queue": q, "transmit": t, "service": s, "downlink": d,
            "total": q + t + s + d,
        }

    def summary(self) -> Dict[str, Any]:
        lats = [r.latency for r in self.records if r.latency is not None]
        return {
            "steps": len(self.records),
            "outcomes": self.outcome_counts(),
            "telemetry": self.telemetry.as_dict(),
            "dispatcher": self.dispatcher,
            "mean_offload_latency": float(np.mean(lats)) if lats else None,
            "latency_decomposition": self.latency_decomposition(),
            "effective_accuracy": self.effective_accuracy(),
        }


def default_edge_fleet(
    n: int = 3, seed: int = 0, *, prefix: str = "edge"
) -> List[EdgeWorker]:
    """A seeded heterogeneous fleet: a fast/small edge, then progressively
    bigger, slower, more rate-limited ones (cycled past n=3).  ``prefix``
    keeps edge names unique when several fleets coexist (one per shard in
    ``repro.fleet``)."""
    profiles = [
        dict(capacity=2, rate=0.5, burst=2.0,
             latency=EdgeLatencyModel(base=0.5, per_inflight=0.1, jitter=0.05)),
        dict(capacity=4, rate=0.35, burst=4.0,
             latency=EdgeLatencyModel(base=1.0, per_inflight=0.2, jitter=0.1)),
        dict(capacity=8, rate=0.25, burst=8.0,
             latency=EdgeLatencyModel(base=2.0, per_inflight=0.1, jitter=0.1)),
    ]
    return [
        EdgeWorker(f"{prefix}{i}", seed=seed + i, **profiles[i % len(profiles)])
        for i in range(n)
    ]


def default_congested_fleet(
    n: int = 3,
    seed: int = 0,
    *,
    transmit_time: float = 5.0,
    queue_depth: int = 12,
    p_gb: float = 0.08,
    p_bg: float = 0.25,
    bad_slowdown: float = 4.0,
    prefix: str = "edge",
) -> List[EdgeWorker]:
    """A seeded fleet behind congested Gilbert–Elliott uplinks — the netsim
    acceptance scenario.  Each edge's link pushes one frame in
    ``transmit_time`` time units in the good state and ``bad_slowdown``×
    that in fades, so with frames arriving every time unit the uplink
    queues genuinely build and queue-aware policies have something to see.
    Service itself is fast (the bottleneck is the link, as in the paper's
    rate-constrained setting)."""
    from repro.netsim import GilbertElliottLink

    return [
        EdgeWorker(
            f"{prefix}{i}",
            capacity=queue_depth + 4,
            latency=EdgeLatencyModel(base=0.2, per_inflight=0.02, jitter=0.02),
            link=GilbertElliottLink(
                bandwidth=1.0 / transmit_time,
                bad_bandwidth=1.0 / (transmit_time * bad_slowdown),
                p_gb=p_gb,
                p_bg=p_bg,
                slot=1.0,
                seed=seed * 101 + i,
            ),
            queue_depth=queue_depth,
            frame_bits=1.0,
            seed=seed + i,
        )
        for i in range(n)
    ]


def default_linked_fleet(
    n: int = 3,
    seed: int = 0,
    *,
    transmit_time: float = 0.08,
    queue_depth: int = 64,
    fading: bool = False,
    p_gb: float = 0.05,
    p_bg: float = 0.4,
    bad_slowdown: float = 3.0,
    prefix: str = "edge",
) -> List[EdgeWorker]:
    """The heterogeneous ``default_edge_fleet`` profiles with *real* netsim
    uplinks in front of them: a fast ``ConstantRateLink`` per edge (one
    frame in ``transmit_time`` time units) or, with ``fading=True``, a
    seeded Gilbert–Elliott channel that slows to ``bad_slowdown``× in
    fades.  Unlike ``default_congested_fleet`` the link is provisioned as
    the *minor* cost — service still dominates — so scenarios built on the
    latency-only fleet keep their character while every frame genuinely
    pays transit (the fleet city scenario runs on this)."""
    from repro.netsim import ConstantRateLink, GilbertElliottLink

    fleet = default_edge_fleet(n, seed, prefix=prefix)
    out: List[EdgeWorker] = []
    for i, e in enumerate(fleet):
        if fading:
            link = GilbertElliottLink(
                bandwidth=1.0 / transmit_time,
                bad_bandwidth=1.0 / (transmit_time * bad_slowdown),
                p_gb=p_gb,
                p_bg=p_bg,
                slot=1.0,
                seed=seed * 211 + i,
            )
        else:
            link = ConstantRateLink(1.0 / transmit_time)
        out.append(
            EdgeWorker(
                e.name,
                capacity=e.capacity,
                rate=e._bucket.rate if e._bucket is not None else None,
                burst=e._bucket.depth if e._bucket is not None else 1.0,
                latency=e.latency,
                link=link,
                queue_depth=queue_depth,
                frame_bits=1.0,
                seed=seed + i,
            )
        )
    return out


class OffloadRuntime:
    """The served system: engine artifact + edge fleet + dispatch strategy.

    ``net_state`` (a :class:`repro.online.netstate.NetworkEstimator`)
    switches the congestion / state probes handed to queue-aware policies
    from the simulator's oracle signals to *measured* estimates fed purely
    by completed round trips — what a real device can actually observe.
    The runtime binds it to its manual clock and fleet size and records
    every admitted offload into it."""

    def __init__(
        self,
        engine: OffloadEngine,
        edges: Sequence[EdgeWorker],
        *,
        strategy: str = "least_loaded",
        on_saturation: str = "degrade",
        seed: int = 0,
        net_state: Optional[Any] = None,
        obs: Optional[Any] = None,
    ):
        self.engine = engine
        self.dispatcher = MultiEdgeDispatcher(
            edges, strategy, on_saturation=on_saturation, seed=seed
        )
        self.clock = ManualClock()
        self.net_state = net_state
        if net_state is not None:
            net_state.bind_clock(self.clock)
            net_state.bind_fleet(len(self.dispatcher.edges))
        # observability: spans are stamped in *simulated* time (the manual
        # clock), edges get trace tracks 100+, streams 1+ (0 is the driver)
        self.obs = obs
        if obs is not None:
            obs.bind_clock(self.clock)
            if obs.tracer is not None:
                obs.tracer.thread_name(0, "runtime")
            self.dispatcher.attach_obs(obs, tid_base=100)

    def _best_edge(self) -> EdgeWorker:
        """The edge a new offload would most plausibly land on: the one
        with the smallest predicted uplink sojourn (ties by fleet order)."""
        edges = self.dispatcher.edges
        now = self.clock()
        return min(edges, key=lambda e: e.predicted_uplink_delay(now))

    def _congestion(self) -> float:
        """Congestion signal for queue-aware policies: the *measured*
        estimate when a ``net_state`` tracker is wired, else the oracle —
        the predicted uplink queueing wait at the best edge right now (how
        long a frame offloaded at this instant would sit behind others
        before its own transmission starts; 0 for link-free fleets)."""
        if self.net_state is not None:
            return float(self.net_state.congestion())
        return self._best_edge().predicted_uplink_delay(self.clock())

    def _state_probe(self):
        """(queue depth, channel state) for ``value_iteration`` policies:
        measured when a ``net_state`` tracker is wired, else observed at
        the best edge."""
        if self.net_state is not None:
            return self.net_state.state_probe()
        return self._best_edge().uplink_state(self.clock())

    def _record_offload(self, now: float, res: DispatchResult) -> None:
        """Feed one dispatch outcome into the measured network tracker
        (admitted offloads only — refusals return no round trip)."""
        if self.net_state is not None and res.outcome == OUTCOME_OFFLOADED:
            self.net_state.record(now, res.latency, res.breakdown)

    def open_session(
        self,
        *,
        ratio: Optional[float] = None,
        micro_batch: int = 8,
        telemetry_window: int = 64,
        staleness: Optional[Any] = None,
        scene_change: Optional[Any] = None,
        coverage_ttl: Optional[Any] = None,
        tracker: Optional[Any] = None,
        name: Optional[str] = None,
        tid: int = 1,
    ) -> OffloadSession:
        """A new per-stream session sharing the frozen engine; time-based
        policies see the runtime's manual clock, queue-aware policies
        (``queue_aware`` / ``value_iteration``) see live congestion probes
        over the runtime's fleet, and video runtimes thread their temporal
        probes (``staleness`` / ``scene_change``) and per-stream tracker
        through unchanged.  The runtime's ``obs`` handle (if any) rides
        into the session: its telemetry counters become registry-backed
        series labeled ``{stream=name}`` and its flush spans land on trace
        track ``tid``."""
        if self.obs is not None and self.obs.tracer is not None:
            self.obs.tracer.thread_name(
                tid, f"session:{tid - 1 if name is None else name}"
            )
        return OffloadSession(
            self.engine,
            ratio=ratio,
            micro_batch=micro_batch,
            telemetry_window=telemetry_window,
            clock=self.clock,
            congestion=self._congestion,
            state_probe=self._state_probe,
            staleness=staleness,
            scene_change=scene_change,
            coverage_ttl=coverage_ttl,
            tracker=tracker,
            obs=self.obs,
            name=name,
            tid=tid,
        )

    # ------------------------------------------------------------- streaming

    def serve(
        self,
        weak_outputs: Any = None,
        *,
        features: Optional[np.ndarray] = None,
        ratio: Optional[float] = None,
        micro_batch: int = 8,
        arrival_period: float = 1.0,
        set_ratio_at: Optional[Dict[int, float]] = None,
    ) -> StreamTrace:
        """Serve one finite stream end to end and return its exact trace.

        Frames arrive every ``arrival_period`` time units; decisions come
        out micro-batched (decision time = flush time); accepted offloads
        are dispatched immediately.  ``set_ratio_at`` maps arrival step ->
        new target ratio, applied before that frame is submitted (mid-stream
        re-budgeting, paper Table I); the pending micro-batch is flushed
        first so earlier arrivals are never re-budgeted retroactively."""
        prof = self.obs.profiler if self.obs is not None else None
        if prof is None:
            x = self.engine.features(weak_outputs, features=features)
        else:
            t0 = prof.begin()
            x = self.engine.features(weak_outputs, features=features)
            prof.add("serve.features", t0)
        session = self.open_session(ratio=ratio, micro_batch=micro_batch)
        rebudget = dict(set_ratio_at or {})
        t_arrival: Dict[int, float] = {}
        records: List[StepRecord] = []

        def settle(decisions: List[StepDecision]) -> None:
            now = self.clock()
            for d in decisions:
                if not d.offload:
                    records.append(
                        StepRecord(
                            step=d.step, t_arrival=t_arrival[d.step],
                            t_decision=now, estimate=d.estimate, offload=False,
                            edge=None, latency=None, outcome=OUTCOME_LOCAL,
                        )
                    )
                    continue
                res: DispatchResult = self.dispatcher.dispatch(
                    now, d.step, d.estimate
                )
                self._record_offload(now, res)
                if res.outcome == OUTCOME_OFFLOADED:
                    session.record_rtt(res.latency)
                    bd0 = res.breakdown
                    if bd0 is not None and bd0.transmit > 0.0:
                        session.record_bandwidth(1.0 / bd0.transmit)
                bd = res.breakdown
                records.append(
                    StepRecord(
                        step=d.step, t_arrival=t_arrival[d.step], t_decision=now,
                        estimate=d.estimate, offload=True, edge=res.edge,
                        latency=res.latency, outcome=res.outcome,
                        queue_delay=bd.queue if bd is not None else None,
                        transmit_delay=bd.transmit if bd is not None else None,
                        service_delay=bd.service if bd is not None else None,
                        downlink_delay=bd.downlink if bd is not None else None,
                    )
                )

        if prof is None:
            for step, row in enumerate(x):
                if step in rebudget:
                    # decide earlier arrivals at the old budget
                    settle(session.flush())
                    session.set_ratio(rebudget[step])
                t_arrival[step] = self.clock()
                settle(session.submit(features=row))
                self.clock.advance(arrival_period)
            settle(session.flush())
        else:
            # profiled serve loop: same schedule, host time attributed to
            # submit (enqueue+score+decide) vs settle (records+dispatch)
            for step, row in enumerate(x):
                if step in rebudget:
                    settle(session.flush())
                    session.set_ratio(rebudget[step])
                t_arrival[step] = self.clock()
                t0 = prof.begin()
                decisions = session.submit(features=row)
                prof.add("serve.submit", t0)
                t0 = prof.begin()
                settle(decisions)
                prof.add("serve.settle", t0)
                self.clock.advance(arrival_period)
            settle(session.flush())

        # drain: run the clock past the last in-flight completion
        horizon = max(
            [r.t_decision + r.latency for r in records if r.latency is not None],
            default=self.clock(),
        )
        self.clock.advance(max(horizon - self.clock(), 0.0) + 1e-9)
        self.dispatcher.poll(self.clock())

        records.sort(key=lambda r: r.step)
        return StreamTrace(
            records=records,
            telemetry=session.telemetry,
            dispatcher=self.dispatcher.stats(),
        )


def simulate(
    engine: OffloadEngine,
    weak_outputs: Any = None,
    *,
    features: Optional[np.ndarray] = None,
    edges: Optional[Sequence[EdgeWorker]] = None,
    n_edges: int = 3,
    strategy: str = "least_loaded",
    on_saturation: str = "degrade",
    ratio: Optional[float] = None,
    micro_batch: int = 8,
    arrival_period: float = 1.0,
    set_ratio_at: Optional[Dict[int, float]] = None,
    seed: int = 0,
    net_state: Optional[Any] = None,
    obs: Optional[Any] = None,
) -> StreamTrace:
    """One-call deterministic streaming simulation: 1 weak device emitting
    the given frames toward ``n_edges`` heterogeneous edges (or an explicit
    ``edges`` fleet), decisions via a session over ``engine``."""
    fleet = list(edges) if edges is not None else default_edge_fleet(n_edges, seed)
    runtime = OffloadRuntime(
        engine, fleet, strategy=strategy, on_saturation=on_saturation, seed=seed,
        net_state=net_state, obs=obs,
    )
    return runtime.serve(
        weak_outputs,
        features=features,
        ratio=ratio,
        micro_batch=micro_batch,
        arrival_period=arrival_period,
        set_ratio_at=set_ratio_at,
    )
