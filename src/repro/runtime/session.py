"""`OffloadSession` — the stateful per-stream serve loop over a frozen
:class:`repro.api.OffloadEngine`.

The engine is the *fitted artifact* (features → estimator → rank transform →
policy construction recipe); a session is one device's *stream* through it:

- frames arrive one at a time and are buffered into micro-batches so reward
  scoring runs the engine's batched path (the fused Pallas ``estimator_mlp``
  kernel for the deployable single-hidden-layer MLP),
- decisions are taken strictly in arrival order through a session-private
  policy instance, so stateful policies (``token_bucket``) carry their
  bucket level across the stream without cross-talk between sessions,
- rolling telemetry tracks the realized offload ratio and (optionally)
  realized rewards against the target budget,
- ``set_ratio`` re-budgets mid-stream without touching the shared engine.

Sessions never mutate the engine: N concurrent streams can serve from one
loaded artifact.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import numpy as np

#: initial pending-buffer capacity (rows); grows geometrically — the hot
#: loop never allocates per frame after warmup
_MIN_BUFFER_ROWS = 64

from repro.api.engine import OffloadEngine
from repro.api.policies import make_policy, policy_context_params
from repro.obs.metrics import Counter, Gauge, Histogram, DEFAULT_TIME_BUCKETS


@dataclass(frozen=True)
class StepDecision:
    """One frame's serve-time decision, in arrival order."""

    step: int
    estimate: float
    offload: bool


@dataclass(frozen=True)
class SessionTelemetry:
    """Snapshot of a session's counters (cumulative + rolling window).

    The video counters (``covered_frames``/``mean_staleness``/
    ``effective_frames``/``mean_effective_accuracy``) stay zero unless the
    stream records temporal state (see ``record_staleness`` /
    ``record_effective_accuracy``); ``as_dict`` keeps them behind
    ``include_video`` so existing consumers see a byte-stable payload.
    The online counters (``mean_rtt``/``mean_bandwidth``/
    ``online_updates``) follow the same pattern behind ``include_online``:
    they stay zero unless the runtime records measured round trips
    (``record_rtt``/``record_bandwidth``) or closed-loop model updates
    (``record_update``).  The fleet counters (``budget_share``/
    ``budget_redistributions``) sit behind ``include_fleet`` the same way:
    zero unless a fleet runtime records the stream's coordinated budget
    state (``record_budget_share``/``record_redistribution``).  The
    mobility counters (``handovers``/``mean_coverage_dbm``) follow suit
    behind ``include_mobility``: zero unless a mobile runtime records edge
    migrations (``record_handover``) or received-signal-strength samples
    (``record_coverage``)."""

    processed: int
    offloaded: int
    realized_ratio: float
    rolling_ratio: float
    mean_estimate: float
    target_ratio: float
    pending: int
    reward_sum: float
    rewards_recorded: int
    covered_frames: int = 0
    mean_staleness: float = 0.0
    effective_frames: int = 0
    mean_effective_accuracy: float = 0.0
    rtt_samples: int = 0
    mean_rtt: float = 0.0
    bandwidth_samples: int = 0
    mean_bandwidth: float = 0.0
    online_updates: int = 0
    budget_share: float = 0.0
    budget_redistributions: int = 0
    handovers: int = 0
    coverage_samples: int = 0
    mean_coverage_dbm: float = 0.0

    def as_dict(
        self,
        include_video: bool = False,
        include_online: bool = False,
        include_fleet: bool = False,
        include_mobility: bool = False,
    ) -> Dict[str, Any]:
        out = {
            "processed": self.processed,
            "offloaded": self.offloaded,
            "realized_ratio": self.realized_ratio,
            "rolling_ratio": self.rolling_ratio,
            "mean_estimate": self.mean_estimate,
            "target_ratio": self.target_ratio,
            "pending": self.pending,
            "reward_sum": self.reward_sum,
            "rewards_recorded": self.rewards_recorded,
        }
        if include_video:
            out.update(
                {
                    "covered_frames": self.covered_frames,
                    "mean_staleness": self.mean_staleness,
                    "effective_frames": self.effective_frames,
                    "mean_effective_accuracy": self.mean_effective_accuracy,
                }
            )
        if include_online:
            out.update(
                {
                    "rtt_samples": self.rtt_samples,
                    "mean_rtt": self.mean_rtt,
                    "bandwidth_samples": self.bandwidth_samples,
                    "mean_bandwidth": self.mean_bandwidth,
                    "online_updates": self.online_updates,
                }
            )
        if include_fleet:
            out.update(
                {
                    "budget_share": self.budget_share,
                    "budget_redistributions": self.budget_redistributions,
                }
            )
        if include_mobility:
            out.update(
                {
                    "handovers": self.handovers,
                    "coverage_samples": self.coverage_samples,
                    "mean_coverage_dbm": self.mean_coverage_dbm,
                }
            )
        return out


class OffloadSession:
    """Stateful per-stream wrapper around a fitted ``OffloadEngine``.

    Parameters
    ----------
    engine : OffloadEngine
        Must be fitted (or loaded); the session builds its own policy
        instance from the engine's calibration scores so per-stream policy
        state is isolated.
    ratio : float or None
        Session-local target offloading ratio; defaults to the engine's.
    micro_batch : int
        Frames buffered before one batched scoring call.  1 = score every
        arrival immediately; larger values trade decision latency for
        scoring throughput through the fused Pallas path.
    telemetry_window : int
        Length of the rolling window behind ``telemetry.rolling_ratio``.
    clock : callable or None
        Injected time source forwarded to time-based policies
        (``token_bucket``); ignored by stateless policies.  Never the wall
        clock in tests/simulations — see ``repro.runtime.clock.ManualClock``.
    congestion : callable or None
        Zero-arg probe of the predicted uplink sojourn at the best edge,
        forwarded to policies that declare it (``queue_aware``); wired by
        ``OffloadRuntime.open_session`` from its link-fronted fleet.
    state_probe : callable or None
        Zero-arg probe of the observed ``(queue_depth, channel_state)``,
        forwarded to policies that declare it (``value_iteration``).
    staleness : callable or None
        Zero-arg probe of the stream's current edge-result staleness
        (frames since the newest covering result was captured, ``inf`` when
        none), forwarded to policies that declare it
        (``temporal_hysteresis``); wired by the video runtime.
    scene_change : callable or None
        Zero-arg probe of the stream's scene-change score in [0, 1],
        forwarded to policies that declare it (``keyframe``).
    coverage_ttl : callable or None
        Zero-arg probe of the stream's predicted time-to-coverage-loss
        (sim time units until the serving base station's signal drops
        below the usable floor, ``inf`` when not leaving coverage),
        forwarded to policies that declare it (``mobility_aware``); wired
        by the mobile runtime from its motion trace + coverage map.
    tracker : repro.video.track.VideoTracker or None
        Optional temporal state carried with the stream — sessions opened
        on video streams hold the tracker that ages/propagates stale edge
        results (possibly shared between sessions when the tracker is
        batched over streams).  The session itself never calls it; it rides
        here so stream state travels as one object.
    obs : repro.obs.Obs or None
        Observability handle.  The session's telemetry counters *are*
        metric instruments (``repro.obs.metrics``); with an obs handle
        whose metrics plane is on they are created through its registry —
        labeled ``{stream=<name>}`` — so Prometheus/JSON exports see the
        live values with no second accounting path.  With ``obs=None``
        (default) the instruments are standalone objects and nothing else
        changes: ``telemetry.as_dict()`` payloads are byte-identical
        either way.  The tracer plane (when on) receives one
        ``session.flush`` span per scoring drain on track ``tid``.
    name : str or None
        Stream label used for this session's metric series; auto-numbered
        within the registry when omitted.
    tid : int
        Trace track for this session's spans (runtimes assign one per
        stream).

    Each injected callable reaches the policy constructor only when the
    policy's ``context_params`` declares it — runtime wiring, never part of
    the engine artifact.
    """

    def __init__(
        self,
        engine: OffloadEngine,
        *,
        ratio: Optional[float] = None,
        micro_batch: int = 8,
        telemetry_window: int = 64,
        clock: Optional[Callable[[], float]] = None,
        congestion: Optional[Callable[[], float]] = None,
        state_probe: Optional[Callable[[], tuple]] = None,
        staleness: Optional[Callable[[], float]] = None,
        scene_change: Optional[Callable[[], float]] = None,
        coverage_ttl: Optional[Callable[[], float]] = None,
        tracker: Optional[Any] = None,
        obs: Optional[Any] = None,
        name: Optional[str] = None,
        tid: int = 0,
    ):
        if engine.calibration_scores is None:
            raise RuntimeError("OffloadSession over an unfitted engine")
        self.engine = engine
        self.tracker = tracker
        self.micro_batch = max(int(micro_batch), 1)
        self._ratio = float(engine.ratio if ratio is None else ratio)
        kwargs = dict(engine.policy_kwargs)
        accepted = set(policy_context_params(engine.policy_name))
        context = {
            "clock": clock,
            "congestion": congestion,
            "state_probe": state_probe,
            "staleness": staleness,
            "scene_change": scene_change,
            "coverage_ttl": coverage_ttl,
        }
        kwargs.update(
            {k: v for k, v in context.items() if v is not None and k in accepted}
        )
        # kept so `recalibrate()` can rebuild the policy (same runtime
        # wiring) against refreshed engine calibration scores
        self._policy_build_kwargs = dict(kwargs)
        self.policy = make_policy(
            engine.policy_name, engine.calibration_scores, self._ratio, **kwargs
        )
        # pending features live in one preallocated (capacity, F) buffer —
        # rows [0, _pending_rows) are queued arrivals.  The old per-frame
        # list of (1, F) blocks + np.concatenate on every drain was the
        # prime suspect in the dispatcher fps regression.
        self._buf: Optional[np.ndarray] = None
        self._pending_rows = 0
        self._next_step = 0                   # arrival index of next submit
        self._window = deque(maxlen=max(int(telemetry_window), 1))
        self._tracer = obs.tracer if obs is not None else None
        self._profiler = obs.profiler if obs is not None else None
        self._tid = int(tid)
        self._flush_t0: Optional[float] = None
        self._init_instruments(
            obs.metrics if obs is not None else None, name
        )

    def _init_instruments(self, reg, name: Optional[str]) -> None:
        """The telemetry counters ARE metric instruments: standalone
        objects when observability is off, registry-backed (walked by the
        exporters) when an obs handle carries a metrics plane.  One write
        path either way — `telemetry` is a view, never a second ledger."""
        if reg is not None:
            opened = reg.counter(
                "repro_sessions_total", help="sessions opened on this registry"
            )
            if name is None:
                name = str(opened.value)
            opened.inc()
            labels: Optional[Dict[str, str]] = {"stream": str(name)}
            counter, gauge, histogram = reg.counter, reg.gauge, reg.histogram
        else:
            labels = None
            counter = lambda n, labels=None, help="": Counter(n)
            gauge = lambda n, labels=None, help="", fn=None: Gauge(n, fn=fn)
            histogram = (
                lambda n, buckets=DEFAULT_TIME_BUCKETS, labels=None, help="":
                Histogram(n, buckets=buckets)
            )
        self._processed = counter(
            "repro_frames_processed_total", labels, help="frames decided"
        )
        self._offloaded = counter(
            "repro_frames_offloaded_total", labels,
            help="frames the policy sent to an edge",
        )
        self._estimate_sum = counter(
            "repro_estimate_sum_total", labels, help="sum of reward estimates"
        )
        self._reward_sum = counter(
            "repro_reward_sum_total", labels, help="sum of realized rewards"
        )
        self._rewards_recorded = counter(
            "repro_rewards_recorded_total", labels, help="realized rewards seen"
        )
        self._staleness_sum = counter(
            "repro_staleness_sum_total", labels,
            help="summed age of propagated edge results (frames)",
        )
        self._covered_frames = counter(
            "repro_covered_frames_total", labels,
            help="frames served from a propagated edge result",
        )
        self._accuracy_sum = counter(
            "repro_effective_accuracy_sum_total", labels,
            help="summed per-frame effective accuracy",
        )
        self._effective_frames = counter(
            "repro_effective_frames_total", labels,
            help="frames with an effective-accuracy sample",
        )
        self._rtt = histogram(
            "repro_offload_rtt", DEFAULT_TIME_BUCKETS, labels,
            help="measured offload round-trip time (sim time units)",
        )
        self._bandwidth_sum = counter(
            "repro_bandwidth_sum_total", labels,
            help="summed measured uplink goodput",
        )
        self._bandwidth_samples = counter(
            "repro_bandwidth_samples_total", labels, help="goodput samples"
        )
        self._online_updates = counter(
            "repro_online_updates_total", labels,
            help="closed-loop model updates visible to this stream",
        )
        self._budget_share = gauge(
            "repro_budget_share", labels,
            help="stream's share of the fleet offload budget",
        )
        self._budget_redistributions = counter(
            "repro_budget_redistributions_total", labels,
            help="fleet budget redistributions applied",
        )
        self._handovers = counter(
            "repro_handovers_total", labels,
            help="mid-stream edge handovers executed",
        )
        self._coverage_sum = counter(
            "repro_coverage_dbm_sum_total", labels,
            help="summed received signal strength samples (dBm)",
        )
        self._coverage_samples = counter(
            "repro_coverage_samples_total", labels,
            help="received signal strength samples",
        )
        self._coverage_dbm = gauge(
            "repro_coverage_dbm", labels,
            help="latest received signal strength from the serving edge (dBm)",
        )
        # live views with zero hot-path cost: evaluated only at collection
        gauge(
            "repro_realized_ratio", labels,
            help="offloaded / processed",
            fn=lambda: (
                self._offloaded.value / self._processed.value
                if self._processed.value else 0.0
            ),
        )
        gauge(
            "repro_pending_frames", labels,
            help="frames buffered awaiting a scoring flush",
            fn=lambda: self._pending_rows,
        )
        gauge(
            "repro_target_ratio", labels,
            help="session target offload ratio",
            fn=lambda: self._ratio,
        )

    # ------------------------------------------------------------- streaming

    def submit(
        self, weak_output: Any = None, *, features: Optional[np.ndarray] = None
    ) -> List[StepDecision]:
        """Enqueue one frame.  Returns the decisions flushed by this arrival
        — empty until the micro-batch fills, then ``micro_batch`` decisions
        in arrival order."""
        if features is not None:
            row = np.asarray(features, np.float32)
            if row.ndim != 1:
                raise ValueError(
                    f"submit() takes one frame; features must be 1-D, got {row.shape}"
                )
            self._enqueue(row[None, :])
        else:
            if weak_output is None:
                raise ValueError("pass weak_output or features=")
            self._enqueue(np.asarray(self.engine.features([weak_output]), np.float32))
        if self._pending_rows >= self.micro_batch:
            return self.flush()
        return []

    def submit_batch(
        self,
        weak_outputs: Any = None,
        *,
        features: Optional[np.ndarray] = None,
        flush: bool = True,
    ) -> List[StepDecision]:
        """Stream a pre-batched matrix through the session in arrival order.

        Feature extraction happens once for the whole batch (adapters like
        ``detection_boxes`` consume a ``DetectionsBatch``, ``lm_logits``
        batch-shaped logits) and the rows enter the pending queue as ONE
        block — no per-item conversion or row-at-a-time Python.  Scoring
        drains in micro-batch chunks and decisions stay sequential; with
        ``flush=False`` a trailing partial micro-batch stays buffered for
        the next call.

        With ``flush=True`` and nothing already pending, the batch never
        touches the host feature queue at all: it goes through
        ``engine.score_device`` — for a padded ``DetectionsBatch`` under
        the detection extractor + fused MLP that is the one-dispatch
        boxes→estimates pipeline — and converts once at the policy
        boundary.  Decisions are identical to the buffered route (both
        score the same rows as one batch, in arrival order)."""
        if flush and self._pending_rows == 0 and (
            features is None or np.ndim(features) == 2
        ):
            est = np.asarray(
                self.engine.score_device(weak_outputs, features=features),
                np.float64,
            ).ravel()
            if est.size == 0:
                return []
            self._next_step += est.size
            return self._decide(est)
        x = np.asarray(self.engine.features(weak_outputs, features=features), np.float32)
        self._enqueue(x)
        out: List[StepDecision] = []
        if flush:
            out.extend(self.flush())
        else:
            while self._pending_rows >= self.micro_batch:
                out.extend(self._drain(self.micro_batch))
        return out

    def _enqueue(self, block: np.ndarray) -> None:
        if block.ndim != 2:
            raise ValueError(f"feature blocks must be 2-D, got {block.shape}")
        rows = block.shape[0]
        if rows:
            if self._tracer is not None and self._pending_rows == 0:
                # the flush span opens when the first frame starts waiting
                self._flush_t0 = self._tracer.clock()
            need = self._pending_rows + rows
            if self._buf is None or self._buf.shape[1] != block.shape[1]:
                cap = max(_MIN_BUFFER_ROWS, self.micro_batch, need)
                self._buf = np.empty((cap, block.shape[1]), np.float32)
            elif need > self._buf.shape[0]:
                grown = np.empty(
                    (max(need, 2 * self._buf.shape[0]), block.shape[1]),
                    np.float32,
                )
                grown[: self._pending_rows] = self._buf[: self._pending_rows]
                self._buf = grown
            self._buf[self._pending_rows : need] = block
            self._pending_rows = need
        self._next_step += rows

    def flush(self) -> List[StepDecision]:
        """Score everything pending (one fused-kernel call) and decide each
        frame in arrival order through the session policy."""
        return self._drain(self._pending_rows)

    def _drain(self, rows: int) -> List[StepDecision]:
        """Score the first ``rows`` pending frames as one batch and decide
        them in arrival order."""
        if rows <= 0 or not self._pending_rows:
            return []
        rows = min(rows, self._pending_rows)
        head = self._buf[:rows]
        prof = self._profiler
        # device scoring; one host conversion at the policy boundary (the
        # estimates are materialized before the buffer is compacted)
        if prof is None:
            estimates = np.asarray(
                self.engine.score_device(features=head), np.float64
            ).ravel()
        else:
            t0 = prof.begin()
            estimates = np.asarray(
                self.engine.score_device(features=head), np.float64
            ).ravel()
            prof.add("session.score", t0)
        rem = self._pending_rows - rows
        if rem:
            self._buf[:rem] = self._buf[rows : self._pending_rows].copy()
        self._pending_rows = rem
        if prof is None:
            return self._decide(estimates)
        t0 = prof.begin()
        out = self._decide(estimates)
        prof.add("session.decide", t0)
        return out

    def submit_scored(self, estimates: np.ndarray) -> List[StepDecision]:
        """Decide a block of already-scored frames in arrival order — the
        seam for fleet runtimes that score all streams centrally through the
        sharded data plane (``repro.fleet.plane``) and fan the estimates out
        to per-shard sessions.  Mixing with buffered unscored arrivals would
        let scored frames jump the queue, so pending rows must be flushed
        first."""
        if self._pending_rows:
            raise RuntimeError(
                f"submit_scored() with {self._pending_rows} unscored frames "
                "pending — flush() first"
            )
        est = np.asarray(estimates, np.float64).ravel()
        self._next_step += est.size
        return self._decide(est)

    def _decide(self, estimates: np.ndarray) -> List[StepDecision]:
        """Run already-scored estimates through the session policy in
        arrival order and account them in the telemetry."""
        if getattr(self.policy, "batch_budget", False):
            # a per-batch budget (topk) would make streaming decisions
            # depend on micro-batch/flush boundaries (and offload nothing
            # at micro_batch=1) — such policies keep the per-item
            # semantics of decide()
            offload = np.fromiter(
                (self.policy.decide(float(e)) for e in estimates),
                dtype=bool, count=len(estimates),
            )
        else:
            # decide_batch is buffer-invariant here: vectorized for
            # threshold, internally sequential for token_bucket
            offload = np.asarray(self.policy.decide_batch(estimates), bool)
        # the queue held exactly the arrivals not yet decided, so the drained
        # rows are the arrival indices trailing the still-pending ones
        first = self._next_step - self._pending_rows - len(estimates)
        n_off = int(offload.sum())
        self._processed.inc(len(estimates))
        self._offloaded.inc(n_off)
        self._estimate_sum.inc(float(estimates.sum()))
        self._window.extend(bool(o) for o in offload)
        if self._tracer is not None:
            now = self._tracer.clock()
            t0 = now if self._flush_t0 is None else self._flush_t0
            self._tracer.add_span(
                "session.flush", t0, now, tid=self._tid,
                args={"frames": len(estimates), "offloaded": n_off},
            )
            self._flush_t0 = now if self._pending_rows else None
        return [
            StepDecision(step=first + i, estimate=float(est), offload=bool(off))
            for i, (est, off) in enumerate(zip(estimates, offload))
        ]

    # --------------------------------------------------------------- control

    def set_ratio(self, ratio: float) -> None:
        """Mid-stream budget change — affects only this session's policy."""
        self._ratio = float(ratio)
        self.policy.set_ratio(self._ratio)

    def recalibrate(self, calibration_scores: Optional[np.ndarray] = None) -> None:
        """Refresh the session policy's calibration distribution mid-stream
        (closed-loop adaptation: the engine's scores just moved).  Stateful
        policies with a sorted ``_cal`` array (the netsim/video/online
        controllers) are patched in place so integral budget state survives;
        anything else is rebuilt with the same runtime wiring."""
        cal = (
            self.engine.calibration_scores
            if calibration_scores is None
            else calibration_scores
        )
        if cal is None:
            raise RuntimeError("recalibrate() with no calibration scores")
        sorted_cal = np.sort(np.asarray(cal, np.float64))
        if hasattr(self.policy, "_cal"):
            self.policy._cal = sorted_cal
        else:
            self.policy = make_policy(
                self.engine.policy_name,
                sorted_cal,
                self._ratio,
                **self._policy_build_kwargs,
            )

    @property
    def ratio(self) -> float:
        return self._ratio

    def record_reward(self, reward: float) -> None:
        """Account a realized per-frame reward (e.g. observed quality delta)
        into the session telemetry."""
        self._reward_sum.inc(float(reward))
        self._rewards_recorded.inc()

    def record_staleness(self, staleness: float) -> None:
        """Account one frame served from a propagated (stale) edge result;
        ``staleness`` is the age of that result in frames."""
        self._staleness_sum.inc(float(staleness))
        self._covered_frames.inc()

    def record_effective_accuracy(self, accuracy: float) -> None:
        """Account one frame's effective accuracy — the AP of whatever was
        actually served for it (weak output or propagated edge result)."""
        self._accuracy_sum.inc(float(accuracy))
        self._effective_frames.inc()

    def record_rtt(self, rtt: float) -> None:
        """Account one completed offload's measured round trip."""
        self._rtt.observe(float(rtt))

    def record_bandwidth(self, bandwidth: float) -> None:
        """Account one measured uplink goodput sample (bits per time unit)."""
        self._bandwidth_sum.inc(float(bandwidth))
        self._bandwidth_samples.inc()

    def record_update(self) -> None:
        """Account one closed-loop model update visible to this stream."""
        self._online_updates.inc()

    def record_budget_share(self, share: float) -> None:
        """Stamp the stream's current share of the fleet-wide offload
        budget (see :class:`repro.fleet.budget.FleetBudget`)."""
        self._budget_share.set(float(share))

    def record_redistribution(self) -> None:
        """Account one fleet budget redistribution applied to this stream."""
        self._budget_redistributions.inc()

    def record_handover(self) -> None:
        """Account one mid-stream edge migration (serving edge changed)."""
        self._handovers.inc()

    def record_coverage(self, dbm: float) -> None:
        """Account one received-signal-strength sample from the stream's
        serving base station (dBm; see :mod:`repro.mobility.coverage`)."""
        self._coverage_sum.inc(float(dbm))
        self._coverage_samples.inc()
        self._coverage_dbm.set(float(dbm))

    # ------------------------------------------------------------- telemetry

    @property
    def telemetry(self) -> SessionTelemetry:
        # a *view* over the metric instruments: every field derives from
        # instrument state the same way the old scalar counters did, so
        # payloads are byte-stable with observability on, off, or absent
        n = self._processed.value
        offloaded = self._offloaded.value
        covered = self._covered_frames.value
        effective = self._effective_frames.value
        bw_samples = self._bandwidth_samples.value
        roll = list(self._window)
        return SessionTelemetry(
            processed=n,
            offloaded=offloaded,
            realized_ratio=offloaded / n if n else 0.0,
            rolling_ratio=float(np.mean(roll)) if roll else 0.0,
            mean_estimate=self._estimate_sum.value / n if n else 0.0,
            target_ratio=self._ratio,
            pending=self._pending_rows,
            reward_sum=float(self._reward_sum.value),
            rewards_recorded=self._rewards_recorded.value,
            covered_frames=covered,
            mean_staleness=(
                self._staleness_sum.value / covered if covered else 0.0
            ),
            effective_frames=effective,
            mean_effective_accuracy=(
                self._accuracy_sum.value / effective if effective else 0.0
            ),
            rtt_samples=self._rtt.n,
            mean_rtt=self._rtt.mean,
            bandwidth_samples=bw_samples,
            mean_bandwidth=(
                self._bandwidth_sum.value / bw_samples if bw_samples else 0.0
            ),
            online_updates=self._online_updates.value,
            budget_share=float(self._budget_share.value),
            budget_redistributions=self._budget_redistributions.value,
            handovers=self._handovers.value,
            coverage_samples=self._coverage_samples.value,
            mean_coverage_dbm=(
                self._coverage_sum.value / self._coverage_samples.value
                if self._coverage_samples.value else 0.0
            ),
        )
