"""Modality frontend STUBS for the [audio] and [vlm] architectures.

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
(whisper) and the ViT vision encoder + projector (qwen2-vl) are NOT
implemented; these helpers produce precomputed frame/patch embeddings of
the correct shape that the language/decoder transformer consumes.
"""
from __future__ import annotations

import numpy as np


def audio_frame_embeddings(
    rng: np.random.Generator, batch: int, frames: int, d_model: int
) -> np.ndarray:
    """Whisper-style encoder input: (batch, frames, d_model) float32 —
    stands in for conv1/conv2(mel) output (frames = samples/320)."""
    t = np.linspace(0, 1, frames)[None, :, None]
    base = np.sin(2 * np.pi * (1 + np.arange(d_model)[None, None, :] % 7) * t)
    noise = rng.normal(0, 0.1, (batch, frames, d_model))
    return (0.5 * base + noise).astype(np.float32)


def vision_patch_embeddings(
    rng: np.random.Generator, batch: int, patches: int, d_model: int
) -> np.ndarray:
    """Qwen2-VL-style projected vision tokens: (batch, patches, d_model) —
    stands in for ViT(dynamic-resolution image) + MLP projector output."""
    return rng.normal(0, 1.0, (batch, patches, d_model)).astype(np.float32)
