"""Synthetic LM token streams for smoke tests and the end-to-end driver.

Markov-chain token synthesis with a power-law unigram prior — enough
structure that a ~100M model's loss visibly decreases over a few hundred
steps, while remaining fully offline and deterministic.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def synth_lm_batch(
    rng: np.random.Generator,
    batch: int,
    seq_len: int,
    vocab: int,
    order: int = 1,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (tokens, labels) each (batch, seq_len) int32; labels are
    tokens shifted left with -1 padding in the last position."""
    # power-law unigram over an effective sub-vocabulary for structure
    eff = min(vocab, 4096)
    ranks = np.arange(1, eff + 1, dtype=np.float64)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    toks = rng.choice(eff, size=(batch, seq_len), p=probs).astype(np.int64)
    # inject local structure: with prob 0.5 copy previous token + fixed offset
    copy = rng.uniform(size=(batch, seq_len)) < 0.5
    for t in range(1, seq_len):
        toks[:, t] = np.where(
            copy[:, t], (toks[:, t - 1] * 31 + 7) % eff, toks[:, t]
        )
    labels = np.full_like(toks, -1)
    labels[:, :-1] = toks[:, 1:]
    return toks.astype(np.int32), labels.astype(np.int32)
