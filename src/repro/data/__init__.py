"""Data pipelines: procedural detection dataset, LM token synthesis, and
modality-frontend stubs for the [audio]/[vlm] architectures."""
from repro.data.shapes import ShapesDataset, render_image
from repro.data.lm_synth import synth_lm_batch
from repro.data.modality_stubs import audio_frame_embeddings, vision_patch_embeddings

__all__ = [
    "ShapesDataset",
    "render_image",
    "synth_lm_batch",
    "audio_frame_embeddings",
    "vision_patch_embeddings",
]
