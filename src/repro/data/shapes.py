"""Procedural object-detection dataset: coloured shapes on textured noise.

COCO/VOC are unavailable offline, so the detection repro trains on this
generator.  8 classes = {rectangle, ellipse, triangle, cross} × {warm,
cool} colour families; 1–6 objects per 64×64 image, sizes 10–30 px, mild
occlusion, per-object colour jitter, background = low-frequency noise.
Ground truth boxes are exact.  The generator is deterministic in its seed
(train/val splits use disjoint seed streams).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.detection.map_engine import Detections, GroundTruth

NUM_CLASSES = 8
IMAGE_SIZE = 64
CLASS_NAMES = [
    "rect_warm", "rect_cool", "ellipse_warm", "ellipse_cool",
    "tri_warm", "tri_cool", "cross_warm", "cross_cool",
]

_WARM = np.array([[0.9, 0.3, 0.2], [0.95, 0.6, 0.1], [0.85, 0.2, 0.5]])
_COOL = np.array([[0.2, 0.4, 0.9], [0.1, 0.8, 0.7], [0.4, 0.2, 0.85]])


def _background(rng: np.random.Generator, size: int) -> np.ndarray:
    base = rng.uniform(0.1, 0.45, (1, 1, 3))
    lowfreq = rng.normal(0, 1, (size // 8, size // 8, 3))
    lowfreq = np.kron(lowfreq, np.ones((8, 8, 1)))
    noise = rng.normal(0, 0.02, (size, size, 3))
    img = base + 0.05 * lowfreq + noise
    return np.clip(img, 0, 1).astype(np.float32)


def _shape_mask(kind: int, h: int, w: int, rng: np.random.Generator) -> np.ndarray:
    yy, xx = np.mgrid[0:h, 0:w]
    cy, cx = (h - 1) / 2, (w - 1) / 2
    if kind == 0:  # rectangle
        return np.ones((h, w), dtype=bool)
    if kind == 1:  # ellipse
        return ((yy - cy) / (h / 2)) ** 2 + ((xx - cx) / (w / 2)) ** 2 <= 1.0
    if kind == 2:  # triangle (apex up)
        frac = yy / max(h - 1, 1)
        half = frac * (w / 2)
        return np.abs(xx - cx) <= half
    # cross
    tw = max(w // 3, 2)
    th = max(h // 3, 2)
    return (np.abs(xx - cx) <= tw / 2) | (np.abs(yy - cy) <= th / 2)


def paint_object(
    img: np.ndarray,
    box: Sequence[float],
    cls: int,
    colour: np.ndarray,
    rng: np.random.Generator,
) -> None:
    """Draw one shape (class ``cls = kind * 2 + warm``) into ``img`` in
    place, clipped to the image bounds.  Shared by the static generator and
    the video scene renderer (:mod:`repro.video.scene`)."""
    size = img.shape[0]
    x1, y1 = max(int(round(box[0])), 0), max(int(round(box[1])), 0)
    x2, y2 = min(int(round(box[2])), size), min(int(round(box[3])), size)
    w, h = x2 - x1, y2 - y1
    if w <= 0 or h <= 0:
        return
    mask = _shape_mask(int(cls) // 2, h, w, rng)
    patch = img[y1:y2, x1:x2]
    patch[mask] = np.clip(colour, 0, 1)
    img[y1:y2, x1:x2] = patch


def class_colour(cls: int, rng: np.random.Generator) -> np.ndarray:
    """A jittered colour sample from the class's warm/cool palette."""
    palette = _WARM if int(cls) % 2 == 0 else _COOL
    return palette[rng.integers(0, len(palette))] + rng.normal(0, 0.05, 3)


def render_image(
    rng: np.random.Generator, size: int = IMAGE_SIZE, max_objects: int = 6
) -> Tuple[np.ndarray, GroundTruth]:
    """One image + exact ground truth."""
    img = _background(rng, size)
    n = int(rng.integers(1, max_objects + 1))
    boxes: List[List[float]] = []
    classes: List[int] = []
    for _ in range(n):
        kind = int(rng.integers(0, 4))
        warm = int(rng.integers(0, 2))
        cls = kind * 2 + warm
        w = int(rng.integers(10, 31))
        h = int(rng.integers(10, 31))
        x1 = int(rng.integers(0, size - w))
        y1 = int(rng.integers(0, size - h))
        paint_object(img, [x1, y1, x1 + w, y1 + h], cls, class_colour(cls, rng), rng)
        boxes.append([x1, y1, x1 + w, y1 + h])
        classes.append(cls)
    gt = GroundTruth(np.array(boxes, dtype=np.float64), np.array(classes))
    return img, gt


@dataclass
class ShapesDataset:
    """Materialised split of the procedural dataset."""

    images: np.ndarray  # (N, S, S, 3) float32
    gts: List[GroundTruth]

    @classmethod
    def generate(
        cls, n: int, seed: int, size: int = IMAGE_SIZE, max_objects: int = 6
    ) -> "ShapesDataset":
        rng = np.random.default_rng(seed)
        imgs, gts = [], []
        for _ in range(n):
            img, gt = render_image(rng, size, max_objects)
            imgs.append(img)
            gts.append(gt)
        return cls(np.stack(imgs), gts)

    def __len__(self) -> int:
        return self.images.shape[0]

    def batches(self, batch_size: int, rng: np.random.Generator):
        """Yield (images, target arrays) minibatches, shuffled; targets are
        padded to ``max_objects`` with class -1."""
        n = len(self)
        perm = rng.permutation(n)
        max_obj = max(len(g) for g in self.gts)
        for s in range(0, n - batch_size + 1, batch_size):
            idx = perm[s : s + batch_size]
            imgs = self.images[idx]
            boxes = np.zeros((batch_size, max_obj, 4), dtype=np.float32)
            classes = np.full((batch_size, max_obj), -1, dtype=np.int32)
            for bi, i in enumerate(idx):
                g = self.gts[i]
                m = len(g)
                boxes[bi, :m] = g.boxes
                classes[bi, :m] = g.classes
            yield imgs, boxes, classes
