"""Mid-stream edge migration: the hysteresis trigger and in-flight semantics.

A moving client's serving station is chosen by the classic A3-style rule:
hand over when some *other* station's signal beats the serving one by at
least ``hysteresis_db`` — and only after ``min_dwell`` time units on the
current server, so a client skirting a cell boundary doesn't ping-pong.
The controller is pure bookkeeping over signals the :class:`CoverageMap`
computes; it never touches edges itself.

What happens to offloads **in flight** on the old edge is configurable —
the three semantics the acceptance tests pin (see docs/API.md for the
table):

- ``"survive"`` — make-before-break: results complete on the old edge and
  are delivered normally (the old downlink still reaches the client).
- ``"die"``     — break-before-make: the old edge's in-flight work for this
  stream is cancelled (:meth:`EdgeWorker.cancel_steps`); those frames'
  results never arrive and their coverage is lost.
- ``"stale"``   — results survive but arrive aged by ``stale_penalty``
  frames (forwarded through the core network after the radio drops), so
  the video staleness machinery discounts them on delivery.

The *application* of these semantics lives in the runtime that owns the
pending-results ledger (:class:`repro.mobility.runtime.MobileRuntime`);
:func:`apply_in_flight` is the shared implementation so tests can drive it
directly against a hand-built ledger.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.mobility.coverage import CoverageMap

IN_FLIGHT = ("survive", "die", "stale")


@dataclass(frozen=True)
class HandoverEvent:
    """One executed migration, stamped in simulation time."""

    t: float
    source: int
    target: int
    rss_source: float
    rss_target: float

    def as_dict(self) -> Dict[str, Any]:
        return {
            "t": self.t,
            "source": self.source,
            "target": self.target,
            "rss_source": self.rss_source,
            "rss_target": self.rss_target,
        }


@dataclass
class PendingResult:
    """One offloaded frame whose result has not been delivered yet — the
    runtime's ledger entry the in-flight semantics operate on."""

    t_done: float        # simulation time the result reaches the client
    capture_step: int    # frame index the result covers from
    step: int            # dispatch step id (for cancel_steps)
    edge: int            # fleet index serving the offload


class HandoverController:
    """Per-stream serving-station state machine.

    Parameters
    ----------
    coverage : CoverageMap
    hysteresis_db : float
        Margin a challenger must beat the serving signal by.
    min_dwell : float
        Minimum time between handovers (simulation time units).
    in_flight : str
        One of :data:`IN_FLIGHT`; what the runtime does to the old edge's
        outstanding results at the moment of migration.
    stale_penalty : int
        Frames of extra staleness under the ``"stale"`` semantics.
    """

    def __init__(
        self,
        coverage: CoverageMap,
        *,
        hysteresis_db: float = 4.0,
        min_dwell: float = 8.0,
        in_flight: str = "survive",
        stale_penalty: int = 4,
    ):
        if in_flight not in IN_FLIGHT:
            raise KeyError(
                f"unknown in-flight semantics {in_flight!r}; have {list(IN_FLIGHT)}"
            )
        if hysteresis_db < 0 or min_dwell < 0 or stale_penalty < 0:
            raise ValueError("hysteresis_db, min_dwell, stale_penalty must be >= 0")
        self.coverage = coverage
        self.hysteresis_db = float(hysteresis_db)
        self.min_dwell = float(min_dwell)
        self.in_flight = in_flight
        self.stale_penalty = int(stale_penalty)
        self.serving: Optional[int] = None
        self.last_rss = float("nan")
        self.events: List[HandoverEvent] = []
        self._attached_at = -np.inf

    def update(self, now: float, pos: np.ndarray) -> Optional[HandoverEvent]:
        """Observe the signal at ``pos``; attach on first call (not counted
        as a handover), migrate when the hysteresis rule fires.  Returns
        the event when one fired, else ``None``."""
        rss = self.coverage.rss(np.asarray(pos, np.float64))
        if self.serving is None:
            self.serving = int(np.argmax(rss))
            self.last_rss = float(rss[self.serving])
            self._attached_at = float(now)
            return None
        best = int(np.argmax(rss))
        self.last_rss = float(rss[self.serving])
        if (
            best != self.serving
            and float(rss[best]) - float(rss[self.serving]) > self.hysteresis_db
            and float(now) - self._attached_at >= self.min_dwell
        ):
            ev = HandoverEvent(
                t=float(now),
                source=self.serving,
                target=best,
                rss_source=float(rss[self.serving]),
                rss_target=float(rss[best]),
            )
            self.events.append(ev)
            self.serving = best
            self.last_rss = float(rss[best])
            self._attached_at = float(now)
            return ev
        return None

    def spec(self) -> Dict[str, Any]:
        return {
            "hysteresis_db": self.hysteresis_db,
            "min_dwell": self.min_dwell,
            "in_flight": self.in_flight,
            "stale_penalty": self.stale_penalty,
        }


def apply_in_flight(
    pending: List[PendingResult],
    event: HandoverEvent,
    mode: str,
    *,
    stale_penalty: int = 4,
    edges: Optional[Any] = None,
) -> Tuple[List[PendingResult], int]:
    """Apply one migration's in-flight semantics to a stream's pending
    ledger.  Returns ``(new_ledger, n_affected)`` where affected means
    cancelled (``die``) or aged (``stale``).  With ``edges`` (the fleet
    list), ``die`` also cancels the jobs on the old
    :class:`~repro.runtime.edge.EdgeWorker` so its in-flight slots free up
    — exactly the accounting a dropped radio bearer implies."""
    if mode not in IN_FLIGHT:
        raise KeyError(f"unknown in-flight semantics {mode!r}; have {list(IN_FLIGHT)}")
    hit = [p for p in pending if p.edge == event.source]
    if mode == "survive" or not hit:
        return list(pending), 0
    if mode == "die":
        if edges is not None:
            edges[event.source].cancel_steps({p.step for p in hit})
        return [p for p in pending if p.edge != event.source], len(hit)
    # stale: results are tunneled through the core network after the radio
    # drops — they arrive, but older: ageing the capture step by the
    # penalty is exactly how the video staleness machinery will see it
    for p in hit:
        p.capture_step -= int(stale_penalty)
    return list(pending), len(hit)
