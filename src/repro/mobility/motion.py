"""Seeded 2-D client mobility traces.

Two standard models, both drawing every random number up front from one
``numpy`` generator so a trace is a pure function of ``(config, n_clients,
n_steps, seed)``:

- ``random_walk`` — heading follows a seeded Gaussian turn process; the
  client advances ``speed * dt`` per step and is clamped to the area.
- ``waypoint`` — the classic random-waypoint model: the client heads for a
  seeded target at constant speed, switching to the next target the step
  it would arrive.

The rollout runs as ONE jitted ``jax.lax.scan`` over the T steps
(:func:`rollout`), with a pure-Python/numpy reference oracle
(:func:`rollout_ref`) that consumes the *same* pre-drawn arrays, mirroring
the ``track_clip`` / ``track_clip_ref`` pairing in :mod:`repro.video.track`.
Because all randomness is materialized before either path runs, the two
agree to float32 rounding (tested), and two calls with equal seeds are
bit-identical — the property the handover acceptance test pins.

Positions are float32 ``(T, n_clients, 2)``; entry ``[t]`` is where each
client is while frame ``t`` is captured (the initial placement is row 0;
motion happens between frames).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MODELS = ("waypoint", "random_walk")


@dataclass(frozen=True)
class MotionConfig:
    """Geometry + kinematics of a client population.

    Parameters
    ----------
    model : str
        ``"waypoint"`` or ``"random_walk"``.
    area : (float, float)
        Width/height of the rectangular world (same distance units as
        base-station placements in :mod:`repro.mobility.coverage`).
    speed : float
        Distance covered per time unit (every client moves every step).
    dt : float
        Simulation step length in time units (one frame period).
    turn_sigma : float
        Random-walk only: stddev of the per-step heading change (radians).
    """

    model: str = "waypoint"
    area: Tuple[float, float] = (1000.0, 1000.0)
    speed: float = 12.0
    dt: float = 1.0
    turn_sigma: float = 0.35

    def __post_init__(self) -> None:
        if self.model not in MODELS:
            raise KeyError(f"unknown motion model {self.model!r}; have {MODELS}")
        if self.speed < 0 or self.dt <= 0:
            raise ValueError(f"need speed >= 0 and dt > 0, got {self.speed}, {self.dt}")
        if self.area[0] <= 0 or self.area[1] <= 0:
            raise ValueError(f"area must be positive, got {self.area}")

    def spec(self) -> Dict[str, float]:
        return {
            "model": self.model,
            "area": list(self.area),
            "speed": self.speed,
            "dt": self.dt,
            "turn_sigma": self.turn_sigma,
        }


def _draws(
    config: MotionConfig, n_clients: int, n_steps: int, seed: int
) -> Dict[str, np.ndarray]:
    """Materialize every random number the rollout will consume — shared
    verbatim by the scan and the reference, so the only difference between
    the two paths is the arithmetic backend."""
    if n_clients < 1 or n_steps < 1:
        raise ValueError(f"need n_clients, n_steps >= 1, got {n_clients}, {n_steps}")
    rng = np.random.default_rng(seed)
    w, h = config.area
    scale = np.array([w, h], np.float32)
    out = {"pos0": (rng.random((n_clients, 2)).astype(np.float32)) * scale}
    if config.model == "random_walk":
        out["heading0"] = (rng.random(n_clients) * (2 * np.pi)).astype(np.float32)
        out["turns"] = rng.normal(
            0.0, config.turn_sigma, (n_steps - 1, n_clients)
        ).astype(np.float32)
    else:
        # one fresh target per (step, client) is a strict upper bound on
        # consumption: a client reaches at most one waypoint per step
        out["targets"] = (
            rng.random((n_steps, n_clients, 2)).astype(np.float32) * scale
        )
    return out


@partial(jax.jit, static_argnames=("model",))
def _rollout_scan(draws: Dict[str, jnp.ndarray], model: str, step_len, lim):
    """One ``lax.scan`` over the T-1 motion steps; carries (pos[, heading
    or waypoint index])."""
    pos0 = draws["pos0"]
    n = pos0.shape[0]
    if model == "random_walk":
        def step(carry, turn):
            pos, heading = carry
            heading = heading + turn
            delta = step_len * jnp.stack(
                [jnp.cos(heading), jnp.sin(heading)], axis=-1
            )
            pos = jnp.clip(pos + delta, 0.0, lim)
            return (pos, heading), pos

        (_, _), path = jax.lax.scan(
            step, (pos0, draws["heading0"]), draws["turns"]
        )
    else:
        targets = draws["targets"]
        t_max = targets.shape[0] - 1
        idx0 = jnp.zeros(n, jnp.int32)

        def step(carry, _):
            pos, idx = carry
            tgt = targets[idx, jnp.arange(n)]
            d = tgt - pos
            dist = jnp.sqrt(jnp.sum(d * d, axis=-1))
            reach = dist <= step_len
            safe = jnp.maximum(dist, jnp.float32(1e-12))
            pos = jnp.where(
                reach[:, None], tgt, pos + d * (step_len / safe)[:, None]
            )
            idx = jnp.minimum(idx + reach.astype(jnp.int32), t_max)
            return (pos, idx), pos

        (_, _), path = jax.lax.scan(
            step, (pos0, idx0), None, length=targets.shape[0] - 1
        )
    return jnp.concatenate([pos0[None], path], axis=0)


def rollout(
    config: MotionConfig, n_clients: int, n_steps: int, seed: int = 0
) -> np.ndarray:
    """Seeded positions ``(n_steps, n_clients, 2)`` via the jitted scan."""
    draws = _draws(config, n_clients, n_steps, seed)
    step_len = np.float32(config.speed * config.dt)
    lim = np.asarray(config.area, np.float32)
    return np.asarray(
        _rollout_scan(
            {k: jnp.asarray(v) for k, v in draws.items()},
            config.model, step_len, lim,
        )
    )


def rollout_ref(
    config: MotionConfig, n_clients: int, n_steps: int, seed: int = 0
) -> np.ndarray:
    """Pure-Python/numpy oracle over the same pre-drawn arrays — the
    reviewable spec the scan is tested against."""
    draws = _draws(config, n_clients, n_steps, seed)
    step_len = np.float32(config.speed * config.dt)
    lim = np.asarray(config.area, np.float32)
    pos = draws["pos0"].copy()
    path = [pos.copy()]
    if config.model == "random_walk":
        heading = draws["heading0"].copy()
        for t in range(n_steps - 1):
            heading = heading + draws["turns"][t]
            delta = step_len * np.stack(
                [np.cos(heading), np.sin(heading)], axis=-1
            ).astype(np.float32)
            pos = np.clip(pos + delta, 0.0, lim).astype(np.float32)
            path.append(pos.copy())
    else:
        targets = draws["targets"]
        t_max = targets.shape[0] - 1
        idx = np.zeros(n_clients, np.int32)
        for t in range(n_steps - 1):
            tgt = targets[idx, np.arange(n_clients)]
            d = tgt - pos
            dist = np.sqrt(np.sum(d * d, axis=-1), dtype=np.float32)
            reach = dist <= step_len
            safe = np.maximum(dist, np.float32(1e-12))
            stepped = (pos + d * (step_len / safe)[:, None]).astype(np.float32)
            pos = np.where(reach[:, None], tgt, stepped)
            idx = np.minimum(idx + reach.astype(np.int32), t_max)
            path.append(pos.copy())
    return np.stack(path, axis=0)
