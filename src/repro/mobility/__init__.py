"""Moving clients, coverage-dependent links, and mid-stream edge handover.

The paper's deployment story — embedded devices *in motion* offloading
detection to a fixed edge fleet — as a subsystem on the four documented
seams: seeded mobility traces rolled out as one jitted ``lax.scan``
(:mod:`repro.mobility.motion`), base-station placements with log-distance
path loss mapped onto the existing netsim links plus a priced downlink
(:mod:`repro.mobility.coverage`), hysteresis-triggered migration with
configurable in-flight semantics (:mod:`repro.mobility.handover`), the
``mobility_aware`` policy (registered in the ``repro.api`` registry), and
the :class:`MobileRuntime` driver tying them to the shared manual clock
(:mod:`repro.mobility.runtime`).  See docs/API.md "Mobility & handover".
"""
from repro.mobility.coverage import (
    NO_SIGNAL_DBM,
    BaseStation,
    CoverageMap,
    default_stations,
    station_fleet,
)
from repro.mobility.handover import (
    IN_FLIGHT,
    HandoverController,
    HandoverEvent,
    PendingResult,
    apply_in_flight,
)
from repro.mobility.motion import MODELS, MotionConfig, rollout, rollout_ref
from repro.mobility.policy import MobilityAwarePolicy
from repro.mobility.runtime import (
    MODES,
    MobileRuntime,
    MobileScenario,
    MobileStepRecord,
    MobileTrace,
    default_mobile_scenario,
    run_mobile_scenario,
)

__all__ = [
    "MODELS",
    "MotionConfig",
    "rollout",
    "rollout_ref",
    "NO_SIGNAL_DBM",
    "BaseStation",
    "CoverageMap",
    "default_stations",
    "station_fleet",
    "IN_FLIGHT",
    "HandoverController",
    "HandoverEvent",
    "PendingResult",
    "apply_in_flight",
    "MobilityAwarePolicy",
    "MODES",
    "MobileRuntime",
    "MobileScenario",
    "MobileStepRecord",
    "MobileTrace",
    "default_mobile_scenario",
    "run_mobile_scenario",
]
