"""``mobility_aware`` — the offload policy for clients in motion.

The quantile-budget rule with one mobility amendment: the reward estimate
is discounted by the client's predicted **time to coverage loss** (probed
via the runtime-injected ``coverage_ttl`` callable, wired by
:class:`repro.mobility.runtime.MobileRuntime` from the motion trace and
coverage map).  An offloaded frame only pays off if its result makes it
back before the client falls out of coverage; with the round trip taking
about ``rtt_horizon`` time units, a frame with ``ttl < rtt_horizon`` keeps
only ``ttl / rtt_horizon`` of its estimated reward.  Frames the discount
suppresses refund their budget through the same integral
:class:`~repro.api.policies.BudgetTracker` as the other stream policies,
so the realized ratio converges to the target — spent where the result
will actually be received.

Registered on import (see ``repro.api.policies._ensure_plugins``); without
a probe it collapses to plain quantile-threshold behaviour.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.api.policies import (
    BudgetTracker,
    decide_sequential,
    register_policy,
)


@register_policy("mobility_aware")
class MobilityAwarePolicy:
    """Quantile budget with coverage-lookahead reward discounting.

    Parameters (beyond the registry's ``calibration_scores, ratio``):

    rtt_horizon : float
        Time units an offload's result roughly takes to come back
        (uplink + service + downlink); the discount ramp's width.
    gain : float
        Integral gain of the budget tracker.
    coverage_ttl : callable or None
        Runtime-injected zero-arg probe of the predicted time to coverage
        loss (``inf`` when not leaving coverage).  Never serialized.
    """

    context_params = ("coverage_ttl",)

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        rtt_horizon: float = 6.0,
        gain: float = 0.05,
        coverage_ttl: Optional[Callable[[], float]] = None,
    ):
        if rtt_horizon <= 0:
            raise ValueError(f"rtt_horizon must be > 0, got {rtt_horizon}")
        self._cal = np.sort(np.asarray(calibration_scores, dtype=np.float64))
        self.rtt_horizon = float(rtt_horizon)
        self.coverage_ttl = coverage_ttl
        self._budget = BudgetTracker(gain)
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))

    def _discount(self) -> float:
        if self.coverage_ttl is None:
            return 1.0
        ttl = float(self.coverage_ttl())
        if not np.isfinite(ttl):
            return 1.0
        return float(np.clip(max(ttl, 0.0) / self.rtt_horizon, 0.0, 1.0))

    def decide(self, estimate: float) -> bool:
        e = float(estimate) * self._discount()
        off = bool(e > self._budget.threshold(self._cal, self.ratio))
        self._budget.account(off)
        return off

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # sequential: the live coverage probe and the integral budget both
        # evolve decision to decision
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, Any]:
        return {"rtt_horizon": self.rtt_horizon, "gain": self._budget.gain}
