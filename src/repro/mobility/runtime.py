"""`MobileRuntime` — moving clients served by a fixed edge fleet.

The paper's deployment picture with the clients finally in motion: N
embedded devices follow seeded :mod:`repro.mobility.motion` traces across
a field of base stations (:mod:`repro.mobility.coverage`), each streaming
frames through its own :class:`~repro.runtime.session.OffloadSession` on
the shared manual clock.  Every offload is priced by position — the frame's
effective uplink size is ``frame_bits / rate_factor(rss)`` on the serving
station's real netsim queue, and the result pays the station's downlink
before it counts.  A per-client :class:`HandoverController` migrates the
serving station mid-stream (``mode="handover"``) or pins the station
attached at t=0 for life (``mode="static"`` — the baseline the acceptance
test beats), with configurable in-flight semantics at each migration.

Effective accuracy follows the video machinery's convention: a delivered
result covers later frames at ``stale_decay ** staleness`` of its strong
accuracy (the same per-frame decay :class:`repro.video.track.VideoTracker`
applies when propagating stale detections), floored by the weak model the
device can always run locally; frames with no usable coverage serve weak.
Everything is seeded and manually clocked: two runs of a scenario are
record-for-record identical, including across handovers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.api.engine import OffloadEngine
from repro.mobility.coverage import CoverageMap, default_stations, station_fleet
from repro.mobility.handover import (
    HandoverController,
    HandoverEvent,
    PendingResult,
    apply_in_flight,
)
from repro.mobility.motion import MotionConfig, rollout
from repro.runtime.dispatch import OUTCOME_LOCAL, OUTCOME_OFFLOADED
from repro.runtime.edge import EdgeWorker
from repro.runtime.session import SessionTelemetry
from repro.runtime.simulate import OffloadRuntime

MODES = ("handover", "static")


@dataclass(frozen=True)
class MobileStepRecord:
    """One client-frame's story: decision, dispatch outcome, signal, and
    what was effectively served."""

    client: int
    step: int
    t: float
    estimate: float
    offload: bool
    outcome: str
    serving: int
    rss_dbm: float
    latency: Optional[float]
    source: str                      # "weak" | "edge"
    staleness: Optional[float]
    effective_accuracy: float

    def as_dict(self) -> Dict[str, Any]:
        return {
            "client": self.client,
            "step": self.step,
            "t": self.t,
            "estimate": self.estimate,
            "offload": self.offload,
            "outcome": self.outcome,
            "serving": self.serving,
            "rss_dbm": self.rss_dbm,
            "latency": self.latency,
            "source": self.source,
            "staleness": self.staleness,
            "effective_accuracy": self.effective_accuracy,
        }


@dataclass
class MobileTrace:
    """Everything one serve produced: the seeded positions, per-frame
    records, per-client telemetry and handover logs, dispatcher stats."""

    mode: str
    in_flight: str
    positions: np.ndarray                 # (T, n_clients, 2) float32
    records: List[MobileStepRecord]
    telemetry: List[SessionTelemetry]
    handovers: List[List[HandoverEvent]] = field(default_factory=list)
    dispatcher: Dict[str, Any] = field(default_factory=dict)

    def mean_effective_accuracy(self) -> float:
        return float(np.mean([r.effective_accuracy for r in self.records]))

    def realized_ratio(self) -> float:
        """Offload decisions over frames, pooled across clients."""
        dec = sum(t.processed for t in self.telemetry)
        off = sum(t.offloaded for t in self.telemetry)
        return off / dec if dec else 0.0

    def offloaded_fraction(self) -> float:
        """Frames an edge actually served (admitted, not degraded away)."""
        n = len(self.records)
        k = sum(r.outcome == OUTCOME_OFFLOADED for r in self.records)
        return k / n if n else 0.0

    def n_handovers(self) -> int:
        return sum(len(h) for h in self.handovers)

    def summary(self) -> Dict[str, Any]:
        return {
            "mode": self.mode,
            "in_flight": self.in_flight,
            "clients": len(self.telemetry),
            "steps": len(self.records) // max(len(self.telemetry), 1),
            "mean_effective_accuracy": self.mean_effective_accuracy(),
            "realized_ratio": self.realized_ratio(),
            "offloaded_fraction": self.offloaded_fraction(),
            "handovers": self.n_handovers(),
            "telemetry": [
                t.as_dict(include_video=True, include_mobility=True)
                for t in self.telemetry
            ],
            "dispatcher": self.dispatcher,
        }


class MobileRuntime:
    """Drive moving clients against a fixed station fleet.

    Parameters
    ----------
    engine : OffloadEngine
        Fitted artifact; each client gets its own session over it.
    coverage : CoverageMap
        Station placements + radio model; must match ``edges`` order.
    edges : sequence of EdgeWorker or None
        One edge per station (defaults to ``station_fleet(coverage)``).
    motion : MotionConfig
        Client kinematics; the trace is rolled out once, seeded.
    mode : str
        ``"handover"`` (hysteresis migration) or ``"static"`` (pin the
        t=0 attachment for life).
    in_flight : str
        ``"survive"`` / ``"die"`` / ``"stale"`` — what a migration does to
        results outstanding on the old edge.
    hysteresis_db, min_dwell, stale_penalty :
        Forwarded to each client's :class:`HandoverController`.
    stale_decay : float
        Per-frame decay of a propagated result's accuracy (the video
        tracker's ``stale_decay`` convention).
    stale_horizon : int
        Frames after which a result stops covering at all.
    frame_bits : float
        Nominal uplink frame size at full signal.
    ttl_horizon : int
        Lookahead (steps) of the ``coverage_ttl`` probe wired into
        ``mobility_aware`` sessions.
    """

    def __init__(
        self,
        engine: OffloadEngine,
        coverage: CoverageMap,
        edges: Optional[Sequence[EdgeWorker]] = None,
        *,
        motion: Optional[MotionConfig] = None,
        mode: str = "handover",
        in_flight: str = "survive",
        hysteresis_db: float = 4.0,
        min_dwell: float = 8.0,
        stale_penalty: int = 4,
        stale_decay: float = 0.9,
        stale_horizon: int = 12,
        frame_bits: float = 1.0,
        ttl_horizon: int = 64,
        strategy: str = "least_loaded",
        seed: int = 0,
        obs: Optional[Any] = None,
    ):
        if mode not in MODES:
            raise KeyError(f"unknown mode {mode!r}; have {list(MODES)}")
        self.coverage = coverage
        fleet = list(edges) if edges is not None else station_fleet(
            coverage, seed=seed
        )
        if len(fleet) != len(coverage.stations):
            raise ValueError(
                f"{len(fleet)} edges for {len(coverage.stations)} stations"
            )
        self.rt = OffloadRuntime(
            engine, fleet, strategy=strategy, on_saturation="degrade",
            seed=seed, obs=obs,
        )
        self.motion = motion if motion is not None else MotionConfig()
        self.mode = mode
        self.in_flight = in_flight
        self.hysteresis_db = float(hysteresis_db)
        self.min_dwell = float(min_dwell)
        self.stale_penalty = int(stale_penalty)
        self.stale_decay = float(stale_decay)
        self.stale_horizon = int(stale_horizon)
        self.frame_bits = float(frame_bits)
        self.ttl_horizon = int(ttl_horizon)
        self.seed = int(seed)

    # ----------------------------------------------------------------- serve

    def serve(
        self,
        features: np.ndarray,     # (T, n_clients, F)
        weak_acc: np.ndarray,     # (T, n_clients)
        strong_acc: np.ndarray,   # (T, n_clients)
        *,
        ratio: Optional[float] = None,
        positions: Optional[np.ndarray] = None,
    ) -> MobileTrace:
        """One deterministic end-to-end serve of ``T`` frames from each of
        ``n_clients`` moving devices.  ``positions`` (T, n, 2) overrides
        the seeded rollout (tests pin traces with it)."""
        x = np.asarray(features, np.float32)
        if x.ndim != 3:
            raise ValueError(f"features must be (T, n_clients, F), got {x.shape}")
        T, n, _ = x.shape
        wa = np.broadcast_to(np.asarray(weak_acc, np.float64), (T, n))
        sa = np.broadcast_to(np.asarray(strong_acc, np.float64), (T, n))
        pos = (
            np.asarray(positions, np.float32)
            if positions is not None
            else rollout(self.motion, n, T, self.seed)
        )
        if pos.shape != (T, n, 2):
            raise ValueError(f"positions must be {(T, n, 2)}, got {pos.shape}")

        clock = self.rt.clock
        dispatcher = self.rt.dispatcher
        dt = self.motion.dt
        cur = [0] * n

        def ttl_probe(c: int):
            return lambda: self.coverage.time_to_loss(
                pos[:, c], cur[c], dt=dt, horizon=self.ttl_horizon
            )

        sessions = [
            self.rt.open_session(
                ratio=ratio, micro_batch=1, coverage_ttl=ttl_probe(c),
                name=f"client{c}", tid=c + 1,
            )
            for c in range(n)
        ]
        controllers = [
            HandoverController(
                self.coverage,
                hysteresis_db=self.hysteresis_db,
                min_dwell=self.min_dwell,
                in_flight=self.in_flight,
                stale_penalty=self.stale_penalty,
            )
            for _ in range(n)
        ]
        pending: List[List[PendingResult]] = [[] for _ in range(n)]
        newest: List[Optional[int]] = [None] * n   # newest delivered capture step
        handovers: List[List[HandoverEvent]] = [[] for _ in range(n)]
        records: List[MobileStepRecord] = []
        frame_rows: List[List[Dict[str, Any]]] = [[] for _ in range(n)]

        for t in range(T):
            now = clock()
            for c in range(n):
                cur[c] = t
                ctrl = controllers[c]
                if ctrl.serving is None:
                    ctrl.update(now, pos[t, c])       # initial attachment
                elif self.mode == "handover":
                    ev = ctrl.update(now, pos[t, c])
                    if ev is not None:
                        pending[c], _ = apply_in_flight(
                            pending[c], ev, ctrl.in_flight,
                            stale_penalty=ctrl.stale_penalty,
                            edges=dispatcher.edges,
                        )
                        handovers[c].append(ev)
                        sessions[c].record_handover()
                else:
                    # static pinning still *observes* the decaying signal
                    ctrl.last_rss = float(
                        self.coverage.rss(pos[t, c])[ctrl.serving]
                    )
                serving, rss = ctrl.serving, ctrl.last_rss
                sessions[c].record_coverage(rss)
                (d,) = sessions[c].submit(features=x[t, c])
                outcome, latency = OUTCOME_LOCAL, None
                if d.offload:
                    res = dispatcher.dispatch(
                        now,
                        d.step * n + c,   # fleet-unique step id per client
                        d.estimate,
                        prefer=serving,
                        pin=True,
                        size_bits=self.frame_bits
                        / self.coverage.rate_factor(rss),
                    )
                    outcome, latency = res.outcome, res.latency
                    if res.outcome == OUTCOME_OFFLOADED:
                        sessions[c].record_rtt(res.latency)
                        pending[c].append(
                            PendingResult(
                                t_done=now + res.latency,
                                capture_step=t,
                                step=d.step * n + c,
                                edge=serving,
                            )
                        )
                frame_rows[c].append(
                    {
                        "estimate": d.estimate, "offload": d.offload,
                        "outcome": outcome, "latency": latency,
                        "serving": serving, "rss": rss, "t": now,
                    }
                )

            # deliveries land, then frame t is scored with what the client
            # actually holds (a result offloaded at t arrives strictly later)
            for c in range(n):
                still: List[PendingResult] = []
                for p in pending[c]:
                    if p.t_done <= now:
                        if newest[c] is None or p.capture_step > newest[c]:
                            newest[c] = p.capture_step
                    else:
                        still.append(p)
                pending[c] = still
                row = frame_rows[c][t]
                source, staleness = "weak", None
                acc = float(wa[t, c])
                if newest[c] is not None:
                    s = t - newest[c]
                    if 0 <= s <= self.stale_horizon:
                        covered = float(
                            sa[max(newest[c], 0), c]
                        ) * self.stale_decay ** s
                        if covered > acc:
                            source, staleness, acc = "edge", float(s), covered
                            sessions[c].record_staleness(float(s))
                sessions[c].record_effective_accuracy(acc)
                records.append(
                    MobileStepRecord(
                        client=c, step=t, t=row["t"],
                        estimate=float(row["estimate"]),
                        offload=bool(row["offload"]),
                        outcome=row["outcome"],
                        serving=int(row["serving"]),
                        rss_dbm=float(row["rss"]),
                        latency=row["latency"],
                        source=source, staleness=staleness,
                        effective_accuracy=float(acc),
                    )
                )
            clock.advance(dt)

        dispatcher.poll(clock())
        records.sort(key=lambda r: (r.step, r.client))
        return MobileTrace(
            mode=self.mode,
            in_flight=self.in_flight,
            positions=pos,
            records=records,
            telemetry=[s.telemetry for s in sessions],
            handovers=handovers,
            dispatcher=dispatcher.stats(),
        )


# --------------------------------------------------------------- scenario


@dataclass
class MobileScenario:
    """A fitted engine + seeded synthetic mobile workload, reusable across
    modes so comparisons are equal-everything-but-the-dispatcher."""

    engine: OffloadEngine
    motion: MotionConfig
    coverage: CoverageMap
    features: np.ndarray      # (T, n_clients, F)
    weak_acc: np.ndarray      # (T, n_clients)
    strong_acc: np.ndarray    # (T, n_clients)
    seed: int

    def fleet(self, **kwargs: Any) -> List[EdgeWorker]:
        kwargs.setdefault("seed", self.seed)
        return station_fleet(self.coverage, **kwargs)


def _synth_frames(T: int, n: int, rng: np.random.Generator):
    """Seeded per-frame features with a planted reward direction: the
    strong model's edge over the weak one loads on feature 0, so a fitted
    estimator has signal to rank frames by."""
    x = rng.normal(0.0, 1.0, (T, n, 8)).astype(np.float32)
    gain = 0.45 / (1.0 + np.exp(-1.6 * x[..., 0].astype(np.float64)))
    weak = np.clip(0.35 + 0.06 * rng.normal(size=(T, n)), 0.1, 0.8)
    strong = np.clip(weak + gain, 0.0, 0.98)
    return x, weak, strong


def default_mobile_scenario(
    n_clients: int = 4,
    n_steps: int = 160,
    *,
    n_stations: int = 3,
    seed: int = 0,
    ratio: float = 0.35,
    policy: str = "mobility_aware",
    estimator_epochs: int = 12,
    area: tuple = (1200.0, 600.0),
    speed: float = 14.0,
) -> MobileScenario:
    """The seeded corridor scenario: ``n_stations`` stations along the
    midline of a wide area, waypoint clients crossing cells.  The engine is
    fitted on a disjoint calibration draw against the TRUE reward
    (strong minus weak accuracy), then switched to ``policy``."""
    from repro.api.reward_model import MLPRewardModel
    from repro.core.estimator import EstimatorConfig

    rng = np.random.default_rng(seed)
    cal_x, cal_w, cal_s = _synth_frames(64, 4, rng)
    engine = OffloadEngine(
        reward_model=MLPRewardModel(
            config=EstimatorConfig(
                hidden=(16,), epochs=estimator_epochs, batch_size=64, seed=seed
            )
        ),
        ratio=ratio,
    )
    engine.fit(
        features=cal_x.reshape(-1, cal_x.shape[-1]),
        rewards=(cal_s - cal_w).ravel(),
    )
    if policy is not None and policy != engine.policy_name:
        engine = engine.with_policy(policy, ratio=ratio)
    x, weak, strong = _synth_frames(n_steps, n_clients, rng)
    return MobileScenario(
        engine=engine,
        motion=MotionConfig(model="waypoint", area=area, speed=speed),
        coverage=CoverageMap(default_stations(n_stations, area=area)),
        features=x,
        weak_acc=weak,
        strong_acc=strong,
        seed=seed,
    )


def run_mobile_scenario(
    scenario: MobileScenario,
    mode: str = "handover",
    *,
    in_flight: str = "survive",
    ratio: Optional[float] = None,
    obs: Optional[Any] = None,
    **runtime_kwargs: Any,
) -> MobileTrace:
    """One deterministic serve of the scenario in the given mode — the
    equal-budget comparison runs this twice (``"handover"`` vs
    ``"static"``) over the same scenario and seeded trace."""
    runtime = MobileRuntime(
        scenario.engine,
        scenario.coverage,
        scenario.fleet(),
        motion=scenario.motion,
        mode=mode,
        in_flight=in_flight,
        seed=scenario.seed,
        obs=obs,
        **runtime_kwargs,
    )
    return runtime.serve(
        scenario.features, scenario.weak_acc, scenario.strong_acc, ratio=ratio
    )
