"""Base-station placements and position-dependent link quality.

The radio model is the standard log-distance path loss: a station
transmitting at ``tx_power_dbm`` is received at

    rss(d) = tx_power_dbm - ref_loss_db - 10 * path_loss_exp * log10(max(d, 1))

(dBm; ``ref_loss_db`` is the loss at 1 distance unit).  A
:class:`CoverageMap` turns that into the two signals the runtime consumes:

- ``rate_factor(rss)`` in ``(0, 1]`` — the fraction of the station link's
  nominal bandwidth a client at that signal strength actually gets, linear
  in dB between the usable ``floor_dbm`` and ``full_dbm``.  The runtime
  prices a frame from a far client as ``size_bits / rate_factor`` on the
  *existing* netsim uplink queue, so path loss composes with whatever link
  model fronts the station (constant-rate, trace, Gilbert–Elliott fading)
  without a new link class.
- ``time_to_loss(trace, t, ...)`` — steps until a moving client's best
  signal drops below the floor, the probe the ``mobility_aware`` policy
  discounts reward by.

``station_fleet`` builds one :class:`~repro.runtime.edge.EdgeWorker` per
station with a real netsim uplink *and* downlink, so offloaded frames pay
transit both ways.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.edge import EdgeLatencyModel, EdgeWorker

#: conventional "no signal" value (thermal noise floor territory)
NO_SIGNAL_DBM = -120.0


@dataclass(frozen=True)
class BaseStation:
    """One fixed edge placement with its radio parameters."""

    name: str
    x: float
    y: float
    tx_power_dbm: float = 30.0
    path_loss_exp: float = 2.7
    ref_loss_db: float = 40.0

    def rss_dbm(self, pos: np.ndarray) -> np.ndarray:
        """Received signal strength at ``pos`` (..., 2), in dBm."""
        p = np.asarray(pos, np.float64)
        d = np.sqrt((p[..., 0] - self.x) ** 2 + (p[..., 1] - self.y) ** 2)
        return (
            self.tx_power_dbm
            - self.ref_loss_db
            - 10.0 * self.path_loss_exp * np.log10(np.maximum(d, 1.0))
        )

    def spec(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "x": self.x,
            "y": self.y,
            "tx_power_dbm": self.tx_power_dbm,
            "path_loss_exp": self.path_loss_exp,
            "ref_loss_db": self.ref_loss_db,
        }


class CoverageMap:
    """The stations' joint footprint: per-position signal vector, best
    server, rate factors, and coverage-loss lookahead.

    Parameters
    ----------
    stations : sequence of BaseStation
    floor_dbm : float
        Usable floor — below this a client is out of coverage (offloads
        from here see ``min_rate_factor`` and should really stay local).
    full_dbm : float
        At or above this the client gets the link's full nominal rate.
    min_rate_factor : float
        Lower clamp on ``rate_factor`` so effective frame sizes stay
        finite (a frame from outside coverage is priced ruinously, not
        infinitely).
    """

    def __init__(
        self,
        stations: Sequence[BaseStation],
        *,
        floor_dbm: float = -82.0,
        full_dbm: float = -56.0,
        min_rate_factor: float = 0.05,
    ):
        if not stations:
            raise ValueError("coverage map needs at least one station")
        if full_dbm <= floor_dbm:
            raise ValueError(
                f"full_dbm must exceed floor_dbm, got {full_dbm} <= {floor_dbm}"
            )
        if not 0.0 < min_rate_factor <= 1.0:
            raise ValueError(f"min_rate_factor in (0, 1], got {min_rate_factor}")
        names = [s.name for s in stations]
        if len(set(names)) != len(names):
            raise ValueError(f"station names must be unique, got {names}")
        self.stations = list(stations)
        self.floor_dbm = float(floor_dbm)
        self.full_dbm = float(full_dbm)
        self.min_rate_factor = float(min_rate_factor)

    # ------------------------------------------------------------- signals

    def rss(self, pos: np.ndarray) -> np.ndarray:
        """Signal vector at ``pos``: shape (..., n_stations), dBm."""
        return np.stack([s.rss_dbm(pos) for s in self.stations], axis=-1)

    def best(self, pos: np.ndarray) -> Tuple[int, float]:
        """(station index, rss) of the strongest server at one position."""
        v = self.rss(pos)
        i = int(np.argmax(v))
        return i, float(v[i])

    def rate_factor(self, rss_dbm: float) -> float:
        """Fraction of nominal link rate at this signal strength — linear
        in dB between floor and full, clamped to [min_rate_factor, 1]."""
        frac = (float(rss_dbm) - self.floor_dbm) / (self.full_dbm - self.floor_dbm)
        return float(np.clip(frac, self.min_rate_factor, 1.0))

    def in_coverage(self, pos: np.ndarray) -> bool:
        return bool(self.rss(pos).max(axis=-1) >= self.floor_dbm)

    # ------------------------------------------------------------ lookahead

    def time_to_loss(
        self,
        trace: np.ndarray,
        t: int,
        *,
        dt: float = 1.0,
        horizon: int = 64,
        station: Optional[int] = None,
    ) -> float:
        """Time units until the client's signal (best-server by default, a
        fixed ``station`` when given) first drops below the floor, scanning
        the precomputed motion ``trace`` (T, 2) forward from step ``t``.
        ``inf`` when coverage holds through the horizon; ``0`` when already
        out.  A *prediction* in the paper's sense only in that the runtime
        owns the trace — clients don't see the future, the controller does
        (it generated the itinerary)."""
        end = min(len(trace), t + horizon + 1)
        seg = self.rss(trace[t:end])
        sig = seg[:, station] if station is not None else seg.max(axis=-1)
        below = np.flatnonzero(sig < self.floor_dbm)
        if below.size == 0:
            return float("inf")
        return float(below[0]) * float(dt)

    def spec(self) -> Dict[str, Any]:
        return {
            "stations": [s.spec() for s in self.stations],
            "floor_dbm": self.floor_dbm,
            "full_dbm": self.full_dbm,
            "min_rate_factor": self.min_rate_factor,
        }


def default_stations(
    n: int = 3, *, area: Tuple[float, float] = (1000.0, 1000.0), **radio: Any
) -> List[BaseStation]:
    """``n`` stations evenly spread along the area's horizontal midline —
    a corridor layout where straight-line motion crosses cell boundaries
    (the interesting case for handover)."""
    w, h = area
    return [
        BaseStation(f"bs{i}", x=w * (i + 0.5) / n, y=h / 2.0, **radio)
        for i in range(n)
    ]


def station_fleet(
    coverage: CoverageMap,
    *,
    capacity: int = 6,
    rate: Optional[float] = None,
    burst: float = 4.0,
    service: Optional[EdgeLatencyModel] = None,
    transmit_time: float = 0.25,
    queue_depth: int = 12,
    downlink_time: float = 0.05,
    downlink_depth: int = 32,
    seed: int = 0,
) -> List[EdgeWorker]:
    """One uplink- and downlink-fronted :class:`EdgeWorker` per station.

    Nominal rates: a full-signal frame transmits in ``transmit_time`` and
    its result returns in ``downlink_time`` (result payloads are small).
    Position-dependent quality enters at dispatch time via
    ``size_bits = frame_bits / rate_factor(rss)`` — the queues themselves
    are shared per-station radios, as in the real topology."""
    from repro.netsim import ConstantRateLink

    svc = service if service is not None else EdgeLatencyModel(
        base=0.3, per_inflight=0.05, jitter=0.02
    )
    return [
        EdgeWorker(
            s.name,
            capacity=capacity,
            rate=rate,
            burst=burst,
            latency=svc,
            link=ConstantRateLink(1.0 / transmit_time),
            queue_depth=queue_depth,
            frame_bits=1.0,
            downlink=ConstantRateLink(1.0 / downlink_time),
            downlink_depth=downlink_depth,
            result_bits=1.0,
            seed=seed + i,
        )
        for i, s in enumerate(coverage.stations)
    ]
