"""mAP engine with incremental per-image evaluation.

The ORIC reward (repro.core.reward) evaluates, for every image ``i``, the mAP
of ``{h_i} ∪ H_E`` where ``E`` is a ~1000-image context set.  Recomputing mAP
from scratch per image is O(|val| · |E|) box work; instead we match each
image's detections to its own ground truth once (matching is strictly
per-image), accumulate per-class (score, tp) lists for the context, and merge
a single image into the accumulator in O(n_class) when evaluating — exact,
not an approximation, because AP only needs globally score-sorted tp flags.

Conventions: COCO-style greedy matching (per class, detections by descending
score, each takes the best unmatched GT with IoU >= threshold); AP via
101-point interpolation; classes with zero ground truth in the evaluated set
are excluded from the mean (their false positives still never surface — the
exact mAPI blind spot the paper's context set fixes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.detection.boxes import box_iou_np

RECALL_GRID = np.linspace(0.0, 1.0, 101)


@dataclass
class Detections:
    """Per-image detector output."""

    boxes: np.ndarray  # (N, 4) xyxy
    scores: np.ndarray  # (N,)
    classes: np.ndarray  # (N,) int

    def __post_init__(self) -> None:
        self.boxes = np.asarray(self.boxes, dtype=np.float64).reshape(-1, 4)
        self.scores = np.asarray(self.scores, dtype=np.float64).reshape(-1)
        self.classes = np.asarray(self.classes, dtype=np.int64).reshape(-1)

    def __len__(self) -> int:
        return self.boxes.shape[0]

    def top_k(self, k: int) -> "Detections":
        # stable: ties keep insertion order, matching the batched data
        # plane's stable top-k selection (repro.core.features)
        order = np.argsort(-self.scores, kind="stable")[:k]
        return Detections(self.boxes[order], self.scores[order], self.classes[order])


@dataclass
class GroundTruth:
    """Per-image annotations."""

    boxes: np.ndarray  # (M, 4) xyxy
    classes: np.ndarray  # (M,) int

    def __post_init__(self) -> None:
        self.boxes = np.asarray(self.boxes, dtype=np.float64).reshape(-1, 4)
        self.classes = np.asarray(self.classes, dtype=np.int64).reshape(-1)

    def __len__(self) -> int:
        return self.boxes.shape[0]


@dataclass
class ImageEval:
    """Matching result for one image: per-class scored tp flags + GT counts.

    ``per_class[c] = (scores (n,), tp (T, n))`` where T = #iou thresholds.
    ``matched_gt[c][t]`` holds, aligned with detections, the matched GT index
    (into the image's per-class GT list) or -1 — used by TIDE.
    """

    per_class: Dict[int, Tuple[np.ndarray, np.ndarray]] = field(default_factory=dict)
    gt_counts: Dict[int, int] = field(default_factory=dict)
    matched_gt: Dict[int, np.ndarray] = field(default_factory=dict)


def match_detections(
    det: Detections,
    gt: GroundTruth,
    iou_thresholds: Sequence[float] = (0.5,),
) -> ImageEval:
    """Greedy per-class matching of one image's detections to its GT."""
    thresholds = np.asarray(iou_thresholds, dtype=np.float64)
    ev = ImageEval()
    for c in np.unique(gt.classes):
        ev.gt_counts[int(c)] = int(np.sum(gt.classes == c))
    class_ids = np.unique(np.concatenate([det.classes, gt.classes])) if (
        len(det) or len(gt)
    ) else np.zeros((0,), dtype=np.int64)
    for c in class_ids:
        c = int(c)
        d_idx = np.where(det.classes == c)[0]
        g_idx = np.where(gt.classes == c)[0]
        if d_idx.size == 0:
            continue
        order = np.argsort(-det.scores[d_idx], kind="stable")
        d_idx = d_idx[order]
        scores = det.scores[d_idx]
        tp = np.zeros((thresholds.size, d_idx.size), dtype=bool)
        match_ix = np.full((thresholds.size, d_idx.size), -1, dtype=np.int64)
        if g_idx.size:
            iou = box_iou_np(det.boxes[d_idx], gt.boxes[g_idx])  # (n, m)
            for t, thr in enumerate(thresholds):
                taken = np.zeros(g_idx.size, dtype=bool)
                for k in range(d_idx.size):
                    row = np.where(taken, -1.0, iou[k])
                    j = int(np.argmax(row)) if row.size else -1
                    if j >= 0 and row[j] >= thr:
                        taken[j] = True
                        tp[t, k] = True
                        match_ix[t, k] = j
        ev.per_class[c] = (scores, tp)
        ev.matched_gt[c] = match_ix
    return ev


def average_precision(
    scores: np.ndarray, tp: np.ndarray, n_gt: int
) -> float:
    """101-point interpolated AP from unsorted (score, tp) pairs."""
    if n_gt <= 0:
        return float("nan")
    if scores.size == 0:
        return 0.0
    order = np.argsort(-scores, kind="stable")
    tp = tp[order].astype(np.float64)
    tp_cum = np.cumsum(tp)
    fp_cum = np.cumsum(1.0 - tp)
    recall = tp_cum / n_gt
    precision = tp_cum / np.maximum(tp_cum + fp_cum, 1e-12)
    # precision envelope (monotone non-increasing from the right)
    prec_env = np.maximum.accumulate(precision[::-1])[::-1]
    # max precision at recall >= r for each grid point
    idx = np.searchsorted(recall, RECALL_GRID, side="left")
    ap = np.where(idx < recall.size, prec_env[np.minimum(idx, recall.size - 1)], 0.0)
    return float(ap.mean())


class APAccumulator:
    """Per-class (scores, tp) accumulation over an image set, with O(classes)
    incremental evaluation of ``mAP(accumulated ∪ {one image})``."""

    def __init__(self, iou_thresholds: Sequence[float] = (0.5,)) -> None:
        self.iou_thresholds = tuple(iou_thresholds)
        self._scores: Dict[int, List[np.ndarray]] = {}
        self._tp: Dict[int, List[np.ndarray]] = {}
        self._gt: Dict[int, int] = {}
        self._frozen: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None
        self._ap_cache: Optional[Dict[int, np.ndarray]] = None

    def add(self, ev: ImageEval) -> None:
        self._frozen = None
        self._ap_cache = None
        for c, n in ev.gt_counts.items():
            self._gt[c] = self._gt.get(c, 0) + n
        for c, (scores, tp) in ev.per_class.items():
            self._scores.setdefault(c, []).append(scores)
            self._tp.setdefault(c, []).append(tp)

    def _freeze(self) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        if self._frozen is None:
            frozen = {}
            classes = set(self._scores) | set(self._gt)
            T = len(self.iou_thresholds)
            for c in classes:
                if c in self._scores:
                    s = np.concatenate(self._scores[c])
                    t = np.concatenate(self._tp[c], axis=1)
                else:
                    s = np.zeros((0,))
                    t = np.zeros((T, 0), dtype=bool)
                frozen[c] = (s, t)
            self._frozen = frozen
        return self._frozen

    def _base_aps(self) -> Dict[int, np.ndarray]:
        if self._ap_cache is None:
            frozen = self._freeze()
            cache: Dict[int, np.ndarray] = {}
            for c, (s, t) in frozen.items():
                n_gt = self._gt.get(c, 0)
                cache[c] = np.array(
                    [average_precision(s, t[ti], n_gt) for ti in range(t.shape[0])]
                )
            self._ap_cache = cache
        return self._ap_cache

    def map(self) -> float:
        """mAP of the accumulated set alone."""
        aps = self._base_aps()
        vals = [a for a in aps.values() if not np.all(np.isnan(a))]
        if not vals:
            return 0.0
        return float(np.nanmean(np.stack(vals)))

    def map_with_image(self, ev: ImageEval) -> float:
        """Exact ``mAP(accumulated ∪ {image})`` without mutating state.

        Only classes touched by the image are re-evaluated; the rest reuse
        the cached per-class APs.
        """
        frozen = self._freeze()
        base = self._base_aps()
        T = len(self.iou_thresholds)
        touched = set(ev.per_class) | set(ev.gt_counts)
        per_class_ap: Dict[int, np.ndarray] = dict(base)
        for c in touched:
            s0, t0 = frozen.get(c, (np.zeros((0,)), np.zeros((T, 0), dtype=bool)))
            if c in ev.per_class:
                s1, t1 = ev.per_class[c]
                s = np.concatenate([s0, s1])
                t = np.concatenate([t0, t1], axis=1)
            else:
                s, t = s0, t0
            n_gt = self._gt.get(c, 0) + ev.gt_counts.get(c, 0)
            per_class_ap[c] = np.array(
                [average_precision(s, t[ti], n_gt) for ti in range(T)]
            )
        vals = [a for a in per_class_ap.values() if not np.all(np.isnan(a))]
        if not vals:
            return 0.0
        return float(np.nanmean(np.stack(vals)))

    def map_with_images(self, evs: Sequence[ImageEval]) -> np.ndarray:
        """Batched ``map_with_image``: one array of exact mAP(accumulated ∪
        {image_i}) values.

        The base accumulator's AP sum/count are hoisted out of the per-image
        loop, so each image costs only its touched classes instead of a full
        O(classes) dict copy + nanmean pass per call.
        """
        frozen = self._freeze()
        base = self._base_aps()
        T = len(self.iou_thresholds)
        base_sum = 0.0
        base_cnt = 0
        for a in base.values():
            valid = ~np.isnan(a)
            base_sum += float(a[valid].sum())
            base_cnt += int(valid.sum())
        empty = (np.zeros((0,)), np.zeros((T, 0), dtype=bool))
        out = np.empty(len(evs), dtype=np.float64)
        for i, ev in enumerate(evs):
            total, count = base_sum, base_cnt
            for c in set(ev.per_class) | set(ev.gt_counts):
                s0, t0 = frozen.get(c, empty)
                if c in ev.per_class:
                    s1, t1 = ev.per_class[c]
                    s = np.concatenate([s0, s1])
                    t = np.concatenate([t0, t1], axis=1)
                else:
                    s, t = s0, t0
                n_gt = self._gt.get(c, 0) + ev.gt_counts.get(c, 0)
                new = np.array(
                    [average_precision(s, t[ti], n_gt) for ti in range(T)]
                )
                old = base.get(c)
                if old is not None:
                    valid = ~np.isnan(old)
                    total -= float(old[valid].sum())
                    count -= int(valid.sum())
                valid = ~np.isnan(new)
                total += float(new[valid].sum())
                count += int(valid.sum())
            out[i] = total / count if count else 0.0
        return out


def dataset_map(
    detections: Iterable[Detections],
    ground_truths: Iterable[GroundTruth],
    iou_thresholds: Sequence[float] = (0.5,),
) -> float:
    """mAP of a detector over a whole image set."""
    acc = APAccumulator(iou_thresholds)
    for det, gt in zip(detections, ground_truths):
        acc.add(match_detections(det, gt, iou_thresholds))
    return acc.map()
