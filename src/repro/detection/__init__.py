"""Detection substrate: box ops, matching, mAP engine, TIDE errors, NMS.

Three layers:
  * ``boxes`` — jnp, jit-able, used inside models/losses and Pallas refs.
  * ``batch`` — the padded struct-of-arrays data plane: ``DetectionsBatch``
    / ``GroundTruthBatch`` and the device-resident ``match_batch`` greedy
    matcher on the ``iou_matrix`` Pallas kernel.
  * ``map_engine`` / ``tide`` — numpy, host-side evaluation (variable-length
    detection lists), used by the ORIC reward machinery in ``repro.core``.
"""
from repro.detection.batch import (
    DetectionsBatch,
    GroundTruthBatch,
    MatchResult,
    match_batch,
    to_image_evals,
)
from repro.detection.boxes import (
    box_area,
    box_iou,
    box_iou_np,
    cxcywh_to_xyxy,
    xyxy_to_cxcywh,
)
from repro.detection.map_engine import (
    Detections,
    GroundTruth,
    average_precision,
    dataset_map,
    match_detections,
)
from repro.detection.nms import nms
from repro.detection.tide import tide_errors

__all__ = [
    "DetectionsBatch",
    "GroundTruthBatch",
    "MatchResult",
    "match_batch",
    "to_image_evals",
    "box_area",
    "box_iou",
    "box_iou_np",
    "cxcywh_to_xyxy",
    "xyxy_to_cxcywh",
    "Detections",
    "GroundTruth",
    "average_precision",
    "dataset_map",
    "match_detections",
    "nms",
    "tide_errors",
]
