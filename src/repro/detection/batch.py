"""Batched detection data plane: padded struct-of-arrays containers + the
device-resident COCO greedy matcher.

The fit-time loop (per-image matching §IV, weak-output features §V-A, ORIC
labels Eq. 5–6) historically ran as per-image Python over ragged numpy
``Detections``.  This module is the batched substrate everything now rides
on:

* ``DetectionsBatch`` / ``GroundTruthBatch`` — fixed ``max_boxes`` padding,
  float32 struct-of-arrays with validity masks.  ``from_list`` pads a ragged
  list; ``__getitem__``/``to_list`` round-trip back to the host dataclasses.
* ``match_batch`` — a jitted matcher that computes per-image IoU through the
  ``iou_matrix`` Pallas kernel (``iou_matrix_batch``) and reproduces COCO
  greedy matching (per class, detections by descending score, one GT per
  detection, per IoU threshold) as masked ``lax`` ops over the whole batch.
  Its tp flags are identical to per-image ``match_detections`` under the
  plane's float32 convention (float64 inputs distinguishable only below
  float32 precision — score ties, IoUs within ~1e-7 of a threshold — may
  resolve differently than a float64 host match of the originals).
* ``to_image_evals`` — converts a ``MatchResult`` back into the exact
  ``ImageEval`` structure the AP accumulator consumes, so the incremental
  mAP engine and the ORIC oracle run unchanged on top of batched matching.

Padding conventions: padded box rows are all-zero (degenerate boxes, IoU 0),
padded classes are ``-1`` (never equal to a real class id), padded scores 0;
the ``mask`` arrays are the source of truth — consumers must never rely on
sentinel values alone.  ``from_list`` produces prefix masks (valid entries
first) but the matcher and feature kernels only require the mask.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.detection.map_engine import Detections, GroundTruth, ImageEval
from repro.kernels.iou_matrix.ops import iou_matrix_batch, resolve_path
from repro.obs.jit_stats import register_jit


def _pad_dim(n: int, multiple: int = 8) -> int:
    return max(multiple, -(-n // multiple) * multiple)


def _stack_padded(
    arrays: Sequence[np.ndarray], max_n: int, trailing: Tuple[int, ...], dtype, fill
) -> np.ndarray:
    out = np.full((len(arrays), max_n) + trailing, fill, dtype=dtype)
    for i, a in enumerate(arrays):
        out[i, : len(a)] = a
    return out


@dataclass(kw_only=True)
class _BoxBatch:
    """Shared padded struct-of-arrays core: ``boxes (B, N, 4)`` float32,
    ``classes (B, N)`` int32, ``mask (B, N)`` bool.

    Keyword-only construction: the silent dtype coercion in
    ``__post_init__`` would otherwise let positionally swapped arrays pass
    shape checks and corrupt downstream matching."""

    boxes: np.ndarray
    classes: np.ndarray
    mask: np.ndarray

    def __post_init__(self) -> None:
        self.boxes = np.asarray(self.boxes, np.float32)
        self.classes = np.asarray(self.classes, np.int32)
        self.mask = np.asarray(self.mask, bool)

    @staticmethod
    def _padded_fields(items, max_boxes: Optional[int]):
        """(resolved max_boxes, common field dict) for a ragged item list —
        every item exposes ``boxes``/``classes`` and ``len``.

        ``items`` may be empty: the result is the explicit zero-length
        batch (``B == 0`` with ``max_boxes`` at the padding floor), which
        round-trips through ``to_list``/``match_batch``/``to_image_evals``
        like any other batch."""
        ns = [len(it) for it in items]
        top = max(ns, default=0)
        if max_boxes is None:
            max_boxes = _pad_dim(top)
        elif top > max_boxes:
            raise ValueError(f"image with {top} boxes exceeds max_boxes={max_boxes}")
        fields = dict(
            boxes=_stack_padded(
                [it.boxes for it in items], max_boxes, (4,), np.float32, 0.0
            ),
            classes=_stack_padded(
                [it.classes for it in items], max_boxes, (), np.int32, -1
            ),
            mask=_stack_padded(
                [np.ones(n, bool) for n in ns], max_boxes, (), bool, False
            ),
        )
        return max_boxes, fields

    #: per-field batch-axis padding fills — the same conventions ``from_list``
    #: uses for the box axis (empty images: zero boxes, class -1, mask False)
    _IMAGE_FILL = {"boxes": 0.0, "classes": -1, "scores": 0.0, "mask": False}

    def pad_images(self, n_images: int):
        """The batch extended to ``n_images`` along the *image* axis with
        empty (all-masked) images — ragged last-shard padding for the
        sharded data plane (``repro.fleet.plane``).  Padded images match and
        featurize to all-False/all-zero rows, so cropping after a sharded
        gather recovers the original results exactly."""
        B = len(self)
        if n_images < B:
            raise ValueError(f"pad_images({n_images}) below batch size {B}")
        if n_images == B:
            return self
        kwargs = {}
        for f in dataclasses.fields(self):
            a = getattr(self, f.name)
            pad = np.full(
                (n_images - B,) + a.shape[1:], self._IMAGE_FILL[f.name], a.dtype
            )
            kwargs[f.name] = np.concatenate([a, pad])
        return type(self)(**kwargs)

    def __len__(self) -> int:
        return self.boxes.shape[0]

    @property
    def max_boxes(self) -> int:
        return self.boxes.shape[1]

    @property
    def counts(self) -> np.ndarray:
        return self.mask.sum(axis=1)

    def to_list(self) -> list:
        return [self[i] for i in range(len(self))]


@dataclass(kw_only=True)
class GroundTruthBatch(_BoxBatch):
    """Padded per-image annotations: ``boxes (B, M, 4)``, ``classes (B, M)``,
    ``mask (B, M)`` — float32/int32 struct-of-arrays."""

    @classmethod
    def from_list(
        cls, gts: Sequence[GroundTruth], max_boxes: Optional[int] = None
    ) -> "GroundTruthBatch":
        """Pad a ragged annotation list; ``[]`` yields the explicit
        zero-length batch."""
        _, fields = cls._padded_fields(gts, max_boxes)
        return cls(**fields)

    def __getitem__(self, i: int) -> GroundTruth:
        m = self.mask[i]
        return GroundTruth(self.boxes[i][m], self.classes[i][m])


@dataclass(kw_only=True)
class DetectionsBatch(_BoxBatch):
    """Padded per-image detector output: ``boxes (B, K, 4)``, ``scores
    (B, K)``, ``classes (B, K)``, ``mask (B, K)``."""

    scores: np.ndarray

    def __post_init__(self) -> None:
        super().__post_init__()
        self.scores = np.asarray(self.scores, np.float32)

    @classmethod
    def from_list(
        cls, dets: Sequence[Detections], max_boxes: Optional[int] = None
    ) -> "DetectionsBatch":
        """Pad a ragged detection list; ``[]`` yields the explicit
        zero-length batch."""
        max_boxes, fields = cls._padded_fields(dets, max_boxes)
        scores = _stack_padded(
            [d.scores for d in dets], max_boxes, (), np.float32, 0.0
        )
        return cls(scores=scores, **fields)

    def __getitem__(self, i: int) -> Detections:
        m = self.mask[i]
        return Detections(self.boxes[i][m], self.scores[i][m], self.classes[i][m])


# ---------------------------------------------------------------------------
# Batched greedy matching
# ---------------------------------------------------------------------------

@dataclass
class MatchResult:
    """Batched matching output, aligned to the original detection slots.

    ``tp[b, t, k]`` — detection slot ``k`` of image ``b`` is a true positive
    at IoU threshold ``t``; ``match_gt[b, t, k]`` — the matched GT *slot*
    (into the padded GT arrays) or -1.  Padded detection slots are never tp.
    """

    tp: np.ndarray  # (B, T, K) bool
    match_gt: np.ndarray  # (B, T, K) int32
    iou_thresholds: Tuple[float, ...] = field(default=(0.5,))


@jax.jit
def _greedy_match(
    iou: jnp.ndarray,  # (B, K, M) masked: ineligible pairs hold -1
    order: jnp.ndarray,  # (B, K) detection slots by descending score
    thresholds: jnp.ndarray,  # (T,)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, K, M = iou.shape
    T = thresholds.shape[0]
    iou_s = jnp.take_along_axis(iou, order[:, :, None], axis=1)

    def step(taken, row):  # taken (B, T, M); row (B, M)
        avail = jnp.where(taken, -1.0, row[:, None, :])  # (B, T, M)
        j = jnp.argmax(avail, axis=-1)  # (B, T) first max, as np.argmax
        best = jnp.take_along_axis(avail, j[..., None], axis=-1)[..., 0]
        hit = best >= thresholds[None, :]
        slot = lax.broadcasted_iota(jnp.int32, (B, T, M), 2)
        taken = taken | (hit[:, :, None] & (slot == j[:, :, None].astype(jnp.int32)))
        return taken, (hit, jnp.where(hit, j.astype(jnp.int32), -1))

    taken0 = jnp.zeros((B, T, M), bool)
    _, (tp_s, mj_s) = lax.scan(step, taken0, jnp.moveaxis(iou_s, 1, 0))
    tp_s = jnp.moveaxis(tp_s, 0, 2)  # (B, T, K), sorted-detection order
    mj_s = jnp.moveaxis(mj_s, 0, 2)
    # scatter back to original detection slots
    inv = jnp.argsort(order, axis=1)  # inv[b, slot] = sorted position of slot
    tp = jnp.take_along_axis(tp_s, inv[:, None, :], axis=2)
    mj = jnp.take_along_axis(mj_s, inv[:, None, :], axis=2)
    return tp, mj


register_jit("detection.greedy_match", _greedy_match)


@jax.jit
def _match_inputs(
    d_scores, d_classes, d_mask, g_classes, g_mask, iou
):
    """Eligibility masking + the global score order that reproduces the
    per-class stable sort of ``match_detections``."""
    eligible = (
        d_mask[:, :, None]
        & g_mask[:, None, :]
        & (d_classes[:, :, None] == g_classes[:, None, :])
    )
    masked = jnp.where(eligible, iou, -1.0)
    # Greedy matching is independent per class, so one global pass in
    # descending-score order with class-eligibility masking is exactly the
    # per-class loop.  Stable sort keeps the reference's tie order; invalid
    # slots sink to the end with -inf keys.
    keys = jnp.where(d_mask, d_scores, -jnp.inf)
    order = jnp.argsort(-keys, axis=1, stable=True)
    return masked, order


register_jit("detection.match_inputs", _match_inputs)


def match_batch(
    det: DetectionsBatch,
    gt: GroundTruthBatch,
    iou_thresholds: Sequence[float] = (0.5,),
    *,
    interpret: Union[None, bool, str] = None,
    tile_b: int = 8,
    tile_n: int = 128,
    tile_m: int = 128,
) -> MatchResult:
    """Batched COCO greedy matching on device; tp flags are identical to
    per-image :func:`repro.detection.map_engine.match_detections`.

    The per-image IoU runs through the ``iou_matrix`` dispatch
    (``interpret=None`` auto-selects the jitted jnp reference on CPU and
    the compiled Pallas kernel on TPU/GPU), the greedy assignment through
    one ``lax.scan`` over score-ordered slots.
    """
    if len(det) != len(gt):
        raise ValueError(f"batch size mismatch: {len(det)} dets vs {len(gt)} gts")
    thresholds = jnp.asarray(iou_thresholds, jnp.float32)
    interp = resolve_path(interpret)
    if interp == "interpret":
        # interpreter mode runs one Python step per grid cell: shrink tiles
        # to the (small) padded box axes and batch more images per step so
        # the grid stays short.  Compiled TPU keeps the 128-lane tiles.
        tile_n = min(tile_n, _pad_dim(det.max_boxes))
        tile_m = min(tile_m, _pad_dim(gt.max_boxes))
        tile_b = min(64, _pad_dim(len(det)))
    iou = iou_matrix_batch(
        jnp.asarray(det.boxes), jnp.asarray(gt.boxes),
        tile_b=tile_b, tile_n=tile_n, tile_m=tile_m, interpret=interp,
    )
    masked, order = _match_inputs(
        jnp.asarray(det.scores), jnp.asarray(det.classes), jnp.asarray(det.mask),
        jnp.asarray(gt.classes), jnp.asarray(gt.mask), iou,
    )
    tp, mj = _greedy_match(masked, order, thresholds)
    return MatchResult(
        tp=np.asarray(tp),
        match_gt=np.asarray(mj, np.int32),
        iou_thresholds=tuple(float(t) for t in iou_thresholds),
    )


def to_image_evals(
    det: DetectionsBatch, gt: GroundTruthBatch, result: MatchResult
) -> List[ImageEval]:
    """Convert a batched :class:`MatchResult` into the per-image
    ``ImageEval`` list ``APAccumulator``/``RewardOracle`` consume — the same
    structure ``match_detections`` produces (per-class scores sorted
    descending, (T, n) tp flags, per-class-local matched GT indices)."""
    out: List[ImageEval] = []
    for b in range(len(det)):
        d_slots = np.where(det.mask[b])[0]
        g_slots = np.where(gt.mask[b])[0]
        d_cls = det.classes[b][d_slots]
        g_cls = gt.classes[b][g_slots]
        scores = det.scores[b].astype(np.float64)
        ev = ImageEval()
        for c in np.unique(g_cls):
            ev.gt_counts[int(c)] = int(np.sum(g_cls == c))
        if d_slots.size or g_slots.size:
            class_ids = np.unique(np.concatenate([d_cls, g_cls]))
        else:
            class_ids = np.zeros((0,), np.int64)
        for c in class_ids:
            c = int(c)
            d_idx = d_slots[d_cls == c]
            if d_idx.size == 0:
                continue
            order = np.argsort(-scores[d_idx], kind="stable")
            d_idx = d_idx[order]
            g_idx = g_slots[g_cls == c]  # ascending slot order == per-class order
            mj = result.match_gt[b][:, d_idx]  # (T, n) global GT slots
            local = np.searchsorted(g_idx, np.where(mj < 0, 0, mj))
            ev.per_class[c] = (scores[d_idx], result.tp[b][:, d_idx])
            ev.matched_gt[c] = np.where(mj < 0, -1, local).astype(np.int64)
        out.append(ev)
    return out
