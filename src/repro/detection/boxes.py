"""Box geometry ops. jnp versions are jit-able; _np versions host-side.

Boxes are ``[x1, y1, x2, y2]`` with ``x2 >= x1`` and ``y2 >= y1``, in
normalized or pixel coordinates (the math is scale-free).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def box_area(boxes: jnp.ndarray) -> jnp.ndarray:
    """Area of ``(..., 4)`` xyxy boxes."""
    w = jnp.maximum(boxes[..., 2] - boxes[..., 0], 0.0)
    h = jnp.maximum(boxes[..., 3] - boxes[..., 1], 0.0)
    return w * h


def box_iou(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pairwise IoU between ``a: (N, 4)`` and ``b: (M, 4)`` -> ``(N, M)``."""
    lt = jnp.maximum(a[:, None, :2], b[None, :, :2])  # (N, M, 2)
    rb = jnp.minimum(a[:, None, 2:], b[None, :, 2:])
    wh = jnp.maximum(rb - lt, 0.0)
    inter = wh[..., 0] * wh[..., 1]
    union = box_area(a)[:, None] + box_area(b)[None, :] - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1e-12), 0.0)


def box_iou_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Numpy pairwise IoU, ``(N, 4) x (M, 4) -> (N, M)``."""
    a = np.asarray(a, dtype=np.float64).reshape(-1, 4)
    b = np.asarray(b, dtype=np.float64).reshape(-1, 4)
    lt = np.maximum(a[:, None, :2], b[None, :, :2])
    rb = np.minimum(a[:, None, 2:], b[None, :, 2:])
    wh = np.clip(rb - lt, 0.0, None)
    inter = wh[..., 0] * wh[..., 1]
    area_a = np.clip(a[:, 2] - a[:, 0], 0, None) * np.clip(a[:, 3] - a[:, 1], 0, None)
    area_b = np.clip(b[:, 2] - b[:, 0], 0, None) * np.clip(b[:, 3] - b[:, 1], 0, None)
    union = area_a[:, None] + area_b[None, :] - inter
    out = np.zeros_like(inter)
    np.divide(inter, union, out=out, where=union > 0)
    return out


def cxcywh_to_xyxy(boxes: jnp.ndarray) -> jnp.ndarray:
    cx, cy, w, h = jnp.split(boxes, 4, axis=-1)
    return jnp.concatenate(
        [cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2], axis=-1
    )


def xyxy_to_cxcywh(boxes: jnp.ndarray) -> jnp.ndarray:
    x1, y1, x2, y2 = jnp.split(boxes, 4, axis=-1)
    return jnp.concatenate(
        [(x1 + x2) / 2, (y1 + y2) / 2, x2 - x1, y2 - y1], axis=-1
    )
