"""Fixed-size (jit-able) non-maximum suppression.

Operates on padded detection tensors: ``boxes (N, 4)``, ``scores (N,)``,
``classes (N,)``; suppressed entries get score 0.  Class-aware: boxes only
suppress boxes of the same class.  Implemented as a ``jax.lax.fori_loop``
over the score-sorted list so it lowers cleanly (no dynamic shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.detection.boxes import box_iou


def nms(
    boxes: jnp.ndarray,
    scores: jnp.ndarray,
    classes: jnp.ndarray,
    iou_threshold: float = 0.5,
    score_threshold: float = 0.0,
) -> jnp.ndarray:
    """Returns a keep mask ``(N,)`` of bools."""
    n = boxes.shape[0]
    order = jnp.argsort(-scores)
    boxes_s = boxes[order]
    scores_s = scores[order]
    classes_s = classes[order]
    iou = box_iou(boxes_s, boxes_s)
    same_class = classes_s[:, None] == classes_s[None, :]
    suppress_pair = (iou > iou_threshold) & same_class

    def body(i, keep):
        # i suppresses all later boxes it overlaps, if i itself is kept
        row = suppress_pair[i] & (jnp.arange(n) > i)
        return jnp.where(keep[i], keep & ~row, keep)

    keep_sorted = jax.lax.fori_loop(
        0, n, body, scores_s > score_threshold
    )
    # scatter back to the original order
    keep = jnp.zeros((n,), dtype=bool).at[order].set(keep_sorted)
    return keep
