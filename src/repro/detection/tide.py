"""TIDE-style decomposition of detection errors into six categories.

Following Bolya et al. [24] (as used in the paper's Fig. 6) we bucket every
false positive / missed ground truth into:

  * ``cls``      — right place, wrong label        (IoU >= tf with other-class GT)
  * ``loc``      — right label, wrong place        (tb <= IoU < tf, same class)
  * ``cls_loc``  — wrong label and place           (tb <= IoU < tf, other class)
  * ``dupe``     — re-detects an already-matched GT (IoU >= tf, same class, taken)
  * ``bkg``      — hallucination                   (IoU < tb with every GT)
  * ``miss``     — GT with no detection at IoU >= tb of any class

and report, per category, the **mAP gained by oracle-fixing it** (TIDE's
"amount of mAP reduction caused by the category").  This is a TIDE-lite: fix
semantics are the standard ones (cls/loc errors become TPs when their GT is
free, otherwise are removed; dupe/bkg/cls_loc detections are removed; misses
shrink the GT denominator), applied independently per category.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.detection.boxes import box_iou_np
from repro.detection.map_engine import (
    APAccumulator,
    Detections,
    GroundTruth,
    ImageEval,
    match_detections,
)

CATEGORIES = ("cls", "loc", "cls_loc", "dupe", "bkg", "miss")


def _classify_image(
    det: Detections, gt: GroundTruth, tf: float, tb: float
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Per-detection error label + per-GT missed flag for one image.

    Returns ``(labels (N,), matched (N,), best_gt (N,), missed (M,))`` where
    labels are indices into CATEGORIES (-1 = true positive), ``best_gt`` is
    the index of the max-IoU ground-truth box (or -1).
    """
    n, m = len(det), len(gt)
    labels = np.full(n, -1, dtype=np.int64)
    matched = np.zeros(n, dtype=bool)
    best_gt = np.full(n, -1, dtype=np.int64)
    gt_taken = np.zeros(m, dtype=bool)
    if m:
        iou = box_iou_np(det.boxes, gt.boxes)  # (n, m)
    else:
        iou = np.zeros((n, 0))
    order = np.argsort(-det.scores, kind="stable")
    for k in order:
        c = det.classes[k]
        same = (gt.classes == c) if m else np.zeros((0,), dtype=bool)
        row = iou[k] if m else np.zeros((0,))
        # greedy TP matching at tf within class
        cand = np.where(same & ~gt_taken, row, -1.0)
        j = int(np.argmax(cand)) if m else -1
        if j >= 0 and cand[j] >= tf:
            matched[k] = True
            gt_taken[j] = True
            best_gt[k] = j
            continue
        iou_same = float(np.max(np.where(same, row, -1.0))) if m else -1.0
        iou_other = float(np.max(np.where(~same, row, -1.0))) if m else -1.0
        best_gt[k] = int(np.argmax(row)) if m else -1
        if iou_same >= tf:
            labels[k] = CATEGORIES.index("dupe")
        elif iou_other >= tf:
            labels[k] = CATEGORIES.index("cls")
        elif iou_same >= tb:
            labels[k] = CATEGORIES.index("loc")
        elif iou_other >= tb:
            labels[k] = CATEGORIES.index("cls_loc")
        else:
            labels[k] = CATEGORIES.index("bkg")
    if m:
        covered = (iou >= tb).any(axis=0) | gt_taken
        missed = ~covered
    else:
        missed = np.zeros((0,), dtype=bool)
    return labels, matched, best_gt, missed


def _fixed_eval(
    det: Detections,
    gt: GroundTruth,
    fix: str,
    tf: float,
    tb: float,
) -> ImageEval:
    """ImageEval for one image with error category ``fix`` oracle-corrected."""
    labels, matched, best_gt, missed = _classify_image(det, gt, tf, tb)
    fix_idx = CATEGORIES.index(fix)
    boxes = det.boxes.copy()
    classes = det.classes.copy()
    keep = np.ones(len(det), dtype=bool)
    sel = labels == fix_idx
    if fix in ("dupe", "bkg", "cls_loc"):
        keep[sel] = False
    elif fix == "cls":
        # relabel to the overlapped GT's class; dedup handled by re-matching
        for k in np.where(sel)[0]:
            if best_gt[k] >= 0:
                classes[k] = gt.classes[best_gt[k]]
            else:
                keep[k] = False
    elif fix == "loc":
        # snap the box onto the overlapped GT
        for k in np.where(sel)[0]:
            if best_gt[k] >= 0:
                boxes[k] = gt.boxes[best_gt[k]]
            else:
                keep[k] = False
    gt_boxes, gt_classes = gt.boxes, gt.classes
    if fix == "miss":
        gt_boxes = gt.boxes[~missed]
        gt_classes = gt.classes[~missed]
    det2 = Detections(boxes[keep], det.scores[keep], classes[keep])
    gt2 = GroundTruth(gt_boxes, gt_classes)
    return match_detections(det2, gt2, (tf,))


def tide_errors(
    detections: Sequence[Detections],
    ground_truths: Sequence[GroundTruth],
    tf: float = 0.5,
    tb: float = 0.1,
) -> Dict[str, float]:
    """Per-category delta-mAP (oracle fix gain) plus raw error counts.

    Returns ``{category: dmap, f"{category}_count": int, "base_map": float}``.
    """
    base_acc = APAccumulator((tf,))
    counts = {c: 0 for c in CATEGORIES}
    for det, gt in zip(detections, ground_truths):
        base_acc.add(match_detections(det, gt, (tf,)))
        labels, _, _, missed = _classify_image(det, gt, tf, tb)
        for ci, c in enumerate(CATEGORIES[:-1]):
            counts[c] += int(np.sum(labels == ci))
        counts["miss"] += int(np.sum(missed))
    base_map = base_acc.map()
    out: Dict[str, float] = {"base_map": base_map}
    for cat in CATEGORIES:
        acc = APAccumulator((tf,))
        for det, gt in zip(detections, ground_truths):
            acc.add(_fixed_eval(det, gt, cat, tf, tb))
        out[cat] = max(acc.map() - base_map, 0.0)
        out[f"{cat}_count"] = counts[cat]
    return out
