"""Qwen2-VL-2B language backbone [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  M-RoPE with
(temporal, height, width) frequency sections (16, 24, 24) over head_dim/2.
The ViT encoder + projector is STUBBED: ``input_specs`` supplies projected
patch embeddings as a vision prefix (dynamic-resolution handled upstream).
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,  # qwen2 family QKV bias
    attn_seq_shard=True,  # 12 heads % 16 != 0 (§Perf #2)
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    vision_tokens=256,  # stubbed vision-prefix length
    tie_embeddings=True,  # qwen2-vl-2b ties embeddings
)
