"""Qwen2-7B [arXiv:2407.10671] — dense decoder, GQA, QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    attn_seq_shard=True,  # 28 heads % 16 != 0 -> context-parallel attention (§Perf #2)
    rope_theta=1e6,
)
