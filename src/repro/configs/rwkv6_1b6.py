"""RWKV6 "Finch" 1.6B [arXiv:2404.05892].

24L d_model=2048, attention-free, d_ff=7168, vocab=65536.  Data-dependent
decay (per-channel w_t from a LoRA of the shifted input) and token-shift
mixing; head_size 64 (32 heads).  Decodes from O(1) recurrent state, so
long_500k runs natively (no window needed).
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="rwkv6-1.6b",
    arch_type="rwkv",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # d_model / head_size
    num_kv_heads=32,
    head_dim=64,
    rwkv_head_size=64,
    d_ff=7168,
    vocab_size=65536,
)
