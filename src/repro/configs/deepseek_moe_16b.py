"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained MoE.

28L d_model=2048 16H (kv=16, full MHA) vocab=102400.  64 routed experts
(top-6) + 2 shared experts, expert d_ff=1408; first layer dense (d_ff
10944 as in the release).  Expert-parallel sharding over the `model` axis.
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense first layer
    d_ff_expert=1408,
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
    first_k_dense=1,
    moe_groups=16,  # group-local dispatch (see EXPERIMENTS.md §Perf #1)
    vocab_size=102400,
    rope_theta=1e4,
)
