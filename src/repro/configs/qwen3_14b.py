"""Qwen3-14B [hf:Qwen/Qwen3-8B family] — dense decoder, GQA + qk_norm.

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.  Per-head RMS
q/k normalisation (qk_norm), no QKV bias (qwen3 dropped it).
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    attn_seq_shard=True,  # 40 heads % 16 != 0 (§Perf #2)
    rope_theta=1e6,
)
