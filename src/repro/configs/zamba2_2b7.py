"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + shared attention.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Every 6th layer applies the single SHARED transformer block (attention +
SwiGLU, one parameter set reused at 9 depths, each with its own KV cache);
the remaining 45 layers are Mamba2 (SSD) blocks.  long_500k decodes from
O(1) SSM state; the shared attention block uses its ring-window cache.
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    mamba_head_dim=64,
    shared_attn_period=6,
)
