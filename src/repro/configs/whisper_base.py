"""Whisper-base [arXiv:2212.04356] — encoder-decoder, audio.

6L (enc) + 6L (dec), d_model=512, 8H (full MHA), d_ff=2048, vocab=51865.
The mel-spectrogram + conv1/conv2 frontend is STUBBED: ``input_specs``
provides (B, 1500, 512) frame embeddings.  Decoder positions are
sinusoidal here (the release uses a learned 448-slot table; our assigned
decode shapes exceed it — deviation recorded in DESIGN.md).
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="whisper-base",
    arch_type="encdec",
    num_layers=6,  # decoder layers
    encoder_layers=6,
    encoder_frames=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    tie_embeddings=True,
    use_rope=False,  # absolute (sinusoidal) positions
    attn_seq_shard=True,  # 8 heads % 16 != 0 (§Perf #2)
)
