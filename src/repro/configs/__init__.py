"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full-scale LMConfig; ``--arch <id>`` in the
launchers resolves through here.  ``long_context_variant`` swaps in the
sliding-window attention config used for the long_500k shape (dense/MoE/VLM
archs; SSM/hybrid run their native recurrent state).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.lm import LMConfig

ARCH_IDS: List[str] = [
    "qwen2_vl_2b",
    "rwkv6_1b6",
    "yi_6b",
    "qwen1_5_32b",
    "qwen2_7b",
    "deepseek_moe_16b",
    "whisper_base",
    "qwen3_14b",
    "deepseek_v2_lite_16b",
    "zamba2_2b7",
]

# public ids as given in the assignment (dashes) -> module names
ALIASES: Dict[str, str] = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "yi-6b": "yi_6b",
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2-7b": "qwen2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-base": "whisper_base",
    "qwen3-14b": "qwen3_14b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "zamba2-2.7b": "zamba2_2b7",
}


def get_config(name: str) -> LMConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def long_context_variant(cfg: LMConfig, window: int = 8192) -> LMConfig:
    """Sliding-window variant for long_500k decode on attention archs.
    SSM/hybrid archs already decode in O(1) state; hybrid additionally
    windows its shared attention block."""
    if cfg.arch_type == "rwkv":
        return cfg
    return dataclasses.replace(cfg, window=window)


def all_configs() -> Dict[str, LMConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
