"""Yi-6B [arXiv:2403.04652] — llama-architecture dense decoder with GQA.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="yi-6b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5e6,
)
