"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434] — MLA + fine-grained MoE.

27L d_model=2048 16H vocab=102400.  MLA: kv_lora_rank=512, qk_nope=128,
qk_rope=64 (decode caches only the 512-d latent + 64-d rope key — the
paper-headline KV saving, implemented in the absorbed form).  MoE: 64
routed experts top-6 + 2 shared, expert d_ff=1408, first layer dense.

NOTE: the assignment bracket says "160 routed" while its headline says
"MoE 64e top-6"; the release has 64 routed — we follow the headline/release
(64) and record the discrepancy here.
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense first layer
    d_ff_expert=1408,
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
    first_k_dense=1,
    moe_groups=16,  # group-local dispatch (see EXPERIMENTS.md §Perf #1)
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    vocab_size=102400,
    rope_theta=1e4,
)
