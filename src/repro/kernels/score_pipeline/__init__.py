from repro.kernels.score_pipeline.ops import (
    PIPELINE_PATHS,
    pipeline_params,
    resolve_pipeline_path,
    score_pipeline,
)
from repro.kernels.score_pipeline.ref import score_pipeline_ref

__all__ = [
    "PIPELINE_PATHS",
    "pipeline_params",
    "resolve_pipeline_path",
    "score_pipeline",
    "score_pipeline_ref",
]
