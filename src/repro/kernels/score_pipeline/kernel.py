"""Pallas kernel: the fused serve-time score pipeline.

One kernel per batch tile takes the *gathered* top-k detection arrays
(selection by confidence is a data-dependent ``argsort`` and stays outside,
see ``ops.py``) and produces the reward estimate with every intermediate —
per-box features, global stats, standardized feature row, hidden
activation — living only in VMEM:

    per-box [s, cx, cy, w, h, area, aspect, onehot(class)]
    global  [n/K, mean, max, entropy, class histogram]
    x   = (concat - mu) / sigma
    out = sigmoid(gelu(x @ W1 + b1) @ W2 + b2)

Layouts mirror ``estimator_mlp``: W2 is padded to (H, 128) so the MXU sees
a 128-lane output, column 0 carries the scalar; F and H are padded to 128
multiples by ops.py, with ``mu`` padded with zeros and ``sigma`` with ones
so the padded feature lanes standardize to exact zeros (and W1's padded
rows are zero, so they never contribute).

The box axis runs at the raw ``top_k`` (25) — on TPU the feature stage is
VPU elementwise work where sublane padding is implicit; the two matmuls
dominate and are fully 128-aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _make_kernel(num_classes: int, top_k: int, f_dim: int):
    def kernel(s_ref, bx_ref, cls_ref, m_ref, w1_ref, b1_ref, w2_ref,
               b2_ref, mu_ref, sig_ref, out_ref):
        s = s_ref[...]  # (TB, K) gathered masked scores
        m = m_ref[...]  # (TB, K) gathered validity as float
        cls = cls_ref[...]  # (TB, K) gathered clipped classes
        bx = bx_ref[...]  # (TB, K, 4) gathered normalized boxes
        TB, K = s.shape

        cx = (bx[..., 0] + bx[..., 2]) / 2
        cy = (bx[..., 1] + bx[..., 3]) / 2
        w = jnp.maximum(bx[..., 2] - bx[..., 0], 0.0)
        h = jnp.maximum(bx[..., 3] - bx[..., 1], 0.0)
        area = w * h
        aspect = jnp.clip(w / jnp.maximum(h, 1e-6), 0.0, 10.0) / 10.0
        # one_hot via broadcasted iota (TPU needs >= 2-D iota)
        cid = lax.broadcasted_iota(jnp.int32, (TB, K, num_classes), 2)
        onehot = jnp.where(
            cid == cls[..., None], 1.0, 0.0
        ).astype(jnp.float32) * m[..., None]
        feats = jnp.concatenate(
            [
                jnp.stack(
                    [s, cx * m, cy * m, w * m, h * m, area * m, aspect * m],
                    axis=-1,
                ),
                onehot,
            ],
            axis=-1,
        )  # (TB, K, 7 + C)

        n = m.sum(axis=1)
        nonempty = n > 0
        safe_n = jnp.maximum(n, 1.0)
        hist = jnp.where(
            nonempty[:, None], onehot.sum(axis=1) / safe_n[:, None], 0.0
        )
        s_sum = s.sum(axis=1)
        p = s / jnp.maximum(s_sum, 1e-9)[:, None]
        entropy = -(p * jnp.log(jnp.maximum(p, 1e-12))).sum(axis=1)
        s_max = jnp.max(jnp.where(m > 0, s, -jnp.inf), axis=1)
        glob = jnp.stack(
            [n / top_k, s_sum / safe_n, jnp.where(nonempty, s_max, 0.0), entropy],
            axis=-1,
        )
        glob = jnp.where(nonempty[:, None], glob, 0.0)
        x = jnp.concatenate([feats.reshape(TB, -1), glob, hist], axis=1)

        f_pad = mu_ref.shape[1] - f_dim
        if f_pad:
            x = jnp.pad(x, ((0, 0), (0, f_pad)))
        x = (x - mu_ref[...]) / sig_ref[...]
        hid = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        hid = jax.nn.gelu(hid + b1_ref[...])
        o = jnp.dot(hid, w2_ref[...], preferred_element_type=jnp.float32)
        out_ref[...] = jax.nn.sigmoid(o + b2_ref[...])

    return kernel


def score_pipeline_pallas(
    s: jnp.ndarray,  # (B, K) gathered masked scores, B % tile_b == 0
    bx: jnp.ndarray,  # (B, K, 4) gathered normalized boxes
    cls: jnp.ndarray,  # (B, K) int32 gathered clipped classes
    m: jnp.ndarray,  # (B, K) float32 gathered validity
    w1: jnp.ndarray,  # (Fp, Hp)
    b1: jnp.ndarray,  # (1, Hp)
    w2: jnp.ndarray,  # (Hp, 128)  col 0 = real weights
    b2: jnp.ndarray,  # (1, 128)
    mu: jnp.ndarray,  # (1, Fp)  zero-padded
    sigma: jnp.ndarray,  # (1, Fp)  one-padded
    num_classes: int,
    f_dim: int,  # unpadded feature dim top_k*(7+C) + 4 + C
    tile_b: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, K = s.shape
    Fp, Hp = w1.shape
    grid = (B // tile_b,)
    return pl.pallas_call(
        _make_kernel(num_classes, K, f_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, K), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, K, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, K), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, K), lambda i: (i, 0)),
            pl.BlockSpec((Fp, Hp), lambda i: (0, 0)),
            pl.BlockSpec((1, Hp), lambda i: (0, 0)),
            pl.BlockSpec((Hp, 128), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
            pl.BlockSpec((1, Fp), lambda i: (0, 0)),
            pl.BlockSpec((1, Fp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 128), jnp.float32),
        interpret=interpret,
    )(s, bx, cls, m, w1, b1, w2, b2, mu, sigma)
