"""Pure-jnp oracle for the fused serve-time score pipeline.

Exactly the composed serve path — top-k box features, standardize, 2-layer
sigmoid MLP — as one unjitted traceable function.  ``ops.py`` jits it for
the portable ``lax`` path; the tests compare the Pallas kernel against it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.features import box_feature_stack
from repro.kernels.estimator_mlp.ref import estimator_mlp_ref


def score_pipeline_ref(
    boxes,  # (B, K, 4) padded detector boxes
    scores,  # (B, K)
    classes,  # (B, K) int32, padded slots -1
    mask,  # (B, K) bool
    w1,  # (F, H)
    b1,  # (H,)
    w2,  # (H,)
    b2,  # ()
    mu,  # (F,) standardize mean (zeros when standardize is off)
    sigma,  # (F,) standardize scale (ones when standardize is off)
    image_size,
    num_classes: int,
    top_k: int,
) -> jnp.ndarray:
    """(B,) reward estimates straight from padded detection arrays."""
    K = scores.shape[1]
    if K < top_k:  # the feature stack slices a fixed top_k window
        pad = top_k - K
        boxes = jnp.pad(boxes, ((0, 0), (0, pad), (0, 0)))
        scores = jnp.pad(scores, ((0, 0), (0, pad)))
        classes = jnp.pad(classes, ((0, 0), (0, pad)), constant_values=-1)
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    f = box_feature_stack(
        boxes, scores, classes, mask, image_size, num_classes, top_k
    )
    x = (f - mu) / sigma
    return estimator_mlp_ref(x, w1, b1, w2, b2)
