"""One device-resident dispatch from padded ``DetectionsBatch`` blocks to
reward estimates — the serve-time hot path with no host materialization
between stages.

The composed path exits to numpy twice per block (features →
``np.asarray``, scores → ``np.asarray``) and re-enters jit three times.
Here the whole pipeline — top-k feature extraction, standardize, estimator
MLP — runs as ONE jitted dispatch:

``"lax"``
    The portable composition: ``score_pipeline_ref`` under ``jax.jit``.
    Because ``estimator_mlp`` resolves to the same plain-jnp MLP math on
    CPU, this path is **bit-identical** to the composed
    ``extract_features_batch → MLPRewardModel.predict`` route (the
    property tests pin this down), while fusing away the host round-trips.
``"pallas"`` / ``"pallas_interpret"``
    The fused Pallas kernel (``kernel.py``): confidence top-k gather stays
    outside (data-dependent ``argsort``), everything downstream — per-box
    features, global stats, standardize, both MLP layers — is one kernel
    with intermediates resident in VMEM.

``path=None`` auto-resolves: ``"pallas"`` where a compiled lowering exists
(TPU/GPU), ``"lax"`` on CPU (the interpreter would be slower than the jit
— the same reasoning as ``repro.kernels.dispatch.resolve_path``).

Buffer donation: on accelerator backends the lax path donates the four
detection arrays (they are consumed by the dispatch — pending blocks are
dead after the policy boundary), letting XLA reuse their buffers for the
feature stage.  CPU ignores donation, so the donating jit is only built
off-CPU.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import feature_dim
from repro.detection.batch import DetectionsBatch
from repro.kernels.score_pipeline.kernel import score_pipeline_pallas
from repro.kernels.score_pipeline.ref import score_pipeline_ref
from repro.obs.jit_stats import register_jit

PIPELINE_PATHS = ("lax", "pallas", "pallas_interpret")


def resolve_pipeline_path(path: Optional[str] = None) -> str:
    """``None`` → ``"pallas"`` on TPU/GPU, ``"lax"`` on CPU."""
    if path is None:
        return "lax" if jax.default_backend() == "cpu" else "pallas"
    if path not in PIPELINE_PATHS:
        raise ValueError(
            f"unknown score-pipeline path {path!r}; use one of {PIPELINE_PATHS}"
        )
    return path


def pipeline_params(model) -> Dict[str, jnp.ndarray]:
    """The device param bundle ``score_pipeline`` consumes, from a *fused*
    ``MLPRewardModel`` (one hidden layer + sigmoid head).  This is the
    uncached builder; ``MLPRewardModel.pipeline_params`` wraps it with an
    identity-keyed cache (safe because weight updates install fresh
    arrays) so the serve hot path skips the eager slicing below."""
    if not getattr(model, "fused", False):
        raise ValueError(
            "score_pipeline needs a fused reward model (single hidden "
            "layer + sigmoid head); score through the composed path instead"
        )
    est = model.estimator
    p = est.params
    w1 = p["layer0"]["w"]
    if model.config.standardize:
        mu = jnp.asarray(est._mu, jnp.float32)
        sigma = jnp.asarray(est._sigma, jnp.float32)
    else:
        # (x - 0) / 1 is exact in IEEE float32: the no-standardize engine
        # keeps bit-identity through the same fused trace
        mu = jnp.zeros((w1.shape[0],), jnp.float32)
        sigma = jnp.ones((w1.shape[0],), jnp.float32)
    return {
        "w1": w1,
        "b1": p["layer0"]["b"],
        "w2": p["layer1"]["w"][:, 0],
        "b2": p["layer1"]["b"][0],
        "mu": mu,
        "sigma": sigma,
    }


_LAX_JITS: Dict[bool, "jax.stages.Wrapped"] = {}


def _lax_jit(donate: bool):
    if donate not in _LAX_JITS:
        kwargs = dict(static_argnames=("num_classes", "top_k"))
        if donate:
            kwargs["donate_argnums"] = (0, 1, 2, 3)
        _LAX_JITS[donate] = register_jit(
            "score_pipeline.lax_donate" if donate else "score_pipeline.lax",
            jax.jit(score_pipeline_ref, **kwargs),
        )
    return _LAX_JITS[donate]


def _ceil_to(n: int, multiple: int) -> int:
    return -(-max(n, 1) // multiple) * multiple


@functools.partial(
    jax.jit, static_argnames=("num_classes", "top_k", "tile_b", "interpret")
)
def _score_pipeline_pallas(
    boxes, scores, classes, mask, w1, b1, w2, b2, mu, sigma,
    image_size, num_classes, top_k, tile_b, interpret,
):
    K = scores.shape[1]
    if K < top_k:  # the kernel slices a fixed top_k window
        pad = top_k - K
        boxes = jnp.pad(boxes, ((0, 0), (0, pad), (0, 0)))
        scores = jnp.pad(scores, ((0, 0), (0, pad)))
        classes = jnp.pad(classes, ((0, 0), (0, pad)), constant_values=-1)
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    # confidence top-k: data-dependent gather, outside the kernel — same
    # selection rule as box_feature_stack (stable, invalid slots sink)
    keys = jnp.where(mask, scores, -jnp.inf)
    order = jnp.argsort(-keys, axis=1, stable=True)[:, :top_k]
    m = jnp.take_along_axis(mask, order, axis=1).astype(jnp.float32)
    s = jnp.take_along_axis(scores, order, axis=1) * m
    cls = jnp.clip(jnp.take_along_axis(classes, order, axis=1), 0, num_classes - 1)
    bx = jnp.take_along_axis(boxes, order[:, :, None], axis=1) / image_size

    B = s.shape[0]
    F, H = w1.shape
    Bp, Fp, Hp = _ceil_to(B, tile_b), _ceil_to(F, 128), _ceil_to(H, 128)
    s_p = jnp.zeros((Bp, top_k), jnp.float32).at[:B].set(s)
    bx_p = jnp.zeros((Bp, top_k, 4), jnp.float32).at[:B].set(bx)
    cls_p = jnp.zeros((Bp, top_k), jnp.int32).at[:B].set(cls)
    m_p = jnp.zeros((Bp, top_k), jnp.float32).at[:B].set(m)
    w1_p = jnp.zeros((Fp, Hp), jnp.float32).at[:F, :H].set(w1)
    b1_p = jnp.zeros((1, Hp), jnp.float32).at[0, :H].set(b1)
    w2_p = jnp.zeros((Hp, 128), jnp.float32).at[:H, 0].set(w2)
    b2_p = jnp.zeros((1, 128), jnp.float32).at[0, 0].set(b2)
    mu_p = jnp.zeros((1, Fp), jnp.float32).at[0, :F].set(mu)
    sig_p = jnp.ones((1, Fp), jnp.float32).at[0, :F].set(sigma)
    out = score_pipeline_pallas(
        s_p, bx_p, cls_p, m_p, w1_p, b1_p, w2_p, b2_p, mu_p, sig_p,
        num_classes=num_classes, f_dim=F, tile_b=tile_b, interpret=interpret,
    )
    return out[:B, 0]


register_jit("score_pipeline.pallas", _score_pipeline_pallas)


def score_pipeline(
    batch: Union[DetectionsBatch, Tuple],
    params: Dict[str, jnp.ndarray],
    *,
    num_classes: int,
    top_k: int = 25,
    image_size: float = 1.0,
    path: Optional[str] = None,
    tile_b: int = 128,
) -> jnp.ndarray:
    """(B,) device-resident reward estimates for a padded detection block.

    ``batch`` is a :class:`DetectionsBatch` or a ``(boxes, scores,
    classes, mask)`` tuple of (possibly already device-resident) arrays;
    ``params`` comes from :func:`pipeline_params`.  The result stays a
    ``jnp`` array — callers convert once at the policy boundary.
    """
    if isinstance(batch, DetectionsBatch):
        arrays = (batch.boxes, batch.scores, batch.classes, batch.mask)
    else:
        arrays = tuple(batch)
    # host arrays go straight into the jit (it converts on dispatch) — an
    # eager jnp.asarray here would cost four extra op dispatches per block
    boxes, scores, classes, mask = arrays
    F = int(params["w1"].shape[0])
    expect = feature_dim(int(num_classes), int(top_k))
    if F != expect:
        raise ValueError(
            f"reward model expects {F} features but the detection extractor "
            f"produces {expect} (num_classes={num_classes}, top_k={top_k})"
        )
    if scores.shape[0] == 0:
        return jnp.zeros((0,), jnp.float32)
    resolved = resolve_pipeline_path(path)
    p = params
    if resolved == "lax":
        fn = _lax_jit(donate=jax.default_backend() != "cpu")
        return fn(
            boxes, scores, classes, mask,
            p["w1"], p["b1"], p["w2"], p["b2"], p["mu"], p["sigma"],
            np.float32(image_size), int(num_classes), int(top_k),
        )
    return _score_pipeline_pallas(
        boxes, scores, classes, mask,
        p["w1"], p["b1"], p["w2"], p["b2"], p["mu"], p["sigma"],
        np.float32(image_size), int(num_classes), int(top_k),
        int(tile_b), resolved == "pallas_interpret",
    )
