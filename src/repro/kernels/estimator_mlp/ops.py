"""jit'd wrapper: pads (B, F, H) to MXU-aligned multiples and calls the
fused kernel; also adapts a trained ``RewardEstimator`` (128, 1)-hidden
param dict when its shape matches the 2-layer form.

``interpret=None`` resolves through ``repro.kernels.dispatch``: the plain
jnp forward on CPU (same math as ``estimator_mlp_ref``, no padding — the
fastest correct path, and the one the fused score pipeline inlines so the
composed and fused serve paths stay bit-identical), compiled Pallas on
TPU/GPU.  Booleans force the interpreter/compiled lowerings as before.
"""
from __future__ import annotations

import functools
from typing import Union

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_path
from repro.kernels.estimator_mlp.kernel import estimator_mlp_pallas
from repro.kernels.estimator_mlp.ref import estimator_mlp_ref
from repro.obs.jit_stats import register_jit

_mlp_ref_jit = register_jit("estimator_mlp.ref", jax.jit(estimator_mlp_ref))


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def _estimator_mlp_pallas(x, w1, b1, w2, b2, tile_b, interpret):
    B, F = x.shape
    H = w1.shape[1]
    Bp = -(-B // tile_b) * tile_b
    Fp = -(-F // 128) * 128
    Hp = -(-H // 128) * 128
    x_p = _pad_to(_pad_to(x, Bp, 0), Fp, 1).astype(jnp.float32)
    w1_p = _pad_to(_pad_to(w1, Fp, 0), Hp, 1).astype(jnp.float32)
    b1_p = _pad_to(b1[None, :], Hp, 1).astype(jnp.float32)
    w2_p = jnp.zeros((Hp, 128), jnp.float32).at[:H, 0].set(w2.astype(jnp.float32))
    b2_p = jnp.zeros((1, 128), jnp.float32).at[0, 0].set(b2.astype(jnp.float32))
    out = estimator_mlp_pallas(x_p, w1_p, b1_p, w2_p, b2_p, tile_b, interpret)
    return out[:B, 0]


register_jit("estimator_mlp.pallas", _estimator_mlp_pallas)


def estimator_mlp(
    x: jnp.ndarray,  # (B, F)
    w1: jnp.ndarray,  # (F, H)
    b1: jnp.ndarray,  # (H,)
    w2: jnp.ndarray,  # (H,)
    b2: jnp.ndarray,  # ()
    tile_b: int = 128,
    interpret: Union[None, bool, str] = None,
) -> jnp.ndarray:
    if x.shape[0] == 0:  # degenerate batch: the padded grid would be empty
        return jnp.zeros((0,), jnp.float32)
    path = resolve_path(interpret)
    if path == "reference":
        return _mlp_ref_jit(x, w1, b1, w2, b2)
    return _estimator_mlp_pallas(x, w1, b1, w2, b2, tile_b, path == "interpret")
