"""Pure-jnp oracle: the estimator MLP forward (2-layer, GELU, sigmoid)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def estimator_mlp_ref(x, w1, b1, w2, b2) -> jnp.ndarray:
    """x (B,F), w1 (F,H), b1 (H,), w2 (H,), b2 () -> (B,)."""
    h = jax.nn.gelu(x @ w1 + b1)
    return jax.nn.sigmoid(h @ w2 + b2)
