"""Pallas TPU kernel: fused 2-layer reward-estimator MLP inference.

The paper's deployable artifact is a ~0.5 ms on-device MLP (TensorRT FP16
fused).  TPU-native equivalent: one kernel computing
``sigmoid((gelu(x·W1 + b1))·W2 + b2)`` per batch tile — the hidden
activation lives only in VMEM (no HBM round-trip between layers).

Layout: x (B, F) tiled (TB, F); W1 (F, H) resident per step; W2 padded to
(H, 128) so the MXU sees a 128-lane output; column 0 carries the scalar
output.  F and H are padded to 128 multiples by ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    x = x_ref[...]  # (TB, F)
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h + b1_ref[...])
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = jax.nn.sigmoid(o + b2_ref[...])


def estimator_mlp_pallas(
    x: jnp.ndarray,  # (B, F)  B % tile_b == 0, F % 128 == 0
    w1: jnp.ndarray,  # (F, H)  H % 128 == 0
    b1: jnp.ndarray,  # (1, H)
    w2: jnp.ndarray,  # (H, 128)  col 0 = real weights
    b2: jnp.ndarray,  # (1, 128)
    tile_b: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, F = x.shape
    H = w1.shape[1]
    grid = (B // tile_b,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, F), lambda i: (i, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((1, H), lambda i: (0, 0)),
            pl.BlockSpec((H, 128), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 128), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
