from repro.kernels.estimator_mlp.ops import estimator_mlp
from repro.kernels.estimator_mlp.ref import estimator_mlp_ref

__all__ = ["estimator_mlp", "estimator_mlp_ref"]
