"""Pure-jnp oracle: masked SDPA (same semantics as models.layers._sdpa
restricted to one head per row)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_sdpa_ref(
    q: jnp.ndarray,  # (BH, S, D)
    k: jnp.ndarray,  # (BH, T, D)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    S, T, D = q.shape[1], k.shape[1], q.shape[2]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (D ** 0.5)
    qpos = q_offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
