"""Pallas TPU kernel: flash (online-softmax) SDPA with causal + sliding
window masking.

The XLA path bounds attention residency by query-chunking (lax.map); the
TPU-native version goes further: the (TQ, T) logits tile never exists —
the kernel streams KV tiles through VMEM with running max/denominator
(the flash-attention recurrence), emitting one (TQ, D) output block per
grid step.  This is the hot kernel of the long_500k serving shape, where
the window (8192) keys × 128 dims fit VMEM comfortably (8192·128·4·2 =
8 MB for K and V).

Grid: (B·H, S/TQ).  Per step: q (TQ, D) block; K/V (T, D) resident;
fori_loop over T/TK tiles with running (m, l, acc) in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, *, tq: int, tk: int,
                  causal: bool, window: int, q_offset: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (TQ, D)
    T = k_ref.shape[1]
    D = q.shape[-1]
    scale = 1.0 / (D ** 0.5)
    qpos = q_offset + qi * tq + jax.lax.iota(jnp.int32, tq)  # (TQ,)

    n_tiles = T // tk

    def body(t, carry):
        m, l, acc = carry  # (TQ,), (TQ,), (TQ, D)
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], t * tk, tk, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], t * tk, tk, axis=0)
        s = (q @ k.astype(jnp.float32).T) * scale  # (TQ, TK)
        kpos = t * tk + jax.lax.iota(jnp.int32, tk)
        mask = jnp.ones((tq, tk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> nan
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(jnp.where(mask, s - m_safe[:, None], -jnp.inf))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l, acc

    m0 = jnp.full((tq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((tq,), jnp.float32)
    acc0 = jnp.zeros((tq, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    out_ref[0] = out.astype(out_ref.dtype)


def flash_sdpa_pallas(
    q: jnp.ndarray,  # (BH, S, D)
    k: jnp.ndarray,  # (BH, T, D)
    v: jnp.ndarray,
    tq: int = 128,
    tk: int = 128,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, S, D = q.shape
    T = k.shape[1]
    assert S % tq == 0 and T % tk == 0, (S, T, tq, tk)
    kern = functools.partial(
        _flash_kernel, tq=tq, tk=tk, causal=causal, window=window,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kern,
        grid=(BH, S // tq),
        in_specs=[
            pl.BlockSpec((1, tq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, T, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, T, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
