from repro.kernels.flash_sdpa.ops import flash_sdpa
from repro.kernels.flash_sdpa.ref import flash_sdpa_ref

__all__ = ["flash_sdpa", "flash_sdpa_ref"]
