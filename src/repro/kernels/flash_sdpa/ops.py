"""jit'd wrapper: maps model-layout GQA tensors onto the kernel's
(B·H, S, D) layout (each query head streams its kv head's K/V) and pads
S/T to tile multiples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_sdpa.kernel import flash_sdpa_pallas


@functools.partial(
    jax.jit, static_argnames=("tq", "tk", "causal", "window", "q_offset", "interpret")
)
def flash_sdpa(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, T, K, D)  GQA: H % K == 0
    v: jnp.ndarray,
    tq: int = 128,
    tk: int = 128,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    Sp = -(-S // tq) * tq
    Tp = -(-T // tk) * tk
    qf = jnp.moveaxis(q, 1, 2).reshape(B * H, S, D)
    kf = jnp.repeat(jnp.moveaxis(k, 1, 2), G, axis=1).reshape(B * H, T, D)
    vf = jnp.repeat(jnp.moveaxis(v, 1, 2), G, axis=1).reshape(B * H, T, D)
    if Sp != S:
        qf = jnp.pad(qf, ((0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        # padded keys sit at positions >= T; causal masking with
        # q_offset < T keeps them masked for all real queries
        kf = jnp.pad(kf, ((0, 0), (0, Tp - T), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, Tp - T), (0, 0)))
    out = flash_sdpa_pallas(
        qf, kf, vf, tq=tq, tk=tk, causal=causal, window=window,
        q_offset=q_offset, interpret=interpret,
    )
    out = out[:, :S].reshape(B, H, S, D)
    return jnp.moveaxis(out, 1, 2)
