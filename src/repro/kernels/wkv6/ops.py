"""jit'd wrapper reshaping (B, T, H, ...) model tensors to the kernel's
(B·H, T, ...) layout and broadcasting the per-head bonus."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(
    r: jnp.ndarray,  # (B, T, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, T, H, V)
    w: jnp.ndarray,  # (B, T, H, K)
    u: jnp.ndarray,  # (H, K) per-head bonus
    s0: jnp.ndarray,  # (B, H, K, V)
    interpret: bool = True,
):
    B, T, H, K = r.shape
    V = v.shape[-1]

    def fold(x):
        return jnp.moveaxis(x, 1, 2).reshape(B * H, T, x.shape[-1])

    u_b = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K, 1)
    out, sT = wkv6_pallas(
        fold(r).astype(jnp.float32),
        fold(k).astype(jnp.float32),
        fold(v).astype(jnp.float32),
        fold(w).astype(jnp.float32),
        u_b.astype(jnp.float32),
        s0.reshape(B * H, K, V).astype(jnp.float32),
        interpret=interpret,
    )
    out = jnp.moveaxis(out.reshape(B, H, T, V), 1, 2)  # (B, T, H, V)
    return out, sT.reshape(B, H, K, V)
