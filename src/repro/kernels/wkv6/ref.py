"""Pure-jnp oracle for the WKV6 recurrence (scan form, as in
repro.models.layers.rwkv6_time_mix's inner loop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0):
    """r/k/w: (BH, T, K); v: (BH, T, V); u: (BH, K, 1); s0: (BH, K, V).
    Returns (out (BH, T, V), sT (BH, K, V))."""

    def step(state, inp):
        rt, kt, vt, wt = inp  # (BH,K),(BH,K),(BH,V),(BH,K)
        kv = kt[:, :, None] * vt[:, None, :]  # (BH,K,V)
        out_t = ((state + u * kv) * rt[:, :, None]).sum(axis=1)  # (BH,V)
        state = wt[:, :, None] * state + kv
        return state, out_t

    sT, out = jax.lax.scan(
        step,
        s0,
        (
            jnp.moveaxis(r, 1, 0),
            jnp.moveaxis(k, 1, 0),
            jnp.moveaxis(v, 1, 0),
            jnp.moveaxis(w, 1, 0),
        ),
    )
    return jnp.moveaxis(out, 0, 1), sT
