"""Pallas TPU kernel: RWKV6 WKV recurrence with data-dependent decay.

The only assigned-arch hot loop that is not a plain matmul.  Per (batch,
head): state S ∈ R^{K×V} evolves as

    out_t = r_t · (S + u ⊙ k_t ⊗ v_t)
    S     = diag(w_t) · S + k_t ⊗ v_t

Grid: one step per (batch·head); the full (T, K) strips for r/k/v/w and the
(K, V) state live in VMEM (T=4096, K=V=64 → 4 strips ≈ 4 MB + 16 KB state),
and the time loop runs as ``fori_loop`` over VMEM-resident tiles — HBM is
touched once per strip instead of once per step, which is the entire point
of fusing the recurrence on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, out_ref, sT_ref):
    T = r_ref.shape[1]
    u = u_ref[0]  # (K, 1) bonus
    state0 = s0_ref[0]  # (K, V)

    def step(t, state):
        rt = r_ref[0, t, :]  # (K,)
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]  # (V,)
        wt = w_ref[0, t, :]  # (K,)
        kv = kt[:, None] * vt[None, :]  # (K, V)
        out_t = (rt[:, None] * (state + u * kv)).sum(axis=0)  # (V,)
        out_ref[0, t, :] = out_t
        return wt[:, None] * state + kv

    sT = jax.lax.fori_loop(0, T, step, state0)
    sT_ref[0] = sT


def wkv6_pallas(
    r: jnp.ndarray,  # (BH, T, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (BH, T, V)
    w: jnp.ndarray,  # (BH, T, K) decay in (0, 1)
    u: jnp.ndarray,  # (BH, K, 1) bonus
    s0: jnp.ndarray,  # (BH, K, V) incoming state
    interpret: bool = True,
):
    BH, T, K = r.shape
    V = v.shape[-1]
    out, sT = pl.pallas_call(
        _wkv_kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, T, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T, V), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, K, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, K, V), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, V), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, K, V), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), jnp.float32),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, sT
