from repro.kernels.iou_matrix.ops import (
    iou_matrix,
    iou_matrix_batch,
    resolve_interpret,
    resolve_path,
)
from repro.kernels.iou_matrix.ref import iou_matrix_batch_ref, iou_matrix_ref

__all__ = [
    "iou_matrix",
    "iou_matrix_batch",
    "iou_matrix_batch_ref",
    "iou_matrix_ref",
    "resolve_interpret",
    "resolve_path",
]
