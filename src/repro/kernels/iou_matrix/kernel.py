"""Pallas TPU kernel: pairwise IoU matrix.

The inner loop of AP matching and ORIC's context evaluation is the N×M
pairwise IoU between detection and ground-truth boxes.  TPU-native layout:
boxes are passed TRANSPOSED, (4, N) — the long axis rides the 128-wide
lanes; a (TN, TM) output tile is produced per grid step from a (4, TN) and
a (4, TM) strip, all VPU element-wise ops on broadcast corners (no MXU).

Grid: (N/TN, M/TM).  VMEM per step: 4·TN + 4·TM + TN·TM floats —
TN=TM=256 → 260 KB, far under the ~16 MB VMEM budget, leaving room for
double buffering.

``iou_matrix_batch_pallas`` is the per-image batched variant behind the
detection data plane (repro.detection.batch): boxes are (B, 4, K) / (B, 4, M)
and the grid gains a leading image-block axis — one (TB, TN, TM) output tile
per step, each image matched only against its own ground truth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iou_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]  # (4, TN)
    b = b_ref[...]  # (4, TM)
    ax1, ay1, ax2, ay2 = a[0], a[1], a[2], a[3]  # (TN,)
    bx1, by1, bx2, by2 = b[0], b[1], b[2], b[3]  # (TM,)
    # broadcast corners to the (TN, TM) tile
    lt_x = jnp.maximum(ax1[:, None], bx1[None, :])
    lt_y = jnp.maximum(ay1[:, None], by1[None, :])
    rb_x = jnp.minimum(ax2[:, None], bx2[None, :])
    rb_y = jnp.minimum(ay2[:, None], by2[None, :])
    iw = jnp.maximum(rb_x - lt_x, 0.0)
    ih = jnp.maximum(rb_y - lt_y, 0.0)
    inter = iw * ih
    area_a = jnp.maximum(ax2 - ax1, 0.0) * jnp.maximum(ay2 - ay1, 0.0)
    area_b = jnp.maximum(bx2 - bx1, 0.0) * jnp.maximum(by2 - by1, 0.0)
    union = area_a[:, None] + area_b[None, :] - inter
    out_ref[...] = jnp.where(union > 0, inter / jnp.maximum(union, 1e-12), 0.0)


def _iou_batch_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]  # (TB, 4, TN)
    b = b_ref[...]  # (TB, 4, TM)
    ax1, ay1, ax2, ay2 = a[:, 0], a[:, 1], a[:, 2], a[:, 3]  # (TB, TN)
    bx1, by1, bx2, by2 = b[:, 0], b[:, 1], b[:, 2], b[:, 3]  # (TB, TM)
    lt_x = jnp.maximum(ax1[:, :, None], bx1[:, None, :])  # (TB, TN, TM)
    lt_y = jnp.maximum(ay1[:, :, None], by1[:, None, :])
    rb_x = jnp.minimum(ax2[:, :, None], bx2[:, None, :])
    rb_y = jnp.minimum(ay2[:, :, None], by2[:, None, :])
    iw = jnp.maximum(rb_x - lt_x, 0.0)
    ih = jnp.maximum(rb_y - lt_y, 0.0)
    inter = iw * ih
    area_a = jnp.maximum(ax2 - ax1, 0.0) * jnp.maximum(ay2 - ay1, 0.0)
    area_b = jnp.maximum(bx2 - bx1, 0.0) * jnp.maximum(by2 - by1, 0.0)
    union = area_a[:, :, None] + area_b[:, None, :] - inter
    out_ref[...] = jnp.where(union > 0, inter / jnp.maximum(union, 1e-12), 0.0)


def iou_matrix_batch_pallas(
    a_t: jnp.ndarray,  # (B, 4, K) transposed per-image boxes
    b_t: jnp.ndarray,  # (B, 4, M)
    tile_b: int = 8,
    tile_n: int = 128,
    tile_m: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, K, M = a_t.shape[0], a_t.shape[2], b_t.shape[2]
    assert B % tile_b == 0 and K % tile_n == 0 and M % tile_m == 0, (
        B, K, M, tile_b, tile_n, tile_m,
    )
    grid = (B // tile_b, K // tile_n, M // tile_m)
    return pl.pallas_call(
        _iou_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, 4, tile_n), lambda b, i, j: (b, 0, i)),
            pl.BlockSpec((tile_b, 4, tile_m), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_n, tile_m), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, K, M), a_t.dtype),
        interpret=interpret,
    )(a_t, b_t)


def iou_matrix_pallas(
    a_t: jnp.ndarray,  # (4, N) transposed boxes
    b_t: jnp.ndarray,  # (4, M)
    tile_n: int = 256,
    tile_m: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    N, M = a_t.shape[1], b_t.shape[1]
    assert N % tile_n == 0 and M % tile_m == 0, (N, M, tile_n, tile_m)
    grid = (N // tile_n, M // tile_m)
    return pl.pallas_call(
        _iou_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, tile_n), lambda i, j: (0, i)),
            pl.BlockSpec((4, tile_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), a_t.dtype),
        interpret=interpret,
    )(a_t, b_t)
