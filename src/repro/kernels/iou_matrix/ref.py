"""Pure-jnp oracle for the IoU kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.detection.boxes import box_iou


def iou_matrix_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: (N, 4), b: (M, 4) -> (N, M)."""
    return box_iou(a, b)
