"""Pure-jnp oracles for the IoU kernels (also the serve-time "reference"
dispatch path on CPU — see ``repro.kernels.dispatch``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.detection.boxes import box_iou


def iou_matrix_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: (N, 4), b: (M, 4) -> (N, M)."""
    return box_iou(a, b)


def iou_matrix_batch_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: (B, K, 4), b: (B, M, 4) -> (B, K, M); image i only against its
    own row.  Elementwise per pair, so sharding the batch axis is trivially
    bit-identical (no grid-shape compilation regimes)."""
    return jax.vmap(box_iou)(a, b)
