"""jit'd wrappers: pad to tile multiples, transpose to the lane-aligned
``(..., 4, N)`` layout, call the Pallas kernel, crop.

``interpret=None`` (the default) resolves to the backend: compiled Pallas on
TPU/GPU, interpreter mode only where no compiled lowering exists (the CPU
test/CI environments).  Passing an explicit bool forces either path — the
benchmarks thread it through to compare the two.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.iou_matrix.kernel import iou_matrix_batch_pallas, iou_matrix_pallas


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> auto: interpret only when the backend has no compiled Pallas
    lowering (CPU).  TPU (and GPU triton) run the compiled kernel."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_m", "interpret"))
def _iou_matrix(a, b, tile_n, tile_m, interpret):
    N, M = a.shape[0], b.shape[0]
    Np = -(-max(N, 1) // tile_n) * tile_n
    Mp = -(-max(M, 1) // tile_m) * tile_m
    # pad with degenerate boxes (zero area -> IoU 0)
    a_p = jnp.zeros((Np, 4), a.dtype).at[:N].set(a)
    b_p = jnp.zeros((Mp, 4), b.dtype).at[:M].set(b)
    out = iou_matrix_pallas(a_p.T, b_p.T, tile_n, tile_m, interpret=interpret)
    return out[:N, :M]


def iou_matrix(
    a: jnp.ndarray,  # (N, 4)
    b: jnp.ndarray,  # (M, 4)
    tile_n: int = 256,
    tile_m: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    return _iou_matrix(a, b, tile_n, tile_m, resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("tile_b", "tile_n", "tile_m", "interpret")
)
def _iou_matrix_batch(a, b, tile_b, tile_n, tile_m, interpret):
    B, K, M = a.shape[0], a.shape[1], b.shape[1]
    Bp = -(-max(B, 1) // tile_b) * tile_b
    Kp = -(-max(K, 1) // tile_n) * tile_n
    Mp = -(-max(M, 1) // tile_m) * tile_m
    a_p = jnp.zeros((Bp, Kp, 4), a.dtype).at[:B, :K].set(a)
    b_p = jnp.zeros((Bp, Mp, 4), b.dtype).at[:B, :M].set(b)
    out = iou_matrix_batch_pallas(
        a_p.transpose(0, 2, 1), b_p.transpose(0, 2, 1),
        tile_b, tile_n, tile_m, interpret=interpret,
    )
    return out[:B, :K, :M]


def iou_matrix_batch(
    a: jnp.ndarray,  # (B, K, 4) per-image boxes
    b: jnp.ndarray,  # (B, M, 4)
    tile_b: int = 8,
    tile_n: int = 128,
    tile_m: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Per-image pairwise IoU, image i matched only against its own row:
    ``out[i] = iou(a[i], b[i])`` with shape (B, K, M)."""
    return _iou_matrix_batch(a, b, tile_b, tile_n, tile_m, resolve_interpret(interpret))
