"""jit'd wrappers: pad to tile multiples, transpose to the lane-aligned
``(..., 4, N)`` layout, call the Pallas kernel, crop.

``interpret=None`` (the default) resolves through
:func:`repro.kernels.dispatch.resolve_path`: the jnp reference on CPU
(where the Pallas interpreter measured *slower* than the oracle — the
BENCH_fc34508 regression), compiled Pallas on TPU/GPU.  Booleans force the
interpreter/compiled lowerings as before; the string ``"reference"``
forces the oracle.

Tile sizes default per path (``None``): the compiled lowering keeps the
MXU-friendly 256-lane tiles; interpreter mode shrinks tiles to the padded
problem so small inputs stop paying for 256x256 zero-padding (the
tile-size sweep behind the defaults lives in ``bench_iou``).
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret, resolve_path
from repro.kernels.iou_matrix.kernel import iou_matrix_batch_pallas, iou_matrix_pallas
from repro.kernels.iou_matrix.ref import iou_matrix_batch_ref, iou_matrix_ref
from repro.obs.jit_stats import register_jit

__all__ = [
    "iou_matrix",
    "iou_matrix_batch",
    "resolve_interpret",
    "resolve_path",
]

_iou_ref_jit = register_jit("iou_matrix.ref", jax.jit(iou_matrix_ref))
_iou_batch_ref_jit = register_jit(
    "iou_matrix.batch_ref", jax.jit(iou_matrix_batch_ref)
)


def _ceil_to(n: int, multiple: int) -> int:
    return -(-max(n, 1) // multiple) * multiple


def _default_tile(n: int, compiled_tile: int, path: str) -> int:
    """Interpreter tiles shrink to the 128-padded problem (fewer wasted
    lanes, same few grid steps); the compiled lowering keeps full tiles."""
    if path == "interpret":
        return min(compiled_tile, _ceil_to(n, 128))
    return compiled_tile


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_m", "interpret"))
def _iou_matrix(a, b, tile_n, tile_m, interpret):
    N, M = a.shape[0], b.shape[0]
    Np = _ceil_to(N, tile_n)
    Mp = _ceil_to(M, tile_m)
    # pad with degenerate boxes (zero area -> IoU 0)
    a_p = jnp.zeros((Np, 4), a.dtype).at[:N].set(a)
    b_p = jnp.zeros((Mp, 4), b.dtype).at[:M].set(b)
    out = iou_matrix_pallas(a_p.T, b_p.T, tile_n, tile_m, interpret=interpret)
    return out[:N, :M]


register_jit("iou_matrix.pallas", _iou_matrix)


def iou_matrix(
    a: jnp.ndarray,  # (N, 4)
    b: jnp.ndarray,  # (M, 4)
    tile_n: Optional[int] = None,
    tile_m: Optional[int] = None,
    interpret: Union[None, bool, str] = None,
) -> jnp.ndarray:
    path = resolve_path(interpret)
    if path == "reference":
        return _iou_ref_jit(a, b)
    tile_n = _default_tile(a.shape[0], 256, path) if tile_n is None else tile_n
    tile_m = _default_tile(b.shape[0], 256, path) if tile_m is None else tile_m
    return _iou_matrix(a, b, tile_n, tile_m, path == "interpret")


@functools.partial(
    jax.jit, static_argnames=("tile_b", "tile_n", "tile_m", "interpret")
)
def _iou_matrix_batch(a, b, tile_b, tile_n, tile_m, interpret):
    B, K, M = a.shape[0], a.shape[1], b.shape[1]
    Bp = _ceil_to(B, tile_b)
    Kp = _ceil_to(K, tile_n)
    Mp = _ceil_to(M, tile_m)
    a_p = jnp.zeros((Bp, Kp, 4), a.dtype).at[:B, :K].set(a)
    b_p = jnp.zeros((Bp, Mp, 4), b.dtype).at[:B, :M].set(b)
    out = iou_matrix_batch_pallas(
        a_p.transpose(0, 2, 1), b_p.transpose(0, 2, 1),
        tile_b, tile_n, tile_m, interpret=interpret,
    )
    return out[:B, :K, :M]


register_jit("iou_matrix.batch_pallas", _iou_matrix_batch)


def iou_matrix_batch(
    a: jnp.ndarray,  # (B, K, 4) per-image boxes
    b: jnp.ndarray,  # (B, M, 4)
    tile_b: int = 8,
    tile_n: int = 128,
    tile_m: int = 128,
    interpret: Union[None, bool, str] = None,
) -> jnp.ndarray:
    """Per-image pairwise IoU, image i matched only against its own row:
    ``out[i] = iou(a[i], b[i])`` with shape (B, K, M)."""
    path = resolve_path(interpret)
    if path == "reference":
        return _iou_batch_ref_jit(a, b)
    return _iou_matrix_batch(a, b, tile_b, tile_n, tile_m, path == "interpret")
