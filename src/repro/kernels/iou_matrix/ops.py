"""jit'd wrapper: pads to tile multiples, transposes to the lane-aligned
(4, N) layout, calls the Pallas kernel, crops."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.iou_matrix.kernel import iou_matrix_pallas


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_m", "interpret"))
def iou_matrix(
    a: jnp.ndarray,  # (N, 4)
    b: jnp.ndarray,  # (M, 4)
    tile_n: int = 256,
    tile_m: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    N, M = a.shape[0], b.shape[0]
    Np = -(-max(N, 1) // tile_n) * tile_n
    Mp = -(-max(M, 1) // tile_m) * tile_m
    # pad with degenerate boxes (zero area -> IoU 0)
    a_p = jnp.zeros((Np, 4), a.dtype).at[:N].set(a)
    b_p = jnp.zeros((Mp, 4), b.dtype).at[:M].set(b)
    out = iou_matrix_pallas(a_p.T, b_p.T, tile_n, tile_m, interpret=interpret)
    return out[:N, :M]
