"""Backend-aware kernel path resolution, shared by the serve-time kernels.

Every Pallas wrapper in this package used to expose ``interpret: bool`` —
compiled lowering vs the Pallas interpreter.  That binary missed the case
CI actually runs: on CPU the interpreter is frequently *slower* than the
plain-jnp oracle (``artifacts/BENCH_fc34508.json`` recorded the interpreted
IoU kernel at 1.75x the jnp reference), so "auto" must be a three-way
choice:

``"compiled"``
    The real Pallas lowering — TPU (and GPU triton).
``"interpret"``
    The Pallas interpreter: one Python step per grid cell.  Correctness
    testing of the kernel semantics on hosts without a compiled lowering.
``"reference"``
    The pure-jnp oracle under ``jax.jit`` — the fastest *correct* path on
    CPU, and trivially shard-safe (elementwise/per-row math, no grid-shape
    compilation regimes).

``resolve_path(None)`` picks ``"reference"`` on CPU and ``"compiled"``
everywhere else; booleans keep their historical meaning (``True`` →
interpreter, ``False`` → compiled) so existing callers that thread
``interpret=`` flags through are unchanged.  The resolution is
deterministic per process — no runtime autotuning — because the fleet
plane's bit-identity contract compares results across processes.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

KERNEL_PATHS = ("compiled", "interpret", "reference")


def resolve_path(interpret: Union[None, bool, str] = None) -> str:
    """Resolve an ``interpret=`` argument to one of :data:`KERNEL_PATHS`.

    ``None`` → ``"reference"`` on CPU, ``"compiled"`` on TPU/GPU;
    ``True``/``False`` → ``"interpret"``/``"compiled"``; a path string
    passes through (validated).
    """
    if interpret is None:
        return "reference" if jax.default_backend() == "cpu" else "compiled"
    if isinstance(interpret, str):
        if interpret not in KERNEL_PATHS:
            raise ValueError(
                f"unknown kernel path {interpret!r}; use one of {KERNEL_PATHS}"
            )
        return interpret
    return "interpret" if interpret else "compiled"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """The legacy two-way resolution for callers that only choose between
    the Pallas lowerings: ``None`` → interpreter exactly where no compiled
    lowering exists (CPU).  Kept for the video tracker and forced-mode
    benchmarks; new code should prefer :func:`resolve_path`."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)
