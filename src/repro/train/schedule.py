"""LR schedules as step -> lr callables (jit-safe)."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(
    peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
