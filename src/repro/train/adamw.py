"""AdamW implemented directly on pytrees (no optax dependency)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.zeros_like, params))


def adamw_update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    lr: jnp.ndarray | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    grad_clip: float | None = 1.0,
) -> Tuple[PyTree, AdamWState]:
    """One AdamW step; returns (new_params, new_state)."""
    if grad_clip is not None:
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves) + 1e-12)
        scale = jnp.minimum(1.0, grad_clip / gnorm)
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
