"""Detector training loop (weak & strong models of the repro)."""
from __future__ import annotations

import functools
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.shapes import ShapesDataset
from repro.models.detector import (
    DetectorConfig,
    build_targets,
    detector_init,
    detector_loss,
)
from repro.train.adamw import adamw_init, adamw_update
from repro.train.schedule import warmup_cosine


def train_detector(
    cfg: DetectorConfig,
    dataset: ShapesDataset,
    steps: int = 600,
    batch_size: int = 64,
    peak_lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 100,
) -> Tuple[dict, List[float]]:
    """Returns (params, loss trace)."""
    key = jax.random.PRNGKey(seed)
    params = detector_init(key, cfg)
    opt_state = adamw_init(params)
    sched = warmup_cosine(peak_lr, max(steps // 10, 1), steps)

    @functools.partial(jax.jit, static_argnames=("cfg",))
    def step_fn(params, opt_state, images, obj_t, cls_t, box_t, lr, *, cfg):
        loss, grads = jax.value_and_grad(detector_loss)(
            params, cfg, images, obj_t, cls_t, box_t
        )
        params, opt_state = adamw_update(
            grads, opt_state, params, lr, weight_decay=1e-4
        )
        return params, opt_state, loss

    rng = np.random.default_rng(seed + 1)
    losses: List[float] = []
    it = 0
    t0 = time.time()
    while it < steps:
        for imgs, boxes, classes in dataset.batches(batch_size, rng):
            obj_t, cls_t, box_t = build_targets(cfg, boxes, classes)
            params, opt_state, loss = step_fn(
                params, opt_state,
                jnp.asarray(imgs), jnp.asarray(obj_t), jnp.asarray(cls_t),
                jnp.asarray(box_t), sched(it), cfg=cfg,
            )
            losses.append(float(loss))
            it += 1
            if log_every and it % log_every == 0:
                rate = it / (time.time() - t0)
                print(f"  [{cfg.name}] step {it}/{steps} loss {float(loss):.4f} ({rate:.1f} it/s)")
            if it >= steps:
                break
    return params, losses
