"""Pytree checkpointing to .npz (flat key-path encoding, no pickle).

Two layers: ``save_pytree``/``load_pytree`` for model params (load requires a
``like`` template), and ``save_flat``/``load_flat`` for self-describing
nested string-keyed dicts of arrays plus a JSON metadata block — the format
``repro.api.OffloadEngine.save`` uses for deployable decision stacks.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "||"
_META_KEY = "__meta__"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat, _ = _flatten(tree)
    np.savez(path, **flat)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Load a checkpoint into the structure of ``like``."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(x) for x in p)
        arr = data[key]
        leaves.append(arr.astype(np.asarray(leaf).dtype).reshape(np.shape(leaf)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _flatten_strdict(tree: Dict[str, Any], prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for k, v in tree.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(_flatten_strdict(v, key))
        else:
            out[key] = np.asarray(v)
    return out


def save_flat(path: str, arrays: Dict[str, Any], meta: Optional[dict] = None) -> None:
    """Save a nested string-keyed dict of arrays (+ JSON meta) to one .npz."""
    flat = _flatten_strdict(arrays)
    if meta is not None:
        flat[_META_KEY] = np.asarray(json.dumps(meta))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def load_flat(path: str) -> Tuple[Dict[str, Any], Optional[dict]]:
    """Inverse of ``save_flat``: (nested arrays dict, meta-or-None).  Needs
    no template — the key paths are self-describing."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    meta = None
    tree: Dict[str, Any] = {}
    for key in data.files:
        if key == _META_KEY:
            meta = json.loads(str(data[key].item()))
            continue
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = data[key]
    return tree, meta
