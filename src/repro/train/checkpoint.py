"""Pytree checkpointing to .npz (flat key-path encoding, no pickle)."""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "||"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat, _ = _flatten(tree)
    np.savez(path, **flat)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Load a checkpoint into the structure of ``like``."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(x) for x in p)
        arr = data[key]
        leaves.append(arr.astype(np.asarray(leaf).dtype).reshape(np.shape(leaf)))
    return jax.tree_util.tree_unflatten(treedef, leaves)
