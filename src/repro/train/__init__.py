"""Training substrate: hand-written AdamW, LR schedules, checkpointing."""
from repro.train.adamw import AdamWState, adamw_init, adamw_update
from repro.train.schedule import constant_schedule, warmup_cosine
from repro.train.checkpoint import load_pytree, save_pytree

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "constant_schedule",
    "warmup_cosine",
    "load_pytree",
    "save_pytree",
]
