"""`UplinkQueue` — the FIFO device→edge transmission queue.

One transmitter, one queue: a frame enqueued at ``t`` waits for every frame
ahead of it, then occupies the link for its own transmission time.  Because
the discipline is FIFO and link bandwidth is a deterministic function of
time (see :mod:`repro.netsim.link`), the full schedule of a frame —
``t_start`` and ``t_delivered`` — is computable *at enqueue time*; ``poll``
then just surfaces deliveries as the simulation clock passes them.  That
keeps the queue event-driven and wall-clock-free like everything under
``repro.runtime``: all timekeeping flows through explicit ``now`` arguments
(a :class:`repro.runtime.clock.ManualClock` in simulations).

Accounting is conservative by construction: every frame offered to
``enqueue`` is exactly one of **delivered** (eventually, once polled past
its ``t_delivered``) or **dropped** (bounded ``depth`` exceeded at arrival)
— property-tested in ``tests/test_netsim.py``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional

from repro.netsim.link import NetworkLink


@dataclass(frozen=True)
class TransmittedFrame:
    """One frame's uplink story: sojourn = queue wait + transmission."""

    step: int
    size_bits: float
    t_enqueue: float
    t_start: float
    t_delivered: float

    @property
    def queue_delay(self) -> float:
        return self.t_start - self.t_enqueue

    @property
    def transmit_delay(self) -> float:
        return self.t_delivered - self.t_start

    @property
    def sojourn(self) -> float:
        return self.t_delivered - self.t_enqueue


class UplinkQueue:
    """Bounded FIFO in front of a :class:`NetworkLink`.

    Parameters
    ----------
    link : NetworkLink
        Deterministic bandwidth model; transmission of a frame is priced at
        the bandwidth holding when the frame *starts* transmitting.
    depth : int
        Max frames queued-or-transmitting at once; an arrival that finds
        ``depth`` frames in the system is dropped (counted, never silently).
    frame_bits : float
        Default frame size when ``enqueue`` is not given one.
    """

    def __init__(self, link: NetworkLink, *, depth: int = 16, frame_bits: float = 1.0):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if frame_bits < 0.0:
            raise ValueError(f"frame_bits must be >= 0, got {frame_bits}")
        self.link = link
        self.depth = int(depth)
        self.frame_bits = float(frame_bits)
        self._now = 0.0
        self._busy_until = 0.0
        self._pending: Deque[TransmittedFrame] = deque()  # scheduled, undelivered
        self.delivered: List[TransmittedFrame] = []
        self.enqueued = 0
        self.dropped = 0

    # ------------------------------------------------------------------ time

    def _advance(self, now: float) -> None:
        self._now = max(self._now, float(now))

    def poll(self, now: float) -> List[TransmittedFrame]:
        """Surface every frame whose transmission completed by ``now``."""
        self._advance(now)
        done: List[TransmittedFrame] = []
        while self._pending and self._pending[0].t_delivered <= self._now:
            f = self._pending.popleft()
            done.append(f)
            self.delivered.append(f)
        return done

    # ------------------------------------------------------------- admission

    @property
    def occupancy(self) -> int:
        """Frames queued or transmitting (delivery not yet polled past)."""
        return len(self._pending)

    def full(self, now: float) -> bool:
        """Would an arrival at ``now`` be dropped?  Polls to ``now`` first,
        so admission pipelines can pre-check without spending other
        resources (rate tokens) on a frame the queue would refuse."""
        self.poll(now)
        return len(self._pending) >= self.depth

    def enqueue(
        self, now: float, step: int, size_bits: Optional[float] = None
    ) -> Optional[TransmittedFrame]:
        """Offer one frame; returns its full (deterministic) schedule, or
        ``None`` when the bounded queue is full and the frame is dropped."""
        self.poll(now)
        if len(self._pending) >= self.depth:
            self.dropped += 1
            return None
        size = self.frame_bits if size_bits is None else float(size_bits)
        t_start = max(self._now, self._busy_until)
        t_delivered = t_start + self.link.transmit_delay(size, t_start)
        frame = TransmittedFrame(
            step=int(step), size_bits=size, t_enqueue=self._now,
            t_start=t_start, t_delivered=t_delivered,
        )
        self._busy_until = t_delivered
        self._pending.append(frame)
        self.enqueued += 1
        return frame

    # ------------------------------------------------------------ prediction

    def predicted_wait(self, now: float) -> float:
        """Queueing delay a frame offered at ``now`` would see before its
        transmission starts (0 when the link is idle).  Pure — no state
        change beyond lazy channel materialization."""
        return max(self._busy_until - max(self._now, float(now)), 0.0)

    def predicted_sojourn(self, now: float, size_bits: Optional[float] = None) -> float:
        """Predicted wait + own transmission time for a frame offered at
        ``now`` — the congestion signal queue-aware policies discount by."""
        t = max(self._now, float(now))
        wait = self.predicted_wait(t)
        size = self.frame_bits if size_bits is None else float(size_bits)
        return wait + self.link.transmit_delay(size, t + wait)

    # ----------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        return {
            "depth": self.depth,
            "enqueued": self.enqueued,
            "delivered": len(self.delivered),
            "dropped": self.dropped,
            "occupancy": len(self._pending),
        }


class DownlinkQueue(UplinkQueue):
    """The edge→device **return** channel: detections coming back also pay
    transit before they count.

    Mechanically identical to :class:`UplinkQueue` (one transmitter, FIFO,
    bounded, deterministic enqueue-time schedules, conservative
    delivered+dropped==enqueued accounting) — the subclass exists so
    topologies read correctly and so result frames get their own default
    size: a detection list is much smaller than the image that produced it,
    so ``frame_bits`` here defaults to a quarter of the uplink convention.

    One semantic difference of *use*, not mechanics: results are enqueued
    at their **service-completion** time, which for concurrently admitted
    offloads need not be monotone in admission order.  The queue serializes
    them in enqueue-call order (``t_start = max(ready, busy_until)``) — the
    return channel is one radio, and the schedule stays deterministic
    because :class:`~repro.runtime.edge.EdgeWorker` enqueues at admission
    time, in admission order.
    """

    def __init__(self, link: NetworkLink, *, depth: int = 32, frame_bits: float = 0.25):
        super().__init__(link, depth=depth, frame_bits=frame_bits)
