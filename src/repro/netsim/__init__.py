"""Event-driven network & queueing simulation for the offloading runtime.

The paper's device→edge link, made explicit: :mod:`repro.netsim.link` prices
size-dependent transmission (constant-rate, trace-driven, or a seeded
Gilbert–Elliott fading channel), :mod:`repro.netsim.queue` is the bounded
FIFO uplink in front of it (per-frame sojourn accounting, deterministic
schedules, manually clocked), and :mod:`repro.netsim.policy` holds the
queue-aware decision policies — the congestion-discounted ``queue_aware``
threshold and the ``value_iteration`` MDP controller over (queue depth ×
channel state), solved as one jitted ``jax.lax.scan``.

Plugs into the serving stack via ``EdgeWorker(link=...)`` (uplink-fronted
edges), ``simulate()`` (per-step queue/transmit/service breakdowns on the
trace), and the ``repro.api`` policy registry (both policies constructible
through ``OffloadEngine``).  See docs/API.md "Network simulation".
"""
from repro.netsim.link import (
    CHANNEL_BAD,
    CHANNEL_GOOD,
    ConstantRateLink,
    GilbertElliottLink,
    NetworkLink,
    TraceBandwidthLink,
)
from repro.netsim.policy import (
    QueueAwarePolicy,
    ValueIterationPolicy,
    quantile_threshold,
    solve_value_iteration,
    value_iteration_ref,
    value_iteration_sweep,
)
from repro.netsim.queue import DownlinkQueue, TransmittedFrame, UplinkQueue

__all__ = [
    "NetworkLink",
    "ConstantRateLink",
    "TraceBandwidthLink",
    "GilbertElliottLink",
    "CHANNEL_GOOD",
    "CHANNEL_BAD",
    "UplinkQueue",
    "DownlinkQueue",
    "TransmittedFrame",
    "QueueAwarePolicy",
    "ValueIterationPolicy",
    "quantile_threshold",
    "solve_value_iteration",
    "value_iteration_ref",
    "value_iteration_sweep",
]
