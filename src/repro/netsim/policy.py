"""Queue-aware offloading policies over the netsim layer.

Two controllers, both registered in the ``repro.api`` policy registry (so
``OffloadEngine(policy="queue_aware")`` / ``"value_iteration"`` and every
runtime built on the engine get them for free):

- ``queue_aware`` — the engine's quantile-threshold rule with the reward
  estimate *discounted by predicted queueing delay* (a bounded penalty
  ``delay_weight * d / (d + delay_scale)``), plus an integral controller on
  the realized ratio so deferring offloads during congestion is paid back
  in uncongested windows — the realized ratio tracks the target while the
  offloads themselves land where the queue is short.
- ``value_iteration`` — the Qiu et al.-style MDP over
  ``(queue depth × channel state)``: value iteration with the calibration
  score distribution as the per-frame reward prior, solved as one **jitted
  ``jax.lax.scan``** over Bellman sweeps (every state updated vectorized per
  sweep, no per-state Python loop), yielding a per-state threshold table
  ``theta[q, c]`` — offload iff estimate > theta at the observed state.
  ``value_iteration_sweep`` vmaps the solver over a ratio grid so policy /
  budget sweeps run batched on device.

Both consume *runtime-injected context* (``congestion`` / ``state_probe``
zero-arg callables, wired by ``OffloadRuntime.open_session`` exactly like
the ``token_bucket`` clock) and degrade gracefully without it: no probe
means no congestion signal, and both collapse to plain threshold behavior.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.api.policies import (
    BudgetTracker,
    decide_sequential,
    quantile_threshold,
    register_policy,
)


# --------------------------------------------------------------- queue_aware


@register_policy("queue_aware")
class QueueAwarePolicy:
    """Quantile threshold on a congestion-discounted estimate, with an
    integral ratio controller.

    Parameters (beyond the registry's ``calibration_scores, ratio``):

    delay_weight : float
        Max penalty subtracted from the estimate as predicted delay grows
        (estimates are rank-transformed into [0, 1] by the engine's CDF, so
        1.0 means "infinite queue kills any offload").
    delay_scale : float
        Delay (in sim time units) at which half the max penalty applies.
    gain : float
        Integral gain of the shared realized-ratio controller
        (:class:`repro.api.policies.BudgetTracker`): any persistent
        suppression — however long the congestion lasts — is eventually
        paid back and the realized ratio converges to the target exactly.
    congestion : callable or None
        Zero-arg probe returning the predicted uplink sojourn (queue wait +
        transmission) at the best edge, in sim time units.  Runtime wiring,
        never serialized (stripped like the token-bucket clock).
    """

    context_params = ("congestion",)

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        delay_weight: float = 0.5,
        delay_scale: float = 2.0,
        gain: float = 0.05,
        congestion: Optional[Callable[[], float]] = None,
    ):
        if delay_scale <= 0.0:
            raise ValueError(f"delay_scale must be > 0, got {delay_scale}")
        self._cal = np.sort(np.asarray(calibration_scores, np.float64))
        self.delay_weight = float(delay_weight)
        self.delay_scale = float(delay_scale)
        self.congestion = congestion
        self._budget = BudgetTracker(gain)
        self.set_ratio(ratio)

    @property
    def gain(self) -> float:
        return self._budget.gain

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))

    def _penalty(self) -> float:
        d = max(float(self.congestion()), 0.0) if self.congestion is not None else 0.0
        return self.delay_weight * d / (d + self.delay_scale)

    def decide(self, estimate: float) -> bool:
        thr = self._budget.threshold(self._cal, self.ratio)
        off = bool(float(estimate) - self._penalty() > thr)
        self._budget.account(off)
        return off

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # sequential by construction: the controller state and the live
        # congestion probe evolve decision to decision
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, Any]:
        return {
            "delay_weight": self.delay_weight,
            "delay_scale": self.delay_scale,
            "gain": self.gain,
        }


# ----------------------------------------------------------- value iteration


def _estimate_bins(calibration_scores: np.ndarray, n_bins: int) -> np.ndarray:
    """Equiprobable discretization of the calibration score distribution
    (bin centers at the mid-bin quantiles, each with mass 1/n_bins)."""
    cal = np.asarray(calibration_scores, np.float64)
    if cal.size == 0:
        return np.zeros(n_bins)
    qs = (np.arange(n_bins) + 0.5) / n_bins
    return np.quantile(cal, qs)


def _vi_sweep_body(V, e_bins, lam, delay_cost, slow, P, gamma, q_off, q_loc):
    """One Bellman sweep over the whole (Q+1, 2) state space, vectorized.

    With ``relu(x) = max(x, 0)`` the backup has the closed form
    ``V(q,c) = E_e[relu(e - theta(q,c))] + gamma * EV_local(q,c)`` where
    ``theta`` is the indifference threshold — exactly the per-state decision
    rule the policy serves with.
    """
    import jax.numpy as jnp

    EV = V @ P.T                                   # (Q+1, 2): E_{c'}[V | c]
    EV_off = EV[q_off]                             # next-state values, offload
    EV_loc = EV[q_loc]                             # next-state values, local
    q_idx = jnp.arange(V.shape[0], dtype=V.dtype)
    theta = (
        lam
        + delay_cost * (q_idx + 1.0)[:, None] * slow[None, :]
        + gamma * (EV_loc - EV_off)
    )
    gain = jnp.mean(
        jnp.maximum(e_bins[:, None, None] - theta[None, :, :], 0.0), axis=0
    )
    return gain + gamma * EV_loc, theta


def solve_value_iteration(
    e_bins: np.ndarray,
    lam: float,
    *,
    max_queue: int = 16,
    delay_cost: float = 0.05,
    bad_slowdown: float = 4.0,
    p_gb: float = 0.1,
    p_bg: float = 0.3,
    gamma: float = 0.9,
    n_sweeps: int = 64,
) -> Tuple[np.ndarray, np.ndarray]:
    """Value-iterate the (queue depth × channel) offloading MDP.

    Returns ``(V, theta)`` with shapes ``(max_queue+1, 2)``: the value table
    and the per-state offload thresholds.  The whole solve is one jitted
    ``lax.scan`` over ``n_sweeps`` Bellman sweeps; every sweep updates all
    states as vectorized array ops — there is no per-state Python loop.
    """
    import jax.numpy as jnp

    _ensure_jit()
    Q = int(max_queue)
    q_idx = np.arange(Q + 1)
    args = (
        jnp.asarray(e_bins, jnp.float32),
        jnp.float32(lam),
        jnp.float32(delay_cost),
        jnp.asarray([1.0, float(bad_slowdown)], jnp.float32),
        jnp.asarray(
            [[1.0 - p_gb, p_gb], [p_bg, 1.0 - p_bg]], jnp.float32
        ),
        jnp.float32(gamma),
        jnp.asarray(np.minimum(q_idx + 1, Q), jnp.int32),
        jnp.asarray(np.maximum(q_idx - 1, 0), jnp.int32),
    )
    V, theta = _solve_jit(*args, n_sweeps=int(n_sweeps))
    return np.asarray(V, np.float64), np.asarray(theta, np.float64)


def _solve_impl(e_bins, lam, delay_cost, slow, P, gamma, q_off, q_loc, *, n_sweeps):
    import jax
    import jax.numpy as jnp

    def sweep(V, _):
        V_new, theta = _vi_sweep_body(
            V, e_bins, lam, delay_cost, slow, P, gamma, q_off, q_loc
        )
        return V_new, None

    V0 = jnp.zeros((q_off.shape[0], 2), jnp.float32)
    V, _ = jax.lax.scan(sweep, V0, None, length=n_sweeps)
    _, theta = _vi_sweep_body(
        V, e_bins, lam, delay_cost, slow, P, gamma, q_off, q_loc
    )
    return V, theta


_solve_jit = None  # populated lazily so importing netsim never pays jax startup
_SWEEP_JIT = {}  # n_sweeps -> persistent jitted vmapped solver (cache hits warm)


def _sweep_solver(n_sweeps: int):
    """Jitted ``vmap`` of the scan solver over the price axis, cached per
    ``n_sweeps`` so repeated sweeps retrace only on shape changes — a fresh
    ``jax.jit`` per call would recompile every time."""
    fn = _SWEEP_JIT.get(n_sweeps)
    if fn is None:
        import jax

        def theta_fn(e_bins, lam, delay_cost, slow, P, gamma, q_off, q_loc):
            return _solve_impl(
                e_bins, lam, delay_cost, slow, P, gamma, q_off, q_loc,
                n_sweeps=n_sweeps,
            )[1]

        fn = jax.jit(
            jax.vmap(theta_fn, in_axes=(None, 0, None, None, None, None, None, None))
        )
        _SWEEP_JIT[n_sweeps] = fn
    return fn


def value_iteration_sweep(
    calibration_scores: np.ndarray,
    ratios: Sequence[float],
    *,
    n_bins: int = 32,
    max_queue: int = 16,
    delay_cost: float = 0.05,
    bad_slowdown: float = 4.0,
    p_gb: float = 0.1,
    p_bg: float = 0.3,
    gamma: float = 0.9,
    n_sweeps: int = 64,
) -> np.ndarray:
    """Per-state thresholds for a whole ratio grid in one device call:
    ``vmap`` of the jitted scan over the ratio-derived offload prices.
    Returns ``theta`` of shape ``(len(ratios), max_queue+1, 2)``."""
    import jax.numpy as jnp

    e_bins = _estimate_bins(calibration_scores, n_bins)
    lams = np.asarray(
        [quantile_threshold(calibration_scores, r) for r in ratios], np.float32
    )
    Q = int(max_queue)
    q_idx = np.arange(Q + 1)
    fixed = (
        jnp.asarray(e_bins, jnp.float32),
        jnp.float32(delay_cost),
        jnp.asarray([1.0, float(bad_slowdown)], jnp.float32),
        jnp.asarray([[1.0 - p_gb, p_gb], [p_bg, 1.0 - p_bg]], jnp.float32),
        jnp.float32(gamma),
        jnp.asarray(np.minimum(q_idx + 1, Q), jnp.int32),
        jnp.asarray(np.maximum(q_idx - 1, 0), jnp.int32),
    )
    batched = _sweep_solver(int(n_sweeps))
    return np.asarray(
        np.asarray(batched(fixed[0], jnp.asarray(lams), *fixed[1:])), np.float64
    )


def value_iteration_ref(
    e_bins: np.ndarray,
    lam: float,
    *,
    max_queue: int = 16,
    delay_cost: float = 0.05,
    bad_slowdown: float = 4.0,
    p_gb: float = 0.1,
    p_bg: float = 0.3,
    gamma: float = 0.9,
    n_sweeps: int = 64,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pure-Python per-state reference solver (the benchmark baseline and
    the correctness oracle for the jitted scan)."""
    e = np.asarray(e_bins, np.float64)
    Q = int(max_queue)
    slow = [1.0, float(bad_slowdown)]
    P = [[1.0 - p_gb, p_gb], [p_bg, 1.0 - p_bg]]
    V = np.zeros((Q + 1, 2))

    def backup(V):
        theta = np.zeros_like(V)
        V_new = np.zeros_like(V)
        for q in range(Q + 1):
            for c in range(2):
                ev_off = sum(P[c][c2] * V[min(q + 1, Q), c2] for c2 in range(2))
                ev_loc = sum(P[c][c2] * V[max(q - 1, 0), c2] for c2 in range(2))
                th = lam + delay_cost * (q + 1) * slow[c] + gamma * (ev_loc - ev_off)
                theta[q, c] = th
                V_new[q, c] = float(np.mean(np.maximum(e - th, 0.0))) + gamma * ev_loc
        return V_new, theta

    theta = np.zeros_like(V)
    for _ in range(n_sweeps):
        V, _ = backup(V)
    _, theta = backup(V)
    return V, theta


def _ensure_jit() -> None:
    global _solve_jit
    if _solve_jit is None:
        import jax

        _solve_jit = jax.jit(_solve_impl, static_argnames=("n_sweeps",))


@register_policy("value_iteration")
class ValueIterationPolicy:
    """Serve-time MDP controller: offload iff estimate > ``theta[q, c]``.

    The threshold table comes from :func:`solve_value_iteration` (one jitted
    scan at construction / ``set_ratio``); ``state_probe`` is the runtime-
    injected zero-arg callable returning the observed ``(queue_depth,
    channel_state)`` at decision time.  Without a probe the policy serves
    from the ``(0, good)`` state — plain threshold behavior.
    """

    context_params = ("state_probe",)

    def __init__(
        self,
        calibration_scores: np.ndarray,
        ratio: float,
        max_queue: int = 16,
        delay_cost: float = 0.05,
        bad_slowdown: float = 4.0,
        p_gb: float = 0.1,
        p_bg: float = 0.3,
        gamma: float = 0.9,
        n_sweeps: int = 64,
        n_bins: int = 32,
        state_probe: Optional[Callable[[], Tuple[int, int]]] = None,
    ):
        _ensure_jit()
        self._cal = np.asarray(calibration_scores, np.float64)
        self.max_queue = int(max_queue)
        self.delay_cost = float(delay_cost)
        self.bad_slowdown = float(bad_slowdown)
        self.p_gb = float(p_gb)
        self.p_bg = float(p_bg)
        self.gamma = float(gamma)
        self.n_sweeps = int(n_sweeps)
        self.n_bins = int(n_bins)
        self.state_probe = state_probe
        self._e_bins = _estimate_bins(self._cal, self.n_bins)
        self.set_ratio(ratio)

    def set_ratio(self, ratio: float) -> None:
        self.ratio = float(np.clip(ratio, 0.0, 1.0))
        lam = quantile_threshold(self._cal, self.ratio)
        _, self.theta = solve_value_iteration(
            self._e_bins,
            lam,
            max_queue=self.max_queue,
            delay_cost=self.delay_cost,
            bad_slowdown=self.bad_slowdown,
            p_gb=self.p_gb,
            p_bg=self.p_bg,
            gamma=self.gamma,
            n_sweeps=self.n_sweeps,
        )

    def _state(self) -> Tuple[int, int]:
        if self.state_probe is None:
            return 0, 0
        q, c = self.state_probe()
        return min(max(int(q), 0), self.max_queue), int(np.clip(int(c), 0, 1))

    def decide(self, estimate: float) -> bool:
        q, c = self._state()
        return bool(float(estimate) > self.theta[q, c])

    def decide_batch(self, estimates: np.ndarray) -> np.ndarray:
        # the probed state evolves as upstream dispatch fills queues, so
        # batches decide sequentially like the other stateful policies
        return decide_sequential(self, estimates)

    def spec(self) -> Dict[str, Any]:
        return {
            "max_queue": self.max_queue,
            "delay_cost": self.delay_cost,
            "bad_slowdown": self.bad_slowdown,
            "p_gb": self.p_gb,
            "p_bg": self.p_bg,
            "gamma": self.gamma,
            "n_sweeps": self.n_sweeps,
            "n_bins": self.n_bins,
        }
