"""Network link models — size-dependent transmission delay over the
device→edge uplink.

The paper's deployment setting puts the strong detector behind a
rate-constrained wireless link; `repro.runtime` previously collapsed that
whole path into one scalar latency draw.  A :class:`NetworkLink` makes the
transmission leg explicit: a frame of ``size_bits`` entering the link at
time ``t`` occupies it for ``size_bits / bandwidth_at(t) + propagation``
time units.  Three models:

- :class:`ConstantRateLink` — fixed bandwidth (the textbook M/D/1 front),
- :class:`TraceBandwidthLink` — piecewise-constant bandwidth from a
  ``(times, bandwidths)`` trace, for replaying measured network conditions,
- :class:`GilbertElliottLink` — the classic seeded two-state (good/bad)
  Markov channel; the bad state throttles bandwidth, so congestion arrives
  in bursts the way wireless fading does.

Everything is manually clocked: links never read the wall clock, and the
Gilbert–Elliott state is a pure function of the time slot (materialized
lazily, cached forever), so any sequence of queries — including *future*
probes from queue-delay predictors — is deterministic under a seed.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

#: channel-state labels (``state_at`` return values)
CHANNEL_GOOD = 0
CHANNEL_BAD = 1


class NetworkLink:
    """Base link: fixed ``bandwidth`` (bits per time unit) + ``propagation``
    delay.  Subclasses override :meth:`bandwidth_at` (and optionally
    :meth:`state_at`) to make the rate time- or state-dependent."""

    def __init__(self, bandwidth: float, *, propagation: float = 0.0):
        if bandwidth <= 0.0:
            raise ValueError(f"bandwidth must be > 0, got {bandwidth}")
        if propagation < 0.0:
            raise ValueError(f"propagation must be >= 0, got {propagation}")
        self.bandwidth = float(bandwidth)
        self.propagation = float(propagation)

    def bandwidth_at(self, now: float) -> float:
        """Instantaneous link rate in bits per time unit."""
        return self.bandwidth

    def state_at(self, now: float) -> int:
        """Channel state at ``now`` (``CHANNEL_GOOD`` unless the model has
        one); queue-aware controllers condition on this."""
        return CHANNEL_GOOD

    def transmit_delay(self, size_bits: float, now: float) -> float:
        """Time to push ``size_bits`` through the link starting at ``now``."""
        if size_bits < 0.0:
            raise ValueError(f"size_bits must be >= 0, got {size_bits}")
        return float(size_bits) / self.bandwidth_at(now) + self.propagation

    def spec(self) -> dict:
        return {"bandwidth": self.bandwidth, "propagation": self.propagation}


class ConstantRateLink(NetworkLink):
    """Fixed-rate link — ``NetworkLink`` under its canonical name."""


class TraceBandwidthLink(NetworkLink):
    """Piecewise-constant bandwidth replayed from a trace.

    ``times`` are the sorted segment start times; ``bandwidths[i]`` holds
    from ``times[i]`` until the next start (the last segment holds forever,
    and queries before ``times[0]`` see ``bandwidths[0]``).
    """

    def __init__(
        self,
        times: Sequence[float],
        bandwidths: Sequence[float],
        *,
        propagation: float = 0.0,
    ):
        t = np.asarray(times, np.float64)
        bw = np.asarray(bandwidths, np.float64)
        if t.ndim != 1 or t.size == 0 or t.shape != bw.shape:
            raise ValueError(
                f"times/bandwidths must be equal-length 1-D, got {t.shape}/{bw.shape}"
            )
        if np.any(np.diff(t) < 0):
            raise ValueError("trace times must be sorted ascending")
        if np.any(bw <= 0.0):
            raise ValueError("trace bandwidths must all be > 0")
        super().__init__(float(bw[0]), propagation=propagation)
        self._times = t
        self._bw = bw

    def bandwidth_at(self, now: float) -> float:
        i = int(np.searchsorted(self._times, now, side="right")) - 1
        return float(self._bw[max(i, 0)])

    def spec(self) -> dict:
        return {
            "times": self._times.tolist(),
            "bandwidths": self._bw.tolist(),
            "propagation": self.propagation,
        }


class GilbertElliottLink(NetworkLink):
    """Seeded two-state Markov (Gilbert–Elliott) channel.

    Time is sliced into ``slot``-length intervals; within a slot the state
    is constant, and at each slot boundary the chain moves good→bad with
    probability ``p_gb`` and bad→good with probability ``p_bg``.  The state
    sequence is materialized lazily from a seeded generator and cached, so
    ``state_at``/``bandwidth_at`` are pure functions of time — probing the
    future (queue predictors do) never perturbs the trajectory.

    ``bad_bandwidth`` defaults to ``bandwidth / 10`` — a deep fade rather
    than a hard outage, so frames in flight still drain, just slowly.

    The cache grows one entry per slot up to the furthest time ever
    queried; ``max_slots`` (default 2e6) bounds it so a runaway query (e.g.
    probing the channel at a drain sentinel like ``t=1e12``) raises a clear
    ``ValueError`` instead of consuming unbounded time and memory.  Long-
    horizon simulations should raise ``slot`` (coarser fades) or
    ``max_slots`` explicitly.
    """

    def __init__(
        self,
        bandwidth: float,
        *,
        bad_bandwidth: float = None,
        p_gb: float = 0.1,
        p_bg: float = 0.3,
        slot: float = 1.0,
        propagation: float = 0.0,
        seed: int = 0,
        max_slots: int = 2_000_000,
    ):
        super().__init__(bandwidth, propagation=propagation)
        self.bad_bandwidth = (
            float(bad_bandwidth) if bad_bandwidth is not None else self.bandwidth / 10.0
        )
        if self.bad_bandwidth <= 0.0:
            raise ValueError(f"bad_bandwidth must be > 0, got {self.bad_bandwidth}")
        for name, p in (("p_gb", p_gb), ("p_bg", p_bg)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if slot <= 0.0:
            raise ValueError(f"slot must be > 0, got {slot}")
        self.p_gb = float(p_gb)
        self.p_bg = float(p_bg)
        self.slot = float(slot)
        self.seed = int(seed)
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.max_slots = int(max_slots)
        self._rng = np.random.default_rng(seed)
        self._states: List[int] = [CHANNEL_GOOD]  # slot index -> state

    def _materialize(self, slot_idx: int) -> None:
        if slot_idx >= self.max_slots:
            raise ValueError(
                f"channel query at slot {slot_idx} exceeds max_slots="
                f"{self.max_slots} (t={slot_idx * self.slot:g}); raise `slot` "
                f"or `max_slots` for longer horizons"
            )
        if len(self._states) > slot_idx:
            return
        # bulk-draw the uniforms; the (state-dependent) transition walk
        # itself stays sequential but touches each slot exactly once ever
        us = self._rng.uniform(size=slot_idx + 1 - len(self._states))
        s = self._states[-1]
        for u in us:
            if s == CHANNEL_GOOD:
                s = CHANNEL_BAD if u < self.p_gb else CHANNEL_GOOD
            else:
                s = CHANNEL_GOOD if u < self.p_bg else CHANNEL_BAD
            self._states.append(s)

    def state_at(self, now: float) -> int:
        idx = max(int(np.floor(now / self.slot)), 0)
        self._materialize(idx)
        return self._states[idx]

    def bandwidth_at(self, now: float) -> float:
        return (
            self.bandwidth
            if self.state_at(now) == CHANNEL_GOOD
            else self.bad_bandwidth
        )

    def stationary_bad_fraction(self) -> float:
        """Long-run fraction of time in the bad state (the chain's
        stationary distribution) — MDP controllers use it as the channel
        prior when they cannot observe the state."""
        denom = self.p_gb + self.p_bg
        return self.p_gb / denom if denom > 0.0 else 0.0

    def spec(self) -> dict:
        return {
            "bandwidth": self.bandwidth,
            "bad_bandwidth": self.bad_bandwidth,
            "p_gb": self.p_gb,
            "p_bg": self.p_bg,
            "slot": self.slot,
            "propagation": self.propagation,
            "seed": self.seed,
            "max_slots": self.max_slots,
        }
