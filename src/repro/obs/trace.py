"""`Tracer` — nested spans over an injectable clock, exported as
Chrome-trace JSON (loads in Perfetto / ``chrome://tracing``).

The serve stack has two time regimes and one tracer serves both:

- **Simulation**: everything is stamped from the runtime's
  :class:`~repro.runtime.clock.ManualClock` — a run is deterministic, so
  the trace is *byte-identical* across repeats with the same seed.  The
  runtimes call :meth:`Tracer.bind_clock` when they bind their own clock.
- **Benchmarks / wall-clock**: with no bound clock the tracer falls back
  to ``time.perf_counter``.

Spans are recorded as Chrome ``ph="X"`` *complete* events (one event
carrying ``ts`` + ``dur``), which lets the simulator synthesize spans for
things it already knows the full extent of (an edge job's
queue/transmit/service decomposition is known at admit time) without a
begin/end protocol.  Nesting is by containment per ``tid`` — Perfetto
stacks overlapping same-thread slices automatically, so a session flush
span on the session track visually contains its per-frame dispatch
instants, and an edge's ``offload`` span contains its
``queue``/``transmit``/``service`` children.

Timestamps: Chrome traces use microseconds.  Simulation time units are
treated as milliseconds (the runtime's latency models speak ms), so
``ts = clock() * 1e3 * 1e3``; wall-clock spans use seconds → µs.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional

#: trace ts is µs; manual-clock units are ms → µs
SIM_TS_SCALE = 1e3
#: perf_counter is seconds → µs
WALL_TS_SCALE = 1e6


class Tracer:
    """Collects spans/instants and serializes Chrome-trace JSON.

    ``clock`` is any zero-arg callable returning the current time;
    ``ts_scale`` converts that unit into microseconds.  ``max_events``
    bounds memory on long runs (oldest events are *not* rotated — the
    tracer simply stops recording and counts the overflow, keeping the
    head of the timeline which is what regressions get diagnosed from).
    """

    def __init__(
        self,
        clock: Optional[Callable[[], float]] = None,
        ts_scale: Optional[float] = None,
        max_events: int = 200_000,
    ):
        if clock is None:
            clock = time.perf_counter
            ts_scale = WALL_TS_SCALE if ts_scale is None else ts_scale
        else:
            ts_scale = SIM_TS_SCALE if ts_scale is None else ts_scale
        self.clock = clock
        self.ts_scale = float(ts_scale)
        self.max_events = int(max_events)
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self._thread_names: Dict[int, str] = {}
        self._seq = 0

    def next_id(self) -> int:
        """Monotone id for async span groups — unique within the tracer,
        deterministic (allocation order is the recording order)."""
        self._seq += 1
        return self._seq

    # ------------------------------------------------------------ recording

    def bind_clock(
        self, clock: Callable[[], float], ts_scale: float = SIM_TS_SCALE
    ) -> None:
        """Swap the time source (runtimes attach their ManualClock here)."""
        self.clock = clock
        self.ts_scale = float(ts_scale)

    def thread_name(self, tid: int, name: str) -> None:
        """Name a track (Chrome ``M``/``thread_name`` metadata event)."""
        self._thread_names[int(tid)] = str(name)

    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def add_span(
        self,
        name: str,
        t0: float,
        t1: float,
        tid: int = 0,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """A complete span over ``[t0, t1]`` in clock units — the way the
        simulator emits spans whose extent it already knows."""
        ev: Dict[str, Any] = {
            "name": str(name),
            "ph": "X",
            "ts": float(t0) * self.ts_scale,
            "dur": max(float(t1) - float(t0), 0.0) * self.ts_scale,
            "pid": 0,
            "tid": int(tid),
        }
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(
        self,
        name: str,
        t: Optional[float] = None,
        tid: int = 0,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """A zero-duration marker (``ph="i"``, thread-scoped)."""
        ev: Dict[str, Any] = {
            "name": str(name),
            "ph": "i",
            "s": "t",
            "ts": float(self.clock() if t is None else t) * self.ts_scale,
            "pid": 0,
            "tid": int(tid),
        }
        if args:
            ev["args"] = args
        self._push(ev)

    def add_async_span(
        self,
        name: str,
        t0: float,
        t1: float,
        id: int,
        cat: str = "offload",
        tid: int = 0,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """A begin/end async pair (``ph="b"``/``"e"``).  Async events with
        the same ``(cat, id)`` nest by b/e ordering on their own lane, so
        concurrent edge jobs — whose extents partially overlap and would
        mis-nest as same-track complete events — each get a correctly
        nested ``offload ⊃ queue/transmit/service`` group."""
        base = {"cat": str(cat), "id": int(id), "pid": 0, "tid": int(tid)}
        b: Dict[str, Any] = {
            "name": str(name), "ph": "b",
            "ts": float(t0) * self.ts_scale, **base,
        }
        if args:
            b["args"] = args
        self._push(b)
        self._push(
            {
                "name": str(name), "ph": "e",
                "ts": float(t1) * self.ts_scale, **base,
            }
        )

    @contextmanager
    def span(self, name: str, tid: int = 0, **args: Any):
        """Clock-stamped span around a block (used where the extent is not
        known up front — wall-clock benchmark sections, adaptive updates)."""
        t0 = self.clock()
        try:
            yield
        finally:
            self.add_span(name, t0, self.clock(), tid=tid, args=args or None)

    # ------------------------------------------------------------- exporting

    def to_chrome(self) -> Dict[str, Any]:
        """``{"traceEvents": [...]}`` — metadata events first, then spans
        in recording order (stable: recording order is deterministic under
        the manual clock)."""
        meta = [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": 0,
                "tid": tid,
                "args": {"name": name},
            }
            for tid, name in sorted(self._thread_names.items())
        ]
        events = meta + self.events
        if self.dropped:
            events.append(
                {
                    "name": "trace_overflow",
                    "ph": "M",
                    "pid": 0,
                    "tid": 0,
                    "args": {"dropped": self.dropped},
                }
            )
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1, sort_keys=True)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0
        self._thread_names.clear()
